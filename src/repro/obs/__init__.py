"""Observability: superstep tracing, phase metrics, exporters, the
BSP-vs-hybrid report CLI, and the one injectable clock.

Layout (each submodule is importable on its own; nothing on the engines'
hot path imports this package — hooks and wrappers are opt-in):

* :mod:`repro.obs.clock`   — the injectable monotonic / perf clock every
  time-consuming subsystem (ft, checkpoint, serve) routes through.
* :mod:`repro.obs.trace`   — span tracer, the executor ``TraceHook``, the
  phased per-phase profiler, and exchange-bytes accounting.
* :mod:`repro.obs.metrics` — the typed metrics registry unifying the
  engine ``Counters``, straggler / checkpoint / serving statistics.
* :mod:`repro.obs.export`  — Chrome trace-event JSON (Perfetto-loadable)
  and the machine-readable profile blob.
* :mod:`repro.obs.report`  — ``python -m repro.obs.report``: the paper's
  headline exchange-vs-compute comparison, measured.

``from repro.obs import clock`` is the only import light enough for
leaf modules (it pulls nothing but stdlib ``time``); everything else is
loaded lazily through ``__getattr__`` so wiring ``obs`` into a module
costs nothing until a tracer or registry is actually constructed.
"""

from __future__ import annotations

import importlib

from repro.obs import clock  # noqa: F401  (stdlib-only; safe everywhere)

_SUBMODULES = ("trace", "metrics", "export", "report")

__all__ = ["clock", *_SUBMODULES]


def __getattr__(name: str):
    if name in _SUBMODULES:
        return importlib.import_module(f"repro.obs.{name}")
    raise AttributeError(f"module 'repro.obs' has no attribute {name!r}")
