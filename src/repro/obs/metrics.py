"""The typed metrics registry.

Before this module, every subsystem kept its own ad-hoc counters: the
engines' paper :class:`~repro.core.runtime.Counters` dataclass, the
straggler mitigator's ``redispatches`` / ``duplicates_suppressed`` ints,
the async checkpointer's ``bytes_written`` / ``save_seconds``, the serving
layer's ``trace_counts`` dict.  Each had its own shape, none exported.
A :class:`MetricsRegistry` is the one named, typed, JSON-round-trippable
surface they all land on:

* **counter** — cumulative, monotonically non-decreasing float
  (:meth:`MetricsRegistry.inc`);
* **gauge** — a point-in-time scalar or vector
  (:meth:`MetricsRegistry.set_gauge`; vectors keep per-partition signals
  like ``pseudo_supersteps`` addressable by one name);
* **histogram** — bucketed distribution with count / sum / min / max
  (:meth:`MetricsRegistry.observe`; the serving layer's arrival-gap and
  batch-size distributions that lane-width autotuning needs).

``record_engine_counters`` / ``record_straggler`` / ``record_checkpointer``
/ ``record_serve`` snapshot the legacy carriers into a registry without
touching their hot paths; :func:`save_registry` / :func:`load_registry`
round-trip everything through JSON.
"""

from __future__ import annotations

import dataclasses
import json
import math
import os
from typing import Any, Iterable

__all__ = ["Metric", "Histogram", "MetricsRegistry", "save_registry",
           "load_registry", "record_engine_counters", "record_straggler",
           "record_checkpointer", "record_serve"]

#: default histogram bucket upper bounds: log-spaced, wide enough for both
#: sub-millisecond inter-arrival gaps and thousand-lane batch sizes.
DEFAULT_BOUNDS = tuple(10.0 ** (e / 2) for e in range(-8, 9))  # 1e-4 .. 1e4


@dataclasses.dataclass
class Histogram:
    """Fixed-bound bucketed distribution.  ``counts[i]`` tallies values
    ``<= bounds[i]`` (first matching bucket); the last bucket is the
    +inf overflow.  Sum/min/max ride along so means and extremes survive
    the bucketing."""

    bounds: tuple[float, ...] = DEFAULT_BOUNDS
    counts: list[int] = None  # type: ignore[assignment]
    count: int = 0
    sum: float = 0.0
    min: float = math.inf
    max: float = -math.inf

    def __post_init__(self):
        if self.counts is None:
            self.counts = [0] * (len(self.bounds) + 1)

    def observe(self, v: float) -> None:
        v = float(v)
        i = next((i for i, b in enumerate(self.bounds) if v <= b),
                 len(self.bounds))
        self.counts[i] += 1
        self.count += 1
        self.sum += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def to_value(self) -> dict:
        return {"bounds": list(self.bounds), "counts": list(self.counts),
                "count": self.count, "sum": self.sum,
                "min": None if self.count == 0 else self.min,
                "max": None if self.count == 0 else self.max}

    @staticmethod
    def from_value(v: dict) -> "Histogram":
        return Histogram(bounds=tuple(v["bounds"]),
                         counts=list(v["counts"]), count=int(v["count"]),
                         sum=float(v["sum"]),
                         min=math.inf if v["min"] is None else v["min"],
                         max=-math.inf if v["max"] is None else v["max"])


@dataclasses.dataclass
class Metric:
    """One named metric.  ``value`` is a float (counter / scalar gauge), a
    list of floats (vector gauge), or a :class:`Histogram`."""

    name: str
    kind: str                   # 'counter' | 'gauge' | 'histogram'
    value: Any
    unit: str = ""


class MetricsRegistry:
    """Name -> :class:`Metric`, with kind enforcement: a name registered as
    a counter stays a counter (re-registering it as a gauge raises, which
    catches two subsystems colliding on a name)."""

    def __init__(self):
        self._metrics: dict[str, Metric] = {}

    # -- write -------------------------------------------------------------

    def _slot(self, name: str, kind: str, unit: str) -> Metric:
        m = self._metrics.get(name)
        if m is None:
            init = Histogram() if kind == "histogram" else 0.0
            m = Metric(name, kind, init, unit)
            self._metrics[name] = m
        elif m.kind != kind:
            raise ValueError(f"metric {name!r} is a {m.kind}, not a {kind}")
        return m

    def inc(self, name: str, v: float = 1.0, unit: str = "") -> None:
        """Add to a cumulative counter (negative increments are a bug)."""
        if v < 0:
            raise ValueError(f"counter {name!r}: negative increment {v}")
        self._slot(name, "counter", unit).value += float(v)

    def set_counter(self, name: str, v: float, unit: str = "") -> None:
        """Set a counter to an absolute cumulative value (snapshotting a
        legacy carrier that already accumulated it)."""
        self._slot(name, "counter", unit).value = float(v)

    def set_gauge(self, name: str, v, unit: str = "") -> None:
        """Set a gauge; scalars stay floats, iterables become list gauges
        (per-partition vectors keep one name)."""
        m = self._slot(name, "gauge", unit)
        if isinstance(v, (int, float)):
            m.value = float(v)
        else:
            m.value = [float(x) for x in v]

    def observe(self, name: str, v: float, unit: str = "",
                bounds: Iterable[float] | None = None) -> None:
        """Record one observation into a histogram (created on first use
        with ``bounds`` or the defaults)."""
        m = self._metrics.get(name)
        if m is None and bounds is not None:
            m = Metric(name, "histogram", Histogram(tuple(bounds)), unit)
            self._metrics[name] = m
        self._slot(name, "histogram", unit)
        self._metrics[name].value.observe(v)

    # -- read --------------------------------------------------------------

    def value(self, name: str, default=None):
        m = self._metrics.get(name)
        return default if m is None else m.value

    def histogram(self, name: str) -> Histogram | None:
        m = self._metrics.get(name)
        if m is not None and m.kind != "histogram":
            raise ValueError(f"metric {name!r} is a {m.kind}")
        return None if m is None else m.value

    def names(self) -> list[str]:
        return sorted(self._metrics)

    def snapshot(self) -> dict[str, Metric]:
        return dict(self._metrics)

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def __len__(self) -> int:
        return len(self._metrics)

    # -- round trip --------------------------------------------------------

    def to_dict(self) -> dict:
        out = {}
        for name, m in sorted(self._metrics.items()):
            v = m.value.to_value() if m.kind == "histogram" else m.value
            out[name] = {"kind": m.kind, "value": v, "unit": m.unit}
        return out

    @staticmethod
    def from_dict(d: dict) -> "MetricsRegistry":
        reg = MetricsRegistry()
        for name, rec in d.items():
            v = (Histogram.from_value(rec["value"])
                 if rec["kind"] == "histogram" else rec["value"])
            reg._metrics[name] = Metric(name, rec["kind"], v,
                                        rec.get("unit", ""))
        return reg


def save_registry(reg: MetricsRegistry, path: str) -> None:
    """Atomically persist a registry as JSON (tmp + rename, so a reader
    never sees a torn file)."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(reg.to_dict(), f, indent=1, sort_keys=True)
    os.replace(tmp, path)


def load_registry(path: str) -> MetricsRegistry:
    with open(path) as f:
        return MetricsRegistry.from_dict(json.load(f))


# ---------------------------------------------------------------------------
# adapters: snapshot the legacy per-subsystem carriers into a registry.
# Pull-based on purpose — the hot paths keep their cheap native counters
# and the registry reads them at observation points, so the disabled path
# costs nothing.
# ---------------------------------------------------------------------------

def record_engine_counters(reg: MetricsRegistry, counters,
                           prefix: str = "engine") -> None:
    """The paper's :class:`~repro.core.runtime.Counters`: scalar totals as
    counters, the per-partition pseudo-superstep vector as a list gauge."""
    import numpy as np

    reg.set_counter(f"{prefix}.iterations",
                    float(np.asarray(counters.iterations)))
    reg.set_counter(f"{prefix}.net_messages",
                    float(np.asarray(counters.net_messages)), unit="msgs")
    reg.set_counter(f"{prefix}.net_local_messages",
                    float(np.asarray(counters.net_local_messages)),
                    unit="msgs")
    reg.set_counter(f"{prefix}.mem_messages",
                    float(np.asarray(counters.mem_messages)), unit="msgs")
    reg.set_gauge(f"{prefix}.pseudo_supersteps",
                  np.asarray(counters.pseudo_supersteps).tolist())


def record_straggler(reg: MetricsRegistry, mit,
                     prefix: str = "straggler") -> None:
    """:class:`~repro.ft.straggler.StragglerMitigator` statistics."""
    reg.set_counter(f"{prefix}.redispatches", float(mit.redispatches))
    reg.set_counter(f"{prefix}.duplicates_suppressed",
                    float(mit.duplicates_suppressed))
    reg.set_gauge(f"{prefix}.deadline_seconds", float(mit.deadline),
                  unit="s")


def record_checkpointer(reg: MetricsRegistry, ck,
                        prefix: str = "checkpoint") -> None:
    """:class:`~repro.checkpoint.ckpt.AsyncCheckpointer` write costs."""
    reg.set_counter(f"{prefix}.bytes_written", float(ck.bytes_written),
                    unit="B")
    reg.set_counter(f"{prefix}.save_seconds", float(ck.save_seconds),
                    unit="s")


def record_serve(reg: MetricsRegistry, engine,
                 prefix: str = "serve") -> None:
    """The serving layer's compile-cache pressure: one counter per
    (program, lane-width) executable traced."""
    for (key, k), n in sorted(engine.trace_counts.items()):
        name = key[0] if isinstance(key, tuple) else key
        reg.set_counter(f"{prefix}.compiles.{name}.K{k}", float(n))
