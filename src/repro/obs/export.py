"""Exporters: Chrome trace-event JSON and the machine-readable profile blob.

``chrome_trace`` serializes a :class:`~repro.obs.trace.Tracer` into the
Chrome trace-event format (the JSON array flavour wrapped in a
``traceEvents`` object), loadable directly in Perfetto / ``chrome://tracing``:
spans become complete events (``ph="X"`` with ``ts``/``dur`` in
microseconds), instants become ``ph="i"``, and named tracks get
``thread_name`` metadata events.  Events are emitted sorted by
``(pid, tid, ts)`` so timestamps are monotone within every track.

``profile_blob`` bundles the same spans with a metrics-registry snapshot
and per-superstep records into one JSON document for scripted analysis —
the ``BENCH_obs`` benchmark and the report CLI both write this shape.
"""

from __future__ import annotations

import json
import os
from typing import Any, Iterable

__all__ = ["chrome_trace", "write_chrome_trace", "profile_blob",
           "write_profile"]

_PID = 0  # single-process reproduction: one Chrome "process" track group


def _event(span, pid: int = _PID) -> dict:
    ev = {
        "name": span.name,
        "cat": span.cat or "default",
        "ph": span.ph,
        "ts": span.ts * 1e6,          # trace-event timestamps are in us
        "pid": pid,
        "tid": span.tid,
        "args": dict(span.args),
    }
    if span.ph == "X":
        ev["dur"] = span.dur * 1e6
    elif span.ph == "i":
        ev["s"] = "t"                 # instant scoped to its thread/track
    return ev


def chrome_trace(tracer, pid: int = _PID) -> dict:
    """The tracer's spans as a Chrome trace-event JSON object."""
    events = [_event(s, pid) for s in tracer.spans]
    # Monotone per track: chrome://tracing tolerates disorder, the schema
    # test (and some Perfetto importers) do not.
    events.sort(key=lambda e: (e["pid"], e["tid"], e["ts"]))
    meta = [{"name": "thread_name", "ph": "M", "pid": pid, "tid": tid,
             "args": {"name": name}}
            for tid, name in sorted(tracer.track_names.items())]
    return {"traceEvents": meta + events, "displayTimeUnit": "ms"}


def write_chrome_trace(tracer, path: str, pid: int = _PID) -> None:
    """Atomically write :func:`chrome_trace` JSON to ``path``."""
    _dump(chrome_trace(tracer, pid), path)


def _record_dict(rec) -> dict:
    """A :class:`~repro.obs.trace.SuperstepRecord` as plain JSON."""
    return {
        "superstep": rec.superstep,
        "barriers": rec.barriers,
        "exchange_bytes": rec.exchange_bytes,
        "phase_seconds": dict(rec.phase_seconds),
        "total_seconds": rec.total_seconds,
        "local_compute_fraction": rec.local_compute_fraction,
        "pseudo_supersteps": rec.pseudo_supersteps,
        "net_messages": rec.net_messages,
        "net_local_messages": rec.net_local_messages,
        "mem_messages": rec.mem_messages,
    }


def profile_blob(tracer=None, registry=None,
                 runs: Iterable[Any] = (), meta: dict | None = None) -> dict:
    """One machine-readable JSON document: trace events + registry snapshot
    + per-engine superstep records (:class:`~repro.obs.trace.PhasedRunResult`
    instances in ``runs``)."""
    blob: dict[str, Any] = {"schema": "repro.obs.profile/1",
                            "meta": dict(meta or {})}
    if tracer is not None:
        blob["trace"] = chrome_trace(tracer)
    if registry is not None:
        blob["metrics"] = registry.to_dict()
    engines = {}
    for run in runs:
        engines[run.engine] = {
            "iterations": run.iterations,
            "total_barriers": run.total_barriers,
            "total_exchange_bytes": run.total_exchange_bytes,
            "mean_local_compute_fraction": run.mean_local_compute_fraction,
            "supersteps": [_record_dict(r) for r in run.records],
        }
    if engines:
        blob["engines"] = engines
    return blob


def write_profile(blob: dict, path: str) -> None:
    """Atomically write a :func:`profile_blob` document to ``path``."""
    _dump(blob, path)


def _dump(obj: dict, path: str) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(obj, f, indent=1, sort_keys=False)
    os.replace(tmp, path)
