"""``python -m repro.obs.report`` — the exchange-vs-compute profile.

The paper's headline claim is architectural: GraphHP pays one global
barrier + one exchange per *global iteration* and pushes the rest of the
work into barrier-free local pseudo-supersteps, where Hama pays a barrier
and an exchange per *superstep*.  This CLI measures that claim end to end
on one shared graph: it runs each requested engine through the phased
profiler (:func:`repro.obs.trace.phased_run` — the superstep decomposed
into its composable phase functions, each jitted and timed separately)
and prints, per superstep, the exchange bytes put on the wire, the global
barrier count, and the fraction of wall time spent computing rather than
exchanging/delivering.

    PYTHONPATH=src python -m repro.obs.report --engines bsp,hybrid

The summary cross-checks the two engines: same converged state (PageRank
fixed point to the run tolerance), hybrid strictly fewer global barriers.
``--profile`` / ``--trace`` persist the same data as a machine-readable
profile blob and a Perfetto-loadable Chrome trace.
"""

from __future__ import annotations

import argparse
from typing import Sequence

__all__ = ["build_fixture", "run_report", "main"]

N_PARTITIONS = 8
AVG_DEGREE = 8


def build_fixture(n_vertices: int, tolerance: float, seed: int = 0):
    """The shared bench graph: PageRank on an R-MAT graph, dense delivery
    (interpret-mode Pallas would profile the interpreter, not the
    engines — same choice as ``benchmarks/ft_bench.py``)."""
    from repro.core import build_partitioned_graph, hash_partition
    from repro.core.apps import IncrementalPageRank
    from repro.core.apps.pagerank import pagerank_edge_weights
    from repro.data.graphs import rmat_graph

    edges, n = rmat_graph(n_vertices, avg_degree=AVG_DEGREE, seed=seed)
    part = hash_partition(n, N_PARTITIONS, seed=0)
    w = pagerank_edge_weights(edges, n)
    graph = build_partitioned_graph(edges, n, part, weights=w,
                                    build_ell=False)
    return graph, IncrementalPageRank(tolerance=tolerance), len(edges)


def _fmt_bytes(b: int) -> str:
    if b >= 2**20:
        return f"{b / 2**20:.2f}MiB"
    if b >= 2**10:
        return f"{b / 2**10:.1f}KiB"
    return f"{b}B"


def _print_engine(result) -> None:
    print(f"\n[{result.engine}] {result.iterations} supersteps, "
          f"{result.total_barriers} global barriers, "
          f"{_fmt_bytes(result.total_exchange_bytes)} exchanged, "
          f"mean local-compute fraction "
          f"{result.mean_local_compute_fraction:.3f}")
    hdr = (f"{'superstep':>9}  {'exch_bytes':>10}  {'barriers':>8}  "
           f"{'local_frac':>10}  {'pseudo':>6}  {'net_msgs':>9}  "
           f"{'wall_ms':>8}")
    print(hdr)
    print("-" * len(hdr))
    for r in result.records:
        print(f"{r.superstep:>9}  {r.exchange_bytes:>10}  {r.barriers:>8}  "
              f"{r.local_compute_fraction:>10.3f}  "
              f"{r.pseudo_supersteps:>6}  {r.net_messages:>9}  "
              f"{r.total_seconds * 1e3:>8.2f}")


def run_report(engines: Sequence[str], n_vertices: int = 2_000,
               tolerance: float = 1e-6, max_iters: int = 200,
               max_local_steps: int = 100_000, tracer=None) -> dict:
    """Run each engine through the phased profiler on the shared fixture;
    returns ``{engine: PhasedRunResult}`` plus cross-checks under the
    ``"checks"`` key."""
    import numpy as np

    from repro.obs.trace import phased_run

    graph, prog, n_edges = build_fixture(n_vertices, tolerance)
    print(f"fixture: PageRank, {n_edges} edges / {n_vertices} vertices / "
          f"{N_PARTITIONS} partitions, tolerance {tolerance:g}")

    results = {}
    for tid, engine in enumerate(engines):
        if tracer is not None:
            tracer.name_track(tid, engine)
        results[engine] = phased_run(
            graph, prog, engine, None, tracer=tracer, tid=tid,
            use_ell=False, max_iters=max_iters,
            max_local_steps=max_local_steps)
        _print_engine(results[engine])

    checks = {}
    if "bsp" in results and "hybrid" in results:
        b, h = results["bsp"], results["hybrid"]
        # Both engines stop at the same residual-tolerance fixed point but
        # flush deltas on different schedules, so the converged ranks agree
        # to a small relative error, not bit-for-bit.
        mask = np.asarray(graph.vertex_mask)
        rb = np.asarray(b.es.state["rank"])[mask]
        rh = np.asarray(h.es.state["rank"])[mask]
        same = bool(np.allclose(rb, rh, rtol=1e-2, atol=10 * tolerance))
        checks["same_converged_state"] = same
        checks["hybrid_fewer_barriers"] = h.total_barriers < b.total_barriers
        checks["hybrid_fewer_exchange_bytes"] = (
            h.total_exchange_bytes < b.total_exchange_bytes)
        print(f"\nsame converged state (rank rtol 1%): {same}")
        print(f"global barriers: hybrid {h.total_barriers} vs "
              f"bsp {b.total_barriers} "
              f"({'fewer' if checks['hybrid_fewer_barriers'] else 'NOT fewer'})")
        print(f"exchange bytes:  hybrid "
              f"{_fmt_bytes(h.total_exchange_bytes)} vs "
              f"bsp {_fmt_bytes(b.total_exchange_bytes)}")
        print(f"local-compute fraction: hybrid "
              f"{h.mean_local_compute_fraction:.3f} vs "
              f"bsp {b.mean_local_compute_fraction:.3f}")
    results["checks"] = checks
    return results


def main(argv: Sequence[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="BSP-vs-hybrid exchange/compute profile on one graph")
    ap.add_argument("--engines", default="bsp,hybrid",
                    help="comma-separated subset of {bsp,hybrid}")
    ap.add_argument("--vertices", type=int, default=2_000)
    ap.add_argument("--tolerance", type=float, default=1e-6)
    ap.add_argument("--max-iters", type=int, default=200)
    ap.add_argument("--profile", default=None,
                    help="write the machine-readable profile blob here")
    ap.add_argument("--trace", default=None,
                    help="write a Chrome trace-event JSON here")
    args = ap.parse_args(argv)

    engines = [e.strip() for e in args.engines.split(",") if e.strip()]
    tracer = None
    if args.trace or args.profile:
        from repro.obs.trace import Tracer
        tracer = Tracer()

    results = run_report(engines, n_vertices=args.vertices,
                         tolerance=args.tolerance, max_iters=args.max_iters,
                         tracer=tracer)
    checks = results.pop("checks")

    if args.trace or args.profile:
        from repro.obs.export import (profile_blob, write_chrome_trace,
                                      write_profile)
        if args.trace:
            write_chrome_trace(tracer, args.trace)
            print(f"wrote {args.trace}")
        if args.profile:
            meta = {"fixture": "pagerank_rmat", "vertices": args.vertices,
                    "tolerance": args.tolerance, "checks": checks}
            write_profile(profile_blob(tracer=tracer,
                                       runs=results.values(), meta=meta),
                          args.profile)
            print(f"wrote {args.profile}")

    return 0 if all(checks.values()) else 1


if __name__ == "__main__":
    raise SystemExit(main())
