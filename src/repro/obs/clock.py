"""The one injectable clock.

``ft/heartbeat.py``, ``ft/straggler.py``, ``checkpoint/ckpt.py`` and
``serve/engine.py`` all need wall time — heartbeat deadlines, straggler
deadlines, checkpoint snapshot billing, request inter-arrival gaps.  Each
used to grab ``time.monotonic`` / ``time.perf_counter`` directly, so a
deterministic test had to monkeypatch (or thread a ``clock=`` kwarg into)
every module separately.  They now all read *this* module's
:func:`monotonic` / :func:`perf_counter`, which dispatch through one
installable backend:

    with obs.clock.fake() as fc:
        mon = HeartbeatMonitor(4)      # reads the fake transparently
        fc.advance(30.0)
        assert mon.sweep() == [0, 1, 2, 3]

The per-call indirection is one global read + one call — nothing on any
hot loop.  Explicit ``clock=`` parameters on the consuming classes remain
(and win over the installed backend) for callers that want two clocks in
one process.
"""

from __future__ import annotations

import contextlib
import time
from typing import Callable, Iterator

__all__ = ["monotonic", "perf_counter", "install", "reset", "FakeClock",
           "fake"]

_monotonic: Callable[[], float] = time.monotonic
_perf_counter: Callable[[], float] = time.perf_counter


def monotonic() -> float:
    """The installed monotonic clock (wall ``time.monotonic`` by default)."""
    return _monotonic()


def perf_counter() -> float:
    """The installed high-resolution timer (``time.perf_counter`` by
    default).  Span durations and save-time billing read this one."""
    return _perf_counter()


def install(monotonic_fn: Callable[[], float] | None = None,
            perf_fn: Callable[[], float] | None = None,
            ) -> tuple[Callable[[], float], Callable[[], float]]:
    """Swap the backend(s); returns the previous ``(monotonic, perf)`` pair
    so callers can restore them (prefer the :func:`fake` context manager)."""
    global _monotonic, _perf_counter
    prev = (_monotonic, _perf_counter)
    if monotonic_fn is not None:
        _monotonic = monotonic_fn
    if perf_fn is not None:
        _perf_counter = perf_fn
    return prev


def reset() -> None:
    """Back to the real ``time`` clocks."""
    global _monotonic, _perf_counter
    _monotonic = time.monotonic
    _perf_counter = time.perf_counter


class FakeClock:
    """A deterministic test clock: calling it reads the current fake time,
    :meth:`advance` moves it.  One instance can back both the monotonic
    and the perf clock."""

    def __init__(self, start: float = 0.0):
        self.t = float(start)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        self.t += float(dt)
        return self.t


@contextlib.contextmanager
def fake(start: float = 0.0) -> Iterator[FakeClock]:
    """Install one :class:`FakeClock` as both clocks for the duration of
    the block; always restores the previous backends."""
    fc = FakeClock(start)
    prev = install(fc, fc)
    try:
        yield fc
    finally:
        install(*prev)
