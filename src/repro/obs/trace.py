"""Span tracing for the superstep executor.

Three granularities, one :class:`Tracer`:

* **run-level** — :class:`RunTraceHook` brackets a whole ``run_engine``
  call in one span.  This is the degraded mode ``device_loop=True`` runs
  get: the driver rejects stepwise hooks there (no host boundary between
  steps), so only start/exit instrumentation is possible.
* **superstep-level** — :class:`TraceHook` records one span per executor
  step with the counter deltas and the exchange bytes the step is about
  to put on the wire.  Works on every host-driven run path (``run_bsp``
  / ``run_am`` / ``run_hybrid(device_loop=False)`` / ``run_hybrid_ft`` /
  ``ServeEngine``); :func:`trace_hooks` picks the right hook class.
* **phase-level** — :func:`phased_run` executes an engine's superstep as
  its composable phase functions (:mod:`repro.exec.iteration`), jitting
  and timing each phase separately: exchange, delivery, global apply,
  local phase.  The composition is bit-identical to the fused step (the
  phase functions *are* the step body), so phase attribution costs only
  the extra dispatch boundaries.

Disabled is free: nothing on the engine hot path imports this module, a
``None``/disabled tracer contributes zero hooks (:func:`trace_hooks`
returns ``()``), and all accounting (exchange bytes, counter deltas) runs
only when a span is actually being recorded.

:func:`wrap_hooks` decorates any other executor hook (checkpointing, the
FT fault hook) so its per-method work shows up as ``cat="hook"`` spans —
that is how checkpoint save time is separated from step time in a trace.
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Callable, Sequence

import numpy as np

from repro.obs import clock

__all__ = ["Span", "Tracer", "TraceHook", "RunTraceHook", "trace_hooks",
           "wrap_hooks", "exchange_bytes", "exchange_bytes_per_partition",
           "halo_slots_per_partition", "phased_run", "SuperstepRecord",
           "PhasedRunResult", "COMM_PHASES"]


@dataclasses.dataclass
class Span:
    """One trace event.  ``ts``/``dur`` are seconds in the
    :func:`repro.obs.clock.perf_counter` domain; the Chrome exporter
    converts to microseconds.  ``ph`` follows the trace-event format:
    ``"X"`` complete spans, ``"i"`` instants."""

    name: str
    ts: float
    dur: float = 0.0
    cat: str = ""
    tid: int = 0
    ph: str = "X"
    args: dict = dataclasses.field(default_factory=dict)


class Tracer:
    """Append-only span sink.  ``enabled=False`` turns every recording
    method into a no-op so instrumentation can stay wired in production
    code paths."""

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self.spans: list[Span] = []
        self.track_names: dict[int, str] = {}

    def name_track(self, tid: int, name: str) -> None:
        self.track_names[int(tid)] = name

    def add(self, name: str, ts: float, dur: float = 0.0, cat: str = "",
            tid: int = 0, ph: str = "X", **args) -> None:
        if self.enabled:
            self.spans.append(Span(name, ts, dur, cat, tid, ph, dict(args)))

    def instant(self, name: str, cat: str = "", tid: int = 0, **args) -> None:
        """A zero-duration annotation (e.g. a recovery event)."""
        self.add(name, clock.perf_counter(), 0.0, cat, tid, ph="i", **args)

    @contextlib.contextmanager
    def span(self, name: str, cat: str = "", tid: int = 0, **args):
        """Record the block as one complete span; the yielded dict can be
        mutated to attach args discovered inside the block."""
        mutable = dict(args)
        if not self.enabled:
            yield mutable
            return
        t0 = clock.perf_counter()
        try:
            yield mutable
        finally:
            self.spans.append(Span(name, t0, clock.perf_counter() - t0,
                                   cat, tid, "X", mutable))


# ---------------------------------------------------------------------------
# exchange-bytes accounting (host-side, from the engine state the step is
# about to exchange — every engine's step body starts with the exchange, so
# the current export buffer is exactly what crosses the wire next).
# ---------------------------------------------------------------------------

def _wire_itemsize(dtype, wire_dtype) -> int:
    if wire_dtype is not None and np.issubdtype(dtype, np.floating):
        return np.dtype(wire_dtype).itemsize
    return np.dtype(dtype).itemsize


def exchange_bytes_per_partition(graph, es, wire_dtype=None) -> np.ndarray:
    """(P,) bytes each partition contributes to the next exchange: its
    valid *sending* export slots times the per-slot payload bytes of every
    exported leaf (after ``wire_dtype`` quantization, matching
    :func:`repro.core.runtime.exchange`'s wire encoding)."""
    import jax

    send = np.asarray(jax.device_get(es.export_send))          # (P, Vp)
    slot = np.asarray(graph.export_slot)                       # (P, X)
    mask = np.asarray(graph.export_mask)
    p = np.arange(send.shape[0])[:, None]
    sending = np.logical_and(send[p, slot], mask)              # (P, X)
    n_sending = sending.sum(axis=1)                            # (P,)
    per_slot = 0
    for leaf in jax.tree_util.tree_leaves(es.export_out):
        width = int(np.prod(leaf.shape[2:], dtype=np.int64)) if \
            leaf.ndim > 2 else 1
        per_slot += width * _wire_itemsize(leaf.dtype, wire_dtype)
    return n_sending.astype(np.int64) * per_slot


def exchange_bytes(graph, es, wire_dtype=None) -> int:
    """Total bytes the next exchange puts on the wire (see
    :func:`exchange_bytes_per_partition`)."""
    return int(exchange_bytes_per_partition(graph, es, wire_dtype).sum())


def halo_slots_per_partition(graph) -> np.ndarray:
    """(P,) valid halo slots per partition — each one is a remote
    out-state the partition consumes per exchange (static per graph)."""
    return np.asarray(graph.halo_mask).sum(axis=1).astype(np.int64)


def _counters_host(counters) -> dict:
    import jax
    c = jax.device_get(counters)
    return {
        "iterations": int(np.asarray(c.iterations)),
        "net_messages": int(np.asarray(c.net_messages)),
        "net_local_messages": int(np.asarray(c.net_local_messages)),
        "mem_messages": int(np.asarray(c.mem_messages)),
        "pseudo_supersteps": np.asarray(c.pseudo_supersteps).astype(np.int64),
    }


def _counter_deltas(before: dict, after: dict) -> dict:
    return {
        "net_messages": after["net_messages"] - before["net_messages"],
        "net_local_messages": (after["net_local_messages"]
                               - before["net_local_messages"]),
        "mem_messages": after["mem_messages"] - before["mem_messages"],
        "pseudo_supersteps": int((after["pseudo_supersteps"]
                                  - before["pseudo_supersteps"]).sum()),
    }


# ---------------------------------------------------------------------------
# executor hooks
# ---------------------------------------------------------------------------

# ExecHook lives in repro.exec.driver, which imports jax; import it here
# (obs -> exec), never the other way around — the executor must not pay a
# tracing import when no one traces.
from repro.exec.driver import ExecContext, ExecHook  # noqa: E402


class TraceHook(ExecHook):
    """One span per executor step, with the step's exchange bytes and
    counter deltas as args.

    Stepwise — rejected by ``device_loop=True`` runs (no host boundary
    between steps); use :func:`trace_hooks` to degrade to a
    :class:`RunTraceHook` there.  Put this hook *last* in the hook list:
    span order then brackets the step plus the preceding hooks' after-work
    (wrap those with :func:`wrap_hooks` to see their cost separately).
    """

    def __init__(self, tracer: Tracer, tid: int = 0, wire_dtype=None):
        self.tracer = tracer
        self.tid = tid
        self.wire_dtype = wire_dtype
        self._t0 = 0.0
        self._xb = 0
        self._before: dict | None = None

    def on_start(self, ctx: ExecContext) -> None:
        self.tracer.instant("run_start", cat="engine", tid=self.tid,
                            iteration=ctx.iteration)

    def before_step(self, ctx: ExecContext) -> None:
        if not self.tracer.enabled:
            return
        self._xb = exchange_bytes(ctx.graph, ctx.es, self.wire_dtype)
        self._before = _counters_host(ctx.es.counters)
        self._t0 = clock.perf_counter()

    def after_step(self, ctx: ExecContext) -> None:
        if not self.tracer.enabled or self._before is None:
            return
        import jax
        jax.block_until_ready(ctx.es)
        dur = clock.perf_counter() - self._t0
        after = _counters_host(ctx.es.counters)
        self.tracer.add(
            "superstep", self._t0, dur, cat="superstep", tid=self.tid,
            iteration=ctx.iteration, exchange_bytes=self._xb, barriers=1,
            **_counter_deltas(self._before, after))
        self._before = None


class RunTraceHook(ExecHook):
    """Run-level span only (``on_start``/``on_exit``) — the most a
    ``device_loop=True`` run can report, since the whole loop is one jit
    with no host boundary between steps."""

    def __init__(self, tracer: Tracer, tid: int = 0):
        self.tracer = tracer
        self.tid = tid
        self._t0 = 0.0
        self._before: dict | None = None

    def on_start(self, ctx: ExecContext) -> None:
        if not self.tracer.enabled:
            return
        self._before = _counters_host(ctx.es.counters)
        self._t0 = clock.perf_counter()

    def on_exit(self, ctx: ExecContext) -> None:
        if not self.tracer.enabled or self._before is None:
            return
        import jax
        jax.block_until_ready(ctx.es)
        after = _counters_host(ctx.es.counters)
        self.tracer.add(
            "run", self._t0, clock.perf_counter() - self._t0, cat="engine",
            tid=self.tid, iterations=ctx.iteration,
            **_counter_deltas(self._before, after))


def trace_hooks(tracer: Tracer | None, device_loop: bool = False,
                tid: int = 0, wire_dtype=None) -> tuple[ExecHook, ...]:
    """The hooks a run should carry for ``tracer``: ``()`` when tracing is
    off (the disabled path adds zero hooks, zero work), a stepwise
    :class:`TraceHook` on host-driven runs, a :class:`RunTraceHook` under
    ``device_loop=True`` (stepwise hooks are rejected there)."""
    if tracer is None or not tracer.enabled:
        return ()
    if device_loop:
        return (RunTraceHook(tracer, tid=tid),)
    return (TraceHook(tracer, tid=tid, wire_dtype=wire_dtype),)


class _WrappedHook(ExecHook):
    """Delegates to ``inner``, timing each overridden method as a
    ``cat="hook"`` span.  Return values pass through untouched, so the
    driver's consumed-tick contract (``before_step`` returning ``False``)
    is preserved."""

    def __init__(self, inner: ExecHook, tracer: Tracer, tid: int = 0):
        self.inner = inner
        self.tracer = tracer
        self.tid = tid

    def _call(self, method: str, ctx: ExecContext):
        fn = getattr(self.inner, method)
        if not self.tracer.enabled:
            return fn(ctx)
        name = f"{type(self.inner).__name__}.{method}"
        t0 = clock.perf_counter()
        try:
            return fn(ctx)
        finally:
            self.tracer.add(name, t0, clock.perf_counter() - t0,
                            cat="hook", tid=self.tid,
                            iteration=ctx.iteration)

    def on_start(self, ctx): return self._call("on_start", ctx)

    def before_step(self, ctx): return self._call("before_step", ctx)

    def after_step(self, ctx): return self._call("after_step", ctx)

    def on_exit(self, ctx): return self._call("on_exit", ctx)


def wrap_hooks(tracer: Tracer | None, hooks: Sequence[ExecHook],
               tid: int = 0) -> tuple[ExecHook, ...]:
    """Wrap each hook so its method calls appear as spans; identity when
    tracing is off."""
    if tracer is None or not tracer.enabled:
        return tuple(hooks)
    return tuple(_WrappedHook(h, tracer, tid=tid) for h in hooks)


# ---------------------------------------------------------------------------
# phase-level profiling: run an engine as its composable phases.
# ---------------------------------------------------------------------------

#: phases counted as communication when computing the local-compute
#: fraction; everything else in a superstep is compute.
COMM_PHASES = ("exchange", "delivery")


@dataclasses.dataclass
class SuperstepRecord:
    """One profiled superstep / global iteration."""

    superstep: int
    barriers: int                     # global synchronizations (always 1)
    exchange_bytes: int               # bytes this superstep's exchange moved
    phase_seconds: dict[str, float]   # phase name -> wall seconds
    pseudo_supersteps: int            # summed over partitions, this step
    net_messages: int
    net_local_messages: int
    mem_messages: int

    @property
    def total_seconds(self) -> float:
        return sum(self.phase_seconds.values())

    @property
    def local_compute_fraction(self) -> float:
        """Fraction of this superstep's wall time spent computing (global
        apply + local phase) rather than exchanging/delivering."""
        total = self.total_seconds
        if total <= 0.0:
            return 0.0
        comm = sum(v for k, v in self.phase_seconds.items()
                   if k in COMM_PHASES)
        return (total - comm) / total


@dataclasses.dataclass
class PhasedRunResult:
    engine: str
    es: Any
    iterations: int
    records: list[SuperstepRecord]

    @property
    def total_barriers(self) -> int:
        return sum(r.barriers for r in self.records)

    @property
    def total_exchange_bytes(self) -> int:
        return sum(r.exchange_bytes for r in self.records)

    @property
    def mean_local_compute_fraction(self) -> float:
        if not self.records:
            return 0.0
        return sum(r.local_compute_fraction for r in self.records) \
            / len(self.records)


def _phase_fns(graph, prog, vdata, engine: str, use_ell: bool,
               collect_metrics: bool, max_local_steps: int,
               wire_dtype) -> list[tuple[str, Callable]]:
    from repro.exec import iteration as it

    if engine == "bsp":
        return [
            ("exchange", lambda es: it.exchange_phase(graph, prog, es)),
            ("delivery", lambda es: it.bsp_delivery(
                graph, prog, es, use_ell, collect_metrics)),
            ("compute", lambda es: it.bsp_compute(graph, prog, es, vdata)),
        ]
    if engine == "hybrid":
        return [
            ("exchange", lambda es: it.exchange_phase(
                graph, prog, es, wire_dtype=wire_dtype)),
            ("delivery", lambda es: it.hybrid_remote_delivery(
                graph, prog, es, use_ell, collect_metrics)),
            ("global", lambda es: it.hybrid_global_phase(
                graph, prog, es, vdata, use_ell, collect_metrics)),
            ("local", lambda es: it.hybrid_local(
                graph, prog, es, vdata, max_local_steps, use_ell,
                collect_metrics)),
        ]
    raise ValueError(f"phased profiling supports engines 'bsp' and "
                     f"'hybrid', not {engine!r}")


def phased_run(graph, prog, engine: str = "hybrid", vdata: Any = None, *,
               tracer: Tracer | None = None, tid: int = 0,
               use_ell: bool = True, collect_metrics: bool = True,
               max_iters: int = 100_000, max_local_steps: int = 100_000,
               wire_dtype=None) -> PhasedRunResult:
    """Run ``engine`` to quiescence with each superstep decomposed into
    its phase functions, jitted and timed one by one.

    The phases compose to exactly the engine's fused step body
    (:mod:`repro.exec.iteration` builds the step from the same functions),
    so the final state and every counter are bit-identical to
    ``run_bsp`` / ``run_hybrid`` — only the phase boundaries cost extra
    dispatches.  Returns a :class:`PhasedRunResult`; with ``tracer`` the
    same data lands as per-phase + per-superstep spans.
    """
    import jax

    from repro.core.runtime import quiescent
    from repro.exec.policy import make_policy

    knobs = dict(use_ell=use_ell, collect_metrics=collect_metrics)
    if engine == "hybrid":
        knobs["max_local_steps"] = max_local_steps
    policy = make_policy(engine, **knobs)
    phases = [(name, jax.jit(fn)) for name, fn in _phase_fns(
        graph, prog, vdata, engine, use_ell, collect_metrics,
        max_local_steps, wire_dtype)]

    es = policy.init(graph, prog, vdata)
    records: list[SuperstepRecord] = []
    step = 0
    while step < max_iters and not bool(quiescent(prog, es)):
        step += 1
        xb = exchange_bytes(graph, es, wire_dtype)
        before = _counters_host(es.counters)
        secs: dict[str, float] = {}
        t_start = clock.perf_counter()
        for name, fn in phases:
            t0 = clock.perf_counter()
            es = jax.block_until_ready(fn(es))
            secs[name] = clock.perf_counter() - t0
            if tracer is not None:
                tracer.add(f"{engine}.{name}", t0, secs[name], cat="phase",
                           tid=tid, superstep=step)
        deltas = _counter_deltas(before, _counters_host(es.counters))
        rec = SuperstepRecord(
            superstep=step, barriers=1, exchange_bytes=xb,
            phase_seconds=secs, pseudo_supersteps=deltas["pseudo_supersteps"],
            net_messages=deltas["net_messages"],
            net_local_messages=deltas["net_local_messages"],
            mem_messages=deltas["mem_messages"])
        records.append(rec)
        if tracer is not None:
            tracer.add(f"{engine}.superstep", t_start,
                       clock.perf_counter() - t_start, cat="superstep",
                       tid=tid, superstep=step, exchange_bytes=xb,
                       barriers=1,
                       local_compute_fraction=rec.local_compute_fraction,
                       **deltas)
    return PhasedRunResult(engine=engine, es=es, iterations=step,
                           records=records)


def traced_dist_step(step: Callable, tracer: Tracer, n_devices: int,
                     tid: int = 0, wire_dtype=None) -> Callable:
    """Wrap a distributed step ``(graph, es) -> es`` with host-side span
    recording: per-block (per-device) exchange bytes, halo sizes, and
    pseudo-superstep counts ride each span's args.  Used by
    :func:`repro.core.distributed.make_dist_hybrid_step` when a tracer is
    passed; the ``tracer=None`` path returns the step untouched."""
    import jax

    def blocked(vec: np.ndarray) -> list[int]:
        return [int(b.sum()) for b in np.array_split(vec, n_devices)]

    def wrapped(graph, es):
        if not tracer.enabled:
            return step(graph, es)
        xb = exchange_bytes_per_partition(graph, es, wire_dtype)
        halo = halo_slots_per_partition(graph)
        before = _counters_host(es.counters)
        t0 = clock.perf_counter()
        es = jax.block_until_ready(step(graph, es))
        dur = clock.perf_counter() - t0
        after = _counters_host(es.counters)
        pseudo = (after["pseudo_supersteps"]
                  - before["pseudo_supersteps"])
        tracer.add(
            "dist_step", t0, dur, cat="superstep", tid=tid,
            iteration=after["iterations"],
            exchange_bytes=int(xb.sum()),
            exchange_bytes_per_block=blocked(xb),
            halo_slots_per_block=blocked(halo),
            pseudo_supersteps_per_block=blocked(pseudo),
            net_messages=after["net_messages"] - before["net_messages"])
        return es

    return wrapped
