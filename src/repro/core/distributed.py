"""Distributed GraphHP execution: one partition block per device via
shard_map over the production mesh.

This is the faithful lowering of the paper's architecture: the local phase's
``lax.while_loop`` runs *per device with no collectives in its body* — every
device truly iterates pseudo-supersteps to its own partition's convergence,
decoupled from the others — and the only cross-device communication is the
once-per-global-iteration export all-gather (+ the quiescence psum the
paper's master performs over worker responses).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.exec.iteration import hybrid_iteration
from repro.core.graph import PartitionedGraph
from repro.core.runtime import Counters, EngineState
from repro.core.vertex_program import VertexProgram

AXES = ("data", "model")


def shard0_specs(tree, axes) -> Any:
    """Every array leaf sharded on dim 0 over the flattened device axes."""
    return jax.tree.map(lambda l: P(axes), tree)


def make_dist_hybrid_step(prog: VertexProgram, mesh: Mesh,
                          axes: tuple = AXES, vdata: Any = None,
                          max_local_steps: int = 10_000,
                          wire_dtype=None, use_ell: bool = True,
                          collect_metrics: bool = True, tracer=None):
    """Returns a jittable step: (graph, es) -> es, running one global
    iteration on a mesh where dim 0 of every array is the partition axis.
    ``wire_dtype=jnp.bfloat16`` halves exchange bytes (§Perf);
    ``use_ell``/``collect_metrics`` select the kernel-backed local phase
    (the ELL tiles shard on dim 0 like every other partition-major array).

    ``use_ell=True`` is the default here exactly as on the single-host
    engines: the shard_map block path runs the same fused/ELL kernels on
    block-local partition slices (``runtime.slice_flat`` re-offsets), the
    multi-device CI matrix pins it bit-exact against the host dense run,
    and ``collect_metrics=True`` costs no dense fallback — remote group
    accounting rides the ELL tiles' per-slot group ids.

    ``tracer`` (a :class:`repro.obs.trace.Tracer`) wraps the returned step
    with host-side span recording — one ``dist_step`` span per global
    iteration carrying per-device-block exchange bytes, halo sizes, and
    pseudo-superstep counts.  The wrapped step blocks between iterations
    (honest timing) and is *not* meant to be re-jitted by the caller;
    ``tracer=None`` (the default) returns the bare jittable step with no
    observability import at all."""

    def gather_table(x):
        # local (Pb, X, ...) -> global (P, X, ...): the one exchange
        return jax.lax.all_gather(x, axes, axis=0, tiled=True)

    def local_step(graph: PartitionedGraph, es: EngineState) -> EngineState:
        c0 = es.counters            # replicated totals from last iteration
        es = hybrid_iteration(graph, prog, es, vdata,
                              gather_table=gather_table,
                              max_local_steps=max_local_steps,
                              wire_dtype=wire_dtype, use_ell=use_ell,
                              collect_metrics=collect_metrics)
        # master-side aggregation of the paper's metrics: psum only THIS
        # iteration's per-device delta (one collective, outside the
        # pseudo-superstep loop), keeping the running totals replicated.
        c = es.counters
        agg = dataclasses.replace(
            c,
            net_messages=c0.net_messages + jax.lax.psum(
                c.net_messages - c0.net_messages, axes),
            net_local_messages=c0.net_local_messages + jax.lax.psum(
                c.net_local_messages - c0.net_local_messages, axes),
            mem_messages=c0.mem_messages + jax.lax.psum(
                c.mem_messages - c0.mem_messages, axes))
        return dataclasses.replace(es, counters=agg)

    def step(graph, es):
        d = mesh.size
        if graph.n_partitions % d or graph.n_blocks % d:
            raise ValueError(
                f"distributed step needs n_partitions ({graph.n_partitions})"
                f" and n_blocks ({graph.n_blocks}) divisible by the device "
                f"count ({d}); build with edge_blocks={d} (or a multiple)")
        in_specs = (shard0_specs(graph, axes), _es_specs(es, axes))
        out_specs = _es_specs(es, axes)
        return _shard_map(local_step, mesh, in_specs, out_specs)(graph, es)

    if tracer is not None:
        from repro.obs.trace import traced_dist_step   # lazy: opt-in only
        return traced_dist_step(step, tracer, mesh.size,
                                wire_dtype=wire_dtype)
    return step


def _shard_map(fn, mesh, in_specs, out_specs):
    """shard_map with replication checking off, across jax versions (older
    releases ship it under jax.experimental with a ``check_rep`` kwarg)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def _es_specs(es: EngineState, axes) -> Any:
    """EngineState specs: arrays partition-sharded on dim 0; the counters are
    scalars — replicated (they are psum'd/identical across devices)."""
    def spec(path, leaf):
        keys = [str(getattr(k, "key", getattr(k, "name", k))) for k in path]
        if "counters" in " ".join(keys):
            return P(axes) if getattr(leaf, "ndim", 0) >= 1 else P()
        return P(axes)
    return jax.tree_util.tree_map_with_path(spec, es)


def block_graph_shapes(n_partitions: int, vp: int, ep: int, xp: int, hp: int,
                       gp: int | None = None, kl: int = 0,
                       n_blocks: int | None = None) -> PartitionedGraph:
    """ShapeDtypeStruct stand-in graph (dry-run; no allocation).  ``kl`` > 0
    adds a single dense-base ELL bin of that slice width per side.
    ``ep``/``gp`` are per-*block* widths of the block-ragged edge layout;
    ``n_blocks`` defaults to one block per partition (the legacy padded
    shape, one partition per device)."""
    from repro.core.graph import EllSlice

    gp = gp or vp
    nb = n_partitions if n_blocks is None else n_blocks
    if n_partitions % nb:
        raise ValueError(f"n_blocks={nb} must divide "
                         f"n_partitions={n_partitions}")
    ppb = n_partitions // nb
    f = jax.ShapeDtypeStruct
    i32, f32, b = jnp.int32, jnp.float32, jnp.bool_

    def ell(stride):
        if kl == 0:
            return ()
        return (EllSlice(
            rows=f((nb, ppb * vp), i32),
            idx=f((nb, ppb * vp, kl), i32),
            val=f((nb, ppb * vp, kl), f32),
            msk=f((nb, ppb * vp, kl), b),
            grp=f((nb, ppb * vp, kl), i32),
            flat_rows=f((n_partitions * vp,), i32),
            flat_idx=f((n_partitions * vp, kl), i32),
            nb=ppb * vp, kb=kl, lo=0, dense=True, stride=stride,
            payload_bound=n_partitions * vp - 1),)

    pg = PartitionedGraph(
        vertex_gid=f((n_partitions, vp), i32),
        vertex_mask=f((n_partitions, vp), b),
        is_boundary=f((n_partitions, vp), b),
        out_degree=f((n_partitions, vp), i32),
        edge_src=f((nb, ep), i32),
        edge_dst=f((nb, ep), i32),
        edge_w=f((nb, ep), f32),
        edge_mask=f((nb, ep), b),
        edge_local=f((nb, ep), b),
        edge_src_gid=f((nb, ep), i32),
        edge_dst_gid=f((nb, ep), i32),
        edge_part=f((nb, ep), i32),
        edge_group=f((nb, ep), i32),
        group_remote=f((nb, gp), b),
        group_mask=f((nb, gp), b),
        export_slot=f((n_partitions, xp), i32),
        export_mask=f((n_partitions, xp), b),
        export_fanout=f((n_partitions, xp), i32),
        halo_ptr=f((n_partitions, hp), i32),
        halo_mask=f((n_partitions, hp), b),
        local_ell=ell(vp), remote_ell=ell(vp + hp),
        n_partitions=n_partitions, n_vertices=n_partitions * vp,
        n_edges=n_partitions * ep, vp=vp, ep=ep, xp=xp, hp=hp, gp=gp,
        n_blocks=nb, ep_by_p=(ep // ppb,) * n_partitions,
        gp_by_p=(gp // ppb,) * n_partitions,
    )
    return pg


def engine_state_shapes(prog: VertexProgram, graph: PartitionedGraph,
                        value_dtype=jnp.float32) -> EngineState:
    """ShapeDtypeStruct EngineState matching SSSP-like single-value apps."""
    p, vp, hp = graph.n_partitions, graph.vp, graph.hp
    f = jax.ShapeDtypeStruct
    val = {"dist": f((p, vp), value_dtype)}
    halo = {"dist": f((p, hp), value_dtype)}
    pend = {ch.name: (tuple(f((p, vp), dt) for dt, _ in ch.components),
                      f((p, vp), jnp.bool_))
            for ch in prog.channels}
    return EngineState(
        state=val, out=dict(val), send=f((p, vp), jnp.bool_),
        active=f((p, vp), jnp.bool_),
        export_out=dict(val), export_send=f((p, vp), jnp.bool_),
        pending=pend, halo_out=halo, halo_send=f((p, hp), jnp.bool_),
        counters=Counters(
            iterations=f((), jnp.int32),
            pseudo_supersteps=f((p,), jnp.int32),
            net_messages=f((), jnp.int32),
            net_local_messages=f((), jnp.int32),
            mem_messages=f((), jnp.int32)),
    )
