"""Vertex-centric program API (the paper's `Compute()` contract, vectorized).

A :class:`VertexProgram` is the bulk-synchronous, array-level equivalent of
subclassing Hama's ``Vertex`` class:

  * ``init``    — superstep 0 (the paper's initialization iteration),
  * ``emit``    — message generation along an edge (``sendMessage`` over the
                  adjacency list), evaluated receiver-side from the sender's
                  exported *out-state*,
  * channels    — per-destination combination (``Combine()``) as a monoid;
                  several typed channels model heterogeneous messages
                  (paper §6.4, bipartite matching),
  * ``apply``   — the body of ``Compute()``: consume the combined inbox,
                  update vertex state, decide what to send and whether to
                  stay active (``voteToHalt``),
  * ``accumulate_export`` — ``SourceCombine()``: how out-states pile up in a
                  partition's export buffer between global exchanges
                  (default: keep-latest, the paper's default rule).

All functions are pure and vectorized over every vertex/edge of a partition
at once; the engines supply masking so semantics match per-vertex execution.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

__all__ = ["Channel", "VertexProgram", "StepInfo", "combine_segments", "INT_INF"]

INT_INF = jnp.iinfo(jnp.int32).max


@dataclasses.dataclass(frozen=True)
class Channel:
    """A typed message channel with a monoid combiner.

    combiner: 'sum' | 'min' | 'max' | 'lexmin'
      'lexmin' performs lexicographic minimum over the payload tuple via
      cascaded masked segment-mins (deterministic tie-breaking) — this is how
      "pick one random request" style combiners (bipartite matching) are
      expressed without int64 packing.
    components: per-payload-component (dtype, identity) pairs.
    semiring: optional kernel declaration, one of the `ell_spmv` semirings
      ('add_mul' | 'min_add' | 'max_add' | 'min_mul' | 'max_min') or None.
      Declaring a
      semiring states that this channel's per-edge message factors as
      ``x[src] ⊗ edge_val`` with an always-valid emit, where ``x`` comes
      from :meth:`VertexProgram.ell_payload` (neutralized to the ⊕/⊗
      identity on non-sending sources).  `runtime.deliver` then dispatches
      local-phase delivery for the channel to the Pallas ELL kernel;
      channels without a semiring (or whose ``ell_payload`` returns None)
      transparently keep the dense gather/segment path.  Only
      single-component channels are eligible.
    lanes: 0 for a classic per-vertex scalar channel; L > 0 declares a
      *K-lane* channel whose state/out/message arrays carry a trailing lane
      axis of width L — L independent queries (multi-source SSSP, per-seed
      personalized PageRank) sharing one delivery.  Lane channels ride the
      semiring SpMM kernels: one dispatch answers all L lanes.
    """

    name: str
    combiner: str
    components: Sequence[tuple[Any, Any]]
    semiring: str | None = None
    lanes: int = 0

    def identity_like(self, shape: tuple[int, ...]) -> tuple[jax.Array, ...]:
        if self.lanes:
            shape = tuple(shape) + (self.lanes,)
        return tuple(jnp.full(shape, ident, dtype=dt) for dt, ident in self.components)


@dataclasses.dataclass(frozen=True)
class StepInfo:
    """What the engine tells `apply` about the current step."""

    superstep: jax.Array | int          # global iteration index
    pseudo_step: jax.Array | int        # pseudo-superstep within local phase
    phase: str                          # 'init' | 'global' | 'local' | 'superstep'


class VertexProgram:
    """Base class; subclasses define the five hooks below."""

    channels: tuple[Channel, ...] = ()
    # whether boundary vertices participate in local phases (paper §4.2 —
    # safe for incremental computations; accelerates convergence).
    boundary_participates: bool = True
    # name of a fully-fused local-phase kernel ('pr_step') or None.  Setting
    # it asserts the program satisfies that kernel's invariants — see
    # engine_hybrid._fused_pr_local_phase for the 'pr_step' contract.
    fused_kernel: str | None = None

    # -- hooks ------------------------------------------------------------
    def init(self, gid, vmask, vdata):
        """-> (state dict, out dict, send (bool per vertex), active)."""
        raise NotImplementedError

    def emit(self, ch: Channel, out_src, w, src_gid, dst_gid):
        """-> (payload tuple, valid bool) per edge for channel ``ch``."""
        raise NotImplementedError

    def apply(self, state, inbox, gid, vmask, vdata, info: StepInfo):
        """-> (state, out, send, active).  ``inbox[name] = (payloads, has_msg)``."""
        raise NotImplementedError

    def accumulate_export(self, acc_out, acc_send, new_out, new_send):
        """SourceCombine(): default keep-latest-if-sent (paper default)."""
        merged = jax.tree.map(
            lambda a, n: _where_send(new_send, n, a), acc_out, new_out)
        return merged, jnp.logical_or(acc_send, new_send)

    def export_identity(self, out):
        """Export-buffer reset value after an exchange.  Keep-latest programs
        don't care (the send flag gates); accumulative (sum) programs override
        with zeros so deltas re-accumulate from scratch."""
        return out

    def ell_payload(self, ch: Channel, out, send):
        """Per-vertex kernel operand ``x`` (P, Vp) for a semiring channel.

        Must satisfy: for every edge (s -> d) with weight w, the channel's
        emitted message equals ``x[s] ⊗ edge_val`` under ``ch.semiring``,
        and ``x`` is the ⊕-annihilating value where ``~send`` (0 for
        add_mul, +inf for min_*, -inf for max_add) so non-senders contribute
        the combine identity.  Return None to force the dense path (the
        default).  Integer payloads must fit float32 exactly (< 2**24)."""
        return None

    def ell_edge_values(self, ch: Channel, val):
        """Edge-value operand for the ELL kernel — the packed edge weights
        by default; override when the message does not use the weight
        (e.g. min-label propagation passes zeros through min_add)."""
        return val

    def global_only_active(self, state, vdata):
        """Optional (P, Vp) mask of vertices whose self-activity only needs
        global-cadence scheduling (they are message-reactivated locally).
        ``None`` means no such vertices.  Lets programs that wait on
        cross-partition round-trips (bipartite matching's granted rights)
        keep local phases terminating."""
        return None


def _where_send(send, new, old):
    send_b = send.reshape(send.shape + (1,) * (new.ndim - send.ndim))
    return jnp.where(send_b, new, old)


# ---------------------------------------------------------------------------
# Monoid segment combination.
# ---------------------------------------------------------------------------

def combine_segments(
    ch: Channel,
    payloads: tuple[jax.Array, ...],
    valid: jax.Array,
    dst: jax.Array,
    num_segments: int,
) -> tuple[tuple[jax.Array, ...], jax.Array]:
    """Combine per-edge payloads into per-destination inboxes.

    Returns (combined payload tuple each (num_segments, ...), has_msg bool).
    Invalid edges contribute the channel identity.
    """
    has = jax.ops.segment_max(valid.astype(jnp.int32), dst,
                              num_segments=num_segments) > 0
    # lane channels carry payloads (E, L) against a per-edge (E,) validity
    bx = lambda v, p: v.reshape(v.shape + (1,) * (p.ndim - v.ndim))

    if ch.combiner == "sum":
        outs = tuple(
            jax.ops.segment_sum(jnp.where(bx(valid, p), p, jnp.zeros_like(p)),
                                dst, num_segments=num_segments)
            for p in payloads)
        return outs, has

    if ch.combiner in ("min", "max"):
        op = jax.ops.segment_min if ch.combiner == "min" else jax.ops.segment_max
        outs = []
        for p, (dt, ident) in zip(payloads, ch.components):
            masked = jnp.where(bx(valid, p), p, jnp.asarray(ident, dtype=dt))
            outs.append(op(masked, dst, num_segments=num_segments))
        return tuple(outs), has

    if ch.combiner == "lexmin":
        # cascaded masked segment-min: component k participates only where all
        # previous components equal their combined minimum.
        eligible = valid
        outs = []
        for p, (dt, ident) in zip(payloads, ch.components):
            masked = jnp.where(eligible, p, jnp.asarray(ident, dtype=dt))
            m = jax.ops.segment_min(masked, dst, num_segments=num_segments)
            outs.append(m)
            eligible = jnp.logical_and(eligible, p == m[dst])
        return tuple(outs), has

    raise ValueError(f"unknown combiner {ch.combiner!r}")
