from repro.core.graph import (PartitionedGraph, bfs_partition,
                              build_partitioned_graph, hash_partition)
from repro.core.vertex_program import Channel, StepInfo, VertexProgram
from repro.core.runtime import Counters, EngineState
from repro.core.engine_bsp import run_bsp
from repro.core.engine_am import run_am
from repro.core.engine_hybrid import run_hybrid

__all__ = [
    "PartitionedGraph", "build_partitioned_graph", "hash_partition",
    "bfs_partition", "Channel", "StepInfo", "VertexProgram", "Counters",
    "EngineState", "run_bsp", "run_am", "run_hybrid",
]
