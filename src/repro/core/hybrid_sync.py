"""GraphHP's hybrid execution model lifted to multi-pod training.

Mapping (DESIGN.md §6): pod = graph partition; one optimizer step = one
pseudo-superstep; the cross-pod exchange = the global phase.  Each pod runs H
*inner* steps with gradient reduction confined to its own (data, model)
slice — zero cross-pod traffic, exactly like the local phase running on
in-memory messages — then the *global phase* exchanges accumulated parameter
deltas once, through an error-feedback int8 combiner (the ``Combine()``
before the wire), and an outer Nesterov step (DiLoCo-style) advances the
shared anchor.

Implementation: per-pod replicas are *stacked along a leading pod axis*
(sharded over the mesh's ``pod`` dimension) and the inner step is ``vmap``ed
over it — per-pod gradients are independent by construction, so no GSPMD
reduction can leak across pods.  Both phases lower and compile on the
(pod=2, data=16, model=16) production mesh; the dry-run proves it.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.optim.compression import (ErrorFeedbackState, ef_int8_compress,
                                     ef_int8_decompress)

Params = Any


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class OuterState:
    """Outer (cross-pod) optimizer state: shared anchor + Nesterov momentum +
    per-pod error-feedback residuals."""

    anchor: Params                  # synchronized parameters (no pod axis)
    momentum: Params                # outer Nesterov buffer (no pod axis)
    ef: ErrorFeedbackState          # residuals, stacked per pod


def stack_pods(tree: Params, n_pods: int) -> Params:
    """Replicate to a leading pod axis (pod-sharded on the mesh)."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n_pods,) + x.shape), tree)


def outer_init(params: Params, n_pods: int) -> OuterState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OuterState(
        anchor=params,
        momentum=jax.tree.map(zeros, params),
        ef=ErrorFeedbackState(
            residual=stack_pods(jax.tree.map(zeros, params), n_pods)),
    )


def inner_steps(train_step: Callable, params_pods, opt_pods, batch_pods,
                step: jax.Array):
    """The local phase: one (or more) pod-independent inner steps.

    ``train_step(params, opt, batch, step) -> (params, opt, metrics)`` is the
    single-pod step; vmap over the leading pod axis keeps each pod's gradient
    reduction inside the pod.
    """
    return jax.vmap(train_step, in_axes=(0, 0, 0, None))(
        params_pods, opt_pods, batch_pods, step)


def global_sync(params_pods: Params, outer: OuterState, *,
                outer_lr: float = 0.7, outer_momentum: float = 0.9,
                compress: bool = True,
                gathered_specs: Params | None = None) -> tuple[Params, OuterState]:
    """The global phase: one cross-pod exchange per H inner steps.

    Per-pod delta vs. the anchor -> int8 error-feedback compression (4× fewer
    cross-pod bytes; the residual rides the next exchange) -> pod-mean ->
    outer Nesterov update of the anchor -> broadcast back to every pod.

    ``gathered_specs`` (pod-replicated PartitionSpecs) pins the cross-pod
    gather to happen ON THE QUANTIZED TENSORS — without it GSPMD may hoist
    the dequant before the collective and erase the wire savings (§Perf).
    """
    n_pods = jax.tree.leaves(params_pods)[0].shape[0]

    delta_pods = jax.tree.map(
        lambda p, a: p.astype(jnp.float32) - a.astype(jnp.float32)[None],
        params_pods, outer.anchor)

    if compress:
        q, scales, ef = ef_int8_compress(delta_pods, outer.ef)
        if gathered_specs is not None:
            q = jax.tree.map(jax.lax.with_sharding_constraint, q,
                             gathered_specs)
        delta_pods = ef_int8_decompress(q, scales)
    else:
        ef = outer.ef
        if gathered_specs is not None:
            delta_pods = jax.tree.map(jax.lax.with_sharding_constraint,
                                      delta_pods, gathered_specs)
    delta = jax.tree.map(lambda d: jnp.mean(d, axis=0), delta_pods)

    # outer Nesterov (DiLoCo): v <- mu v + delta; anchor += lr (mu v + delta)
    momentum = jax.tree.map(
        lambda v, d: outer_momentum * v + d, outer.momentum, delta)
    anchor = jax.tree.map(
        lambda a, v, d: (a.astype(jnp.float32)
                         + outer_lr * (outer_momentum * v + d)).astype(a.dtype),
        outer.anchor, momentum, delta)

    params_pods = stack_pods(anchor, n_pods)
    return params_pods, OuterState(anchor=anchor, momentum=momentum, ef=ef)
