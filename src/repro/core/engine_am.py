"""AM-Hama: Hama + asynchronous in-memory messaging (paper §4.2 / §7).

Same superstep/exchange cadence as standard BSP, but messages between
co-located vertices are delivered in memory, and a message sent earlier in a
superstep may be consumed by a not-yet-processed vertex *within the same
superstep* (the Grace mechanism [35] as implemented for comparison in [32]).

Vectorized adaptation (DESIGN.md §9.3): the JVM implementation processes
vertices sequentially, so roughly the messages flowing "forward" in processing
order land in the same superstep.  We split each partition's slots into two
ordered half-blocks A|B: A computes first, its in-partition messages are
delivered in memory, then B computes — every vertex still runs Compute() at
most once per superstep (Grace's bound), and forward-crossing messages land
same-superstep.  Cross-partition messages keep the superstep-latency of Hama.

This module is configuration only: the superstep body lives in
:mod:`repro.exec.iteration` and the loop in :mod:`repro.exec.driver` —
``run_am`` is the executor under :func:`repro.exec.policy.am_policy`.
"""

from __future__ import annotations

from typing import Any

from repro.core.runtime import EngineState
from repro.core.vertex_program import VertexProgram
from repro.exec.driver import run_engine
from repro.exec.iteration import am_superstep
from repro.exec.policy import am_policy

__all__ = ["am_superstep", "run_am"]


def run_am(
    graph,
    prog: VertexProgram,
    vdata: Any = None,
    max_iters: int = 100_000,
    use_ell: bool = True,
    collect_metrics: bool = True,
) -> tuple[EngineState, int]:
    """Host-driven loop: init superstep + AM supersteps until quiescence."""
    ctx = run_engine(graph, prog,
                     am_policy(use_ell=use_ell,
                               collect_metrics=collect_metrics),
                     vdata, max_iters=max_iters)
    return ctx.es, ctx.iteration
