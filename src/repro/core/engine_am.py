"""AM-Hama: Hama + asynchronous in-memory messaging (paper §4.2 / §7).

Same superstep/exchange cadence as standard BSP, but messages between
co-located vertices are delivered in memory, and a message sent earlier in a
superstep may be consumed by a not-yet-processed vertex *within the same
superstep* (the Grace mechanism [35] as implemented for comparison in [32]).

Vectorized adaptation (DESIGN.md §9.3): the JVM implementation processes
vertices sequentially, so roughly the messages flowing "forward" in processing
order land in the same superstep.  We split each partition's slots into two
ordered half-blocks A|B: A computes first, its in-partition messages are
delivered in memory, then B computes — every vertex still runs Compute() at
most once per superstep (Grace's bound), and forward-crossing messages land
same-superstep.  Cross-partition messages keep the superstep-latency of Hama.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.graph import PartitionedGraph
from repro.core.runtime import (EngineState, apply_phase, deliver,
                                ell_channels, exchange, init_state, quiescent)
from repro.core.vertex_program import StepInfo, VertexProgram

__all__ = ["am_superstep", "run_am"]


def am_superstep(
    graph: PartitionedGraph,
    prog: VertexProgram,
    es: EngineState,
    vdata: Any,
    gather_table: Callable | None = None,
    use_ell: bool = True,
    collect_metrics: bool = True,
) -> EngineState:
    es = exchange(graph, es, gather_table)
    es = dataclasses.replace(
        es, export_out=prog.export_identity(es.export_out),
        export_send=jnp.zeros_like(es.export_send))
    if use_ell and ell_channels(graph, prog, es.out, es.send):
        # split so each half rides its ELL layout (groups never mix local
        # and remote edges, so counters are unchanged); programs with no
        # kernel-eligible channel keep the single 'all' delivery
        es, _ = deliver(graph, prog, es, edges="remote", use_ell=True,
                        collect_metrics=collect_metrics)
        es, _ = deliver(graph, prog, es, edges="local", use_ell=True,
                        collect_metrics=collect_metrics)
    else:
        es, _ = deliver(graph, prog, es, edges="all",
                        collect_metrics=collect_metrics)

    slot = jnp.arange(graph.vp)[None, :]
    half_a = jnp.logical_and(graph.vertex_mask, slot < graph.vp // 2)
    half_b = jnp.logical_and(graph.vertex_mask, jnp.logical_not(slot < graph.vp // 2))

    info = StepInfo(superstep=es.counters.iterations + 1, pseudo_step=0,
                    phase="superstep")
    es = apply_phase(graph, prog, es, half_a, info, vdata)
    es, _ = deliver(graph, prog, es, edges="local", use_ell=use_ell,
                    collect_metrics=collect_metrics)   # A's messages, in memory
    es = apply_phase(graph, prog, es, half_b, info, vdata)
    # es.send is now B's senders only: A's in-partition messages were already
    # delivered above (delivering them again next superstep would double-count
    # for sum channels); A's cross-partition messages travel via the export
    # buffer, which accumulated A's sends in its apply_phase.

    c = es.counters
    return dataclasses.replace(
        es, counters=dataclasses.replace(
            c, iterations=c.iterations + 1,
            pseudo_supersteps=c.pseudo_supersteps + 1))


def run_am(
    graph: PartitionedGraph,
    prog: VertexProgram,
    vdata: Any = None,
    max_iters: int = 100_000,
    use_ell: bool = True,
    collect_metrics: bool = True,
) -> tuple[EngineState, int]:
    step = jax.jit(partial(am_superstep, graph, prog, vdata=vdata,
                           use_ell=use_ell, collect_metrics=collect_metrics))
    es = init_state(graph, prog, vdata)
    for _ in range(max_iters):
        if bool(quiescent(prog, es)):
            break
        es = step(es=es)
    return es, int(es.counters.iterations)
