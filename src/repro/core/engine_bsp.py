"""Standard BSP engine (the paper's Hama baseline).

Every superstep = one distributed exchange + one bulk Compute() over all
(active ∨ messaged) vertices.  Synchronization/communication frequency is
O(#supersteps) — the inefficiency GraphHP attacks.

Message accounting follows the paper's Hama baseline: *all* messages travel
through the distributed mechanism (RPC "by default", §4.1), so M counts both
same-partition and cross-partition combined groups.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.graph import PartitionedGraph
from repro.core.runtime import (EngineState, apply_phase, deliver,
                                ell_channels, exchange, init_state, quiescent)
from repro.core.vertex_program import StepInfo, VertexProgram

__all__ = ["bsp_superstep", "run_bsp"]


def _reset_export(prog: VertexProgram, es: EngineState) -> EngineState:
    return dataclasses.replace(
        es, export_out=prog.export_identity(es.export_out),
        export_send=jnp.zeros_like(es.export_send))


def bsp_superstep(
    graph: PartitionedGraph,
    prog: VertexProgram,
    es: EngineState,
    vdata: Any,
    gather_table: Callable | None = None,
    use_ell: bool = True,
    collect_metrics: bool = True,
) -> EngineState:
    """One Hama superstep: exchange -> deliver(all) -> Compute(all).

    With ``use_ell`` (the default) the delivery splits into remote + local
    halves so each half can dispatch to its Pallas ELL layout.  Combine
    groups never mix local and remote edges, so counters are unchanged;
    float 'sum' inboxes may differ in the last bit (different reduction
    order).
    """
    es = exchange(graph, es, gather_table)
    es = _reset_export(prog, es)
    if use_ell and ell_channels(graph, prog, es.out, es.send):
        es, _ = deliver(graph, prog, es, edges="remote", use_ell=True,
                        collect_metrics=collect_metrics)
        es, _ = deliver(graph, prog, es, edges="local", use_ell=True,
                        collect_metrics=collect_metrics)
    else:
        es, _ = deliver(graph, prog, es, edges="all",
                        collect_metrics=collect_metrics)
    info = StepInfo(superstep=es.counters.iterations + 1, pseudo_step=0,
                    phase="superstep")
    es = apply_phase(graph, prog, es, graph.vertex_mask, info, vdata)
    c = es.counters
    return dataclasses.replace(
        es, counters=dataclasses.replace(
            c, iterations=c.iterations + 1,
            pseudo_supersteps=c.pseudo_supersteps + 1))


def run_bsp(
    graph: PartitionedGraph,
    prog: VertexProgram,
    vdata: Any = None,
    max_iters: int = 100_000,
    use_ell: bool = True,
    collect_metrics: bool = True,
) -> tuple[EngineState, int]:
    """Host-driven loop: init superstep + supersteps until quiescence."""
    step = jax.jit(partial(bsp_superstep, graph, prog, vdata=vdata,
                           use_ell=use_ell, collect_metrics=collect_metrics))
    es = init_state(graph, prog, vdata)
    for _ in range(max_iters):
        if bool(quiescent(prog, es)):
            break
        es = step(es=es)
    return es, int(es.counters.iterations)
