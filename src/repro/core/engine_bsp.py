"""Standard BSP engine (the paper's Hama baseline).

Every superstep = one distributed exchange + one bulk Compute() over all
(active ∨ messaged) vertices.  Synchronization/communication frequency is
O(#supersteps) — the inefficiency GraphHP attacks.

Message accounting follows the paper's Hama baseline: *all* messages travel
through the distributed mechanism (RPC "by default", §4.1), so M counts both
same-partition and cross-partition combined groups.

This module is configuration only: the superstep body lives in
:mod:`repro.exec.iteration` and the loop in :mod:`repro.exec.driver` —
``run_bsp`` is the executor under :func:`repro.exec.policy.bsp_policy`.
"""

from __future__ import annotations

from typing import Any

from repro.core.runtime import EngineState
from repro.core.vertex_program import VertexProgram
from repro.exec.driver import run_engine
from repro.exec.iteration import bsp_superstep
from repro.exec.policy import bsp_policy

__all__ = ["bsp_superstep", "run_bsp"]


def run_bsp(
    graph,
    prog: VertexProgram,
    vdata: Any = None,
    max_iters: int = 100_000,
    use_ell: bool = True,
    collect_metrics: bool = True,
) -> tuple[EngineState, int]:
    """Host-driven loop: init superstep + supersteps until quiescence."""
    ctx = run_engine(graph, prog,
                     bsp_policy(use_ell=use_ell,
                                collect_metrics=collect_metrics),
                     vdata, max_iters=max_iters)
    return ctx.es, ctx.iteration
