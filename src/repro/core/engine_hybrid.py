"""GraphHP hybrid engine — the paper's contribution (§4.2, §5.2, Algorithm 2).

One *global iteration* =
  1. distributed exchange of the export buffers (the ONLY cross-partition
     communication + the only synchronization point),
  2. **global phase**: each active boundary vertex runs Compute() exactly
     once, consuming the messages buffered since the previous iteration,
  3. **local phase**: pseudo-supersteps iterated *per partition, in memory,
     with zero collectives* until every participating vertex is inactive and
     no local message is in transit (Algorithm 2's inner while loop).

Messages to remote vertices produced anywhere in the iteration accumulate in
the export buffer through ``SourceCombine()`` and ride the next exchange.

Two functionally identical drivers are provided:

* ``run_hybrid``        — host loop (counters, tests, paper tables): the
                          local phase is a ``lax.while_loop`` whose per-
                          partition convergence is tracked with a ``running``
                          mask so pseudo-superstep counts stay faithful;
* ``hybrid_iteration``  — one jittable global iteration, reused by the
                          shard_map distributed lowering in launch/ where the
                          while_loop truly runs decoupled per device.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.graph import PartitionedGraph
from repro.core.runtime import (EngineState, _has_any_pending, apply_phase,
                                deliver, exchange, init_state, quiescent)
from repro.core.vertex_program import StepInfo, VertexProgram

__all__ = ["hybrid_iteration", "run_hybrid", "init_hybrid"]


def _participation_mask(graph: PartitionedGraph, prog: VertexProgram) -> jax.Array:
    """Vertices eligible for local-phase computation (paper §4.2: boundary
    vertices join local phases for incremental algorithms)."""
    if prog.boundary_participates:
        return graph.vertex_mask
    return jnp.logical_and(graph.vertex_mask, jnp.logical_not(graph.is_boundary))


def _partition_running(graph, prog, es, participate, vdata) -> jax.Array:
    """(P,) — does any participating vertex still need a pseudo-superstep?"""
    act = es.active
    gonly = prog.global_only_active(es.state, vdata)
    if gonly is not None:
        act = jnp.logical_and(act, jnp.logical_not(gonly))
    need = jnp.logical_or(act, _has_any_pending(prog, es.pending))
    return jnp.any(jnp.logical_and(need, participate), axis=1)


def hybrid_iteration(
    graph: PartitionedGraph,
    prog: VertexProgram,
    es: EngineState,
    vdata: Any,
    gather_table: Callable | None = None,
    max_local_steps: int = 100_000,
    wire_dtype=None,
) -> EngineState:
    """One global iteration: exchange -> global phase -> local phase."""
    participate = _participation_mask(graph, prog)
    it = es.counters.iterations + 1

    # -- 1. the one distributed exchange ---------------------------------
    es = exchange(graph, es, gather_table, wire_dtype=wire_dtype)
    es = dataclasses.replace(
        es, export_out=prog.export_identity(es.export_out),
        export_send=jnp.zeros_like(es.export_send))
    es, _ = deliver(graph, prog, es, edges="remote")

    # -- 2. global phase: boundary vertices, exactly once -----------------
    # (plus any program-declared global-only-active vertices: interior
    #  vertices waiting on cross-partition round-trips tick here)
    gmask = graph.is_boundary
    gonly = prog.global_only_active(es.state, vdata)
    if gonly is not None:
        gmask = jnp.logical_or(gmask, jnp.logical_and(es.active, gonly))
    info_g = StepInfo(superstep=it, pseudo_step=0, phase="global")
    es = apply_phase(graph, prog, es, gmask, info_g, vdata)
    # boundary -> same-partition messages are processed by the immediate
    # local phase of this iteration (paper §4.2)
    es, _ = deliver(graph, prog, es, edges="local")

    # -- 3. local phase: pseudo-supersteps until per-partition quiescence --
    def cond(carry):
        es_, running, k = carry
        return jnp.logical_and(jnp.any(running), k < max_local_steps)

    def body(carry):
        es_, running, k = carry
        mask = jnp.logical_and(participate, running[:, None])
        info_l = StepInfo(superstep=it, pseudo_step=k + 1, phase="local")
        es_ = apply_phase(graph, prog, es_, mask, info_l, vdata)
        es_, _ = deliver(graph, prog, es_, edges="local")
        running = _partition_running(graph, prog, es_, mask, vdata)
        c = es_.counters
        es_ = dataclasses.replace(es_, counters=dataclasses.replace(
            c, pseudo_supersteps=c.pseudo_supersteps + running.astype(jnp.int32)))
        return es_, running, k + 1

    running0 = _partition_running(graph, prog, es, participate, vdata)
    c0 = es.counters
    es = dataclasses.replace(es, counters=dataclasses.replace(
        c0, pseudo_supersteps=c0.pseudo_supersteps + running0.astype(jnp.int32)))
    es, _, _ = jax.lax.while_loop(cond, body, (es, running0, jnp.zeros((), jnp.int32)))

    c = es.counters
    return dataclasses.replace(
        es, counters=dataclasses.replace(c, iterations=c.iterations + 1))


def init_hybrid(graph: PartitionedGraph, prog: VertexProgram, vdata: Any) -> EngineState:
    """Initialization iteration (iteration 0): same as Hama's first superstep;
    in-partition messages go to pending for iteration 1's phases, crossing
    messages ride the export buffer."""
    es = init_state(graph, prog, vdata)
    es, _ = deliver(graph, prog, es, edges="local")
    return es


def run_hybrid(
    graph: PartitionedGraph,
    prog: VertexProgram,
    vdata: Any = None,
    max_iters: int = 100_000,
    max_local_steps: int = 100_000,
) -> tuple[EngineState, int]:
    step = jax.jit(partial(hybrid_iteration, graph, prog, vdata=vdata,
                           max_local_steps=max_local_steps))
    es = init_hybrid(graph, prog, vdata)
    for _ in range(max_iters):
        if bool(quiescent(prog, es)):
            break
        es = step(es=es)
    return es, int(es.counters.iterations)
