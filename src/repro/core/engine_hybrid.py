"""GraphHP hybrid engine — the paper's contribution (§4.2, §5.2, Algorithm 2).

One *global iteration* =
  1. distributed exchange of the export buffers (the ONLY cross-partition
     communication + the only synchronization point),
  2. **global phase**: each active boundary vertex runs Compute() exactly
     once, consuming the messages buffered since the previous iteration,
  3. **local phase**: pseudo-supersteps iterated *per partition, in memory,
     with zero collectives* until every participating vertex is inactive and
     no local message is in transit (Algorithm 2's inner while loop).

Messages to remote vertices produced anywhere in the iteration accumulate in
the export buffer through ``SourceCombine()`` and ride the next exchange.

This module is configuration only: the iteration body lives in
:mod:`repro.exec.iteration` (re-exported here), the local phase and its
fused Pallas kernels in :mod:`repro.exec.local_phase`, and the loop in
:mod:`repro.exec.driver` — ``run_hybrid`` is the executor under
:func:`repro.exec.policy.hybrid_policy`, with ``device_loop=True`` lowering
the whole outer loop into one jitted ``lax.while_loop``.
"""

from __future__ import annotations

from typing import Any

from repro.core.runtime import EngineState
from repro.core.vertex_program import VertexProgram
from repro.exec.driver import run_engine
from repro.exec.iteration import hybrid_iteration, init_hybrid
from repro.exec.local_phase import fused_local_kernel, fused_step_fn

__all__ = ["hybrid_iteration", "run_hybrid", "init_hybrid", "fused_step_fn"]

# back-compat alias (kernel tests poke the fused-dispatch gate directly)
_fused_local_kernel = fused_local_kernel


def run_hybrid(
    graph,
    prog: VertexProgram,
    vdata: Any = None,
    max_iters: int = 100_000,
    max_local_steps: int = 100_000,
    use_ell: bool = True,
    collect_metrics: bool = True,
    device_loop: bool = True,
) -> tuple[EngineState, int]:
    """Run global iterations to quiescence.

    ``device_loop=True`` (default) runs the whole outer loop as one jitted
    device-side ``lax.while_loop`` — the per-iteration ``bool(quiescent(...))``
    host round-trip disappears and the host syncs exactly once at the end.
    ``device_loop=False`` keeps the host-driven loop (useful when
    stepping/debugging iteration by iteration).

    Args:
        graph: the ``PartitionedGraph`` to iterate over.
        prog: the ``VertexProgram``; its channels decide kernel dispatch
            (semiring / ``fused_kernel`` / lane width).
        vdata: optional per-run auxiliary arrays handed to the program's
            hooks (e.g. ``{"sources": (K,) int32}`` for the K-lane
            multi-query programs); traced, so varying it does not recompile.
        max_iters: upper bound on global iterations; the loop stops early
            at quiescence (no active vertices, no pending or in-flight
            messages).
        max_local_steps: per-iteration cap on local pseudo-supersteps
            before the local phase cuts off (with rollback semantics for
            monotone fused kernels).
        use_ell: dispatch delivery through the sliced-ELL Pallas kernels
            where the program qualifies; ``False`` forces the dense
            gather/segment path (identical results and counters).
        collect_metrics: maintain the paper's per-iteration I/M message
            counters; ``False`` drops the accounting work from the hot
            loop (only ``iterations`` / ``pseudo_supersteps`` count).
        device_loop: see above.

    Returns:
        ``(es, iterations)`` — the final ``EngineState`` (per-channel
        state stacked ``(P, Vp[, L])``; read it back in global vertex
        order via ``graph.unpack_vertex``) and the number of global
        iterations executed, ``int(es.counters.iterations)``.
    """
    from repro.exec.policy import hybrid_policy

    policy = hybrid_policy(use_ell=use_ell, collect_metrics=collect_metrics,
                           max_local_steps=max_local_steps)
    ctx = run_engine(graph, prog, policy, vdata, max_iters=max_iters,
                     device_loop=device_loop)
    return ctx.es, ctx.iteration
