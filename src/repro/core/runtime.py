"""Shared execution primitives for the three engines (Hama / AM-Hama / GraphHP).

The engines differ only in *when* they exchange across partitions and *which*
edges deliver in a step; the primitives here are common:

  ``exchange``         gather exported out-states across the partition cut
                       (the once-per-iteration distributed communication),
  ``deliver``          generate + combine messages along a selected edge set
                       into the per-vertex pending inboxes,
  ``apply_phase``      run the vertex program on a masked vertex set,
                       consuming pending inboxes (Pregel reactivation rules).

All primitives run on partition-major arrays ``(P, ...)`` and are pure, so the
same code serves the host (all partitions on one device; used by tests and the
paper-table benchmarks) and the distributed `shard_map` lowering (a block of
partitions per device; used by the multi-pod dry-run) — only the export-table
gather differs, which is injected as ``gather_table``.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.graph import EllSlice, PartitionedGraph
from repro.core.vertex_program import (Channel, StepInfo, VertexProgram,
                                       combine_segments)

__all__ = ["Counters", "EngineState", "init_state", "exchange", "deliver",
           "apply_phase", "merge_inbox", "quiescent", "gather_per_partition",
           "ell_channels", "ell_f32_exact", "ell_slices", "slice_flat",
           "ell_send_accounting", "ell_group_accounting"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Counters:
    """The paper's metrics: I (global iterations), M (network messages), plus
    in-memory message and pseudo-superstep counts."""

    iterations: jax.Array          # () int32
    pseudo_supersteps: jax.Array   # (P,) int32
    net_messages: jax.Array        # () int32  — combined, crossing the cut
    net_local_messages: jax.Array  # () int32  — combined, same-partition RPC (Hama)
    mem_messages: jax.Array        # () int32  — raw in-memory deliveries

    @staticmethod
    def zeros(p: int) -> "Counters":
        z = jnp.zeros((), jnp.int32)
        return Counters(z, jnp.zeros((p,), jnp.int32), z, z, z)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class EngineState:
    state: Any                 # app vertex state: dict of (P, Vp, ...)
    out: Any                   # current out-state: dict of (P, Vp, ...)
    send: jax.Array            # (P, Vp) bool — sent in the last apply
    active: jax.Array          # (P, Vp) bool
    export_out: Any            # accumulated out-state for the next exchange
    export_send: jax.Array     # (P, Vp) bool accumulated
    pending: Any               # {ch: (payload tuple (P,Vp,...), has (P,Vp))}
    halo_out: Any              # dict of (P, H, ...) — gathered remote out-states
    halo_send: jax.Array       # (P, H) bool
    counters: Counters


def gather_per_partition(leaf: jax.Array, idx: jax.Array) -> jax.Array:
    """leaf (P, N, ...) gathered with idx (P, K) -> (P, K, ...)."""
    return jax.vmap(lambda l, i: l[i])(leaf, idx)


def _empty_inbox(prog: VertexProgram, p: int, vp: int):
    return {
        ch.name: (ch.identity_like((p, vp)), jnp.zeros((p, vp), bool))
        for ch in prog.channels
    }


def init_state(graph: PartitionedGraph, prog: VertexProgram, vdata: Any) -> EngineState:
    """Run the paper's initialization iteration (superstep 0)."""
    state, out, send, active = prog.init(graph.vertex_gid, graph.vertex_mask, vdata)
    send = jnp.logical_and(send, graph.vertex_mask)
    active = jnp.logical_and(active, graph.vertex_mask)
    p, vp, h = graph.n_partitions, graph.vp, graph.hp
    halo_out = jax.tree.map(
        lambda l: jnp.zeros((p, h) + l.shape[2:], l.dtype), out)
    return EngineState(
        state=state, out=out, send=send, active=active,
        export_out=out, export_send=send,
        pending=_empty_inbox(prog, p, vp),
        halo_out=halo_out, halo_send=jnp.zeros((p, h), bool),
        counters=Counters.zeros(p),
    )


# ---------------------------------------------------------------------------
# exchange: the once-per-global-iteration distributed communication.
# ---------------------------------------------------------------------------

def exchange(
    graph: PartitionedGraph,
    es: EngineState,
    gather_table: Callable[[Any], Any] | None = None,
    wire_dtype=None,
) -> EngineState:
    """Gather exported out-states through the halo plan.

    ``gather_table`` maps per-partition export buffers (P_local, X, ...) to the
    globally-visible table (P, X, ...); identity on the host, an all-gather
    over the device axis inside shard_map.

    ``wire_dtype`` (e.g. bf16) quantizes float payloads *before* the wire —
    a GraphHP ``Combine()``-style bandwidth optimization: halves exchange
    bytes; safe for monotone/incremental programs (min/accumulate re-apply
    the combiner on the receiver) at ≤0.4% value quantization.  §Perf.
    """
    exports = jax.tree.map(
        lambda l: gather_per_partition(l, graph.export_slot), es.export_out)
    exp_send = jnp.logical_and(
        gather_per_partition(es.export_send, graph.export_slot),
        graph.export_mask)
    dtypes = jax.tree.map(lambda l: l.dtype, exports)
    if wire_dtype is not None:
        # quantize, then BITCAST to the integer carrier: a plain
        # convert->allgather->convert chain gets folded away by XLA's
        # simplifier (lossy-cast hoisting), erasing the wire savings
        carrier = jnp.uint16 if wire_dtype == jnp.bfloat16 else jnp.uint8
        exports = jax.tree.map(
            lambda l: jax.lax.bitcast_convert_type(
                l.astype(wire_dtype), carrier)
            if jnp.issubdtype(l.dtype, jnp.floating) else l, exports)
    if gather_table is not None:
        exports = gather_table(exports)
        exp_send = gather_table(exp_send)
    if wire_dtype is not None:
        # decode iff the ORIGINAL leaf was floating (the saved dtypes tree
        # drives the decision): keying on the carrier dtype would also
        # bitcast channels whose genuine payload dtype is uint16/uint8 and
        # corrupt them on the way back
        exports = jax.tree.map(
            lambda l, dt: jax.lax.bitcast_convert_type(l, wire_dtype)
            .astype(dt) if jnp.issubdtype(dt, jnp.floating) else l,
            exports, dtypes)

    def pull(leaf):
        flat = leaf.reshape((-1,) + leaf.shape[2:])
        return flat[graph.halo_ptr]

    halo_out = jax.tree.map(pull, exports)
    halo_send = jnp.logical_and(pull(exp_send), graph.halo_mask)
    return dataclasses.replace(es, halo_out=halo_out, halo_send=halo_send)


# ---------------------------------------------------------------------------
# deliver: emit + combine along a selected edge set into pending inboxes.
# ---------------------------------------------------------------------------

def merge_inbox(ch: Channel, a, b):
    """Pairwise monoid merge of two combined inboxes (payloads, has)."""
    (pa, ha), (pb, hb) = a, b
    has = jnp.logical_or(ha, hb)
    if ch.combiner == "sum":
        out = tuple(x + y for x, y in zip(pa, pb))
    elif ch.combiner == "min":
        out = tuple(jnp.minimum(x, y) for x, y in zip(pa, pb))
    elif ch.combiner == "max":
        out = tuple(jnp.maximum(x, y) for x, y in zip(pa, pb))
    elif ch.combiner == "lexmin":
        a_lt_b = _lex_lt(pa, pb)
        out = tuple(jnp.where(a_lt_b, x, y) for x, y in zip(pa, pb))
    else:  # pragma: no cover
        raise ValueError(ch.combiner)
    return out, has


def _lex_lt(pa, pb):
    lt = jnp.zeros(pa[0].shape, bool)
    eq = jnp.ones(pa[0].shape, bool)
    for x, y in zip(pa, pb):
        lt = jnp.logical_or(lt, jnp.logical_and(eq, x < y))
        eq = jnp.logical_and(eq, x == y)
    return jnp.logical_or(lt, eq)  # ties keep a


def ell_f32_exact(ch: Channel, payload_bound: int) -> bool:
    """Integer payloads ride the kernel as float32, which is only exact up
    to 2**24 — past that, vertex-id-valued payloads (WCC labels) would be
    silently rounded.  Judged per ELL degree bin: ``payload_bound`` is the
    largest source gid feeding the bin, which bounds every monotone
    min-label payload flowing through it (a HashMin label never exceeds its
    carrier's own gid)."""
    (dt, _), = ch.components
    if not jnp.issubdtype(jnp.dtype(dt), jnp.integer):
        return True
    return payload_bound <= (1 << 24)


def ell_slices(graph: PartitionedGraph, edges: str) -> tuple[EllSlice, ...]:
    return graph.local_ell if edges == "local" else graph.remote_ell


def ell_channels(graph: PartitionedGraph, prog: VertexProgram,
                 out, send, edges: str = "local") -> list[Channel]:
    """Channels eligible for kernel-backed delivery of ``edges``
    ('local' | 'remote'): the graph carries that side's sliced-ELL layout
    and the channel declares a matching single-component semiring whose
    ``ell_payload`` hook is implemented (and whose payloads survive every
    bin's float32 carriage exactly — see :func:`ell_f32_exact`).  The
    decision is static (per program/channel/bin, not data-dependent)."""
    slices = ell_slices(graph, edges)
    if not slices:
        return []
    return [ch for ch in prog.channels
            if ch.semiring is not None and len(ch.components) == 1
            and all(ell_f32_exact(ch, s.payload_bound) for s in slices)
            and prog.ell_payload(ch, out, send) is not None]


def slice_flat(s: EllSlice, graph: PartitionedGraph, p: int):
    """Flattened (rows, idx, msk) views of one ELL slice for a p-partition
    block.  The build-time cache serves the host path (the block covers the
    whole graph); inside a shard_map block the block-ragged tiles are
    re-offset with block-local strides instead: the owning partition of
    each tile row is recovered from its block-relative row id
    (``p_rel = row // Vp``; the sentinel clips to the last partition of
    the block, where the mask discards it)."""
    kb = s.kb
    if p == graph.n_partitions:
        return s.flat_rows, s.flat_idx, s.msk.reshape(-1, kb)
    b = s.rows.shape[0]                   # block rows in this shard
    ppb = p // b
    bvp = ppb * graph.vp
    prel = jnp.clip(s.rows // graph.vp, 0, ppb - 1)
    pabs = jnp.arange(b, dtype=jnp.int32)[:, None] * ppb + prel
    idx = (s.idx + (pabs * s.stride)[..., None]).reshape(-1, kb)
    rows = jnp.where(
        s.rows < bvp,
        s.rows + (jnp.arange(b, dtype=jnp.int32) * bvp)[:, None],
        p * graph.vp).reshape(-1)
    return rows, idx, s.msk.reshape(-1, kb)


# ⊕-combination of per-bin partials into the per-destination output; the
# scatter indices carry an out-of-range sentinel on padded rows, dropped.
_SCATTER = {
    "add_mul": lambda y, r, v: y.at[r].add(v, mode="drop"),
    "min_add": lambda y, r, v: y.at[r].min(v, mode="drop"),
    "min_mul": lambda y, r, v: y.at[r].min(v, mode="drop"),
    "max_add": lambda y, r, v: y.at[r].max(v, mode="drop"),
    "max_min": lambda y, r, v: y.at[r].max(v, mode="drop"),
}


def ell_combine_bins(prog, ch, slices, views, x, y, p: int, interpret: bool):
    """⊕-combine each bin's ``ell_spmv`` partials onto the flat destination
    vector ``y`` — the dense base bin via the semiring combine, spill bins
    via semiring scatter over their row lists.  The single source of truth
    for `deliver`'s kernel path and the fused local phases' spill operand."""
    from repro.kernels.ell_spmv import ell_spmv
    from repro.kernels.common import SEMIRINGS

    combine, _, _ = SEMIRINGS[ch.semiring]
    for s, (rows, idx, msk) in zip(slices, views):
        v = prog.ell_edge_values(ch, s.val).reshape(-1, s.kb)
        yb = ell_spmv(idx, v, msk, x, semiring=ch.semiring,
                      interpret=interpret)
        if s.dense:
            y = combine(y, yb)
        else:
            y = _SCATTER[ch.semiring](y, rows, yb)
    return y


def ell_send_accounting(graph: PartitionedGraph, slices, views, send_flat,
                        p: int):
    """Exact parity with the dense local accounting, from the ELL layout:
    per-destination has-flags (one combined local group per messaged dst)
    and the raw in-memory message count (every valid sender edge slot).
    The single source of truth for both `deliver`'s kernel path and the
    fused local phases."""
    has = jnp.zeros((p * graph.vp,), bool)
    mem = jnp.zeros((), jnp.int32)
    for s, (rows, idx, msk) in zip(slices, views):
        tile = jnp.logical_and(send_flat[idx], msk)
        row_has = jnp.any(tile, axis=-1)
        if s.dense:
            has = jnp.logical_or(has, row_has)
        else:
            has = has.at[rows].max(row_has, mode="drop")
        mem += jnp.sum(tile).astype(jnp.int32)
    return has.reshape(p, graph.vp), mem


def ell_group_accounting(graph: PartitionedGraph, slices, views, send_flat,
                         p: int) -> jax.Array:
    """Combined-message count at the paper's Combine() granularity — one per
    (destination vertex, source partition) group with a sending edge — read
    straight off the ELL tiles via the per-slot ``grp`` ids.  This is the
    tile-resident replacement for the dense per-group segment reduction:
    exact parity, because the tiles hold exactly the delivering edge set and
    ``grp`` carries the same ids as ``PartitionedGraph.edge_group`` —
    block-relative flat, so each block row offsets by its row index times
    the shared group width.  Padded slots contribute False updates (their
    grp id is an arbitrary in-range slot), which a boolean ``max`` scatter
    ignores."""
    if not slices:
        return jnp.zeros((), jnp.int32)
    b = slices[0].grp.shape[0]
    offs = (jnp.arange(b, dtype=jnp.int32) * graph.gp)[:, None, None]
    sent = jnp.zeros((b * graph.gp,), bool)
    for s, (_, idx, msk) in zip(slices, views):
        tile = jnp.logical_and(send_flat[idx], msk)
        grp = (s.grp + offs).reshape(tile.shape)
        sent = sent.at[grp].max(tile)
    return jnp.sum(sent).astype(jnp.int32)


def _ell_deliver(graph, prog, chs, es, pending, delivered, collect_metrics,
                 edges: str):
    """Kernel-backed delivery for semiring channels along ``edges``.

    Local deliveries read the (P*Vp,) out-state frontier; remote deliveries
    read the concat(out, halo_out) frontier of stride Vp + H, with sources
    halo-encoded as Vp + halo_slot.  Each sliced-ELL degree bin runs one
    `ell_spmv` Pallas call over its flattened tiles; spill-bin partials are
    ⊕-scattered onto the dense base bin's output.  The has-message flags
    (and, when ``collect_metrics``, the paper counters) come from a cheap
    masked gather of the send flags through the same layout.
    """
    from repro.kernels.common import SEMIRINGS, default_interpret

    p, vp = es.send.shape
    slices = ell_slices(graph, edges)
    if edges == "local":
        out_tab, send_tab = es.out, es.send
    else:
        cat = lambda a, b: jnp.concatenate([a, b], axis=1)
        out_tab = jax.tree.map(cat, es.out, es.halo_out)
        send_tab = cat(es.send, es.halo_send)
    send_flat = send_tab.reshape(-1)
    interpret = default_interpret()

    # has-message flags per destination, shared by every kernel channel
    views = [slice_flat(s, graph, p) for s in slices]
    has_fresh, mem_edges = ell_send_accounting(graph, slices, views,
                                               send_flat, p)
    delivered = jnp.logical_or(delivered, jnp.any(has_fresh, axis=1))

    net = jnp.zeros((), jnp.int32)
    net_local = jnp.zeros((), jnp.int32)
    mem = jnp.zeros((), jnp.int32)
    for ch in chs:
        _, _, ident = SEMIRINGS[ch.semiring]
        x = prog.ell_payload(ch, out_tab, send_tab)
        # lane channels carry a trailing (L,) axis through the same kernel
        # dispatch (semiring SpMM): flatten partitions only, keep lanes
        x = x.reshape((-1,) + x.shape[2:]).astype(jnp.float32)
        y = jnp.full((p * vp,) + x.shape[1:], ident, jnp.float32)
        y = ell_combine_bins(prog, ch, slices, views, x, y, p, interpret)
        y = y.reshape((p, vp) + y.shape[1:])
        dt, ident_ch = ch.components[0]
        has_b = has_fresh.reshape(
            has_fresh.shape + (1,) * (y.ndim - has_fresh.ndim))
        payload = jnp.where(has_b, y.astype(dt), jnp.asarray(ident_ch, dt))
        pending[ch.name] = merge_inbox(ch, pending[ch.name],
                                       ((payload,), has_fresh))
        if collect_metrics and edges == "local":
            # local deliveries: one combine group per messaged destination
            # (same-partition source), every valid edge an in-memory message
            net_local += jnp.sum(has_fresh).astype(jnp.int32)
            mem += mem_edges

    if collect_metrics and edges == "remote" and chs:
        # remote deliveries count per (source-partition, destination) combine
        # group, exactly like the dense path's accounting — but read off the
        # ELL tiles' per-slot group ids instead of re-reducing the dense edge
        # arrays; semiring channels declare an always-valid emit, so one
        # tile pass covers every kernel channel identically.
        net += len(chs) * ell_group_accounting(graph, slices, views,
                                               send_flat, p)

    return pending, delivered, net, net_local, mem


def deliver(
    graph: PartitionedGraph,
    prog: VertexProgram,
    es: EngineState,
    edges: str,                  # 'all' | 'local' | 'remote'
    use_halo: bool = True,
    use_ell: bool = False,
    collect_metrics: bool = True,
) -> tuple[EngineState, jax.Array]:
    """Messages from the last apply travel along ``edges`` into pending.

    Returns (state', delivered_any (P,) bool).  Updates the message counters:
    remote deliveries count as combined network messages (one per
    (source-partition, destination-vertex) group, i.e. post-``Combine()``),
    local deliveries as in-memory messages.

    ``use_ell`` dispatches semiring-declared channels of a 'local' or
    'remote' delivery to the Pallas ELL kernels (see :func:`ell_channels`);
    other channels — and every channel of 'all' deliveries — keep the dense
    gather/segment path.  ``collect_metrics=False`` skips the paper's
    message-accounting reductions entirely (the perf path pays nothing; the
    counters then stay at their previous values).
    """
    vp = graph.vp

    kernel_chs = ell_channels(graph, prog, es.out, es.send, edges) \
        if (use_ell and edges in ("local", "remote")
            and (use_halo or edges == "local")) else []
    dense_chs = [ch for ch in prog.channels if ch not in kernel_chs]

    pending = dict(es.pending)
    delivered = jnp.zeros((es.send.shape[0],), bool)
    net = jnp.zeros((), jnp.int32)
    net_local = jnp.zeros((), jnp.int32)
    mem = jnp.zeros((), jnp.int32)

    if kernel_chs:
        pending, delivered, nt, nl, mm = _ell_deliver(
            graph, prog, kernel_chs, es, pending, delivered, collect_metrics,
            edges)
        net += nt
        net_local += nl
        mem += mm

    if dense_chs:
        # per-edge source out-state and send flag (local then halo slots)
        def cat(local_leaf, halo_leaf):
            return jnp.concatenate([local_leaf, halo_leaf], axis=1)

        if use_halo:
            src_tab = jax.tree.map(cat, es.out, es.halo_out)
            send_tab = cat(es.send, es.halo_send)
        else:
            src_tab = jax.tree.map(
                lambda l: jnp.concatenate(
                    [l, jnp.zeros((l.shape[0], graph.hp) + l.shape[2:], l.dtype)],
                    axis=1),
                es.out)
            send_tab = cat(es.send, jnp.zeros((es.send.shape[0], graph.hp), bool))

        # the edge family is block-ragged (B block rows of p // B
        # consecutive partitions side by side), so gathers and segment
        # combines run flat: `edge_part` recovers each slot's absolute
        # partition, from which source-table and destination indices
        # follow
        p = es.send.shape[0]
        bsz = graph.edge_src.shape[0]
        ppb = p // bsz
        epart = (graph.edge_part
                 + (jnp.arange(bsz, dtype=jnp.int32) * ppb)[:, None])
        width = vp + graph.hp
        flat_src = (epart * width + graph.edge_src).reshape(-1)
        out_src = jax.tree.map(
            lambda l: l.reshape((p * width,) + l.shape[2:])[flat_src]
            .reshape(graph.edge_src.shape + l.shape[2:]), src_tab)
        send_e = send_tab.reshape(-1)[flat_src].reshape(graph.edge_src.shape)

        if edges == "all":
            sel = graph.edge_mask
        elif edges == "local":
            sel = jnp.logical_and(graph.edge_mask, graph.edge_local)
        elif edges == "remote":
            sel = jnp.logical_and(graph.edge_mask,
                                  jnp.logical_not(graph.edge_local))
        else:  # pragma: no cover
            raise ValueError(edges)
        base_valid = jnp.logical_and(sel, send_e)

        dst_flat = (epart * vp + graph.edge_dst).reshape(-1)
        gseg = (graph.edge_group
                + (jnp.arange(bsz, dtype=jnp.int32) * graph.gp)[:, None]
                ).reshape(-1)
        for ch in dense_chs:
            payloads, valid = prog.emit(
                ch, out_src, graph.edge_w, graph.edge_src_gid, graph.edge_dst_gid)
            valid = jnp.logical_and(valid, base_valid)
            valid_flat = valid.reshape(-1)
            comb_pl, comb_has = combine_segments(
                ch, tuple(x.reshape((-1,) + x.shape[2:]) for x in payloads),
                valid_flat, dst_flat, p * vp)
            fresh = (tuple(x.reshape((p, vp) + x.shape[1:]) for x in comb_pl),
                     comb_has.reshape(p, vp))
            pending[ch.name] = merge_inbox(ch, pending[ch.name], fresh)
            delivered = jnp.logical_or(
                delivered,
                jnp.zeros((p,), bool).at[epart.reshape(-1)].max(valid_flat))
            if not collect_metrics:
                continue
            # --- paper metrics ---------------------------------------------
            grp_sent = jax.ops.segment_max(
                valid_flat.astype(jnp.int32), gseg,
                num_segments=bsz * graph.gp).reshape(bsz, graph.gp) > 0
            grp_sent = jnp.logical_and(grp_sent, graph.group_mask)
            net += jnp.sum(jnp.logical_and(grp_sent, graph.group_remote)).astype(jnp.int32)
            net_local += jnp.sum(
                jnp.logical_and(grp_sent, jnp.logical_not(graph.group_remote))
            ).astype(jnp.int32)
            mem += jnp.sum(jnp.logical_and(valid, graph.edge_local)).astype(jnp.int32)

    c = es.counters
    counters = dataclasses.replace(
        c, net_messages=c.net_messages + net,
        net_local_messages=c.net_local_messages + net_local,
        mem_messages=c.mem_messages + mem)
    return dataclasses.replace(es, pending=pending, counters=counters), delivered


# ---------------------------------------------------------------------------
# apply: run Compute() on a masked vertex set, consuming pending inboxes.
# ---------------------------------------------------------------------------

def _has_any_pending(prog: VertexProgram, pending) -> jax.Array:
    flags = [pending[ch.name][1] for ch in prog.channels]
    out = flags[0]
    for f in flags[1:]:
        out = jnp.logical_or(out, f)
    return out


def apply_phase(
    graph: PartitionedGraph,
    prog: VertexProgram,
    es: EngineState,
    phase_mask: jax.Array,       # (P, Vp) bool — vertices allowed in this phase
    info: StepInfo,
    vdata: Any,
) -> EngineState:
    """Compute() on ``phase_mask ∧ (active ∨ has-message)`` vertices."""
    has_msg = _has_any_pending(prog, es.pending)
    compute = jnp.logical_and(graph.vertex_mask, phase_mask)
    compute = jnp.logical_and(compute, jnp.logical_or(es.active, has_msg))

    new_state, new_out, new_send, new_active = prog.apply(
        es.state, es.pending, graph.vertex_gid, graph.vertex_mask, vdata, info)

    def sel(new, old):
        m = compute.reshape(compute.shape + (1,) * (new.ndim - compute.ndim))
        return jnp.where(m, new, old)

    state = jax.tree.map(sel, new_state, es.state)
    out = jax.tree.map(sel, new_out, es.out)
    send = jnp.logical_and(jnp.logical_and(new_send, compute), graph.vertex_mask)
    active = jnp.where(compute, jnp.logical_and(new_active, graph.vertex_mask),
                       es.active)

    # consumed inboxes reset to the channel identity
    pending = {}
    for ch in prog.channels:
        payloads, has = es.pending[ch.name]
        keep = jnp.logical_not(compute)
        ident = ch.identity_like(has.shape)
        payloads = tuple(
            jnp.where(keep.reshape(keep.shape + (1,) * (p.ndim - keep.ndim)), p, i)
            for p, i in zip(payloads, ident))
        pending[ch.name] = (payloads, jnp.logical_and(has, keep))

    # export accumulation (SourceCombine) — only freshly computed sends count
    export_out, export_send = prog.accumulate_export(
        es.export_out, es.export_send, out, send)

    return dataclasses.replace(
        es, state=state, out=out, send=send, active=active, pending=pending,
        export_out=export_out, export_send=export_send)


def quiescent(prog: VertexProgram, es: EngineState) -> jax.Array:
    """Termination: no active vertex, nothing pending, nothing left to export."""
    return jnp.logical_not(
        jnp.any(es.active)
        | jnp.any(_has_any_pending(prog, es.pending))
        | jnp.any(es.export_send))
