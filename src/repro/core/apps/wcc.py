"""Weakly-connected components by min-label propagation.

The classic Pregel "HashMin" program: every vertex repeatedly adopts the
smallest component label it hears about.  Used both as a fourth application
and as the substrate for the paper's Single Pivot discussion (§1): a
high-diameter component converges in O(P) global iterations on GraphHP vs
O(diameter) supersteps on Hama.  Run on a symmetrized edge list.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.vertex_program import Channel, StepInfo, VertexProgram

_IMAX = jnp.iinfo(jnp.int32).max


class WCC(VertexProgram):
    channels = (Channel("label", "min", ((jnp.int32, _IMAX),)),)
    boundary_participates = True

    def init(self, gid, vmask, vdata):
        label = jnp.where(vmask, gid, _IMAX).astype(jnp.int32)
        return {"label": label}, {"label": label}, vmask, jnp.zeros_like(vmask)

    def emit(self, ch, out_src, w, src_gid, dst_gid):
        return (out_src["label"],), jnp.ones(w.shape, bool)

    def apply(self, state, inbox, gid, vmask, vdata, info: StepInfo):
        (msg,), has = inbox["label"]
        new = jnp.minimum(state["label"], jnp.where(has, msg, _IMAX))
        send = new < state["label"]
        return {"label": new}, {"label": new}, send, jnp.zeros_like(send)
