"""Weakly-connected components by min-label propagation.

The classic Pregel "HashMin" program: every vertex repeatedly adopts the
smallest component label it hears about.  Used both as a fourth application
and as the substrate for the paper's Single Pivot discussion (§1): a
high-diameter component converges in O(P) global iterations on GraphHP vs
O(diameter) supersteps on Hama.  Run on a symmetrized edge list.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.vertex_program import Channel, StepInfo, VertexProgram

_IMAX = jnp.iinfo(jnp.int32).max


class WCC(VertexProgram):
    channels = (Channel("label", "min", ((jnp.int32, _IMAX),),
                        semiring="min_add"),)
    boundary_participates = True
    # min-label propagation fuses through `min_step` like SSSP; the engine
    # gate keeps integer labels off the float32-resident fused loop past
    # 2**24 vertices (plain per-bin ELL delivery still applies below that)
    fused_kernel = "min_step"

    def init(self, gid, vmask, vdata):
        label = jnp.where(vmask, gid, _IMAX).astype(jnp.int32)
        return {"label": label}, {"label": label}, vmask, jnp.zeros_like(vmask)

    def emit(self, ch, out_src, w, src_gid, dst_gid):
        return (out_src["label"],), jnp.ones(w.shape, bool)

    # kernel path: labels ride min_add with zeroed edge values — exact for
    # labels < 2**24 (float32-representable vertex ids); runtime.ell_channels
    # enforces the bound and falls back to dense past it
    def ell_payload(self, ch, out, send):
        return jnp.where(send, out["label"].astype(jnp.float32), jnp.inf)

    def ell_edge_values(self, ch, val):
        return jnp.zeros_like(val)

    def apply(self, state, inbox, gid, vmask, vdata, info: StepInfo):
        (msg,), has = inbox["label"]
        new = jnp.minimum(state["label"], jnp.where(has, msg, _IMAX))
        send = new < state["label"]
        return {"label": new}, {"label": new}, send, jnp.zeros_like(send)
