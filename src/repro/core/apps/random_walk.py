"""Most-likely absorbing random walk: per-vertex best-path probability mass.

A walker starts at ``source`` and steps to a uniformly random out-neighbour;
the probability of one particular walk is the product of its step
probabilities ``p(u -> v) = 1 / out_degree(u)``.  Each vertex computes the
probability of the *most likely* walk reaching it — the Viterbi-style
fixed point ``P[v] = max over in-edges of P[u] * p(u -> v)`` — which is the
(max, *) closure.  Two isomorphic monotone formulations exercise both
dormant kernel semirings; :func:`random_walk_edge_weights` builds the
matching edge-weight convention host-side (so the device hot loop is pure
⊗ arithmetic — no runtime log, whose vectorized lowering is not
bit-deterministic across array shapes):

  * ``mode='odds'``    — weights ``w = out_degree(src)`` (≥ 1); state is
    the walk's inverse probability ``1/P = Π w``; cycles multiply by ≥ 1,
    so the *minimum* over walks is the fixed point: the (min, *) semiring
    (``min_mul``).
  * ``mode='logprob'`` — weights ``w = log p = -log out_degree(src)``
    (≤ 0); state is ``log P = Σ w``; the *maximum* over walks is the fixed
    point: the (max, +) semiring (``max_add``).

Both are adopt-if-better monotone programs (SSSP with the algebra swapped),
so boundary vertices join local phases and the whole local phase fuses
through the generalized `min_step` kernel.  ``probability`` converts either
state back to P for comparison against the oracle (1 at the source,
0 where unreachable).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.vertex_program import Channel, StepInfo, VertexProgram

INF = jnp.float32(jnp.inf)


class RandomWalk(VertexProgram):
    boundary_participates = True
    # single min/min_mul (or max/max_add) channel, out == state,
    # adopt-if-better apply, never self-activating, keep-latest export
    fused_kernel = "min_step"

    def __init__(self, source: int, mode: str = "odds"):
        if mode not in ("odds", "logprob"):  # pragma: no cover
            raise ValueError(mode)
        self.source = source
        self.mode = mode
        if mode == "odds":
            self.channels = (Channel("mass", "min", ((jnp.float32, jnp.inf),),
                                     semiring="min_mul"),)
        else:
            self.channels = (Channel("mass", "max", ((jnp.float32, -jnp.inf),),
                                     semiring="max_add"),)

    @property
    def _ident(self):
        return INF if self.mode == "odds" else -INF

    def init(self, gid, vmask, vdata):
        is_src = gid == self.source
        # odds: 1/P = 1 at the source; logprob: log P = 0
        start = jnp.float32(1.0 if self.mode == "odds" else 0.0)
        mass = jnp.where(is_src, start, self._ident).astype(jnp.float32)
        state = {"mass": mass}
        out = {"mass": mass}
        send = jnp.logical_and(is_src, vmask)
        active = jnp.zeros_like(vmask)          # voteToHalt()
        return state, out, send, active

    def emit(self, ch, out_src, w, src_gid, dst_gid):
        # the graph carries the mode's weight convention (see module doc)
        if self.mode == "odds":
            msg = out_src["mass"] * w
        else:
            msg = out_src["mass"] + w
        return (msg,), jnp.ones(w.shape, bool)

    def ell_payload(self, ch, out, send):
        # message = mass[src] ⊗ edge_val; non-senders take the ⊕ identity
        return jnp.where(send, out["mass"], self._ident)

    def apply(self, state, inbox, gid, vmask, vdata, info: StepInfo):
        (msg,), has = inbox["mass"]
        masked = jnp.where(has, msg, self._ident)
        if self.mode == "odds":
            new = jnp.minimum(state["mass"], masked)
            send = new < state["mass"]
        else:
            new = jnp.maximum(state["mass"], masked)
            send = new > state["mass"]
        state = {"mass": new}
        return state, {"mass": new}, send, jnp.zeros_like(send)

    def probability(self, mass):
        """Best-walk probability P from either state convention."""
        if self.mode == "odds":
            return jnp.where(jnp.isfinite(mass), 1.0 / mass, 0.0)
        return jnp.where(jnp.isfinite(mass), jnp.exp(mass), 0.0)


def random_walk_edge_weights(edges, n_vertices, mode: str = "odds"):
    """Uniform-transition edge weights in the mode's convention: inverse
    step probability ``out_degree(src)`` for 'odds' (≥ 1, so the min_mul
    closure is monotone), ``-log out_degree(src)`` = log p for 'logprob'
    (≤ 0, so the max_add closure is monotone).  Computed host-side so the
    device hot loop never evaluates a transcendental."""
    deg = np.bincount(edges[:, 0], minlength=n_vertices).astype(np.float32)
    w = deg[edges[:, 0]]
    return w if mode == "odds" else -np.log(w)
