"""Single-source shortest paths (paper §6.1, Algorithm 4).

Min-combiner over distance messages; a vertex relaxes and re-sends only when
its value improves; always votes to halt.  Incremental (monotone min), so
boundary vertices participate in local phases (paper recommendation).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.vertex_program import Channel, StepInfo, VertexProgram

INF = jnp.float32(jnp.inf)


class SSSP(VertexProgram):
    channels = (Channel("dist", "min", ((jnp.float32, jnp.inf),),
                        semiring="min_add"),)
    boundary_participates = True
    # the hybrid engine may run the whole local phase through the fused
    # `min_step` Pallas kernel: single min_add channel, out == state,
    # relax-on-improve apply, never self-activating, keep-latest export
    fused_kernel = "min_step"

    def __init__(self, source: int):
        self.source = source

    def init(self, gid, vmask, vdata):
        is_src = gid == self.source
        dist = jnp.where(is_src, 0.0, INF).astype(jnp.float32)
        state = {"dist": dist}
        out = {"dist": dist}
        send = jnp.logical_and(is_src, vmask)
        active = jnp.zeros_like(vmask)          # voteToHalt()
        return state, out, send, active

    def emit(self, ch, out_src, w, src_gid, dst_gid):
        return (out_src["dist"] + w,), jnp.ones(w.shape, bool)

    def ell_payload(self, ch, out, send):
        # message = dist[src] + w; non-senders relax to +inf (min identity)
        return jnp.where(send, out["dist"], INF)

    def apply(self, state, inbox, gid, vmask, vdata, info: StepInfo):
        (msg,), has = inbox["dist"]
        new = jnp.minimum(state["dist"], jnp.where(has, msg, INF))
        send = new < state["dist"]
        state = {"dist": new}
        return state, {"dist": new}, send, jnp.zeros_like(send)
