"""Incremental (accumulative) PageRank (paper §6.2, Algorithm 5, after [36]).

Each vertex accumulates delta updates into its rank; when the received delta
exceeds the tolerance Δ it propagates ``0.85 * delta / out_degree`` to its
neighbours (the edge weight is pre-set to ``1/out_degree(src)`` by the graph
builder helper below).  The fixed point of ``rank = 0.15 + 0.85 Σ rank/deg``
equals N × the normalized PageRank vector, which the tests check against
networkx.

Sum channel ⇒ the export buffer must *accumulate* deltas between exchanges
(``accumulate_export``) and reset to zero after each exchange
(``export_identity``) — the GraphHP SourceCombine() with an additive rule.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.vertex_program import Channel, StepInfo, VertexProgram


class IncrementalPageRank(VertexProgram):
    channels = (Channel("delta", "sum", ((jnp.float32, 0.0),),
                        semiring="add_mul"),)
    boundary_participates = True
    # the hybrid engine may run the whole local phase through the fused
    # `pr_step` Pallas kernel: sum channel, always-emitting, never
    # self-activating, strictly positive contributions (w > 0, delta > tol)
    fused_kernel = "pr_step"

    def __init__(self, tolerance: float = 1e-4, damping: float = 0.85):
        self.tol = float(tolerance)
        self.damping = float(damping)

    def init(self, gid, vmask, vdata):
        base = jnp.where(vmask, 0.15, 0.0).astype(jnp.float32)
        state = {"rank": base}
        out = {"delta": base}
        send = vmask
        return state, out, send, jnp.zeros_like(vmask)

    def emit(self, ch, out_src, w, src_gid, dst_gid):
        return (self.damping * out_src["delta"] * w,), jnp.ones(w.shape, bool)

    def ell_payload(self, ch, out, send):
        # message = (damping * delta)[src] * w; non-senders contribute 0
        return jnp.where(send, self.damping * out["delta"], 0.0)

    def apply(self, state, inbox, gid, vmask, vdata, info: StepInfo):
        (delta,), has = inbox["delta"]
        delta = jnp.where(has, delta, 0.0)
        rank = state["rank"] + delta
        send = delta > self.tol
        return {"rank": rank}, {"delta": delta}, send, jnp.zeros_like(send)

    # ---- additive SourceCombine ----------------------------------------
    def accumulate_export(self, acc_out, acc_send, new_out, new_send):
        acc = acc_out["delta"] + jnp.where(new_send, new_out["delta"], 0.0)
        return {"delta": acc}, jnp.logical_or(acc_send, new_send)

    def export_identity(self, out):
        return {"delta": jnp.zeros_like(out["delta"])}


def pagerank_edge_weights(edges, n_vertices):
    """1/out_degree(src) per edge — what Algorithm 5's send loop divides by."""
    import numpy as np
    deg = np.bincount(edges[:, 0], minlength=n_vertices).astype(np.float32)
    return 1.0 / np.maximum(deg[edges[:, 0]], 1.0)
