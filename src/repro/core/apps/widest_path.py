"""Single-source widest (maximum-capacity / bottleneck) paths.

The max-min twin of SSSP: the capacity of a path is the *minimum* capacity
of its edges, and every vertex keeps the *maximum* such bottleneck over all
paths from the source — the (max, min) semiring.  A vertex raises its
capacity and re-sends only when it improves; always votes to halt.
Monotone (max-combine), so boundary vertices participate in local phases
and the whole local phase fuses through the generalized `min_step` kernel
with ⊕ = max, ⊗ = min.

This is the network-capacity member of the paper's incremental family
(§6.1's SSSP argument applies verbatim with the order flipped): maximum
bandwidth routes, bottleneck throughput, percolation thresholds.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.vertex_program import Channel, StepInfo, VertexProgram

NINF = jnp.float32(-jnp.inf)


class WidestPath(VertexProgram):
    channels = (Channel("cap", "max", ((jnp.float32, -jnp.inf),),
                        semiring="max_min"),)
    boundary_participates = True
    # single max/max_min channel, out == state, adopt-if-better apply,
    # never self-activating, keep-latest export: the min_step contract
    fused_kernel = "min_step"

    def __init__(self, source: int):
        self.source = source

    def init(self, gid, vmask, vdata):
        is_src = gid == self.source
        cap = jnp.where(is_src, jnp.inf, NINF).astype(jnp.float32)
        state = {"cap": cap}
        out = {"cap": cap}
        send = jnp.logical_and(is_src, vmask)
        active = jnp.zeros_like(vmask)          # voteToHalt()
        return state, out, send, active

    def emit(self, ch, out_src, w, src_gid, dst_gid):
        # path capacity through this edge: bottleneck of sender and edge
        return (jnp.minimum(out_src["cap"], w),), jnp.ones(w.shape, bool)

    def ell_payload(self, ch, out, send):
        # message = min(cap[src], w); non-senders flatten to -inf (max id.)
        return jnp.where(send, out["cap"], NINF)

    def apply(self, state, inbox, gid, vmask, vdata, info: StepInfo):
        (msg,), has = inbox["cap"]
        new = jnp.maximum(state["cap"], jnp.where(has, msg, NINF))
        send = new > state["cap"]
        state = {"cap": new}
        return state, {"cap": new}, send, jnp.zeros_like(send)
