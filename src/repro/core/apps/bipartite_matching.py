"""Bipartite maximal matching (paper §6.3, Algorithm 6).

The representative of "algorithms that send and process *different types* of
messages at different stages" (§6.4).  Typed channels model the paper's
handshake:

  req    left -> right   match request; the ``lexmin`` combiner over a
                         per-edge hash realizes the right vertex's "randomly
                         choose one request" as a deterministic
                         random-priority pick,
  grant  right -> left   targeted grant (only the edge whose destination is
                         the granted left carries a message),
  acc    left -> right   targeted acceptance,
  full   right -> left   broadcast "I am matched": lefts count exhausted
                         neighbours and retire when all are matched,
  retry  right -> left   broadcast "my grant fell through, ask again".

Fidelity note (DESIGN.md §9): the paper's rights iterate over *all* received
requests and send per-requester deny messages.  A combining engine keeps only
the winning request, so losers cannot be denied individually; instead a right
broadcasts ``retry``/``full`` when its grant resolves, which re-activates the
losers.  The fixed point is the same (a valid maximal matching), the
iteration structure matches the paper's 3-stage handshake, and message counts
keep the same engine-to-engine ordering.

Right states: 0 = ungranted, 1 = granted (waiting for acceptance with a
countdown that ticks only at global/superstep cadence — local-phase accepts
arrive by message, so no local tick is needed), 2 = matched.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.vertex_program import Channel, StepInfo, VertexProgram

_IMAX = jnp.iinfo(jnp.int32).max

UNGRANTED, GRANTED, MATCHED = 0, 1, 2


def _hash2(a, b):
    x = a.astype(jnp.uint32) * jnp.uint32(2654435761)
    y = b.astype(jnp.uint32) * jnp.uint32(40503)
    h = jnp.bitwise_xor(x, y)
    h = h * jnp.uint32(2246822519)
    h = jnp.bitwise_xor(h, h >> 13)
    return (h & jnp.uint32(0x7FFFFFFF)).astype(jnp.int32)


class BipartiteMatching(VertexProgram):
    channels = (
        Channel("req", "lexmin", ((jnp.int32, _IMAX), (jnp.int32, _IMAX))),
        Channel("grant", "min", ((jnp.int32, _IMAX),)),
        Channel("acc", "min", ((jnp.int32, _IMAX),)),
        Channel("full", "sum", ((jnp.int32, 0),)),
        Channel("retry", "max", ((jnp.int32, 0),)),
    )
    boundary_participates = True

    def __init__(self, seed: int = 0):
        self.seed = seed

    def init(self, gid, vmask, vdata):
        is_left = vdata["is_left"]
        deg = vdata["degree"]
        state = {
            "matched": jnp.full_like(gid, -1),
            "rstate": jnp.zeros_like(gid),         # rights: UNGRANTED
            "grantee": jnp.full_like(gid, -1),     # rights: granted left gid
            "cd": jnp.zeros_like(gid),             # rights: acceptance countdown
            "n_full": jnp.zeros_like(gid),         # lefts: matched neighbours
        }
        out = {
            "requesting": jnp.logical_and(is_left, deg > 0),
            "grant_to": jnp.full_like(gid, -1),
            "accept_to": jnp.full_like(gid, -1),
            "announce_full": jnp.zeros_like(vmask),
            "announce_retry": jnp.zeros_like(vmask),
        }
        send = jnp.logical_and(out["requesting"], vmask)   # stage 1 at init
        active = jnp.zeros_like(vmask)
        return state, out, send, active

    def emit(self, ch, out_src, w, src_gid, dst_gid):
        if ch.name == "req":
            pri = _hash2(src_gid + self.seed, dst_gid)
            return (pri, src_gid), out_src["requesting"]
        if ch.name == "grant":
            return (src_gid,), dst_gid == out_src["grant_to"]
        if ch.name == "acc":
            return (src_gid,), dst_gid == out_src["accept_to"]
        if ch.name == "full":
            return (jnp.ones_like(src_gid),), out_src["announce_full"]
        if ch.name == "retry":
            return (jnp.ones_like(src_gid),), out_src["announce_retry"]
        raise ValueError(ch.name)

    def apply(self, state, inbox, gid, vmask, vdata, info: StepInfo):
        is_left = vdata["is_left"]
        deg = vdata["degree"]
        (_, req_gid), has_req = inbox["req"]
        (grant_gid,), has_grant = inbox["grant"]
        (acc_gid,), has_acc = inbox["acc"]
        (full_cnt,), has_full = inbox["full"]

        matched = state["matched"]
        rstate = state["rstate"]
        grantee = state["grantee"]
        cd = state["cd"]
        n_full = state["n_full"] + jnp.where(has_full, full_cnt, 0)

        # ---------------- left vertices (stages 1 & 3) -------------------
        l_unmatched = jnp.logical_and(is_left, matched < 0)
        l_accepts = jnp.logical_and(l_unmatched, has_grant)
        l_retired = jnp.logical_and(l_unmatched, n_full >= deg)
        l_requesting = jnp.logical_and(
            l_unmatched, jnp.logical_and(~l_accepts, ~l_retired))

        # ---------------- right vertices (stages 2 & 4) ------------------
        r = jnp.logical_not(is_left)
        r_ungranted = jnp.logical_and(r, rstate == UNGRANTED)
        r_grants = jnp.logical_and(r_ungranted, has_req)
        r_granted = jnp.logical_and(r, rstate == GRANTED)
        r_accepted = jnp.logical_and(
            r_granted, jnp.logical_and(has_acc, acc_gid == grantee))
        # countdown ticks at global/superstep cadence only: a same-partition
        # acceptance arrives by message within two pseudo-supersteps, a
        # cross-partition one within two global iterations (< the timeout).
        tick = info.phase != "local"
        r_timeout = jnp.logical_and(
            r_granted, jnp.logical_and(~r_accepted,
                                       jnp.logical_and(tick, cd <= 0)))

        new_matched = jnp.where(l_accepts, grant_gid, matched)
        new_matched = jnp.where(r_accepted, acc_gid, new_matched)
        new_rstate = jnp.where(r_grants, GRANTED, rstate)
        new_rstate = jnp.where(r_accepted, MATCHED, new_rstate)
        new_rstate = jnp.where(r_timeout, UNGRANTED, new_rstate)
        new_grantee = jnp.where(r_grants, req_gid, grantee)
        new_cd = jnp.where(r_grants, 3,
                           jnp.where(tick, jnp.maximum(cd - 1, 0), cd))

        out = {
            "requesting": l_requesting,
            "grant_to": jnp.where(r_grants, req_gid, -1),
            "accept_to": jnp.where(l_accepts, grant_gid, -1),
            "announce_full": r_accepted,
            "announce_retry": r_timeout,
        }
        send = (l_requesting | l_accepts | r_grants | r_accepted | r_timeout)
        # granted rights must observe their own timeout even with no incoming
        # message — they stay active, but only for global-cadence scheduling
        # (global_only_active below keeps local phases terminating).
        active = jnp.logical_and(jnp.logical_and(r, new_rstate == GRANTED), vmask)

        state = {"matched": new_matched, "rstate": new_rstate,
                 "grantee": new_grantee, "cd": new_cd, "n_full": n_full}
        return state, out, send, active

    def global_only_active(self, state, vdata):
        """Granted rights wait for remote acceptances/timeouts: they are
        scheduled at global phases, not kept spinning in local phases."""
        return jnp.logical_and(jnp.logical_not(vdata["is_left"]),
                               state["rstate"] == GRANTED)
