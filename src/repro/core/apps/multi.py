"""K-lane multi-query programs: one engine run answers K independent queries.

GraphHP amortizes synchronization across pseudo-supersteps *within* a
partition; these programs amortize graph traversal across *queries*.  Vertex
state carries a trailing lane axis of width L (``Channel(lanes=L)``), every
message is an (..., L) stack, and delivery rides the semiring SpMM kernels —
one Pallas dispatch per degree bin answers all L sources.

Two families:

  * :class:`MultiSourceMonotone` — the monotone relax/adopt family over any
    ``MONOTONE_SEMIRINGS`` entry: multi-source SSSP and landmark distance
    tables (min_add), batched reachability (min_add; a vertex is reachable
    from lane j iff its lane-j distance is finite — see :func:`reachable`),
    K-lane widest/bottleneck paths (max_min), odds/log-likelihood walks
    (min_mul / max_add).
  * :class:`PersonalizedPageRank` — per-seed personalized PageRank: lane j
    runs incremental PageRank with all teleport mass at seed j.

Lane-axis contracts (what makes K-lane bit-identical to K single runs):

  * Send flags stay *per-vertex* (any lane): the engines' scheduling,
    has-message flags and counters are lane-oblivious, so a K-lane message
    counts once.  Per-lane gating happens in the *values*.
  * Monotone programs export full per-lane state (keep-latest, like SSSP):
    re-delivering an already-known lane value is a ⊕-no-op, so vertex-level
    send gating cannot corrupt a lane.
  * Accumulative (sum) programs pre-neutralize ``out`` per lane
    (``where(lane_send, delta, 0)``): a zero delta re-delivered adds
    nothing, so additive export accumulation stays per-lane exact.

Sources/seeds may be passed to the constructor (static) or per-run through
``vdata={"sources": (L,) int32}`` — the serving layer uses the latter so one
compiled (program, K) executable serves every source set.
"""

from __future__ import annotations

import hashlib

import numpy as np

import jax.numpy as jnp

from repro.core.vertex_program import Channel, StepInfo, VertexProgram
from repro.kernels.common import MONOTONE_SEMIRINGS, SEMIRINGS, \
    semiring_improves

__all__ = ["MultiSourceMonotone", "PersonalizedPageRank", "reachable",
           "sources_digest"]


def sources_digest(sources) -> str:
    """Content digest of a (K,) source/seed vector — the lane-batch half
    of the ``(program, K, sources)`` checkpoint key.  Order-sensitive on
    purpose: lane j of a checkpoint is only valid for lane j's source."""
    a = np.ascontiguousarray(np.asarray(sources, dtype=np.int64))
    return hashlib.sha256(a.tobytes()).hexdigest()[:16]

# natural "the path starts here" value per monotone semiring: the ⊗-identity
# (so the first edge's message is just the edge value), except max_min whose
# source must not cap any path (+inf bottleneck).
_SOURCE_VALUE = {"min_add": 0.0, "max_add": 0.0, "min_mul": 1.0,
                 "max_min": jnp.inf}


def _lane_mask(send, v):
    """Broadcast a per-vertex send mask against per-lane values."""
    return send.reshape(send.shape + (1,) * (v.ndim - send.ndim))


class MultiSourceMonotone(VertexProgram):
    """K-lane monotone propagation: lane j solves the single-source problem
    from ``sources[j]`` under ``semiring`` — SSSP (min_add), widest path
    (max_min), odds (min_mul), best score (max_add).

    State/out hold a (P, Vp, L) value table; lane j of the result is
    bit-identical to a single-source run from ``sources[j]``.
    """

    boundary_participates = True
    # single monotone channel, out == state, adopt-if-better apply, never
    # self-activating, keep-latest export: the (lane-general) min_step
    # contract — the hybrid engine fuses the whole local phase
    fused_kernel = "min_step"

    def __init__(self, sources=None, *, lanes: int | None = None,
                 semiring: str = "min_add", source_value=None):
        if semiring not in MONOTONE_SEMIRINGS:
            raise ValueError(f"{semiring!r} is not a monotone semiring")
        if lanes is None:
            if sources is None:
                raise ValueError("need sources or lanes")
            lanes = len(sources)
        self.sources = sources
        self.lanes = int(lanes)
        self.semiring = semiring
        self.source_value = (_SOURCE_VALUE[semiring] if source_value is None
                             else source_value)
        combiner = "min" if semiring.startswith("min") else "max"
        _, _, ident = SEMIRINGS[semiring]
        self.ident = jnp.float32(ident)
        self.channels = (Channel("val", combiner, ((jnp.float32, ident),),
                                 semiring=semiring, lanes=self.lanes),)

    def _sources(self, vdata):
        if vdata is not None and "sources" in vdata:
            return jnp.asarray(vdata["sources"], jnp.int32)
        return jnp.asarray(self.sources, jnp.int32)

    def init(self, gid, vmask, vdata):
        src = self._sources(vdata)                   # (L,)
        is_src = gid[..., None] == src               # (P, Vp, L)
        val = jnp.where(is_src, jnp.float32(self.source_value),
                        self.ident).astype(jnp.float32)
        send = jnp.logical_and(jnp.any(is_src, axis=-1), vmask)
        active = jnp.zeros_like(vmask)               # voteToHalt()
        return {"val": val}, {"val": val}, send, active

    def emit(self, ch, out_src, w, src_gid, dst_gid):
        _, times, _ = SEMIRINGS[self.semiring]
        return (times(out_src["val"], w[..., None]),), jnp.ones(w.shape, bool)

    def ell_payload(self, ch, out, send):
        # message = val[src] ⊗ w per lane; non-senders flatten to the ⊕
        # identity.  Sending vertices expose their full lane state (see the
        # module contract: re-delivering a known value is a ⊕-no-op).
        v = out["val"]
        return jnp.where(_lane_mask(send, v), v, self.ident)

    def apply(self, state, inbox, gid, vmask, vdata, info: StepInfo):
        combine, _, _ = SEMIRINGS[self.semiring]
        improves = semiring_improves(self.semiring)
        (msg,), has = inbox["val"]
        msg = jnp.where(_lane_mask(has, msg), msg, self.ident)
        new = combine(state["val"], msg)
        send = jnp.any(improves(new, state["val"]), axis=-1)
        return {"val": new}, {"val": new}, send, jnp.zeros_like(send)


class PersonalizedPageRank(VertexProgram):
    """Per-seed personalized PageRank, K lanes at once.

    Lane j runs the incremental-PageRank recurrence with all teleport mass
    at seed j: ``rank_j = (1-d)·e_seed_j + d·AᵀD⁻¹ rank_j`` (unnormalized,
    like :class:`~repro.core.apps.pagerank.IncrementalPageRank`; use
    ``pagerank_edge_weights`` for the 1/out_degree edge weights).  Lane j of
    the result is bit-identical to a single-seed run.
    """

    boundary_participates = True
    fused_kernel = "pr_step"

    def __init__(self, seeds=None, *, lanes: int | None = None,
                 tolerance: float = 1e-4, damping: float = 0.85):
        if lanes is None:
            if seeds is None:
                raise ValueError("need seeds or lanes")
            lanes = len(seeds)
        self.seeds = seeds
        self.lanes = int(lanes)
        self.tol = float(tolerance)
        self.damping = float(damping)
        self.channels = (Channel("delta", "sum", ((jnp.float32, 0.0),),
                                 semiring="add_mul", lanes=self.lanes),)

    def _seeds(self, vdata):
        if vdata is not None and "sources" in vdata:
            return jnp.asarray(vdata["sources"], jnp.int32)
        return jnp.asarray(self.seeds, jnp.int32)

    def init(self, gid, vmask, vdata):
        is_seed = gid[..., None] == self._seeds(vdata)    # (P, Vp, L)
        base = jnp.where(is_seed, 1.0 - self.damping, 0.0).astype(jnp.float32)
        send = jnp.logical_and(jnp.any(is_seed, axis=-1), vmask)
        return {"rank": base}, {"delta": base}, send, jnp.zeros_like(send)

    def emit(self, ch, out_src, w, src_gid, dst_gid):
        return ((self.damping * out_src["delta"] * w[..., None],),
                jnp.ones(w.shape, bool))

    def ell_payload(self, ch, out, send):
        # out["delta"] is pre-neutralized per lane (zero where the lane did
        # not send), so vertex-level gating completes the (+)-annihilation
        v = out["delta"]
        return jnp.where(_lane_mask(send, v), self.damping * v, 0.0)

    def apply(self, state, inbox, gid, vmask, vdata, info: StepInfo):
        (delta,), has = inbox["delta"]
        delta = jnp.where(_lane_mask(has, delta), delta, 0.0)
        rank = state["rank"] + delta
        lane_send = delta > self.tol
        # pre-neutralized out: only improving lanes re-propagate (a zero
        # delta adds nothing if a vertex-level send re-delivers it)
        out = jnp.where(lane_send, delta, 0.0)
        send = jnp.any(lane_send, axis=-1)
        return {"rank": rank}, {"delta": out}, send, jnp.zeros_like(send)

    # ---- additive SourceCombine (per-lane exact: out is pre-neutralized)
    def accumulate_export(self, acc_out, acc_send, new_out, new_send):
        acc = acc_out["delta"] + jnp.where(_lane_mask(new_send,
                                                      new_out["delta"]),
                                           new_out["delta"], 0.0)
        return {"delta": acc}, jnp.logical_or(acc_send, new_send)

    def export_identity(self, out):
        return {"delta": jnp.zeros_like(out["delta"])}


def reachable(dist_lanes) -> jnp.ndarray:
    """Reachability view of a min_add :class:`MultiSourceMonotone` result:
    vertex v is reachable from lane j's source iff its distance is finite."""
    return jnp.isfinite(dist_lanes)
