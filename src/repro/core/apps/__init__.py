from repro.core.apps.sssp import SSSP
from repro.core.apps.pagerank import IncrementalPageRank
from repro.core.apps.wcc import WCC
from repro.core.apps.bipartite_matching import BipartiteMatching
from repro.core.apps.widest_path import WidestPath
from repro.core.apps.random_walk import RandomWalk
from repro.core.apps.multi import (MultiSourceMonotone, PersonalizedPageRank,
                                   reachable)

__all__ = ["SSSP", "IncrementalPageRank", "WCC", "BipartiteMatching",
           "WidestPath", "RandomWalk", "MultiSourceMonotone",
           "PersonalizedPageRank", "reachable"]
