from repro.core.apps.sssp import SSSP
from repro.core.apps.pagerank import IncrementalPageRank
from repro.core.apps.wcc import WCC
from repro.core.apps.bipartite_matching import BipartiteMatching

__all__ = ["SSSP", "IncrementalPageRank", "WCC", "BipartiteMatching"]
