"""Partitioned graph representation for the GraphHP hybrid execution model.

The paper's runtime keeps, per worker, adjacency lists plus per-vertex message
queues and distinguishes *local* vertices (all in-edges originate in the same
partition) from *boundary* vertices (at least one remote in-edge).  The TPU
realization keeps the same logical structure as padded, partition-major dense
arrays so that one `shard_map` device owns one block of partitions:

  * vertices   -> slots [0, Vp) per partition (padded, masked),
  * in-edges   -> flat per-partition edge arrays sorted by destination slot,
  * the cut    -> a static halo-exchange plan: each partition exports the
                  out-state of its "exporter" vertices (vertices with at least
                  one out-edge crossing the cut); remote in-edges reference
                  gathered halo slots instead of local slots.

Everything is computed once on the host in numpy; the resulting pytree is what
the engines (standard BSP / AM-Hama / GraphHP hybrid) iterate on-device.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp

# the partitioners grew into their own subsystem (repro.partition: hash/bfs
# plus fennel streaming and multilevel); re-exported here for compatibility
from repro.partition import bfs_partition, hash_partition

__all__ = [
    "EllSlice",
    "PartitionedGraph",
    "build_partitioned_graph",
    "hash_partition",
    "bfs_partition",
    "unpack_vertex",
]


def unpack_vertex(graph: "PartitionedGraph", values) -> np.ndarray:
    """Scatter a per-slot (P, Vp) array back to global vertex-id order —
    the inverse of the builder's slot assignment (padding slots dropped)."""
    gid = np.asarray(graph.vertex_gid).ravel()
    val = np.asarray(values).ravel()
    out = np.zeros(graph.n_vertices, dtype=val.dtype)
    out[gid[gid >= 0]] = val[gid >= 0]
    return out


def _pad_to(x: np.ndarray, n: int, fill) -> np.ndarray:
    out = np.full((n,) + x.shape[1:], fill, dtype=x.dtype)
    out[: x.shape[0]] = x
    return out


def _round_up(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m if n > 0 else m


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class EllSlice:
    """One degree bin of a sliced-ELL edge layout (partition-major).

    Row binning keeps power-law graphs on the kernel fast path: bin 0 holds
    slots [0, K0) of every row (dense — row r is destination slot r), spill
    bins hold the overflow slots of high-degree rows only, indirected
    through ``rows``.  A delivery is the ⊕-combination of one `ell_spmv`
    call per bin.

    The ``flat_*`` views are the single-device fast path, precomputed at
    build time: row ids offset by p*Vp (sentinel P*Vp on padding, dropped by
    ``mode='drop'`` scatters) and source ids offset by p*stride so one
    kernel call covers every partition.  Inside a shard_map block the
    per-partition arrays are re-offset locally instead (see
    ``runtime.slice_flat``).
    """

    rows: jax.Array       # (P, Nb) int32 — destination slot, Vp sentinel pad
    idx: jax.Array        # (P, Nb, Kb) int32 — source slot, or Vp + halo slot
    val: jax.Array        # (P, Nb, Kb) float32 — edge weight
    msk: jax.Array        # (P, Nb, Kb) bool — slot occupancy
    # per-slot message-accounting group id (the (destination, source
    # partition) Combine() granularity of `PartitionedGraph.edge_group`),
    # 0 on padding — lets `collect_metrics=True` counters ride the tiles
    # instead of re-reducing the dense edge arrays
    grp: jax.Array        # (P, Nb, Kb) int32
    flat_rows: jax.Array  # (P*Nb,) int32 — p*Vp + row, P*Vp sentinel
    flat_idx: jax.Array   # (P*Nb, Kb) int32 — idx + p*stride
    nb: int = dataclasses.field(metadata=dict(static=True))
    kb: int = dataclasses.field(metadata=dict(static=True))
    lo: int = dataclasses.field(metadata=dict(static=True))   # first edge slot
    dense: bool = dataclasses.field(metadata=dict(static=True))
    stride: int = dataclasses.field(metadata=dict(static=True))  # frontier row pitch
    # max source *global id* feeding this slice — the per-bin bound deciding
    # whether integer payloads survive the kernel's float32 carriage exactly
    payload_bound: int = dataclasses.field(metadata=dict(static=True))


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PartitionedGraph:
    """Static, padded, partition-major graph structure (a pytree of arrays).

    Shapes use P = #partitions, Vp = max vertices/partition, Ep = max
    in-edges/partition, X = max exports/partition, H = max halo entries.
    """

    # ---- vertices -------------------------------------------------------
    vertex_gid: jax.Array       # (P, Vp) int32, -1 on padding
    vertex_mask: jax.Array      # (P, Vp) bool
    is_boundary: jax.Array      # (P, Vp) bool — has a remote in-edge
    out_degree: jax.Array       # (P, Vp) int32 — global out-degree
    # ---- in-edges, sorted by destination slot ---------------------------
    edge_src: jax.Array         # (P, Ep) int32 — local slot, or Vp + halo slot
    edge_dst: jax.Array         # (P, Ep) int32 — destination local slot
    edge_w: jax.Array           # (P, Ep) float32
    edge_mask: jax.Array        # (P, Ep) bool
    edge_local: jax.Array       # (P, Ep) bool — source in same partition
    edge_src_gid: jax.Array     # (P, Ep) int32 — global id of source
    edge_dst_gid: jax.Array     # (P, Ep) int32 — global id of destination
    # message-accounting groups: one group per (destination vertex, source
    # partition) pair — the granularity at which Pregel's Combine() merges
    # traffic.  Group ids are partition-local and dense in [0, Gp).
    edge_group: jax.Array       # (P, Ep) int32
    group_remote: jax.Array     # (P, Gp) bool — group's source partition != p
    group_mask: jax.Array       # (P, Gp) bool
    # ---- halo-exchange plan ---------------------------------------------
    export_slot: jax.Array      # (P, X) int32 — local slots exported
    export_mask: jax.Array      # (P, X) bool
    export_fanout: jax.Array    # (P, X) int32 — #remote partitions consuming
    halo_ptr: jax.Array         # (P, H) int32 — flat index q*X + x into exports
    halo_mask: jax.Array        # (P, H) bool
    # ---- sliced-ELL edge layouts (destination-major degree bins) --------
    # The kernel fast paths: ``local_ell`` packs each partition's
    # same-partition in-edges (sources are local slots, frontier stride Vp),
    # ``remote_ell`` packs its remote in-edges (sources are Vp + halo slot,
    # frontier stride Vp + H — the concat(out, halo_out) table).  Empty
    # tuples when the layout was not built.
    local_ell: tuple[EllSlice, ...]
    remote_ell: tuple[EllSlice, ...]
    # ---- static metadata (not traced) -----------------------------------
    n_partitions: int = dataclasses.field(metadata=dict(static=True))
    n_vertices: int = dataclasses.field(metadata=dict(static=True))
    n_edges: int = dataclasses.field(metadata=dict(static=True))
    vp: int = dataclasses.field(metadata=dict(static=True))
    ep: int = dataclasses.field(metadata=dict(static=True))
    xp: int = dataclasses.field(metadata=dict(static=True))
    hp: int = dataclasses.field(metadata=dict(static=True))
    gp: int = dataclasses.field(metadata=dict(static=True))

    @property
    def has_ell(self) -> bool:
        """Whether the local-edge ELL layout is available for kernel-backed
        delivery."""
        return len(self.local_ell) > 0

    @property
    def has_remote_ell(self) -> bool:
        return len(self.remote_ell) > 0

    @property
    def kl(self) -> int:
        """Base-bin slice width of the local layout (0 when not built)."""
        return self.local_ell[0].kb if self.local_ell else 0

    # ------------------------------------------------------------------
    @property
    def shape_summary(self) -> str:
        return (
            f"P={self.n_partitions} V={self.n_vertices} E={self.n_edges} "
            f"Vp={self.vp} Ep={self.ep} X={self.xp} H={self.hp}"
        )


def build_partitioned_graph(
    edges: np.ndarray,
    n_vertices: int,
    part: np.ndarray | str,
    weights: np.ndarray | None = None,
    pad_multiple: int = 8,
    build_ell: bool = True,
    ell_pad_slices: int = 8,
    ell_base_slices: int = 128,
    n_partitions: int | None = None,
    partition_seed: int = 0,
) -> PartitionedGraph:
    """Construct the padded partition-major structure from a global edge list.

    ``edges`` is (E, 2) int [src, dst]; ``part`` maps vertex -> partition id
    — either a precomputed (V,) labeling, or a partitioner name from
    ``repro.partition.PARTITIONERS`` ('hash' | 'bfs' | 'fennel' |
    'multilevel'), in which case ``n_partitions`` (and optionally
    ``partition_seed``) choose how the labeling is computed.

    ``build_ell`` additionally packs each partition's local *and* remote
    in-edges into destination-major sliced-ELL layouts (the kernel fast
    paths for both delivery phases).  ``ell_pad_slices`` pads the slice axis
    (use 128 when targeting TPU lanes; 8 keeps CPU/interpret memory small).
    ``ell_base_slices`` bounds the dense base bin: rows whose in-degree
    exceeds it spill into up to two extra degree bins (see
    ``kernels.common.ell_bin_widths``), so power-law skew widens only the
    tiny spill bins instead of padding every row to the hub degree.
    """
    edges = np.asarray(edges, dtype=np.int64)
    if isinstance(part, str):
        if n_partitions is None:
            raise ValueError("partitioner-by-name needs n_partitions")
        from repro.partition import make_partition
        part = make_partition(part, edges, n_vertices, n_partitions,
                              seed=partition_seed)
    part = np.asarray(part, dtype=np.int32)
    n_edges = edges.shape[0]
    if weights is None:
        weights = np.ones(n_edges, dtype=np.float32)
    weights = np.asarray(weights, dtype=np.float32)
    P = int(part.max()) + 1 if part.size else 1

    src, dst = edges[:, 0], edges[:, 1]
    psrc, pdst = part[src], part[dst]

    out_degree = np.bincount(src, minlength=n_vertices).astype(np.int32)

    # --- vertex slots per partition --------------------------------------
    order_v = np.argsort(part, kind="stable")
    verts_by_p: list[np.ndarray] = []
    slot_of = np.zeros(n_vertices, dtype=np.int64)
    counts = np.bincount(part, minlength=P)
    off = 0
    for p in range(P):
        vs = order_v[off:off + counts[p]]
        off += counts[p]
        verts_by_p.append(vs)
        slot_of[vs] = np.arange(len(vs))
    Vp = _round_up(int(counts.max()) if counts.size else 1, pad_multiple)

    # --- boundary classification -----------------------------------------
    is_boundary_g = np.zeros(n_vertices, dtype=bool)
    cross = psrc != pdst
    is_boundary_g[dst[cross]] = True

    # --- exporters: vertices with >= 1 crossing out-edge ------------------
    # fanout = number of *distinct* remote partitions consuming the export
    exp_pairs = np.unique(
        np.stack([src[cross], pdst[cross].astype(np.int64)], axis=1), axis=0
    )
    exporters_by_p: list[np.ndarray] = []
    fanout_by_p: list[np.ndarray] = []
    export_idx_of = np.full(n_vertices, -1, dtype=np.int64)  # slot in own export buf
    for p in range(P):
        rows = exp_pairs[part[exp_pairs[:, 0]] == p]
        vs, fan = (np.unique(rows[:, 0], return_counts=True)
                   if rows.size else (np.zeros(0, np.int64), np.zeros(0, np.int64)))
        exporters_by_p.append(vs)
        fanout_by_p.append(fan)
        export_idx_of[vs] = np.arange(len(vs))
    X = _round_up(max((len(v) for v in exporters_by_p), default=1), pad_multiple)

    # --- halo: remote sources needed per partition ------------------------
    halo_by_p: list[np.ndarray] = []      # global vertex ids (unique) needed
    halo_slot_of: list[dict[int, int]] = []
    for p in range(P):
        need = np.unique(src[cross & (pdst == p)])
        halo_by_p.append(need)
        halo_slot_of.append({int(v): i for i, v in enumerate(need)})
    H = _round_up(max((len(h) for h in halo_by_p), default=1), pad_multiple)

    # --- per-partition in-edge arrays sorted by destination slot ----------
    Ep = 0
    per_p: list[dict[str, np.ndarray]] = []
    for p in range(P):
        sel = pdst == p
        es, ed, ew = src[sel], dst[sel], weights[sel]
        eps = psrc[sel]
        d_slot = slot_of[ed]
        # encode source: local slot, or Vp + halo slot
        s_enc = np.where(
            eps == p,
            slot_of[es],
            Vp + np.array([halo_slot_of[p].get(int(v), 0) for v in es],
                          dtype=np.int64),
        )
        order_e = np.argsort(d_slot, kind="stable")
        es, ed, ew, eps = es[order_e], ed[order_e], ew[order_e], eps[order_e]
        d_slot, s_enc = d_slot[order_e], s_enc[order_e]
        # (dst vertex, src partition) combine groups, dense ids
        gkey = d_slot * P + eps
        _, ginv = np.unique(gkey, return_inverse=True)
        gremote = np.zeros(int(ginv.max()) + 1 if ginv.size else 1, dtype=bool)
        np.maximum.at(gremote, ginv, eps != p)
        per_p.append(dict(src_enc=s_enc, dst_slot=d_slot, w=ew,
                          local=(eps == p), src_gid=es, dst_gid=ed,
                          group=ginv, group_remote=gremote))
        Ep = max(Ep, len(es))
    Ep = _round_up(Ep, pad_multiple)
    Gp = _round_up(max((len(d["group_remote"]) for d in per_p), default=1),
                   pad_multiple)

    # --- assemble padded arrays -------------------------------------------
    def stack(fn, shape, dtype, fill):
        out = np.full((P,) + shape, fill, dtype=dtype)
        for p in range(P):
            v = fn(p)
            out[p, : len(v)] = v
        return out

    vertex_gid = stack(lambda p: verts_by_p[p].astype(np.int32), (Vp,), np.int32, -1)
    vertex_mask = vertex_gid >= 0
    is_boundary = stack(lambda p: is_boundary_g[verts_by_p[p]], (Vp,), bool, False)
    out_deg = stack(lambda p: out_degree[verts_by_p[p]], (Vp,), np.int32, 0)

    edge_src = stack(lambda p: per_p[p]["src_enc"].astype(np.int32), (Ep,), np.int32, 0)
    edge_dst = stack(lambda p: per_p[p]["dst_slot"].astype(np.int32), (Ep,), np.int32, 0)
    edge_w = stack(lambda p: per_p[p]["w"], (Ep,), np.float32, 0.0)
    edge_mask = stack(lambda p: np.ones(len(per_p[p]["w"]), bool), (Ep,), bool, False)
    edge_local = stack(lambda p: per_p[p]["local"], (Ep,), bool, False)
    edge_src_gid = stack(lambda p: per_p[p]["src_gid"].astype(np.int32), (Ep,), np.int32, -1)
    edge_dst_gid = stack(lambda p: per_p[p]["dst_gid"].astype(np.int32), (Ep,), np.int32, -1)
    edge_group = stack(lambda p: per_p[p]["group"].astype(np.int32), (Ep,), np.int32, 0)
    group_remote = stack(lambda p: per_p[p]["group_remote"], (Gp,), bool, False)
    group_mask = stack(lambda p: np.ones(len(per_p[p]["group_remote"]), bool), (Gp,), bool, False)

    export_slot = stack(lambda p: slot_of[exporters_by_p[p]].astype(np.int32), (X,), np.int32, 0)
    export_mask = stack(lambda p: np.ones(len(exporters_by_p[p]), bool), (X,), bool, False)
    export_fanout = stack(lambda p: fanout_by_p[p].astype(np.int32), (X,), np.int32, 0)

    def halo_ptrs(p: int) -> np.ndarray:
        vs = halo_by_p[p]
        qs = part[vs].astype(np.int64)
        xs = export_idx_of[vs]
        assert (xs >= 0).all(), "halo source must be an exporter"
        return (qs * X + xs).astype(np.int32)

    halo_ptr = stack(halo_ptrs, (H,), np.int32, 0)
    halo_mask = stack(lambda p: np.ones(len(halo_by_p[p]), bool), (H,), bool, False)

    # --- sliced-ELL in-edge layouts (destination-major fast paths) --------
    local_ell: tuple[EllSlice, ...] = ()
    remote_ell: tuple[EllSlice, ...] = ()
    if build_ell:
        local_ell = _build_ell_slices(
            per_p, sel_key="local", negate=False, P=P, Vp=Vp, stride=Vp,
            pad=pad_multiple, slice_pad=ell_pad_slices,
            base_slices=ell_base_slices)
        remote_ell = _build_ell_slices(
            per_p, sel_key="local", negate=True, P=P, Vp=Vp, stride=Vp + H,
            pad=pad_multiple, slice_pad=ell_pad_slices,
            base_slices=ell_base_slices)

    return PartitionedGraph(
        vertex_gid=jnp.asarray(vertex_gid), vertex_mask=jnp.asarray(vertex_mask),
        is_boundary=jnp.asarray(is_boundary), out_degree=jnp.asarray(out_deg),
        edge_src=jnp.asarray(edge_src), edge_dst=jnp.asarray(edge_dst),
        edge_w=jnp.asarray(edge_w), edge_mask=jnp.asarray(edge_mask),
        edge_local=jnp.asarray(edge_local),
        edge_src_gid=jnp.asarray(edge_src_gid), edge_dst_gid=jnp.asarray(edge_dst_gid),
        edge_group=jnp.asarray(edge_group), group_remote=jnp.asarray(group_remote),
        group_mask=jnp.asarray(group_mask),
        export_slot=jnp.asarray(export_slot), export_mask=jnp.asarray(export_mask),
        export_fanout=jnp.asarray(export_fanout),
        halo_ptr=jnp.asarray(halo_ptr), halo_mask=jnp.asarray(halo_mask),
        local_ell=local_ell, remote_ell=remote_ell,
        n_partitions=P, n_vertices=int(n_vertices), n_edges=int(n_edges),
        vp=int(Vp), ep=int(Ep), xp=int(X), hp=int(H), gp=int(Gp),
    )


def _build_ell_slices(per_p, sel_key: str, negate: bool, P: int, Vp: int,
                      stride: int, pad: int, slice_pad: int,
                      base_slices: int) -> tuple[EllSlice, ...]:
    """Pack one side (local or remote) of every partition's in-edges into
    shared-width sliced-ELL degree bins, flat views precomputed."""
    from repro.kernels.common import ell_bin_widths, sliced_ell_pack_numpy

    picks = []
    kmax = 0
    for p in range(P):
        sel = per_p[p][sel_key]
        if negate:
            sel = np.logical_not(sel)
        e = dict(src=per_p[p]["src_enc"][sel], dst=per_p[p]["dst_slot"][sel],
                 w=per_p[p]["w"][sel], gid=per_p[p]["src_gid"][sel],
                 grp=per_p[p]["group"][sel])
        if len(e["dst"]):
            kmax = max(kmax, int(np.bincount(e["dst"], minlength=Vp).max()))
        # per-edge rank within its destination run — computed once, handed
        # to the packer and shared by every bin's source-gid bound below
        order = np.argsort(e["dst"], kind="stable")
        dst_s = e["dst"][order]
        e["order"] = order
        e["gid_ranked"] = e["gid"][order]
        e["rank"] = (np.arange(len(dst_s))
                     - np.searchsorted(dst_s, dst_s, side="left"))
        picks.append(e)
    widths = ell_bin_widths(kmax, base_slices, slice_pad)
    if not widths:
        return ()

    packs = [sliced_ell_pack_numpy(e["src"], e["dst"], e["w"], Vp, widths,
                                   order_rank=(e["order"], e["rank"]),
                                   extras=(e["grp"],))
             for e in picks]
    slices = []
    for b, (lo, kb) in enumerate(widths):
        dense = lo == 0
        if dense:
            Nb = Vp
        else:
            Nb = _round_up(max(len(packs[p][b][0]) for p in range(P)), pad)
        rows = np.full((P, Nb), Vp, dtype=np.int32)
        idx = np.zeros((P, Nb, kb), dtype=np.int32)
        val = np.zeros((P, Nb, kb), dtype=np.float32)
        msk = np.zeros((P, Nb, kb), dtype=bool)
        grp = np.zeros((P, Nb, kb), dtype=np.int32)
        flat_rows = np.full((P, Nb), P * Vp, dtype=np.int32)
        bound = -1
        for p in range(P):
            rows_b, idx_b, val_b, msk_b, grp_b = packs[p][b]
            if rows_b is None:                      # dense base bin
                rows[p] = np.arange(Vp, dtype=np.int32)
            else:
                rows[p, : len(rows_b)] = rows_b
            n = idx_b.shape[0]
            idx[p, :n], val[p, :n], msk[p, :n] = idx_b, val_b, msk_b
            grp[p, :n] = grp_b
            flat_rows[p] = np.where(rows[p] < Vp, p * Vp + rows[p], P * Vp)
            bound = max(bound, _bin_src_bound(picks[p], lo, kb))
        flat_idx = idx + (np.arange(P, dtype=np.int32) * stride)[:, None, None]
        slices.append(EllSlice(
            rows=jnp.asarray(rows), idx=jnp.asarray(idx),
            val=jnp.asarray(val), msk=jnp.asarray(msk),
            grp=jnp.asarray(grp),
            flat_rows=jnp.asarray(flat_rows.reshape(-1)),
            flat_idx=jnp.asarray(flat_idx.reshape(P * Nb, kb)),
            nb=int(Nb), kb=int(kb), lo=int(lo), dense=bool(dense),
            stride=int(stride), payload_bound=int(bound)))
    return tuple(slices)


def _bin_src_bound(e: dict, lo: int, kb: int) -> int:
    """Max source gid among the edges landing in bin [lo, lo+kb), via the
    precomputed dst-ranking (mirrors ``sliced_ell_pack_numpy``)."""
    rank = e["rank"]
    if not len(rank):
        return -1
    sel = (rank >= lo) & (rank < lo + kb)
    return int(e["gid_ranked"][sel].max()) if sel.any() else -1
