"""Partitioned graph representation for the GraphHP hybrid execution model.

The paper's runtime keeps, per worker, adjacency lists plus per-vertex message
queues and distinguishes *local* vertices (all in-edges originate in the same
partition) from *boundary* vertices (at least one remote in-edge).  The TPU
realization keeps the same logical structure as padded, partition-major dense
arrays so that one `shard_map` device owns one block of partitions:

  * vertices   -> slots [0, Vp) per partition (padded, masked),
  * in-edges   -> flat per-partition edge arrays sorted by destination slot,
  * the cut    -> a static halo-exchange plan: each partition exports the
                  out-state of its "exporter" vertices (vertices with at least
                  one out-edge crossing the cut); remote in-edges reference
                  gathered halo slots instead of local slots.

Everything is computed once on the host in numpy; the resulting pytree is what
the engines (standard BSP / AM-Hama / GraphHP hybrid) iterate on-device.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp

# the partitioners grew into their own subsystem (repro.partition: hash/bfs
# plus fennel streaming and multilevel); re-exported here for compatibility
from repro.partition import bfs_partition, hash_partition

__all__ = [
    "EllSlice",
    "PartitionedGraph",
    "build_partitioned_graph",
    "hash_partition",
    "bfs_partition",
    "unpack_vertex",
]


def unpack_vertex(graph: "PartitionedGraph", values) -> np.ndarray:
    """Scatter a per-slot (P, Vp, ...) array back to global vertex-id order —
    the inverse of the builder's slot assignment (padding slots dropped).
    Trailing axes (e.g. the K-lane axis of a multi-query run) are kept, so a
    (P, Vp, L) lane state unpacks to (V, L)."""
    gid = np.asarray(graph.vertex_gid).ravel()
    val = np.asarray(values)
    val = val.reshape((-1,) + val.shape[2:])
    out = np.zeros((graph.n_vertices,) + val.shape[1:], dtype=val.dtype)
    out[gid[gid >= 0]] = val[gid >= 0]
    return out


def _pad_to(x: np.ndarray, n: int, fill) -> np.ndarray:
    out = np.full((n,) + x.shape[1:], fill, dtype=x.dtype)
    out[: x.shape[0]] = x
    return out


def _round_up(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m if n > 0 else m


def _block_layout(widths_by_p, n_blocks: int):
    """Column offsets + shared block width for a per-partition-ragged
    family packed into ``(n_blocks, W)`` rows: partition ``p`` occupies
    columns ``[offs[p], offs[p] + widths_by_p[p])`` of block row
    ``p // ppb``; ``W`` is the widest block's span sum, so storage scales
    with ``max_b sum_{p in b}`` widths instead of ``P * max_p``."""
    P = len(widths_by_p)
    ppb = P // n_blocks
    offs = np.zeros(P, dtype=np.int64)
    W = 0
    for b in range(n_blocks):
        acc = 0
        for p in range(b * ppb, (b + 1) * ppb):
            offs[p] = acc
            acc += int(widths_by_p[p])
        W = max(W, acc)
    return offs, int(W)


@dataclasses.dataclass(frozen=True)
class _EdgeLayout:
    """Host-side placement of the block-ragged edge/group families: B
    blocks of ``ppb = P // B`` consecutive partitions, each partition a
    private column span inside its block row (see ``PartitionedGraph``)."""

    n_blocks: int
    ppb: int
    ep_by_p: tuple
    gp_by_p: tuple
    eoff: np.ndarray     # (P,) edge column offset of p within its block
    goff: np.ndarray     # (P,) group column offset of p within its block
    eb: int              # shared edge block width (max per-block span sum)
    gb: int              # shared group block width

    @staticmethod
    def create(P: int, n_blocks: int, ep_by_p, gp_by_p) -> "_EdgeLayout":
        if n_blocks < 1 or P % n_blocks:
            raise ValueError(
                f"edge_blocks={n_blocks} must divide n_partitions={P}")
        eoff, eb = _block_layout(ep_by_p, n_blocks)
        goff, gb = _block_layout(gp_by_p, n_blocks)
        return _EdgeLayout(int(n_blocks), P // n_blocks, tuple(ep_by_p),
                           tuple(gp_by_p), eoff, goff, eb, gb)

    def p_rel(self, p: int) -> int:
        return p % self.ppb


class _SpanView:
    """Partition-local window into a block-ragged ``(B, W, ...)`` array:
    key ``[p, sl]`` resolves to block row ``p // ppb`` at the partition's
    column span.  Keeps the shared per-partition fill helpers addressing
    partitions uniformly whatever the block count (``B == P`` reproduces
    the former fully-padded layout, ``B == 1`` is fully ragged)."""

    def __init__(self, arr, ppb: int, offs, widths):
        self._a, self._ppb = arr, ppb
        self._offs, self._widths = offs, widths

    def _map(self, key):
        p, sl = key if isinstance(key, tuple) else (key, slice(None))
        o = int(self._offs[p])
        if isinstance(sl, slice):
            start = o + (sl.start or 0)
            stop = o + (int(self._widths[p]) if sl.stop is None else sl.stop)
            return p // self._ppb, slice(start, stop)
        return p // self._ppb, o + sl

    def __getitem__(self, key):
        return self._a[self._map(key)]

    def __setitem__(self, key, val):
        self._a[self._map(key)] = val


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class EllSlice:
    """One degree bin of a sliced-ELL edge layout (partition-major).

    Row binning keeps power-law graphs on the kernel fast path: bin 0 holds
    slots [0, K0) of every row (dense — row r is destination slot r), spill
    bins hold the overflow slots of high-degree rows only, indirected
    through ``rows``.  A delivery is the ⊕-combination of one `ell_spmv`
    call per bin.

    Like the dense edge family, the tiles are block-ragged: ``B`` block
    rows (``B = graph.n_blocks``) each packing ``ppb = P // B``
    consecutive partitions side by side, so the row axis scales with the
    widest block's span *sum* instead of ``P * max_p``.  ``rows`` are
    block-relative (``p_rel * Vp + slot``, sentinel ``ppb * Vp``) and
    ``grp`` ids are block-relative flat (partition group-span offset baked
    in), which is what lets a shard_map block run the same code on its
    slice of blocks.

    The ``flat_*`` views are the single-device fast path, precomputed at
    build time: absolute row ids ``p*Vp + slot`` (sentinel P*Vp on
    padding, dropped by ``mode='drop'`` scatters) and source ids offset by
    p*stride so one kernel call covers every partition.  Inside a
    shard_map block the per-partition arrays are re-offset locally instead
    (see ``runtime.slice_flat``).
    """

    rows: jax.Array       # (B, Nb) int32 — p_rel*Vp + slot, ppb*Vp sentinel
    idx: jax.Array        # (B, Nb, Kb) int32 — source slot, or Vp + halo slot
    val: jax.Array        # (B, Nb, Kb) float32 — edge weight
    msk: jax.Array        # (B, Nb, Kb) bool — slot occupancy
    # per-slot message-accounting group id (the (destination, source
    # partition) Combine() granularity of `PartitionedGraph.edge_group`,
    # block-relative flat like it), 0 on padding — lets
    # `collect_metrics=True` counters ride the tiles instead of
    # re-reducing the dense edge arrays
    grp: jax.Array        # (B, Nb, Kb) int32
    flat_rows: jax.Array  # (B*Nb,) int32 — p*Vp + slot, P*Vp sentinel
    flat_idx: jax.Array   # (B*Nb, Kb) int32 — idx + p*stride
    nb: int = dataclasses.field(metadata=dict(static=True))
    kb: int = dataclasses.field(metadata=dict(static=True))
    lo: int = dataclasses.field(metadata=dict(static=True))   # first edge slot
    dense: bool = dataclasses.field(metadata=dict(static=True))
    stride: int = dataclasses.field(metadata=dict(static=True))  # frontier row pitch
    # max source *global id* feeding this slice — the per-bin bound deciding
    # whether integer payloads survive the kernel's float32 carriage exactly
    payload_bound: int = dataclasses.field(metadata=dict(static=True))


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PartitionedGraph:
    """Static, partition-major graph structure (a pytree of arrays).

    Vertex-scale families are padded per partition: P = #partitions,
    Vp = max vertices/partition, X = max exports/partition, H = max halo
    entries.

    Edge-scale families are **block-ragged** to keep memory scaling with
    ``sum_p Ep_p`` instead of ``P * max_p Ep_p`` under skewed labelings
    (fennel/multilevel cluster hubs, so per-partition in-edge counts vary
    wildly): the ``B = n_blocks`` block rows each pack ``ppb = P // B``
    consecutive partitions side by side, partition ``p`` owning the
    column span ``[eoff_p, eoff_p + ep_by_p[p])`` of block ``p // ppb``
    (``edge_span``/``group_span`` recover the spans).  ``Ep`` below is the
    shared block width (the widest block's span sum) and ``Gp`` its group
    analogue.  ``edge_part`` holds each slot's block-relative partition
    index and ``edge_group`` block-relative flat group ids, so runtime
    code never needs the per-partition offsets.  ``B == 1`` (the build
    default) is fully ragged; ``B == P`` reproduces the former shared-Ep
    padded layout; the distributed step shards block rows on dim 0 like
    every other family (``B`` a multiple of the device count).
    """

    # ---- vertices -------------------------------------------------------
    vertex_gid: jax.Array       # (P, Vp) int32, -1 on padding
    vertex_mask: jax.Array      # (P, Vp) bool
    is_boundary: jax.Array      # (P, Vp) bool — has a remote in-edge
    out_degree: jax.Array       # (P, Vp) int32 — global out-degree
    # ---- in-edges, block-ragged, sorted by destination slot per span ----
    edge_src: jax.Array         # (B, Ep) int32 — local slot, or Vp + halo slot
    edge_dst: jax.Array         # (B, Ep) int32 — destination local slot
    edge_w: jax.Array           # (B, Ep) float32
    edge_mask: jax.Array        # (B, Ep) bool
    edge_local: jax.Array       # (B, Ep) bool — source in same partition
    edge_src_gid: jax.Array     # (B, Ep) int32 — global id of source
    edge_dst_gid: jax.Array     # (B, Ep) int32 — global id of destination
    # block-relative partition index (p % ppb) of each slot's owning
    # partition — the runtime's key back from a block column to a
    # partition (absolute: edge_part + block_row * ppb)
    edge_part: jax.Array        # (B, Ep) int32
    # message-accounting groups: one group per (destination vertex, source
    # partition) pair — the granularity at which Pregel's Combine() merges
    # traffic.  Ids are block-relative flat: partition p's dense local ids
    # offset by its group-span start, so they index (B, Gp) directly.
    edge_group: jax.Array       # (B, Ep) int32
    group_remote: jax.Array     # (B, Gp) bool — group's source partition != p
    group_mask: jax.Array       # (B, Gp) bool
    # ---- halo-exchange plan ---------------------------------------------
    export_slot: jax.Array      # (P, X) int32 — local slots exported
    export_mask: jax.Array      # (P, X) bool
    export_fanout: jax.Array    # (P, X) int32 — #remote partitions consuming
    halo_ptr: jax.Array         # (P, H) int32 — flat index q*X + x into exports
    halo_mask: jax.Array        # (P, H) bool
    # ---- sliced-ELL edge layouts (destination-major degree bins) --------
    # The kernel fast paths: ``local_ell`` packs each partition's
    # same-partition in-edges (sources are local slots, frontier stride Vp),
    # ``remote_ell`` packs its remote in-edges (sources are Vp + halo slot,
    # frontier stride Vp + H — the concat(out, halo_out) table).  Empty
    # tuples when the layout was not built.
    local_ell: tuple[EllSlice, ...]
    remote_ell: tuple[EllSlice, ...]
    # ---- static metadata (not traced) -----------------------------------
    n_partitions: int = dataclasses.field(metadata=dict(static=True))
    n_vertices: int = dataclasses.field(metadata=dict(static=True))
    n_edges: int = dataclasses.field(metadata=dict(static=True))
    vp: int = dataclasses.field(metadata=dict(static=True))
    ep: int = dataclasses.field(metadata=dict(static=True))
    xp: int = dataclasses.field(metadata=dict(static=True))
    hp: int = dataclasses.field(metadata=dict(static=True))
    gp: int = dataclasses.field(metadata=dict(static=True))
    # block-ragged edge layout: block count + per-partition padded span
    # widths (tuples of ints — hashable static pytree metadata)
    n_blocks: int = dataclasses.field(metadata=dict(static=True))
    ep_by_p: tuple = dataclasses.field(metadata=dict(static=True))
    gp_by_p: tuple = dataclasses.field(metadata=dict(static=True))

    def edge_span(self, p: int) -> tuple[int, slice]:
        """(block row, column slice) of partition ``p``'s in-edge span."""
        ppb = self.n_partitions // self.n_blocks
        off = sum(self.ep_by_p[(p // ppb) * ppb:p])
        return p // ppb, slice(off, off + self.ep_by_p[p])

    def group_span(self, p: int) -> tuple[int, slice]:
        """(block row, column slice) of partition ``p``'s group span."""
        ppb = self.n_partitions // self.n_blocks
        off = sum(self.gp_by_p[(p // ppb) * ppb:p])
        return p // ppb, slice(off, off + self.gp_by_p[p])

    @property
    def pad_waste(self) -> float:
        """What the former shared-Ep layout would have paid: the ratio of
        ``P * max_p Ep_p`` to ``sum_p Ep_p`` over the padded spans."""
        total = sum(self.ep_by_p)
        return (self.n_partitions * max(self.ep_by_p) / total
                if total else 1.0)

    @property
    def has_ell(self) -> bool:
        """Whether the local-edge ELL layout is available for kernel-backed
        delivery."""
        return len(self.local_ell) > 0

    @property
    def has_remote_ell(self) -> bool:
        return len(self.remote_ell) > 0

    @property
    def kl(self) -> int:
        """Base-bin slice width of the local layout (0 when not built)."""
        return self.local_ell[0].kb if self.local_ell else 0

    # ------------------------------------------------------------------
    @property
    def shape_summary(self) -> str:
        return (
            f"P={self.n_partitions} V={self.n_vertices} E={self.n_edges} "
            f"Vp={self.vp} B={self.n_blocks} Ep={self.ep} X={self.xp} "
            f"H={self.hp}"
        )


def build_partitioned_graph(
    edges: np.ndarray,
    n_vertices: int,
    part: np.ndarray | str,
    weights: np.ndarray | None = None,
    pad_multiple: int = 8,
    build_ell: bool = True,
    ell_pad_slices: int = 8,
    ell_base_slices: int = 128,
    n_partitions: int | None = None,
    partition_seed: int = 0,
    edge_blocks: int = 1,
) -> PartitionedGraph:
    """Construct the partition-major structure from a global edge list.

    ``edges`` is (E, 2) int [src, dst]; ``part`` maps vertex -> partition id
    — either a precomputed (V,) labeling, or a partitioner name from
    ``repro.partition.PARTITIONERS`` ('hash' | 'bfs' | 'fennel' |
    'multilevel'), in which case ``n_partitions`` (and optionally
    ``partition_seed``) choose how the labeling is computed.

    ``pad_multiple`` rounds every per-partition extent (vertex, edge,
    export, halo and group spans) up to a multiple, trading a bounded
    sliver of padding for aligned array extents; the structure's *values*
    are identical across choices (only masked padding moves), which the
    builder parity sweep pins.

    ``edge_blocks`` sets the block count B of the ragged edge layout:
    per-partition edge spans are packed into B block rows of P // B
    consecutive partitions each, so edge memory scales with the widest
    block's span *sum* (B=1, the default: exactly ``sum_p Ep_p``) instead
    of ``P * max_p Ep_p`` (B=P: the former shared-width padded layout).
    The distributed step shards block rows over devices, so pass a
    multiple of the device count there.

    ``build_ell`` additionally packs each partition's local *and* remote
    in-edges into destination-major sliced-ELL layouts (the kernel fast
    paths for both delivery phases).  ``ell_pad_slices`` pads the slice axis
    (use 128 when targeting TPU lanes; 8 keeps CPU/interpret memory small).
    ``ell_base_slices`` bounds the dense base bin: rows whose in-degree
    exceeds it spill into up to two extra degree bins (see
    ``kernels.common.ell_bin_widths``), so power-law skew widens only the
    tiny spill bins instead of padding every row to the hub degree.

    For graphs too large to hold as one in-memory edge array, the same
    structure — bit-identical — is produced out-of-core by
    ``repro.io.build_partitioned_graph_from_path``, which shares every
    per-partition helper below.

    Args:
        edges: (E, 2) int array of [src, dst] vertex ids in [0, V).
        n_vertices: V, the global vertex count.
        part: (V,) labeling, or a partitioner name (see above).
        weights: optional (E,) float32 edge values; defaults to ones.
        pad_multiple / build_ell / ell_pad_slices / ell_base_slices /
            edge_blocks: layout knobs, see above.
        n_partitions, partition_seed: used only when ``part`` is a name.

    Returns:
        A ``PartitionedGraph``: partition-major vertex tables,
        block-ragged edge spans, export/halo routing for the exchange,
        and (when ``build_ell``) local + halo-encoded remote sliced-ELL
        tiles.

    Raises:
        ValueError: ``part`` is a partitioner name but ``n_partitions``
            was not given; an unknown partitioner name; or ``edge_blocks``
            does not divide into the partition count.
    """
    edges = np.asarray(edges, dtype=np.int64)
    if isinstance(part, str):
        if n_partitions is None:
            raise ValueError("partitioner-by-name needs n_partitions")
        from repro.partition import make_partition
        part = make_partition(part, edges, n_vertices, n_partitions,
                              seed=partition_seed)
    part = np.asarray(part, dtype=np.int32)
    n_edges = edges.shape[0]
    if weights is None:
        weights = np.ones(n_edges, dtype=np.float32)
    weights = np.asarray(weights, dtype=np.float32)

    src, dst = edges[:, 0], edges[:, 1]
    psrc, pdst = part[src], part[dst]

    out_degree = np.bincount(src, minlength=n_vertices).astype(np.int32)

    P, verts_by_p, slot_of, Vp = _vertex_slots(part, n_vertices, pad_multiple)

    # --- boundary classification -----------------------------------------
    is_boundary_g = np.zeros(n_vertices, dtype=bool)
    cross = psrc != pdst
    is_boundary_g[dst[cross]] = True

    # --- halo: remote sources needed per partition (sorted unique) --------
    halo_by_p = [np.unique(src[cross & (pdst == p)]) for p in range(P)]

    # --- exporters: vertices with >= 1 crossing out-edge ------------------
    exp_pairs = np.unique(
        np.stack([src[cross], pdst[cross].astype(np.int64)], axis=1), axis=0
    )
    exporters_by_p, fanout_by_p, export_idx_of = _export_tables(
        exp_pairs[:, 0], part, n_vertices, P)
    X = _round_up(max((len(v) for v in exporters_by_p), default=1), pad_multiple)
    H = _round_up(max((len(h) for h in halo_by_p), default=1), pad_multiple)

    # --- per-partition in-edge arrays sorted by destination slot ----------
    per_p: list[dict[str, np.ndarray]] = []
    for p in range(P):
        sel = pdst == p
        per_p.append(_partition_edges(src[sel], dst[sel], weights[sel],
                                      psrc[sel], p, slot_of, halo_by_p[p],
                                      Vp, P))
    layout = _EdgeLayout.create(
        P, edge_blocks,
        tuple(_round_up(len(d["w"]), pad_multiple) for d in per_p),
        tuple(_round_up(len(d["group_remote"]), pad_multiple)
              for d in per_p))

    # --- assemble block-ragged + padded arrays ----------------------------
    arrs = _alloc_core(P, Vp, X, H, layout)
    staged = _core_views(arrs, layout)
    for p in range(P):
        _fill_core_partition(
            staged, p, per_p[p], verts_by_p[p], is_boundary_g, out_degree,
            slot_of, exporters_by_p[p], fanout_by_p[p],
            _halo_ptrs(halo_by_p[p], part, export_idx_of, X), layout)

    # --- sliced-ELL in-edge layouts (destination-major fast paths) --------
    local_ell: tuple[EllSlice, ...] = ()
    remote_ell: tuple[EllSlice, ...] = ()
    if build_ell:
        picks_l = [_ell_pick(d, negate=False) for d in per_p]
        picks_r = [_ell_pick(d, negate=True) for d in per_p]
        local_ell = _build_ell_slices(
            picks_l.__getitem__, P=P, Vp=Vp, stride=Vp,
            pad=pad_multiple, slice_pad=ell_pad_slices,
            base_slices=ell_base_slices, layout=layout)
        remote_ell = _build_ell_slices(
            picks_r.__getitem__, P=P, Vp=Vp, stride=Vp + H,
            pad=pad_multiple, slice_pad=ell_pad_slices,
            base_slices=ell_base_slices, layout=layout)

    return _finalize_graph(arrs, local_ell, remote_ell, n_partitions=P,
                           n_vertices=int(n_vertices), n_edges=int(n_edges),
                           vp=int(Vp), ep=int(layout.eb), xp=int(X),
                           hp=int(H), gp=int(layout.gb), layout=layout)


# ---------------------------------------------------------------------------
# shared build helpers — `repro.io`'s out-of-core builder calls these same
# functions one partition shard at a time, which is what keeps the two
# builders bit-identical by construction rather than by test alone
# ---------------------------------------------------------------------------

def _vertex_slots(part: np.ndarray, n_vertices: int, pad_multiple: int):
    """Partition-major vertex slot assignment: vertices of partition p in
    ascending global-id order.  Returns (P, verts_by_p, slot_of, Vp)."""
    P = int(part.max()) + 1 if part.size else 1
    order_v = np.argsort(part, kind="stable")
    verts_by_p: list[np.ndarray] = []
    slot_of = np.zeros(n_vertices, dtype=np.int64)
    counts = np.bincount(part, minlength=P)
    off = 0
    for p in range(P):
        vs = order_v[off:off + counts[p]]
        off += counts[p]
        verts_by_p.append(vs)
        slot_of[vs] = np.arange(len(vs))
    Vp = _round_up(int(counts.max()) if counts.size else 1, pad_multiple)
    return P, verts_by_p, slot_of, Vp


def _export_tables(pair_src: np.ndarray, part: np.ndarray, n_vertices: int,
                   P: int):
    """Exporter tables from the *unique* (source vertex, destination
    partition) cross pairs — ``pair_src`` is the source column; fanout is
    the number of distinct remote partitions consuming each export."""
    pair_src = np.asarray(pair_src)        # int32 or int64, preserved
    exporters_by_p: list[np.ndarray] = []
    fanout_by_p: list[np.ndarray] = []
    export_idx_of = np.full(n_vertices, -1, dtype=np.int64)
    psrc_pair = part[pair_src] if pair_src.size else pair_src
    for p in range(P):
        rows = pair_src[psrc_pair == p]
        vs, fan = (np.unique(rows, return_counts=True)
                   if rows.size else (np.zeros(0, np.int64),
                                      np.zeros(0, np.int64)))
        exporters_by_p.append(vs)
        fanout_by_p.append(fan)
        export_idx_of[vs] = np.arange(len(vs))
    return exporters_by_p, fanout_by_p, export_idx_of


def _halo_ptrs(halo_need: np.ndarray, part: np.ndarray,
               export_idx_of: np.ndarray, X: int) -> np.ndarray:
    """Flat q*X + x pointers into the exporters' buffers for one
    partition's halo table."""
    qs = part[halo_need].astype(np.int64)
    xs = export_idx_of[halo_need]
    assert (xs >= 0).all(), "halo source must be an exporter"
    return (qs * X + xs).astype(np.int32)


def _partition_edges(es: np.ndarray, ed: np.ndarray, ew: np.ndarray,
                     eps: np.ndarray, p: int, slot_of: np.ndarray,
                     halo_need: np.ndarray, Vp: int, P: int
                     ) -> dict[str, np.ndarray]:
    """One partition's in-edge arrays, sorted by destination slot.

    ``es``/``ed``/``ew``/``eps`` are the src/dst/weight/src-partition of
    every edge whose destination lives in partition ``p``, in original
    edge-list order; ``halo_need`` is the partition's sorted unique remote
    source list (the halo slot of a remote source is its position there).
    """
    d_slot = slot_of[ed]
    # encode source: local slot, or Vp + halo slot (searchsorted over the
    # sorted unique halo list; the local branch's lookup value is unused)
    s_enc = np.where(eps == p, slot_of[es],
                     Vp + np.searchsorted(halo_need, es))
    order_e = np.argsort(d_slot, kind="stable")
    es, ed, ew, eps = es[order_e], ed[order_e], ew[order_e], eps[order_e]
    d_slot, s_enc = d_slot[order_e], s_enc[order_e]
    # (dst vertex, src partition) combine groups, dense ids
    gkey = d_slot * P + eps
    _, ginv = np.unique(gkey, return_inverse=True)
    gremote = np.zeros(int(ginv.max()) + 1 if ginv.size else 1, dtype=bool)
    np.maximum.at(gremote, ginv, eps != p)
    return dict(src_enc=s_enc, dst_slot=d_slot, w=ew, local=(eps == p),
                src_gid=es, dst_gid=ed, group=ginv, group_remote=gremote)


_CORE_SPEC = {
    # name -> (per-partition shape axis, dtype, fill)
    "vertex_gid": ("Vp", np.int32, -1),
    "is_boundary": ("Vp", bool, False),
    "out_degree": ("Vp", np.int32, 0),
    "edge_src": ("Ep", np.int32, 0),
    "edge_dst": ("Ep", np.int32, 0),
    "edge_w": ("Ep", np.float32, 0.0),
    "edge_mask": ("Ep", bool, False),
    "edge_local": ("Ep", bool, False),
    "edge_src_gid": ("Ep", np.int32, -1),
    "edge_dst_gid": ("Ep", np.int32, -1),
    "edge_part": ("Ep", np.int32, 0),
    "edge_group": ("Ep", np.int32, 0),
    "group_remote": ("Gp", bool, False),
    "group_mask": ("Gp", bool, False),
    "export_slot": ("X", np.int32, 0),
    "export_mask": ("X", bool, False),
    "export_fanout": ("X", np.int32, 0),
    "halo_ptr": ("H", np.int32, 0),
    "halo_mask": ("H", bool, False),
}


def _alloc_core(P: int, Vp: int, X: int, H: int, layout: _EdgeLayout
                ) -> dict[str, np.ndarray]:
    """The core arrays: vertex-scale families padded ``(P, axis)``,
    edge/group families block-ragged ``(B, width)`` per ``layout``."""
    dims = {"Vp": (P, Vp), "X": (P, X), "H": (P, H),
            "Ep": (layout.n_blocks, layout.eb),
            "Gp": (layout.n_blocks, layout.gb)}
    return {name: np.full(dims[axis], fill, dtype=dtype)
            for name, (axis, dtype, fill) in _CORE_SPEC.items()}


def _core_views(arrs, layout: _EdgeLayout) -> dict[str, Any]:
    """Per-partition span views over the block-ragged families (vertex-
    scale arrays pass through) — what the fill helpers write into."""
    ew = np.asarray(layout.ep_by_p)
    gw = np.asarray(layout.gp_by_p)
    out: dict[str, Any] = {}
    for name, (axis, _, _) in _CORE_SPEC.items():
        if axis == "Ep":
            out[name] = _SpanView(arrs[name], layout.ppb, layout.eoff, ew)
        elif axis == "Gp":
            out[name] = _SpanView(arrs[name], layout.ppb, layout.goff, gw)
        else:
            out[name] = arrs[name]
    return out


def _fill_core_partition(arrs: dict[str, Any], p: int,
                         e: dict[str, np.ndarray], verts: np.ndarray,
                         is_boundary_g: np.ndarray, out_degree: np.ndarray,
                         slot_of: np.ndarray, exporters: np.ndarray,
                         fanout: np.ndarray, halo_ptrs: np.ndarray,
                         layout: _EdgeLayout) -> None:
    """Write one partition's span of every core array (``arrs`` carries
    span views over the block-ragged families, see ``_core_views``)."""
    nv = len(verts)
    arrs["vertex_gid"][p, :nv] = verts.astype(np.int32)
    arrs["is_boundary"][p, :nv] = is_boundary_g[verts]
    arrs["out_degree"][p, :nv] = out_degree[verts]
    ne = len(e["w"])
    arrs["edge_src"][p, :ne] = e["src_enc"].astype(np.int32)
    arrs["edge_dst"][p, :ne] = e["dst_slot"].astype(np.int32)
    arrs["edge_w"][p, :ne] = e["w"]
    arrs["edge_mask"][p, :ne] = True
    arrs["edge_local"][p, :ne] = e["local"]
    arrs["edge_src_gid"][p, :ne] = e["src_gid"].astype(np.int32)
    arrs["edge_dst_gid"][p, :ne] = e["dst_gid"].astype(np.int32)
    arrs["edge_part"][p, :] = np.int32(layout.p_rel(p))
    arrs["edge_group"][p, :ne] = (e["group"]
                                  + int(layout.goff[p])).astype(np.int32)
    ng = len(e["group_remote"])
    arrs["group_remote"][p, :ng] = e["group_remote"]
    arrs["group_mask"][p, :ng] = True
    nx = len(exporters)
    arrs["export_slot"][p, :nx] = slot_of[exporters].astype(np.int32)
    arrs["export_mask"][p, :nx] = True
    arrs["export_fanout"][p, :nx] = fanout.astype(np.int32)
    nh = len(halo_ptrs)
    arrs["halo_ptr"][p, :nh] = halo_ptrs
    arrs["halo_mask"][p, :nh] = True


def _finalize_graph(arrs: dict[str, np.ndarray],
                    local_ell: tuple[EllSlice, ...],
                    remote_ell: tuple[EllSlice, ...], *, n_partitions: int,
                    n_vertices: int, n_edges: int, vp: int, ep: int, xp: int,
                    hp: int, gp: int,
                    layout: _EdgeLayout) -> PartitionedGraph:
    """Convert the filled numpy arrays to the on-device pytree, dropping
    each host copy as soon as it is converted (the out-of-core path's peak
    memory is the final structure, not twice it)."""
    vertex_mask = arrs["vertex_gid"] >= 0

    def take(name: str):
        return jnp.asarray(arrs.pop(name))

    return PartitionedGraph(
        vertex_gid=take("vertex_gid"), vertex_mask=jnp.asarray(vertex_mask),
        is_boundary=take("is_boundary"), out_degree=take("out_degree"),
        edge_src=take("edge_src"), edge_dst=take("edge_dst"),
        edge_w=take("edge_w"), edge_mask=take("edge_mask"),
        edge_local=take("edge_local"),
        edge_src_gid=take("edge_src_gid"), edge_dst_gid=take("edge_dst_gid"),
        edge_part=take("edge_part"),
        edge_group=take("edge_group"), group_remote=take("group_remote"),
        group_mask=take("group_mask"),
        export_slot=take("export_slot"), export_mask=take("export_mask"),
        export_fanout=take("export_fanout"),
        halo_ptr=take("halo_ptr"), halo_mask=take("halo_mask"),
        local_ell=local_ell, remote_ell=remote_ell,
        n_partitions=n_partitions, n_vertices=n_vertices, n_edges=n_edges,
        vp=vp, ep=ep, xp=xp, hp=hp, gp=gp,
        n_blocks=layout.n_blocks, ep_by_p=layout.ep_by_p,
        gp_by_p=layout.gp_by_p,
    )


def _ell_pick(e: dict[str, np.ndarray], negate: bool) -> dict[str, np.ndarray]:
    """Select one side (local or remote) of a partition's in-edges and
    precompute the stable dst argsort + per-edge rank within its
    destination run, shared by the packer and the per-bin source-gid
    bound."""
    sel = e["local"]
    if negate:
        sel = np.logical_not(sel)
    pick = dict(src=e["src_enc"][sel], dst=e["dst_slot"][sel],
                w=e["w"][sel], gid=e["src_gid"][sel], grp=e["group"][sel])
    order = np.argsort(pick["dst"], kind="stable")
    dst_s = pick["dst"][order]
    pick["order"] = order
    pick["gid_ranked"] = pick["gid"][order]
    pick["rank"] = (np.arange(len(dst_s))
                    - np.searchsorted(dst_s, dst_s, side="left"))
    return pick


def _ell_plan(slot_degrees: list[np.ndarray], Vp: int, pad: int,
              slice_pad: int, base_slices: int):
    """Bin widths + per-bin *per-partition* row counts from the
    per-partition destination-slot in-degree histograms.  Returns
    ``(widths, nb_by_p)`` with one row-count list per bin (the dense base
    bin is Vp rows per partition, spill bins the padded count of rows
    exceeding the bin's lo); ``([], [])`` when the edge side is empty."""
    from repro.kernels.common import ell_bin_widths

    kmax = max((int(d.max()) for d in slot_degrees if len(d)), default=0)
    widths = ell_bin_widths(kmax, base_slices, slice_pad)
    nb_by_p = [[Vp] * len(slot_degrees) if lo == 0 else
               [_round_up(int((d > lo).sum()), pad) for d in slot_degrees]
               for lo, kb in widths]
    return widths, nb_by_p


def _ell_alloc(widths, bin_layouts, layout: _EdgeLayout, Vp: int
               ) -> list[dict[str, np.ndarray]]:
    B, ppb = layout.n_blocks, layout.ppb
    P = B * ppb
    arrs = []
    for (lo, kb), (_, Nb) in zip(widths, bin_layouts):
        arrs.append(dict(
            rows=np.full((B, Nb), ppb * Vp, dtype=np.int32),
            idx=np.zeros((B, Nb, kb), dtype=np.int32),
            val=np.zeros((B, Nb, kb), dtype=np.float32),
            msk=np.zeros((B, Nb, kb), dtype=bool),
            grp=np.zeros((B, Nb, kb), dtype=np.int32),
            flat_rows=np.full((B, Nb), P * Vp, dtype=np.int32),
            flat_idx=np.zeros((B, Nb, kb), dtype=np.int32)))
    return arrs


def _ell_fill_partition(arrs: list[dict[str, Any]], widths, p: int,
                        pick: dict[str, np.ndarray], P: int, Vp: int,
                        layout: _EdgeLayout, stride: int) -> list[int]:
    """Pack one partition's picked edge side and write its row span into
    every bin (``arrs`` carries per-partition span views, see
    ``_build_ell_slices``): block-relative rows (``p_rel*Vp + slot``,
    sentinel ``ppb*Vp``), block-relative flat ``grp`` ids, and the
    absolute ``flat_*`` host views.  Returns the per-bin max-source-gid
    contributions."""
    from repro.kernels.common import sliced_ell_pack_numpy

    packs = sliced_ell_pack_numpy(pick["src"], pick["dst"], pick["w"], Vp,
                                  widths,
                                  order_rank=(pick["order"], pick["rank"]),
                                  extras=(pick["grp"],))
    prel = layout.p_rel(p)
    goff = int(layout.goff[p])
    bounds = []
    for b, (lo, kb) in enumerate(widths):
        rows_b, idx_b, val_b, msk_b, grp_b = packs[b]
        a = arrs[b]
        if rows_b is None:                      # dense base bin
            a["rows"][p] = np.arange(Vp, dtype=np.int32) + np.int32(prel * Vp)
        else:
            a["rows"][p, : len(rows_b)] = (rows_b.astype(np.int32)
                                           + np.int32(prel * Vp))
        n = idx_b.shape[0]
        a["idx"][p, :n], a["val"][p, :n], a["msk"][p, :n] = idx_b, val_b, msk_b
        a["grp"][p, :n] = np.where(msk_b, grp_b.astype(np.int32)
                                   + np.int32(goff), np.int32(0))
        rloc = a["rows"][p].astype(np.int64) - prel * Vp
        a["flat_rows"][p] = np.where(rloc < Vp, p * Vp + rloc,
                                     P * Vp).astype(np.int32)
        a["flat_idx"][p, :] = a["idx"][p] + np.int32(p * stride)
        bounds.append(_bin_src_bound(pick, lo, kb))
    return bounds


def _ell_finalize(arrs: list[dict[str, np.ndarray]], widths,
                  bounds: list[int], stride: int) -> tuple[EllSlice, ...]:
    slices = []
    for (lo, kb), a, bound in zip(widths, arrs, bounds):
        B, Nb = a["rows"].shape
        flat_idx = a.pop("flat_idx")
        slices.append(EllSlice(
            rows=jnp.asarray(a.pop("rows")), idx=jnp.asarray(a.pop("idx")),
            val=jnp.asarray(a.pop("val")), msk=jnp.asarray(a.pop("msk")),
            grp=jnp.asarray(a.pop("grp")),
            flat_rows=jnp.asarray(a.pop("flat_rows").reshape(-1)),
            flat_idx=jnp.asarray(flat_idx.reshape(B * Nb, kb)),
            nb=int(Nb), kb=int(kb), lo=int(lo), dense=bool(lo == 0),
            stride=int(stride), payload_bound=int(bound)))
    return tuple(slices)


def _build_ell_slices(make_pick, P: int, Vp: int, stride: int, pad: int,
                      slice_pad: int, base_slices: int,
                      layout: _EdgeLayout) -> tuple[EllSlice, ...]:
    """Pack one side (local or remote) of every partition's in-edges into
    block-ragged sliced-ELL degree bins, flat views precomputed.

    ``make_pick(p)`` returns partition p's pick dict (see ``_ell_pick``);
    it is called twice per partition — once for the degree histograms that
    fix the bin widths, once for the fill — so callers that cannot hold
    every pick at once (the out-of-core builder) stay memory-bounded.
    """
    degs = []
    for p in range(P):
        e = make_pick(p)
        degs.append(np.bincount(e["dst"], minlength=Vp))
    widths, nb_by_p = _ell_plan(degs, Vp, pad, slice_pad, base_slices)
    if not widths:
        return ()
    bin_layouts = [_block_layout(tuple(nbp), layout.n_blocks)
                   for nbp in nb_by_p]
    arrs = _ell_alloc(widths, bin_layouts, layout, Vp)
    staged = [
        {name: _SpanView(a[name], layout.ppb, offs, np.asarray(nbp))
         for name in a}
        for a, (offs, _), nbp in zip(arrs, bin_layouts, nb_by_p)]
    bounds = [-1] * len(widths)
    for p in range(P):
        contrib = _ell_fill_partition(staged, widths, p, make_pick(p), P,
                                      Vp, layout, stride)
        bounds = [max(b, c) for b, c in zip(bounds, contrib)]
    return _ell_finalize(arrs, widths, bounds, stride)


def _bin_src_bound(e: dict, lo: int, kb: int) -> int:
    """Max source gid among the edges landing in bin [lo, lo+kb), via the
    precomputed dst-ranking (mirrors ``sliced_ell_pack_numpy``)."""
    rank = e["rank"]
    if not len(rank):
        return -1
    sel = (rank >= lo) & (rank < lo + kb)
    return int(e["gid_ranked"][sel].max()) if sel.any() else -1
