"""mamba2-370m [ssm] — arXiv:2405.21060 (unverified).
48L d_model=1024 attn-free, vocab=50280, ssm_state=128 (SSD)."""

import dataclasses

from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, n_heads=0, n_kv_heads=0, head_dim=0,
    d_ff=0, vocab=50_280,
    pattern=(LayerSpec(mixer="mamba", attn="none"),),
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_chunk=128, conv_dim=4,
    tie_embeddings=True, sub_quadratic=True,
    source="arXiv:2405.21060; unverified",
)

SMOKE = dataclasses.replace(
    CONFIG, name="mamba2-smoke", n_layers=2, d_model=64, vocab=256,
    ssm_state=16, ssm_head_dim=16, ssm_chunk=16)
