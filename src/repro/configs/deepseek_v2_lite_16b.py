"""deepseek-v2-lite-16b [moe] — arXiv:2405.04434 (hf-verified).
27L d_model=2048, MLA with 16 heads (kv_lora=512, qk_nope=128, qk_rope=64,
v_head=128), vocab=102400; MoE 64 routed experts top-6 + 2 shared
(expert hidden 1408), first layer dense (d_ff=10944)."""

import dataclasses

from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=192,
    d_ff=10_944, vocab=102_400,
    pattern=(LayerSpec(mixer="attn", attn="mla", moe=True),),
    first_k_dense=1,
    n_experts=64, top_k=6, d_expert=1408, n_shared_experts=2,
    kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    source="arXiv:2405.04434; hf",
)

SMOKE = dataclasses.replace(
    CONFIG, name="deepseek-smoke", n_layers=3, d_model=64, n_heads=4,
    n_kv_heads=4, head_dim=48, d_ff=128, vocab=256, first_k_dense=1,
    n_experts=8, top_k=2, d_expert=32, n_shared_experts=1,
    kv_lora_rank=32, qk_nope_dim=32, qk_rope_dim=16, v_head_dim=32)
