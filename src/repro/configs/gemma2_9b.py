"""gemma2-9b [dense] — arXiv:2408.00118 (hf-verified).
42L d_model=3584 16H (GQA kv=8) d_ff=14336 vocab=256000; local/global
alternating attention (window 4096), attn/final logit soft-capping."""

import dataclasses

from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="gemma2-9b", family="dense",
    n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8, head_dim=256,
    d_ff=14_336, vocab=256_000, rope_theta=10_000.0, window=4096,
    pattern=(LayerSpec(mixer="attn", attn="window", window=4096),
             LayerSpec(mixer="attn", attn="full")),
    softcap_attn=50.0, softcap_final=30.0, tie_embeddings=True,
    act="gelu", sub_quadratic=True,   # half the stack is windowed
    source="arXiv:2408.00118; hf",
)

SMOKE = dataclasses.replace(
    CONFIG, name="gemma2-smoke", n_layers=4, d_model=64, n_heads=4,
    n_kv_heads=2, head_dim=16, d_ff=128, vocab=256, window=16,
    pattern=(LayerSpec(mixer="attn", attn="window", window=16),
             LayerSpec(mixer="attn", attn="full")))
