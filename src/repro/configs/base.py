"""Architecture / shape configuration system.

One ``ArchConfig`` per assigned architecture (exact public configs), plus
reduced smoke variants for CPU tests.  ``LayerSpec`` describes one layer of a
possibly heterogeneous stack (local/global attention interleaves, Mamba:attn
hybrids, dense-then-MoE stacks); the model groups layers into the smallest
repeating unit and ``lax.scan``s over units so 70-layer models compile fast.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Literal, Sequence

AttnKind = Literal["full", "window", "mla", "none"]


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer of the stack."""

    mixer: Literal["attn", "mamba"] = "attn"
    attn: AttnKind = "full"
    window: int = 0                  # sliding-window size when attn == 'window'
    moe: bool = False                # MoE FFN instead of dense
    causal: bool = True              # False for encoder stacks
    cross: bool = False              # add cross-attention (whisper decoder)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    # layer pattern: unit repeated; remainder unrolled (see models/stack.py)
    pattern: tuple[LayerSpec, ...] = (LayerSpec(),)
    window: int = 4096
    rope_theta: float = 10_000.0
    softcap_attn: float = 0.0        # gemma2 logit soft-capping
    softcap_final: float = 0.0
    tie_embeddings: bool = False
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    d_expert: int = 0                # expert hidden size (d_ff of one expert)
    n_shared_experts: int = 0        # deepseek shared experts
    first_k_dense: int = 0           # deepseek: first k layers dense
    # --- MLA (deepseek) ---
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # --- Mamba2 ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    conv_dim: int = 4
    # --- encoder-decoder (whisper) ---
    enc_layers: int = 0
    enc_frames: int = 0              # precomputed frame embeddings (stub)
    # --- VLM stub (internvl) ---
    vis_tokens: int = 0              # precomputed patch embeddings (stub)
    vis_dim: int = 0
    # --- misc ---
    norm_eps: float = 1e-6
    act: str = "silu"
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    pos: str = "rope"                # rope | sinusoidal (whisper)
    mlp: str = "gated"               # gated (SwiGLU/GeGLU) | plain (whisper)
    sub_quadratic: bool = False      # eligible for long_500k
    source: str = ""

    # ---- derived ---------------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def layers(self) -> tuple[LayerSpec, ...]:
        reps = -(-self.n_layers // len(self.pattern))
        out = list((self.pattern * reps)[: self.n_layers])
        # deepseek-style: first k layers use a dense FFN instead of MoE
        for i in range(min(self.first_k_dense, len(out))):
            out[i] = dataclasses.replace(out[i], moe=False)
        return tuple(out)

    def n_params(self) -> int:
        """Total parameter count (embedding included)."""
        from repro.models.registry import count_params
        return count_params(self)

    def n_active_params(self) -> int:
        from repro.models.registry import count_params
        return count_params(self, active_only=True)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

# The paper's own workload is the only registered config; the LM substrate
# stays usable with ad-hoc ArchConfigs (see examples/train_lm.py).
_ARCH_MODULES = [
    "graphhp_paper",
]


def list_archs() -> list[str]:
    out = []
    for m in _ARCH_MODULES:
        mod = importlib.import_module(f"repro.configs.{m}")
        out.append(mod.CONFIG.name if hasattr(mod, "CONFIG") else m)
    return out


def get_config(name: str, smoke: bool = False):
    """Load an arch config by id (e.g. 'gemma2-9b'), or its reduced smoke
    variant (same family/pattern, tiny dims) when ``smoke=True``."""
    key = name.replace("-", "_").replace(".", "p")
    for m in _ARCH_MODULES:
        mod = importlib.import_module(f"repro.configs.{m}")
        cfg = getattr(mod, "CONFIG", None)
        if cfg is not None and (cfg.name == name or m == key):
            return mod.SMOKE if smoke else cfg
    raise KeyError(f"unknown arch {name!r}; have {list_archs()}")
