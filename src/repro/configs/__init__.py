from repro.configs.base import (ArchConfig, LayerSpec, ShapeConfig, SHAPES,
                                get_config, list_archs)

__all__ = ["ArchConfig", "LayerSpec", "ShapeConfig", "SHAPES", "get_config",
           "list_archs"]
