"""gemma3-4b [dense] — hf:google/gemma-3-1b-pt family (unverified).
34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144; 5 local : 1 global
interleave (window 1024), 128k context."""

import dataclasses

from repro.configs.base import ArchConfig, LayerSpec

_LOCAL = LayerSpec(mixer="attn", attn="window", window=1024)
_GLOBAL = LayerSpec(mixer="attn", attn="full")

CONFIG = ArchConfig(
    name="gemma3-4b", family="dense",
    n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4, head_dim=256,
    d_ff=10_240, vocab=262_144, rope_theta=1_000_000.0, window=1024,
    pattern=(_LOCAL, _LOCAL, _LOCAL, _LOCAL, _LOCAL, _GLOBAL),
    tie_embeddings=True, act="gelu", sub_quadratic=True,
    source="hf:google/gemma-3-1b-pt; unverified",
)

SMOKE = dataclasses.replace(
    CONFIG, name="gemma3-smoke", n_layers=6, d_model=64, n_heads=4,
    n_kv_heads=2, head_dim=16, d_ff=128, vocab=256, window=16,
    pattern=(LayerSpec(mixer="attn", attn="window", window=16),) * 5
            + (LayerSpec(mixer="attn", attn="full"),))
