"""jamba-1.5-large-398b [hybrid] — arXiv:2403.19887 (hf-verified).
72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536; Mamba:attn 7:1
interleave (1 attention layer per 8-layer block), MoE 16e top-2 on every
other layer."""

import dataclasses

from repro.configs.base import ArchConfig, LayerSpec

_M = lambda moe: LayerSpec(mixer="mamba", attn="none", moe=moe)
_A = lambda moe: LayerSpec(mixer="attn", attn="full", moe=moe)

# 8-layer Jamba block: attention at position 4, MoE every other layer
_PATTERN = (_M(False), _M(True), _M(False), _M(True),
            _A(False), _M(True), _M(False), _M(True))

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=24_576, vocab=65_536,
    pattern=_PATTERN,
    n_experts=16, top_k=2, d_expert=24_576,
    # ssm_chunk=64 (not 256): the SSD intra-chunk decay tensor is
    # (B, S/Q, Q, Q, H) — quadratic in Q; at d_inner=16384 (H=128 heads),
    # Q=64 keeps the per-device working set ~2 GiB instead of ~550 GiB
    # (EXPERIMENTS.md #Perf, jamba iteration 1).
    ssm_state=64, ssm_expand=2, ssm_head_dim=128, ssm_chunk=64, conv_dim=4,
    sub_quadratic=True,
    source="arXiv:2403.19887; hf",
)

SMOKE = dataclasses.replace(
    CONFIG, name="jamba-smoke", n_layers=8, d_model=64, n_heads=4,
    n_kv_heads=2, head_dim=16, d_ff=128, vocab=256, n_experts=4, top_k=2,
    d_expert=128, ssm_state=16, ssm_head_dim=16, ssm_chunk=16)
