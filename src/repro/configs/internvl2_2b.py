"""internvl2-2b [vlm] — arXiv:2404.16821 (hf-verified).
InternViT frontend (STUB: precomputed patch embeddings) + InternLM2-1.8b
backbone: 24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553."""

import dataclasses

from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="internvl2-2b", family="vlm",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab=92_553, rope_theta=1_000_000.0,
    pattern=(LayerSpec(mixer="attn", attn="full"),),
    vis_tokens=256, vis_dim=1024,
    source="arXiv:2404.16821; hf",
)

SMOKE = dataclasses.replace(
    CONFIG, name="internvl2-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, head_dim=16, d_ff=128, vocab=256, vis_tokens=8, vis_dim=32)
