"""phi3-medium-14b [dense] — arXiv:2404.14219 (unverified).
40L d_model=5120 40H (GQA kv=10) d_ff=17920 vocab=100352, RoPE+SwiGLU."""

import dataclasses

from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="phi3-medium-14b", family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=10, head_dim=128,
    d_ff=17_920, vocab=100_352, rope_theta=10_000.0,
    pattern=(LayerSpec(mixer="attn", attn="full"),),
    source="arXiv:2404.14219; unverified",
)

SMOKE = dataclasses.replace(
    CONFIG, name="phi3-medium-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, head_dim=16, d_ff=160, vocab=256)
