"""whisper-small [audio] — arXiv:2212.04356 (unverified).
Encoder-decoder, 12L+12L d_model=768 12H d_ff=3072 vocab=51865.
The conv/mel frontend is a STUB: input_specs() provides precomputed frame
embeddings (1500 frames), per the assignment."""

import dataclasses

from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="whisper-small", family="audio",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, head_dim=64,
    d_ff=3072, vocab=51_865,
    pattern=(LayerSpec(mixer="attn", attn="full"),),
    enc_layers=12, enc_frames=1500,
    norm="layernorm", pos="sinusoidal", act="gelu", mlp="plain",
    source="arXiv:2212.04356; unverified",
)

SMOKE = dataclasses.replace(
    CONFIG, name="whisper-smoke", n_layers=2, enc_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, vocab=256, enc_frames=24)
