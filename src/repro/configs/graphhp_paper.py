"""The paper's own workload as a dry-runnable 'architecture': the GraphHP
hybrid engine over a partitioned synthetic road-network graph, distributed
with shard_map over the production mesh (one partition block per device)."""

import dataclasses


@dataclasses.dataclass(frozen=True)
class GraphHPConfig:
    name: str = "graphhp-paper"
    family: str = "graph"
    # per-device partition block sizes (padded static shapes)
    n_partitions: int = 256            # one per single-pod device
    vertices_per_partition: int = 16_384
    edges_per_partition: int = 65_536
    exports_per_partition: int = 2_048
    halo_per_partition: int = 2_048
    app: str = "sssp"
    source: str = "GraphHP (CS.DC 2017) §7"


CONFIG = GraphHPConfig()
SMOKE = dataclasses.replace(
    CONFIG, name="graphhp-smoke", n_partitions=4, vertices_per_partition=64,
    edges_per_partition=256, exports_per_partition=32, halo_per_partition=32)
