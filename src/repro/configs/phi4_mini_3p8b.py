"""phi4-mini-3.8b [dense] — arXiv:2412.08905 (hf-verified).
32L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=200064, RoPE+SwiGLU."""

import dataclasses

from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="phi4-mini-3.8b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab=200_064, rope_theta=10_000.0,
    pattern=(LayerSpec(mixer="attn", attn="full"),),
    tie_embeddings=True, source="arXiv:2412.08905; hf",
)

SMOKE = dataclasses.replace(
    CONFIG, name="phi4-mini-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, head_dim=16, d_ff=128, vocab=256)
