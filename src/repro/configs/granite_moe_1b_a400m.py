"""granite-moe-1b-a400m [moe] — hf:ibm-granite/granite-3.0-1b-a400m-base.
24L d_model=1024 16H (GQA kv=8) vocab=49155, 32 experts top-8 with expert
hidden 512 (d_ff field = expert hidden, every layer MoE)."""

import dataclasses

from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8, head_dim=64,
    d_ff=512, vocab=49_155,
    pattern=(LayerSpec(mixer="attn", attn="full", moe=True),),
    n_experts=32, top_k=8, d_expert=512, tie_embeddings=True,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
)

SMOKE = dataclasses.replace(
    CONFIG, name="granite-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, head_dim=16, d_ff=32, vocab=256, n_experts=8, top_k=2,
    d_expert=32)
