from repro.checkpoint.ckpt import (AsyncCheckpointer, CheckpointError,
                                   checkpoint_bytes, latest_checkpoint,
                                   load_checkpoint, load_checkpoint_arrays,
                                   read_manifest, save_checkpoint)

__all__ = ["save_checkpoint", "load_checkpoint", "load_checkpoint_arrays",
           "AsyncCheckpointer", "CheckpointError", "latest_checkpoint",
           "checkpoint_bytes", "read_manifest"]
