"""Sharded checkpointing: per-leaf compressed npy blobs + a manifest with
integrity hashes; an async background writer; elastic restore that re-shards
onto a *different* mesh (grow/shrink pods between runs).

The graph engine checkpoints at global-iteration boundaries (paper §5.3);
the trainer at step boundaries.  On real multi-host TPU each host writes its
addressable shards; on this container the host owns everything — the format
(one blob per leaf per shard-group + manifest) is the multi-host one.

Blobs are zstd-compressed when the optional ``zstandard`` package is
present, raw ``.npy`` bytes otherwise; the manifest records the codec so a
checkpoint written either way restores anywhere the codec is available.
Every structural problem — missing/torn manifest, leaf-count mismatch,
per-leaf name/shape/dtype disagreement with the restoring tree, blob hash
corruption — raises :class:`CheckpointError` (an ``IOError``), never a bare
``assert`` (which ``python -O`` strips) and never a silently transposed
restore.
"""

from __future__ import annotations

import hashlib
import io
import json
import os
import queue
import shutil
import threading
from typing import Any

import numpy as np

try:                               # optional: raw codec works without it
    import zstandard as zstd
except ImportError:                # pragma: no cover - env without zstandard
    zstd = None

import jax

from repro.obs import clock as obs_clock

Tree = Any

__all__ = ["CheckpointError", "save_checkpoint", "load_checkpoint",
           "load_checkpoint_arrays", "read_manifest", "AsyncCheckpointer",
           "latest_checkpoint", "checkpoint_bytes"]


class CheckpointError(IOError):
    """A checkpoint directory failed validation (torn write, corrupt blob,
    or a restore into a tree whose structure does not match the manifest)."""


def _default_codec() -> str:
    return "zstd" if zstd is not None else "raw"


def _encode(raw: bytes, codec: str) -> bytes:
    if codec == "zstd":
        if zstd is None:
            raise CheckpointError(
                "codec 'zstd' needs the optional 'zstandard' package "
                "(pip install zstandard, see requirements.txt)")
        return zstd.ZstdCompressor(level=3).compress(raw)
    if codec == "raw":
        return raw
    raise CheckpointError(f"unknown checkpoint codec {codec!r}")


def _decode(blob: bytes, codec: str) -> bytes:
    if codec == "zstd":
        if zstd is None:
            raise CheckpointError(
                "checkpoint was written with codec 'zstd'; restoring needs "
                "the optional 'zstandard' package")
        return zstd.ZstdDecompressor().decompress(blob)
    if codec == "raw":
        return blob
    raise CheckpointError(f"unknown checkpoint codec {codec!r}")


def _flatten(tree: Tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _leaf_path_names(tree: Tree) -> list[str]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    names = []
    for path, _ in flat:
        names.append("/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                              for k in path))
    return names


def read_manifest(path: str) -> dict:
    """Load + validate a checkpoint manifest; :class:`CheckpointError` on a
    missing or torn file."""
    mpath = os.path.join(path, "manifest.json")
    if not os.path.exists(mpath):
        raise CheckpointError(f"{path}: no manifest.json (torn write?)")
    try:
        with open(mpath) as f:
            manifest = json.load(f)
    except (json.JSONDecodeError, UnicodeDecodeError) as e:
        raise CheckpointError(f"{mpath}: corrupt or truncated json "
                              f"({e})") from None
    if not isinstance(manifest, dict) or "leaves" not in manifest:
        raise CheckpointError(f"{mpath}: not a checkpoint manifest")
    return manifest


def save_checkpoint(path: str, tree: Tree, step: int,
                    extra_meta: dict | None = None,
                    codec: str | None = None) -> None:
    codec = codec or _default_codec()
    os.makedirs(path, exist_ok=True)
    leaves, _ = _flatten(tree)
    names = _leaf_path_names(tree)
    ext = ".npy.zst" if codec == "zstd" else ".npy"
    manifest = {"step": int(step), "codec": codec, "leaves": [],
                "meta": extra_meta or {}}
    for i, (name, leaf) in enumerate(zip(names, leaves)):
        arr = np.asarray(leaf)
        buf = io.BytesIO()
        np.save(buf, arr, allow_pickle=False)
        blob = _encode(buf.getvalue(), codec)
        fn = f"leaf_{i:05d}{ext}"
        with open(os.path.join(path, fn), "wb") as f:
            f.write(blob)
        manifest["leaves"].append({
            "name": name, "file": fn, "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "sha256": hashlib.sha256(blob).hexdigest(),
        })
    tmp = os.path.join(path, "manifest.json.tmp")
    with open(tmp, "w") as f:
        json.dump(manifest, f, indent=1)
    os.replace(tmp, os.path.join(path, "manifest.json"))   # atomic commit


def _read_leaf(path: str, rec: dict, codec: str, verify: bool) -> np.ndarray:
    full = os.path.join(path, rec["file"])
    if not os.path.exists(full):
        raise CheckpointError(f"{full}: leaf blob missing")
    with open(full, "rb") as f:
        blob = f.read()
    if verify:
        h = hashlib.sha256(blob).hexdigest()
        if h != rec["sha256"]:
            raise CheckpointError(f"checkpoint corruption in {rec['file']}")
    arr = np.load(io.BytesIO(_decode(blob, codec)), allow_pickle=False)
    if list(arr.shape) != list(rec["shape"]) or str(arr.dtype) != rec["dtype"]:
        raise CheckpointError(
            f"{full}: decoded {arr.dtype}{arr.shape}, manifest says "
            f"{rec['dtype']}{tuple(rec['shape'])}")
    return arr


def load_checkpoint(path: str, tree_like: Tree, shardings: Tree | None = None,
                    verify: bool = True) -> tuple[Tree, int]:
    """Restore into the structure of ``tree_like``; if ``shardings`` given
    (possibly for a DIFFERENT mesh than the writer's), device_put re-shards —
    elastic scaling across restarts.

    Every leaf is validated against the manifest — path name, shape and
    dtype — so restoring into a mismatched tree (renamed field, transposed
    axes, wrong dtype) raises :class:`CheckpointError` instead of silently
    pouring bytes into the wrong slots.
    """
    manifest = read_manifest(path)
    codec = manifest.get("codec", "zstd")
    leaves, treedef = _flatten(tree_like)
    names = _leaf_path_names(tree_like)
    if len(leaves) != len(manifest["leaves"]):
        raise CheckpointError(
            f"{path}: checkpoint has {len(manifest['leaves'])} leaves, "
            f"restoring tree has {len(leaves)}")
    out = []
    for name, leaf, rec in zip(names, leaves, manifest["leaves"]):
        if rec["name"] != name:
            raise CheckpointError(
                f"{path}: leaf {rec['file']} is {rec['name']!r} in the "
                f"manifest but {name!r} in the restoring tree")
        shape = tuple(getattr(leaf, "shape", np.shape(leaf)))
        dtype = str(getattr(leaf, "dtype", np.asarray(leaf).dtype))
        if tuple(rec["shape"]) != shape or rec["dtype"] != dtype:
            raise CheckpointError(
                f"{path}: leaf {name!r} is {rec['dtype']}"
                f"{tuple(rec['shape'])} on disk but {dtype}{shape} in the "
                f"restoring tree")
        out.append(_read_leaf(path, rec, codec, verify))
    tree = jax.tree_util.tree_unflatten(treedef, out)
    if shardings is not None:
        tree = jax.tree.map(jax.device_put, tree, shardings)
    return tree, manifest["step"]


def load_checkpoint_arrays(path: str, verify: bool = True
                           ) -> tuple[dict[str, np.ndarray], dict]:
    """Raw restore: every leaf as ``{manifest name: np.ndarray}`` plus the
    manifest, with no target tree.  The elastic paths use this to re-shard a
    checkpoint written under a *different* partitioning, where no
    same-shaped ``tree_like`` exists."""
    manifest = read_manifest(path)
    codec = manifest.get("codec", "zstd")
    arrs = {rec["name"]: _read_leaf(path, rec, codec, verify)
            for rec in manifest["leaves"]}
    return arrs, manifest


def checkpoint_bytes(path: str) -> int:
    """Total on-disk bytes of one checkpoint directory (blobs + manifest) —
    the recovery path's 'bytes read' metric."""
    return sum(os.path.getsize(os.path.join(path, f))
               for f in os.listdir(path)
               if os.path.isfile(os.path.join(path, f)))


class AsyncCheckpointer:
    """Background writer: snapshot to host, write off-thread, never stall the
    step loop; keeps the last ``keep`` checkpoints.

    A failure in the background writer is surfaced on the *next* ``save()``
    or ``wait()`` call (the step loop must find out, not a daemon thread's
    stderr).  ``wait()`` blocks until every queued checkpoint is durable —
    the recovery path calls it before trusting ``latest_checkpoint``."""

    def __init__(self, base: str, keep: int = 3, codec: str | None = None):
        self.base = base
        self.keep = keep
        self.codec = codec or _default_codec()
        _encode(b"", self.codec)   # fail on the caller thread, not the worker
        self.q: queue.Queue = queue.Queue(maxsize=2)
        self._err: Exception | None = None
        self.bytes_written = 0
        self.save_seconds = 0.0    # snapshot time billed to the step loop
        self.t = threading.Thread(target=self._worker, daemon=True)
        self.t.start()

    def _worker(self):
        while True:
            item = self.q.get()
            try:
                if item is None:
                    return
                step, host_tree, meta = item
                path = os.path.join(self.base, f"step_{step:08d}")
                save_checkpoint(path, host_tree, step, meta,
                                codec=self.codec)
                self.bytes_written += checkpoint_bytes(path)
                self._gc()
            except Exception as e:       # surfaced on next save()/wait()
                self._err = e
            finally:
                self.q.task_done()       # wait() joins on this

    def _gc(self):
        if not os.path.isdir(self.base):
            return
        ckpts = sorted(d for d in os.listdir(self.base)
                       if d.startswith("step_"))
        for d in ckpts[:-self.keep]:
            shutil.rmtree(os.path.join(self.base, d), ignore_errors=True)

    def _raise_pending(self):
        if self._err:
            err, self._err = self._err, None
            raise err

    def save(self, step: int, tree: Tree, meta: dict | None = None):
        self._raise_pending()
        t0 = obs_clock.perf_counter()
        host = jax.tree.map(lambda x: np.asarray(x), tree)   # snapshot
        self.save_seconds += obs_clock.perf_counter() - t0
        self.q.put((int(step), host, meta))

    def wait(self):
        self.q.join()
        self._raise_pending()

    def close(self):
        self.q.put(None)
        self.t.join(timeout=30)


def latest_checkpoint(base: str) -> str | None:
    """Newest complete checkpoint under ``base`` — a directory whose
    ``manifest.json`` exists (the manifest is renamed into place *after*
    every blob, so its presence certifies the write committed; a torn
    directory is skipped, falling back to the previous step)."""
    if not os.path.isdir(base):
        return None
    ckpts = sorted(d for d in os.listdir(base) if d.startswith("step_")
                   and os.path.exists(os.path.join(base, d, "manifest.json")))
    return os.path.join(base, ckpts[-1]) if ckpts else None
