"""Sharded checkpointing: per-leaf zstd-compressed npy blobs + a manifest
with integrity hashes; an async background writer; elastic restore that
re-shards onto a *different* mesh (grow/shrink pods between runs).

The graph engine checkpoints at global-iteration boundaries (paper §5.3);
the trainer at step boundaries.  On real multi-host TPU each host writes its
addressable shards; on this container the host owns everything — the format
(one blob per leaf per shard-group + manifest) is the multi-host one.
"""

from __future__ import annotations

import dataclasses
import hashlib
import io
import json
import os
import queue
import threading
from typing import Any

import numpy as np

try:                               # optional: only save/load need it
    import zstandard as zstd
except ImportError:                # pragma: no cover - env without zstandard
    zstd = None

import jax

Tree = Any


def _require_zstd():
    if zstd is None:
        raise ImportError(
            "checkpointing requires the optional 'zstandard' package "
            "(pip install zstandard, see requirements-dev.txt)")


def _flatten(tree: Tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _leaf_path_names(tree: Tree) -> list[str]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    names = []
    for path, _ in flat:
        names.append("/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                              for k in path))
    return names


def save_checkpoint(path: str, tree: Tree, step: int,
                    extra_meta: dict | None = None) -> None:
    _require_zstd()
    os.makedirs(path, exist_ok=True)
    leaves, _ = _flatten(tree)
    names = _leaf_path_names(tree)
    manifest = {"step": int(step), "leaves": [], "meta": extra_meta or {}}
    cctx = zstd.ZstdCompressor(level=3)
    for i, (name, leaf) in enumerate(zip(names, leaves)):
        arr = np.asarray(leaf)
        buf = io.BytesIO()
        np.save(buf, arr, allow_pickle=False)
        blob = cctx.compress(buf.getvalue())
        fn = f"leaf_{i:05d}.npy.zst"
        with open(os.path.join(path, fn), "wb") as f:
            f.write(blob)
        manifest["leaves"].append({
            "name": name, "file": fn, "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "sha256": hashlib.sha256(blob).hexdigest(),
        })
    tmp = os.path.join(path, "manifest.json.tmp")
    with open(tmp, "w") as f:
        json.dump(manifest, f, indent=1)
    os.replace(tmp, os.path.join(path, "manifest.json"))   # atomic commit


def load_checkpoint(path: str, tree_like: Tree, shardings: Tree | None = None,
                    verify: bool = True) -> tuple[Tree, int]:
    """Restore into the structure of ``tree_like``; if ``shardings`` given
    (possibly for a DIFFERENT mesh than the writer's), device_put re-shards —
    elastic scaling across restarts."""
    _require_zstd()
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, treedef = _flatten(tree_like)
    assert len(leaves) == len(manifest["leaves"]), \
        f"checkpoint has {len(manifest['leaves'])} leaves, model {len(leaves)}"
    dctx = zstd.ZstdDecompressor()
    out = []
    for rec in manifest["leaves"]:
        with open(os.path.join(path, rec["file"]), "rb") as f:
            blob = f.read()
        if verify:
            h = hashlib.sha256(blob).hexdigest()
            if h != rec["sha256"]:
                raise IOError(f"checkpoint corruption in {rec['file']}")
        arr = np.load(io.BytesIO(dctx.decompress(blob)), allow_pickle=False)
        out.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, out)
    if shardings is not None:
        tree = jax.tree.map(jax.device_put, tree, shardings)
    return tree, manifest["step"]


class AsyncCheckpointer:
    """Background writer: snapshot to host, write off-thread, never stall the
    step loop; keeps the last ``keep`` checkpoints."""

    def __init__(self, base: str, keep: int = 3):
        _require_zstd()   # fail on the caller thread, not silently in the worker
        self.base = base
        self.keep = keep
        self.q: queue.Queue = queue.Queue(maxsize=2)
        self._err: Exception | None = None
        self.t = threading.Thread(target=self._worker, daemon=True)
        self.t.start()

    def _worker(self):
        while True:
            item = self.q.get()
            try:
                if item is None:
                    return
                step, host_tree, meta = item
                path = os.path.join(self.base, f"step_{step:08d}")
                save_checkpoint(path, host_tree, step, meta)
                self._gc()
            except Exception as e:       # surfaced on next save()
                self._err = e
            finally:
                self.q.task_done()       # wait() joins on this

    def _gc(self):
        if not os.path.isdir(self.base):
            return
        ckpts = sorted(d for d in os.listdir(self.base)
                       if d.startswith("step_"))
        for d in ckpts[:-self.keep]:
            import shutil
            shutil.rmtree(os.path.join(self.base, d), ignore_errors=True)

    def save(self, step: int, tree: Tree, meta: dict | None = None):
        if self._err:
            raise self._err
        host = jax.tree.map(lambda x: np.asarray(x), tree)   # snapshot
        self.q.put((int(step), host, meta))

    def wait(self):
        self.q.join()

    def close(self):
        self.q.put(None)
        self.t.join(timeout=30)


def latest_checkpoint(base: str) -> str | None:
    if not os.path.isdir(base):
        return None
    ckpts = sorted(d for d in os.listdir(base) if d.startswith("step_")
                   and os.path.exists(os.path.join(base, d, "manifest.json")))
    return os.path.join(base, ckpts[-1]) if ckpts else None
