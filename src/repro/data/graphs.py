"""Synthetic graph generators standing in for the paper's datasets (Table 1).

  * ``grid_graph``       — lattice road network: high diameter, like
                           USA-Road-NE/Full (the SSSP datasets),
  * ``rmat_graph``       — power-law/heavy-tail web graph, like Web-Google
                           and uk-2002 (the PageRank datasets),
  * ``bipartite_graph``  — random bipartite, like cit-patents in the BM role,
  * ``geometric_graph``  — random points connected by proximity, the
                           delaunay_n24 stand-in (planar-ish, BM/partitioning),
  * ``path_graph`` / ``cycle_graph`` — exactness fixtures.

All return ``(edges (E,2) int64, n_vertices)`` (+ weights where meaningful).
Deterministic under ``seed``.
"""

from __future__ import annotations

import numpy as np

__all__ = ["grid_graph", "rmat_graph", "bipartite_graph", "geometric_graph",
           "path_graph", "cycle_graph", "symmetrize", "ensure_no_dangling",
           "materialize"]


def materialize(path: str, kind: str, **params):
    """Stage a synthetic graph on disk as a binary edge directory that the
    ``repro.io`` out-of-core pipeline (and ``python -m repro.io.convert``)
    consumes — how benchmarks put a 10^7-edge R-MAT on disk once instead
    of re-synthesizing it per consumer.  ``kind`` is one of 'rmat' |
    'grid' | 'geometric' | 'bipartite' | 'path' | 'cycle'; ``params`` pass
    through to the generator (plus ``symmetrize=True``).  Returns the
    opened :class:`repro.io.StagedEdgeSource`."""
    from repro.io.stage import materialize as _materialize
    return _materialize(path, kind, **params)


def symmetrize(edges: np.ndarray) -> np.ndarray:
    """Both directions, deduplicated."""
    both = np.concatenate([edges, edges[:, ::-1]], axis=0)
    return np.unique(both, axis=0)


def ensure_no_dangling(edges: np.ndarray, n: int, seed: int = 0) -> np.ndarray:
    """Give every vertex out-degree >= 1 (Algorithm 5 does not redistribute
    dangling mass; the oracle matches this dynamics either way, but dangling-
    free graphs also let networkx.pagerank serve as a second oracle)."""
    rng = np.random.RandomState(seed)
    deg = np.bincount(edges[:, 0], minlength=n)
    dangling = np.nonzero(deg == 0)[0]
    if len(dangling) == 0:
        return edges
    tgt = rng.randint(0, n, size=len(dangling))
    tgt = np.where(tgt == dangling, (tgt + 1) % n, tgt)
    extra = np.stack([dangling, tgt], axis=1)
    return np.concatenate([edges, extra], axis=0)


def grid_graph(rows: int, cols: int, seed: int = 0,
               weighted: bool = True) -> tuple[np.ndarray, np.ndarray, int]:
    """4-neighbour lattice with bidirectional weighted edges (road network)."""
    rng = np.random.RandomState(seed)
    n = rows * cols
    vid = np.arange(n).reshape(rows, cols)
    e = []
    e.append(np.stack([vid[:, :-1].ravel(), vid[:, 1:].ravel()], axis=1))
    e.append(np.stack([vid[:-1, :].ravel(), vid[1:, :].ravel()], axis=1))
    edges = np.concatenate(e, axis=0)
    edges = np.concatenate([edges, edges[:, ::-1]], axis=0)
    w = (rng.uniform(1.0, 10.0, size=len(edges) // 2) if weighted
         else np.ones(len(edges) // 2))
    w = np.concatenate([w, w]).astype(np.float32)   # symmetric weights
    return edges.astype(np.int64), w, n


def rmat_graph(n: int, avg_degree: int = 8, seed: int = 0,
               a: float = 0.57, b: float = 0.19, c: float = 0.19
               ) -> tuple[np.ndarray, int]:
    """R-MAT power-law digraph (Web-Google / uk-2002 stand-in)."""
    rng = np.random.RandomState(seed)
    scale = int(np.ceil(np.log2(max(n, 2))))
    m = n * avg_degree
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    for _ in range(scale):
        r = rng.uniform(size=m)
        src = src * 2 + (r >= a + b).astype(np.int64)
        dst = dst * 2 + (((r >= a) & (r < a + b)) |
                         (r >= a + b + c)).astype(np.int64)
    keep = (src < n) & (dst < n) & (src != dst)
    edges = np.unique(np.stack([src[keep], dst[keep]], axis=1), axis=0)
    return edges, n


def bipartite_graph(n_left: int, n_right: int, avg_degree: int = 4,
                    seed: int = 0) -> tuple[np.ndarray, int, int]:
    """Random bipartite graph; lefts are ids [0, n_left), rights follow.
    Edges are returned in BOTH directions (the matching handshake needs
    right->left channels)."""
    rng = np.random.RandomState(seed)
    m = n_left * avg_degree
    l = rng.randint(0, n_left, size=m)
    r = rng.randint(0, n_right, size=m) + n_left
    edges = np.unique(np.stack([l, r], axis=1), axis=0)
    edges = np.concatenate([edges, edges[:, ::-1]], axis=0)
    return edges.astype(np.int64), n_left, n_left + n_right


def geometric_graph(n: int, radius: float | None = None, seed: int = 0
                    ) -> tuple[np.ndarray, int]:
    """Random geometric graph in the unit square (delaunay_n24 stand-in):
    planar-ish locality, low max degree — the structure partitioners love.

    Grid-bucketed neighbour search in O(n + E) *array* work: candidate
    pairs are materialized per cell-pair offset with run-expansion
    (``np.repeat`` over bucket counts), so there is no per-vertex Python
    loop and ~10⁶-vertex instances build in seconds."""
    rng = np.random.RandomState(seed)
    if radius is None:
        radius = np.sqrt(6.0 / (np.pi * n))   # ~6 expected neighbours
    pts = rng.uniform(size=(n, 2))
    nb = max(1, int(1.0 / radius))
    cell = np.minimum((pts / (1.0 / nb)).astype(np.int64), nb - 1)
    key = cell[:, 0] * nb + cell[:, 1]
    order = np.argsort(key, kind="stable")
    starts = np.searchsorted(key[order], np.arange(nb * nb + 1))
    ids = np.arange(n, dtype=np.int64)
    r2 = radius * radius
    out = []
    for dx in (-1, 0, 1):
        for dy in (-1, 0, 1):
            nc0 = cell[:, 0] + dx
            nc1 = cell[:, 1] + dy
            ok = (nc0 >= 0) & (nc0 < nb) & (nc1 >= 0) & (nc1 < nb)
            src0 = ids[ok]
            nk = nc0[ok] * nb + nc1[ok]
            cnt = starts[nk + 1] - starts[nk]
            nonempty = cnt > 0
            src0, nk, cnt = src0[nonempty], nk[nonempty], cnt[nonempty]
            if not len(src0):
                continue
            # expand each source against its neighbour bucket's run
            src = np.repeat(src0, cnt)
            within = np.arange(len(src)) - np.repeat(np.cumsum(cnt) - cnt, cnt)
            cand = order[np.repeat(starts[nk], cnt) + within]
            d2 = ((pts[cand] - pts[src]) ** 2).sum(axis=1)
            hit = (d2 < r2) & (cand != src)
            if hit.any():
                out.append(np.stack([src[hit], cand[hit]], axis=1))
    if not out:
        return np.zeros((0, 2), np.int64), n
    edges = np.unique(np.concatenate(out, axis=0), axis=0)
    return edges.astype(np.int64), n


def path_graph(n: int) -> tuple[np.ndarray, int]:
    e = np.stack([np.arange(n - 1), np.arange(1, n)], axis=1)
    return e.astype(np.int64), n


def cycle_graph(n: int) -> tuple[np.ndarray, int]:
    e = np.stack([np.arange(n), (np.arange(n) + 1) % n], axis=1)
    return e.astype(np.int64), n
