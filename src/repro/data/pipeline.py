"""Data pipeline: deterministic synthetic token streams (per-shard seeded,
restart-reproducible) and a file-backed tokenized dataset with sharded
sequential readers + host-side prefetch.

At dry-run scale each data-parallel rank draws only its own shard — the
pipeline is a pure function of (seed, step, shard), so checkpoint restart
and elastic re-sharding (different #ranks) replay identical global streams.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0


class SyntheticTokens:
    """Markov-ish synthetic stream: deterministic in (seed, step, shard)."""

    def __init__(self, cfg: DataConfig, n_shards: int = 1, shard: int = 0):
        self.cfg = cfg
        self.n_shards = n_shards
        self.shard = shard
        assert cfg.global_batch % n_shards == 0

    def batch(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        b = cfg.global_batch // self.n_shards
        rng = np.random.RandomState(
            (cfg.seed * 1_000_003 + step * 997 + self.shard) % 2**31)
        # structured stream (random walk over vocab) => learnable bigrams
        start = rng.randint(0, cfg.vocab, size=(b, 1))
        steps = rng.randint(-8, 9, size=(b, cfg.seq_len))
        toks = (np.cumsum(np.concatenate([start, steps[:, :-1]], axis=1),
                          axis=1) % cfg.vocab).astype(np.int32)
        labels = np.roll(toks, -1, axis=1)
        labels[:, -1] = toks[:, 0]
        return {"tokens": toks, "labels": labels.astype(np.int32)}

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


class FileDataset:
    """Flat .bin of int32 tokens; each shard reads a strided window."""

    def __init__(self, path: str, cfg: DataConfig, n_shards: int = 1,
                 shard: int = 0):
        self.tokens = np.memmap(path, dtype=np.int32, mode="r")
        self.cfg = cfg
        self.n_shards = n_shards
        self.shard = shard

    def batch(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        b = cfg.global_batch // self.n_shards
        span = cfg.seq_len + 1
        n_windows = len(self.tokens) // span
        idx = (step * cfg.global_batch + self.shard * b
               + np.arange(b)) % n_windows
        rows = np.stack([self.tokens[i * span:(i + 1) * span] for i in idx])
        return {"tokens": rows[:, :-1].astype(np.int32),
                "labels": rows[:, 1:].astype(np.int32)}


class Prefetcher:
    """Host-side background prefetch (overlap input with step compute)."""

    def __init__(self, source, depth: int = 2):
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()

        def worker():
            it = iter(source)
            while not self._stop.is_set():
                try:
                    self.q.put(next(it), timeout=0.5)
                except queue.Full:
                    continue

        self.t = threading.Thread(target=worker, daemon=True)
        self.t.start()

    def next(self, timeout: float = 30.0):
        return self.q.get(timeout=timeout)

    def close(self):
        self._stop.set()
