"""Training step construction: loss, microbatch gradient accumulation,
optimizer, metrics — the single-pod step that hybrid_sync vmaps per pod.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.registry import ModelAPI
from repro.optim.adamw import AdamWState, adamw_init, adamw_update, global_norm
from repro.optim.schedule import cosine_schedule

Params = Any


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: jax.Array | None = None) -> jax.Array:
    """Token-mean NLL in f32.  logits (B,S,V), labels (B,S).

    Sharding-friendly formulation: ``take_along_axis`` is a gather that
    stops GSPMD propagation (forcing full-logit replication — hundreds of
    GiB at 200k vocab); instead the label logit is extracted with an
    iota-compare reduction and normalization via logsumexp, both of which
    reduce over the (model-sharded) vocab axis with a psum.
    """
    from repro.sharding.util import maybe_constrain
    logits = maybe_constrain(logits.astype(jnp.float32),
                             "data", None, "model")
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    v = logits.shape[-1]
    onehot = (labels[..., None] ==
              jax.lax.broadcasted_iota(jnp.int32, (1, 1, v), 2))
    label_logit = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    nll = logz - label_logit
    if mask is not None:
        denom = jnp.maximum(jnp.sum(mask), 1.0)
        return jnp.sum(nll * mask) / denom
    return jnp.mean(nll)


def make_loss_fn(cfg: ArchConfig, api: ModelAPI) -> Callable:
    def loss_fn(params, batch):
        logits = api.forward(params, batch, cfg, remat=True)
        s = batch["labels"].shape[1]
        logits = logits[:, -s:]                  # vlm prepends patch tokens
        return cross_entropy(logits, batch["labels"], batch.get("mask"))
    return loss_fn


def make_train_step(cfg: ArchConfig, api: ModelAPI, *,
                    microbatches: int = 1,
                    peak_lr: float = 3e-4, warmup: int = 100,
                    total_steps: int = 10_000,
                    weight_decay: float = 0.1,
                    clip_norm: float = 1.0) -> Callable:
    """-> train_step(params, opt, batch, step) -> (params, opt, metrics).

    ``microbatches > 1`` accumulates gradients over a scan across leading
    batch splits (activation memory / global-batch decoupling).
    """
    loss_fn = make_loss_fn(cfg, api)
    vg = jax.value_and_grad(loss_fn)

    def grads_of(params, batch):
        if microbatches == 1:
            return vg(params, batch)
        micro = jax.tree.map(
            lambda x: x.reshape((microbatches, x.shape[0] // microbatches)
                                + x.shape[1:]), batch)

        def acc_step(carry, mb):
            loss_acc, g_acc = carry
            loss, g = vg(params, mb)
            g_acc = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32), g_acc, g)
            return (loss_acc + loss, g_acc), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss_sum, g_sum), _ = jax.lax.scan(acc_step, (0.0, g0), micro)
        inv = 1.0 / microbatches
        return loss_sum * inv, jax.tree.map(lambda g: g * inv, g_sum)

    def train_step(params, opt: AdamWState, batch, step):
        loss, grads = grads_of(params, batch)
        lr = cosine_schedule(step, warmup, total_steps, peak_lr)
        params, opt = adamw_update(params, grads, opt, lr,
                                   weight_decay=weight_decay,
                                   clip_norm=clip_norm)
        metrics = {"loss": loss, "grad_norm": global_norm(grads), "lr": lr}
        return params, opt, metrics

    return train_step
