"""Pure-jnp oracle for the fused PageRank pseudo-superstep."""

from __future__ import annotations

import jax.numpy as jnp


def fused_pr_step_ref(idx, val, msk, delta, send, rank, extra=None, *,
                      damping: float = 0.85, tol: float = 1e-4):
    if delta.ndim == 2:                     # (N, L) lane frontier
        val = val[..., None]
        msk = msk[..., None]
    contrib = jnp.where(send[idx], delta[idx], 0.0)
    contrib = jnp.where(msk, damping * val * contrib, 0.0)
    d_in = jnp.sum(contrib, axis=1)
    if extra is not None:
        d_in = d_in + extra
    return rank + d_in, d_in, d_in > tol
