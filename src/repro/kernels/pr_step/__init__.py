from repro.kernels.pr_step.ops import fused_pr_step
from repro.kernels.pr_step.ref import fused_pr_step_ref

__all__ = ["fused_pr_step", "fused_pr_step_ref"]
