"""Fused GraphHP pseudo-superstep for incremental PageRank (Pallas).

One local-phase pseudo-superstep of Algorithm 5 is, per partition:

    delta_in[r] = Σ_k  0.85 · w[r,k] · (send[s] ? delta[s] : 0),  s = idx[r,k]
    rank'       = rank + delta_in
    send'       = delta_in > Δ

The unfused engine path runs gather → segment-sum → add → compare as four HLO
ops with HBM round-trips between them; since the local phase iterates this
to convergence (the paper's whole point is that it iterates *a lot*), fusing
the chain into one VMEM-resident kernel removes three HBM round-trips per
pseudo-superstep.  Same blocking scheme as ell_spmv: grid (R/Bm, K/Bk),
(Bm, Bk) edge tiles, frontier vectors whole in VMEM, output accumulated
across the K grid axis with the epilogue on the final K step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import accumulate_k, ell_blocking


def _kernel(idx_ref, val_ref, msk_ref, delta_ref, send_ref, rank_ref,
            extra_ref, acc_ref, rank_out_ref, send_out_ref, *, damping: float,
            tol: float, n_kblocks: int):
    k = pl.program_id(1)

    idx = idx_ref[...]
    val = val_ref[...]
    msk = msk_ref[...]
    delta = delta_ref[...]                  # (N,) or (N, L) lane frontier
    send = send_ref[...]                    # matches delta's rank

    if delta.ndim == 2:                     # K-lane SpMM: edge tile broadcast
        val = val[..., None]                # over the trailing lane axis
        msk = msk[..., None]
    contrib = jnp.where(send[idx], delta[idx], 0.0)
    contrib = jnp.where(msk, damping * val * contrib, 0.0)
    partial = contrib[:, 0]
    for j in range(1, contrib.shape[1]):    # sequential slice-axis fold, as
        partial = partial + contrib[:, j]   # in ell_spmv: the order is the
    # same with or without a lane axis, so a lane column is bit-identical
    # to the single-frontier dispatch of that lane

    accumulate_k(acc_ref, partial, jnp.add)

    @pl.when(k == n_kblocks - 1)
    def _epilogue():
        # fold in the sliced-ELL spill bins' pre-combined contributions so
        # the returned delta_in covers every edge slot of the row
        d_in = acc_ref[...] + extra_ref[...]
        acc_ref[...] = d_in
        rank_out_ref[...] = rank_ref[...] + d_in
        send_out_ref[...] = d_in > tol


def fused_pr_step_pallas(idx, val, msk, delta, send, rank, extra, *,
                         damping: float = 0.85, tol: float = 1e-4,
                         block_rows: int = 256, block_slices: int = 128,
                         interpret: bool = True):
    """-> (rank', delta_in, send').  With an (N, L) lane frontier ``delta``
    (per-seed personalized PageRank), ``send``/``rank``/``extra`` carry the
    same trailing L axis and all three outputs are (R, L)."""
    r, kk = idx.shape
    bm, bk, nkb, grid = ell_blocking(r, kk, block_rows, block_slices)
    lanes = delta.shape[1:]                 # () SpMV or (L,) lane SpMM

    front_spec = pl.BlockSpec(delta.shape, lambda i, k: (0,) * delta.ndim)
    row_spec = pl.BlockSpec((bm,) + lanes,
                            (lambda i, k: (i, 0)) if lanes
                            else (lambda i, k: (i,)))

    acc, rank_out, send_out = pl.pallas_call(
        functools.partial(_kernel, damping=damping, tol=tol, n_kblocks=nkb),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, k: (i, k)),
            pl.BlockSpec((bm, bk), lambda i, k: (i, k)),
            pl.BlockSpec((bm, bk), lambda i, k: (i, k)),
            front_spec,
            front_spec,
            row_spec,
            row_spec,
        ],
        out_specs=[row_spec, row_spec, row_spec],
        out_shape=[
            jax.ShapeDtypeStruct((r,) + lanes, rank.dtype),
            jax.ShapeDtypeStruct((r,) + lanes, rank.dtype),
            jax.ShapeDtypeStruct((r,) + lanes, jnp.bool_),
        ],
        interpret=interpret,
    )(idx, val, msk, delta, send, rank, extra)
    return rank_out, acc, send_out
