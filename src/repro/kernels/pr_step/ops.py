"""Jitted wrapper for the fused PageRank pseudo-superstep kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.pr_step.pr_step import fused_pr_step_pallas


@functools.partial(jax.jit, static_argnames=("damping", "tol", "block_rows",
                                             "block_slices", "interpret"))
def fused_pr_step(idx, val, msk, delta, send, rank, extra=None, *,
                  damping: float = 0.85, tol: float = 1e-4,
                  block_rows: int = 256, block_slices: int = 128,
                  interpret: bool = True):
    """``extra`` carries the sliced-ELL spill bins' pre-combined per-row
    contributions (zeros / omitted when the layout has a single bin).  With
    an (N, L) lane frontier every operand and output carries the trailing L
    axis (K-lane SpMM dispatch)."""
    if extra is None:
        extra = jnp.zeros(idx.shape[:1] + delta.shape[1:], rank.dtype)
    return fused_pr_step_pallas(idx, val, msk, delta, send, rank, extra,
                                damping=damping, tol=tol,
                                block_rows=block_rows,
                                block_slices=block_slices,
                                interpret=interpret)
