"""Fused GraphHP pseudo-superstep for monotone-semiring programs (Pallas).

One local-phase pseudo-superstep of the monotone propagation family — SSSP's
relax loop (min,+), WCC's HashMin (min,+ over zeroed edges), bottleneck /
widest paths (max,min), odds or log-likelihood walks ((min,*) / (max,+)) —
is, per partition:

    d_in[r] = ⊕_k  send[s] ? x[s] ⊗ val[r,k] : identity(⊕),   s = idx[r,k]
    x'[r]   = x[r] ⊕ d_in[r]
    send'   = d_in improves x      (re-send only on strict improvement)

with (⊕, ⊗) any `kernels.common.MONOTONE_SEMIRINGS` entry — ⊕ ∈ {min, max}
is a selection, so the state update is a monotone adopt-if-better and the
whole family shares one kernel.  The unfused engine path runs gather →
segment-⊕ → ⊕ → compare as four HLO ops with HBM round-trips between them;
the local phase iterates this chain to per-partition convergence, so fusing
it into one VMEM-resident kernel removes three HBM round-trips per
pseudo-superstep — the monotone twin of `pr_step`.

``extra`` carries spill-bin contributions of the sliced-ELL layout (the
⊕-partials of the high-degree rows' overflow slots, pre-combined outside)
and is folded in during the epilogue, so degree-binned power-law graphs fuse
exactly like single-bin graphs.  Same blocking scheme as `ell_spmv`: grid
(R/Bm, K/Bk), (Bm, Bk) edge tiles, frontier vectors whole in VMEM, output
accumulated across the K grid axis with the epilogue on the final K step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import (MONOTONE_SEMIRINGS, SEMIRINGS, accumulate_k,
                                  ell_blocking, semiring_improves)


def _kernel(idx_ref, val_ref, msk_ref, x_ref, send_ref, xrow_ref, extra_ref,
            acc_ref, x_out_ref, send_out_ref, *, n_kblocks: int,
            semiring: str):
    combine, times, ident = SEMIRINGS[semiring]
    improves = semiring_improves(semiring)
    k = pl.program_id(1)

    idx = idx_ref[...]
    val = val_ref[...]
    msk = msk_ref[...]
    x = x_ref[...]                          # (N,) or (N, L) lane frontier
    send = send_ref[...]                    # matches x's rank (per-lane send)

    if x.ndim == 2:                         # K-lane SpMM: edge tile broadcast
        val = val[..., None]                # over the trailing lane axis
        msk = msk[..., None]
    cand = times(x[idx], val)
    cand = jnp.where(jnp.logical_and(msk, send[idx]),
                     cand, jnp.asarray(ident, cand.dtype))

    partial = cand[:, 0]
    for j in range(1, cand.shape[1]):       # slice-axis fold, as in ell_spmv
        partial = combine(partial, cand[:, j])

    accumulate_k(acc_ref, partial, combine)

    @pl.when(k == n_kblocks - 1)
    def _epilogue():
        d_in = combine(acc_ref[...], extra_ref[...])
        acc_ref[...] = d_in
        xr = xrow_ref[...]
        x_out_ref[...] = combine(xr, d_in)
        send_out_ref[...] = improves(d_in, xr)


def fused_min_step_pallas(idx, val, msk, x, send, xrow, extra, *,
                          semiring: str = "min_add",
                          block_rows: int = 256, block_slices: int = 128,
                          interpret: bool = True):
    """-> (x', d_in, send').  ``x`` is the (N,) frontier — or (N, L) for L
    independent query lanes, in which case ``send``/``xrow``/``extra`` carry
    the same trailing lane axis and all three outputs are (R, L).  ``xrow``
    is the per-row state the epilogue compares against (the same array when
    rows and frontier share the vertex slot space), ``extra`` a pre-combined
    spill contribution (the ⊕-identity where none)."""
    assert semiring in MONOTONE_SEMIRINGS, semiring
    r, kk = idx.shape
    bm, bk, nkb, grid = ell_blocking(r, kk, block_rows, block_slices)
    lanes = x.shape[1:]                     # () SpMV or (L,) lane SpMM

    front_spec = pl.BlockSpec(x.shape, lambda i, k: (0,) * x.ndim)
    row_spec = pl.BlockSpec((bm,) + lanes,
                            (lambda i, k: (i, 0)) if lanes
                            else (lambda i, k: (i,)))

    acc, x_out, send_out = pl.pallas_call(
        functools.partial(_kernel, n_kblocks=nkb, semiring=semiring),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, k: (i, k)),
            pl.BlockSpec((bm, bk), lambda i, k: (i, k)),
            pl.BlockSpec((bm, bk), lambda i, k: (i, k)),
            front_spec,
            front_spec,
            row_spec,
            row_spec,
        ],
        out_specs=[row_spec, row_spec, row_spec],
        out_shape=[
            jax.ShapeDtypeStruct((r,) + lanes, x.dtype),
            jax.ShapeDtypeStruct((r,) + lanes, x.dtype),
            jax.ShapeDtypeStruct((r,) + lanes, jnp.bool_),
        ],
        interpret=interpret,
    )(idx, val, msk, x, send, xrow, extra)
    return x_out, acc, send_out
