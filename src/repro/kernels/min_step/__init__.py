from repro.kernels.min_step.ops import fused_min_step
from repro.kernels.min_step.ref import fused_min_step_ref

__all__ = ["fused_min_step", "fused_min_step_ref"]
