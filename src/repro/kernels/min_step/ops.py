"""Jitted wrapper for the fused monotone-semiring pseudo-superstep kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import SEMIRINGS
from repro.kernels.min_step.min_step import fused_min_step_pallas


@functools.partial(jax.jit, static_argnames=("semiring", "block_rows",
                                             "block_slices", "interpret"))
def fused_min_step(idx, val, msk, x, send, xrow=None, extra=None, *,
                   semiring: str = "min_add",
                   block_rows: int = 256, block_slices: int = 128,
                   interpret: bool = True):
    """Jitted fused monotone pseudo-superstep -> (x', d_in, send').

    ``semiring`` is any ``MONOTONE_SEMIRINGS`` entry (default the historic
    'min_add'); ``xrow`` defaults to ``x`` (rows and frontier share the
    vertex slot space, the engine case); ``extra`` defaults to the
    ⊕-identity (no spill bins).  With an (N, L) lane frontier every operand
    and output carries the trailing L axis (K-lane SpMM dispatch).
    """
    if xrow is None:
        xrow = x
    if extra is None:
        _, _, ident = SEMIRINGS[semiring]
        extra = jnp.full(idx.shape[:1] + x.shape[1:], ident, x.dtype)
    return fused_min_step_pallas(idx, val, msk, x, send, xrow, extra,
                                 semiring=semiring, block_rows=block_rows,
                                 block_slices=block_slices,
                                 interpret=interpret)
