"""Pure-jnp oracle for the fused monotone-semiring pseudo-superstep."""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.common import SEMIRINGS, semiring_improves


def fused_min_step_ref(idx, val, msk, x, send, xrow=None, extra=None, *,
                       semiring: str = "min_add"):
    combine, times, ident = SEMIRINGS[semiring]
    improves = semiring_improves(semiring)
    if xrow is None:
        xrow = x
    if x.ndim == 2:                         # (N, L) lane frontier
        val = val[..., None]
        msk = msk[..., None]
    cand = jnp.where(jnp.logical_and(msk, send[idx]), times(x[idx], val),
                     jnp.asarray(ident, x.dtype))
    d_in = (jnp.min if semiring.startswith("min") else jnp.max)(cand, axis=1)
    if extra is not None:
        d_in = combine(d_in, extra)
    return combine(xrow, d_in), d_in, improves(d_in, xrow)
