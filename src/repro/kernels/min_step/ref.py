"""Pure-jnp oracle for the fused min-semiring pseudo-superstep."""

from __future__ import annotations

import jax.numpy as jnp


def fused_min_step_ref(idx, val, msk, x, send, xrow=None, extra=None):
    if xrow is None:
        xrow = x
    cand = jnp.where(jnp.logical_and(msk, send[idx]), x[idx] + val, jnp.inf)
    d_in = jnp.min(cand, axis=1)
    if extra is not None:
        d_in = jnp.minimum(d_in, extra)
    return jnp.minimum(xrow, d_in), d_in, d_in < xrow
