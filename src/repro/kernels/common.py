"""Shared ELL kernel plumbing: blocking/grid computation, the
accumulate-across-K output pattern, backend-dependent interpret default, and
the vectorized destination-major ELL packer.

Both `ell_spmv` and `pr_step` tile a (R, K) edge array with grid
(R/Bm, K/Bk) and revisit the same (Bm,) output block along the K grid axis,
initializing on the first K step and combining on the rest — the standard TPU
revisiting-output-block accumulation.  That boilerplate lives here once.
"""

from __future__ import annotations

import numpy as np

import jax
from jax.experimental import pallas as pl

__all__ = ["ell_blocking", "accumulate_k", "default_interpret",
           "ell_pack_numpy"]


def ell_blocking(r: int, kk: int, block_rows: int, block_slices: int):
    """Clamp the requested block shape to the array and derive the grid.

    Returns (bm, bk, n_kblocks, grid) for a (R, K) ELL tile iterated as
    grid = (R/Bm, K/Bk).
    """
    bm = min(block_rows, r)
    bk = min(block_slices, kk)
    nkb = pl.cdiv(kk, bk)
    return bm, bk, nkb, (pl.cdiv(r, bm), nkb)


def accumulate_k(acc_ref, partial, combine):
    """Accumulate ``partial`` into ``acc_ref`` across the K grid axis
    (axis 1): initialize on the first K step, combine on subsequent ones."""
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = partial

    @pl.when(k > 0)
    def _acc():
        acc_ref[...] = combine(acc_ref[...], partial)


def default_interpret() -> bool:
    """Pallas kernels run the Mosaic lowering on TPU and interpret mode
    everywhere else (this CPU container)."""
    return jax.default_backend() != "tpu"


def ell_pack_numpy(src: np.ndarray, dst: np.ndarray, w: np.ndarray,
                   n_rows: int, k_slices: int):
    """Vectorized destination-major ELL pack (host-side, numpy).

    Slot k of row d holds the k-th edge of destination d in stable
    dst-sorted input order — identical layout to a per-edge scatter loop,
    but O(E) vectorized: after the stable sort by destination the slot of
    each edge is its rank within its destination run (arange minus the run's
    first index via searchsorted on the sorted keys).

    Returns (idx (n_rows, k_slices) int32, val float32, msk bool).
    """
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    w = np.asarray(w, dtype=np.float32)
    idx = np.zeros((n_rows, k_slices), dtype=np.int32)
    val = np.zeros((n_rows, k_slices), dtype=np.float32)
    msk = np.zeros((n_rows, k_slices), dtype=bool)
    if len(dst) == 0:
        return idx, val, msk
    order = np.argsort(dst, kind="stable")
    src_s, dst_s, w_s = src[order], dst[order], w[order]
    slot = np.arange(len(dst_s)) - np.searchsorted(dst_s, dst_s, side="left")
    idx[dst_s, slot] = src_s
    val[dst_s, slot] = w_s
    msk[dst_s, slot] = True
    return idx, val, msk
