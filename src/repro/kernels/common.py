"""Shared ELL kernel plumbing: the semiring table, blocking/grid computation,
the accumulate-across-K output pattern, backend-dependent interpret default,
and the vectorized destination-major ELL packer.

Both `ell_spmv` and the fused step kernels tile a (R, K) edge array with grid
(R/Bm, K/Bk) and revisit the same (Bm,) output block along the K grid axis,
initializing on the first K step and combining on the rest — the standard TPU
revisiting-output-block accumulation.  That boilerplate lives here once, as
does the (⊕, ⊗, identity) table every kernel and dispatch site shares.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["SEMIRINGS", "MONOTONE_SEMIRINGS", "semiring_improves",
           "ell_blocking", "accumulate_k", "default_interpret",
           "ell_pack_numpy", "ell_bin_widths", "sliced_ell_pack_numpy"]


#: Semiring table: ``name -> (⊕ combine, ⊗ times, ⊕-identity)``.
#:
#: Every kernel (``ell_spmv``, fused ``pr_step``/``min_step``), the engine
#: dispatch in ``runtime.deliver``, and the reference oracles are generic
#: over this table.  The entries:
#:
#: - ``add_mul``  (+, ×, 0)        — PageRank mass propagation
#: - ``min_add``  (min, +, +inf)   — shortest paths / HashMin WCC
#: - ``max_add``  (max, +, -inf)   — best-score / log-likelihood paths
#: - ``min_mul``  (min, ×, +inf)   — odds propagation
#: - ``max_min``  (max, min, -inf) — bottleneck / widest-path capacity
#:
#: ``⊕`` folds edge products per destination row, ``⊗`` combines a source
#: value with an edge weight, and the identity fills masked ELL slots so
#: padding never contributes.  Adding an entry here is all a new semiring
#: needs (plus a `_SCATTER` rule in runtime for its spill bins).  A
#: ``Channel(semiring=...)`` naming an entry opts that channel into the
#: kernel delivery path; monotone entries (see ``MONOTONE_SEMIRINGS``)
#: additionally unlock the fused ``min_step`` local phase.
SEMIRINGS = {
    "add_mul": (jnp.add, jnp.multiply, 0.0),
    "min_add": (jnp.minimum, jnp.add, jnp.inf),
    "max_add": (jnp.maximum, jnp.add, -jnp.inf),
    "min_mul": (jnp.minimum, jnp.multiply, jnp.inf),
    "max_min": (jnp.maximum, jnp.minimum, -jnp.inf),
}

# Semirings whose ⊕ is a selection (min/max) rather than an accumulation:
# vertex state under these evolves monotonically (new = x ⊕ d_in, re-send on
# strict improvement), which is exactly the contract the fused `min_step`
# pseudo-superstep kernel generalizes over.
MONOTONE_SEMIRINGS = frozenset({"min_add", "min_mul", "max_add", "max_min"})


def semiring_improves(semiring: str):
    """Strict-improvement predicate of a monotone semiring: did ``new``
    beat ``old`` under ⊕?  (< for the min family, > for the max family.)"""
    if semiring not in MONOTONE_SEMIRINGS:  # pragma: no cover
        raise ValueError(f"{semiring} has no improvement direction")
    return jnp.less if semiring.startswith("min") else jnp.greater


def ell_blocking(r: int, kk: int, block_rows: int, block_slices: int):
    """Clamp the requested block shape to the array and derive the grid.

    Returns (bm, bk, n_kblocks, grid) for a (R, K) ELL tile iterated as
    grid = (R/Bm, K/Bk).
    """
    bm = min(block_rows, r)
    bk = min(block_slices, kk)
    nkb = pl.cdiv(kk, bk)
    return bm, bk, nkb, (pl.cdiv(r, bm), nkb)


def accumulate_k(acc_ref, partial, combine):
    """Accumulate ``partial`` into ``acc_ref`` across the K grid axis
    (axis 1): initialize on the first K step, combine on subsequent ones."""
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = partial

    @pl.when(k > 0)
    def _acc():
        acc_ref[...] = combine(acc_ref[...], partial)


def default_interpret() -> bool:
    """Pallas kernels run the Mosaic lowering on TPU and interpret mode
    everywhere else (this CPU container)."""
    return jax.default_backend() != "tpu"


def ell_pack_numpy(src: np.ndarray, dst: np.ndarray, w: np.ndarray,
                   n_rows: int, k_slices: int):
    """Vectorized destination-major ELL pack (host-side, numpy).

    Slot k of row d holds the k-th edge of destination d in stable
    dst-sorted input order — identical layout to a per-edge scatter loop,
    but O(E) vectorized: after the stable sort by destination the slot of
    each edge is its rank within its destination run (arange minus the run's
    first index via searchsorted on the sorted keys).

    Returns (idx (n_rows, k_slices) int32, val float32, msk bool).
    """
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    w = np.asarray(w, dtype=np.float32)
    idx = np.zeros((n_rows, k_slices), dtype=np.int32)
    val = np.zeros((n_rows, k_slices), dtype=np.float32)
    msk = np.zeros((n_rows, k_slices), dtype=bool)
    if len(dst) == 0:
        return idx, val, msk
    order = np.argsort(dst, kind="stable")
    src_s, dst_s, w_s = src[order], dst[order], w[order]
    slot = np.arange(len(dst_s)) - np.searchsorted(dst_s, dst_s, side="left")
    idx[dst_s, slot] = src_s
    val[dst_s, slot] = w_s
    msk[dst_s, slot] = True
    return idx, val, msk


def ell_bin_widths(kmax: int, base_slices: int, pad: int,
                   growth: int = 8, max_bins: int = 3) -> list[tuple[int, int]]:
    """Slot ranges ``(lo, kb)`` of the sliced-ELL degree bins for a row set
    whose maximum in-degree is ``kmax``.

    Bin 0 holds slots [0, K0) of *every* row (dense, no row indirection);
    spill bins hold the overflow slots of the high-degree rows only.  When
    the padded max degree fits ``base_slices`` this degenerates to the
    single dense bin of the unbinned layout; otherwise spill widths grow
    geometrically so at most ``max_bins`` bins cover any skew (the last bin
    is unbounded — its row count is tiny by construction).
    """
    if kmax <= 0:
        return []
    rup = lambda n: ((n + pad - 1) // pad) * pad if n > 0 else pad
    base = rup(base_slices)
    if rup(kmax) <= base:
        return [(0, rup(kmax))]
    bins = [(0, base)]
    lo = base
    while kmax > lo:
        kb = rup(kmax - lo)
        if len(bins) < max_bins - 1:
            kb = min(kb, rup(base * growth ** len(bins)))
        bins.append((lo, kb))
        lo += kb
    return bins


def sliced_ell_pack_numpy(src: np.ndarray, dst: np.ndarray, w: np.ndarray,
                          n_rows: int, widths: list[tuple[int, int]],
                          order_rank: tuple[np.ndarray, np.ndarray] | None
                          = None,
                          extras: tuple[np.ndarray, ...] = ()):
    """Pack a destination-major edge set into sliced-ELL degree bins.

    ``widths`` comes from :func:`ell_bin_widths`: bin b owns each row's edge
    slots [lo_b, lo_b + kb_b) in stable dst-sorted order.  Bin 0 (lo == 0)
    is packed dense over all ``n_rows``; spill bins carry only the rows
    whose degree exceeds their ``lo``, as a (rows, idx, val, msk) quadruple
    where ``rows`` lists the destination row ids in ascending order.

    ``order_rank`` optionally supplies the stable dst argsort and the
    per-edge rank within its destination run, when the caller has already
    computed them over the same edge set.

    ``extras`` are additional per-edge int payloads (e.g. accounting group
    ids) packed into the same slots, zero on padding; each appends one
    (nb, kb) int32 array to every bin's tuple.

    Returns ``[(rows (nb,) int32, idx (nb, kb) int32, val f32, msk bool,
    *extras)]`` per bin (``rows`` is None for the dense base bin).
    """
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    w = np.asarray(w, dtype=np.float32)
    if order_rank is None:
        order = np.argsort(dst, kind="stable")
        rank = None
    else:
        order, rank = order_rank
    src_s, dst_s, w_s = src[order], dst[order], w[order]
    extras_s = tuple(np.asarray(e, dtype=np.int64)[order] for e in extras)
    if rank is None:
        rank = (np.arange(len(dst_s))
                - np.searchsorted(dst_s, dst_s, side="left"))
    degree = np.zeros(n_rows, dtype=np.int64)
    if len(dst_s):
        np.add.at(degree, dst_s, 1)

    out = []
    for lo, kb in widths:
        sel = (rank >= lo) & (rank < lo + kb)
        if lo == 0:
            rows = None
            nb = n_rows
            r = dst_s[sel]
        else:
            rows = np.nonzero(degree > lo)[0].astype(np.int32)
            row_of = np.zeros(n_rows, dtype=np.int64)
            row_of[rows] = np.arange(len(rows))
            nb = len(rows)
            r = row_of[dst_s[sel]]
        idx = np.zeros((nb, kb), dtype=np.int32)
        val = np.zeros((nb, kb), dtype=np.float32)
        msk = np.zeros((nb, kb), dtype=bool)
        ext = tuple(np.zeros((nb, kb), dtype=np.int32) for _ in extras_s)
        s = rank[sel] - lo
        idx[r, s] = src_s[sel]
        val[r, s] = w_s[sel]
        msk[r, s] = True
        for packed, e in zip(ext, extras_s):
            packed[r, s] = e[sel]
        out.append((rows, idx, val, msk) + ext)
    return out
