"""Pure-jnp oracle for the sliced-ELL semiring SpMV."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ell_spmv.ell_spmv import SEMIRINGS


def ell_spmv_ref(idx, val, msk, x, *, semiring: str = "add_mul") -> jax.Array:
    combine, times, ident = SEMIRINGS[semiring]
    if x.ndim == 2:                         # (N, L) lane frontier -> (R, L)
        val = val[..., None]
        msk = msk[..., None]
    prod = times(val, x[idx])
    prod = jnp.where(msk, prod, jnp.asarray(ident, prod.dtype))
    if semiring == "add_mul":
        return jnp.sum(prod, axis=1)
    if semiring in ("min_add", "min_mul"):
        return jnp.min(prod, axis=1)
    if semiring in ("max_add", "max_min"):
        return jnp.max(prod, axis=1)
    raise ValueError(semiring)
