from repro.kernels.ell_spmv.ops import ell_spmv, to_ell
from repro.kernels.ell_spmv.ref import ell_spmv_ref

__all__ = ["ell_spmv", "to_ell", "ell_spmv_ref"]
