"""Semiring SpMV Pallas kernel in sliced-ELL layout — the GraphHP local-phase
hot loop, adapted for TPU.

The paper's pseudo-superstep iterates "gather messages along in-edges, combine
per destination" over a partition's adjacency lists.  A CPU worker chases
pointers; a TPU needs a dense, VMEM-tileable layout, so the in-edges of a
partition are packed as ELL slices:

    idx  (R, K) int32   source slot of the k-th in-edge of row r
    val  (R, K) f32     edge weight
    msk  (R, K) bool    slot occupancy

and one pseudo-superstep's combine is a blocked reduce

    y[r] = ⊕_k  msk[r,k] ? (val[r,k] ⊗ x[idx[r,k]]) : identity(⊕)

over semirings (⊕, ⊗) ∈ {(+,*) PageRank, (min,+) SSSP, (max,+) best-score
paths, (min,*) odds propagation, (max,min) bottleneck capacity} — the shared
table in `kernels.common.SEMIRINGS`.

The frontier ``x`` is either a vector (N,) — the classic SpMV — or a stacked
frontier *matrix* (N, L) of L independent query lanes (multi-source SSSP,
landmark tables, per-seed personalized PageRank), in which case the same
gather indices serve every lane and the product/reduce broadcast over the
trailing lane axis: one dispatch computes a semiring SpMM, y (R, L).  The
1-D path is untouched — lane handling is a static rank check, so single-lane
callers compile the exact original kernel.

Blocking: grid = (R/Bm, K/Bk); each step loads a (Bm, Bk) tile of idx/val/msk
into VMEM plus the whole source vector x (a graph partition's frontier fits
VMEM comfortably: 64k fp32 slots = 256 KiB), gathers, reduces over the slice
axis and accumulates into the (Bm,) output block across the K-grid dimension —
the standard TPU revisiting-output-block accumulation pattern.  Row blocks are
multiples of 8 and slice blocks multiples of 128 so tiles are VPU
lane/sublane aligned.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import SEMIRINGS, accumulate_k, ell_blocking


def _kernel(idx_ref, val_ref, msk_ref, x_ref, y_ref, *, semiring: str):
    combine, times, ident = SEMIRINGS[semiring]

    idx = idx_ref[...]                      # (Bm, Bk) int32
    val = val_ref[...]                      # (Bm, Bk)
    msk = msk_ref[...]                      # (Bm, Bk)
    x = x_ref[...]                          # (N,) or (N, L) — whole frontier

    if x.ndim == 2:                         # K-lane SpMM: broadcast the edge
        val = val[..., None]                # tile over the trailing lane axis
        msk = msk[..., None]
    gathered = x[idx]                       # (Bm, Bk) or (Bm, Bk, L)
    prod = times(val, gathered)
    prod = jnp.where(msk, prod, jnp.asarray(ident, prod.dtype))

    partial = prod[:, 0]
    for j in range(1, prod.shape[1]):       # slice-axis tree would also do;
        partial = combine(partial, prod[:, j])   # XLA re-associates on VPU

    accumulate_k(y_ref, partial, combine)


def ell_spmv_pallas(
    idx: jax.Array,
    val: jax.Array,
    msk: jax.Array,
    x: jax.Array,
    *,
    semiring: str = "add_mul",
    block_rows: int = 256,
    block_slices: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """y = ⊕_k val ⊗ x[idx] per row.  Returns (R,) for an (N,) frontier and
    (R, L) for a stacked (N, L) lane frontier, in x.dtype."""
    r, kk = idx.shape
    bm, bk, _, grid = ell_blocking(r, kk, block_rows, block_slices)
    lanes = x.shape[1:]                     # () SpMV or (L,) SpMM

    return pl.pallas_call(
        functools.partial(_kernel, semiring=semiring),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, k: (i, k)),
            pl.BlockSpec((bm, bk), lambda i, k: (i, k)),
            pl.BlockSpec((bm, bk), lambda i, k: (i, k)),
            pl.BlockSpec(x.shape, lambda i, k: (0,) * x.ndim),
        ],
        out_specs=pl.BlockSpec((bm,) + lanes,
                               (lambda i, k: (i, 0)) if lanes
                               else (lambda i, k: (i,))),
        out_shape=jax.ShapeDtypeStruct((r,) + lanes, x.dtype),
        interpret=interpret,
    )(idx, val, msk, x)
