"""Jitted wrapper + layout conversion for the ELL semiring SpMV kernel."""

from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels.common import ell_pack_numpy
from repro.kernels.ell_spmv.ell_spmv import ell_spmv_pallas


@functools.partial(jax.jit, static_argnames=("semiring", "block_rows",
                                             "block_slices", "interpret"))
def ell_spmv(idx, val, msk, x, *, semiring: str = "add_mul",
             block_rows: int = 256, block_slices: int = 128,
             interpret: bool = True) -> jax.Array:
    """Jitted semiring SpMV/SpMM: y[r] = ⊕_k val[r,k] ⊗ x[idx[r,k]].

    ``x`` is an (N,) frontier vector (SpMV, returns (R,)) or an (N, L)
    stacked frontier of L query lanes (semiring SpMM, returns (R, L) — one
    dispatch answers L simultaneous sources over the same edge tiles).

    ``interpret=True`` executes the Pallas kernel body on CPU (this
    container); on a TPU runtime pass ``interpret=False`` to lower to Mosaic.
    """
    return ell_spmv_pallas(idx, val, msk, x, semiring=semiring,
                           block_rows=block_rows, block_slices=block_slices,
                           interpret=interpret)


def to_ell(edges: np.ndarray, n_rows: int,
           weights: np.ndarray | None = None,
           pad_rows: int = 8, pad_slices: int = 128):
    """Pack a COO edge list (src, dst) into destination-major ELL arrays.

    Returns (idx (R,K) int32, val (R,K) f32, msk (R,K) bool) with
    R = n_rows rounded up to ``pad_rows`` and K = max in-degree rounded up to
    ``pad_slices`` (TPU lane alignment).
    """
    edges = np.asarray(edges)
    if weights is None:
        weights = np.ones(len(edges), dtype=np.float32)
    indeg = np.bincount(edges[:, 1], minlength=n_rows)
    kmax = int(indeg.max()) if len(indeg) else 1
    K = max(pad_slices, ((kmax + pad_slices - 1) // pad_slices) * pad_slices)
    R = ((n_rows + pad_rows - 1) // pad_rows) * pad_rows
    idx, val, msk = ell_pack_numpy(edges[:, 0], edges[:, 1], weights, R, K)
    return jnp.asarray(idx), jnp.asarray(val), jnp.asarray(msk)
