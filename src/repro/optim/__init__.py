from repro.optim.adamw import AdamWState, adamw_init, adamw_update, global_norm
from repro.optim.schedule import cosine_schedule, linear_warmup
from repro.optim.compression import (ErrorFeedbackState, ef_init,
                                     ef_int8_compress, ef_int8_decompress)

__all__ = ["AdamWState", "adamw_init", "adamw_update", "global_norm",
           "cosine_schedule", "linear_warmup", "ErrorFeedbackState",
           "ef_init", "ef_int8_compress", "ef_int8_decompress"]
