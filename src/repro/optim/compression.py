"""Error-feedback int8 gradient/delta compression for the cross-pod phase.

The hybrid-sync global phase (GraphHP's once-per-iteration exchange lifted to
training, DESIGN.md §6) all-reduces an accumulated parameter delta across
pods.  Before the wire, deltas are quantized to int8 with a per-tensor scale;
the quantization error is fed back into the next round's accumulator — the
``Combine()``-before-RPC idea applied to gradients.  4× fewer cross-pod bytes
with no asymptotic convergence penalty (error feedback keeps the sum of
applied updates unbiased up to O(1/H) terms).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Params = Any


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ErrorFeedbackState:
    residual: Params


def ef_init(params: Params) -> ErrorFeedbackState:
    return ErrorFeedbackState(
        residual=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params))


def ef_int8_compress(tree: Params, ef: ErrorFeedbackState
                     ) -> tuple[Params, Params, ErrorFeedbackState]:
    """-> (q_int8, scales, new_ef).  Quantizes (tree + residual)."""
    def comp(x, r):
        xf = x.astype(jnp.float32) + r
        scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
        err = xf - q.astype(jnp.float32) * scale
        return q, scale, err

    out = jax.tree.map(comp, tree, ef.residual)
    is_t = lambda t: isinstance(t, tuple)
    q = jax.tree.map(lambda t: t[0], out, is_leaf=is_t)
    s = jax.tree.map(lambda t: t[1], out, is_leaf=is_t)
    err = jax.tree.map(lambda t: t[2], out, is_leaf=is_t)
    return q, s, ErrorFeedbackState(residual=err)


def ef_int8_decompress(q: Params, scales: Params, dtype=jnp.float32) -> Params:
    return jax.tree.map(
        lambda qq, ss: (qq.astype(jnp.float32) * ss).astype(dtype), q, scales)
