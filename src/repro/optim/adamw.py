"""AdamW from scratch, with dtype-configurable moments.

At jamba-398B scale, f32 m/v/master (12 bytes/param) does not fit a v5e pod;
``moment_dtype=bfloat16`` drops step state to 4 bytes/param (DESIGN.md §5),
the bf16 params acting as master weights.  Decoupled weight decay, bias
correction, global-norm clipping.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Params = Any


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AdamWState:
    mu: Params
    nu: Params
    step: jax.Array


def adamw_init(params: Params, moment_dtype=jnp.float32) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
    return AdamWState(
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
        step=jnp.zeros((), jnp.int32),
    )


def global_norm(tree: Params) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(
    params: Params,
    grads: Params,
    state: AdamWState,
    lr: jax.Array | float,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    clip_norm: float = 1.0,
) -> tuple[Params, AdamWState]:
    step = state.step + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gn, 1e-12))

    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g)
        mh = m_new / c1
        vh = v_new / c2
        delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return (p_new.astype(p.dtype), m_new.astype(m.dtype),
                v_new.astype(v.dtype))

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    p_new = jax.tree.map(lambda t: t[0], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    mu = jax.tree.map(lambda t: t[1], out,
                      is_leaf=lambda t: isinstance(t, tuple))
    nu = jax.tree.map(lambda t: t[2], out,
                      is_leaf=lambda t: isinstance(t, tuple))
    return p_new, AdamWState(mu=mu, nu=nu, step=step)
