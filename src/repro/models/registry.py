"""Model registry: dispatch an ArchConfig to its functional model API, plus
parameter counting (total & active) used by the roofline analysis."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer as tf


class ModelAPI(NamedTuple):
    init: Callable          # (key, cfg, dtype) -> params
    forward: Callable       # (params, batch, cfg, remat=) -> logits
    init_cache: Callable    # (cfg, batch_size, max_len, dtype) -> cache
    prefill: Callable       # (params, batch, cache, cfg) -> (logits, cache)
    decode_step: Callable | None  # (params, token, cache, cur_len, cfg, decode_axis=)


def get_model(cfg: ArchConfig) -> ModelAPI:
    if cfg.family == "audio":
        return ModelAPI(tf.encdec_init, tf.encdec_forward,
                        tf.encdec_cache_init, tf.encdec_prefill,
                        tf.encdec_decode_step)
    if cfg.family == "vlm":
        return ModelAPI(tf.vlm_init, tf.vlm_forward, tf.lm_cache_init,
                        tf.vlm_prefill, tf.lm_decode_step)
    return ModelAPI(tf.lm_init, tf.lm_forward, tf.lm_cache_init,
                    tf.lm_prefill, tf.lm_decode_step)


def param_shapes(cfg: ArchConfig, dtype=jnp.bfloat16):
    api = get_model(cfg)
    return jax.eval_shape(
        lambda k: api.init(k, cfg, dtype),
        jax.ShapeDtypeStruct((2,), jnp.uint32))


def count_params(cfg: ArchConfig, active_only: bool = False) -> int:
    shapes = param_shapes(cfg)
    total = 0
    expert = 0
    leaves = jax.tree_util.tree_leaves_with_path(shapes)
    for path, leaf in leaves:
        n = 1
        for s in leaf.shape:
            n *= s
        total += n
        keys = [str(getattr(k, "key", k)) for k in path]
        # routed-expert weights: (E, ...) stacks inside moe ffn params
        if (cfg.n_experts and "ffn" in keys and keys[-1] in ("wi", "wo")
                and leaf.ndim >= 3):
            expert += n
    if not active_only or not cfg.n_experts:
        return total
    active_expert = expert * cfg.top_k // cfg.n_experts
    return total - expert + active_expert
