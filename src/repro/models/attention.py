"""Attention: GQA (full / sliding-window) and MLA, with memory-bounded
chunked-flash prefill/train paths and a distributed flash-decode.

Design notes (DESIGN.md §5):

* **Prefill/train** uses a pure-jnp chunked flash attention (scan over KV
  chunks with online softmax) so 32k contexts never materialize S×S scores.
  Sliding-window layers slice exactly one (window + chunk) KV band per query
  chunk instead of scanning the whole sequence — the gemma local layers are
  then O(S·W) compute with no cross-shard traffic when the sequence is
  sharded contiguously.
* **Decode** computes per-shard partial (m, ℓ, o) flash statistics; when the
  KV cache is sequence-sharded (``axis_name`` set inside shard_map), partials
  merge with one tiny all-gather + log-sum-exp combine — any head count works
  on any mesh, which is how 24-head/40-head archs run on a 16-way model axis.
* **Sliding-window decode caches are ring buffers** of size W, not S — a
  34-layer gemma3 cache at 500k context costs MBs, not GBs.
* **MLA** (deepseek) caches only the compressed latent (c_kv, k_rope) and
  decodes in absorbed form: q is folded through W_UK once, attention runs in
  the 512-dim latent space, and the output unfolds through W_UV — per-token
  decode FLOPs scale with the latent rank, not heads × head_dim.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense_init, matmul, softcap

Params = dict
NEG_INF = -1e30


def _divisor_chunk(s: int, target: int) -> int:
    """Largest divisor of s that is <= target (static, trace-time)."""
    c = min(target, s)
    while s % c:
        c -= 1
    return c


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------

def gqa_init(key, cfg, dtype=jnp.float32) -> Params:
    d, h, kvh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d, h * hd, dtype),
        "wk": dense_init(ks[1], d, kvh * hd, dtype),
        "wv": dense_init(ks[2], d, kvh * hd, dtype),
        "wo": dense_init(ks[3], h * hd, d, dtype),
    }


def mla_init(key, cfg, dtype=jnp.float32) -> Params:
    d, h = cfg.d_model, cfg.n_heads
    r, nope, rope, vd = (cfg.kv_lora_rank, cfg.qk_nope_dim, cfg.qk_rope_dim,
                         cfg.v_head_dim)
    ks = jax.random.split(key, 5)
    return {
        "wq": dense_init(ks[0], d, h * (nope + rope), dtype),
        "w_dkv": dense_init(ks[1], d, r + rope, dtype),
        "w_uk": (jax.random.normal(ks[2], (r, h, nope), jnp.float32)
                 / math.sqrt(r)).astype(dtype),
        "w_uv": (jax.random.normal(ks[3], (r, h, vd), jnp.float32)
                 / math.sqrt(r)).astype(dtype),
        "wo": dense_init(ks[4], h * vd, d, dtype),
        "kv_norm": jnp.ones((r,), dtype),
    }


# ---------------------------------------------------------------------------
# chunked flash attention (prefill / train)
# ---------------------------------------------------------------------------

def _block_attn(qb, kb, vb, qpos, kpos, *, causal, window, cap, scale,
                kv_len, kv_start=None):
    """One (Cq, Ckv) block of masked scores (B,KVH,G,Cq,Ckv), f32."""
    # qb (B,Cq,KVH,G,hd) kb (B,Ckv,KVH,hd) -> s (B,KVH,G,Cq,Ckv)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qb, kb,
                   preferred_element_type=jnp.float32)
    s = s * scale
    if cap:
        s = cap * jnp.tanh(s / cap)
    mask = kpos[None, :] < kv_len
    if causal:
        mask = jnp.logical_and(mask, kpos[None, :] <= qpos[:, None])
    if window > 0:
        mask = jnp.logical_and(mask, qpos[:, None] - kpos[None, :] < window)
    mask = mask[None, None, None]
    if kv_start is not None:      # left-padded serving batches
        mask = jnp.logical_and(
            mask, (kpos[None, :] >= kv_start[:, None])[:, None, None, None])
    s = jnp.where(mask, s, NEG_INF)
    return s


def flash_attention(q, k, v, *, causal=True, window=0, cap=0.0,
                    q_offset=0, kv_len=None, chunk_q=512, chunk_kv=1024,
                    scale=None, kv_start=None):
    """Memory-bounded attention.

    q (B,Sq,H,hd); k,v (B,Skv,KVH,hd).  ``q_offset`` is the global position of
    q[0] (prefill continuation); ``kv_len`` masks cache padding.
    """
    b, sq, h, hd = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    kv_len = skv if kv_len is None else kv_len
    cq = _divisor_chunk(sq, chunk_q)
    ckv = _divisor_chunk(skv, chunk_kv)
    nq, nkv = sq // cq, skv // ckv

    qr = q.reshape(b, nq, cq, kvh, g, hd)

    def q_chunk(qi, qb):
        qpos = q_offset + qi * cq + jnp.arange(cq)

        if window > 0:
            # one KV band of width (window + cq) covers the whole chunk
            band = min(window + cq, skv)
            start = jnp.clip(qpos[0] - window + 1, 0, skv - band)
            kb = jax.lax.dynamic_slice_in_dim(k, start, band, axis=1)
            vb = jax.lax.dynamic_slice_in_dim(v, start, band, axis=1)
            kpos = start + jnp.arange(band)
            s = _block_attn(qb, kb, vb, qpos, kpos, causal=causal,
                            window=window, cap=cap, scale=scale,
                            kv_len=kv_len, kv_start=kv_start)
            m = jnp.max(s, axis=-1)
            p = jnp.exp(s - m[..., None])
            l = jnp.sum(p, axis=-1)
            acc = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(q.dtype), vb,
                             preferred_element_type=jnp.float32)
            out = acc / jnp.maximum(
                l.transpose(0, 3, 1, 2), 1e-30)[..., None]
            return out.astype(q.dtype)

        def kv_step(carry, ki):
            m, l, acc = carry
            kb = jax.lax.dynamic_slice_in_dim(k, ki * ckv, ckv, axis=1)
            vb = jax.lax.dynamic_slice_in_dim(v, ki * ckv, ckv, axis=1)
            kpos = ki * ckv + jnp.arange(ckv)
            s = _block_attn(qb, kb, vb, qpos, kpos, causal=causal, window=0,
                            cap=cap, scale=scale, kv_len=kv_len,
                            kv_start=kv_start)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(q.dtype), vb,
                            preferred_element_type=jnp.float32)
            acc = acc * corr[..., None] + pv
            return (m_new, l, acc), None

        m0 = jnp.full((b, kvh, g, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, cq), jnp.float32)
        a0 = jnp.zeros((b, kvh, g, cq, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nkv),
                                      unroll=nkv if unroll_all else 1)
        out = acc / jnp.maximum(l, 1e-30)[..., None]       # (b,kvh,g,cq,hd)
        return out.transpose(0, 3, 1, 2, 4).astype(q.dtype)

    # Small block grids are unrolled so XLA's cost analysis sees every block
    # (a scan body is counted once); big grids (32k prefill) stay rolled and
    # the roofline adds the analytic attention-core correction instead
    # (EXPERIMENTS.md §Roofline methodology).  Window layers have one band
    # per q chunk, so only nq matters for them.
    unroll_all = (nq * (1 if window > 0 else nkv)) <= 64

    # checkpoint per q-chunk: without it the backward of the (q × kv) scan
    # nest saves every score block — the full S×S matrix flash attention
    # exists to avoid.  With it, only per-chunk outputs persist and score
    # blocks are recomputed chunk-at-a-time in the backward sweep.
    def scan_body(_, args):
        return None, jax.checkpoint(lambda a: q_chunk(*a))(args)

    _, outs = jax.lax.scan(
        scan_body, None, (jnp.arange(nq), qr.transpose(1, 0, 2, 3, 4, 5)),
        unroll=nq if unroll_all else 1)
    return outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, h, hd)


# ---------------------------------------------------------------------------
# decode attention (single new token against a cache)
# ---------------------------------------------------------------------------

def decode_attention(q, k, v, kpos, cur_len, *, cap=0.0, window=0,
                     scale=None, axis_name=None, kv_start=None):
    """q (B,1,H,hd); k,v (B,S,KVH,hd) — S is the *local* cache shard inside
    shard_map (``axis_name`` set) or the full cache; kpos (S,) are the global
    positions of the cache rows.  Flash partials merge across shards with one
    small all-gather (o, m, ℓ per head)."""
    b, _, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)

    qr = q.reshape(b, kvh, g, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qr, k,
                   preferred_element_type=jnp.float32) * scale
    if cap:
        s = cap * jnp.tanh(s / cap)
    valid = kpos < cur_len
    if window > 0:
        valid = jnp.logical_and(valid, kpos > cur_len - 1 - window)
    valid = jnp.logical_and(valid, kpos >= 0)   # unwritten ring slots
    valid = valid[None, None, None]
    if kv_start is not None:
        valid = jnp.logical_and(
            valid, (kpos[None, :] >= kv_start[:, None])[:, None, None])
    s = jnp.where(valid, s, NEG_INF)

    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p.astype(q.dtype), v,
                   preferred_element_type=jnp.float32)

    if axis_name is not None:
        # merge partials: tiny (n_shards, b, kvh, g, [hd|1]) all-gathers
        ms = jax.lax.all_gather(m, axis_name)
        ls = jax.lax.all_gather(l, axis_name)
        os_ = jax.lax.all_gather(o, axis_name)
        m_g = jnp.max(ms, axis=0)
        corr = jnp.exp(ms - m_g[None])
        l_g = jnp.sum(ls * corr, axis=0)
        o_g = jnp.sum(os_ * corr[..., None], axis=0)
        out = o_g / jnp.maximum(l_g, 1e-30)[..., None]
    else:
        out = o / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, 1, h, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA layer forward (train/prefill & decode), cache management
# ---------------------------------------------------------------------------

def gqa_cache_init(cfg, spec, batch: int, max_len: int, dtype) -> Params:
    s = min(max_len, spec.window) if spec.attn == "window" else max_len
    kvh, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, s, kvh, hd), dtype),
        "v": jnp.zeros((batch, s, kvh, hd), dtype),
    }


def gqa_fwd(p: Params, x, spec, cfg, *, positions, cache=None, cur_len=None,
            decode_axis=None, kv_start=None):
    """Returns (y, new_cache).  Train/prefill when cache is None or being
    filled; decode when x has one token and cur_len is set."""
    b, s, d = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = matmul(x, p["wq"]).reshape(b, s, h, hd)
    k = matmul(x, p["wk"]).reshape(b, s, kvh, hd)
    v = matmul(x, p["wv"]).reshape(b, s, kvh, hd)
    if cfg.pos == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    window = spec.window if spec.attn == "window" else 0
    causal = getattr(spec, "causal", True)

    if cache is None:
        y = flash_attention(q, k, v, causal=causal, window=window,
                            cap=cfg.softcap_attn, kv_start=kv_start)
        new_cache = None
    elif s > 1:                                   # prefill into cache
        y = flash_attention(q, k, v, causal=causal, window=window,
                            cap=cfg.softcap_attn, kv_start=kv_start)
        cs = cache["k"].shape[1]
        if window > 0 and s > cs:
            # ring buffer: keep the last cs positions, each at slot p % cs
            k_in = jnp.roll(k[:, -cs:], s % cs, axis=1)
            v_in = jnp.roll(v[:, -cs:], s % cs, axis=1)
        else:
            k_in, v_in = k, v
        new_cache = {
            "k": jax.lax.dynamic_update_slice_in_dim(cache["k"], k_in, 0, 1),
            "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], v_in, 0, 1),
        }
    else:                                         # decode step
        cs = cache["k"].shape[1]
        slot = (cur_len % cs) if window > 0 else cur_len
        ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
        if window > 0:
            # ring buffer: reconstruct global positions of each slot
            idx = jnp.arange(cs)
            wraps = (cur_len + 1 + cs - 1) // cs
            kpos = jnp.where(idx <= slot, idx + (wraps - 1) * cs,
                             idx + (wraps - 2) * cs)
            kpos = jnp.where(idx == slot, cur_len, kpos)
        else:
            kpos = jnp.arange(cs)
        y = decode_attention(q, ck, cv, kpos, cur_len + 1,
                             cap=cfg.softcap_attn, window=window,
                             axis_name=decode_axis, kv_start=kv_start)
        new_cache = {"k": ck, "v": cv}

    y = matmul(y.reshape(b, s, h * hd), p["wo"])
    return y, new_cache


# ---------------------------------------------------------------------------
# MLA layer forward
# ---------------------------------------------------------------------------

def mla_cache_init(cfg, batch: int, max_len: int, dtype) -> Params:
    return {
        "c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype),
    }


def _mla_expand(p, c_kv, k_rope, cfg):
    """Latent -> per-head K/V (prefill path)."""
    k_nope = jnp.einsum("bsr,rhn->bshn", c_kv, p["w_uk"])
    v = jnp.einsum("bsr,rhn->bshn", c_kv, p["w_uv"])
    k_r = jnp.broadcast_to(k_rope[:, :, None, :],
                           k_nope.shape[:3] + (cfg.qk_rope_dim,))
    k = jnp.concatenate([k_nope, k_r], axis=-1)
    return k.astype(c_kv.dtype), v.astype(c_kv.dtype)


def mla_fwd(p: Params, x, spec, cfg, *, positions, cache=None, cur_len=None,
            decode_axis=None, kv_start=None):
    from repro.models.layers import norm_fwd
    b, s, d = x.shape
    h = cfg.n_heads
    nope, rope, r, vd = (cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.kv_lora_rank,
                         cfg.v_head_dim)
    qd = nope + rope
    scale = 1.0 / math.sqrt(qd)

    q = matmul(x, p["wq"]).reshape(b, s, h, qd)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    dkv = matmul(x, p["w_dkv"])
    c_kv, k_rope = dkv[..., :r], dkv[..., r:]
    c_kv = norm_fwd({"scale": p["kv_norm"]}, c_kv, "rmsnorm", cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0, :]

    if cache is None or s > 1:                    # train / prefill: expand
        if cache is not None:
            new_cache = {
                "c_kv": jax.lax.dynamic_update_slice_in_dim(
                    cache["c_kv"], c_kv, 0, 1),
                "k_rope": jax.lax.dynamic_update_slice_in_dim(
                    cache["k_rope"], k_rope, 0, 1),
            }
        else:
            new_cache = None
        k, v = _mla_expand(p, c_kv, k_rope, cfg)
        qq = jnp.concatenate([q_nope, q_rope], axis=-1)
        # pad V up to the qk head dim so flash kernels see uniform shapes
        y = flash_attention(qq, k, jnp.pad(v, ((0, 0), (0, 0), (0, 0),
                                               (0, qd - vd))),
                            causal=True, cap=0.0, scale=scale,
                            kv_start=kv_start)
        y = y[..., :vd]
    else:                                         # absorbed decode
        c = jax.lax.dynamic_update_slice(cache["c_kv"], c_kv, (0, cur_len, 0))
        kr = jax.lax.dynamic_update_slice(cache["k_rope"], k_rope,
                                          (0, cur_len, 0))
        new_cache = {"c_kv": c, "k_rope": kr}
        # fold q through W_UK: (b,1,h,nope) @ (r,h,nope) -> (b,1,h,r)
        q_eff = jnp.einsum("bqhn,rhn->bqhr", q_nope, p["w_uk"])
        cs = c.shape[1]
        kpos = jnp.arange(cs)
        s_lat = jnp.einsum("bqhr,bsr->bhqs", q_eff, c,
                           preferred_element_type=jnp.float32)
        s_rope = jnp.einsum("bqhn,bsn->bhqs", q_rope, kr,
                            preferred_element_type=jnp.float32)
        sc = (s_lat + s_rope) * scale
        valid = (kpos < (cur_len + 1))[None, None, None]
        if kv_start is not None:
            valid = jnp.logical_and(
                valid, (kpos[None, :] >= kv_start[:, None])[:, None, None])
        sc = jnp.where(valid, sc, NEG_INF)
        m = jnp.max(sc, axis=-1)
        pr = jnp.exp(sc - m[..., None])
        l = jnp.sum(pr, axis=-1)
        o_lat = jnp.einsum("bhqs,bsr->bhqr", pr.astype(x.dtype), c,
                           preferred_element_type=jnp.float32)
        if decode_axis is not None:
            ms = jax.lax.all_gather(m, decode_axis)
            ls = jax.lax.all_gather(l, decode_axis)
            os_ = jax.lax.all_gather(o_lat, decode_axis)
            m_g = jnp.max(ms, axis=0)
            corr = jnp.exp(ms - m_g[None])
            l = jnp.sum(ls * corr, axis=0)
            o_lat = jnp.sum(os_ * corr[..., None], axis=0)
            m = m_g
        o_lat = o_lat / jnp.maximum(l, 1e-30)[..., None]
        y = jnp.einsum("bhqr,rhn->bqhn", o_lat.astype(x.dtype), p["w_uv"])

    y = matmul(y.reshape(b, s, h * vd), p["wo"])
    return y, new_cache


# ---------------------------------------------------------------------------
# cross attention (whisper decoder)
# ---------------------------------------------------------------------------

def cross_attn_init(key, cfg, dtype=jnp.float32) -> Params:
    return gqa_init(key, cfg, dtype)


def cross_attn_fwd(p: Params, x, enc, cfg):
    b, s, d = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = matmul(x, p["wq"]).reshape(b, s, h, hd)
    k = matmul(enc, p["wk"]).reshape(b, enc.shape[1], kvh, hd)
    v = matmul(enc, p["wv"]).reshape(b, enc.shape[1], kvh, hd)
    y = flash_attention(q, k, v, causal=False, chunk_q=min(512, s),
                        chunk_kv=min(1024, enc.shape[1]))
    return matmul(y.reshape(b, s, h * hd), p["wo"])
