"""Elementary layers: norms, rotary embeddings, MLPs, initializers.

Everything is functional: ``*_init(key, ...) -> params-dict`` and
``*_fwd(params, x, ...) -> y``.  Matmuls accumulate in f32
(``preferred_element_type``) regardless of the storage dtype.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

Params = dict


def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32,
               scale: float | None = None) -> jax.Array:
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    """x @ w with f32 accumulation, result in x.dtype."""
    return jnp.matmul(x, w, preferred_element_type=jnp.float32).astype(x.dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def norm_init(d: int, kind: str, dtype=jnp.float32) -> Params:
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def norm_fwd(p: Params, x: jax.Array, kind: str, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    elif kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # pragma: no cover
        raise ValueError(kind)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x (..., S, H, hd) rotated by per-position angles; positions (..., S)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                       # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs   # (..., S, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]                    # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n: int, d: int) -> jax.Array:
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10_000.0, dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)[:, :d]


def sinusoidal_position_at(pos, d: int) -> jax.Array:
    """Single (possibly traced) position -> (d,) sinusoid."""
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)
    ang = jnp.asarray(pos, jnp.float32) / jnp.power(10_000.0, dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)[:d]


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def _act(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


def mlp_init(key, d: int, f: int, kind: str, dtype=jnp.float32) -> Params:
    k1, k2 = jax.random.split(key)
    if kind == "gated":
        return {"wi": dense_init(k1, d, 2 * f, dtype),
                "wo": dense_init(k2, f, d, dtype)}
    return {"wi": dense_init(k1, d, f, dtype),
            "wo": dense_init(k2, f, d, dtype)}


def mlp_fwd(p: Params, x: jax.Array, kind: str, act: str) -> jax.Array:
    h = matmul(x, p["wi"])
    if kind == "gated":
        gate, up = jnp.split(h, 2, axis=-1)
        h = _act(act)(gate) * up
    else:
        h = _act(act)(h)
    return matmul(h, p["wo"])


def softcap(x: jax.Array, cap: float) -> jax.Array:
    if cap and cap > 0.0:
        return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)
    return x


def embed_init(key, vocab: int, d: int, dtype=jnp.float32) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)
