"""Mixture-of-experts FFN with sort-based capacity dispatch.

Dispatch is scatter/gather based (argsort tokens by expert, slot = expert ×
capacity + rank-within-expert), NOT the (T,E,C) one-hot einsum — so dispatch
costs O(T·D) data movement instead of O(T·E·C·D) flops, matching what a real
deployment does; with tokens sharded over `data` and experts over `model`,
the SPMD partitioner turns the scatter/gather pair into the expert-parallel
all-to-alls.  Over-capacity tokens are dropped (their gate mass simply does
not contribute — standard Switch/GShard semantics, capacity factor 1.25).

DeepSeek-style shared experts are a fused dense MLP running alongside.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import _act, dense_init, matmul

Params = dict


def moe_init(key, cfg, dtype=jnp.float32) -> Params:
    d, e, fe = cfg.d_model, cfg.n_experts, cfg.d_expert
    ks = jax.random.split(key, 4)
    p = {
        "router": dense_init(ks[0], d, e, dtype),
        "wi": (jax.random.normal(ks[1], (e, d, 2 * fe), jnp.float32)
               / jnp.sqrt(d)).astype(dtype),
        "wo": (jax.random.normal(ks[2], (e, fe, d), jnp.float32)
               / jnp.sqrt(fe)).astype(dtype),
    }
    if cfg.n_shared_experts:
        fs = cfg.n_shared_experts * fe
        k1, k2 = jax.random.split(ks[3])
        p["shared_wi"] = dense_init(k1, d, 2 * fs, dtype)
        p["shared_wo"] = dense_init(k2, fs, d, dtype)
    return p


def _n_blocks(t: int, target: int = 16) -> int:
    n = min(target, t)
    while t % n:
        n -= 1
    return n


def moe_fwd(p: Params, x: jax.Array, cfg, capacity_factor: float = 1.25):
    """Block-parallel dispatch: tokens are split into ``nblk`` blocks (one
    per data shard on the production mesh) and each block sorts/dispatches
    its own tokens — sort, cumsum and scatter all carry a leading block dim,
    so GSPMD shards them over ``data`` instead of replicating the global
    token stream (the single-stream argsort is a propagation barrier; see
    EXPERIMENTS.md §Perf iteration log).  Capacity is per (block, expert).
    """
    from repro.sharding.util import maybe_constrain
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    nblk = _n_blocks(t)
    tb = t // nblk
    cap = max(1, int(tb * k / e * capacity_factor))
    act = _act(cfg.act)

    xt = x.reshape(nblk, tb, d)
    xt = maybe_constrain(xt, "data", None, None)
    logits = jnp.matmul(xt, p["router"],
                        preferred_element_type=jnp.float32)
    gates = jax.nn.softmax(logits, axis=-1)                  # (nblk, tb, e)
    topg, topi = jax.lax.top_k(gates, k)                     # (nblk, tb, k)
    topg = topg / jnp.maximum(jnp.sum(topg, -1, keepdims=True), 1e-9)

    flat_e = topi.reshape(nblk, tb * k)
    flat_g = topg.reshape(nblk, tb * k)
    flat_t = jnp.broadcast_to(
        jnp.repeat(jnp.arange(tb), k)[None], (nblk, tb * k))

    order = jnp.argsort(flat_e, axis=-1, stable=True)
    se = jnp.take_along_axis(flat_e, order, axis=-1)
    st = jnp.take_along_axis(flat_t, order, axis=-1)
    sg = jnp.take_along_axis(flat_g, order, axis=-1)

    # rank within (block, expert): position - start offset of the expert
    onehot_counts = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
    counts = jnp.sum(onehot_counts, axis=1)                  # (nblk, e)
    start = jnp.cumsum(counts, axis=-1) - counts
    pos = (jnp.broadcast_to(jnp.arange(tb * k)[None], se.shape)
           - jnp.take_along_axis(start, se, axis=-1))
    keep = pos < cap
    slot = jnp.where(keep, se * cap + pos, e * cap)          # overflow slot

    gathered = jnp.take_along_axis(xt, st[..., None], axis=1)
    disp = jnp.zeros((nblk, e * cap + 1, d), x.dtype)
    disp = jax.vmap(lambda dd, sl, g: dd.at[sl].set(g))(disp, slot, gathered)
    h = disp[:, :-1].reshape(nblk, e, cap, d)
    h = maybe_constrain(h, "data", "model", None, None)

    hi = jnp.einsum("necd,edf->necf", h, p["wi"],
                    preferred_element_type=jnp.float32).astype(x.dtype)
    gate, up = jnp.split(hi, 2, axis=-1)
    ho = jnp.einsum("necf,efd->necd", act(gate) * up, p["wo"],
                    preferred_element_type=jnp.float32).astype(x.dtype)
    ho = maybe_constrain(ho, "data", "model", None, None)

    y_slots = jnp.concatenate(
        [ho.reshape(nblk, e * cap, d),
         jnp.zeros((nblk, 1, d), x.dtype)], axis=1)
    contrib = jnp.take_along_axis(y_slots, slot[..., None], axis=1)
    contrib = contrib * sg[..., None].astype(x.dtype)
    y = jnp.zeros((nblk, tb, d), x.dtype)
    y = jax.vmap(lambda yy, tt, cc: yy.at[tt].add(cc))(y, st, contrib)
    y = maybe_constrain(y, "data", None, None)

    if cfg.n_shared_experts:
        hs = matmul(xt, p["shared_wi"])
        g2, u2 = jnp.split(hs, 2, axis=-1)
        y = y + matmul(act(g2) * u2, p["shared_wo"])

    return y.reshape(b, s, d)


def moe_aux_loss(p: Params, x: jax.Array, cfg) -> jax.Array:
    """Switch load-balance loss: E · Σ_e f_e · P_e (optional trainer term)."""
    b, s, d = x.shape
    xt = x.reshape(-1, d)
    logits = jnp.matmul(xt, p["router"], preferred_element_type=jnp.float32)
    gates = jax.nn.softmax(logits, axis=-1)
    _, topi = jax.lax.top_k(gates, cfg.top_k)
    hard = jnp.zeros_like(gates).at[
        jnp.arange(gates.shape[0])[:, None], topi].set(1.0)
    f = jnp.mean(hard, axis=0)
    pm = jnp.mean(gates, axis=0)
    return cfg.n_experts * jnp.sum(f * pm)
