"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) block.

Chunked SSD algorithm: the sequence is split into chunks of Q; within a chunk
the output is an attention-like masked contraction (parallel, MXU-friendly);
across chunks a small (H, P, N) state is carried by a scan — the paper's
"local phase / boundary exchange" structure in sequence space (DESIGN.md §4).

Decode is O(1): one state update per token, which is why the SSM/hybrid archs
are the ones eligible for the 500k-context shapes.

All decays stay in log space until the last moment and are bounded above by 0
(A < 0), so every exp() is ≤ 1 — no overflow at any chunk size.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, matmul, norm_fwd

Params = dict


def _dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    h = d_inner // cfg.ssm_head_dim
    return d_inner, h, cfg.ssm_state, cfg.ssm_head_dim


def mamba_init(key, cfg, dtype=jnp.float32) -> Params:
    d_inner, h, n, p_ = _dims(cfg)
    conv_ch = d_inner + 2 * n                     # x, B, C go through conv
    ks = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(ks[0], cfg.d_model,
                              2 * d_inner + 2 * n + h, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_dim, conv_ch),
                                     jnp.float32) / math.sqrt(cfg.conv_dim)
                   ).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.zeros((h,), jnp.float32),    # A = -exp(A_log) = -1
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm_scale": jnp.ones((d_inner,), dtype),
        "out_proj": dense_init(ks[3], d_inner, cfg.d_model, dtype),
    }


def _causal_conv(xbc, w, b, conv_state=None):
    """Depthwise causal conv along S.  xbc (B,S,C); w (K,C)."""
    k = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = conv_state                          # (B, K-1, C)
    xp = jnp.concatenate([pad, xbc], axis=1)
    out = sum(xp[:, i:i + xbc.shape[1]] * w[i] for i in range(k))
    new_state = xp[:, -(k - 1):] if k > 1 else pad
    return jax.nn.silu(out + b), new_state


def mamba_cache_init(cfg, batch: int, dtype) -> Params:
    d_inner, h, n, p_ = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.conv_dim - 1, d_inner + 2 * n), dtype),
        "ssm": jnp.zeros((batch, h, p_, n), jnp.float32),
    }


def _split_proj(proj, cfg):
    d_inner, h, n, p_ = _dims(cfg)
    z = proj[..., :d_inner]
    xbc = proj[..., d_inner:2 * d_inner + 2 * n]
    dt = proj[..., 2 * d_inner + 2 * n:]
    return z, xbc, dt


def mamba_fwd(p: Params, u: jax.Array, cfg, cache=None):
    """Train/prefill path.  u (B,S,D) -> (y, new_cache)."""
    d_inner, h, n, p_ = _dims(cfg)
    b, s, _ = u.shape
    q = min(cfg.ssm_chunk, s)
    while s % q:
        q -= 1
    nc = s // q

    proj = matmul(u, p["in_proj"])
    z, xbc, dt = _split_proj(proj, cfg)
    xbc, conv_state = _causal_conv(
        xbc, p["conv_w"], p["conv_b"],
        None if cache is None else cache["conv"])
    x = xbc[..., :d_inner].reshape(b, s, h, p_)
    bmat = xbc[..., d_inner:d_inner + n]                    # (B,S,N)
    cmat = xbc[..., d_inner + n:]                           # (B,S,N)

    a = -jnp.exp(p["A_log"])                                # (H,) < 0
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,S,H)
    da = dt * a                                             # (B,S,H) <= 0

    # ---- chunked SSD ------------------------------------------------------
    xc = x.reshape(b, nc, q, h, p_).astype(jnp.float32)
    bc = bmat.reshape(b, nc, q, n).astype(jnp.float32)
    cc = cmat.reshape(b, nc, q, n).astype(jnp.float32)
    dtc = dt.reshape(b, nc, q, h)
    dac = da.reshape(b, nc, q, h)
    cum = jnp.cumsum(dac, axis=2)                           # (B,nc,Q,H)
    cum_last = cum[:, :, -1:, :]                            # (B,nc,1,H)

    # per-chunk input state: sum_q exp(cum_last - cum_q) * dt_q * B_q ⊗ x_q
    wgt = jnp.exp(cum_last - cum) * dtc                     # (B,nc,Q,H)
    chunk_state = jnp.einsum("bcqh,bcqn,bcqhp->bchpn", wgt, bc, xc)

    # inter-chunk recurrence (sequential over nc chunks)
    chunk_decay = jnp.exp(cum_last[:, :, 0, :])             # (B,nc,H)

    def step(carry, inp):
        st = carry                                          # (B,H,P,N)
        cs, dec = inp
        out = st                                            # state BEFORE chunk
        st = st * dec[:, :, None, None] + cs
        return st, out

    init = (jnp.zeros((b, h, p_, n), jnp.float32) if cache is None
            else cache["ssm"])
    final_state, prev_states = jax.lax.scan(
        step, init,
        (chunk_state.transpose(1, 0, 2, 3, 4),
         chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)      # (B,nc,H,P,N)

    # inter-chunk output: C_q · (prev_state decayed to q)
    y_inter = jnp.einsum("bcqn,bchpn->bcqhp", cc, prev_states) \
        * jnp.exp(cum)[..., None]

    # intra-chunk (attention-like, causal within chunk)
    l = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])   # (B,nc,Q,Q,H)
    causal = jnp.tril(jnp.ones((q, q), bool))
    scores = jnp.einsum("bcqn,bcsn->bcqs", cc, bc)          # (B,nc,Q,Q)
    scores = jnp.where(causal[None, None], scores, 0.0)
    y_intra = jnp.einsum("bcqs,bcqsh,bcsh,bcshp->bcqhp",
                         scores, jnp.where(causal[None, None, :, :, None],
                                           l, 0.0),
                         dtc, xc)

    y = (y_inter + y_intra).reshape(b, s, h, p_)
    y = y + p["D"][None, None, :, None] * x.astype(jnp.float32)
    y = y.reshape(b, s, d_inner).astype(u.dtype)

    y = y * jax.nn.silu(z)
    y = norm_fwd({"scale": p["norm_scale"]}, y, "rmsnorm", cfg.norm_eps)
    y = matmul(y, p["out_proj"])
    new_cache = None if cache is None else {"conv": conv_state,
                                            "ssm": final_state}
    return y, new_cache


def mamba_decode(p: Params, u: jax.Array, cfg, cache: Params):
    """Single-token decode: O(1) state update.  u (B,1,D)."""
    d_inner, h, n, p_ = _dims(cfg)
    b = u.shape[0]
    proj = matmul(u, p["in_proj"])
    z, xbc, dt = _split_proj(proj, cfg)
    xbc, conv_state = _causal_conv(xbc, p["conv_w"], p["conv_b"],
                                   cache["conv"])
    x = xbc[:, 0, :d_inner].reshape(b, h, p_).astype(jnp.float32)
    bvec = xbc[:, 0, d_inner:d_inner + n].astype(jnp.float32)
    cvec = xbc[:, 0, d_inner + n:].astype(jnp.float32)

    a = -jnp.exp(p["A_log"])
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    decay = jnp.exp(dt * a)                                 # (B,H)

    st = cache["ssm"] * decay[:, :, None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt, x, bvec)
    y = jnp.einsum("bn,bhpn->bhp", cvec, st)
    y = y + p["D"][None, :, None] * x
    y = y.reshape(b, 1, d_inner).astype(u.dtype)
    y = y * jax.nn.silu(z)
    y = norm_fwd({"scale": p["norm_scale"]}, y, "rmsnorm", cfg.norm_eps)
    y = matmul(y, p["out_proj"])
    return y, {"conv": conv_state, "ssm": st}
