"""Top-level models: decoder LM, encoder-decoder (whisper), VLM (internvl).

All share one functional API (see registry.ModelAPI):

  init(key, cfg, dtype)                     -> params
  forward(params, batch, cfg)               -> logits           (train)
  init_cache(cfg, batch, max_len, dtype)    -> cache
  prefill(params, batch, cache, cfg)        -> (last_logits, cache)
  decode_step(params, token, cache, cur_len, cfg) -> (logits, cache)

``batch`` is a dict: tokens (B,S) int32 [+ vis_embed (B,Tv,Dv) for vlm,
audio_embed (B,F,D) for audio].
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, LayerSpec
from repro.models.layers import (embed_init, matmul, norm_fwd, norm_init,
                                 sinusoidal_position_at, sinusoidal_positions,
                                 softcap, dense_init)
from repro.models.stack import (stack_cache_init, stack_fwd, stack_init)

Params = dict


# ---------------------------------------------------------------------------
# decoder-only LM (phi, gemma, granite, deepseek, mamba, jamba)
# ---------------------------------------------------------------------------

def lm_init(key, cfg: ArchConfig, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 3)
    p: Params = {
        "embed": embed_init(ks[0], cfg.vocab, cfg.d_model, dtype),
        "stack": stack_init(ks[1], cfg, cfg.layers(), dtype),
        "final_norm": norm_init(cfg.d_model, cfg.norm, dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[2], cfg.d_model, cfg.vocab, dtype)
    return p


def _logits(p: Params, x, cfg: ArchConfig):
    from repro.sharding.util import maybe_constrain
    x = norm_fwd(p["final_norm"], x, cfg.norm, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.matmul(x, p["embed"].T,
                            preferred_element_type=jnp.float32)
    else:
        logits = jnp.matmul(x, p["lm_head"],
                            preferred_element_type=jnp.float32)
    logits = maybe_constrain(logits, "data", None, "model")
    return softcap(logits, cfg.softcap_final)


def lm_forward(p: Params, batch, cfg: ArchConfig, *, remat=True):
    from repro.sharding.util import maybe_constrain
    tokens = batch["tokens"]
    x = maybe_constrain(p["embed"][tokens], "data", None, None)
    positions = jnp.arange(tokens.shape[1])
    x, _ = stack_fwd(p["stack"], x, cfg, cfg.layers(), positions=positions,
                     remat=remat)
    return _logits(p, x, cfg)


def lm_cache_init(cfg: ArchConfig, batch: int, max_len: int,
                  dtype=jnp.float32) -> Params:
    return stack_cache_init(cfg, cfg.layers(), batch, max_len, dtype)


def lm_prefill(p: Params, batch, cache, cfg: ArchConfig):
    tokens = batch["tokens"]
    x = p["embed"][tokens]
    start = batch.get("start")          # (B,) left-pad offsets (serving)
    if start is not None:
        positions = jnp.maximum(
            jnp.arange(tokens.shape[1])[None, :] - start[:, None], 0)
    else:
        positions = jnp.arange(tokens.shape[1])
    x, cache = stack_fwd(p["stack"], x, cfg, cfg.layers(),
                         positions=positions, cache=cache, cur_len=0,
                         kv_start=start)
    return _logits(p, x[:, -1:], cfg), cache


def lm_decode_step(p: Params, token, cache, cur_len, cfg: ArchConfig,
                   decode_axis=None, kv_start=None):
    """token (B,1) int32; cur_len = #tokens already in the cache."""
    x = p["embed"][token]
    if kv_start is not None:
        positions = jnp.maximum(cur_len - kv_start, 0)[:, None]
    else:
        positions = jnp.full(token.shape, cur_len, jnp.int32)
    x, cache = stack_fwd(p["stack"], x, cfg, cfg.layers(),
                         positions=positions, cache=cache, cur_len=cur_len,
                         decode=True, decode_axis=decode_axis,
                         kv_start=kv_start)
    return _logits(p, x, cfg), cache


# ---------------------------------------------------------------------------
# encoder-decoder (whisper): conv/mel frontend is a stub — the batch carries
# precomputed frame embeddings (B, F, d_model) per the assignment.
# ---------------------------------------------------------------------------

def _enc_layers(cfg) -> tuple[LayerSpec, ...]:
    return (LayerSpec(mixer="attn", attn="full", causal=False),) * cfg.enc_layers


def _dec_layers(cfg) -> tuple[LayerSpec, ...]:
    return (LayerSpec(mixer="attn", attn="full", cross=True),) * cfg.n_layers


def encdec_init(key, cfg: ArchConfig, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 5)
    enc_cfg = _with_pattern(cfg, _enc_layers(cfg))
    dec_cfg = _with_pattern(cfg, _dec_layers(cfg))
    return {
        "frontend_proj": dense_init(ks[0], cfg.d_model, cfg.d_model, dtype),
        "embed": embed_init(ks[1], cfg.vocab, cfg.d_model, dtype),
        "enc_stack": stack_init(ks[2], enc_cfg, _enc_layers(cfg), dtype),
        "enc_norm": norm_init(cfg.d_model, cfg.norm, dtype),
        "stack": stack_init(ks[3], dec_cfg, _dec_layers(cfg), dtype),
        "final_norm": norm_init(cfg.d_model, cfg.norm, dtype),
        "lm_head": dense_init(ks[4], cfg.d_model, cfg.vocab, dtype),
    }


def _with_pattern(cfg: ArchConfig, layers):
    import dataclasses
    pat = (layers[0],) if layers else (LayerSpec(),)   # 0-layer cost probes
    return dataclasses.replace(cfg, pattern=pat, n_layers=len(layers))


def encode(p: Params, batch, cfg: ArchConfig):
    frames = batch["audio_embed"].astype(p["embed"].dtype)
    x = matmul(frames, p["frontend_proj"])
    x = x + sinusoidal_positions(x.shape[1], cfg.d_model).astype(x.dtype)
    enc_cfg = _with_pattern(cfg, _enc_layers(cfg))
    x, _ = stack_fwd(p["enc_stack"], x, enc_cfg, _enc_layers(cfg),
                     positions=jnp.arange(x.shape[1]))
    return norm_fwd(p["enc_norm"], x, cfg.norm, cfg.norm_eps)


def encdec_forward(p: Params, batch, cfg: ArchConfig, *, remat=True):
    enc = encode(p, batch, cfg)
    tokens = batch["tokens"]
    x = p["embed"][tokens]
    x = x + sinusoidal_positions(x.shape[1], cfg.d_model).astype(x.dtype)
    dec_cfg = _with_pattern(cfg, _dec_layers(cfg))
    x, _ = stack_fwd(p["stack"], x, dec_cfg, _dec_layers(cfg),
                     positions=jnp.arange(tokens.shape[1]), enc=enc,
                     remat=remat)
    x = norm_fwd(p["final_norm"], x, cfg.norm, cfg.norm_eps)
    return jnp.matmul(x, p["lm_head"], preferred_element_type=jnp.float32)


def encdec_cache_init(cfg: ArchConfig, batch: int, max_len: int,
                      dtype=jnp.float32) -> Params:
    dec_cfg = _with_pattern(cfg, _dec_layers(cfg))
    return {"dec": stack_cache_init(dec_cfg, _dec_layers(cfg), batch,
                                    max_len, dtype),
            "enc_out": jnp.zeros((batch, cfg.enc_frames, cfg.d_model), dtype)}


def encdec_prefill(p: Params, batch, cache, cfg: ArchConfig):
    enc = encode(p, batch, cfg)
    tokens = batch["tokens"]
    x = p["embed"][tokens]
    x = x + sinusoidal_positions(x.shape[1], cfg.d_model).astype(x.dtype)
    dec_cfg = _with_pattern(cfg, _dec_layers(cfg))
    x, dec_cache = stack_fwd(p["stack"], x, dec_cfg, _dec_layers(cfg),
                             positions=jnp.arange(tokens.shape[1]), enc=enc,
                             cache=cache["dec"], cur_len=0)
    x = norm_fwd(p["final_norm"], x[:, -1:], cfg.norm, cfg.norm_eps)
    logits = jnp.matmul(x, p["lm_head"], preferred_element_type=jnp.float32)
    return logits, {"dec": dec_cache, "enc_out": enc}


def encdec_decode_step(p: Params, token, cache, cur_len, cfg: ArchConfig,
                       decode_axis=None):
    x = p["embed"][token]
    x = x + sinusoidal_position_at(cur_len, cfg.d_model)[None, None, :].astype(x.dtype)
    dec_cfg = _with_pattern(cfg, _dec_layers(cfg))
    x, dec_cache = stack_fwd(p["stack"], x, dec_cfg, _dec_layers(cfg),
                             positions=jnp.full(token.shape, cur_len),
                             enc=cache["enc_out"], cache=cache["dec"],
                             cur_len=cur_len, decode=True,
                             decode_axis=decode_axis)
    x = norm_fwd(p["final_norm"], x, cfg.norm, cfg.norm_eps)
    logits = jnp.matmul(x, p["lm_head"], preferred_element_type=jnp.float32)
    return logits, {"dec": dec_cache, "enc_out": cache["enc_out"]}


# ---------------------------------------------------------------------------
# VLM (internvl): ViT frontend is a stub — batch carries precomputed patch
# embeddings (B, Tv, vis_dim), projected and prepended to the token stream.
# ---------------------------------------------------------------------------

def vlm_init(key, cfg: ArchConfig, dtype=jnp.float32) -> Params:
    k1, k2 = jax.random.split(key)
    p = lm_init(k1, cfg, dtype)
    p["vis_proj"] = dense_init(k2, cfg.vis_dim, cfg.d_model, dtype)
    return p


def _vlm_embed(p, batch, cfg):
    tok = p["embed"][batch["tokens"]]
    vis = matmul(batch["vis_embed"].astype(tok.dtype), p["vis_proj"])
    return jnp.concatenate([vis, tok], axis=1)


def vlm_forward(p: Params, batch, cfg: ArchConfig, *, remat=True):
    x = _vlm_embed(p, batch, cfg)
    positions = jnp.arange(x.shape[1])
    x, _ = stack_fwd(p["stack"], x, cfg, cfg.layers(), positions=positions,
                     remat=remat)
    return _logits(p, x, cfg)


def vlm_prefill(p: Params, batch, cache, cfg: ArchConfig):
    x = _vlm_embed(p, batch, cfg)
    positions = jnp.arange(x.shape[1])
    x, cache = stack_fwd(p["stack"], x, cfg, cfg.layers(),
                         positions=positions, cache=cache, cur_len=0)
    return _logits(p, x[:, -1:], cfg), cache
