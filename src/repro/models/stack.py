"""Heterogeneous layer stacks with scan-over-units.

A stack's layer list is grouped into repetitions of its ``pattern`` unit
(gemma3's LLLLLG, jamba's MMMM A MMM, or a single uniform layer); full units
are ``lax.scan``ned over stacked parameters (compile the unit once, not 72
layers) with per-unit rematerialization, and any remainder layers are
unrolled.  Decode threads per-unit caches through the scan as xs/ys.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, LayerSpec
from repro.models import attention as attn_mod
from repro.models import mamba as mamba_mod
from repro.models import moe as moe_mod
from repro.models.layers import mlp_fwd, mlp_init, norm_fwd, norm_init

Params = dict


def _unit_specs(cfg: ArchConfig, layers: tuple[LayerSpec, ...]):
    """Split the layer list into (head, pattern, n_units, tail): ``head``
    holds leading layers that differ from the repeating unit (deepseek's
    first-k-dense), scanned units cover the homogeneous middle, ``tail`` the
    trailing remainder (gemma3's final locals)."""
    u = len(cfg.pattern)
    head = tuple(layers[: cfg.first_k_dense]) if cfg.first_k_dense else ()
    rest = layers[len(head):]
    n_units = len(rest) // u
    tail = rest[n_units * u:]
    return head, cfg.pattern, n_units, tail


# ---------------------------------------------------------------------------
# one layer
# ---------------------------------------------------------------------------

def layer_init(key, cfg: ArchConfig, spec: LayerSpec, dtype) -> Params:
    ks = jax.random.split(key, 6)
    p: Params = {"norm1": norm_init(cfg.d_model, cfg.norm, dtype)}
    if spec.mixer == "attn":
        if spec.attn == "mla":
            p["mixer"] = attn_mod.mla_init(ks[0], cfg, dtype)
        else:
            p["mixer"] = attn_mod.gqa_init(ks[0], cfg, dtype)
    else:
        p["mixer"] = mamba_mod.mamba_init(ks[0], cfg, dtype)
    if spec.cross:
        p["norm_x"] = norm_init(cfg.d_model, cfg.norm, dtype)
        p["cross"] = attn_mod.cross_attn_init(ks[1], cfg, dtype)
    if spec.moe:
        p["norm2"] = norm_init(cfg.d_model, cfg.norm, dtype)
        p["ffn"] = moe_mod.moe_init(ks[2], cfg, dtype)
    elif cfg.d_ff > 0:
        p["norm2"] = norm_init(cfg.d_model, cfg.norm, dtype)
        p["ffn"] = mlp_init(ks[2], cfg.d_model, cfg.d_ff, cfg.mlp, dtype)
    return p


def layer_cache_init(cfg: ArchConfig, spec: LayerSpec, batch: int,
                     max_len: int, dtype) -> Params:
    if spec.mixer == "mamba":
        return {"mamba": mamba_mod.mamba_cache_init(cfg, batch, dtype)}
    if spec.attn == "mla":
        return {"mla": attn_mod.mla_cache_init(cfg, batch, max_len, dtype)}
    return {"kv": attn_mod.gqa_cache_init(cfg, spec, batch, max_len, dtype)}


def layer_fwd(p: Params, x, cfg: ArchConfig, spec: LayerSpec, *, positions,
              cache=None, cur_len=None, enc=None, decode=False,
              decode_axis=None, kv_start=None):
    from repro.sharding.util import maybe_constrain, seq_axis
    # re-anchor propagation; with sequence parallelism on, the residual
    # stream (and thus the remat carry) shards over model on the seq dim
    x = maybe_constrain(x, "data", seq_axis(), None)
    h = norm_fwd(p["norm1"], x, cfg.norm, cfg.norm_eps)
    if spec.mixer == "attn":
        fwd = attn_mod.mla_fwd if spec.attn == "mla" else attn_mod.gqa_fwd
        key = "mla" if spec.attn == "mla" else "kv"
        sub = None if cache is None else cache[key]
        y, new_sub = fwd(p["mixer"], h, spec, cfg, positions=positions,
                         cache=sub, cur_len=cur_len, decode_axis=decode_axis,
                         kv_start=kv_start)
        new_cache = None if cache is None else {key: new_sub}
    else:
        if decode:
            y, new_sub = mamba_mod.mamba_decode(p["mixer"], h, cfg,
                                                cache["mamba"])
        else:
            sub = None if cache is None else cache["mamba"]
            y, new_sub = mamba_mod.mamba_fwd(p["mixer"], h, cfg, cache=sub)
        new_cache = None if cache is None else {"mamba": new_sub}
    x = x + y

    if spec.cross and enc is not None:
        hx = norm_fwd(p["norm_x"], x, cfg.norm, cfg.norm_eps)
        x = x + attn_mod.cross_attn_fwd(p["cross"], hx, enc, cfg)

    if "ffn" in p:
        h2 = norm_fwd(p["norm2"], x, cfg.norm, cfg.norm_eps)
        if spec.moe:
            y2 = moe_mod.moe_fwd(p["ffn"], h2, cfg)
        else:
            y2 = mlp_fwd(p["ffn"], h2, cfg.mlp, cfg.act)
        x = x + y2
    return x, new_cache


# ---------------------------------------------------------------------------
# stack: scan over units + unrolled tail
# ---------------------------------------------------------------------------

def stack_init(key, cfg: ArchConfig, layers: tuple[LayerSpec, ...],
               dtype) -> Params:
    head, pattern, n_units, tail = _unit_specs(cfg, layers)

    def unit_init(k):
        ks = jax.random.split(k, len(pattern))
        return {f"layer_{i}": layer_init(ks[i], cfg, s, dtype)
                for i, s in enumerate(pattern)}

    p: Params = {}
    head_keys = jax.random.split(jax.random.fold_in(key, 3), max(1, len(head)))
    p["head"] = [layer_init(head_keys[i], cfg, s, dtype)
                 for i, s in enumerate(head)]
    if n_units:
        p["units"] = jax.vmap(unit_init)(jax.random.split(key, n_units))
    tail_keys = jax.random.split(jax.random.fold_in(key, 7), max(1, len(tail)))
    p["tail"] = [layer_init(tail_keys[i], cfg, s, dtype)
                 for i, s in enumerate(tail)]
    return p


def stack_cache_init(cfg: ArchConfig, layers, batch, max_len, dtype) -> Params:
    head, pattern, n_units, tail = _unit_specs(cfg, layers)

    def unit_cache(_):
        return {f"layer_{i}": layer_cache_init(cfg, s, batch, max_len, dtype)
                for i, s in enumerate(pattern)}

    c: Params = {}
    c["head"] = [layer_cache_init(cfg, s, batch, max_len, dtype)
                 for s in head]
    if n_units:
        c["units"] = jax.vmap(unit_cache)(jnp.arange(n_units))
    c["tail"] = [layer_cache_init(cfg, s, batch, max_len, dtype)
                 for s in tail]
    return c


def stack_fwd(p: Params, x, cfg: ArchConfig, layers, *, positions,
              cache=None, cur_len=None, enc=None, decode=False,
              decode_axis=None, remat: bool = False, kv_start=None):
    head, pattern, n_units, tail = _unit_specs(cfg, layers)

    def unit_fwd(x, unit_p, unit_c):
        new_c = {} if unit_c is not None else None
        for i, spec in enumerate(pattern):
            sub_c = None if unit_c is None else unit_c[f"layer_{i}"]
            x, nc = layer_fwd(unit_p[f"layer_{i}"], x, cfg, spec,
                              positions=positions, cache=sub_c,
                              cur_len=cur_len, enc=enc, decode=decode,
                              decode_axis=decode_axis, kv_start=kv_start)
            if new_c is not None:
                new_c[f"layer_{i}"] = nc
        return x, new_c

    if remat:
        unit_fwd = jax.checkpoint(
            unit_fwd, policy=jax.checkpoint_policies.nothing_saveable)

    new_head = [] if cache is not None else None
    for i, spec in enumerate(head):
        sub_c = None if cache is None else cache["head"][i]
        x, nc = layer_fwd(p["head"][i], x, cfg, spec, positions=positions,
                          cache=sub_c, cur_len=cur_len, enc=enc,
                          decode=decode, decode_axis=decode_axis,
                          kv_start=kv_start)
        if new_head is not None:
            new_head.append(nc)

    if n_units:
        if cache is None:
            def body(carry, unit_p):
                y, _ = unit_fwd(carry, unit_p, None)
                return y, None
            x, _ = jax.lax.scan(body, x, p["units"])
            new_cache = None
        else:
            def body(carry, xs):
                unit_p, unit_c = xs
                y, nc = unit_fwd(carry, unit_p, unit_c)
                return y, nc
            x, new_units = jax.lax.scan(body, x, (p["units"], cache["units"]))
            new_cache = {"head": new_head, "units": new_units, "tail": []}
    else:
        new_cache = None if cache is None else {"head": new_head, "tail": []}

    for i, spec in enumerate(tail):
        sub_c = None if cache is None else cache["tail"][i]
        x, nc = layer_fwd(p["tail"][i], x, cfg, spec, positions=positions,
                          cache=sub_c, cur_len=cur_len, enc=enc,
                          decode=decode, decode_axis=decode_axis,
                          kv_start=kv_start)
        if new_cache is not None:
            new_cache["tail"].append(nc)
    return x, new_cache
