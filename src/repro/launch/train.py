"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch granite-moe-1b-a400m \
        --steps 100 --smoke            # CPU-sized sanity run
    PYTHONPATH=src python -m repro.launch.train --arch jamba-1.5-large-398b \
        --dry-run                      # lower+compile on the production mesh

On real hardware this process runs per-host under the cluster scheduler; the
launcher wires together mesh construction, sharding rules, the data pipeline,
hybrid-sync (multi-pod), async checkpointing and the heartbeat monitor.
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config on host devices")
    ap.add_argument("--dry-run", action="store_true",
                    help="lower+compile for the production mesh, no execution")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--hybrid-sync-h", type=int, default=8,
                    help="inner steps per cross-pod sync (multi-pod)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    if args.dry_run:
        # delegate to the dry-run module (it must own process start-up to set
        # XLA_FLAGS before jax initializes)
        import subprocess
        import os
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", args.arch, "--shape", "train_4k",
               "--mesh", "multi" if args.multi_pod else "single"]
        return subprocess.call(cmd, env=dict(os.environ))

    import jax
    import jax.numpy as jnp

    from repro.checkpoint import AsyncCheckpointer
    from repro.checkpoint.ckpt import latest_checkpoint, load_checkpoint
    from repro.configs import get_config
    from repro.data.pipeline import DataConfig, SyntheticTokens
    from repro.ft.heartbeat import HeartbeatMonitor
    from repro.models.registry import count_params, get_model
    from repro.optim.adamw import adamw_init
    from repro.train.trainer import make_train_step

    cfg = get_config(args.arch, smoke=args.smoke)
    api = get_model(cfg)
    print(f"[train] {cfg.name}: {count_params(cfg)/1e6:.1f}M params, "
          f"{len(jax.devices())} device(s)")

    params = api.init(jax.random.PRNGKey(0), cfg, jnp.float32)
    opt = adamw_init(params)
    start = 0
    latest = latest_checkpoint(args.ckpt_dir)
    if latest:
        state, start = load_checkpoint(latest, {"p": params, "o": opt})
        params, opt = state["p"], state["o"]
        print(f"[train] restored step {start} from {latest}")

    step_fn = jax.jit(make_train_step(cfg, api, total_steps=args.steps))
    data = SyntheticTokens(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                      global_batch=args.batch))
    ckpt = AsyncCheckpointer(args.ckpt_dir, keep=3)
    mon = HeartbeatMonitor(n_workers=1)

    t0 = time.time()
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
        params, opt, m = step_fn(params, opt, batch, jnp.asarray(step))
        mon.beat(0)
        if step % 10 == 0:
            print(f"[train] step {step}: loss {float(m['loss']):.4f} "
                  f"({time.time()-t0:.1f}s)", flush=True)
        if step and step % args.ckpt_every == 0:
            ckpt.save(step, {"p": params, "o": opt})
    ckpt.save(args.steps, {"p": params, "o": opt})
    ckpt.close()
    print("[train] done")
    return 0


if __name__ == "__main__":
    sys.exit(main())
