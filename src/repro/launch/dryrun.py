import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first initialization).

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the single-pod (16×16) and multi-pod (2×16×16) production meshes, record
memory_analysis / cost_analysis / parsed collective bytes per cell.

    PYTHONPATH=src python -m repro.launch.dryrun [--arch X] [--shape Y]
        [--mesh single|multi|both] [--out results/dryrun]

Each cell writes its JSON incrementally, so a long sweep is resumable
(--skip-done).  Failures (sharding mismatch, OOM at compile, unsupported
collective) are recorded — they are bugs in the system, per the brief.
"""

import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs.base import SHAPES, get_config
from repro.launch.mesh import make_production_mesh, set_mesh
from repro.launch.specs import build_cell, runnable

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", ".."))

# The LM preset zoo was pruned; LM cells now dry-run only via an explicit
# --arch against a registered config.  The default sweep is the paper's own
# graph workload (--graphhp / run_graphhp_cell).
ARCHS: list[str] = []


def _compile_once(cfg, shape, mesh, multi_pod, microbatches: int = 1):
    cell = build_cell(cfg, shape, mesh, multi_pod, microbatches=microbatches)
    with set_mesh(mesh):
        jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings)
        lowered = jitted.lower(*cell.args)
        compiled = lowered.compile()
    return compiled


def _probe_cfg(cfg, k_units: int):
    """Same arch with k repeating units (head/tail dropped for the probe)."""
    import dataclasses
    n = (cfg.first_k_dense or 0) + k_units * len(cfg.pattern)
    repl = {"n_layers": n}
    if cfg.family == "audio":
        repl["enc_layers"] = k_units
    return dataclasses.replace(cfg, **repl)


def _n_units(cfg) -> int:
    return (cfg.n_layers - (cfg.first_k_dense or 0)) // len(cfg.pattern)


def attn_correction_flops(cfg, shape, mesh) -> float:
    """Per-device analytic flops for full-attention layers whose flash block
    grid stays ROLLED at this sequence length (32k prefill): XLA's cost
    analysis sees one (cq × ckv) block of the scan, this adds the other
    nq·nkv−1 blocks.  Train/decode cells and window layers are unrolled or
    loop-free and need no correction (see models/attention.py)."""
    if shape.kind != "prefill":
        return 0.0
    s = shape.seq_len - (cfg.vis_tokens if cfg.family == "vlm" else 0)
    s_tot = s + (cfg.vis_tokens if cfg.family == "vlm" else 0)
    b = shape.global_batch
    cq, ckv = 512, 1024
    total = 0.0
    for spec in cfg.layers():
        if spec.mixer != "attn" or spec.attn in ("none", "window"):
            continue
        nq, nkv = s_tot // cq, s_tot // ckv
        if nq * nkv <= 64:
            continue
        hd = (cfg.qk_nope_dim + cfg.qk_rope_dim) if spec.attn == "mla" \
            else cfg.head_dim
        total += 4.0 * b * cfg.n_heads * hd * (s_tot * s_tot - cq * ckv)
    if cfg.family == "audio":                    # decoder cross-attention
        skv = cfg.enc_frames
        ckv2 = min(1024, skv)
        nq = s_tot // cq
        if nq * max(1, skv // ckv2) > 64:
            total += 4.0 * b * cfg.n_heads * cfg.head_dim * cfg.n_layers \
                * (s_tot * skv - cq * ckv2)
    # per-device: heads shard over model when divisible, batch over data
    tp = mesh.shape.get("model", 1)
    dp = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    div = dp * (tp if cfg.n_heads % max(tp, 1) == 0 else 1)
    return total / max(div, 1)


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             verbose: bool = True, probes: bool = True,
             variant: str = "", microbatches: int = 1) -> dict:
    from benchmarks.roofline import collective_bytes, roofline_terms

    mesh_tag = ("multi" if multi_pod else "single") + \
        (f"-{variant}" if variant else "")
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
           "status": "unknown"}
    try:
        cfg = get_config(arch)
        shape = SHAPES[shape_name]
    except KeyError as e:
        rec.update(status="fail", error=f"unknown arch/shape: {e}")
        return _write(rec, out_dir)

    ok, why = runnable(cfg, shape)
    if not ok:
        rec.update(status="skip", reason=why)
        return _write(rec, out_dir)

    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        mb = microbatches if shape.kind == "train" else 1
        compiled = _compile_once(cfg, shape, mesh, multi_pod, mb)
        t_full = time.time() - t0
        mem = compiled.memory_analysis()
        cost = dict(compiled.cost_analysis() or {})
        hlo = compiled.as_text()
        coll_full = collective_bytes(hlo)["total"]

        # --- scan-body extrapolation: cost_analysis counts a lax.scan body
        # once; probe with 1 and 2 units, add (n_units-1) * (B - A).
        extrap = {}
        if probes:
            # C0 = zero scanned units (embed/loss/optimizer base), C1 = one
            # unit; body = C1 - C0 is exactly one scan-body's cost whether or
            # not XLA unrolls the length-1 loop.
            c0_comp = _compile_once(_probe_cfg(cfg, 0), shape, mesh,
                                    multi_pod, mb)
            c1_comp = _compile_once(_probe_cfg(cfg, 1), shape, mesh,
                                    multi_pod, mb)
            c0 = dict(c0_comp.cost_analysis() or {})
            c1 = dict(c1_comp.cost_analysis() or {})
            coll_0 = collective_bytes(c0_comp.as_text())["total"]
            coll_1 = collective_bytes(c1_comp.as_text())["total"]
            n_u = _n_units(cfg)
            for key in ("flops", "bytes accessed"):
                body = max(0.0, float(c1.get(key, 0) or 0)
                           - float(c0.get(key, 0) or 0))
                cost[key] = float(cost.get(key, 0) or 0) + (n_u - 1) * body
            coll_full += (n_u - 1) * max(0.0, coll_1 - coll_0)
            extrap = {"n_units": n_u,
                      "unit_flops": max(0.0, float(c1.get("flops", 0) or 0)
                                        - float(c0.get("flops", 0) or 0)),
                      "unit_coll_bytes": max(0.0, coll_1 - coll_0)}

        attn_fix = attn_correction_flops(cfg, shape, mesh)
        cost["flops"] = float(cost.get("flops", 0) or 0) + attn_fix
        if mb > 1:
            # the grad-accumulation scan body is also counted once; one
            # microbatch's cost × M approximates the step (the optimizer
            # update outside the scan is over-scaled by M — negligible).
            cost["flops"] *= mb
            cost["bytes accessed"] = float(
                cost.get("bytes accessed", 0) or 0) * mb
            coll_full *= mb

        terms = roofline_terms(cost, hlo)
        terms["collective_bytes"] = coll_full
        from benchmarks.roofline import ICI_BW
        terms["t_collective_s"] = coll_full / ICI_BW
        terms["dominant"] = max(
            (("compute", terms["t_compute_s"]),
             ("memory", terms["t_memory_s"]),
             ("collective", terms["t_collective_s"])),
            key=lambda kv: kv[1])[0]
        rec.update(
            status="ok",
            compile_s=round(t_full, 1),
            devices=int(mesh.size),
            memory=_mem_dict(mem),
            roofline=terms,
            extrapolation=extrap,
            attn_correction_flops=attn_fix,
            hlo_bytes=len(hlo),
        )
        if verbose:
            print(f"[ok] {arch} {shape_name} {mesh_tag}: "
                  f"mem/dev={rec['memory'].get('bytes_per_device', 0)/2**30:.2f}GiB "
                  f"flops={terms['flops']:.3e} "
                  f"coll={terms['collective_bytes']:.3e}B "
                  f"dom={terms['dominant']} ({time.time()-t0:.0f}s)",
                  flush=True)
    except Exception as e:
        rec.update(status="fail", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
        if verbose:
            print(f"[FAIL] {arch} {shape_name} {mesh_tag}: {e}", flush=True)
    return _write(rec, out_dir)


def run_graphhp_cell(multi_pod: bool, out_dir: str, smoke: bool = False,
                     wire_bf16: bool = False, variant: str = "") -> dict:
    """The paper's own workload: one distributed hybrid global iteration."""
    from benchmarks.roofline import roofline_terms
    from repro.configs.graphhp_paper import CONFIG, SMOKE
    from repro.core.apps.sssp import SSSP
    from repro.core.distributed import (block_graph_shapes,
                                        engine_state_shapes,
                                        make_dist_hybrid_step)

    import jax.numpy as jnp
    gcfg = SMOKE if smoke else CONFIG
    mesh = make_production_mesh(multi_pod=multi_pod)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n_part = mesh.size          # one partition per device
    mesh_tag = ("multi" if multi_pod else "single") + \
        (f"-{variant}" if variant else "")
    rec = {"arch": gcfg.name, "shape": "hybrid_iteration", "mesh": mesh_tag,
           "status": "unknown"}
    t0 = time.time()
    try:
        graph = block_graph_shapes(
            n_part, gcfg.vertices_per_partition, gcfg.edges_per_partition,
            gcfg.exports_per_partition, gcfg.halo_per_partition)
        prog = SSSP(source=0)
        es = engine_state_shapes(prog, graph)
        step = make_dist_hybrid_step(
            prog, mesh, axes=axes, max_local_steps=10_000,
            wire_dtype=jnp.bfloat16 if wire_bf16 else None)
        from repro.core.distributed import _es_specs, shard0_specs
        from jax.sharding import NamedSharding
        gs = jax.tree.map(lambda s: NamedSharding(mesh, s),
                          shard0_specs(graph, axes))
        ess = jax.tree.map(lambda s: NamedSharding(mesh, s),
                           _es_specs(es, axes))
        with set_mesh(mesh):
            jitted = jax.jit(lambda g, e: step(g, e),
                             in_shardings=(gs, ess))
            lowered = jitted.lower(graph, es)
            compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        rec.update(status="ok", devices=int(mesh.size),
                   memory=_mem_dict(mem),
                   roofline=roofline_terms(cost or {}, hlo),
                   elapsed_s=round(time.time() - t0, 1))
        print(f"[ok] graphhp {mesh_tag}: {rec['roofline']['dominant']}",
              flush=True)
    except Exception as e:
        rec.update(status="fail", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
        print(f"[FAIL] graphhp {mesh_tag}: {e}", flush=True)
    return _write(rec, out_dir)


def run_sync_cell(arch: str, out_dir: str, compress: bool = True,
                  variant: str = "") -> dict:
    """Lower the hybrid-sync GLOBAL PHASE (cross-pod delta exchange with
    int8 error-feedback compression) on the multi-pod mesh — GraphHP's
    once-per-iteration exchange at training scale.  The int8 wire shows up
    directly in the parsed collective schedule."""
    import functools

    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from benchmarks.roofline import roofline_terms
    from repro.core.hybrid_sync import OuterState, global_sync, outer_init
    from repro.models.registry import param_shapes
    from repro.optim.compression import ErrorFeedbackState
    from repro.sharding.rules import param_specs
    from repro.sharding.util import named, sanitize_specs

    tag = "multi" + (f"-{variant}" if variant else "")
    rec = {"arch": arch, "shape": "global_sync", "mesh": tag,
           "status": "unknown", "compress": compress}
    t0 = time.time()
    try:
        cfg = get_config(arch)
        mesh = make_production_mesh(multi_pod=True)
        n_pods = mesh.shape["pod"]
        pshapes = param_shapes(cfg, jnp.bfloat16)
        pspecs = sanitize_specs(param_specs(pshapes), pshapes, mesh)
        pp_shapes = jax.tree.map(
            lambda l: jax.ShapeDtypeStruct((n_pods,) + l.shape, l.dtype),
            pshapes)
        pp_specs = jax.tree.map(lambda s: P("pod", *tuple(s)), pspecs,
                                is_leaf=lambda x: isinstance(x, P))
        outer_shapes = jax.eval_shape(lambda p: outer_init(p, n_pods),
                                      pshapes)
        outer_specs = OuterState(
            anchor=pspecs, momentum=pspecs,
            ef=ErrorFeedbackState(residual=pp_specs))
        # pod-REPLICATED specs pin the cross-pod gather onto the quantized
        # tensors (wire bytes = int8, not dequantized f32)
        gspecs = jax.tree.map(lambda s: P(None, *tuple(s)), pspecs,
                              is_leaf=lambda x: isinstance(x, P))
        fn = functools.partial(global_sync, compress=compress,
                               gathered_specs=gspecs)
        with set_mesh(mesh):
            compiled = jax.jit(fn, in_shardings=(
                named(pp_specs, mesh), named(outer_specs, mesh))
            ).lower(pp_shapes, outer_shapes).compile()
        cost = dict(compiled.cost_analysis() or {})
        hlo = compiled.as_text()
        terms = roofline_terms(cost, hlo)
        rec.update(status="ok", devices=int(mesh.size),
                   memory=_mem_dict(compiled.memory_analysis()),
                   roofline=terms,
                   elapsed_s=round(time.time() - t0, 1))
        print(f"[ok] {arch} global_sync {tag} compress={compress}: "
              f"coll={terms['collective_bytes']:.3e}B "
              f"dom={terms['dominant']}", flush=True)
    except Exception as e:
        rec.update(status="fail", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
        print(f"[FAIL] {arch} global_sync: {e}", flush=True)
    return _write(rec, out_dir)


def _mem_dict(mem) -> dict:
    if mem is None:
        return {}
    keys = ("temp_size_in_bytes", "argument_size_in_bytes",
            "output_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes")
    d = {k: int(getattr(mem, k, 0)) for k in keys if hasattr(mem, k)}
    d["bytes_per_device"] = (d.get("temp_size_in_bytes", 0)
                             + d.get("argument_size_in_bytes", 0))
    return d


def _write(rec: dict, out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    fn = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json"
    with open(os.path.join(out_dir, fn), "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-done", action="store_true")
    ap.add_argument("--graphhp", action="store_true",
                    help="also dry-run the paper's graph engine")
    ap.add_argument("--seq-parallel", action="store_true",
                    help="enable sequence-parallel residual streams "
                         "(the §Perf optimized variant)")
    ap.add_argument("--variant", default="",
                    help="tag appended to the mesh name in output JSONs")
    ap.add_argument("--microbatches", type=int, default=1,
                    help="grad-accumulation microbatches for train cells "
                         "(§Perf memory optimization)")
    ap.add_argument("--graphhp-wire-bf16", action="store_true",
                    help="quantize graph-engine exchange payloads to bf16 "
                         "(§Perf collective optimization)")
    args = ap.parse_args()

    if args.seq_parallel:
        from repro.sharding.util import set_seq_parallel
        set_seq_parallel(True)
        if not args.variant:
            args.variant = "sp" 

    archs = [args.arch] if args.arch else ARCHS
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    n_fail = 0
    for multi in meshes:
        tag = "multi" if multi else "single"
        for arch in archs:
            for shape in shapes:
                vtag = tag + (f"-{args.variant}" if args.variant else "")
                fn = os.path.join(args.out, f"{arch}__{shape}__{vtag}.json")
                if args.skip_done and os.path.exists(fn):
                    with open(fn) as f:
                        if json.load(f).get("status") in ("ok", "skip"):
                            continue
                rec = run_cell(arch, shape, multi, args.out,
                               variant=args.variant,
                               microbatches=args.microbatches)
                n_fail += rec["status"] == "fail"
        if args.graphhp:
            rec = run_graphhp_cell(multi, args.out,
                                   wire_bf16=args.seq_parallel is None and False
                                   or args.graphhp_wire_bf16,
                                   variant=args.variant)
            n_fail += rec["status"] == "fail"
    print(f"dry-run complete; failures: {n_fail}", flush=True)
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
