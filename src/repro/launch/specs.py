"""Dry-run cell construction: for every (arch × input-shape × mesh) build the
jittable step function + ShapeDtypeStruct inputs + shardings, without ever
allocating real arrays (ShapeDtypeStruct end to end).

Cells:
  train_4k     -> train_step   (single-pod) / vmapped-per-pod hybrid-sync
                  inner step (multi-pod; the pod axis carries stacked
                  replicas, DESIGN.md §6)
  prefill_32k  -> prefill      (batch over data [+pod])
  decode_32k   -> serve_step   (one token against a seq_len KV cache;
                  cache sequence-sharded over model)
  long_500k    -> serve_step for sub-quadratic archs only
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, SHAPES, ShapeConfig
from repro.models.registry import get_model, param_shapes
from repro.optim.adamw import AdamWState
from repro.sharding.rules import batch_spec, cache_specs, param_specs
from repro.sharding.util import named, sanitize_specs
from repro.train.trainer import make_train_step

BF16 = jnp.bfloat16


class Cell(NamedTuple):
    label: str
    fn: Callable                 # jittable
    args: tuple                  # ShapeDtypeStruct pytree(s)
    in_shardings: tuple
    donate: tuple | None = None


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _tree_sds(tree):
    return jax.tree.map(lambda l: _sds(l.shape, l.dtype), tree)


def token_shapes(cfg: ArchConfig, shape: ShapeConfig, with_labels: bool):
    """Batch ShapeDtypeStructs for this arch (modality stubs included)."""
    b, s = shape.global_batch, shape.seq_len
    if cfg.family == "vlm":
        s = s - cfg.vis_tokens          # patches + text = nominal seq_len
    batch = {"tokens": _sds((b, s), jnp.int32)}
    if with_labels:
        batch["labels"] = _sds((b, s), jnp.int32)
    if cfg.family == "vlm":
        batch["vis_embed"] = _sds((b, cfg.vis_tokens, cfg.vis_dim), BF16)
    if cfg.family == "audio":
        batch["audio_embed"] = _sds((b, cfg.enc_frames, cfg.d_model), BF16)
    return batch


def runnable(cfg: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Is this (arch, shape) cell runnable?  (see DESIGN.md §5 skip table)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("full-attention stack: 500k-token decode requires "
                       "sub-quadratic attention (run for ssm/hybrid/"
                       "sliding-window archs only)")
    return True, ""


def opt_moment_dtype(cfg: ArchConfig):
    """bf16 moments above 50B params so optimizer state fits v5e HBM."""
    from repro.models.registry import count_params
    return BF16 if count_params(cfg) > 50e9 else jnp.float32


def build_cell(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh,
               multi_pod: bool, microbatches: int = 1) -> Cell:
    api = get_model(cfg)
    pshapes = param_shapes(cfg, BF16)
    pspecs = sanitize_specs(param_specs(pshapes), pshapes, mesh)
    n_pods = mesh.shape.get("pod", 1)

    if shape.kind == "train":
        return _train_cell(cfg, api, shape, mesh, multi_pod, pshapes, pspecs,
                           n_pods, microbatches)
    if shape.kind == "prefill":
        return _prefill_cell(cfg, api, shape, mesh, multi_pod, pshapes,
                             pspecs)
    return _decode_cell(cfg, api, shape, mesh, multi_pod, pshapes, pspecs)


# ---------------------------------------------------------------------------

def _train_cell(cfg, api, shape, mesh, multi_pod, pshapes, pspecs, n_pods,
                microbatches: int = 1):
    mdt = opt_moment_dtype(cfg)
    opt_shapes = AdamWState(
        mu=jax.tree.map(lambda l: _sds(l.shape, mdt), pshapes),
        nu=jax.tree.map(lambda l: _sds(l.shape, mdt), pshapes),
        step=_sds((), jnp.int32))
    opt_specs = AdamWState(mu=pspecs, nu=pspecs, step=P())
    batch_shapes = token_shapes(cfg, shape, with_labels=True)
    bspecs = sanitize_specs(batch_spec(batch_shapes), batch_shapes, mesh)
    step_fn = make_train_step(cfg, api, microbatches=microbatches)

    if not multi_pod:
        args = (pshapes, opt_shapes, batch_shapes, _sds((), jnp.int32))
        shard = (named(pspecs, mesh), named(opt_specs, mesh),
                 named(bspecs, mesh), NamedSharding(mesh, P()))
        return Cell(f"{cfg.name}:{shape.name}", step_fn, args, shard)

    # multi-pod: hybrid-sync inner step — per-pod replicas stacked on a
    # leading pod axis, vmapped so gradient reductions stay pod-local.
    def stackP(tree, specs):
        sh = jax.tree.map(lambda l: _sds((n_pods,) + l.shape, l.dtype), tree)
        sp = jax.tree.map(lambda s: P("pod", *tuple(s)), specs,
                          is_leaf=lambda x: isinstance(x, P))
        return sh, sp

    p_sh, p_sp = stackP(pshapes, pspecs)
    o_sh, o_sp = stackP(opt_shapes, opt_specs)
    pb = shape.global_batch // n_pods
    b_sh = jax.tree.map(
        lambda l: _sds((n_pods, pb) + l.shape[1:], l.dtype), batch_shapes)
    b_sp = jax.tree.map(lambda s: P("pod", *tuple(s)), bspecs,
                        is_leaf=lambda x: isinstance(x, P))
    b_sp = sanitize_specs(b_sp, b_sh, mesh)

    from repro.core.hybrid_sync import inner_steps
    fn = partial(inner_steps, step_fn)
    args = (p_sh, o_sh, b_sh, _sds((), jnp.int32))
    shard = (named(p_sp, mesh), named(o_sp, mesh), named(b_sp, mesh),
             NamedSharding(mesh, P()))
    return Cell(f"{cfg.name}:{shape.name}", fn, args, shard)


def _prefill_cell(cfg, api, shape, mesh, multi_pod, pshapes, pspecs):
    batch_shapes = token_shapes(cfg, shape, with_labels=False)
    data_axes = ("pod", "data") if multi_pod else "data"
    bspecs = sanitize_specs(
        batch_spec(batch_shapes, data=data_axes), batch_shapes, mesh)
    cache_shapes = jax.eval_shape(
        lambda: api.init_cache(cfg, shape.global_batch, shape.seq_len, BF16))
    cspecs = sanitize_specs(
        cache_specs(cache_shapes, data=data_axes), cache_shapes, mesh)

    def fn(params, batch, cache):
        logits, cache = api.prefill(params, batch, cache, cfg)
        return logits, cache

    args = (pshapes, batch_shapes, cache_shapes)
    shard = (named(pspecs, mesh), named(bspecs, mesh), named(cspecs, mesh))
    return Cell(f"{cfg.name}:{shape.name}", fn, args, shard)


def _decode_cell(cfg, api, shape, mesh, multi_pod, pshapes, pspecs):
    b = shape.global_batch
    data_axes = ("pod", "data") if multi_pod else "data"
    cache_shapes = jax.eval_shape(
        lambda: api.init_cache(cfg, b, shape.seq_len, BF16))
    cspecs = sanitize_specs(
        cache_specs(cache_shapes, data=data_axes), cache_shapes, mesh)
    tok = _sds((b, 1), jnp.int32)
    tok_spec = sanitize_specs(P(data_axes, None), tok, mesh)

    def fn(params, token, cache, cur_len):
        return api.decode_step(params, token, cache, cur_len, cfg)

    args = (pshapes, tok, cache_shapes, _sds((), jnp.int32))
    shard = (named(pspecs, mesh), NamedSharding(mesh, tok_spec),
             named(cspecs, mesh), NamedSharding(mesh, P()))
    return Cell(f"{cfg.name}:{shape.name}", fn, args, shard)
