"""Production mesh definitions (TPU v5e).

Single pod: (data=16, model=16) = 256 chips.
Multi-pod:  (pod=2, data=16, model=16) = 512 chips — the ``pod`` axis is the
GraphHP partition axis for hybrid-sync training (DESIGN.md §6).

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before any jax initialization).
"""

from __future__ import annotations

import jax

SINGLE_POD = (16, 16)
MULTI_POD = (2, 16, 16)

# TPU v5e hardware constants used by the roofline analysis
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = MULTI_POD if multi_pod else SINGLE_POD
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def set_mesh(mesh):
    """``jax.set_mesh`` context manager across jax versions.

    Older releases have no public ambient-mesh context (the private
    ``jax._src.mesh.set_mesh`` switches on sharding-in-types and breaks
    plain ops there), so this degrades to a no-op — every call site also
    passes the mesh explicitly (shard_map / NamedSharding), which is what
    actually places the computation."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    import contextlib
    return contextlib.nullcontext(mesh)


def make_host_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many (CPU) devices exist — for tests."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // data))
    return jax.make_mesh((data, model), ("data", "model"))
