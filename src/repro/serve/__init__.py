from repro.serve.engine import PROGRAMS, Query, ServeEngine

__all__ = ["PROGRAMS", "Query", "ServeEngine"]
