"""Graph-query serving: micro-batched K-lane execution of graph queries.

A :class:`ServeEngine` loads a partitioned graph once (a built
:class:`~repro.core.graph.PartitionedGraph` or a ``.ghp`` shard directory)
and serves point queries against it — "distance from vertex s", "rank
around seed s", "what does s reach".  Queries are micro-batched: requests
for the same program are grouped, padded to a fixed lane width K, and
dispatched as ONE K-lane engine run over the semiring SpMM kernels
(:mod:`repro.core.apps.multi`), so K queries cost one graph traversal.

Compile-cache contract: the lane program is constructed with ``lanes=K``
and *no* sources — sources arrive as a traced ``(K,)`` array through
``vdata={"sources": ...}``.  One jitted executable per (program, K) pair
therefore serves every source set; padding the batch up to the nearest
width in ``lane_widths`` keeps the set of shapes (and compiles) fixed.

Two dispatch modes:

* :meth:`run` — drain the queue; each batch is one jitted
  device-side run to quiescence.  Straggler handling reuses
  :class:`repro.ft.straggler.StragglerMitigator`: every batch is issued
  against a deadline, overdue batches are re-dispatched to the next
  replica slot, and duplicate completions are suppressed (first result
  wins by work id).
* :meth:`stream` — host-stepped; yields each query as soon as ITS lane
  converges, while the rest of the batch keeps iterating.  A lane whose
  state is unchanged across one full global iteration is at its fixed
  point: any delivery that could still change it would have changed it
  during that iteration, and unchanged lanes emit only ⊕-identity
  payloads (per-lane send masking), so nothing new is in flight for them.
"""

from __future__ import annotations

import dataclasses
import itertools
import os
import shutil
from typing import Any, Callable, Iterator

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.apps.multi import (MultiSourceMonotone, PersonalizedPageRank,
                                   reachable)
from repro.core.graph import PartitionedGraph, unpack_vertex
from repro.core.runtime import quiescent
from repro.exec.checkpoint import (CheckpointHook, checkpoint_key,
                                   drop_converged_lanes, require_monotone)
from repro.exec.driver import ExecContext, ExecHook, run_engine, while_engine
from repro.exec.policy import hybrid_policy
from repro.ft.straggler import StragglerMitigator
from repro.obs import clock as obs_clock
from repro.obs.metrics import MetricsRegistry, save_registry

#: filename of the persisted serving-statistics registry (see
#: :attr:`ServeEngine.stats_path`); read it back with
#: :func:`repro.obs.metrics.load_registry`.
STATS_FILENAME = "serve_stats.json"


@dataclasses.dataclass
class Query:
    """One graph query: run ``program`` from ``source``.

    ``payload`` carries program parameters (e.g. ``tolerance`` for ppr);
    queries batch together only when program AND payload match, so every
    lane of a dispatch runs the same program instance.
    """

    request_id: int
    program: str
    source: int
    payload: dict = dataclasses.field(default_factory=dict)
    result: np.ndarray | None = None
    done: bool = False
    iterations: int | None = None

    @property
    def key(self):
        return (self.program, tuple(sorted(self.payload.items())))


@dataclasses.dataclass(frozen=True)
class ResumeEvent:
    """One killed batch picked back up from its durable checkpoint."""

    program: str
    lanes: int
    sources_digest: str
    path: str                      # checkpoint directory restored from
    iteration: int                 # global iteration the batch resumed at
    lanes_done: tuple[bool, ...]   # converged lanes dropped from the frontier


@dataclasses.dataclass(frozen=True)
class _ProgramSpec:
    factory: Callable          # (lanes, payload) -> VertexProgram
    state_key: str             # es.state entry holding the (P, Vp, L) result
    post: Callable = staticmethod(lambda col: col)


#: program registry: name -> how to build the K-lane program and read back
#: one lane of its fixed point.  All factories take ``lanes=K`` and no
#: sources — sources are traced in through vdata (see module docstring).
PROGRAMS: dict[str, _ProgramSpec] = {
    "sssp": _ProgramSpec(
        lambda lanes, p: MultiSourceMonotone(lanes=lanes, semiring="min_add",
                                             **p), "val"),
    "widest": _ProgramSpec(
        lambda lanes, p: MultiSourceMonotone(lanes=lanes, semiring="max_min",
                                             **p), "val"),
    "reach": _ProgramSpec(
        lambda lanes, p: MultiSourceMonotone(lanes=lanes, semiring="min_add",
                                             **p), "val",
        lambda col: np.asarray(reachable(col))),
    "ppr": _ProgramSpec(
        lambda lanes, p: PersonalizedPageRank(lanes=lanes, **p), "rank"),
}


class _LaneHook(ExecHook):
    """Per-lane convergence tracking for one checkpointed K-lane dispatch.

    ``done[j]`` goes (and stays) True once lane j's state is unchanged
    across one full global iteration — the same fixed-point criterion
    :meth:`ServeEngine.stream` yields on.  The mask rides every
    checkpoint's meta (via the :class:`CheckpointHook`'s ``meta_fn``); on
    resume it comes back from the manifest and the converged lanes are
    dropped from the restored frontier before the first step.
    """

    def __init__(self, engine: "ServeEngine", program: str, K: int,
                 changed: Callable):
        self.engine = engine
        self.program = program
        self.K = K
        self.changed = changed
        self.ckpt: CheckpointHook | None = None   # wired by the dispatcher
        self.done = np.zeros((K,), bool)
        self._prev = None
        self._resume_checked = False

    def before_step(self, ctx: ExecContext) -> None:
        if not self._resume_checked:
            self._resume_checked = True
            if self.ckpt is not None and self.ckpt.resumed_from is not None:
                meta = self.ckpt.restore_manifest() or {}
                self.done = np.asarray(
                    meta.get("lanes_done", self.done), bool)
                ctx.es = drop_converged_lanes(ctx.prog, ctx.es,
                                              jnp.asarray(self.done))
                self.engine.resume_events.append(ResumeEvent(
                    program=self.program, lanes=self.K,
                    sources_digest=self.ckpt.key.get("sources_digest", ""),
                    path=self.ckpt.resumed_from, iteration=ctx.iteration,
                    lanes_done=tuple(bool(b) for b in self.done)))
        self._prev = ctx.es.state

    def after_step(self, ctx: ExecContext) -> None:
        self.done = np.logical_or(
            self.done, ~np.asarray(self.changed(self._prev, ctx.es.state)))
        if self.engine.on_iteration is not None:
            self.engine.on_iteration(self.engine, self.program, self.K,
                                     ctx.iteration)


class ServeEngine:
    """Serve graph queries against one resident partitioned graph.

    Parameters
    ----------
    graph:
        A built :class:`PartitionedGraph`, or a path to a ``.ghp`` shard
        directory (loaded once via
        :func:`repro.io.pipeline.build_partitioned_graph_from_path`).
    lane_widths:
        The fixed micro-batch widths.  A batch of b queries is padded up
        to the smallest width >= b (larger groups split at the maximum
        width); the compile cache holds at most
        ``len(PROGRAMS) * len(lane_widths)`` executables.
    use_ell / max_iters:
        Forwarded to the hybrid engine per dispatch.
    straggler / dispatch_fn:
        Deadline re-dispatch state machine and an injectable dispatch
        hook ``(engine, key, K, sources, attempt) -> EngineState | None``
        (None = this attempt produced nothing before the deadline; tests
        drive this with a fake clock).
    ckpt_dir / checkpoint_every / keep:
        When ``ckpt_dir`` is set, :meth:`run` dispatches every batch
        through the checkpointing executor: the batch's state is saved
        every ``checkpoint_every`` global iterations under
        ``ckpt_dir/<program>_K<K>_<sources-digest>`` (keyed to the
        ``(program, K, sources-digest)`` tuple), a killed batch resumes
        from its latest durable checkpoint instead of recomputing (with
        already-converged lanes dropped from the restored frontier — see
        :func:`~repro.exec.checkpoint.drop_converged_lanes`), and the
        batch's checkpoint family is deleted once it completes.  Monotone
        programs only (the shared executor gate); resumes are recorded in
        ``resume_events``.
    on_iteration:
        Optional callback ``(engine, program, K, iteration)`` invoked
        after every global iteration of a checkpointed dispatch — tests
        kill a batch mid-flight by raising from it.
    registry / stats_dir:
        The engine keeps per-program serving statistics in a
        :class:`~repro.obs.metrics.MetricsRegistry` (own one by default,
        pass one to share): request inter-arrival gap and dispatched
        batch-size histograms (``serve.arrival_seconds.<program>``,
        ``serve.batch_size.<program>`` — the distributions lane-width
        autotuning needs), plus compile counts per (program, K).  With
        ``stats_dir`` set (default: ``ckpt_dir``, so the stats land
        beside the checkpoint/compile-cache state) the registry is
        persisted to ``<stats_dir>/serve_stats.json`` after every
        :meth:`run` / :meth:`stream` drain; read it back with
        :func:`repro.obs.metrics.load_registry`.
    """

    def __init__(self, graph: PartitionedGraph | str, *,
                 lane_widths: tuple[int, ...] = (1, 4, 16, 64),
                 use_ell: bool = True, max_iters: int = 10_000,
                 straggler: StragglerMitigator | None = None,
                 dispatch_fn: Callable | None = None,
                 build_kwargs: dict | None = None,
                 ckpt_dir: str | None = None, checkpoint_every: int = 1,
                 keep: int = 3, on_iteration: Callable | None = None,
                 registry: MetricsRegistry | None = None,
                 stats_dir: str | None = None):
        if isinstance(graph, str):
            from repro.io.pipeline import build_partitioned_graph_from_path
            graph = build_partitioned_graph_from_path(
                graph, **(build_kwargs or {}))
        self.graph = graph
        self.lane_widths = tuple(sorted(lane_widths))
        self.use_ell = use_ell
        self.max_iters = max_iters
        self.straggler = straggler or StragglerMitigator()
        self._dispatch_fn = dispatch_fn
        self.ckpt_dir = ckpt_dir
        self.checkpoint_every = checkpoint_every
        self.keep = keep
        self.on_iteration = on_iteration
        self.resume_events: list[ResumeEvent] = []
        self._policy = hybrid_policy(use_ell=use_ell, collect_metrics=False)
        self.queue: list[Query] = []
        self._ids = itertools.count()        # monotonic: ids never collide
        self._work_ids = itertools.count()
        self._progs: dict[tuple, Any] = {}   # (key, K) -> program instance
        self._full: dict[tuple, Callable] = {}
        self._init: dict[tuple, Callable] = {}
        self._step: dict[tuple, Callable] = {}
        self._changed: dict[tuple, Callable] = {}
        self.trace_counts: dict[tuple, int] = {}   # compiles per (key, K)
        self.registry = registry if registry is not None else MetricsRegistry()
        self.stats_dir = stats_dir if stats_dir is not None else ckpt_dir
        self._last_arrival: dict[str, float] = {}

    @property
    def stats_path(self) -> str | None:
        """Where the serving-statistics registry persists (None when no
        ``stats_dir``/``ckpt_dir`` was configured)."""
        if self.stats_dir is None:
            return None
        return os.path.join(self.stats_dir, STATS_FILENAME)

    def _persist_stats(self) -> None:
        from repro.obs.metrics import record_serve

        record_serve(self.registry, self)
        if self.stats_path is not None:
            save_registry(self.registry, self.stats_path)

    # -- admission ---------------------------------------------------------

    def submit(self, program: str, source: int, **payload) -> Query:
        """Enqueue one query; returns its (pending) :class:`Query`."""
        if program not in PROGRAMS:
            raise KeyError(f"unknown program {program!r}; have "
                           f"{sorted(PROGRAMS)}")
        q = Query(next(self._ids), program, int(source), payload)
        now = obs_clock.monotonic()
        last = self._last_arrival.get(program)
        if last is not None:
            self.registry.observe(f"serve.arrival_seconds.{program}",
                                  now - last, unit="s")
        self._last_arrival[program] = now
        self.queue.append(q)
        return q

    # -- batching ----------------------------------------------------------

    def _take_batches(self) -> list[tuple[tuple, list[Query]]]:
        """Drain the queue into (key, queries) chunks of <= max lane width,
        grouping same-program same-payload queries (submit order kept
        within a group)."""
        groups: dict[tuple, list[Query]] = {}
        for q in self.queue:
            groups.setdefault(q.key, []).append(q)
        self.queue = []
        wmax = self.lane_widths[-1]
        batches = [(key, qs[i:i + wmax])
                   for key, qs in groups.items()
                   for i in range(0, len(qs), wmax)]
        for key, qs in batches:
            self.registry.observe(f"serve.batch_size.{key[0]}", len(qs),
                                  unit="queries")
        return batches

    def _pad_width(self, b: int) -> int:
        for w in self.lane_widths:
            if w >= b:
                return w
        return self.lane_widths[-1]

    def _sources(self, queries: list[Query], K: int) -> jnp.ndarray:
        src = [q.source for q in queries]
        src += [src[-1]] * (K - len(src))    # pad lanes repeat a real source
        return jnp.asarray(src, jnp.int32)

    # -- compile cache -----------------------------------------------------

    def _program(self, key: tuple, K: int):
        ck = (key, K)
        if ck not in self._progs:
            name, payload = key
            self._progs[ck] = PROGRAMS[name].factory(K, dict(payload))
        return self._progs[ck]

    def _full_run(self, key: tuple, K: int) -> Callable:
        ck = (key, K)
        if ck not in self._full:
            prog = self._program(key, K)

            def run(sources):
                # executes at trace time only: counts compiles per (key, K)
                self.trace_counts[ck] = self.trace_counts.get(ck, 0) + 1
                vdata = {"sources": sources}
                es = self._policy.init(self.graph, prog, vdata)
                return while_engine(
                    prog,
                    lambda e: self._policy.step(self.graph, prog, e, vdata),
                    es, self.max_iters)

            self._full[ck] = jax.jit(run)
        return self._full[ck]

    def _stream_fns(self, key: tuple, K: int):
        ck = (key, K)
        if ck not in self._step:
            prog = self._program(key, K)
            self._init[ck] = jax.jit(lambda src: self._policy.init(
                self.graph, prog, {"sources": src}))
            self._step[ck] = jax.jit(lambda es, src: self._policy.step(
                self.graph, prog, es, {"sources": src}))

            def changed(prev, state):
                ch = jnp.zeros((K,), bool)
                for name in state:
                    ch = jnp.logical_or(ch, jnp.any(
                        state[name] != prev[name],
                        axis=tuple(range(state[name].ndim - 1))))
                return ch

            self._changed[ck] = jax.jit(changed)
        return self._init[ck], self._step[ck], self._changed[ck]

    # -- dispatch ----------------------------------------------------------

    def _dispatch(self, key: tuple, K: int, sources, attempt: int):
        if self._dispatch_fn is not None:
            return self._dispatch_fn(self, key, K, sources, attempt)
        return self._full_run(key, K)(sources)

    def _dispatch_checkpointed(self, key: tuple, K: int, sources):
        """One batch through the checkpointing executor: host-stepped with
        a :class:`CheckpointHook` keyed to (program, K, sources-digest),
        resuming from the latest durable checkpoint when one exists and
        deleting the batch's checkpoint family once it completes."""
        prog = self._program(key, K)
        require_monotone(prog, "K-lane resume")
        name = key[0]
        vdata = {"sources": sources}
        ckey = checkpoint_key(self.graph, prog, vdata)
        bdir = os.path.join(self.ckpt_dir,
                            f"{name}_K{K}_{ckey['sources_digest']}")
        init, step, changed = self._stream_fns(key, K)
        es0 = init(sources)
        lane = _LaneHook(self, name, K, changed)
        ckpt = CheckpointHook(
            key=ckey, ckpt_dir=bdir, every=self.checkpoint_every,
            keep=self.keep, template=es0,
            meta_fn=lambda _ctx: {"lanes_done": [bool(b)
                                                 for b in lane.done]})
        lane.ckpt = ckpt
        killed = True
        try:
            ctx = run_engine(self.graph, prog, self._policy, vdata,
                             max_iters=self.max_iters, hooks=(lane, ckpt),
                             es=es0, jit_step=lambda e: step(e, sources))
            killed = False
        finally:
            if killed:    # queued saves become durable for the resume
                try:
                    ckpt.checkpointer.wait()
                finally:
                    ckpt.checkpointer.close()
        shutil.rmtree(bdir, ignore_errors=True)   # completed: drop family
        return ctx.es

    def _dispatch_mitigated(self, key: tuple, K: int, sources):
        """One batch through the straggler state machine: issue against the
        deadline, re-dispatch to the next replica slot while overdue,
        first completion wins."""
        wid = next(self._work_ids)
        self.straggler.issue(wid, replica=0)
        attempt = 0
        while True:
            es = self._dispatch(key, K, sources, attempt)
            if es is not None and self.straggler.complete(wid):
                return es
            overdue = [w for w in self.straggler.overdue()
                       if w.work_id == wid]
            if es is None and not overdue:
                raise RuntimeError(
                    f"dispatch produced no result for work {wid} and the "
                    f"deadline ({self.straggler.deadline:.3f}s) has not "
                    f"passed — nothing to re-dispatch")
            attempt += 1

    def _finish(self, queries: list[Query], lanes: np.ndarray, iters: int):
        spec = PROGRAMS[queries[0].program]
        for j, q in enumerate(queries):
            q.result = spec.post(lanes[:, j])
            q.iterations = iters
            q.done = True

    # -- serving -----------------------------------------------------------

    def run(self) -> list[Query]:
        """Serve everything in the queue; returns the completed queries
        (each batch = one jitted K-lane run to quiescence)."""
        done: list[Query] = []
        for key, queries in self._take_batches():
            K = self._pad_width(len(queries))
            sources = self._sources(queries, K)
            if self.ckpt_dir is not None:
                es = self._dispatch_checkpointed(key, K, sources)
            else:
                es = self._dispatch_mitigated(key, K, sources)
            spec = PROGRAMS[queries[0].program]
            lanes = np.asarray(unpack_vertex(self.graph,
                                             es.state[spec.state_key]))
            self._finish(queries, lanes, int(es.counters.iterations))
            done.extend(queries)
        self._persist_stats()
        return done

    def stream(self) -> Iterator[Query]:
        """Serve the queue host-stepped, yielding each query as soon as its
        lane converges (state unchanged across one full iteration — see
        the module docstring for why that is the lane's fixed point)."""
        for key, queries in self._take_batches():
            K = self._pad_width(len(queries))
            sources = self._sources(queries, K)
            init, step, changed = self._stream_fns(key, K)
            spec = PROGRAMS[queries[0].program]
            es = init(sources)
            pending = {j: q for j, q in enumerate(queries)}
            it = 0
            while pending and it < self.max_iters:
                prev = es.state
                es = step(es, sources)
                it += 1
                if bool(quiescent(self._program(key, K), es)):
                    lane_done = np.ones((K,), bool)
                else:
                    lane_done = ~np.asarray(changed(prev, es.state))
                if not any(lane_done[j] for j in pending):
                    continue
                lanes = np.asarray(unpack_vertex(
                    self.graph, es.state[spec.state_key]))
                for j in [j for j in pending if lane_done[j]]:
                    q = pending.pop(j)
                    q.result = spec.post(lanes[:, j])
                    q.iterations = it
                    q.done = True
                    yield q
            if pending:          # max_iters safety valve: flush as-is
                lanes = np.asarray(unpack_vertex(
                    self.graph, es.state[spec.state_key]))
                for j, q in sorted(pending.items()):
                    q.result = spec.post(lanes[:, j])
                    q.iterations = it
                    q.done = True
                    yield q
        self._persist_stats()
