"""Batched serving engine.

Requests are grouped into fixed-size batches, left-padded to a common
timeline (per-slot ``start`` offsets keep RoPE positions and masks exact —
see models/attention.py kv_start), prefilled once, then decoded in lockstep;
finished slots (EOS or budget) are masked out.  Straggler mitigation hooks in
through ft.straggler: per-batch deadlines + re-dispatch with duplicate
suppression (meaningful with >1 replica; the state machine is exercised in
tests with a fake clock).

Greedy or temperature sampling; decode is a single jitted step reused across
the batch lifetime, so serving costs 1 compile per (arch, batch-shape).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.registry import ModelAPI


@dataclasses.dataclass
class Request:
    request_id: int
    prompt: np.ndarray              # (L,) int32
    max_new: int = 32
    result: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ArchConfig, api: ModelAPI, params,
                 max_batch: int = 8, max_len: int = 256,
                 eos_id: int = -1, dtype=jnp.float32):
        self.cfg, self.api, self.params = cfg, api, params
        self.max_batch, self.max_len, self.eos_id = max_batch, max_len, eos_id
        self.dtype = dtype
        self.queue: list[Request] = []
        self._prefill = jax.jit(
            lambda p, b, c: api.prefill(p, b, c, cfg))
        self._decode = jax.jit(
            lambda p, t, c, n, s: api.decode_step(p, t, c, n, cfg,
                                                  kv_start=s))

    def submit(self, prompt: np.ndarray, max_new: int = 32) -> Request:
        req = Request(len(self.queue), np.asarray(prompt, np.int32), max_new)
        self.queue.append(req)
        return req

    def _make_batch(self, reqs: list[Request]):
        lmax = max(len(r.prompt) for r in reqs)
        b = len(reqs)
        toks = np.zeros((b, lmax), np.int32)
        start = np.zeros((b,), np.int32)
        for i, r in enumerate(reqs):
            pad = lmax - len(r.prompt)
            toks[i, pad:] = r.prompt
            start[i] = pad
        return {"tokens": jnp.asarray(toks), "start": jnp.asarray(start)}, lmax

    def run(self, temperature: float = 0.0, seed: int = 0) -> list[Request]:
        """Serve everything in the queue; returns completed requests."""
        rng = np.random.RandomState(seed)
        done: list[Request] = []
        while self.queue:
            batch_reqs = self.queue[: self.max_batch]
            self.queue = self.queue[self.max_batch:]
            batch, lmax = self._make_batch(batch_reqs)
            cache = self.api.init_cache(self.cfg, len(batch_reqs),
                                        self.max_len, self.dtype)
            logits, cache = self._prefill(self.params, batch, cache)
            tok = self._sample(logits[:, -1], temperature, rng)
            for i, r in enumerate(batch_reqs):
                r.result.append(int(tok[i]))
            max_new = max(r.max_new for r in batch_reqs)
            alive = np.ones(len(batch_reqs), bool)
            for t in range(1, max_new):
                if not alive.any():
                    break
                logits, cache = self._decode(self.params, tok[:, None],
                                             cache, lmax + t - 1,
                                             batch["start"])
                tok = self._sample(logits[:, 0], temperature, rng)
                for i, r in enumerate(batch_reqs):
                    if not alive[i]:
                        continue
                    nxt = int(tok[i])
                    r.result.append(nxt)
                    if nxt == self.eos_id or len(r.result) >= r.max_new:
                        alive[i] = False
                        r.done = True
            for r in batch_reqs:
                r.done = True
                done.append(r)
        return done

    @staticmethod
    def _sample(logits, temperature, rng):
        logits = np.asarray(logits, np.float32)
        if temperature <= 0.0:
            return logits.argmax(axis=-1).astype(np.int32)
        z = logits / temperature
        z = z - z.max(axis=-1, keepdims=True)
        p = np.exp(z)
        p /= p.sum(axis=-1, keepdims=True)
        return np.array([rng.choice(len(row), p=row) for row in p],
                        np.int32)
