"""Checkpointing as an executor hook.

One :class:`CheckpointHook` serves every run path: the fault-tolerant
driver (`run_hybrid_ft`), the K-lane serving layer (`ServeEngine`), and
anything else built on :func:`repro.exec.driver.run_engine`.  Checkpoints
are keyed by :func:`checkpoint_key` — graph content digest + program name,
extended with ``(lanes, sources_digest)`` for K-lane programs so a killed
multi-query batch can only resume into the identical (program, K, sources)
dispatch — and validated by :func:`validate_key` on restore.

:func:`require_monotone` is the single engine gate shared by every path
that re-enters a computation with less than the full saved message state
(elastic restore's re-announce, the K-lane frontier drop): only monotone
(min/max-combiner) programs absorb re-delivered or dropped values without
moving their fixed point.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.checkpoint.ckpt import (AsyncCheckpointer, CheckpointError,
                                   checkpoint_bytes, latest_checkpoint,
                                   load_checkpoint, read_manifest)
from repro.core.runtime import EngineState
from repro.exec.driver import ExecContext, ExecHook

__all__ = ["checkpoint_key", "validate_key", "require_monotone",
           "drop_converged_lanes", "CheckpointHook"]


def checkpoint_key(graph, prog, vdata: Any = None) -> dict:
    """What a checkpoint is keyed to.

    Always: the graph content digest (the same ``io.digest.graph_digest``
    the ingest benchmark pins builder identity with) + the program's class
    name.  K-lane programs additionally pin ``lanes`` and the
    ``sources_digest`` of their (K,) sources/seeds (static or via
    ``vdata={"sources": ...}``) — one checkpoint family per (program, K,
    sources) dispatch, so a resumed batch can never restore another
    batch's state.
    """
    from repro.core.apps.multi import sources_digest
    from repro.io.digest import graph_digest

    key = {"graph_digest": graph_digest(graph),
           "program": type(prog).__name__}
    lanes = max((int(getattr(ch, "lanes", 0) or 0) for ch in prog.channels),
                default=0)
    if lanes:
        key["lanes"] = lanes
        src = None
        if vdata is not None and "sources" in vdata:
            src = vdata["sources"]
        else:
            src = getattr(prog, "sources", None)
            if src is None:
                src = getattr(prog, "seeds", None)
        if src is not None:
            key["sources_digest"] = sources_digest(src)
    return key


def validate_key(meta: dict, key: dict, path: str) -> None:
    """Refuse to restore a checkpoint whose meta disagrees with ``key`` on
    any keyed field (graph digest, program, lanes, sources digest)."""
    for k, want in key.items():
        if meta.get(k) != want:
            raise CheckpointError(
                f"{path}: checkpoint is keyed to {k}={meta.get(k)!r}, this "
                f"run has {want!r} — refusing to restore state from a "
                f"different graph/program")


def require_monotone(prog, what: str) -> None:
    """The one engine gate for partial-state re-entry (elastic restore,
    K-lane frontier drop): monotone (min/max-combiner) programs only."""
    bad = [ch.name for ch in prog.channels if ch.combiner not in
           ("min", "max")]
    if bad:
        raise CheckpointError(
            f"{what} re-announces every vertex's current value on the next "
            f"exchange, which only monotone (min/max-combiner) programs "
            f"absorb; channels {bad} do not qualify")


def drop_converged_lanes(prog, es: EngineState,
                         done: jax.Array) -> EngineState:
    """Exclude already-converged lanes from a restored frontier.

    ``done`` is the (L,) per-lane convergence mask saved with the
    checkpoint (a lane whose state was unchanged across one full iteration
    is at its fixed point).  Done lanes' pending payloads and export
    values are reset to the channel's ⊕-identity, so on resume they emit
    nothing: the bootstrap combine is an identity, per-lane send gating
    stays off, and no message rides the next exchange for them.  Callers
    must have passed :func:`require_monotone` — for monotone channels a
    dropped re-delivery can only re-confirm the fixed point, so per-lane
    results stay bit-identical to the uninterrupted run.
    """
    done = jnp.asarray(done, bool)
    pending = dict(es.pending)
    export_out = dict(es.export_out)
    for ch in prog.channels:
        if not getattr(ch, "lanes", 0):
            continue
        comps, has = pending[ch.name]
        comps = tuple(
            jnp.where(done, jnp.asarray(ident, c.dtype), c)
            for c, (_, ident) in zip(comps, ch.components))
        pending[ch.name] = (comps, has)
        _, ident = ch.components[0]
        export_out[ch.name] = jnp.where(
            done, jnp.asarray(ident, export_out[ch.name].dtype),
            export_out[ch.name])
    return dataclasses.replace(es, pending=pending, export_out=export_out)


class CheckpointHook(ExecHook):
    """Executor hook: resume on start, checkpoint every N iterations,
    flush on exit.

    ``meta_fn(ctx) -> dict`` extends each checkpoint's meta (the serving
    layer records its per-lane convergence mask here); ``restore()`` is
    public so a failure-recovery hook can roll the run back to the latest
    durable checkpoint mid-loop.
    """

    def __init__(self, *, key: dict, ckpt_dir: str | None = None,
                 checkpointer: AsyncCheckpointer | None = None,
                 every: int = 1, keep: int = 3, resume: bool = True,
                 template: EngineState | None = None,
                 shardings: Any = None,
                 meta_fn: Callable[[ExecContext], dict] | None = None):
        self.key = dict(key)
        self._own = checkpointer is None and ckpt_dir is not None
        self.checkpointer = (AsyncCheckpointer(ckpt_dir, keep=keep)
                             if self._own else checkpointer)
        self.base = ckpt_dir if ckpt_dir is not None else getattr(
            self.checkpointer, "base", None)
        self.every = every
        self.resume = resume
        self.template = template
        self.shardings = shardings
        self.meta_fn = meta_fn
        self.resumed_from: str | None = None

    # -- restore -----------------------------------------------------------

    def restore(self) -> tuple[EngineState, int, str | None, int]:
        """(state, iteration, path, bytes_read) from the latest durable
        checkpoint, or ``(template, 0, None, 0)`` when none exists."""
        if self.checkpointer is not None:
            self.checkpointer.wait()   # in-flight writes become durable
        path = latest_checkpoint(self.base) if self.base else None
        if path is None:
            return self.template, 0, None, 0
        validate_key(read_manifest(path).get("meta", {}), self.key, path)
        es, step = load_checkpoint(path, self.template,
                                   shardings=self.shardings)
        return es, int(step), path, checkpoint_bytes(path)

    def restore_manifest(self) -> dict | None:
        """Meta of the latest durable checkpoint (lane masks etc.), or
        None when no checkpoint exists."""
        path = latest_checkpoint(self.base) if self.base else None
        return None if path is None else read_manifest(path).get("meta", {})

    # -- hook protocol -----------------------------------------------------

    def on_start(self, ctx: ExecContext) -> None:
        if self.template is None:
            self.template = ctx.es
        if self.resume and self.base is not None:
            es, it, path, _ = self.restore()
            if path is not None:
                ctx.es, ctx.iteration = es, it
                self.resumed_from = path

    def after_step(self, ctx: ExecContext) -> None:
        if self.checkpointer is not None and \
                ctx.iteration % self.every == 0:
            meta = {**self.key, "iteration": ctx.iteration}
            if self.meta_fn is not None:
                meta.update(self.meta_fn(ctx))
            self.checkpointer.save(ctx.iteration, ctx.es, meta=meta)

    def on_exit(self, ctx: ExecContext) -> None:
        if self.checkpointer is not None:
            self.checkpointer.wait()
            if self._own:
                self.checkpointer.close()
