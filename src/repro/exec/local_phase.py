"""The GraphHP local phase: pseudo-supersteps to per-partition quiescence.

This module owns everything that happens *inside* a partition between two
synchronization points — the participation/scheduling masks, the fully-fused
Pallas local phases (`pr_step` / `min_step`), and the generic
``lax.while_loop`` fallback — behind one entry point, :func:`local_phase`.
The executor's hybrid policy calls it once per global iteration; the A/B
benchmark calls :func:`fused_step_fn` directly so the kernels it times are
the exact ones the engine runs.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.graph import PartitionedGraph
from repro.core.runtime import (EngineState, _has_any_pending, apply_phase,
                                deliver, ell_send_accounting)
from repro.core.vertex_program import StepInfo, VertexProgram

__all__ = ["local_phase", "fused_step_fn", "participation_mask",
           "partition_running", "fused_local_kernel"]


def participation_mask(graph: PartitionedGraph, prog: VertexProgram) -> jax.Array:
    """Vertices eligible for local-phase computation (paper §4.2: boundary
    vertices join local phases for incremental algorithms)."""
    if prog.boundary_participates:
        return graph.vertex_mask
    return jnp.logical_and(graph.vertex_mask, jnp.logical_not(graph.is_boundary))


def partition_running(graph, prog, es, participate, vdata) -> jax.Array:
    """(P,) — does any participating vertex still need a pseudo-superstep?"""
    act = es.active
    gonly = prog.global_only_active(es.state, vdata)
    if gonly is not None:
        act = jnp.logical_and(act, jnp.logical_not(gonly))
    need = jnp.logical_or(act, _has_any_pending(prog, es.pending))
    return jnp.any(jnp.logical_and(need, participate), axis=1)


def fused_local_kernel(graph: PartitionedGraph, prog: VertexProgram,
                       use_ell: bool, max_local_steps: int) -> str | None:
    """Static gate for the fully-fused local phase: the kernel name
    ('pr_step' | 'min_step') when the program declares one and the graph
    carries a dense-base sliced-ELL layout, else None (generic loop)."""
    from repro.kernels.common import MONOTONE_SEMIRINGS

    if not (use_ell and graph.has_ell and max_local_steps > 0
            and len(prog.channels) == 1 and prog.boundary_participates
            and graph.local_ell[0].dense):
        return None
    kern = getattr(prog, "fused_kernel", None)
    if kern == "min_step":
        ch = prog.channels[0]
        # any monotone semiring fuses, provided the channel's combiner is
        # that semiring's ⊕ (the kernel's adopt-if-better state update)
        if (ch.semiring not in MONOTONE_SEMIRINGS
                or ch.combiner != ch.semiring.split("_")[0]):
            return None
        # unlike plain ELL delivery (only *messages* ride float32, judged
        # per bin), the fused loop keeps the whole vertex state in float32 —
        # integer states need every vertex id exactly representable
        (dt, _), = ch.components
        if (jnp.issubdtype(jnp.dtype(dt), jnp.integer)
                and graph.n_vertices - 1 > (1 << 24)):
            return None
    return kern if kern in ("pr_step", "min_step") else None


def _spill_extra(graph: PartitionedGraph, prog, ch, slices, views, out_d,
                 send, p, interpret):
    """⊕-combined spill-bin contributions (P*Vp, ...) for a fused kernel's
    ``extra`` operand — None when the layout is a single dense bin.  Lane
    channels keep their trailing (L,) axis through the spill SpMM."""
    if len(slices) == 1:
        return None
    from repro.core.runtime import ell_combine_bins
    from repro.kernels.common import SEMIRINGS

    _, _, ident = SEMIRINGS[ch.semiring]
    x = prog.ell_payload(ch, out_d, send)
    x = x.reshape((-1,) + x.shape[2:]).astype(jnp.float32)
    extra = jnp.full((p * graph.vp,) + x.shape[1:], ident, jnp.float32)
    return ell_combine_bins(prog, ch, slices[1:], views[1:], x, extra, p,
                            interpret)


def fused_step_fn(graph: PartitionedGraph, prog: VertexProgram, kind: str,
                  p: int):
    """The single fused pseudo-superstep over the graph's sliced-ELL layout
    — the one implementation both the engine local phases and the A/B
    benchmark run, so they cannot drift apart.

    'pr_step': ``step(rank, delta, send) -> (rank', d_in, send')``;
    'min_step': ``step(x, send) -> (x', d_in, send')``.  All arrays are
    (p, Vp) — or (p, Vp, L) for a lane channel, with per-lane ``send``
    gating inside the kernel (the SpMM dispatch) — and spill bins beyond
    the dense base feed the kernel's ``extra`` operand through
    :func:`_spill_extra`.
    """
    from repro.core.runtime import slice_flat
    from repro.kernels.common import default_interpret

    ch = prog.channels[0]
    vp = graph.vp
    slices = graph.local_ell
    views = [slice_flat(s, graph, p) for s in slices]
    _, idx, msk = views[0]
    interpret = default_interpret()
    flat = lambda a: a.reshape((-1,) + a.shape[2:])
    unflat = lambda a: a.reshape((p, vp) + a.shape[1:])

    if kind == "pr_step":
        from repro.kernels.pr_step import fused_pr_step

        val = slices[0].val.reshape(-1, slices[0].kb)

        def step(rank, delta, send):
            extra = _spill_extra(graph, prog, ch, slices, views,
                                 {ch.name: delta}, send, p, interpret)
            r, d, s = fused_pr_step(
                idx, val, msk, flat(delta), flat(send),
                flat(rank), extra, damping=prog.damping, tol=prog.tol,
                interpret=interpret)
            return unflat(r), unflat(d), unflat(s)
    elif kind == "min_step":
        from repro.kernels.min_step import fused_min_step

        val = prog.ell_edge_values(ch, slices[0].val).reshape(
            -1, slices[0].kb)

        def step(x, send):
            extra = _spill_extra(graph, prog, ch, slices, views,
                                 {ch.name: x}, send, p, interpret)
            xn, d, s = fused_min_step(
                idx, val, msk, flat(x), flat(send), extra=extra,
                semiring=ch.semiring, interpret=interpret)
            return unflat(xn), unflat(d), unflat(s)
    else:  # pragma: no cover
        raise ValueError(kind)
    return step, slices, views


def _fused_pr_local_phase(
    graph: PartitionedGraph,
    prog: VertexProgram,
    es: EngineState,
    running0: jax.Array,
    max_local_steps: int,
    collect_metrics: bool,
) -> EngineState:
    """Local phase fused through the `pr_step` Pallas kernel.

    One kernel call performs deliver(pseudo-superstep s) + apply(s+1): the
    incremental-PageRank pseudo-superstep chain gather -> segment-sum ->
    add -> compare collapses into a single VMEM-resident pass per step, so
    the iterated-a-lot inner loop pays one HBM round-trip instead of four
    and zero message-accounting reductions when ``collect_metrics=False``.

    Kernel contract (asserted by ``prog.fused_kernel == 'pr_step'``):
    single 'sum' channel, always-valid emit ``x[src] * w`` with w > 0 and
    sent deltas > tol > 0 (so delivered sums are strictly positive and
    d_in > 0 <=> has-message), apply is ``rank += delta; send = delta >
    tol``, never self-activating, additive SourceCombine, boundary
    vertices participating.  The bootstrap below runs the first apply
    (consuming the inbox filled by the global phase) in plain jnp, then the
    while-loop iterates the fused kernel; trip count, pseudo-superstep and
    message counters match the generic path exactly.
    """
    p = es.send.shape[0]
    ch = prog.channels[0]
    kstep, slices, views = fused_step_fn(graph, prog, "pr_step", p)
    tol = prog.tol
    name = ch.name
    # lane channels: send flags ride the loop per-lane (the kernel's SpMM
    # gating); vertex-level views (`vany`) feed scheduling and counters,
    # `ex` broadcasts vertex masks against lane arrays.  Scalar channels:
    # both are the identity and the loop below is the original computation.
    lanes = ch.lanes
    ex = (lambda a: a[..., None]) if lanes else (lambda a: a)
    vany = (lambda a: jnp.any(a, axis=-1)) if lanes else (lambda a: a)

    (p0,), has0 = es.pending[name]
    # bootstrap: apply_1 consumes the inbox (payload is 0 wherever ~has,
    # the sum identity, so the adds need no explicit compute mask)
    rank = es.state["rank"] + p0
    send = p0 > tol
    if lanes:
        # the lane program pre-neutralizes out per lane (sub-tol lanes
        # carry 0), mirroring PersonalizedPageRank.apply
        out_delta = jnp.where(ex(has0), jnp.where(send, p0, 0.0),
                              es.out["delta"])
    else:
        out_delta = jnp.where(has0, p0, es.out["delta"])
    exp_out = es.export_out["delta"] + jnp.where(send, p0, 0.0)
    exp_send = jnp.logical_or(es.export_send, vany(send))
    c0 = es.counters

    def cond(carry):
        _, _, _, _, _, _, _, running, _, _, k, _ = carry
        return jnp.logical_and(jnp.any(running), k < max_local_steps)

    def body(carry):
        (rank, delta, send, has, out_d, eo, esend, running, pseudo,
         metrics, k, _prev) = carry
        # pre-step apply state, so a max_local_steps cutoff can roll the
        # final fused apply back to generic-path semantics (see below)
        prev = (rank, out_d, eo, esend, send)
        rank_n, d_in, send_n = kstep(rank, delta, send)
        net_local, mem = metrics
        if collect_metrics:
            # exact parity with the dense accounting: has-flags from the
            # send gather, one combined local group per messaged dst (a
            # K-lane message counts once — vertex-level send)
            has_n, mem_inc = ell_send_accounting(graph, slices, views,
                                                 vany(send).reshape(-1), p)
            net_local = net_local + jnp.sum(has_n).astype(jnp.int32)
            mem = mem + mem_inc
        else:
            has_n = vany(d_in > 0)     # positive-contribution invariant
        if lanes:
            out_d = jnp.where(ex(has_n), jnp.where(send_n, d_in, 0.0), out_d)
        else:
            out_d = jnp.where(has_n, d_in, out_d)
        eo = eo + jnp.where(send_n, d_in, 0.0)
        esend = jnp.logical_or(esend, vany(send_n))
        running = jnp.any(has_n, axis=1)
        pseudo = pseudo + running.astype(jnp.int32)
        return (rank_n, d_in, send_n, has_n, out_d, eo, esend, running,
                pseudo, (net_local, mem), k + 1, prev)

    carry0 = (rank, p0, send, has0, out_delta, exp_out, exp_send, running0,
              c0.pseudo_supersteps,
              (jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32)),
              jnp.zeros((), jnp.int32),
              (rank, out_delta, exp_out, exp_send, send))
    (rank, delta, send, has, out_delta, exp_out, exp_send, _, pseudo,
     (net_local, mem), _,
     (rank_p, out_p, eo_p, esend_p, send_p)) = jax.lax.while_loop(
        cond, body, carry0)

    # max_local_steps cutoff: the kernel has already folded the final
    # delivery into rank/out/export, but the generic path leaves it
    # pending-only for the next iteration's apply — roll the non-pending
    # state back one step so the delivery is not applied twice.  At a
    # quiescent exit `has` is all-False and this is the identity.
    cut = jnp.any(has)
    rank = jnp.where(cut, rank_p, rank)
    out_delta = jnp.where(cut, out_p, out_delta)
    exp_out = jnp.where(cut, eo_p, exp_out)
    exp_send = jnp.where(cut, esend_p, exp_send)
    send = jnp.where(cut, send_p, send)

    counters = dataclasses.replace(
        c0, pseudo_supersteps=pseudo,
        net_local_messages=c0.net_local_messages + net_local,
        mem_messages=c0.mem_messages + mem)
    return dataclasses.replace(
        es, state={"rank": rank}, out={"delta": out_delta}, send=vany(send),
        pending={name: ((delta,), has)},
        export_out={"delta": exp_out}, export_send=exp_send,
        counters=counters)


def _fused_min_local_phase(
    graph: PartitionedGraph,
    prog: VertexProgram,
    es: EngineState,
    running0: jax.Array,
    max_local_steps: int,
    collect_metrics: bool,
) -> EngineState:
    """Local phase fused through the `min_step` Pallas kernel — the
    monotone-semiring twin of :func:`_fused_pr_local_phase` serving SSSP,
    WCC, widest-path and random-walk style adopt-if-better programs.

    One kernel call performs deliver(pseudo-superstep s) + apply(s+1): the
    relax chain gather -> segment-⊕ -> ⊕ -> compare collapses into a
    single VMEM-resident pass per step, with the same cutoff-rollback
    semantics as the PageRank fusion.

    Kernel contract (asserted by ``prog.fused_kernel == 'min_step'``):
    single single-component channel whose combiner is the ⊕ of its monotone
    semiring (min_add/min_mul/max_add/max_min) and whose state, out and
    channel share one name and one value (``out == state``), always-valid
    emit ``x[src] ⊗ edge_val`` (``ell_payload`` / ``ell_edge_values`` define
    the factorization), apply is ``new = state ⊕ msg; send = new improves
    state``, never self-activating, keep-latest SourceCombine (the default
    ``accumulate_export``), boundary vertices participating.  The whole
    state rides the loop as float32 and is cast back under the vertex mask
    on exit (the gate in ``fused_local_kernel`` guarantees integer states
    stay exact).
    """
    from repro.kernels.common import SEMIRINGS, semiring_improves

    ch = prog.channels[0]
    name = ch.name
    dt, ident = ch.components[0]
    combine, _, sr_ident = SEMIRINGS[ch.semiring]
    improves = semiring_improves(ch.semiring)
    p = es.send.shape[0]
    kstep, slices, views = fused_step_fn(graph, prog, "min_step", p)
    vmask = graph.vertex_mask
    # lane channels: per-lane send flags ride the loop (SpMM gating in the
    # kernel); `vany` collapses to the vertex level for scheduling/export
    # (the generic keep-latest SourceCombine gates on vertex send) and `ex`
    # broadcasts vertex masks against lane arrays.  Scalar channels: both
    # are the identity and the loop is the original computation.
    lanes = ch.lanes
    ex = (lambda a: a[..., None]) if lanes else (lambda a: a)
    vany = (lambda a: jnp.any(a, axis=-1)) if lanes else (lambda a: a)

    (m0,), has0 = es.pending[name]
    x0 = es.state[name].astype(jnp.float32)
    eo0 = es.export_out[name]
    # bootstrap: apply_1 consumes the inbox (payload is the ⊕-identity
    # wherever ~has, so the combines need no explicit compute mask)
    m0f = jnp.where(ex(has0), m0.astype(jnp.float32), sr_ident)
    x1 = combine(x0, m0f)
    send1 = improves(x1, x0)
    eo_f = jnp.where(ex(vany(send1)), x1, eo0.astype(jnp.float32))
    esend1 = jnp.logical_or(es.export_send, vany(send1))
    c0 = es.counters

    def cond(carry):
        _, _, _, _, _, _, running, _, _, k, _ = carry
        return jnp.logical_and(jnp.any(running), k < max_local_steps)

    def body(carry):
        (x, d_in, send, has, eo, esend, running, pseudo, metrics, k,
         _prev) = carry
        # pre-step apply state for the max_local_steps cutoff rollback
        prev = (x, eo, esend, send)
        x_n, d_n, send_n = kstep(x, send)
        net_local, mem = metrics
        if collect_metrics:
            has_n, mem_inc = ell_send_accounting(graph, slices, views,
                                                 vany(send).reshape(-1), p)
            net_local = net_local + jnp.sum(has_n).astype(jnp.int32)
            mem = mem + mem_inc
        else:
            # some sender beat the identity (any lane)
            has_n = vany(improves(d_n, sr_ident))
        eo = jnp.where(ex(vany(send_n)), x_n, eo)
        esend = jnp.logical_or(esend, vany(send_n))
        running = jnp.any(has_n, axis=1)
        pseudo = pseudo + running.astype(jnp.int32)
        return (x_n, d_n, send_n, has_n, eo, esend, running, pseudo,
                (net_local, mem), k + 1, prev)

    carry0 = (x1, m0f, send1, has0, eo_f, esend1, running0,
              c0.pseudo_supersteps,
              (jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32)),
              jnp.zeros((), jnp.int32),
              (x1, eo_f, esend1, send1))
    (x, d_in, send, has, eo, esend, _, pseudo, (net_local, mem), _,
     (x_p, eo_p, esend_p, send_p)) = jax.lax.while_loop(cond, body, carry0)

    # max_local_steps cutoff: roll the final fused apply back so the still-
    # pending delivery is not applied twice (identity at a quiescent exit)
    cut = jnp.any(has)
    x = jnp.where(cut, x_p, x)
    eo = jnp.where(cut, eo_p, eo)
    esend = jnp.where(cut, esend_p, esend)
    send = jnp.where(cut, send_p, send)

    # leave the float32 loop: integer states cast back exactly (gate) under
    # the vertex mask, so padded sentinel slots keep their original bits
    state = jnp.where(ex(vmask), x.astype(dt), es.state[name])
    exp_out = jnp.where(ex(vmask), eo.astype(dt), eo0)
    payload = jnp.where(ex(has), d_in.astype(dt), jnp.asarray(ident, dt))

    counters = dataclasses.replace(
        c0, pseudo_supersteps=pseudo,
        net_local_messages=c0.net_local_messages + net_local,
        mem_messages=c0.mem_messages + mem)
    return dataclasses.replace(
        es, state={name: state}, out={name: state}, send=vany(send),
        pending={name: ((payload,), has)},
        export_out={name: exp_out}, export_send=esend,
        counters=counters)


def local_phase(
    graph: PartitionedGraph,
    prog: VertexProgram,
    es: EngineState,
    vdata: Any,
    superstep,
    max_local_steps: int = 100_000,
    use_ell: bool = True,
    collect_metrics: bool = True,
) -> EngineState:
    """Pseudo-supersteps to per-partition quiescence (Algorithm 2's inner
    while loop) — the defining move of the hybrid policy.

    Dispatches to a fully-fused Pallas phase when the program/graph qualify
    (:func:`fused_local_kernel`), else iterates the generic
    apply -> local-deliver ``lax.while_loop`` with a per-partition
    ``running`` mask so pseudo-superstep counts stay faithful.
    """
    participate = participation_mask(graph, prog)
    running0 = partition_running(graph, prog, es, participate, vdata)
    c0 = es.counters
    es = dataclasses.replace(es, counters=dataclasses.replace(
        c0, pseudo_supersteps=c0.pseudo_supersteps + running0.astype(jnp.int32)))

    fused = fused_local_kernel(graph, prog, use_ell, max_local_steps)
    if fused == "pr_step":
        return _fused_pr_local_phase(graph, prog, es, running0,
                                     max_local_steps, collect_metrics)
    if fused == "min_step":
        return _fused_min_local_phase(graph, prog, es, running0,
                                      max_local_steps, collect_metrics)

    def cond(carry):
        es_, running, k = carry
        return jnp.logical_and(jnp.any(running), k < max_local_steps)

    def body(carry):
        es_, running, k = carry
        mask = jnp.logical_and(participate, running[:, None])
        info_l = StepInfo(superstep=superstep, pseudo_step=k + 1,
                          phase="local")
        es_ = apply_phase(graph, prog, es_, mask, info_l, vdata)
        es_, _ = deliver(graph, prog, es_, edges="local", use_ell=use_ell,
                         collect_metrics=collect_metrics)
        running = partition_running(graph, prog, es_, mask, vdata)
        c = es_.counters
        es_ = dataclasses.replace(es_, counters=dataclasses.replace(
            c, pseudo_supersteps=c.pseudo_supersteps + running.astype(jnp.int32)))
        return es_, running, k + 1

    es, _, _ = jax.lax.while_loop(
        cond, body, (es, running0, jnp.zeros((), jnp.int32)))
    return es
