"""The executor layer: one driver loop, engines as policies, checkpointing
as a hook.  See docs/architecture.md for the dataflow."""

from repro.exec.checkpoint import (CheckpointHook, checkpoint_key,
                                   drop_converged_lanes, require_monotone,
                                   validate_key)
from repro.exec.driver import ExecContext, ExecHook, run_engine, while_engine
from repro.exec.iteration import (am_superstep, bsp_superstep,
                                  hybrid_iteration, init_hybrid)
from repro.exec.local_phase import fused_local_kernel, fused_step_fn, \
    local_phase
from repro.exec.policy import (EnginePolicy, POLICIES, am_policy, bsp_policy,
                               hybrid_policy, make_policy)

__all__ = [
    "run_engine", "while_engine", "ExecContext", "ExecHook",
    "EnginePolicy", "POLICIES", "bsp_policy", "am_policy", "hybrid_policy",
    "make_policy",
    "bsp_superstep", "am_superstep", "hybrid_iteration", "init_hybrid",
    "local_phase", "fused_step_fn", "fused_local_kernel",
    "CheckpointHook", "checkpoint_key", "validate_key", "require_monotone",
    "drop_converged_lanes",
]
