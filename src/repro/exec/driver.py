"""The one superstep executor.

Every run path in the reproduction — ``run_bsp``, ``run_am``,
``run_hybrid``, the fault-tolerant driver, the serving layer and the
shard_map distributed step — is this loop with a different
:class:`~repro.exec.policy.EnginePolicy` and hook set:

    init -> [ while not quiescent and iteration < max_iters: step ] -> done

Two lowerings of the same loop:

* :func:`run_engine` — host-driven; checks ``quiescent`` once per step and
  calls :class:`ExecHook` methods between steps (checkpointing, failure
  detection, per-lane convergence tracking, ...).  ``device_loop=True``
  jits the whole loop instead (one host sync at the end) when no hook
  needs to run between steps.
* :func:`while_engine` — the bare ``lax.while_loop`` form, for embedding
  inside a larger jitted computation (the serving layer's full-run path).

The driver is the only place an outer iteration loop exists; the policy
modules contain step bodies, the engine modules contain configuration.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

from repro.core.runtime import EngineState, quiescent
from repro.exec.policy import EnginePolicy

__all__ = ["run_engine", "while_engine", "ExecContext", "ExecHook"]


@dataclasses.dataclass
class ExecContext:
    """Mutable view of a run, handed to every hook.

    ``iteration`` mirrors ``int(es.counters.iterations)`` after every step
    and restore; ``tick`` counts host-loop trips (including trips a hook
    turned into a restore instead of a step), so failure-detection clocks
    can advance even when no progress is made.
    """

    graph: Any
    prog: Any
    policy: EnginePolicy | None
    vdata: Any
    es: EngineState
    iteration: int = 0
    tick: int = 0


class ExecHook:
    """Executor hook protocol — subclass and override what you need.

    ``on_start`` runs once before the loop (a resume hook may replace
    ``ctx.es`` / ``ctx.iteration`` here); ``before_step`` runs every tick
    and may return ``False`` to skip this tick's step (e.g. a failure was
    detected and the state was rolled back instead); ``after_step`` runs
    after each completed step (checkpoint cadence lives here); ``on_exit``
    runs once after the loop (flush/close).
    """

    def on_start(self, ctx: ExecContext) -> None: ...

    def before_step(self, ctx: ExecContext) -> bool | None: ...

    def after_step(self, ctx: ExecContext) -> None: ...

    def on_exit(self, ctx: ExecContext) -> None: ...


def while_engine(prog, step: Callable, es: EngineState, max_iters: int):
    """The device-side loop body: iterate ``step`` (``es -> es``) until
    quiescence or ``max_iters``, as a ``lax.while_loop``.  Not jitted here
    — embed it in whatever jit owns the surrounding computation."""
    def cond(e):
        return jnp.logical_and(jnp.logical_not(quiescent(prog, e)),
                               e.counters.iterations < max_iters)

    return jax.lax.while_loop(cond, step, es)


def run_engine(
    graph,
    prog,
    policy: EnginePolicy,
    vdata: Any = None,
    *,
    max_iters: int = 100_000,
    hooks: Sequence[ExecHook] = (),
    es: EngineState | None = None,
    jit_step: Callable | None = None,
    device_loop: bool = False,
) -> ExecContext:
    """Run ``policy`` to quiescence; returns the final :class:`ExecContext`
    (``ctx.es``, ``ctx.iteration``).

    ``es`` seeds the loop (default: ``policy.init``); ``jit_step``
    overrides the jitted step ``es -> es`` (callers with a compile cache —
    the serving layer — or a shard_map step pass their own).
    ``device_loop=True`` lowers the whole loop into one jit; hooks then
    only see ``on_start`` / ``on_exit`` (there is no host boundary between
    steps), so it rejects hooks that override the per-step methods.
    """
    if es is None:
        es = policy.init(graph, prog, vdata)
    if jit_step is None:
        jit_step = jax.jit(
            lambda e: policy.step(graph, prog, e, vdata))

    ctx = ExecContext(graph=graph, prog=prog, policy=policy, vdata=vdata,
                      es=es, iteration=int(es.counters.iterations))
    for h in hooks:
        h.on_start(ctx)

    if device_loop:
        stepwise = [h for h in hooks
                    if type(h).before_step is not ExecHook.before_step
                    or type(h).after_step is not ExecHook.after_step]
        if stepwise:
            raise ValueError(
                f"device_loop=True runs with no host boundary between "
                f"steps; hooks {[type(h).__name__ for h in stepwise]} "
                f"override before_step/after_step and need the host loop")
        ctx.es = jax.jit(
            lambda e: while_engine(prog, jit_step, e, max_iters))(ctx.es)
        ctx.iteration = int(ctx.es.counters.iterations)
    else:
        while (ctx.iteration < max_iters
               and not bool(quiescent(prog, ctx.es))):
            ctx.tick += 1
            # evaluate every hook (clocks must advance even when another
            # hook consumes the tick), then skip the step if any said so
            if False in [h.before_step(ctx) for h in hooks]:
                continue            # a hook consumed this tick (restore)
            ctx.es = jit_step(ctx.es)
            ctx.iteration = int(ctx.es.counters.iterations)
            for h in hooks:
                h.after_step(ctx)

    for h in hooks:
        h.on_exit(ctx)
    return ctx
