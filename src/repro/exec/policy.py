"""Engines as policy objects.

GraphHP, Hama and AM-Hama share one execution skeleton — initialize, then
iterate a synchronization-delimited step until quiescence — and differ only
in what one step does.  An :class:`EnginePolicy` captures exactly that
difference: an ``init`` building the starting :class:`EngineState` and a
``step`` advancing it by one superstep / global iteration.  The driver
(:func:`repro.exec.driver.run_engine`) owns the loop, the halt rule, and
the hook points; every public runner (``run_bsp`` / ``run_am`` /
``run_hybrid`` / ``run_hybrid_ft`` / ``ServeEngine`` / the shard_map
distributed step) is a thin configuration built from one of the
constructors below.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

from repro.core.runtime import init_state
from repro.exec.iteration import (am_superstep, bsp_superstep,
                                  hybrid_iteration, init_hybrid)

__all__ = ["EnginePolicy", "bsp_policy", "am_policy", "hybrid_policy",
           "POLICIES", "make_policy"]


@dataclasses.dataclass(frozen=True)
class EnginePolicy:
    """One engine = two functions.

    ``init(graph, prog, vdata) -> EngineState`` builds iteration 0's state;
    ``step(graph, prog, es, vdata) -> EngineState`` advances one
    synchronization-delimited unit (a superstep, or a global iteration with
    its pseudo-superstep local phase) and must increment
    ``counters.iterations`` by exactly 1 — the driver's halt rule and
    checkpoint cadence count on it.  Both must be jittable.
    """

    name: str
    init: Callable
    step: Callable


def bsp_policy(use_ell: bool = True, collect_metrics: bool = True,
               gather_table: Callable | None = None) -> EnginePolicy:
    """Hama: one exchange + one bulk Compute() per superstep."""
    return EnginePolicy(
        name="bsp",
        init=lambda graph, prog, vdata: init_state(graph, prog, vdata),
        step=partial(_bsp_step, gather_table=gather_table, use_ell=use_ell,
                     collect_metrics=collect_metrics))


def am_policy(use_ell: bool = True, collect_metrics: bool = True,
              gather_table: Callable | None = None) -> EnginePolicy:
    """AM-Hama: Hama's cadence + in-memory same-superstep local delivery."""
    return EnginePolicy(
        name="am",
        init=lambda graph, prog, vdata: init_state(graph, prog, vdata),
        step=partial(_am_step, gather_table=gather_table, use_ell=use_ell,
                     collect_metrics=collect_metrics))


def hybrid_policy(use_ell: bool = True, collect_metrics: bool = True,
                  max_local_steps: int = 100_000,
                  gather_table: Callable | None = None,
                  wire_dtype=None) -> EnginePolicy:
    """GraphHP: one exchange per global iteration, then pseudo-supersteps
    to per-partition quiescence (fused Pallas local phase where eligible)."""
    return EnginePolicy(
        name="hybrid",
        init=partial(_hybrid_init, use_ell=use_ell,
                     collect_metrics=collect_metrics),
        step=partial(_hybrid_step, gather_table=gather_table,
                     max_local_steps=max_local_steps, wire_dtype=wire_dtype,
                     use_ell=use_ell, collect_metrics=collect_metrics))


# module-level step adapters (not closures) so a policy built twice with the
# same knobs still hashes/compares usefully and partials stay picklable
def _bsp_step(graph, prog, es, vdata, **kw):
    return bsp_superstep(graph, prog, es, vdata, **kw)


def _am_step(graph, prog, es, vdata, **kw):
    return am_superstep(graph, prog, es, vdata, **kw)


def _hybrid_step(graph, prog, es, vdata, **kw):
    return hybrid_iteration(graph, prog, es, vdata, **kw)


def _hybrid_init(graph, prog, vdata, **kw):
    return init_hybrid(graph, prog, vdata, **kw)


POLICIES: dict[str, Callable[..., EnginePolicy]] = {
    "bsp": bsp_policy,
    "am": am_policy,
    "hybrid": hybrid_policy,
}


def make_policy(name: str, **knobs: Any) -> EnginePolicy:
    """Build a policy by engine name ('bsp' | 'am' | 'hybrid')."""
    if name not in POLICIES:
        raise KeyError(f"unknown engine {name!r}; have {sorted(POLICIES)}")
    return POLICIES[name](**knobs)
