"""The superstep bodies behind every run path.

Each function here is one jittable unit of progress — a Hama superstep
(:func:`bsp_superstep`), an AM-Hama superstep (:func:`am_superstep`), or a
GraphHP global iteration (:func:`hybrid_iteration`) — expressed over the
same runtime primitives (``exchange`` / ``deliver`` / ``apply_phase``) and
differing only in *policy*: how often they synchronize and how far the
local phase runs between synchronizations.  The executor
(:mod:`repro.exec.driver`) iterates whichever body its
:class:`~repro.exec.policy.EnginePolicy` names; nothing here loops to
quiescence.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax.numpy as jnp

from repro.core.graph import PartitionedGraph
from repro.core.runtime import (EngineState, apply_phase, deliver,
                                ell_channels, exchange, init_state)
from repro.core.vertex_program import StepInfo, VertexProgram
from repro.exec.local_phase import local_phase

__all__ = ["bsp_superstep", "am_superstep", "hybrid_iteration",
           "init_hybrid", "reset_export", "exchange_phase", "bsp_delivery",
           "bsp_compute", "hybrid_remote_delivery", "hybrid_global_phase",
           "hybrid_local"]


def reset_export(prog: VertexProgram, es: EngineState) -> EngineState:
    """Clear the export buffer after an exchange: values to the channel
    identity, send flags off.  Every superstep body starts with this."""
    return dataclasses.replace(
        es, export_out=prog.export_identity(es.export_out),
        export_send=jnp.zeros_like(es.export_send))


def _deliver_split(graph, prog, es, use_ell, collect_metrics):
    """Superstep delivery: remote + local halves when a channel can ride
    the Pallas ELL layouts (combine groups never mix local and remote
    edges, so counters are unchanged), else one dense 'all' pass."""
    if use_ell and ell_channels(graph, prog, es.out, es.send):
        es, _ = deliver(graph, prog, es, edges="remote", use_ell=True,
                        collect_metrics=collect_metrics)
        es, _ = deliver(graph, prog, es, edges="local", use_ell=True,
                        collect_metrics=collect_metrics)
    else:
        es, _ = deliver(graph, prog, es, edges="all",
                        collect_metrics=collect_metrics)
    return es


# ---------------------------------------------------------------------------
# phase functions: each superstep body below is a composition of these.
# The observability layer (:mod:`repro.obs`) jits and times them one by one
# to attribute wall time to exchange / delivery / compute / local phases —
# they must compose to *exactly* the unsplit bodies (the golden parity
# suite pins the composed results bit-identical).
# ---------------------------------------------------------------------------

def exchange_phase(graph, prog, es, gather_table=None,
                   wire_dtype=None) -> EngineState:
    """The one distributed communication of a superstep / global iteration:
    gather export buffers through the halo plan, then clear them."""
    es = exchange(graph, es, gather_table, wire_dtype=wire_dtype)
    return reset_export(prog, es)


def bsp_delivery(graph, prog, es, use_ell: bool = True,
                 collect_metrics: bool = True) -> EngineState:
    """Hama's delivery: every edge (remote + local halves on the ELL path,
    one dense 'all' pass otherwise)."""
    return _deliver_split(graph, prog, es, use_ell, collect_metrics)


def bsp_compute(graph, prog, es, vdata) -> EngineState:
    """Hama's bulk Compute() over all (active ∨ messaged) vertices, plus
    the superstep counter bump."""
    info = StepInfo(superstep=es.counters.iterations + 1, pseudo_step=0,
                    phase="superstep")
    es = apply_phase(graph, prog, es, graph.vertex_mask, info, vdata)
    c = es.counters
    return dataclasses.replace(
        es, counters=dataclasses.replace(
            c, iterations=c.iterations + 1,
            pseudo_supersteps=c.pseudo_supersteps + 1))


def hybrid_remote_delivery(graph, prog, es, use_ell: bool = True,
                           collect_metrics: bool = True) -> EngineState:
    """GraphHP: deliver the just-exchanged remote messages into pending."""
    es, _ = deliver(graph, prog, es, edges="remote", use_ell=use_ell,
                    collect_metrics=collect_metrics)
    return es


def hybrid_global_phase(graph, prog, es, vdata, use_ell: bool = True,
                        collect_metrics: bool = True) -> EngineState:
    """GraphHP's global phase: boundary vertices Compute() exactly once,
    then their same-partition messages are delivered for the immediate
    local phase (paper §4.2)."""
    it = es.counters.iterations + 1
    gmask = graph.is_boundary
    gonly = prog.global_only_active(es.state, vdata)
    if gonly is not None:
        gmask = jnp.logical_or(gmask, jnp.logical_and(es.active, gonly))
    info_g = StepInfo(superstep=it, pseudo_step=0, phase="global")
    es = apply_phase(graph, prog, es, gmask, info_g, vdata)
    es, _ = deliver(graph, prog, es, edges="local", use_ell=use_ell,
                    collect_metrics=collect_metrics)
    return es


def hybrid_local(graph, prog, es, vdata, max_local_steps: int = 100_000,
                 use_ell: bool = True,
                 collect_metrics: bool = True) -> EngineState:
    """GraphHP's local phase — pseudo-supersteps to per-partition
    quiescence — plus the global-iteration counter bump."""
    it = es.counters.iterations + 1
    es = local_phase(graph, prog, es, vdata, it,
                     max_local_steps=max_local_steps, use_ell=use_ell,
                     collect_metrics=collect_metrics)
    c = es.counters
    return dataclasses.replace(
        es, counters=dataclasses.replace(c, iterations=c.iterations + 1))


def bsp_superstep(
    graph: PartitionedGraph,
    prog: VertexProgram,
    es: EngineState,
    vdata: Any,
    gather_table: Callable | None = None,
    use_ell: bool = True,
    collect_metrics: bool = True,
) -> EngineState:
    """One Hama superstep: exchange -> deliver(all) -> Compute(all).

    With ``use_ell`` (the default) the delivery splits into remote + local
    halves so each half can dispatch to its Pallas ELL layout.  Combine
    groups never mix local and remote edges, so counters are unchanged;
    float 'sum' inboxes may differ in the last bit (different reduction
    order).
    """
    es = exchange_phase(graph, prog, es, gather_table)
    es = bsp_delivery(graph, prog, es, use_ell, collect_metrics)
    return bsp_compute(graph, prog, es, vdata)


def am_superstep(
    graph: PartitionedGraph,
    prog: VertexProgram,
    es: EngineState,
    vdata: Any,
    gather_table: Callable | None = None,
    use_ell: bool = True,
    collect_metrics: bool = True,
) -> EngineState:
    """One AM-Hama superstep: Hama's cadence + asynchronous in-memory
    delivery between two ordered half-blocks A|B (the Grace mechanism,
    vectorized — see :mod:`repro.core.engine_am`)."""
    es = exchange_phase(graph, prog, es, gather_table)
    es = bsp_delivery(graph, prog, es, use_ell, collect_metrics)

    slot = jnp.arange(graph.vp)[None, :]
    half_a = jnp.logical_and(graph.vertex_mask, slot < graph.vp // 2)
    half_b = jnp.logical_and(graph.vertex_mask,
                             jnp.logical_not(slot < graph.vp // 2))

    info = StepInfo(superstep=es.counters.iterations + 1, pseudo_step=0,
                    phase="superstep")
    es = apply_phase(graph, prog, es, half_a, info, vdata)
    es, _ = deliver(graph, prog, es, edges="local", use_ell=use_ell,
                    collect_metrics=collect_metrics)   # A's messages, in memory
    es = apply_phase(graph, prog, es, half_b, info, vdata)
    # es.send is now B's senders only: A's in-partition messages were already
    # delivered above (delivering them again next superstep would double-count
    # for sum channels); A's cross-partition messages travel via the export
    # buffer, which accumulated A's sends in its apply_phase.

    c = es.counters
    return dataclasses.replace(
        es, counters=dataclasses.replace(
            c, iterations=c.iterations + 1,
            pseudo_supersteps=c.pseudo_supersteps + 1))


def hybrid_iteration(
    graph: PartitionedGraph,
    prog: VertexProgram,
    es: EngineState,
    vdata: Any,
    gather_table: Callable | None = None,
    max_local_steps: int = 100_000,
    wire_dtype=None,
    use_ell: bool = True,
    collect_metrics: bool = True,
) -> EngineState:
    """One global iteration: exchange -> global phase -> local phase.

    ``use_ell`` (the default) routes remote- and local-phase delivery
    through the Pallas ELL kernels for semiring-declared channels (and the
    entire local phase through the fused `pr_step` / `min_step` kernels for
    programs declaring ``fused_kernel``); ``collect_metrics=False`` drops
    the paper's message accounting from the hot loop (counters other than
    iterations/pseudo-supersteps stay put).
    """
    # -- 1. the one distributed exchange ---------------------------------
    es = exchange_phase(graph, prog, es, gather_table, wire_dtype=wire_dtype)
    es = hybrid_remote_delivery(graph, prog, es, use_ell=use_ell,
                                collect_metrics=collect_metrics)

    # -- 2. global phase: boundary vertices, exactly once -----------------
    # (plus any program-declared global-only-active vertices: interior
    #  vertices waiting on cross-partition round-trips tick here)
    es = hybrid_global_phase(graph, prog, es, vdata, use_ell=use_ell,
                             collect_metrics=collect_metrics)

    # -- 3. local phase: pseudo-supersteps until per-partition quiescence --
    return hybrid_local(graph, prog, es, vdata,
                        max_local_steps=max_local_steps, use_ell=use_ell,
                        collect_metrics=collect_metrics)


def init_hybrid(graph: PartitionedGraph, prog: VertexProgram, vdata: Any,
                use_ell: bool = True,
                collect_metrics: bool = True) -> EngineState:
    """Initialization iteration (iteration 0): same as Hama's first superstep;
    in-partition messages go to pending for iteration 1's phases, crossing
    messages ride the export buffer."""
    es = init_state(graph, prog, vdata)
    es, _ = deliver(graph, prog, es, edges="local", use_ell=use_ell,
                    collect_metrics=collect_metrics)
    return es
