"""Fennel-style streaming partitioner (Tsourakakis et al., WSDM'14).

One pass over the vertices in random order; each vertex lands in the
partition maximizing

    |N(v) ∩ P_p|  −  α·γ·|P_p|^(γ−1)

i.e. greedy neighbour affinity minus a superlinear balance term whose
weight ``α = m·k^(γ−1)/n^γ`` scales with the average degree (dense graphs
pay a larger penalty per occupied slot, which is what keeps hubs from
dragging everything into one part — the degree-penalized interpolation
between pure greedy and pure balance).  A hard capacity ``ν·n/k`` caps the
slack regardless of scores, so the output always satisfies
``balance ≤ balance_slack`` (up to the ceil needed for feasibility).

Streaming means O(E) total work and one vertex-at-a-time decisions — the
regime where the partitioner itself must not cost more than the first few
supersteps it saves.  The scoring loop is blocked: neighbour-affinity
counts against already-assigned vertices are batched per block of the
visit permutation (one vectorized scatter-add over the block's
concatenated adjacency), the balance penalty is cached and updated one
entry per assignment, and only the rare within-block neighbours are
corrected per vertex — the per-vertex Python work no longer touches the
full adjacency row.  The assignment sequence (and therefore the labeling)
is identical to the naive sequential scan for a given seed.

``fennel_partition`` consumes an in-memory edge list; ``fennel_partition_csr``
runs the same core over any CSR adjacency — including the mmap-backed
external CSR that ``repro.io`` builds chunk-by-chunk for graphs that never
fit in memory.
"""

from __future__ import annotations

import numpy as np

from repro.partition.seed import undirected_csr

__all__ = ["fennel_partition", "fennel_partition_csr"]


def fennel_partition(edges: np.ndarray, n_vertices: int, n_partitions: int,
                     seed: int = 0, gamma: float = 1.5,
                     balance_slack: float = 1.1) -> np.ndarray:
    """Stream vertices once, greedily assigning by the Fennel objective."""
    edges = np.asarray(edges, dtype=np.int64)
    if n_partitions <= 1 or n_vertices == 0:
        return np.zeros(n_vertices, dtype=np.int32)
    starts, adj_val = undirected_csr(edges, n_vertices)
    return fennel_partition_csr(starts, adj_val, n_vertices, n_partitions,
                                n_edges=len(edges), seed=seed, gamma=gamma,
                                balance_slack=balance_slack)


def fennel_partition_csr(starts: np.ndarray, adj_val: np.ndarray,
                         n_vertices: int, n_partitions: int, *,
                         n_edges: int, seed: int = 0, gamma: float = 1.5,
                         balance_slack: float = 1.1,
                         block: int = 4096) -> np.ndarray:
    """Fennel over a symmetrized CSR adjacency (``starts`` (V+1,),
    ``adj_val`` (2E,) — plain arrays or ``np.memmap``).  Neighbour *order*
    is irrelevant (affinity is a count), so any CSR with the right
    per-vertex neighbour multiset — in-memory or externally built — yields
    the same labeling."""
    n, k = int(n_vertices), int(n_partitions)
    if k <= 1 or n == 0:
        return np.zeros(n, dtype=np.int32)
    starts = np.asarray(starts, dtype=np.int64)

    m = max(int(n_edges), 1)
    alpha = m * (k ** (gamma - 1.0)) / float(max(n, 1) ** gamma)
    cap = max(balance_slack * n / k,
              float(-(-n // k)))              # feasibility: >= ceil(n/k)

    part = np.full(n, -1, dtype=np.int32)
    sizes = np.zeros(k, dtype=np.float64)
    # effective penalty: the balance term, +inf once a partition hits the
    # hard cap (finite_count − inf == −inf, exactly the masked score the
    # per-vertex formulation computes), updated one entry per assignment
    eff = alpha * gamma * np.power(sizes, gamma - 1.0)
    eff[sizes + 1.0 > cap] = np.inf
    rng = np.random.RandomState(seed)
    perm = rng.permutation(n)
    rank = np.empty(n, dtype=np.int64)
    rank[perm] = np.arange(n)

    for b0 in range(0, n, block):
        vs = perm[b0:b0 + block]
        deg = starts[vs + 1] - starts[vs]
        total = int(deg.sum())
        off = np.zeros(len(vs) + 1, dtype=np.int64)
        np.cumsum(deg, out=off[1:])
        # gather the block's concatenated adjacency in one fancy index
        gidx = (np.repeat(starts[vs], deg)
                + np.arange(total) - np.repeat(off[:-1], deg))
        nbrs = np.asarray(adj_val[gidx], dtype=np.int64)
        owner = np.repeat(np.arange(len(vs)), deg)
        # affinity against everything assigned before this block, batched
        # (flat bincount: same integer counts as a scatter-add, ~10-30x
        # the throughput of ufunc.at's per-element dispatch)
        npart = part[nbrs]
        assigned = npart >= 0
        base = np.bincount(owner[assigned] * k + npart[assigned],
                           minlength=len(vs) * k
                           ).reshape(len(vs), k).astype(np.float64)
        # neighbours that will be assigned *within* this block need the
        # per-vertex correction below (a vanishing fraction: block/n)
        inblk = (rank[nbrs] >= b0) & (rank[nbrs] < b0 + len(vs))
        inb_cnt = np.bincount(owner[inblk], minlength=len(vs))
        for i in range(len(vs)):
            if inb_cnt[i]:
                # counts are exact in float64, so summing them before the
                # penalty subtraction keeps the score bit-identical to the
                # naive one-vertex-at-a-time evaluation
                score = base[i].copy()
                ib = nbrs[off[i]:off[i + 1]][inblk[off[i]:off[i + 1]]]
                pp = part[ib]
                pp = pp[pp >= 0]
                if len(pp):
                    score += np.bincount(pp, minlength=k)
                score -= eff
            else:
                score = base[i] - eff
            p = int(np.argmax(score))
            part[vs[i]] = p
            sizes[p] += 1.0
            eff[p] = (np.inf if sizes[p] + 1.0 > cap
                      else alpha * gamma * np.power(sizes[p], gamma - 1.0))
    return part
