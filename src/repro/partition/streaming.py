"""Fennel-style streaming partitioner (Tsourakakis et al., WSDM'14).

One pass over the vertices in random order; each vertex lands in the
partition maximizing

    |N(v) ∩ P_p|  −  α·γ·|P_p|^(γ−1)

i.e. greedy neighbour affinity minus a superlinear balance term whose
weight ``α = m·k^(γ−1)/n^γ`` scales with the average degree (dense graphs
pay a larger penalty per occupied slot, which is what keeps hubs from
dragging everything into one part — the degree-penalized interpolation
between pure greedy and pure balance).  A hard capacity ``ν·n/k`` caps the
slack regardless of scores, so the output always satisfies
``balance ≤ balance_slack`` (up to the ceil needed for feasibility).

Streaming means O(E) total work and one vertex-at-a-time decisions — the
regime where the partitioner itself must not cost more than the first few
supersteps it saves.
"""

from __future__ import annotations

import numpy as np

from repro.partition.seed import undirected_csr

__all__ = ["fennel_partition"]


def fennel_partition(edges: np.ndarray, n_vertices: int, n_partitions: int,
                     seed: int = 0, gamma: float = 1.5,
                     balance_slack: float = 1.1) -> np.ndarray:
    """Stream vertices once, greedily assigning by the Fennel objective."""
    edges = np.asarray(edges, dtype=np.int64)
    k = int(n_partitions)
    if k <= 1 or n_vertices == 0:
        return np.zeros(n_vertices, dtype=np.int32)
    starts, adj_val = undirected_csr(edges, n_vertices)

    m = max(len(edges), 1)
    alpha = m * (k ** (gamma - 1.0)) / float(max(n_vertices, 1) ** gamma)
    cap = max(balance_slack * n_vertices / k,
              float(-(-n_vertices // k)))          # feasibility: >= ceil(n/k)

    part = np.full(n_vertices, -1, dtype=np.int32)
    sizes = np.zeros(k, dtype=np.float64)
    rng = np.random.RandomState(seed)
    for v in rng.permutation(n_vertices):
        nbr = part[adj_val[starts[v]:starts[v + 1]]]
        score = np.bincount(nbr[nbr >= 0], minlength=k).astype(np.float64)
        score -= alpha * gamma * np.power(sizes, gamma - 1.0)
        score[sizes + 1.0 > cap] = -np.inf   # placing v must stay under cap
        p = int(np.argmax(score))
        part[v] = p
        sizes[p] += 1.0
    return part
