"""Multilevel partitioner: the Metis recipe on numpy arrays.

Three phases, all vectorized except the (small) move loops:

  1. **Coarsen** — repeated mutual heavy-edge matching: every vertex
     proposes its heaviest incident edge (ties broken by a seeded jitter);
     mutual proposals merge.  Each level roughly halves the graph while
     preserving the cut structure, because a heavy edge inside a coarse
     vertex can never be cut.
  2. **Partition the coarse graph** — ``bfs_partition`` (the repo's seed
     grower) on the coarsest graph, where its O(n) Python loop is cheap.
  3. **Uncoarsen + refine** — project labels back level by level; at each
     level a few greedy boundary-refinement passes apply single-vertex
     moves that strictly reduce the (weighted) cut subject to a balance
     cap.  Gains are kept exact by locking the moved vertex's neighbourhood
     for the rest of the pass (a moved neighbour would invalidate the
     precomputed connectivity row); overweight partitions may additionally
     shed vertices on negative gain until they fit the cap.

The finest level carries unit vertex weights, so the closing rebalance can
always restore ``balance ≤ balance_slack`` exactly.
"""

from __future__ import annotations

import numpy as np

from repro.partition.seed import bfs_partition

__all__ = ["multilevel_partition"]


def _undirected_weighted(edges: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Unique undirected (u < v) pairs with multiplicity as edge weight."""
    und = np.sort(np.asarray(edges, dtype=np.int64), axis=1)
    und = und[und[:, 0] != und[:, 1]]
    if not len(und):
        return np.zeros((0, 2), np.int64), np.zeros(0, np.float64)
    uv, w = np.unique(und, axis=0, return_counts=True)
    return uv, w.astype(np.float64)


def _heavy_edge_matching(uv: np.ndarray, w: np.ndarray, n: int,
                         rng: np.random.RandomState) -> np.ndarray:
    """Coarse-vertex map (n,) from one round of mutual heaviest-edge
    proposals; unmatched vertices map to themselves."""
    ids = np.arange(n, dtype=np.int64)
    if not len(uv):
        return ids
    jitter = 1.0 + 1e-6 * rng.uniform(size=len(w))
    s = np.concatenate([uv[:, 0], uv[:, 1]])
    t = np.concatenate([uv[:, 1], uv[:, 0]])
    ww = np.concatenate([w * jitter, w * jitter])
    order = np.lexsort((-ww, s))
    s, t = s[order], t[order]
    first = np.unique(s, return_index=True)[1]
    cand = np.full(n, -1, dtype=np.int64)
    cand[s[first]] = t[first]
    ok = cand >= 0
    mutual = ok & (cand[np.where(ok, cand, 0)] == ids)
    rep = np.where(mutual & (ids > cand), cand, ids)
    return rep


def _coarsen(uv, w, vweight, rng):
    """One matching level -> (coarse uv, w, vweight, fine->coarse map)."""
    n = len(vweight)
    rep = _heavy_edge_matching(uv, w, n, rng)
    roots, cmap = np.unique(rep, return_inverse=True)
    nc = len(roots)
    cvw = np.bincount(cmap, weights=vweight, minlength=nc)
    cu, cv = cmap[uv[:, 0]], cmap[uv[:, 1]]
    keep = cu != cv
    cuv = np.sort(np.stack([cu[keep], cv[keep]], axis=1), axis=1)
    if len(cuv):
        cuv, inv = np.unique(cuv, axis=0, return_inverse=True)
        cw = np.bincount(inv, weights=w[keep], minlength=len(cuv))
    else:
        cuv, cw = np.zeros((0, 2), np.int64), np.zeros(0, np.float64)
    return cuv, cw, cvw, cmap.astype(np.int64)


def _refine(uv: np.ndarray, w: np.ndarray, vweight: np.ndarray,
            part: np.ndarray, k: int, cap: float, passes: int) -> np.ndarray:
    """Greedy boundary refinement: exact-gain single-vertex moves that
    reduce the weighted cut (or shed weight from over-cap partitions),
    neighbourhoods locked per pass so applied gains stay exact."""
    n = len(vweight)
    if not len(uv) or k <= 1:
        return part
    s = np.concatenate([uv[:, 0], uv[:, 1]])
    t = np.concatenate([uv[:, 1], uv[:, 0]])
    ww = np.concatenate([w, w])
    order = np.argsort(s, kind="stable")
    s_s, t_s, w_s = s[order], t[order], ww[order]
    starts = np.searchsorted(s_s, np.arange(n + 1))

    sizes = np.bincount(part, weights=vweight, minlength=k).astype(np.float64)
    ids = np.arange(n)
    for _ in range(passes):
        conn = np.zeros((n, k), dtype=np.float64)
        np.add.at(conn, (s, part[t]), ww)
        cur = conn[ids, part]
        conn[ids, part] = -np.inf
        best = conn.argmax(axis=1).astype(np.int32)
        gain = conn[ids, best] - cur
        over = sizes[part] > cap
        cand = np.nonzero((gain > 0) | over)[0]
        if not cand.size:
            break
        cand = cand[np.argsort(-gain[cand], kind="stable")]
        locked = np.zeros(n, dtype=bool)
        moved = 0
        for vtx in cand:
            if locked[vtx]:
                continue
            p0, p1 = int(part[vtx]), int(best[vtx])
            if p1 == p0:
                continue
            wv = float(vweight[vtx])
            fits = sizes[p1] + wv <= cap
            sheds = sizes[p0] > cap and sizes[p1] + wv < sizes[p0]
            if not (fits or sheds):
                continue
            if gain[vtx] <= 0 and sizes[p0] <= cap:
                continue
            part[vtx] = p1
            sizes[p0] -= wv
            sizes[p1] += wv
            moved += 1
            locked[vtx] = True
            locked[t_s[starts[vtx]:starts[vtx + 1]]] = True
        if not moved:
            break
    return part


def _rebalance(uv, w, part, k, cap):
    """Hard cap enforcement at the finest (unit-weight) level: move the
    cheapest-to-move vertices out of over-cap partitions into the least
    loaded ones until every partition fits."""
    n = len(part)
    sizes = np.bincount(part, minlength=k).astype(np.float64)
    if sizes.max() <= cap:
        return part
    conn = np.zeros((n, k), dtype=np.float64)
    if len(uv):
        s = np.concatenate([uv[:, 0], uv[:, 1]])
        t = np.concatenate([uv[:, 1], uv[:, 0]])
        ww = np.concatenate([w, w])
        np.add.at(conn, (s, part[t]), ww)
    others = np.arange(k)
    for p in range(k):
        while sizes[p] > cap:
            movers = np.nonzero(part == p)[0]
            # cheapest first: least attached to home
            vtx = int(movers[np.argmin(conn[movers, p])])
            # target: most attached among partitions with room, else smallest
            roomy = (sizes + 1 <= cap) & (others != p)
            if roomy.any():
                p1 = int(np.argmax(np.where(roomy, conn[vtx], -np.inf)))
            else:
                p1 = int(np.argmin(np.where(others != p, sizes, np.inf)))
            part[vtx] = p1
            sizes[p] -= 1
            sizes[p1] += 1
    return part


def multilevel_partition(edges: np.ndarray, n_vertices: int,
                         n_partitions: int, seed: int = 0,
                         coarsen_to: int | None = None,
                         max_levels: int = 24,
                         balance_slack: float = 1.1,
                         refine_passes: int = 4) -> np.ndarray:
    """Heavy-edge coarsening -> ``bfs_partition`` coarse seed -> greedy
    boundary refinement per uncoarsening level.  See module docstring."""
    k = int(n_partitions)
    if k <= 1 or n_vertices == 0:
        return np.zeros(n_vertices, dtype=np.int32)
    rng = np.random.RandomState(seed)
    uv, w = _undirected_weighted(edges)
    vweight = np.ones(n_vertices, dtype=np.float64)
    if coarsen_to is None:
        coarsen_to = max(32 * k, 128)

    levels: list[tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]] = []
    for _ in range(max_levels):
        if len(vweight) <= coarsen_to or not len(uv):
            break
        cuv, cw, cvw, cmap = _coarsen(uv, w, vweight, rng)
        if len(cvw) > 0.95 * len(vweight):     # matching stalled
            break
        levels.append((uv, w, vweight, cmap))
        uv, w, vweight = cuv, cw, cvw

    total = float(vweight.sum())
    cap = max(balance_slack * total / k, float(vweight.max()))
    part = bfs_partition(uv, len(vweight), k, seed=seed).astype(np.int32)
    part = _refine(uv, w, vweight, part, k, cap, refine_passes)

    for fuv, fw, fvw, cmap in reversed(levels):
        part = part[cmap]
        cap = max(balance_slack * float(fvw.sum()) / k, float(fvw.max()))
        part = _refine(fuv, fw, fvw, part, k, cap, refine_passes)

    cap = max(balance_slack * n_vertices / k, float(-(-n_vertices // k)))
    fuv, fw = (levels[0][0], levels[0][1]) if levels else (uv, w)
    part = _rebalance(fuv, fw, part, k, cap)
    return part.astype(np.int32)
