"""Partitioners + partition-quality reporting for the GraphHP engines.

The paper runs on (Par)Metis partitions; this package is the repo's
partitioner ladder, cheapest to best:

  * ``hash``        — Hama's default placement (random cut, the baseline),
  * ``bfs``         — multi-source BFS growth (locality on a budget; also
                      the multilevel coarse-level seed),
  * ``fennel``      — Fennel-style streaming: one greedy pass, degree-
                      scaled balance penalty, hard capacity,
  * ``multilevel``  — heavy-edge coarsening -> bfs coarse seed -> greedy
                      boundary refinement (the Metis recipe).

All partitioners share one signature through :func:`make_partition`:
``(edges (E,2), n_vertices, n_partitions, seed) -> (V,) int32 labels``.
``build_partitioned_graph`` accepts a partitioner *name* for ``part`` and
resolves it here, so callers pick a partitioner with a string.
:func:`~repro.partition.quality.partition_report` scores any labeling
(edge-cut fraction, boundary fraction, replication H/V, balance, estimated
exchange bytes); ``benchmarks/partition_bench.py`` A/Bs the ladder
end-to-end on the paper's counters.
"""

from __future__ import annotations

import numpy as np

from repro.partition.seed import bfs_partition, hash_partition
from repro.partition.streaming import fennel_partition, fennel_partition_csr
from repro.partition.multilevel import multilevel_partition
from repro.partition.quality import PartitionReport, partition_report

__all__ = [
    "hash_partition", "bfs_partition", "fennel_partition",
    "fennel_partition_csr", "multilevel_partition", "PartitionReport",
    "partition_report", "PARTITIONERS", "make_partition",
]

# uniform signature: (edges, n_vertices, n_partitions, seed, **kw) -> labels
PARTITIONERS = {
    "hash": lambda edges, n, k, seed=0, **kw: hash_partition(n, k, seed=seed),
    "bfs": lambda edges, n, k, seed=0, **kw: bfs_partition(
        edges, n, k, seed=seed),
    "fennel": fennel_partition,
    "multilevel": multilevel_partition,
}


def make_partition(name: str, edges: np.ndarray, n_vertices: int,
                   n_partitions: int, seed: int = 0, **kw) -> np.ndarray:
    """Resolve a partitioner by name and run it."""
    try:
        fn = PARTITIONERS[name]
    except KeyError:
        raise ValueError(f"unknown partitioner {name!r}; "
                         f"have {sorted(PARTITIONERS)}") from None
    return np.asarray(fn(edges, n_vertices, n_partitions, seed=seed, **kw),
                      dtype=np.int32)
