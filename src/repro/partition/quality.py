"""Partition-quality report: the numbers that predict GraphHP traffic.

GraphHP's advantage scales with how many in-edges a partition keeps
internal, so every quantity here is a direct proxy for a paper metric:

  * ``edge_cut_frac``   — crossing edges / E: the raw message surface,
  * ``boundary_frac``   — vertices with a remote in-edge / V: the global-
                          phase workload (only boundary vertices compute
                          once per global iteration),
  * ``replication``     — H/V, halo entries per vertex: each halo entry is
                          one exported value per exchange (Pregel-speak:
                          the vertex replication factor of the cut),
  * ``balance``         — max partition size / (V/k): straggler exposure,
  * ``exchange_bytes``  — estimated bytes per exchange: one value per halo
                          entry, i.e. ``sum(export_fanout)`` of the built
                          :class:`~repro.core.graph.PartitionedGraph`
                          (computable from the raw labeling without
                          building — both routes agree, tested),
  * ``pad_waste``       — ``k * max_p |edges(p)| / sum_p |edges(p)|``: the
                          memory and work multiplier a shared-width padded
                          edge layout (``edge_blocks=P``) pays over the
                          ragged one (``edge_blocks=1``) for this
                          labeling's in-edge skew; 1.0 means perfectly
                          even, hub-clustering labelings run much higher.

``partition_report`` works from the raw ``(edges, part)`` labeling; pass
``graph=`` to read the halo size off a built ``PartitionedGraph``'s
``export_fanout`` plan instead (they are equal by construction: fanout
counts distinct consuming partitions per exporter, halo counts distinct
needed sources per consumer).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

__all__ = ["PartitionReport", "partition_report"]


@dataclasses.dataclass(frozen=True)
class PartitionReport:
    n_vertices: int
    n_edges: int
    n_partitions: int
    edge_cut: int           # crossing edges
    edge_cut_frac: float
    boundary_vertices: int  # vertices with >= 1 remote in-edge
    boundary_frac: float
    halo_entries: int       # unique (consumer partition, remote source) pairs
    replication: float      # halo_entries / n_vertices (H/V)
    balance: float          # max partition size / (n/k)
    exchange_bytes: int     # halo_entries * bytes_per_value per exchange
    pad_waste: float        # k * max_p in-edges / sum_p in-edges

    def as_dict(self) -> dict[str, Any]:
        return dataclasses.asdict(self)

    def summary(self) -> str:
        return (f"cut {100 * self.edge_cut_frac:.1f}% "
                f"boundary {100 * self.boundary_frac:.1f}% "
                f"H/V {self.replication:.2f} "
                f"balance {self.balance:.2f} "
                f"pad-waste {self.pad_waste:.2f}x "
                f"exchange {self.exchange_bytes / 1024:.1f} KiB")


def partition_report(edges: np.ndarray, n_vertices: int, part: np.ndarray,
                     bytes_per_value: int = 4,
                     graph: Any = None,
                     n_partitions: int | None = None) -> PartitionReport:
    """Quality metrics of a vertex->partition labeling (see module doc).

    Pass ``n_partitions`` when the labeling was *requested* at a given k:
    a partitioner that leaves trailing partitions empty would otherwise
    have its balance measured against the smaller occupied count."""
    edges = np.asarray(edges, dtype=np.int64)
    part = np.asarray(part)
    occupied = int(part.max()) + 1 if part.size else 1
    k = occupied if n_partitions is None else max(int(n_partitions), occupied)
    src, dst = edges[:, 0], edges[:, 1]
    cross = part[src] != part[dst]
    cut = int(cross.sum())

    boundary = np.zeros(n_vertices, dtype=bool)
    boundary[dst[cross]] = True
    n_boundary = int(boundary.sum())

    if graph is not None:
        fanout = np.asarray(graph.export_fanout)[np.asarray(graph.export_mask)]
        halo = int(fanout.sum())
    else:
        pairs = np.unique(
            np.stack([part[dst[cross]].astype(np.int64), src[cross]], axis=1),
            axis=0)
        halo = len(pairs)

    sizes = np.bincount(part, minlength=k)
    balance = float(sizes.max() / (n_vertices / k)) if n_vertices else 1.0

    in_edges = np.bincount(part[dst], minlength=k) if len(edges) else \
        np.zeros(k, dtype=np.int64)
    pad_waste = (float(k * in_edges.max() / in_edges.sum())
                 if in_edges.sum() else 1.0)

    return PartitionReport(
        n_vertices=int(n_vertices), n_edges=len(edges), n_partitions=k,
        edge_cut=cut,
        edge_cut_frac=cut / max(len(edges), 1),
        boundary_vertices=n_boundary,
        boundary_frac=n_boundary / max(n_vertices, 1),
        halo_entries=halo,
        replication=halo / max(n_vertices, 1),
        balance=balance,
        exchange_bytes=halo * bytes_per_value,
        pad_waste=pad_waste,
    )
