"""Seed partitioners: the hash cut and the multi-source BFS grower.

These are the two partitioners the repo shipped inside ``core/graph.py``
since the seed: ``hash_partition`` is Hama's default placement (the paper's
baseline, a random cut), ``bfs_partition`` a cheap locality-preserving
stand-in for (Par)Metis.  They live here now as the bottom rungs of the
partitioner ladder — ``bfs_partition`` doubles as the coarse-level seed of
:func:`repro.partition.multilevel.multilevel_partition`.
"""

from __future__ import annotations

import numpy as np

__all__ = ["hash_partition", "bfs_partition", "undirected_csr"]


def hash_partition(n_vertices: int, n_partitions: int, seed: int = 0) -> np.ndarray:
    """Hama's default placement: hash(id) mod k (random cut, many crossings)."""
    rng = np.random.RandomState(seed)
    perm = rng.permutation(n_vertices).astype(np.int64)
    return (perm % n_partitions).astype(np.int32)


def undirected_csr(edges: np.ndarray, n_vertices: int
                   ) -> tuple[np.ndarray, np.ndarray]:
    """(starts, neighbours) CSR view of the symmetrized edge list."""
    adj_idx = np.concatenate([edges[:, 0], edges[:, 1]])
    adj_val = np.concatenate([edges[:, 1], edges[:, 0]])
    order = np.argsort(adj_idx, kind="stable")
    adj_idx, adj_val = adj_idx[order], adj_val[order]
    starts = np.searchsorted(adj_idx, np.arange(n_vertices + 1))
    return starts, adj_val


def bfs_partition(edges: np.ndarray, n_vertices: int, n_partitions: int,
                  seed: int = 0) -> np.ndarray:
    """Locality-preserving partitioner standing in for (Par)Metis.

    Multi-source BFS growth: seeds spread round-robin; each round grows the
    *smallest* partitions first (partitions are processed in ascending size
    order, so frontier claims genuinely favour the partition most behind —
    the Metis-ish balance objective the docstring always promised).  When a
    partition's budget runs out mid-frontier the unexpanded frontier tail
    is kept, not dropped, so growth resumes exactly where it stopped
    instead of re-seeding across a hole.
    """
    rng = np.random.RandomState(seed)
    starts, adj_val = undirected_csr(edges, n_vertices)

    part = np.full(n_vertices, -1, dtype=np.int32)
    sizes = np.zeros(n_partitions, dtype=np.int64)
    target = (n_vertices + n_partitions - 1) // n_partitions
    frontiers: list[list[int]] = [[] for _ in range(n_partitions)]
    unvisited = rng.permutation(n_vertices).tolist()
    uptr = 0

    def next_seed() -> int | None:
        nonlocal uptr
        while uptr < len(unvisited):
            v = unvisited[uptr]
            uptr += 1
            if part[v] < 0:
                return v
        return None

    for p in range(n_partitions):
        s = next_seed()
        if s is None:
            break
        part[s] = p
        sizes[p] += 1
        frontiers[p].append(s)

    active = True
    while active:
        active = False
        for p in sorted(range(n_partitions), key=lambda q: (sizes[q], q)):
            if sizes[p] >= target:
                continue
            budget = target - sizes[p]
            frontier = frontiers[p]
            new_frontier: list[int] = []
            consumed = 0
            for v in frontier:
                if budget <= 0:
                    break
                for u in adj_val[starts[v]:starts[v + 1]]:
                    if part[u] < 0 and budget > 0:
                        part[u] = p
                        sizes[p] += 1
                        budget -= 1
                        new_frontier.append(int(u))
                # v counts as consumed only if the budget survived its whole
                # neighbour scan — a mid-scan cutoff keeps v in the tail so
                # growth resumes there (its already-claimed neighbours are
                # skipped by the part[u] < 0 test on the rescan)
                if budget > 0:
                    consumed += 1
            new_frontier.extend(frontier[consumed:])
            if not new_frontier and sizes[p] < target:
                s = next_seed()
                if s is not None:
                    part[s] = p
                    sizes[p] += 1
                    new_frontier.append(s)
            frontiers[p] = new_frontier
            active = active or bool(new_frontier)

    # sweep leftovers (isolated vertices) to the smallest partitions
    for v in range(n_vertices):
        if part[v] < 0:
            p = int(np.argmin(sizes))
            part[v] = p
            sizes[p] += 1
    return part
