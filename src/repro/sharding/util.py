"""Spec sanitation: drop sharding on axes whose size does not divide the mesh
axis (e.g. batch=1 long-context decode cannot shard over data=16) and build
NamedShardings."""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Tree = Any


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def sanitize_specs(specs: Tree, shapes: Tree, mesh: Mesh) -> Tree:
    """Replace spec entries that don't divide the dimension with None."""

    def fix(spec: P, leaf) -> P:
        parts = tuple(spec)
        out = []
        for i, ax in enumerate(parts):
            if ax is not None and i < leaf.ndim and \
                    leaf.shape[i] % _axis_size(mesh, ax) == 0:
                out.append(ax)
            else:
                out.append(None)
        out += [None] * (leaf.ndim - len(out))
        return P(*out[: leaf.ndim])

    return jax.tree.map(fix, specs, shapes,
                        is_leaf=lambda x: isinstance(x, P))


def named(specs: Tree, mesh: Mesh) -> Tree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Sequence parallelism (Korthikanti et al.) switch: when enabled, the
# residual stream between layers is sharded over ('data', 'model') on
# (batch, seq) instead of ('data',) on batch alone.  GSPMD then turns the
# Megatron row-parallel all-reduce of (B,S,D) activations into a
# reduce-scatter (+ all-gather before the next column-parallel input), and
# the per-layer remat carry shrinks by the model-axis size.  §Perf iteration.
_SEQ_PARALLEL = False


def set_seq_parallel(enabled: bool) -> None:
    global _SEQ_PARALLEL
    _SEQ_PARALLEL = bool(enabled)


def seq_axis():
    return "model" if _SEQ_PARALLEL else None


def maybe_constrain(x, *parts):
    """with_sharding_constraint if the ambient mesh has the named axes and
    they divide the dims; identity otherwise (CPU tests run mesh-free).

    Constraints are the steering wheel for GSPMD propagation: ops like
    gather/sort/scatter stop propagation, and without a constraint
    downstream of them XLA happily replicates 100-GB activations.
    """
    get_mesh = getattr(jax.sharding, "get_abstract_mesh", None)
    mesh = get_mesh() if get_mesh is not None else None
    # older jax has no public ambient-mesh query (or returns a sentinel
    # without axis_names): skip the constraint — it is only a GSPMD hint
    if mesh is None or not getattr(mesh, "axis_names", ()):
        return x
    out = []
    for i, axis in enumerate(parts):
        if axis is None:
            out.append(None)
            continue
        axes = axis if isinstance(axis, tuple) else (axis,)
        if not all(a in mesh.axis_names for a in axes):
            out.append(None)
            continue
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        # uneven sharding is fine (GSPMD pads); only refuse degenerate dims
        out.append(axis if i < x.ndim and x.shape[i] >= size else None)
    return jax.lax.with_sharding_constraint(x, P(*out))
