"""Partition rules: parameter / batch / cache PartitionSpecs.

2-D param sharding (MaxText-style): FSDP along ``data`` × tensor-parallel
along ``model``; MoE experts shard over ``model`` (expert parallelism); KV
caches shard their sequence axis over ``model`` so decode works for any head
count (the flash-decode merge handles the softmax across shards).

Rules match on the leaf's path keys, using the *unstacked* rank (scan-over-
units prepends one stacking axis, detected via the ``units`` path component).
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import PartitionSpec as P

Tree = Any


def _base_spec(keys: list[str], ndim: int, fsdp: str, tp: str) -> P:
    last = keys[-1]
    if last == "embed":
        return P(tp, fsdp)
    if last in ("lm_head", "vis_proj", "frontend_proj"):
        return P(fsdp, tp)
    if last in ("wq", "wk", "wv", "in_proj", "shared_wi"):
        return P(fsdp, tp)
    if last in ("out_proj", "shared_wo"):
        return P(tp, fsdp)
    if last == "wi":
        return P(tp, fsdp, None) if ndim == 3 else P(fsdp, tp)
    if last == "wo":
        return P(tp, None, fsdp) if ndim == 3 else P(tp, fsdp)
    if last == "router":
        return P(fsdp, None)
    if last == "w_dkv":
        return P(fsdp, None)
    if last in ("w_uk", "w_uv"):
        return P(None, tp, None)
    if last == "conv_w":
        return P(None, tp)
    return P()                       # 1-d scales/biases: replicated


def param_specs(shapes: Tree, fsdp: str = "data", tp: str = "model",
                prepend: tuple = ()) -> Tree:
    """Spec tree mirroring a param (shape) tree."""

    def spec_for(path, leaf):
        keys = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
        stacked = "units" in keys
        nd = leaf.ndim - (1 if stacked else 0)
        base = _base_spec(keys, nd, fsdp, tp)
        parts = tuple(base) + (None,) * (nd - len(tuple(base)))
        if stacked:
            parts = (None,) + parts
        return P(*prepend, *parts)

    return jax.tree_util.tree_map_with_path(spec_for, shapes)


def batch_spec(batch_shapes: Tree, data: str = "data",
               prepend: tuple = ()) -> Tree:
    """Batch dict: batch dimension over the data axis."""
    def spec_for(path, leaf):
        return P(*prepend, data, *([None] * (leaf.ndim - 1)))
    return jax.tree_util.tree_map_with_path(spec_for, batch_shapes)


def cache_specs(cache_shapes: Tree, data: str = "data", tp: str = "model",
                seq_shard: bool = True, prepend: tuple = ()) -> Tree:
    """KV/state caches: batch over data; sequence over model (decode flash
    merge); mamba states head-sharded over model."""

    def spec_for(path, leaf):
        keys = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
        stacked = "units" in keys
        nd = leaf.ndim - (1 if stacked else 0)
        last = keys[-1]
        seq = tp if seq_shard else None
        if last in ("k", "v"):                    # (B, S, KVH, hd)
            base = (data, seq, None, None)
        elif last == "c_kv":                      # (B, S, r)
            base = (data, seq, None)
        elif last == "k_rope":
            base = (data, seq, None)
        elif last == "conv":                      # (B, K-1, C)
            base = (data, None, tp)
        elif last == "ssm":                       # (B, H, P, N)
            base = (data, tp, None, None)
        elif last == "enc_out":                   # (B, F, D)
            base = (data, None, None)
        else:
            base = (data,) + (None,) * (nd - 1)
        if stacked:
            base = (None,) + base
        return P(*prepend, *base)

    return jax.tree_util.tree_map_with_path(spec_for, cache_shapes)


def prepend_axis(specs: Tree, axis: str) -> Tree:
    """Prepend a mesh axis (e.g. 'pod') to every spec in a tree — used when
    per-pod replicas are stacked along a leading axis for hybrid sync."""
    return jax.tree.map(lambda s: P(axis, *tuple(s)), specs,
                        is_leaf=lambda x: isinstance(x, P))
