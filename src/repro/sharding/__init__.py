from repro.sharding.rules import (batch_spec, cache_specs, param_specs,
                                  prepend_axis)

__all__ = ["param_specs", "batch_spec", "cache_specs", "prepend_axis"]
