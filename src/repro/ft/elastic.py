"""Elastic scaling: replan partition/shard ownership when the worker count
changes between restarts (grow or shrink), keeping data movement minimal."""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    n_partitions: int
    old_workers: int
    new_workers: int
    owner: np.ndarray          # (n_partitions,) new owner per partition
    moved: int                 # partitions that changed owner


def replan_partitions(n_partitions: int, old_workers: int,
                      new_workers: int) -> ElasticPlan:
    """Contiguous-block ownership before and after; only the boundary blocks
    move.  The same plan reshards training state: leaves saved per shard
    group are re-gathered by `checkpoint.load_checkpoint(shardings=new)`."""
    old_owner = np.arange(n_partitions) * old_workers // n_partitions
    new_owner = np.arange(n_partitions) * new_workers // n_partitions
    moved = int(np.sum(old_owner * new_workers != new_owner * old_workers))
    return ElasticPlan(n_partitions, old_workers, new_workers,
                       new_owner.astype(np.int32),
                       moved=int(np.sum(
                           new_owner != np.minimum(old_owner,
                                                   new_workers - 1))))
