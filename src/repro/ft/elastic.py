"""Elastic scaling: replan partition/shard ownership when the worker count
changes between restarts (grow or shrink), and re-shard checkpointed vertex
state when the *partition* count itself changes (``repro.io.resize``)."""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["ElasticPlan", "replan_partitions", "resize_labels",
           "reshard_vertex_tree"]


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    n_partitions: int
    old_workers: int
    new_workers: int
    owner: np.ndarray          # (n_partitions,) new owner per partition
    moved: int                 # partitions that changed owner


def partition_owners(n_partitions: int, n_workers: int) -> np.ndarray:
    """Contiguous-block ownership: partition i -> worker i*W//P."""
    return (np.arange(n_partitions) * n_workers
            // n_partitions).astype(np.int32)


def replan_partitions(n_partitions: int, old_workers: int,
                      new_workers: int) -> ElasticPlan:
    """Contiguous-block ownership before and after; only the boundary blocks
    move.  The same plan reshards training state: leaves saved per shard
    group are re-gathered by `checkpoint.load_checkpoint(shardings=new)`."""
    old_owner = partition_owners(n_partitions, old_workers)
    new_owner = partition_owners(n_partitions, new_workers)
    moved = int(np.sum(new_owner != old_owner))
    return ElasticPlan(n_partitions, old_workers, new_workers,
                       new_owner, moved=moved)


def resize_labels(part: np.ndarray, new_partitions: int) -> np.ndarray:
    """Re-label a vertex->partition assignment from k to k' partitions.

    Shrink merges contiguous old partitions (``p -> p*k'//k`` — the same
    contiguous-block arithmetic as :func:`replan_partitions`, so only
    boundary blocks change meaning).  Grow splits each old partition among
    its contiguous children ``[p*k'//k, (p+1)*k'//k)``, dividing the
    partition's vertices (ascending global id, the builder's slot order)
    into equal contiguous runs.  Deterministic, vertex-level, and needs no
    edge data — which is what lets ``repro.io.resize`` re-spill a ``.ghp``
    without a rebuild from edge lists."""
    part = np.asarray(part)
    k = int(part.max()) + 1 if part.size else 1
    kp = int(new_partitions)
    if kp < 1:
        raise ValueError(f"new_partitions must be >= 1, got {kp}")
    if kp == k:
        return part.astype(np.int32)
    if kp < k:                       # pure merge, vertex-count free
        merge = partition_owners(k, kp)
        return merge[part].astype(np.int32)
    # grow: split each old partition's vertex run among its children
    new_part = np.zeros(part.shape, dtype=np.int32)
    children_lo = np.arange(k) * kp // k
    children_hi = (np.arange(k) + 1) * kp // k
    for p in range(k):
        vs = np.flatnonzero(part == p)       # ascending gid == slot order
        m = int(children_hi[p] - children_lo[p])
        if len(vs):
            new_part[vs] = (children_lo[p]
                            + (np.arange(len(vs)) * m) // len(vs))
    return new_part


def reshard_vertex_tree(leaves: dict[str, np.ndarray],
                        old_part: np.ndarray, new_part: np.ndarray,
                        pad_multiple: int = 8) -> dict[str, np.ndarray]:
    """Re-shard vertex-keyed ``(P, Vp, ...)`` checkpoint leaves from one
    partitioning to another.

    Both layouts follow the builder's slot rule (partition-major, ascending
    global id within a partition — :func:`core.graph._vertex_slots`), so
    the map is gather-by-vertex then scatter-by-new-slot.  Slots past a new
    partition's population keep the array's fill (zeros), which every
    engine path masks off via ``vertex_mask``.  Leaves whose leading dims
    are not the old ``(P, Vp)`` are returned untouched."""
    from repro.core.graph import _vertex_slots

    old_part = np.asarray(old_part)
    new_part = np.asarray(new_part)
    if old_part.shape != new_part.shape:
        raise ValueError(f"labelings disagree on vertex count: "
                         f"{old_part.shape} vs {new_part.shape}")
    n = len(old_part)
    P_o, _, slot_o, Vp_o = _vertex_slots(old_part, n, pad_multiple)
    P_n, _, slot_n, Vp_n = _vertex_slots(new_part, n, pad_multiple)
    src = old_part.astype(np.int64) * Vp_o + slot_o     # (n,) old flat slot
    dst = new_part.astype(np.int64) * Vp_n + slot_n     # (n,) new flat slot
    out = {}
    for name, arr in leaves.items():
        arr = np.asarray(arr)
        if arr.ndim >= 2 and arr.shape[:2] == (P_o, Vp_o):
            flat = arr.reshape((P_o * Vp_o,) + arr.shape[2:])
            res = np.zeros((P_n * Vp_n,) + arr.shape[2:], dtype=arr.dtype)
            res[dst] = flat[src]
            out[name] = res.reshape((P_n, Vp_n) + arr.shape[2:])
        else:
            out[name] = arr
    return out
