"""Deterministic fault injection for the fault-tolerant driver.

A :class:`FaultPlan` scripts, per driver *tick* (one tick = one global
iteration of the outer loop, monotonically increasing across recoveries —
NOT the engine's iteration counter, which rewinds on restore), which
simulated worker is killed or delayed.  The driver advances an injected
logical clock one ``tick_seconds`` per tick and forwards each live worker's
heartbeat through :meth:`FaultInjector.beating`; a killed worker goes
silent forever, a delayed worker goes silent for ``n`` ticks and then
resumes (exercising the monitor's healthy -> suspect -> healthy path
without a failover).

Nothing here touches wall-clock time or randomness — the same plan against
the same graph/program replays the same recovery sequence bit-for-bit,
which is what lets the kill-and-resume tests assert exact state equality
instead of sleeping.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

__all__ = ["FaultPlan", "FaultInjector"]


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """``kill[t] = worker`` kills that worker at tick ``t`` (permanent);
    ``delay[t] = (worker, n_ticks)`` silences it for ``n_ticks`` ticks."""

    kill: Mapping[int, int] = dataclasses.field(default_factory=dict)
    delay: Mapping[int, tuple[int, int]] = dataclasses.field(
        default_factory=dict)

    @staticmethod
    def kill_at(tick: int, worker: int = 0) -> "FaultPlan":
        return FaultPlan(kill={int(tick): int(worker)})


class FaultInjector:
    """Replays a :class:`FaultPlan` against ``n_workers`` simulated workers."""

    def __init__(self, plan: FaultPlan, n_workers: int):
        self.plan = plan
        self.n_workers = n_workers
        self.killed: set[int] = set()
        self.silent_until: dict[int, int] = {}    # worker -> first loud tick
        self.tick = -1

    def beating(self, tick: int) -> Sequence[int]:
        """Advance to ``tick`` and return the workers that heartbeat now."""
        if tick <= self.tick:
            raise ValueError(f"ticks must advance: {tick} after {self.tick}")
        self.tick = tick
        if tick in self.plan.kill:
            self.killed.add(self.plan.kill[tick])
        if tick in self.plan.delay:
            w, n = self.plan.delay[tick]
            self.silent_until[w] = tick + int(n)
        return [w for w in range(self.n_workers)
                if w not in self.killed
                and tick >= self.silent_until.get(w, 0)]
