"""Straggler mitigation.

Two mechanisms, one per workload kind:

* serving: deadline-based re-dispatch — a request batch stuck past the
  p99-derived deadline is re-enqueued to another replica slot; first result
  wins (duplicate suppression by request id).
* training (hybrid sync): pods vote — the global phase proceeds when a
  quorum of pods delivered deltas; laggard deltas ride the next exchange via
  the error-feedback residual (gradient-skip voting, DESIGN.md §7).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

from repro.obs import clock as obs_clock


@dataclasses.dataclass
class PendingWork:
    work_id: int
    issued_at: float
    replica: int
    attempts: int = 1
    done: bool = False


class StragglerMitigator:
    def __init__(self, deadline_factor: float = 3.0, min_deadline: float = 0.5,
                 clock: Callable | None = None):
        # default: the installable obs clock (an explicit clock= still wins)
        self.clock = clock if clock is not None else obs_clock.monotonic
        self.deadline_factor = deadline_factor
        self.min_deadline = min_deadline
        self._lat_ewma: float | None = None
        self.pending: dict[int, PendingWork] = {}
        self.duplicates_suppressed = 0
        self.redispatches = 0

    # -- latency model ----------------------------------------------------
    def observe_latency(self, dt: float) -> None:
        self._lat_ewma = dt if self._lat_ewma is None else \
            0.9 * self._lat_ewma + 0.1 * dt

    @property
    def deadline(self) -> float:
        base = self._lat_ewma if self._lat_ewma is not None else self.min_deadline
        return max(self.min_deadline, self.deadline_factor * base)

    # -- dispatch ----------------------------------------------------------
    def issue(self, work_id: int, replica: int) -> None:
        self.pending[work_id] = PendingWork(work_id, self.clock(), replica)

    def complete(self, work_id: int) -> bool:
        """Returns False if this was a duplicate (already completed)."""
        w = self.pending.get(work_id)
        if w is None or w.done:
            self.duplicates_suppressed += 1
            return False
        self.observe_latency(self.clock() - w.issued_at)
        w.done = True
        return True

    def overdue(self) -> list[PendingWork]:
        now = self.clock()
        out = [w for w in self.pending.values()
               if not w.done and now - w.issued_at > self.deadline]
        for w in out:
            w.issued_at = now
            w.attempts += 1
            self.redispatches += 1
        return out


def quorum_ready(delivered: int, total: int, quorum: float = 0.75) -> bool:
    """Training: global phase proceeds when >= quorum of pods delivered."""
    return delivered >= max(1, int(total * quorum))


# ---------------------------------------------------------------------------
# graph-engine stragglers: slow shards, from the paper's own counters
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardFlag:
    """One flagged slow shard.  ``cause`` separates the two remedies: a
    shard slow *because it is oversized* ('skew' — re-partition it, the
    ladder's job) from one slow on balanced data ('straggler' — the node is
    the problem, re-dispatch / reassign)."""

    partition: int
    pseudo_supersteps: int
    ratio: float               # vs the median shard
    cause: str                 # 'skew' | 'straggler'


def flag_slow_shards(pseudo_supersteps=None, balance: float | None = None,
                     factor: float = 1.5, registry=None) -> list[ShardFlag]:
    """Flag shards whose local phase runs long, from the per-partition
    ``Counters.pseudo_supersteps`` the hybrid engine already keeps.

    GraphHP's local phase iterates each partition to its own convergence,
    so a partition's pseudo-superstep count *is* its work clock — a shard
    running ``factor``x past the median is holding the next exchange
    hostage.  ``balance`` (``PartitionReport.balance`` — max partition
    size over the even share) classifies the flag: when the labeling
    itself is skewed past the same factor the remedy is re-partitioning,
    not failover, so the cause reads 'skew'.

    ``registry`` (a :class:`repro.obs.metrics.MetricsRegistry`) supplies
    either input not passed explicitly: the per-partition vector from the
    ``engine.pseudo_supersteps`` gauge, the balance from
    ``partition.balance``."""
    import numpy as np

    if registry is not None:
        if pseudo_supersteps is None:
            pseudo_supersteps = registry.value("engine.pseudo_supersteps")
        if balance is None:
            balance = registry.value("partition.balance")
    if pseudo_supersteps is None:
        return []
    counts = np.asarray(pseudo_supersteps)
    if counts.ndim != 1 or not counts.size:
        return []
    med = float(np.median(counts))
    floor = max(med, 1.0)
    flags = []
    for p in np.flatnonzero(counts > factor * floor):
        cause = ("skew" if balance is not None and balance > factor
                 else "straggler")
        flags.append(ShardFlag(int(p), int(counts[p]),
                               float(counts[p] / floor), cause))
    return flags
