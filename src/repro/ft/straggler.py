"""Straggler mitigation.

Two mechanisms, one per workload kind:

* serving: deadline-based re-dispatch — a request batch stuck past the
  p99-derived deadline is re-enqueued to another replica slot; first result
  wins (duplicate suppression by request id).
* training (hybrid sync): pods vote — the global phase proceeds when a
  quorum of pods delivered deltas; laggard deltas ride the next exchange via
  the error-feedback residual (gradient-skip voting, DESIGN.md §7).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable


@dataclasses.dataclass
class PendingWork:
    work_id: int
    issued_at: float
    replica: int
    attempts: int = 1
    done: bool = False


class StragglerMitigator:
    def __init__(self, deadline_factor: float = 3.0, min_deadline: float = 0.5,
                 clock: Callable = time.monotonic):
        self.clock = clock
        self.deadline_factor = deadline_factor
        self.min_deadline = min_deadline
        self._lat_ewma: float | None = None
        self.pending: dict[int, PendingWork] = {}
        self.duplicates_suppressed = 0
        self.redispatches = 0

    # -- latency model ----------------------------------------------------
    def observe_latency(self, dt: float) -> None:
        self._lat_ewma = dt if self._lat_ewma is None else \
            0.9 * self._lat_ewma + 0.1 * dt

    @property
    def deadline(self) -> float:
        base = self._lat_ewma if self._lat_ewma is not None else self.min_deadline
        return max(self.min_deadline, self.deadline_factor * base)

    # -- dispatch ----------------------------------------------------------
    def issue(self, work_id: int, replica: int) -> None:
        self.pending[work_id] = PendingWork(work_id, self.clock(), replica)

    def complete(self, work_id: int) -> bool:
        """Returns False if this was a duplicate (already completed)."""
        w = self.pending.get(work_id)
        if w is None or w.done:
            self.duplicates_suppressed += 1
            return False
        self.observe_latency(self.clock() - w.issued_at)
        w.done = True
        return True

    def overdue(self) -> list[PendingWork]:
        now = self.clock()
        out = [w for w in self.pending.values()
               if not w.done and now - w.issued_at > self.deadline]
        for w in out:
            w.issued_at = now
            w.attempts += 1
            self.redispatches += 1
        return out


def quorum_ready(delivered: int, total: int, quorum: float = 0.75) -> bool:
    """Training: global phase proceeds when >= quorum of pods delivered."""
    return delivered >= max(1, int(total * quorum))
