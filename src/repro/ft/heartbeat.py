"""Master-side worker health tracking (the paper's §5.3 ping mechanism).

The master pings workers; a worker silent past the deadline is marked FAILED
and its partitions / shards are reassigned, to be reloaded from the most
recent checkpoint.  Here the transport is injected (in-process for tests; a
real deployment plugs RPC in) — the state machine and reassignment logic is
what the framework owns.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Callable

from repro.obs import clock as obs_clock


class WorkerState(enum.Enum):
    HEALTHY = "healthy"
    SUSPECT = "suspect"
    FAILED = "failed"


@dataclasses.dataclass
class WorkerInfo:
    worker_id: int
    last_seen: float
    state: WorkerState = WorkerState.HEALTHY
    assignments: list = dataclasses.field(default_factory=list)


class HeartbeatMonitor:
    def __init__(self, n_workers: int, suspect_after: float = 5.0,
                 fail_after: float = 15.0, clock: Callable | None = None):
        # default: the installable obs clock (an explicit clock= still wins)
        self.clock = clock if clock is not None else obs_clock.monotonic
        now = self.clock()
        self.workers = {i: WorkerInfo(i, now) for i in range(n_workers)}
        self.suspect_after = suspect_after
        self.fail_after = fail_after
        self.epoch = 0          # bumped on every reassignment

    def beat(self, worker_id: int) -> None:
        w = self.workers[worker_id]
        w.last_seen = self.clock()
        if w.state is WorkerState.SUSPECT:
            w.state = WorkerState.HEALTHY

    def assign(self, worker_id: int, item) -> None:
        self.workers[worker_id].assignments.append(item)

    def sweep(self) -> list[int]:
        """Advance states; returns newly-failed worker ids."""
        now = self.clock()
        failed = []
        for w in self.workers.values():
            if w.state is WorkerState.FAILED:
                continue
            dt = now - w.last_seen
            if dt > self.fail_after:
                w.state = WorkerState.FAILED
                failed.append(w.worker_id)
            elif dt > self.suspect_after:
                w.state = WorkerState.SUSPECT
        return failed

    def reassign_failed(self) -> dict[int, list]:
        """Move failed workers' assignments to the least-loaded healthy ones
        (the paper: 'the master reassigns its graph partitions to another
        currently available worker').  Returns {worker: regained items}."""
        healthy = [w for w in self.workers.values()
                   if w.state is not WorkerState.FAILED]
        if not healthy:
            raise RuntimeError("no healthy workers left")
        moved: dict[int, list] = {}
        for w in self.workers.values():
            if w.state is WorkerState.FAILED and w.assignments:
                for item in w.assignments:
                    tgt = min(healthy, key=lambda h: len(h.assignments))
                    tgt.assignments.append(item)
                    moved.setdefault(tgt.worker_id, []).append(item)
                w.assignments = []
        if moved:
            # one epoch per reassignment *event*: every worker adopting the
            # new assignment table in the same sweep must agree on a single
            # epoch id, however many workers failed at once
            self.epoch += 1
        return moved
