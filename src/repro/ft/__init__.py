from repro.ft.heartbeat import HeartbeatMonitor, WorkerState
from repro.ft.elastic import ElasticPlan, replan_partitions
from repro.ft.straggler import StragglerMitigator

__all__ = ["HeartbeatMonitor", "WorkerState", "ElasticPlan",
           "replan_partitions", "StragglerMitigator"]
