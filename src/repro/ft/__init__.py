from repro.ft.heartbeat import HeartbeatMonitor, WorkerState
from repro.ft.elastic import (ElasticPlan, partition_owners,
                              replan_partitions, resize_labels,
                              reshard_vertex_tree)
from repro.ft.inject import FaultInjector, FaultPlan
from repro.ft.straggler import ShardFlag, StragglerMitigator, flag_slow_shards
from repro.ft.driver import (FTRunResult, RecoveryEvent, checkpoint_key,
                             elastic_restore, reshard_checkpoint_arrays,
                             run_hybrid_ft)

__all__ = ["HeartbeatMonitor", "WorkerState", "ElasticPlan",
           "partition_owners", "replan_partitions", "resize_labels",
           "reshard_vertex_tree", "FaultInjector", "FaultPlan",
           "ShardFlag", "StragglerMitigator", "flag_slow_shards",
           "FTRunResult", "RecoveryEvent", "checkpoint_key",
           "elastic_restore", "reshard_checkpoint_arrays", "run_hybrid_ft"]
