"""Fault-tolerant hybrid driver: superstep checkpointing, failure recovery,
elastic resume (paper §5.3).

GraphHP's local phase runs minutes of pseudo-supersteps between
synchronization points, which amplifies the cost of losing a worker
mid-iteration — so the engine checkpoints ``EngineState`` at
global-iteration boundaries (the only points where the whole computation is
a pure function of vertex state: halo buffers are refilled by the next
exchange, so nothing transient needs saving) through an
:class:`~repro.checkpoint.AsyncCheckpointer` that snapshots to host and
writes off-thread.  Each checkpoint is keyed to the graph content digest +
program name + iteration; resume validates the key and restores bit-for-bit
— a run interrupted after iteration k and resumed produces the *identical*
final state and :class:`~repro.core.runtime.Counters` as the uninterrupted
run.

Failure recovery follows the paper's ping mechanism:
:class:`~repro.ft.heartbeat.HeartbeatMonitor` tracks simulated workers on
an injected logical clock (one tick per global iteration), a
:class:`~repro.ft.inject.FaultInjector` scripts deterministic kills/delays,
and a detected failure triggers ``reassign_failed`` + restore from the
latest durable checkpoint, with the recovery cost (iterations lost, restore
seconds, bytes read) surfaced on the run result.

Elastic resume (k -> k' partitions, via ``repro.io.resize``) re-shards the
checkpointed vertex state by global vertex id and re-announces every
vertex's current out-value on the first exchange — safe exactly for
monotone-semiring programs (min/max combiners: re-delivery can only
re-confirm the fixed point), which the restore path enforces.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.ckpt import (AsyncCheckpointer, CheckpointError,
                                   checkpoint_bytes, latest_checkpoint,
                                   load_checkpoint, load_checkpoint_arrays,
                                   read_manifest, _leaf_path_names)
from repro.core.engine_hybrid import hybrid_iteration, init_hybrid
from repro.core.runtime import EngineState, deliver, quiescent
from repro.core.vertex_program import VertexProgram
from repro.ft.elastic import partition_owners, reshard_vertex_tree
from repro.ft.heartbeat import HeartbeatMonitor
from repro.ft.inject import FaultInjector
from repro.ft.straggler import ShardFlag, flag_slow_shards
from repro.io.digest import graph_digest

__all__ = ["run_hybrid_ft", "RecoveryEvent", "FTRunResult", "checkpoint_key",
           "elastic_restore", "reshard_checkpoint_arrays"]

_PSEUDO = "pseudo_supersteps"
_HALO = ("halo_out", "halo_send")


@dataclasses.dataclass(frozen=True)
class RecoveryEvent:
    """One failure -> reassign -> restore cycle, with its cost."""

    tick: int                     # driver tick at detection
    failed_workers: tuple[int, ...]
    moved: dict[int, list]        # reassignment table (worker -> partitions)
    restored_iteration: int
    iterations_lost: int          # work rolled back to the checkpoint
    restore_seconds: float
    bytes_read: int               # the latest checkpoint only, never a rebuild


@dataclasses.dataclass
class FTRunResult:
    es: EngineState
    iterations: int
    recoveries: list[RecoveryEvent]
    straggler_flags: list[ShardFlag]
    resumed_from: str | None      # checkpoint dir this run started from
    epoch: int                    # monitor reassignment epoch at exit


def checkpoint_key(graph, prog: VertexProgram) -> dict:
    """What a checkpoint is keyed to: the graph content digest (the same
    ``io.digest.graph_digest`` the ingest benchmark pins builder identity
    with) + the program's class name."""
    return {"graph_digest": graph_digest(graph),
            "program": type(prog).__name__}


def _validate_key(meta: dict, key: dict, path: str) -> None:
    for k in ("graph_digest", "program"):
        if meta.get(k) != key[k]:
            raise CheckpointError(
                f"{path}: checkpoint is keyed to {k}={meta.get(k)!r}, this "
                f"run has {key[k]!r} — refusing to restore state from a "
                f"different graph/program")


def _monotone_only(prog: VertexProgram, what: str) -> None:
    bad = [ch.name for ch in prog.channels if ch.combiner not in
           ("min", "max")]
    if bad:
        raise CheckpointError(
            f"{what} re-announces every vertex's current value on the next "
            f"exchange, which only monotone (min/max-combiner) programs "
            f"absorb; channels {bad} do not qualify")


def reshard_checkpoint_arrays(arrs: dict[str, np.ndarray],
                              old_part: np.ndarray, new_part: np.ndarray,
                              pad_multiple: int = 8) -> dict[str, np.ndarray]:
    """Re-shard one checkpoint's leaves (by manifest name) from the old to
    the new partitioning: vertex-keyed ``(P, Vp, ...)`` families remap by
    global vertex id, halo families drop (derived state — the next exchange
    refills them), per-partition ``pseudo_supersteps`` reset (the counts
    are meaningless across a re-partition), scalars carry over."""
    P_n = int(np.asarray(new_part).max()) + 1 if len(new_part) else 1
    keep = {k: v for k, v in arrs.items()
            if not any(h in k for h in _HALO)}
    out = reshard_vertex_tree(keep, old_part, new_part,
                              pad_multiple=pad_multiple)
    for name in list(out):
        if _PSEUDO in name:
            out[name] = np.zeros((P_n,), dtype=np.asarray(out[name]).dtype)
    return out


def elastic_restore(ckpt_path: str, graph, prog: VertexProgram, vdata: Any,
                    old_part: np.ndarray, new_part: np.ndarray,
                    pad_multiple: int = 8, use_ell: bool = True,
                    collect_metrics: bool = True,
                    expect_digest: str | None = None
                    ) -> tuple[EngineState, int]:
    """Restore a checkpoint written under ``old_part`` into an engine state
    for ``graph`` built under ``new_part`` (k -> k' elastic resume).

    Returns ``(state, iteration)``.  Monotone-semiring programs only (the
    re-announce on the first exchange re-delivers current values, which
    min/max combiners absorb and a sum combiner would double-count)."""
    _monotone_only(prog, "elastic restore")
    arrs, manifest = load_checkpoint_arrays(ckpt_path)
    meta = manifest.get("meta", {})
    if meta.get("program") not in (None, type(prog).__name__):
        raise CheckpointError(
            f"{ckpt_path}: checkpoint is for program {meta.get('program')!r}"
            f", restoring {type(prog).__name__!r}")
    if expect_digest is not None and meta.get("graph_digest") != expect_digest:
        raise CheckpointError(
            f"{ckpt_path}: graph_digest {meta.get('graph_digest')!r} != "
            f"expected {expect_digest!r}")
    if not meta.get("elastic"):
        arrs = reshard_checkpoint_arrays(arrs, old_part, new_part,
                                         pad_multiple=pad_multiple)
    template = init_hybrid(graph, prog, vdata, use_ell=use_ell,
                           collect_metrics=collect_metrics)
    names = _leaf_path_names(template)
    leaves, treedef = jax.tree_util.tree_flatten(template)
    out = []
    for name, leaf in zip(names, leaves):
        if name not in arrs:          # halo families: refilled by exchange
            out.append(leaf)
            continue
        a = arrs[name]
        if tuple(a.shape) != tuple(leaf.shape) or str(a.dtype) != \
                str(leaf.dtype):
            raise CheckpointError(
                f"{ckpt_path}: re-sharded leaf {name!r} is {a.dtype}"
                f"{a.shape}, the new graph's state wants {leaf.dtype}"
                f"{tuple(leaf.shape)} (pad_multiple mismatch?)")
        out.append(jnp.asarray(a))
    es = jax.tree_util.tree_unflatten(treedef, out)
    # re-announce: every valid vertex re-sends its current out-value — via
    # export_send for the next exchange (edges the new cut made remote), and
    # by one immediate local delivery into pending (edges a shrink made
    # local, whose consumers used to be fed by the old cut's exchange; the
    # global apply overwrites `send` before the iteration's local delivery,
    # so a flag alone would be lost — this mirrors ``init_hybrid``).
    # Monotone combiners make the duplicate deliveries to old consumers
    # no-ops.
    es = dataclasses.replace(es, export_out=jax.tree.map(jnp.asarray, es.out),
                             export_send=graph.vertex_mask,
                             send=graph.vertex_mask)
    es, _ = deliver(graph, prog, es, edges="local", use_ell=use_ell,
                    collect_metrics=collect_metrics)
    return es, int(manifest["step"])


def run_hybrid_ft(
    graph,
    prog: VertexProgram,
    vdata: Any = None,
    *,
    ckpt_dir: str | None = None,
    checkpointer: AsyncCheckpointer | None = None,
    checkpoint_every: int = 1,
    keep: int = 3,
    resume: bool = True,
    step_fn: Callable | None = None,
    es_shardings: Any = None,
    max_iters: int = 100_000,
    max_local_steps: int = 100_000,
    use_ell: bool = True,
    collect_metrics: bool = True,
    n_workers: int = 1,
    monitor: HeartbeatMonitor | None = None,
    injector: FaultInjector | None = None,
    tick_seconds: float = 1.0,
    straggler_factor: float = 1.5,
    balance: float | None = None,
) -> FTRunResult:
    """Run global iterations to quiescence with checkpointing + recovery.

    ``step_fn`` is one jittable global iteration ``(graph, es) -> es``
    (default: the host :func:`hybrid_iteration`; pass the result of
    :func:`~repro.core.distributed.make_dist_hybrid_step` plus
    ``es_shardings`` for the shard_map path — restores are ``device_put``
    back onto the mesh through ``load_checkpoint(shardings=...)``).

    Checkpoints land under ``ckpt_dir`` every ``checkpoint_every`` global
    iterations, written off-thread (:class:`AsyncCheckpointer`), each keyed
    to :func:`checkpoint_key`; ``resume=True`` restarts from the latest
    complete checkpoint when one exists (exact resume: identical final
    state and counters to the uninterrupted run).

    Failure detection runs on an injected logical clock: each driver tick
    advances it ``tick_seconds``, live workers heartbeat (all of them, or
    the ones ``injector`` scripts), and a sweep past ``fail_after`` marks a
    worker FAILED — the driver then reassigns its partitions to the
    least-loaded healthy workers and rolls back to the latest checkpoint,
    recording a :class:`RecoveryEvent`.  Deterministic by construction: no
    wall-clock enters control flow.

    Engine knobs (``vdata``, ``max_iters``, ``max_local_steps``,
    ``use_ell``, ``collect_metrics``) mean exactly what they mean to
    :func:`~repro.core.engine_hybrid.run_hybrid`.  ``straggler_factor``
    flags a worker's iteration as straggling when its simulated duration
    exceeds that multiple of the tick median; ``balance`` optionally caps
    post-recovery load imbalance during reassignment.

    Returns:
        An :class:`FTRunResult`: the final ``EngineState`` (``es``) and
        iteration count, every :class:`RecoveryEvent` and straggler
        ``ShardFlag`` observed, ``resumed_from`` (checkpoint dir this run
        restored from, or ``None`` for a cold start), and the monitor's
        final reassignment ``epoch``.

    Raises:
        CheckpointError: a checkpoint under ``ckpt_dir`` is keyed to a
            different graph digest or program than this run — refusing to
            restore mismatched state.
    """
    if step_fn is None:
        def step_fn(g, e):
            return hybrid_iteration(g, prog, e, vdata,
                                    max_local_steps=max_local_steps,
                                    use_ell=use_ell,
                                    collect_metrics=collect_metrics)
    jstep = jax.jit(step_fn)

    key = checkpoint_key(graph, prog)
    template = init_hybrid(graph, prog, vdata, use_ell=use_ell,
                           collect_metrics=collect_metrics)
    if es_shardings is not None:
        template = jax.device_put(template, es_shardings)

    own_ckpt = checkpointer is None and ckpt_dir is not None
    if own_ckpt:
        checkpointer = AsyncCheckpointer(ckpt_dir, keep=keep)
    base = ckpt_dir if ckpt_dir is not None else getattr(
        checkpointer, "base", None)

    def restore() -> tuple[EngineState, int, str | None, int]:
        """(state, iteration, path, bytes_read) from the latest durable
        checkpoint, or the initialization state when none exists."""
        if checkpointer is not None:
            checkpointer.wait()        # in-flight writes become durable
        path = latest_checkpoint(base) if base else None
        if path is None:
            return template, 0, None, 0
        _validate_key(read_manifest(path).get("meta", {}), key, path)
        es, step = load_checkpoint(path, template, shardings=es_shardings)
        return es, int(step), path, checkpoint_bytes(path)

    resumed_from = None
    if resume and base is not None:
        es, it, resumed_from, _ = restore()
    else:
        es, it = template, 0

    # --- simulated cluster: contiguous partition blocks per worker --------
    P = graph.n_partitions
    clock = [0.0]
    if monitor is None:
        monitor = HeartbeatMonitor(n_workers, suspect_after=1.5 * tick_seconds,
                                   fail_after=2.5 * tick_seconds,
                                   clock=lambda: clock[0])
        for p, w in enumerate(partition_owners(P, n_workers)):
            monitor.assign(int(w), p)
    n_workers = len(monitor.workers)

    recoveries: list[RecoveryEvent] = []
    tick = 0
    while it < max_iters and not bool(quiescent(prog, es)):
        tick += 1
        clock[0] += tick_seconds
        beating = (injector.beating(tick) if injector is not None
                   else range(n_workers))
        for w in beating:
            monitor.beat(w)
        newly_failed = monitor.sweep()
        if newly_failed:
            moved = monitor.reassign_failed()
            t0 = time.perf_counter()
            es, rit, _, nbytes = restore()
            recoveries.append(RecoveryEvent(
                tick=tick, failed_workers=tuple(newly_failed), moved=moved,
                restored_iteration=rit, iterations_lost=it - rit,
                restore_seconds=time.perf_counter() - t0, bytes_read=nbytes))
            it = rit
            continue
        es = jstep(graph, es)
        it = int(es.counters.iterations)
        if checkpointer is not None and it % checkpoint_every == 0:
            checkpointer.save(it, es, meta={**key, "iteration": it})

    if checkpointer is not None:
        checkpointer.wait()
        if own_ckpt:
            checkpointer.close()

    flags = flag_slow_shards(
        np.asarray(jax.device_get(es.counters.pseudo_supersteps)),
        balance=balance, factor=straggler_factor)
    return FTRunResult(es=es, iterations=it, recoveries=recoveries,
                       straggler_flags=flags, resumed_from=resumed_from,
                       epoch=monitor.epoch)
