"""Fault-tolerant hybrid driver: superstep checkpointing, failure recovery,
elastic resume (paper §5.3).

GraphHP's local phase runs minutes of pseudo-supersteps between
synchronization points, which amplifies the cost of losing a worker
mid-iteration — so the engine checkpoints ``EngineState`` at
global-iteration boundaries (the only points where the whole computation is
a pure function of vertex state: halo buffers are refilled by the next
exchange, so nothing transient needs saving) through an
:class:`~repro.checkpoint.AsyncCheckpointer` that snapshots to host and
writes off-thread.  Each checkpoint is keyed to the graph content digest +
program name + iteration; resume validates the key and restores bit-for-bit
— a run interrupted after iteration k and resumed produces the *identical*
final state and :class:`~repro.core.runtime.Counters` as the uninterrupted
run.

Failure recovery follows the paper's ping mechanism:
:class:`~repro.ft.heartbeat.HeartbeatMonitor` tracks simulated workers on
an injected logical clock (one tick per global iteration), a
:class:`~repro.ft.inject.FaultInjector` scripts deterministic kills/delays,
and a detected failure triggers ``reassign_failed`` + restore from the
latest durable checkpoint, with the recovery cost (iterations lost, restore
seconds, bytes read) surfaced on the run result.

Elastic resume (k -> k' partitions, via ``repro.io.resize``) re-shards the
checkpointed vertex state by global vertex id and re-announces every
vertex's current out-value on the first exchange — safe exactly for
monotone-semiring programs (min/max combiners: re-delivery can only
re-confirm the fixed point), which the shared executor gate
(:func:`repro.exec.checkpoint.require_monotone`) enforces.

This module is configuration only: the loop lives in
:mod:`repro.exec.driver`, checkpoint save/resume in
:class:`repro.exec.checkpoint.CheckpointHook`, and ``run_hybrid_ft`` wires
them to a :class:`_FaultHook` driving the heartbeat -> reassign -> restore
cycle between steps.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.ckpt import (AsyncCheckpointer, CheckpointError,
                                   load_checkpoint_arrays, _leaf_path_names)
from repro.core.runtime import EngineState, deliver
from repro.core.vertex_program import VertexProgram
from repro.exec.checkpoint import (CheckpointHook, checkpoint_key,
                                   require_monotone)
from repro.exec.driver import ExecContext, ExecHook, run_engine
from repro.exec.iteration import init_hybrid
from repro.exec.policy import hybrid_policy
from repro.ft.elastic import partition_owners, reshard_vertex_tree
from repro.ft.heartbeat import HeartbeatMonitor
from repro.ft.inject import FaultInjector
from repro.ft.straggler import ShardFlag, flag_slow_shards
from repro.obs import clock as obs_clock

__all__ = ["run_hybrid_ft", "RecoveryEvent", "FTRunResult", "checkpoint_key",
           "elastic_restore", "reshard_checkpoint_arrays"]

_PSEUDO = "pseudo_supersteps"
_HALO = ("halo_out", "halo_send")


@dataclasses.dataclass(frozen=True)
class RecoveryEvent:
    """One failure -> reassign -> restore cycle, with its cost."""

    tick: int                     # driver tick at detection
    failed_workers: tuple[int, ...]
    moved: dict[int, list]        # reassignment table (worker -> partitions)
    restored_iteration: int
    iterations_lost: int          # work rolled back to the checkpoint
    restore_seconds: float
    bytes_read: int               # the latest checkpoint only, never a rebuild


@dataclasses.dataclass
class FTRunResult:
    es: EngineState
    iterations: int
    recoveries: list[RecoveryEvent]
    straggler_flags: list[ShardFlag]
    resumed_from: str | None      # checkpoint dir this run started from
    epoch: int                    # monitor reassignment epoch at exit
    registry: Any = None          # MetricsRegistry when one was passed in


def reshard_checkpoint_arrays(arrs: dict[str, np.ndarray],
                              old_part: np.ndarray, new_part: np.ndarray,
                              pad_multiple: int = 8) -> dict[str, np.ndarray]:
    """Re-shard one checkpoint's leaves (by manifest name) from the old to
    the new partitioning: vertex-keyed ``(P, Vp, ...)`` families remap by
    global vertex id, halo families drop (derived state — the next exchange
    refills them), per-partition ``pseudo_supersteps`` reset (the counts
    are meaningless across a re-partition), scalars carry over."""
    P_n = int(np.asarray(new_part).max()) + 1 if len(new_part) else 1
    keep = {k: v for k, v in arrs.items()
            if not any(h in k for h in _HALO)}
    out = reshard_vertex_tree(keep, old_part, new_part,
                              pad_multiple=pad_multiple)
    for name in list(out):
        if _PSEUDO in name:
            out[name] = np.zeros((P_n,), dtype=np.asarray(out[name]).dtype)
    return out


def elastic_restore(ckpt_path: str, graph, prog: VertexProgram, vdata: Any,
                    old_part: np.ndarray, new_part: np.ndarray,
                    pad_multiple: int = 8, use_ell: bool = True,
                    collect_metrics: bool = True,
                    expect_digest: str | None = None
                    ) -> tuple[EngineState, int]:
    """Restore a checkpoint written under ``old_part`` into an engine state
    for ``graph`` built under ``new_part`` (k -> k' elastic resume).

    Returns ``(state, iteration)``.  Monotone-semiring programs only (the
    re-announce on the first exchange re-delivers current values, which
    min/max combiners absorb and a sum combiner would double-count) — the
    gate is the executor's :func:`~repro.exec.checkpoint.require_monotone`,
    shared with the serving layer's K-lane resume."""
    require_monotone(prog, "elastic restore")
    arrs, manifest = load_checkpoint_arrays(ckpt_path)
    meta = manifest.get("meta", {})
    if meta.get("program") not in (None, type(prog).__name__):
        raise CheckpointError(
            f"{ckpt_path}: checkpoint is for program {meta.get('program')!r}"
            f", restoring {type(prog).__name__!r}")
    if expect_digest is not None and meta.get("graph_digest") != expect_digest:
        raise CheckpointError(
            f"{ckpt_path}: graph_digest {meta.get('graph_digest')!r} != "
            f"expected {expect_digest!r}")
    if not meta.get("elastic"):
        arrs = reshard_checkpoint_arrays(arrs, old_part, new_part,
                                         pad_multiple=pad_multiple)
    template = init_hybrid(graph, prog, vdata, use_ell=use_ell,
                           collect_metrics=collect_metrics)
    names = _leaf_path_names(template)
    leaves, treedef = jax.tree_util.tree_flatten(template)
    out = []
    for name, leaf in zip(names, leaves):
        if name not in arrs:          # halo families: refilled by exchange
            out.append(leaf)
            continue
        a = arrs[name]
        if tuple(a.shape) != tuple(leaf.shape) or str(a.dtype) != \
                str(leaf.dtype):
            raise CheckpointError(
                f"{ckpt_path}: re-sharded leaf {name!r} is {a.dtype}"
                f"{a.shape}, the new graph's state wants {leaf.dtype}"
                f"{tuple(leaf.shape)} (pad_multiple mismatch?)")
        out.append(jnp.asarray(a))
    es = jax.tree_util.tree_unflatten(treedef, out)
    # re-announce: every valid vertex re-sends its current out-value — via
    # export_send for the next exchange (edges the new cut made remote), and
    # by one immediate local delivery into pending (edges a shrink made
    # local, whose consumers used to be fed by the old cut's exchange; the
    # global apply overwrites `send` before the iteration's local delivery,
    # so a flag alone would be lost — this mirrors ``init_hybrid``).
    # Monotone combiners make the duplicate deliveries to old consumers
    # no-ops.
    es = dataclasses.replace(es, export_out=jax.tree.map(jnp.asarray, es.out),
                             export_send=graph.vertex_mask,
                             send=graph.vertex_mask)
    es, _ = deliver(graph, prog, es, edges="local", use_ell=use_ell,
                    collect_metrics=collect_metrics)
    return es, int(manifest["step"])


class _FaultHook(ExecHook):
    """Heartbeat/failure detection between executor steps.

    Each tick advances the injected logical clock, beats the live (or
    injector-scripted) workers, and sweeps the monitor; a detected failure
    reassigns the dead workers' partitions and rolls the run back to the
    latest durable checkpoint via the shared :class:`CheckpointHook`,
    consuming the tick (the step is skipped).  Deterministic by
    construction: no wall-clock enters control flow.
    """

    def __init__(self, monitor: HeartbeatMonitor,
                 injector: FaultInjector | None,
                 ckpt: CheckpointHook, clock: list, tick_seconds: float,
                 tracer=None):
        self.monitor = monitor
        self.injector = injector
        self.ckpt = ckpt
        self.clock = clock
        self.tick_seconds = tick_seconds
        self.tracer = tracer
        self.recoveries: list[RecoveryEvent] = []

    def before_step(self, ctx: ExecContext) -> bool | None:
        self.clock[0] += self.tick_seconds
        n_workers = len(self.monitor.workers)
        beating = (self.injector.beating(ctx.tick)
                   if self.injector is not None else range(n_workers))
        for w in beating:
            self.monitor.beat(w)
        newly_failed = self.monitor.sweep()
        if not newly_failed:
            return None
        moved = self.monitor.reassign_failed()
        t0 = obs_clock.perf_counter()
        es, rit, _, nbytes = self.ckpt.restore()
        ev = RecoveryEvent(
            tick=ctx.tick, failed_workers=tuple(newly_failed), moved=moved,
            restored_iteration=rit, iterations_lost=ctx.iteration - rit,
            restore_seconds=obs_clock.perf_counter() - t0, bytes_read=nbytes)
        self.recoveries.append(ev)
        if self.tracer is not None:
            self.tracer.add(
                "recovery", t0, ev.restore_seconds, cat="ft", ph="X",
                tick=ev.tick, failed_workers=list(ev.failed_workers),
                restored_iteration=rit,
                iterations_lost=ev.iterations_lost,
                bytes_read=ev.bytes_read)
        ctx.es, ctx.iteration = es, rit
        return False                  # rolled back: skip this tick's step


def run_hybrid_ft(
    graph,
    prog: VertexProgram,
    vdata: Any = None,
    *,
    ckpt_dir: str | None = None,
    checkpointer: AsyncCheckpointer | None = None,
    checkpoint_every: int = 1,
    keep: int = 3,
    resume: bool = True,
    step_fn: Callable | None = None,
    es_shardings: Any = None,
    max_iters: int = 100_000,
    max_local_steps: int = 100_000,
    use_ell: bool = True,
    collect_metrics: bool = True,
    n_workers: int = 1,
    monitor: HeartbeatMonitor | None = None,
    injector: FaultInjector | None = None,
    tick_seconds: float = 1.0,
    straggler_factor: float = 1.5,
    balance: float | None = None,
    tracer=None,
    registry=None,
) -> FTRunResult:
    """Run global iterations to quiescence with checkpointing + recovery.

    ``step_fn`` is one jittable global iteration ``(graph, es) -> es``
    (default: the host :func:`~repro.exec.iteration.hybrid_iteration`;
    pass the result of
    :func:`~repro.core.distributed.make_dist_hybrid_step` plus
    ``es_shardings`` for the shard_map path — restores are ``device_put``
    back onto the mesh through ``load_checkpoint(shardings=...)``).

    Checkpoints land under ``ckpt_dir`` every ``checkpoint_every`` global
    iterations, written off-thread (:class:`AsyncCheckpointer`), each keyed
    to :func:`~repro.exec.checkpoint.checkpoint_key`; ``resume=True``
    restarts from the latest complete checkpoint when one exists (exact
    resume: identical final state and counters to the uninterrupted run).

    Failure detection runs on an injected logical clock: each driver tick
    advances it ``tick_seconds``, live workers heartbeat (all of them, or
    the ones ``injector`` scripts), and a sweep past ``fail_after`` marks a
    worker FAILED — the driver then reassigns its partitions to the
    least-loaded healthy workers and rolls back to the latest checkpoint,
    recording a :class:`RecoveryEvent`.  Deterministic by construction: no
    wall-clock enters control flow.

    Engine knobs (``vdata``, ``max_iters``, ``max_local_steps``,
    ``use_ell``, ``collect_metrics``) mean exactly what they mean to
    :func:`~repro.core.engine_hybrid.run_hybrid`.  ``straggler_factor``
    flags a worker's iteration as straggling when its simulated duration
    exceeds that multiple of the tick median; ``balance`` optionally caps
    post-recovery load imbalance during reassignment.

    ``tracer`` (a :class:`repro.obs.trace.Tracer`) records one span per
    global iteration, the checkpoint/fault hooks' per-method costs, and a
    ``recovery`` span (``cat="ft"``) for every failure -> restore cycle.
    ``registry`` (a :class:`repro.obs.metrics.MetricsRegistry`) receives
    the run's counters / checkpoint / recovery metrics at exit, and the
    straggler flags are then derived *from the registry* (the
    ``engine.pseudo_supersteps`` gauge and ``partition.balance``).  Both
    default to off, adding nothing to the run.

    Returns:
        An :class:`FTRunResult`: the final ``EngineState`` (``es``) and
        iteration count, every :class:`RecoveryEvent` and straggler
        ``ShardFlag`` observed, ``resumed_from`` (checkpoint dir this run
        restored from, or ``None`` for a cold start), the monitor's final
        reassignment ``epoch``, and the populated ``registry`` (when one
        was passed).

    Raises:
        CheckpointError: a checkpoint under ``ckpt_dir`` is keyed to a
            different graph digest or program than this run — refusing to
            restore mismatched state.
    """
    policy = hybrid_policy(use_ell=use_ell, collect_metrics=collect_metrics,
                           max_local_steps=max_local_steps)
    if step_fn is None:
        def step_fn(g, e):
            return policy.step(g, prog, e, vdata)
    jstep = jax.jit(step_fn)

    template = init_hybrid(graph, prog, vdata, use_ell=use_ell,
                           collect_metrics=collect_metrics)
    if es_shardings is not None:
        template = jax.device_put(template, es_shardings)

    ckpt = CheckpointHook(key=checkpoint_key(graph, prog, vdata),
                          ckpt_dir=ckpt_dir, checkpointer=checkpointer,
                          every=checkpoint_every, keep=keep, resume=resume,
                          template=template, shardings=es_shardings)

    # --- simulated cluster: contiguous partition blocks per worker --------
    P = graph.n_partitions
    clock = [0.0]
    if monitor is None:
        monitor = HeartbeatMonitor(n_workers, suspect_after=1.5 * tick_seconds,
                                   fail_after=2.5 * tick_seconds,
                                   clock=lambda: clock[0])
        for p, w in enumerate(partition_owners(P, n_workers)):
            monitor.assign(int(w), p)
    fault = _FaultHook(monitor, injector, ckpt, clock, tick_seconds,
                       tracer=tracer)

    hooks: tuple = (fault, ckpt)
    if tracer is not None:
        # opt-in only: the default path never imports the tracing module
        from repro.obs.trace import trace_hooks, wrap_hooks
        hooks = wrap_hooks(tracer, hooks) + trace_hooks(tracer)

    ctx = run_engine(graph, prog, policy, vdata, max_iters=max_iters,
                     hooks=hooks, es=template,
                     jit_step=lambda e: jstep(graph, e))

    pseudo = np.asarray(jax.device_get(ctx.es.counters.pseudo_supersteps))
    if registry is not None:
        from repro.obs.metrics import (record_checkpointer,
                                       record_engine_counters)
        record_engine_counters(registry, ctx.es.counters)
        if ckpt.checkpointer is not None:
            record_checkpointer(registry, ckpt.checkpointer)
        if balance is not None:
            registry.set_gauge("partition.balance", float(balance))
        registry.set_counter("ft.recoveries", float(len(fault.recoveries)))
        registry.set_counter("ft.iterations_lost", float(sum(
            r.iterations_lost for r in fault.recoveries)))
        # the flags now come from the registry's own gauges — the same
        # numbers any external consumer of the profile would read
        flags = flag_slow_shards(registry=registry, factor=straggler_factor)
    else:
        flags = flag_slow_shards(pseudo, balance=balance,
                                 factor=straggler_factor)
    return FTRunResult(es=ctx.es, iterations=ctx.iteration,
                       recoveries=fault.recoveries, straggler_flags=flags,
                       resumed_from=ckpt.resumed_from, epoch=monitor.epoch,
                       registry=registry)
