"""The out-of-core build pipeline: edge stream -> ``PartitionedGraph``.

Four bounded-memory stages, each a pass over the chunk stream or the
shards — never over a full in-memory edge array:

  1. **degree pass** — out/in-degree histograms (vertex-scale memory),
     vertex-count inference for headerless text;
  2. **labeling** — ``hash`` needs nothing, ``fennel`` runs its scoring
     core over an *external* undirected CSR scatter-built on disk and
     mmap'd back (identical labels to the in-memory path — affinity is a
     neighbour count, so CSR neighbour order is irrelevant); ``bfs`` /
     ``multilevel`` are inherently in-memory algorithms and transparently
     fall back to loading the edges once for the labeling step only;
  3. **spill** — one pass bucketing edges by destination partition into a
     ``.ghp`` shard directory (pre-headered ``.npy`` shards appended
     through buffered handles; original relative order preserved within
     each shard);
  4. **per-partition build** — two passes over the shards (a dimension
     prescan, then the fill) driving the *same* per-partition helpers
     ``core.graph`` uses; each filled partition row streams to scratch
     ``.npy`` files and the final jax arrays convert straight off the
     mmap, so even the padded product is resident only once, as the
     result.

Peak memory is O(chunk + vertex-scale tables + largest partition shard +
the finished graph) — the O(E) edge array, its per-partition copies, the
sort scratch *and the numpy copy of the product* that bound the in-memory
builder never materialize together.  The result is bit-identical to
``build_partitioned_graph`` for any labeling and any chunk size (pinned
by ``tests/test_io.py``).
"""

from __future__ import annotations

import os
import tempfile

import numpy as np

from repro.core.graph import (_CORE_SPEC, PartitionedGraph, _EdgeLayout,
                              _block_layout, _ell_fill_partition,
                              _ell_finalize, _ell_pick, _ell_plan,
                              _export_tables, _fill_core_partition,
                              _finalize_graph, _halo_ptrs, _partition_edges,
                              _round_up, _vertex_slots)
from repro.io.format import (GraphFormatError, ShardedGraph, ShardWriter,
                             load_graph)
from repro.io.readers import (DEFAULT_CHUNK_EDGES, EdgeSource,
                              TextEdgeSource, open_edge_source)

__all__ = ["degree_pass", "external_undirected_csr", "partition_source",
           "spill_to_ghp", "ingest_to_ghp", "build_from_sharded",
           "build_partitioned_graph_from_path"]


def _accum_bincount(acc: np.ndarray, ids: np.ndarray, width: int
                    ) -> np.ndarray:
    out = np.bincount(ids, minlength=max(len(acc), width))
    out[: len(acc)] += acc
    return out


def degree_pass(source: EdgeSource):
    """One pass: ``(n_vertices, n_edges, out_degree, in_degree)``.
    Vertex count is taken from the source's metadata when it has any,
    else inferred as max id + 1."""
    out_deg = np.zeros(0, dtype=np.int64)
    in_deg = np.zeros(0, dtype=np.int64)
    n_edges = 0
    for edges, _ in source.chunks():
        if len(edges):
            width = int(edges.max()) + 1
            out_deg = _accum_bincount(out_deg, edges[:, 0], width)
            in_deg = _accum_bincount(in_deg, edges[:, 1], width)
        n_edges += len(edges)
    n_vertices = (source.n_vertices if source.n_vertices is not None
                  else len(out_deg))
    if len(out_deg) > n_vertices:
        raise GraphFormatError(
            f"edge endpoint {len(out_deg) - 1} out of range for "
            f"n_vertices={n_vertices}")
    pad = n_vertices - len(out_deg)
    out_deg = np.pad(out_deg, (0, pad))
    in_deg = np.pad(in_deg, (0, pad))
    return n_vertices, n_edges, out_deg, in_deg


def external_undirected_csr(source: EdgeSource, n_vertices: int,
                            und_degree: np.ndarray, workdir: str):
    """Scatter-build the symmetrized CSR adjacency on disk and hand back
    ``(starts, adj)`` with ``adj`` an ``.npy`` memmap — the structure
    fennel's scoring core random-accesses without ever holding 2E
    neighbour entries in memory.  ``und_degree`` is out+in degree (the
    degree pass already paid for it), fixing every row's extent up
    front so one pass suffices."""
    from numpy.lib.format import open_memmap

    und_degree = np.asarray(und_degree)
    if und_degree.shape != (n_vertices,):
        raise ValueError(f"und_degree shape {und_degree.shape} != "
                         f"({n_vertices},)")
    starts = np.zeros(n_vertices + 1, dtype=np.int64)
    np.cumsum(und_degree, out=starts[1:])
    total = int(starts[-1])
    dtype = (np.int32 if n_vertices <= np.iinfo(np.int32).max + 1
             else np.int64)
    adj_path = os.path.join(workdir, "adj.npy")
    adj = open_memmap(adj_path, mode="w+", dtype=dtype, shape=(total,))
    cursor = np.zeros(n_vertices, dtype=np.int64)
    for edges, _ in source.chunks():
        if not len(edges):
            continue
        ends = np.concatenate([edges[:, 0], edges[:, 1]])
        vals = np.concatenate([edges[:, 1], edges[:, 0]])
        order = np.argsort(ends, kind="stable")
        ends_s, vals_s = ends[order], vals[order]
        run0 = np.searchsorted(ends_s, ends_s, side="left")
        slot = cursor[ends_s] + (np.arange(len(ends_s)) - run0)
        adj[starts[ends_s] + slot] = vals_s.astype(dtype)
        cursor += np.bincount(ends_s, minlength=n_vertices)
    if not np.array_equal(cursor, und_degree):
        raise GraphFormatError("edge stream changed between the degree "
                               "pass and the CSR pass")
    adj.flush()
    return starts, np.load(adj_path, mmap_mode="r")


def partition_source(source: EdgeSource, part, n_vertices: int,
                     n_partitions: int | None, seed: int, workdir: str,
                     n_edges: int, und_degree: np.ndarray) -> np.ndarray:
    """Resolve ``part`` (a labeling array or a partitioner name) against a
    chunk stream.  'hash' touches no edges; 'fennel' streams through the
    external CSR; every other registered partitioner is an in-memory
    algorithm and falls back to loading the edge list once, for the
    labeling step only (the build itself stays out-of-core)."""
    if not isinstance(part, str):
        part = np.asarray(part, dtype=np.int32)
        if part.shape != (n_vertices,):
            raise ValueError(f"labeling shape {part.shape} != "
                             f"({n_vertices},)")
        if part.size and int(part.min()) < 0:
            raise ValueError(
                f"labeling contains negative partition id "
                f"{int(part.min())} (every vertex must be assigned)")
        return part
    if n_partitions is None:
        raise ValueError("partitioner-by-name needs n_partitions")
    if part == "hash":
        from repro.partition import hash_partition
        return hash_partition(n_vertices, n_partitions, seed=seed)
    if part == "fennel":
        from repro.partition import fennel_partition_csr
        starts, adj = external_undirected_csr(source, n_vertices,
                                              und_degree, workdir)
        return fennel_partition_csr(starts, adj, n_vertices, n_partitions,
                                    n_edges=n_edges, seed=seed)
    from repro.partition import make_partition
    edges = np.concatenate([e for e, _ in source.chunks()], axis=0) \
        if n_edges else np.zeros((0, 2), np.int64)
    return make_partition(part, edges, n_vertices, n_partitions, seed=seed)


class _RowShim:
    """Index adapter handing the shared fill helpers a single-partition
    staging row: ``arr[p, ...]`` resolves to row 0 whatever ``p`` is, so
    ``_fill_core_partition``/``_ell_fill_partition`` run unchanged while
    only one partition's row of the padded product exists in memory."""

    def __init__(self, arr: np.ndarray):
        self._a = arr

    @staticmethod
    def _map(key):
        return (0,) + key[1:] if isinstance(key, tuple) else 0

    def __getitem__(self, key):
        return self._a[self._map(key)]

    def __setitem__(self, key, val):
        self._a[self._map(key)] = val


class _RowSpill:
    """One family of arrays streamed to scratch ``.npy`` files one
    partition at a time (fill order is partition-major, so writes append
    sequentially), then handed to jax straight off the mmap — the full
    numpy product never becomes resident alongside the jax one.

    ``spec`` maps array name -> (staging tail shape, dtype, fill value,
    file shape).  The staging row's leading tail axis is the *widest*
    per-partition span; ``commit_row(spans)`` writes only the first
    ``spans[name]`` entries of that axis (a C-contiguous prefix) and
    ``pad(n, names)`` appends ``n`` fill entries — together they stream
    block-ragged ``(B, W, ...)`` files whose rows are per-partition spans
    laid end to end, as well as plain padded ``(P, ...)`` families (where
    every committed span is the full width)."""

    def __init__(self, workdir: str, tag: str, spec: dict):
        from repro.io.format import _create_npy
        self._paths = {}
        self._files = {}
        self._rows = {}
        self._fills = {}
        self._written = {}
        self._expected = {}
        for name, (tail, dtype, fill, file_shape) in spec.items():
            path = os.path.join(workdir, f"{tag}.{name}.npy")
            self._paths[name] = path
            self._files[name] = _create_npy(path, dtype, file_shape)
            self._rows[name] = np.full((1,) + tail, fill, dtype=dtype)
            self._fills[name] = fill
            self._written[name] = 0
            self._expected[name] = int(file_shape[0]) * int(file_shape[1])

    def staging(self) -> dict:
        return {name: _RowShim(a) for name, a in self._rows.items()}

    def row(self, name: str) -> np.ndarray:
        return self._rows[name][0]

    def commit_row(self, spans: dict | int | None = None) -> None:
        """Append each staging row's first-``n`` entries (``n`` from
        ``spans`` — a per-name dict, one int for every name, or None for
        the full staging width) and reset the staging to fill."""
        for name, f in self._files.items():
            if spans is None:
                n = self._rows[name].shape[1]
            elif isinstance(spans, dict):
                n = spans.get(name, self._rows[name].shape[1])
            else:
                n = spans
            f.write(self._rows[name][0][:n].tobytes())
            self._written[name] += int(n)
            self._rows[name][...] = self._fills[name]

    def pad(self, n: int, names=None) -> None:
        """Append ``n`` fill entries (a block tail) to each named file."""
        if not n:
            return
        for name in (self._rows if names is None else names):
            a = np.full((n,) + self._rows[name].shape[2:],
                        self._fills[name], dtype=self._rows[name].dtype)
            self._files[name].write(a.tobytes())
            self._written[name] += int(n)

    def close(self) -> None:
        for name, f in self._files.items():
            f.close()
            if self._written[name] != self._expected[name]:
                raise AssertionError(
                    f"{name}: spilled {self._written[name]} entries, file "
                    f"header says {self._expected[name]}")
        self._files = {}
        self._rows = {}

    def load(self, name: str) -> np.ndarray:
        """The finished (P, ...) array as a read-only mmap — the shared
        finalizers jnp.asarray straight off it, pages only transiently
        resident."""
        return np.load(self._paths[name], mmap_mode="r")


def spill_to_ghp(source: EdgeSource, part: np.ndarray, n_vertices: int,
                 in_degree: np.ndarray, out_path: str, dtype=np.int64,
                 positions: bool = False, partitioner: str = "explicit",
                 partition_seed=None) -> ShardedGraph:
    """External bucket sort: one pass over the chunks, each edge appended
    to its destination partition's shard."""
    part = np.asarray(part, dtype=np.int32)
    P = int(part.max()) + 1 if part.size else 1
    sizes = np.zeros(P, dtype=np.int64)
    np.add.at(sizes, part, in_degree)
    weighted = source.weighted
    if weighted is None:        # unsniffed text: peek at the first chunk
        first = next(iter(source.chunks()), (None, None))
        weighted = first[1] is not None
    wr = ShardWriter(out_path, n_vertices, part, sizes, dtype=dtype,
                     weighted=bool(weighted), positions=positions,
                     partitioner=partitioner, partition_seed=partition_seed)
    for edges, w in source.chunks():
        wr.append(np.asarray(edges, dtype=np.int64), w, part)
    return wr.close()


def build_from_sharded(sg: ShardedGraph, pad_multiple: int = 8,
                       build_ell: bool = True, ell_pad_slices: int = 8,
                       ell_base_slices: int = 128,
                       edge_blocks: int = 1,
                       workdir: str | None = None) -> PartitionedGraph:
    """Out-of-core ``build_partitioned_graph``: two passes over the
    shards (dimension prescan, then fill), one partition resident at a
    time, through the same per-partition helpers as the in-memory builder.
    Filled partition spans stream to scratch ``.npy`` files (``workdir``,
    default a TemporaryDirectory) and come back as jax arrays straight off
    the mmap, so the block-ragged product is resident once — as the
    result — never twice.  ``edge_blocks`` selects the edge layout exactly
    as on the in-memory builder (1 = fully ragged, ``P`` = the legacy
    shared-width padding).  Same arrays out as
    ``build_partitioned_graph``, bit for bit; peak memory O(largest shard
    + vertex tables + the result)."""
    part = sg.part
    n = sg.n_vertices
    P, verts_by_p, slot_of, Vp = _vertex_slots(part, n, pad_multiple)
    if P != sg.n_partitions:
        raise GraphFormatError(
            f"{sg.path}: labels span {P} partitions, meta says "
            f"{sg.n_partitions}")

    # --- prescan: global dims + vertex-scale tables ----------------------
    out_degree = np.zeros(n, dtype=np.int64)
    is_boundary_g = np.zeros(n, dtype=bool)
    halo_by_p: list[np.ndarray] = []
    deg_local: list[np.ndarray] = []
    deg_remote: list[np.ndarray] = []
    ne_by_p: list[int] = []
    ng_by_p: list[int] = []
    for p in range(P):
        e, _, _ = sg.shard(p, mmap=False, weights=False, positions=False)
        es = np.ascontiguousarray(e[:, 0], dtype=np.int64)
        ed = np.ascontiguousarray(e[:, 1], dtype=np.int64)
        del e
        out_degree += np.bincount(es, minlength=n)
        psrc = part[es]
        local = psrc == p
        # int32 halo lists: vertex-scale but one entry per (partition,
        # remote source) pair — on a hash cut that is most of V per
        # partition, so the width matters
        halo_by_p.append(np.unique(es[~local]).astype(np.int32))
        is_boundary_g[ed[~local]] = True
        d_slot = slot_of[ed]
        if build_ell:
            deg_local.append(np.bincount(d_slot[local], minlength=Vp))
            deg_remote.append(np.bincount(d_slot[~local], minlength=Vp))
        gkey = d_slot * P + psrc
        # group count min 1: _partition_edges gives an edgeless partition
        # a single (masked-off) group row
        ng_by_p.append(len(np.unique(gkey)) if len(gkey) else 1)
        ne_by_p.append(len(es))
    layout = _EdgeLayout.create(
        P, edge_blocks,
        tuple(_round_up(ne, pad_multiple) for ne in ne_by_p),
        tuple(_round_up(ng, pad_multiple) for ng in ng_by_p))
    out_degree = out_degree.astype(np.int32)

    exporters_by_p, fanout_by_p, export_idx_of = _export_tables(
        np.concatenate(halo_by_p) if P else np.zeros(0, np.int64),
        part, n, P)
    X = _round_up(max((len(v) for v in exporters_by_p), default=1),
                  pad_multiple)
    H = _round_up(max((len(h) for h in halo_by_p), default=1), pad_multiple)

    # staging rows are one partition's widest possible span; files carry
    # the block-ragged (B, Eb/Gb) product
    stage = {"Vp": Vp, "X": X, "H": H,
             "Ep": max(layout.ep_by_p), "Gp": max(layout.gp_by_p)}
    shape = {"Vp": (P, Vp), "X": (P, X), "H": (P, H),
             "Ep": (layout.n_blocks, layout.eb),
             "Gp": (layout.n_blocks, layout.gb)}
    e_names = [nm for nm, (ax, _, _) in _CORE_SPEC.items() if ax == "Ep"]
    g_names = [nm for nm, (ax, _, _) in _CORE_SPEC.items() if ax == "Gp"]
    with tempfile.TemporaryDirectory(dir=workdir) as scratch:
        core = _RowSpill(scratch, "core",
                         {name: ((stage[axis],), dtype, fill, shape[axis])
                          for name, (axis, dtype, fill)
                          in _CORE_SPEC.items()})
        core_arrs = core.staging()
        widths_l = widths_r = ()
        if build_ell:
            widths_l, nbp_l = _ell_plan(deg_local, Vp, pad_multiple,
                                        ell_pad_slices, ell_base_slices)
            widths_r, nbp_r = _ell_plan(deg_remote, Vp, pad_multiple,
                                        ell_pad_slices, ell_base_slices)
            blay_l = [_block_layout(tuple(nbp), layout.n_blocks)
                      for nbp in nbp_l]
            blay_r = [_block_layout(tuple(nbp), layout.n_blocks)
                      for nbp in nbp_r]
            spills_l = _ell_row_spills(scratch, "lell", P, Vp, widths_l,
                                       nbp_l, blay_l, layout)
            spills_r = _ell_row_spills(scratch, "rell", P, Vp, widths_r,
                                       nbp_r, blay_r, layout)
            arrs_l = [sp.staging() for sp in spills_l]
            arrs_r = [sp.staging() for sp in spills_r]
            bounds_l = [-1] * len(widths_l)
            bounds_r = [-1] * len(widths_r)
        del deg_local, deg_remote

        # --- fill: one shard resident at a time, spans spilled as written
        for p in range(P):
            e, w, _ = sg.shard(p, mmap=False, positions=False)
            es = np.ascontiguousarray(e[:, 0], dtype=np.int64)
            ed = np.ascontiguousarray(e[:, 1], dtype=np.int64)
            del e
            ew = (np.ones(len(es), dtype=np.float32) if w is None
                  else np.asarray(w, dtype=np.float32))
            d = _partition_edges(es, ed, ew, part[es], p, slot_of,
                                 halo_by_p[p], Vp, P)
            _fill_core_partition(core_arrs, p, d, verts_by_p[p],
                                 is_boundary_g, out_degree, slot_of,
                                 exporters_by_p[p], fanout_by_p[p],
                                 _halo_ptrs(halo_by_p[p], part,
                                            export_idx_of, X), layout)
            core.commit_row(
                {**{nm: layout.ep_by_p[p] for nm in e_names},
                 **{nm: layout.gp_by_p[p] for nm in g_names}})
            if p % layout.ppb == layout.ppb - 1:     # close out the block
                used_e = int(layout.eoff[p]) + layout.ep_by_p[p]
                used_g = int(layout.goff[p]) + layout.gp_by_p[p]
                core.pad(layout.eb - used_e, e_names)
                core.pad(layout.gb - used_g, g_names)
            if widths_l:
                contrib = _ell_fill_partition(arrs_l, widths_l, p,
                                              _ell_pick(d, negate=False),
                                              P, Vp, layout, Vp)
                bounds_l = [max(b, c) for b, c in zip(bounds_l, contrib)]
                _commit_ell_rows(spills_l, blay_l, nbp_l, layout, p)
            if widths_r:
                contrib = _ell_fill_partition(arrs_r, widths_r, p,
                                              _ell_pick(d, negate=True),
                                              P, Vp, layout, Vp + H)
                bounds_r = [max(b, c) for b, c in zip(bounds_r, contrib)]
                _commit_ell_rows(spills_r, blay_r, nbp_r, layout, p)
            del d

        # vertex-scale tables are done; free them before the jax product
        # becomes resident
        del (halo_by_p, exporters_by_p, fanout_by_p, export_idx_of,
             slot_of, verts_by_p, is_boundary_g, out_degree)
        local_ell = (_ell_take(spills_l, widths_l, bounds_l, Vp)
                     if widths_l else ())
        remote_ell = (_ell_take(spills_r, widths_r, bounds_r, Vp + H)
                      if widths_r else ())
        return _take_graph(core, local_ell, remote_ell, n_partitions=P,
                           n_vertices=int(n), n_edges=int(sg.n_edges),
                           vp=int(Vp), ep=int(layout.eb), xp=int(X),
                           hp=int(H), gp=int(layout.gb), layout=layout)


def _ell_row_spills(scratch: str, tag: str, P: int, Vp: int, widths,
                    nb_by_p, bin_layouts, layout) -> list[_RowSpill]:
    """Row spills for one ELL side: the seven arrays
    ``_ell_fill_partition`` writes (``flat_idx`` included — the fill
    derives it in staging, the commit keeps only the span).  Staging
    width is the bin's widest per-partition row count; files carry the
    bin's block-ragged ``(B, Nb)`` product."""
    B, ppb = layout.n_blocks, layout.ppb
    spills = []
    for b, ((lo, kb), nbp, (_, Nb)) in enumerate(
            zip(widths, nb_by_p, bin_layouts)):
        W = max(nbp)
        spills.append(_RowSpill(scratch, f"{tag}{b}", {
            "rows": ((W,), np.int32, ppb * Vp, (B, Nb)),
            "idx": ((W, kb), np.int32, 0, (B, Nb, kb)),
            "val": ((W, kb), np.float32, 0.0, (B, Nb, kb)),
            "msk": ((W, kb), bool, False, (B, Nb, kb)),
            "grp": ((W, kb), np.int32, 0, (B, Nb, kb)),
            "flat_rows": ((W,), np.int32, P * Vp, (B, Nb)),
            "flat_idx": ((W, kb), np.int32, 0, (B, Nb, kb)),
        }))
    return spills


def _commit_ell_rows(spills: list[_RowSpill], bin_layouts, nb_by_p,
                     layout, p: int) -> None:
    for sp, (offs, Nb), nbp in zip(spills, bin_layouts, nb_by_p):
        span = int(nbp[p])
        sp.commit_row(span)
        if p % layout.ppb == layout.ppb - 1:         # close out the block
            sp.pad(Nb - (int(offs[p]) + span))


def _ell_take(spills: list[_RowSpill], widths, bounds: list[int],
              stride: int) -> tuple[EllSlice, ...]:
    """The shared ``_ell_finalize`` over lazily mmap'd spill files — each
    array's pages only transiently resident while ``jnp.asarray``
    converts it (the precomputed ``flat_idx`` rides along so the full
    offset array is never materialized in RAM)."""
    for sp in spills:
        sp.close()
    arrs = [{name: sp.load(name)
             for name in ("rows", "idx", "val", "msk", "grp", "flat_rows",
                          "flat_idx")}
            for sp in spills]
    return _ell_finalize(arrs, widths, bounds, stride)


def _take_graph(core: _RowSpill, local_ell, remote_ell, *,
                n_partitions: int, n_vertices: int, n_edges: int, vp: int,
                ep: int, xp: int, hp: int, gp: int,
                layout) -> PartitionedGraph:
    """The shared ``_finalize_graph`` over the lazily mmap'd spilled core
    arrays: one field list to maintain, same transient-residency
    property (``take`` pops each mmap as it converts)."""
    core.close()
    arrs = {name: core.load(name) for name in _CORE_SPEC}
    return _finalize_graph(arrs, local_ell, remote_ell,
                           n_partitions=n_partitions, n_vertices=n_vertices,
                           n_edges=n_edges, vp=vp, ep=ep, xp=xp, hp=hp,
                           gp=gp, layout=layout)


def ingest_to_ghp(path: str, part, n_partitions: int | None,
                  out_path: str, wd: str, *, n_vertices: int | None = None,
                  chunk_edges: int = DEFAULT_CHUNK_EDGES,
                  positions: bool = False, partition_seed: int = 0,
                  dtype=np.int64) -> ShardedGraph:
    """The shared ingest prefix: open/stage the edge source, degree pass,
    resolve the labeling, spill to ``out_path`` — one implementation for
    ``build_partitioned_graph_from_path`` and the convert CLI.  ``wd``
    hosts staging temporaries (the caller owns its lifetime);
    ``n_vertices`` overrides/extends the inferred or stored vertex count
    (isolated tail vertices), and raises if edges exceed it."""
    source = open_edge_source(path, chunk_edges)
    if isinstance(source, TextEdgeSource):
        from repro.io.stage import stage_edges
        source = stage_edges(source, os.path.join(wd, "staged"),
                             n_vertices=n_vertices, dtype=dtype)
        source.chunk_edges = chunk_edges
    nv, ne, out_deg, in_deg = degree_pass(source)
    if n_vertices is not None:
        if nv > n_vertices:
            raise GraphFormatError(
                f"{path}: edge endpoint out of range for "
                f"n_vertices={n_vertices}")
        pad = n_vertices - nv
        out_deg, in_deg = np.pad(out_deg, (0, pad)), np.pad(in_deg,
                                                            (0, pad))
        nv = n_vertices
    labels = partition_source(source, part, nv, n_partitions,
                              partition_seed, wd, ne, out_deg + in_deg)
    return spill_to_ghp(source, labels, nv, in_deg, out_path, dtype=dtype,
                        positions=positions,
                        partitioner=(part if isinstance(part, str)
                                     else "explicit"),
                        partition_seed=partition_seed)


def build_partitioned_graph_from_path(
    path: str,
    part: str | np.ndarray | None = None,
    n_partitions: int | None = None,
    *,
    n_vertices: int | None = None,
    chunk_edges: int = DEFAULT_CHUNK_EDGES,
    workdir: str | None = None,
    ghp_out: str | None = None,
    positions: bool = False,
    partition_seed: int = 0,
    pad_multiple: int = 8,
    build_ell: bool = True,
    ell_pad_slices: int = 8,
    ell_base_slices: int = 128,
    edge_blocks: int = 1,
    dtype=np.int64,
) -> PartitionedGraph:
    """Build a ``PartitionedGraph`` from a graph on disk, out-of-core.

    ``path`` is a ``.ghp`` shard directory (already partitioned — ``part``
    must be left None), a staged-edge directory, or a text edge list
    (``.gz``-aware; staged to binary once so later passes parse nothing).
    For edge inputs ``part`` is a partitioner name (default ``'fennel'``)
    or a precomputed labeling; weights come from the file (third column /
    ``weights.bin``).  ``workdir`` hosts the temporaries (default: a
    ``TemporaryDirectory``); ``ghp_out`` additionally keeps the sharded
    graph at that path (``positions=True`` to make it round-trippable).

    ``pad_multiple`` and ``edge_blocks`` mean exactly what they mean on
    ``build_partitioned_graph``: each partition's edge/group span is
    rounded up to ``pad_multiple`` entries, and the spans are packed into
    ``edge_blocks`` block rows (1 = fully ragged, ``n_partitions`` = the
    legacy shared-width layout; a ``D``-device mesh needs a multiple of
    ``D``).

    The result is bit-identical to
    ``build_partitioned_graph(edges, n, part, weights)`` on the same edge
    list, labeling, ``pad_multiple`` and ``edge_blocks``, for every chunk
    size.
    """
    if os.path.isdir(path) and os.path.exists(os.path.join(path,
                                                           "meta.json")):
        if part is not None or n_partitions is not None:
            raise ValueError(
                f"{path} is already partitioned (.ghp) — its labeling is "
                f"fixed at convert time; to relabel, run repro.io.convert "
                f"on the original edge list (or a staged copy) with the "
                f"new partitioner")
        return build_from_sharded(load_graph(path),
                                  pad_multiple=pad_multiple,
                                  build_ell=build_ell,
                                  ell_pad_slices=ell_pad_slices,
                                  ell_base_slices=ell_base_slices,
                                  edge_blocks=edge_blocks,
                                  workdir=workdir)

    if part is None:
        part = "fennel"
    with tempfile.TemporaryDirectory(dir=workdir) as wd:
        sg = ingest_to_ghp(path, part, n_partitions,
                           ghp_out or os.path.join(wd, "graph.ghp"), wd,
                           n_vertices=n_vertices, chunk_edges=chunk_edges,
                           positions=positions,
                           partition_seed=partition_seed, dtype=dtype)
        return build_from_sharded(sg, pad_multiple=pad_multiple,
                                  build_ell=build_ell,
                                  ell_pad_slices=ell_pad_slices,
                                  ell_base_slices=ell_base_slices,
                                  edge_blocks=edge_blocks,
                                  workdir=wd)
