"""Chunked edge-list sources: bounded-memory iteration over graphs on disk.

Every reader yields ``(edges (c, 2) int64, weights (c,) float32 | None)``
blocks of at most ``chunk_edges`` edges, in file order — the unit the whole
out-of-core pipeline (degree pass, external CSR, partition spill) is built
from.  Three concrete sources share the small :class:`EdgeSource` surface:

  * :class:`TextEdgeSource`   — SNAP-style whitespace-separated edge lists
                                (``src dst`` or ``src dst weight`` per line,
                                ``#`` comments), transparently gzip-aware;
  * :class:`StagedEdgeSource` — the binary staged-edge directory written by
                                :func:`repro.io.stage.stage_edges` /
                                ``repro.data.graphs.materialize`` (mmap'd,
                                re-iterable for free);
  * :class:`ArrayEdgeSource`  — in-memory arrays chunked for tests and for
                                funnelling the in-memory API through the
                                identical code path.

Sources are re-iterable: ``chunks()`` starts a fresh pass each call (the
pipeline takes several passes — degrees, CSR fill, spill).
"""

from __future__ import annotations

import gzip
import io
import itertools
import os

import numpy as np

__all__ = ["EdgeSource", "ArrayEdgeSource", "TextEdgeSource",
           "StagedEdgeSource", "open_edge_source", "DEFAULT_CHUNK_EDGES"]

DEFAULT_CHUNK_EDGES = 1 << 20


class EdgeSource:
    """Re-iterable chunk stream over an edge list.

    ``n_vertices`` / ``n_edges`` / ``weighted`` are None when the source
    cannot know them without a full pass (text files); the pipeline's
    degree pass fills the gaps.
    """

    n_vertices: int | None = None
    n_edges: int | None = None
    weighted: bool | None = None
    chunk_edges: int = DEFAULT_CHUNK_EDGES

    def chunks(self):
        raise NotImplementedError


class ArrayEdgeSource(EdgeSource):
    """Chunk an in-memory edge array (tests; in-memory save_graph)."""

    def __init__(self, edges: np.ndarray, weights: np.ndarray | None = None,
                 n_vertices: int | None = None,
                 chunk_edges: int = DEFAULT_CHUNK_EDGES):
        self.edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
        self.weights = (None if weights is None
                        else np.asarray(weights, dtype=np.float32))
        self.n_vertices = n_vertices
        self.n_edges = len(self.edges)
        self.weighted = self.weights is not None
        self.chunk_edges = int(chunk_edges)

    def chunks(self):
        for a in range(0, len(self.edges), self.chunk_edges):
            b = min(a + self.chunk_edges, len(self.edges))
            w = None if self.weights is None else self.weights[a:b]
            yield self.edges[a:b], w


class TextEdgeSource(EdgeSource):
    """SNAP-style text edge list, gzip-aware, parsed in bounded blocks.

    Lines are ``src dst`` or ``src dst weight`` (whitespace-separated);
    ``#``-prefixed lines and blank lines are skipped.  The column count is
    sniffed from the first data line and then required of every block
    (np.loadtxt's C tokenizer does the parsing, so a pass is cheap enough
    to repeat — though the pipeline stages text to binary once instead).
    """

    def __init__(self, path: str, chunk_edges: int = DEFAULT_CHUNK_EDGES):
        self.path = path
        self.chunk_edges = int(chunk_edges)
        self.weighted = None          # sniffed on first pass

    def _open(self) -> io.TextIOBase:
        if self.path.endswith(".gz"):
            return io.TextIOWrapper(gzip.open(self.path, "rb"))
        return open(self.path, "rt")

    def chunks(self):
        with self._open() as f:
            data = (ln for ln in f
                    if ln.strip() and not ln.lstrip().startswith("#"))
            while True:
                block = list(itertools.islice(data, self.chunk_edges))
                if not block:
                    break
                ncol = len(block[0].split())
                if ncol == 2:
                    arr = np.loadtxt(block, dtype=np.int64, ndmin=2)
                    if self.weighted:
                        raise ValueError(
                            f"{self.path}: weight column disappeared "
                            f"mid-file")
                    self.weighted = False
                    yield arr, None
                elif ncol == 3:
                    arr = np.loadtxt(block, dtype=np.float64, ndmin=2)
                    if self.weighted is False:
                        raise ValueError(
                            f"{self.path}: weight column appeared mid-file")
                    self.weighted = True
                    yield (arr[:, :2].astype(np.int64),
                           arr[:, 2].astype(np.float32))
                else:
                    raise ValueError(
                        f"{self.path}: expected 2 or 3 columns, got {ncol}")


class StagedEdgeSource(EdgeSource):
    """Binary staged-edge directory (``edges.json`` + ``edges.bin`` [+
    ``weights.bin``]), written by :func:`repro.io.stage.stage_edges`.

    Chunks come through buffered sequential reads, not a persistent mmap:
    file-backed pages a pass touches through a mapping stay on the
    process's peak RSS, and bounding peak RSS is this subsystem's whole
    job.  Each file existence/size is validated against the json up
    front."""

    def __init__(self, path: str, chunk_edges: int = DEFAULT_CHUNK_EDGES):
        from repro.io.format import GraphFormatError, read_meta
        self.path = path
        meta = read_meta(os.path.join(path, "edges.json"), expect="edges")
        self.meta = meta
        self.n_vertices = int(meta["n_vertices"])
        self.n_edges = int(meta["n_edges"])
        self.weighted = bool(meta["weighted"])
        self.dtype = np.dtype(meta["dtype"])
        self.chunk_edges = int(chunk_edges)
        self._epath = os.path.join(path, "edges.bin")
        self._wpath = os.path.join(path, "weights.bin")
        want = self.n_edges * 2 * self.dtype.itemsize
        if not os.path.exists(self._epath):
            raise GraphFormatError(f"{self._epath}: missing")
        have = os.path.getsize(self._epath)
        if have != want:
            raise GraphFormatError(f"{self._epath}: {have} bytes, json "
                                   f"says {want}")
        if self.weighted and not os.path.exists(self._wpath):
            raise GraphFormatError(f"{self._wpath}: missing")

    def chunks(self):
        with open(self._epath, "rb") as fe:
            fw = open(self._wpath, "rb") if self.weighted else None
            try:
                for a in range(0, self.n_edges, self.chunk_edges):
                    c = min(self.chunk_edges, self.n_edges - a)
                    e = np.fromfile(fe, dtype=self.dtype,
                                    count=2 * c).reshape(c, 2)
                    yield (np.asarray(e, dtype=np.int64),
                           np.fromfile(fw, dtype=np.float32, count=c)
                           if fw is not None else None)
            finally:
                if fw is not None:
                    fw.close()

    def load_arrays(self):
        """The whole edge list in memory — the *in-memory* builder's entry
        point (and the A/B benchmark's baseline), not the pipeline's."""
        with open(self._epath, "rb") as f:
            edges = np.fromfile(f, dtype=self.dtype).reshape(-1, 2)
        edges = np.asarray(edges, dtype=np.int64)
        w = None
        if self.weighted:
            with open(self._wpath, "rb") as f:
                w = np.fromfile(f, dtype=np.float32)
        return edges, w


def open_edge_source(path: str,
                     chunk_edges: int = DEFAULT_CHUNK_EDGES) -> EdgeSource:
    """Resolve a path to the right chunked source: a staged-edge directory
    (``edges.json`` inside) or a text edge list (optionally ``.gz``).
    ``.ghp`` graph directories are *not* edge sources — load those with
    :func:`repro.io.load_graph`."""
    if os.path.isdir(path):
        if os.path.exists(os.path.join(path, "edges.json")):
            return StagedEdgeSource(path, chunk_edges)
        if os.path.exists(os.path.join(path, "meta.json")):
            raise ValueError(
                f"{path} looks like a sharded .ghp graph directory; use "
                f"repro.io.load_graph / build_partitioned_graph_from_path")
        raise FileNotFoundError(f"{path}: no edges.json in directory")
    if not os.path.exists(path):
        raise FileNotFoundError(path)
    return TextEdgeSource(path, chunk_edges)
