"""The ``.ghp`` sharded on-disk graph format.

A ``.ghp`` directory is a partitioned graph at rest: edges already bucketed
by *destination* partition (the axis the builder consumes), with the
labeling that produced the buckets.  Layout::

    graph.ghp/
      meta.json                  format tag, version, counts, dtype,
                                 partition provenance, per-shard ranges
      part.npy                   (V,) int32    vertex -> partition labels
      shards/part00000.edges.npy (E_p, 2) dt   in-edges of partition p,
                                               original edge-list order
      shards/part00000.w.npy     (E_p,) f32    [weighted graphs only]
      shards/part00000.pos.npy   (E_p,) int64  [optional] original edge
                                               index of each shard row

Everything is a plain ``.npy`` — ``np.load(..., mmap_mode='r')`` works on
any shard, so a build touches one partition's pages at a time.  Because a
shard keeps its edges in original edge-list order, feeding shard ``p`` to
the builder's per-partition helpers reproduces the in-memory
``build_partitioned_graph`` bit-for-bit; ``pos`` (when saved) additionally
makes the *edge list itself* reconstructible, which is what the save/load
round-trip test pins.

``meta.json`` is the integrity anchor: :func:`load_graph` validates format
tag, version, shard presence and shapes against it and raises
:class:`GraphFormatError` on any mismatch (truncated JSON, missing shard,
wrong length) rather than letting a corrupt directory produce a wrong
graph.
"""

from __future__ import annotations

import dataclasses
import json
import os

import numpy as np

__all__ = ["GraphFormatError", "ShardedGraph", "save_graph", "load_graph",
           "read_meta", "write_meta", "shard_prefix", "check_id_range",
           "GHP_VERSION"]

# version 2: shards build into the block-ragged edge layout (per-partition
# Ep_p spans) — shard bytes are unchanged, but graphs built from v1-era
# directories would not be bit-comparable to freshly converted ones, so
# loads refuse the old tag instead of failing deep in the builder
GHP_VERSION = 2


class GraphFormatError(Exception):
    """A .ghp / staged-edge directory failed validation."""


def check_id_range(ids: np.ndarray, dtype: np.dtype, where: str) -> None:
    """Refuse to narrow vertex ids that the target dtype cannot hold —
    a wrapped id is either an opaque bincount crash three stages later or,
    worse, a silently wrong graph."""
    if not len(ids):
        return
    lo, hi = int(ids.min()), int(ids.max())
    if lo < 0:
        # a negative id "fits" any signed dtype but wraps every part[]/
        # slot_of[] lookup downstream into a structurally-valid wrong graph
        raise GraphFormatError(f"{where}: negative vertex id {lo}")
    if hi > np.iinfo(dtype).max:
        raise GraphFormatError(
            f"{where}: vertex id range [{lo}, {hi}] does not fit "
            f"{np.dtype(dtype).name}")


def shard_prefix(p: int) -> str:
    return os.path.join("shards", f"part{p:05d}")


def write_meta(path: str, meta: dict) -> None:
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(meta, f, indent=1)
    os.replace(tmp, path)


def read_meta(path: str, expect: str) -> dict:
    """Load + validate a meta json (``expect`` is the format tag:
    'ghp' or 'edges')."""
    if not os.path.exists(path):
        raise GraphFormatError(f"{path}: missing")
    try:
        with open(path) as f:
            meta = json.load(f)
    except (json.JSONDecodeError, UnicodeDecodeError) as e:
        raise GraphFormatError(f"{path}: corrupt or truncated json "
                               f"({e})") from None
    if not isinstance(meta, dict) or meta.get("format") != expect:
        raise GraphFormatError(
            f"{path}: format tag {meta.get('format') if isinstance(meta, dict) else meta!r} "
            f"!= {expect!r}")
    version = meta.get("version")
    if version != GHP_VERSION:
        raise GraphFormatError(f"{path}: unsupported version {version!r} "
                               f"(have {GHP_VERSION})")
    required = {"ghp": ("n_vertices", "n_edges", "dtype", "weighted",
                        "n_partitions", "shards"),
                "edges": ("n_vertices", "n_edges", "dtype", "weighted")}
    missing = [k for k in required[expect] if k not in meta]
    if missing:
        raise GraphFormatError(f"{path}: missing keys {missing}")
    return meta


@dataclasses.dataclass
class ShardedGraph:
    """Handle over a validated ``.ghp`` directory: metadata + the labeling
    in memory, per-partition edge shards loaded (mmap'd) on demand."""

    path: str
    meta: dict
    part: np.ndarray                  # (V,) int32

    @property
    def n_vertices(self) -> int:
        return int(self.meta["n_vertices"])

    @property
    def n_edges(self) -> int:
        return int(self.meta["n_edges"])

    @property
    def n_partitions(self) -> int:
        return int(self.meta["n_partitions"])

    @property
    def weighted(self) -> bool:
        return bool(self.meta["weighted"])

    @property
    def dtype(self) -> np.dtype:
        return np.dtype(self.meta["dtype"])

    def _load(self, rel: str, shape: tuple, dtype, mmap: bool):
        full = os.path.join(self.path, rel)
        if not os.path.exists(full):
            raise GraphFormatError(f"{full}: shard file missing")
        arr = np.load(full, mmap_mode="r" if mmap else None)
        if arr.shape != shape or arr.dtype != np.dtype(dtype):
            raise GraphFormatError(
                f"{full}: have {arr.dtype}{arr.shape}, meta says "
                f"{np.dtype(dtype)}{shape}")
        return arr

    def shard(self, p: int, mmap: bool = True, weights: bool = True,
              positions: bool = True):
        """Partition p's in-edges as ``(edges (E_p, 2), weights | None,
        positions | None)``, in original edge-list order.  ``weights`` /
        ``positions`` skip those columns entirely (None) — callers that
        only need edges shouldn't page in the rest."""
        rec = self.meta["shards"][p]
        ne = int(rec["n_edges"])
        prefix = rec["prefix"]
        edges = self._load(prefix + ".edges.npy", (ne, 2), self.dtype, mmap)
        w = (self._load(prefix + ".w.npy", (ne,), np.float32, mmap)
             if self.weighted and weights else None)
        pos = (self._load(prefix + ".pos.npy", (ne,), np.int64, mmap)
               if self.meta.get("has_positions") and positions else None)
        return edges, w, pos

    def edges(self):
        """Reassemble the full edge list (and weights): in original order
        when positions were saved, else shard-major.  O(E) memory — a
        convenience for tests and small graphs, not the build path."""
        out = np.empty((self.n_edges, 2), dtype=self.dtype)
        w_out = (np.empty(self.n_edges, dtype=np.float32)
                 if self.weighted else None)
        cur = 0
        for p in range(self.n_partitions):
            e, w, pos = self.shard(p)
            if pos is not None:
                out[pos] = e
                if w_out is not None:
                    w_out[pos] = w
            else:
                out[cur:cur + len(e)] = e
                if w_out is not None:
                    w_out[cur:cur + len(e)] = w
            cur += len(e)
        return out, w_out


def load_graph(path: str) -> ShardedGraph:
    """Open + validate a ``.ghp`` directory.

    Validation is structural and cheap — shard payloads are *not* read:
    ``meta.json`` must parse with the expected magic/version, ``part.npy``
    must be an int32 labeling of exactly ``n_vertices`` entries, the shard
    records must match ``n_partitions``, and the per-shard edge counts
    must sum to ``n_edges``.

    Args:
        path: the ``.ghp`` directory (as written by ``ShardWriter`` /
            ``repro.io.convert``).

    Returns:
        A ``ShardedGraph`` handle: parsed ``meta``, the in-memory
        partition labeling, and the path — shard arrays are loaded lazily
        by consumers (mmap-friendly ``.npy``).

    Raises:
        GraphFormatError: missing or malformed ``meta.json`` /
            ``part.npy``, or shard records inconsistent with the metadata.
    """
    meta = read_meta(os.path.join(path, "meta.json"), expect="ghp")
    n = int(meta["n_vertices"])
    part_path = os.path.join(path, "part.npy")
    if not os.path.exists(part_path):
        raise GraphFormatError(f"{part_path}: missing")
    part = np.load(part_path)
    if part.shape != (n,) or part.dtype != np.int32:
        raise GraphFormatError(f"{part_path}: have {part.dtype}{part.shape},"
                               f" meta says int32({n},)")
    shards = meta["shards"]
    if len(shards) != int(meta["n_partitions"]):
        raise GraphFormatError(
            f"{path}: {len(shards)} shard records for "
            f"{meta['n_partitions']} partitions")
    total = sum(int(s["n_edges"]) for s in shards)
    if total != int(meta["n_edges"]):
        raise GraphFormatError(f"{path}: shard ranges sum to {total}, meta "
                               f"says n_edges={meta['n_edges']}")
    return ShardedGraph(path=path, meta=meta, part=np.asarray(part))


def _create_npy(path: str, dtype, shape: tuple):
    """Open a ``.npy`` for sequential append: header written up front (the
    shard sizes are known from the degree pass), raw data streamed after.
    Buffered file writes instead of ``open_memmap`` keep the spilled bytes
    out of the writer's resident set — dirty mapped pages of every shard
    would otherwise pile onto peak RSS, which is the resource this whole
    pipeline exists to bound."""
    from numpy.lib import format as npy_format
    f = open(path, "wb")
    npy_format.write_array_header_1_0(
        f, {"descr": npy_format.dtype_to_descr(np.dtype(dtype)),
            "fortran_order": False, "shape": tuple(shape)})
    return f


class ShardWriter:
    """Incremental ``.ghp`` writer: shard sizes are known up front (the
    degree pass supplies them), so every shard is a pre-headered ``.npy``
    appended through buffered file handles — bounded memory however large
    the graph.  Handles are opened per append, not held: 3 files per
    shard times a large ``--n-partitions`` would otherwise blow the
    file-descriptor limit."""

    def __init__(self, path: str, n_vertices: int, part: np.ndarray,
                 shard_sizes: np.ndarray, dtype=np.int64,
                 weighted: bool = False, positions: bool = True,
                 partitioner: str = "explicit", partition_seed=None):
        self.path = path
        self.P = len(shard_sizes)
        self.dtype = np.dtype(dtype)
        self.weighted = weighted
        self.positions = positions
        self.sizes = np.asarray(shard_sizes, dtype=np.int64)
        os.makedirs(os.path.join(path, "shards"), exist_ok=True)
        np.save(os.path.join(path, "part.npy"),
                np.asarray(part, dtype=np.int32))
        self._cur = np.zeros(self.P, dtype=np.int64)
        self._gpos = 0
        for p in range(self.P):
            prefix = os.path.join(path, shard_prefix(p))
            ne = int(self.sizes[p])
            _create_npy(prefix + ".edges.npy", self.dtype, (ne, 2)).close()
            if weighted:
                _create_npy(prefix + ".w.npy", np.float32, (ne,)).close()
            if positions:
                _create_npy(prefix + ".pos.npy", np.int64, (ne,)).close()
        self.meta = {
            "format": "ghp", "version": GHP_VERSION,
            "n_vertices": int(n_vertices), "n_edges": int(self.sizes.sum()),
            "dtype": self.dtype.name, "weighted": bool(weighted),
            "has_positions": bool(positions),
            "n_partitions": self.P,
            "partitioner": partitioner,
            "partition_seed": partition_seed,
            "shards": [{"partition": p, "n_edges": int(self.sizes[p]),
                        "prefix": shard_prefix(p).replace(os.sep, "/")}
                       for p in range(self.P)],
        }

    def _append_to(self, p: int, suffix: str, data: bytes) -> None:
        with open(os.path.join(self.path, shard_prefix(p)) + suffix,
                  "ab") as f:
            f.write(data)

    def append(self, edges: np.ndarray, weights: np.ndarray | None,
               part: np.ndarray,
               positions: np.ndarray | None = None) -> None:
        """Spill one chunk: bucket rows by destination partition, keeping
        original relative order (stable sort by bucket).  ``positions``
        overrides the derived original-edge-index column — for writers
        (like ``repro.io.resize``) whose stream is *not* the original edge
        list order but who know each row's original index."""
        pd = part[edges[:, 1]]
        order = np.argsort(pd, kind="stable")
        pd_s = pd[order]
        e_s = edges[order]
        w_s = None if weights is None else weights[order]
        if positions is not None:
            if not self.positions:
                raise GraphFormatError(
                    f"{self.path}: explicit positions passed to a writer "
                    f"created with positions=False")
            pos_s = np.asarray(positions, dtype=np.int64)[order]
        else:
            pos_s = (np.arange(self._gpos, self._gpos + len(edges),
                               dtype=np.int64)[order]
                     if self.positions else None)
        check_id_range(e_s, self.dtype, self.path)
        bounds = np.searchsorted(pd_s, np.arange(self.P + 1))
        for p in np.unique(pd_s):
            a, b = bounds[p], bounds[p + 1]
            self._append_to(p, ".edges.npy", np.ascontiguousarray(
                e_s[a:b], dtype=self.dtype).tobytes())
            if w_s is not None:
                self._append_to(p, ".w.npy", np.ascontiguousarray(
                    w_s[a:b], np.float32).tobytes())
            if pos_s is not None:
                self._append_to(p, ".pos.npy", pos_s[a:b].tobytes())
            self._cur[p] += b - a
        self._gpos += len(edges)

    def close(self) -> ShardedGraph:
        if not np.array_equal(self._cur, self.sizes):
            raise GraphFormatError(
                f"{self.path}: spill wrote {self._cur.tolist()} edges per "
                f"shard, expected {self.sizes.tolist()} — degree pass and "
                f"edge stream disagree")
        write_meta(os.path.join(self.path, "meta.json"), self.meta)
        return load_graph(self.path)


def save_graph(path: str, edges: np.ndarray, n_vertices: int,
               part: np.ndarray, weights: np.ndarray | None = None,
               dtype=None, positions: bool = True,
               partitioner: str = "explicit",
               partition_seed=None) -> ShardedGraph:
    """Shard an in-memory edge list to a ``.ghp`` directory (the one-shot
    counterpart of the streaming spill; same bytes on disk)."""
    edges = np.asarray(edges)
    if dtype is None:
        dtype = edges.dtype if edges.dtype in (np.int32, np.int64) \
            else np.int64
    part = np.asarray(part, dtype=np.int32)
    P = int(part.max()) + 1 if part.size else 1
    sizes = np.bincount(part[edges[:, 1]], minlength=P) if len(edges) \
        else np.zeros(P, dtype=np.int64)
    wr = ShardWriter(path, n_vertices, part, sizes, dtype=dtype,
                     weighted=weights is not None, positions=positions,
                     partitioner=partitioner, partition_seed=partition_seed)
    wr.append(np.asarray(edges, dtype=np.int64).reshape(-1, 2),
              None if weights is None else np.asarray(weights, np.float32),
              part)
    return wr.close()
