"""Graph I/O subsystem: chunked readers, the sharded ``.ghp`` on-disk
format, and the out-of-core partition/build pipeline.

The in-memory path (``repro.data.graphs`` -> ``build_partitioned_graph``)
caps the platform at one host's RAM; this package is the disk-backed
on-ramp for everything bigger:

  * :mod:`repro.io.readers` — bounded ``(chunk, 2)`` int64 blocks from
    SNAP-style text (gzip-aware), staged binary, or in-memory arrays;
  * :mod:`repro.io.stage`   — binary staging + ``materialize`` for
    putting synthetic graphs on disk;
  * :mod:`repro.io.format`  — the ``.ghp`` sharded format:
    ``meta.json`` + per-partition mmap-loadable ``.npy`` edge shards,
    ``save_graph``/``load_graph`` round-trip, validated errors;
  * :mod:`repro.io.pipeline` — streaming degree pass, external-CSR
    fennel, destination-partition spill, and the out-of-core builder
    behind :func:`build_partitioned_graph_from_path` (bit-identical to
    the in-memory builder, peak memory O(chunk + largest partition));
  * ``python -m repro.io.convert`` — edge list -> ``.ghp`` CLI.
"""

from repro.io.digest import graph_digest
from repro.io.format import (GraphFormatError, ShardedGraph, load_graph,
                             save_graph)
from repro.io.pipeline import (build_from_sharded,
                               build_partitioned_graph_from_path,
                               degree_pass, external_undirected_csr,
                               spill_to_ghp)
from repro.io.readers import (ArrayEdgeSource, EdgeSource, StagedEdgeSource,
                              TextEdgeSource, open_edge_source)
from repro.io.stage import materialize, stage_arrays, stage_edges

__all__ = [
    "GraphFormatError", "ShardedGraph", "save_graph", "load_graph",
    "graph_digest",
    "build_from_sharded", "build_partitioned_graph_from_path",
    "degree_pass", "external_undirected_csr", "spill_to_ghp",
    "EdgeSource", "ArrayEdgeSource", "TextEdgeSource", "StagedEdgeSource",
    "open_edge_source",
    "materialize", "stage_arrays", "stage_edges",
]
