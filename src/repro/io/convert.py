"""Edge list -> sharded ``.ghp`` graph directory, out-of-core.

    python -m repro.io.convert INPUT OUT.ghp --partitioner fennel \
        --n-partitions 8 [--seed 0] [--chunk-edges N] [--n-vertices N] \
        [--dtype int64|int32] [--no-positions] [--workdir DIR]

INPUT is a SNAP-style text edge list (``src dst [weight]`` per line, ``#``
comments, ``.gz``-aware) or a staged-edge directory.  The conversion runs
the same streaming prefix as ``build_partitioned_graph_from_path``
(:func:`repro.io.pipeline.ingest_to_ghp`: degree pass, labeling,
destination-partition spill) in chunk-bounded memory, so a 10^9-edge file
needs no more RAM than its largest chunk plus the vertex tables.
Positions are stored by default so the original edge order is
reconstructible (``ShardedGraph.edges()``); drop them with
``--no-positions`` to save 8 bytes/edge.
"""

from __future__ import annotations

import argparse
import os
import sys
import tempfile
import time

import numpy as np


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.io.convert",
        description="convert an edge list to a sharded .ghp graph "
                    "directory (streaming, chunk-bounded memory)")
    ap.add_argument("input", help="text edge list (.gz ok) or staged dir")
    ap.add_argument("output", help="output .ghp directory")
    ap.add_argument("--partitioner", default="fennel",
                    help="partitioner name (repro.partition.PARTITIONERS) "
                         "[fennel]")
    ap.add_argument("--n-partitions", "-k", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--chunk-edges", type=int, default=1 << 20)
    ap.add_argument("--n-vertices", type=int, default=None,
                    help="vertex count (default: inferred as max id + 1 / "
                         "the staged metadata; larger values add isolated "
                         "tail vertices)")
    ap.add_argument("--dtype", default="int64", choices=("int64", "int32"),
                    help="on-disk edge id dtype [int64]")
    ap.add_argument("--no-positions", action="store_true",
                    help="skip the per-shard original-index arrays")
    ap.add_argument("--workdir", default=None,
                    help="where the staging temporaries live "
                         "(default: a TemporaryDirectory)")
    args = ap.parse_args(argv)

    from repro.io.pipeline import ingest_to_ghp

    t0 = time.perf_counter()
    with tempfile.TemporaryDirectory(dir=args.workdir) as wd:
        sg = ingest_to_ghp(args.input, args.partitioner, args.n_partitions,
                           args.output, wd, n_vertices=args.n_vertices,
                           chunk_edges=args.chunk_edges,
                           positions=not args.no_positions,
                           partition_seed=args.seed,
                           dtype=np.dtype(args.dtype))
        # streaming edge-cut fraction (one cheap pass over the shards)
        cut = sum(int((sg.part[np.asarray(
            sg.shard(p, weights=False, positions=False)[0][:, 0])]
            != p).sum()) for p in range(sg.n_partitions))
    sizes = [s["n_edges"] for s in sg.meta["shards"]]
    print(f"wrote {args.output}: V={sg.n_vertices} E={sg.n_edges}, "
          f"{sg.n_partitions} shards [{args.partitioner}] "
          f"(in-edges per shard: {sizes}), "
          f"edge-cut {cut}/{sg.n_edges} ({cut / max(sg.n_edges, 1):.3f}), "
          f"{time.perf_counter() - t0:.1f}s total")
    return 0


if __name__ == "__main__":
    sys.path.insert(0, os.path.join(os.path.dirname(
        os.path.abspath(__file__)), "..", ".."))
    sys.exit(main())
