"""Canonical content digest of a ``PartitionedGraph`` pytree.

The in-memory and out-of-core builders promise *bit-identical* structures;
a digest makes that claim checkable across process boundaries — the ingest
benchmark builds each graph in its own subprocess (for honest peak-RSS
accounting) and compares digests instead of shipping gigabytes of arrays
between them.
"""

from __future__ import annotations

import dataclasses
import hashlib

import numpy as np

__all__ = ["graph_digest"]


def _update(h, value) -> None:
    if isinstance(value, tuple):
        h.update(str(len(value)).encode())
        for v in value:
            if dataclasses.is_dataclass(v):
                for f in dataclasses.fields(v):
                    _update(h, getattr(v, f.name))
            else:                      # static int tuples (ep_by_p/gp_by_p)
                _update(h, v)
    elif isinstance(value, (int, bool)):
        h.update(str(value).encode())
    else:
        arr = np.asarray(value)
        h.update(str(arr.dtype).encode())
        h.update(str(arr.shape).encode())
        h.update(arr.tobytes())


def graph_digest(graph) -> str:
    """SHA-256 over every field of the graph (dataclass field order:
    arrays as dtype+shape+bytes, ELL slice tuples recursively, static
    ints verbatim)."""
    h = hashlib.sha256()
    for f in dataclasses.fields(graph):
        _update(h, getattr(graph, f.name))
    return h.hexdigest()
