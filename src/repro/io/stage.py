"""Staging: edge lists as flat binary, ready for repeated cheap passes.

A staged-edge directory is the pre-partition counterpart of ``.ghp``::

    staged/
      edges.json    {"format": "edges", "version": 1, n_vertices, n_edges,
                     dtype, weighted}
      edges.bin     (E, 2) row-major, dtype from the json
      weights.bin   (E,) float32            [weighted only]

Raw ``.bin`` (not ``.npy``) because the writer appends chunks without
knowing the final count up front — text sources reveal their length only
as they are parsed; shape lives in ``edges.json`` and readers
``np.memmap`` against it.

:func:`stage_edges` converts any :class:`~repro.io.readers.EdgeSource`
(one parse of a text file, at most chunk-sized memory);
:func:`materialize` generates one of ``repro.data.graphs``'s synthetic
families straight into a staged directory, which is how benchmarks put a
10^7-edge R-MAT on disk without every consumer re-synthesizing it.
"""

from __future__ import annotations

import os

import numpy as np

from repro.io.format import (GHP_VERSION, GraphFormatError,
                             check_id_range, write_meta)
from repro.io.readers import EdgeSource, StagedEdgeSource

__all__ = ["stage_edges", "stage_arrays", "materialize"]


def stage_arrays(path: str, edges: np.ndarray,
                 weights: np.ndarray | None = None,
                 n_vertices: int | None = None,
                 dtype=None) -> StagedEdgeSource:
    """Write in-memory arrays as a staged-edge directory."""
    edges = np.asarray(edges)
    if dtype is None:
        dtype = edges.dtype if edges.dtype in (np.int32, np.int64) \
            else np.int64
    dtype = np.dtype(dtype)
    if n_vertices is None:
        n_vertices = int(edges.max()) + 1 if len(edges) else 0
    check_id_range(edges, dtype, path)
    os.makedirs(path, exist_ok=True)
    with open(os.path.join(path, "edges.bin"), "wb") as f:
        f.write(np.ascontiguousarray(edges, dtype=dtype).tobytes())
    if weights is not None:
        with open(os.path.join(path, "weights.bin"), "wb") as f:
            f.write(np.ascontiguousarray(weights, np.float32).tobytes())
    write_meta(os.path.join(path, "edges.json"), {
        "format": "edges", "version": GHP_VERSION,
        "n_vertices": int(n_vertices), "n_edges": int(len(edges)),
        "dtype": dtype.name, "weighted": weights is not None,
    })
    return StagedEdgeSource(path)


def stage_edges(source: EdgeSource, path: str,
                n_vertices: int | None = None,
                dtype=np.int64) -> StagedEdgeSource:
    """Stream any edge source into a staged-edge directory (one pass,
    chunk-bounded memory)."""
    dtype = np.dtype(dtype)
    os.makedirs(path, exist_ok=True)
    n_edges = 0
    max_id = -1
    weighted = None
    with open(os.path.join(path, "edges.bin"), "wb") as fe:
        fw = None
        try:
            for edges, w in source.chunks():
                if weighted is None:
                    weighted = w is not None
                    if weighted:
                        fw = open(os.path.join(path, "weights.bin"), "wb")
                elif weighted != (w is not None):
                    raise GraphFormatError(
                        f"{path}: weight column changed mid-stream")
                check_id_range(edges, dtype, path)
                fe.write(np.ascontiguousarray(edges, dtype=dtype).tobytes())
                if weighted:
                    fw.write(np.ascontiguousarray(w, np.float32).tobytes())
                n_edges += len(edges)
                if len(edges):
                    max_id = max(max_id, int(edges.max()))
        finally:
            if fw is not None:
                fw.close()
    if n_vertices is None:
        n_vertices = (source.n_vertices if source.n_vertices is not None
                      else max_id + 1)
    write_meta(os.path.join(path, "edges.json"), {
        "format": "edges", "version": GHP_VERSION,
        "n_vertices": int(n_vertices), "n_edges": int(n_edges),
        "dtype": dtype.name, "weighted": bool(weighted),
    })
    return StagedEdgeSource(path)


def materialize(path: str, kind: str, **params) -> StagedEdgeSource:
    """Generate a synthetic graph family on disk.

    ``kind`` picks the ``repro.data.graphs`` generator ('rmat' | 'grid' |
    'geometric' | 'bipartite' | 'path' | 'cycle'); ``params`` pass through
    (plus ``symmetrize=True`` to mirror the edge set).  The generator
    itself runs in memory — it is the *consumers* that stay out-of-core —
    so staging is exactly one array write.
    """
    from repro.data import graphs as G

    sym = params.pop("symmetrize", False)
    weights = None
    if kind == "rmat":
        edges, n = G.rmat_graph(**params)
    elif kind == "grid":
        edges, weights, n = G.grid_graph(**params)
    elif kind == "geometric":
        edges, n = G.geometric_graph(**params)
    elif kind == "bipartite":
        edges, _, n = G.bipartite_graph(**params)
    elif kind == "path":
        edges, n = G.path_graph(**params)
    elif kind == "cycle":
        edges, n = G.cycle_graph(**params)
    else:
        raise ValueError(f"unknown graph kind {kind!r}")
    if sym:
        if weights is not None:
            raise ValueError("symmetrize=True only applies to unweighted "
                             "kinds")
        edges = G.symmetrize(edges)
    return stage_arrays(path, edges, weights=weights, n_vertices=n)
