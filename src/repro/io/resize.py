"""Elastic resize of a ``.ghp`` directory: re-spill from k to k' partitions
without a rebuild from edge lists.

``python -m repro.io.resize src.ghp dst.ghp -k 12`` re-labels the stored
vertex assignment with :func:`repro.ft.elastic.resize_labels` (shrink merges
contiguous partitions, grow splits each partition's vertex run among its
children) and re-buckets the edge shards out-of-core: each new shard gathers
its rows from the parent shards that contribute to it, one new shard
resident at a time — the full edge list never materializes.

When the source carries ``pos`` columns (``positions=True`` at convert
time), each new shard is re-sorted into original edge-list order, so
building the resized directory is **bit-identical** to sharding the original
edge list under the new labeling directly — same ``graph_digest``, which is
what lets a re-sharded checkpoint be re-keyed trustworthily.

``--checkpoint ckpts/ --checkpoint-out ckpts-k12/`` additionally re-shards
the newest engine checkpoint onto the new partitioning
(:func:`repro.ft.driver.reshard_checkpoint_arrays`: vertex state remapped by
global id, halo dropped — the next exchange refills it — per-partition
counters reset) and re-keys it to the *new* graph's digest, which the tool
computes by actually building the resized graph; the written manifest is
marked ``elastic`` so the driver's restore path knows to apply the monotone
re-announce instead of a strict bit-exact restore.
"""

from __future__ import annotations

import argparse
import os

import numpy as np

from repro.io.format import (GraphFormatError, ShardWriter, ShardedGraph,
                             load_graph)

__all__ = ["resize_ghp", "resize_checkpoint", "main"]


def resize_ghp(src: str, dst: str, new_partitions: int) -> ShardedGraph:
    """Re-spill ``src`` (a ``.ghp`` directory) to ``dst`` under
    ``new_partitions`` partitions.  Out-of-core: peak memory is one new
    shard (plus the vertex-scale labelings)."""
    sg = load_graph(src)
    old_part = sg.part
    from repro.ft.elastic import resize_labels
    kp = int(new_partitions)
    new_part = resize_labels(old_part, kp)
    if len(np.unique(new_part)) != kp:
        raise GraphFormatError(
            f"{src}: cannot split {sg.n_partitions} partitions of "
            f"{sg.n_vertices} vertices into {kp} non-empty partitions")
    has_pos = bool(sg.meta.get("has_positions"))

    # pass 1: new shard sizes + which old shards feed which new ones.
    # An edge lives in the shard of its *destination*, so old shard p
    # contributes to new shard q iff some vertex moved p -> q.
    sizes = np.zeros(kp, dtype=np.int64)
    for p in range(sg.n_partitions):
        e, _, _ = sg.shard(p, mmap=True, weights=False, positions=False)
        if len(e):
            sizes += np.bincount(new_part[np.asarray(e[:, 1])],
                                 minlength=kp)
    pairs = np.unique(np.stack([old_part, new_part], axis=1), axis=0)
    parents = [pairs[pairs[:, 1] == q, 0] for q in range(kp)]

    wr = ShardWriter(dst, sg.n_vertices, new_part, sizes, dtype=sg.dtype,
                     weighted=sg.weighted, positions=has_pos,
                     partitioner=f"resize[{sg.meta.get('partitioner')}]",
                     partition_seed=sg.meta.get("partition_seed"))
    # pass 2: fill, one new shard at a time.  With positions, rows re-sort
    # into original edge-list order — a merge interleaves parents exactly
    # as a direct re-shard of the original edge list would.
    for q in range(kp):
        ce, cw, cp = [], [], []
        for p in parents[q]:
            e, w, pos = sg.shard(int(p), mmap=True)
            sel = new_part[np.asarray(e[:, 1])] == q
            ce.append(np.asarray(e[sel], dtype=np.int64))
            if w is not None:
                cw.append(np.asarray(w[sel], dtype=np.float32))
            if pos is not None:
                cp.append(np.asarray(pos[sel]))
        if not ce:
            continue
        e_all = np.concatenate(ce, axis=0)
        w_all = np.concatenate(cw) if cw else None
        pos_all = np.concatenate(cp) if cp else None
        if pos_all is not None and len(parents[q]) > 1:
            order = np.argsort(pos_all, kind="stable")
            e_all, pos_all = e_all[order], pos_all[order]
            if w_all is not None:
                w_all = w_all[order]
        wr.append(e_all, w_all, new_part, positions=pos_all)
    return wr.close()


def resize_checkpoint(ckpt: str, out_base: str, old_part: np.ndarray,
                      new_part: np.ndarray, new_digest: str,
                      pad_multiple: int = 8) -> str:
    """Re-shard one engine checkpoint (a ``step_*`` directory, or a base
    directory whose newest complete checkpoint is taken) onto
    ``new_part`` and re-key it to ``new_digest``.  Returns the written
    checkpoint path.  The manifest is marked ``elastic``: restoring it is
    only exact-to-the-fixed-point for monotone programs, which
    ``repro.ft.driver.elastic_restore`` enforces."""
    from repro.checkpoint.ckpt import (CheckpointError, latest_checkpoint,
                                       load_checkpoint_arrays,
                                       save_checkpoint)
    from repro.ft.driver import reshard_checkpoint_arrays

    if not os.path.exists(os.path.join(ckpt, "manifest.json")):
        found = latest_checkpoint(ckpt)
        if found is None:
            raise CheckpointError(f"{ckpt}: no complete checkpoint found")
        ckpt = found
    arrs, manifest = load_checkpoint_arrays(ckpt)
    meta = dict(manifest.get("meta") or {})
    if meta.get("elastic"):
        raise CheckpointError(f"{ckpt}: already elastic-resharded once; "
                              f"reshard from the original checkpoint")
    new_arrs = reshard_checkpoint_arrays(arrs, old_part, new_part,
                                         pad_multiple=pad_multiple)
    step = int(manifest["step"])
    meta.update(elastic=True, elastic_from=meta.get("graph_digest"),
                graph_digest=new_digest,
                n_partitions=int(np.asarray(new_part).max()) + 1,
                pad_multiple=int(pad_multiple))
    out = os.path.join(out_base, f"step_{step:08d}")
    save_checkpoint(out, new_arrs, step, extra_meta=meta)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.io.resize",
        description="re-spill a .ghp directory from k to k' partitions "
                    "(and optionally re-shard + re-key a checkpoint)")
    ap.add_argument("src", help="source .ghp directory")
    ap.add_argument("dst", help="destination .ghp directory")
    ap.add_argument("-k", "--new-partitions", type=int, required=True)
    ap.add_argument("--checkpoint", default=None,
                    help="checkpoint to re-shard: a step_* directory or a "
                         "base directory (newest complete step taken)")
    ap.add_argument("--checkpoint-out", default=None,
                    help="base directory for the re-sharded checkpoint "
                         "(required with --checkpoint)")
    ap.add_argument("--pad-multiple", type=int, default=8,
                    help="vertex padding of the engine build the "
                         "checkpoint targets (default 8)")
    ap.add_argument("--edge-blocks", type=int, default=1,
                    help="edge layout of the digest-computing build "
                         "(default 1)")
    args = ap.parse_args(argv)

    sg_new = resize_ghp(args.src, args.dst, args.new_partitions)
    print(f"resized {args.src} ({load_graph(args.src).n_partitions} parts) "
          f"-> {args.dst} ({sg_new.n_partitions} parts, "
          f"{sg_new.n_edges} edges)")

    if args.checkpoint is not None:
        if args.checkpoint_out is None:
            ap.error("--checkpoint needs --checkpoint-out")
        from repro.io.digest import graph_digest
        from repro.io.pipeline import build_from_sharded
        graph = build_from_sharded(sg_new, pad_multiple=args.pad_multiple,
                                   edge_blocks=args.edge_blocks)
        digest = graph_digest(graph)
        out = resize_checkpoint(args.checkpoint, args.checkpoint_out,
                                load_graph(args.src).part, sg_new.part,
                                digest, pad_multiple=args.pad_multiple)
        print(f"resharded checkpoint -> {out} (graph_digest {digest[:12]}…)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
