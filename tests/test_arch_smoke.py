"""Per-architecture smoke tests: instantiate the REDUCED config of each
assigned architecture family, run one forward (train) step and a
prefill+decode step on CPU, assert output shapes and finiteness."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.registry import count_params, get_model

ARCHS = [
    "phi4-mini-3.8b", "phi3-medium-14b", "gemma2-9b", "gemma3-4b",
    "whisper-small", "internvl2-2b", "mamba2-370m", "jamba-1.5-large-398b",
    "granite-moe-1b-a400m", "deepseek-v2-lite-16b",
]

B, S = 2, 32


def make_batch(cfg, rng):
    batch = {"tokens": jnp.asarray(
        rng.randint(0, cfg.vocab, size=(B, S)).astype(np.int32))}
    if cfg.family == "audio":
        batch["audio_embed"] = jnp.asarray(
            rng.randn(B, cfg.enc_frames, cfg.d_model).astype(np.float32))
    if cfg.family == "vlm":
        batch["vis_embed"] = jnp.asarray(
            rng.randn(B, cfg.vis_tokens, cfg.vis_dim).astype(np.float32))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch, smoke=True)
    api = get_model(cfg)
    rng = np.random.RandomState(0)
    params = api.init(jax.random.PRNGKey(0), cfg, jnp.float32)
    batch = make_batch(cfg, rng)
    logits = jax.jit(lambda p, b: api.forward(p, b, cfg))(params, batch)
    exp_s = S + (cfg.vis_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (B, exp_s, cfg.vocab), logits.shape
    assert bool(jnp.all(jnp.isfinite(logits))), f"NaN/inf in {arch} logits"


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    """One full train step (fwd + bwd + sgd) — gradients finite, loss drops
    or at least exists."""
    cfg = get_config(arch, smoke=True)
    api = get_model(cfg)
    rng = np.random.RandomState(1)
    params = api.init(jax.random.PRNGKey(1), cfg, jnp.float32)
    batch = make_batch(cfg, rng)
    labels = jnp.asarray(rng.randint(0, cfg.vocab, size=(B, S)).astype(np.int32))

    def loss_fn(p):
        logits = api.forward(p, batch, cfg)
        logits = logits[:, -S:]  # text positions (vlm prepends patches)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)
        return jnp.mean(nll)

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert bool(jnp.isfinite(loss)), f"{arch} loss not finite"
    flat = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in flat), \
        f"{arch} has non-finite grads"
    # rough sanity: loss near log(vocab) for random init
    assert float(loss) < np.log(cfg.vocab) * 3


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode(arch):
    cfg = get_config(arch, smoke=True)
    api = get_model(cfg)
    if api.decode_step is None:
        pytest.skip("no decode path")
    rng = np.random.RandomState(2)
    params = api.init(jax.random.PRNGKey(2), cfg, jnp.float32)
    batch = make_batch(cfg, rng)
    max_len = S + 8
    cache = api.init_cache(cfg, B, max_len, jnp.float32)
    logits, cache = jax.jit(lambda p, b, c: api.prefill(p, b, c, cfg))(
        params, batch, cache)
    assert logits.shape == (B, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))

    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    step = jax.jit(lambda p, t, c, n: api.decode_step(p, t, c, n, cfg))
    base = S + (cfg.vis_tokens if cfg.family == "vlm" else 0)
    for i in range(3):
        logits, cache = step(params, tok, cache, base + i)
        assert logits.shape == (B, 1, cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(logits))), f"{arch} decode {i}"
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)


def test_decode_matches_forward_on_dense_arch():
    """Teacher-forced decode logits == full forward logits (phi4 smoke)."""
    cfg = get_config("phi4-mini-3.8b", smoke=True)
    api = get_model(cfg)
    rng = np.random.RandomState(3)
    params = api.init(jax.random.PRNGKey(3), cfg, jnp.float32)
    tokens = jnp.asarray(rng.randint(0, cfg.vocab, size=(1, 8)).astype(np.int32))
    full = api.forward(params, {"tokens": tokens}, cfg, remat=False)

    cache = api.init_cache(cfg, 1, 16, jnp.float32)
    logits, cache = api.prefill(params, {"tokens": tokens[:, :4]}, cache, cfg)
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(full[:, 3]), rtol=2e-4, atol=2e-4)
    for i in range(4, 8):
        logits, cache = api.decode_step(params, tokens[:, i:i + 1], cache,
                                        i, cfg)
        np.testing.assert_allclose(np.asarray(logits[:, 0]),
                                   np.asarray(full[:, i]),
                                   rtol=2e-4, atol=2e-4)


def test_decode_matches_forward_mamba():
    cfg = get_config("mamba2-370m", smoke=True)
    api = get_model(cfg)
    rng = np.random.RandomState(4)
    params = api.init(jax.random.PRNGKey(4), cfg, jnp.float32)
    tokens = jnp.asarray(rng.randint(0, cfg.vocab, size=(1, 8)).astype(np.int32))
    full = api.forward(params, {"tokens": tokens}, cfg, remat=False)
    cache = api.init_cache(cfg, 1, 16, jnp.float32)
    logits, cache = api.prefill(params, {"tokens": tokens[:, :4]}, cache, cfg)
    np.testing.assert_allclose(np.asarray(logits[:, 0]), np.asarray(full[:, 3]),
                               rtol=2e-3, atol=2e-3)
    for i in range(4, 8):
        logits, cache = api.decode_step(params, tokens[:, i:i + 1], cache,
                                        i, cfg)
        np.testing.assert_allclose(np.asarray(logits[:, 0]),
                                   np.asarray(full[:, i]),
                                   rtol=2e-3, atol=2e-3)


def test_sliding_window_ring_buffer_matches_full():
    """gemma2 smoke: decode with ring-buffer window cache == full forward."""
    cfg = get_config("gemma2-9b", smoke=True)
    api = get_model(cfg)
    rng = np.random.RandomState(5)
    params = api.init(jax.random.PRNGKey(5), cfg, jnp.float32)
    n = 24  # > window (16) to force wraparound
    tokens = jnp.asarray(rng.randint(0, cfg.vocab, size=(1, n)).astype(np.int32))
    full = api.forward(params, {"tokens": tokens}, cfg, remat=False)
    cache = api.init_cache(cfg, 1, n + 4, jnp.float32)
    logits, cache = api.prefill(params, {"tokens": tokens[:, :20]}, cache, cfg)
    np.testing.assert_allclose(np.asarray(logits[:, 0]), np.asarray(full[:, 19]),
                               rtol=2e-4, atol=2e-4)
    for i in range(20, n):
        logits, cache = api.decode_step(params, tokens[:, i:i + 1], cache,
                                        i, cfg)
        np.testing.assert_allclose(np.asarray(logits[:, 0]),
                                   np.asarray(full[:, i]),
                                   rtol=2e-4, atol=2e-4)


def test_param_counts_in_expected_range():
    """Full configs should land near their nameplate sizes."""
    expectations = {
        "phi4-mini-3.8b": (3.0e9, 5.0e9),
        "phi3-medium-14b": (12e9, 16e9),
        "gemma2-9b": (8e9, 11e9),
        "gemma3-4b": (3e9, 5.5e9),
        "whisper-small": (0.15e9, 0.4e9),
        "internvl2-2b": (1.5e9, 2.5e9),
        "mamba2-370m": (0.25e9, 0.5e9),
        "jamba-1.5-large-398b": (300e9, 480e9),
        "granite-moe-1b-a400m": (0.8e9, 1.6e9),
        "deepseek-v2-lite-16b": (12e9, 20e9),
    }
    for arch, (lo, hi) in expectations.items():
        n = count_params(get_config(arch))
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"


def test_active_params_moe():
    cfg = get_config("granite-moe-1b-a400m")
    total = count_params(cfg)
    active = count_params(cfg, active_only=True)
    assert active < total
    assert 0.2e9 <= active <= 0.8e9, active / 1e9
