"""Shared parity assertion: kernel-backed remote delivery ≡ dense halo path.

Used by both the deterministic suite (test_kernel_engine) and the
hypothesis sweep (test_property) so the two assert one delivery contract.
"""

from __future__ import annotations

import dataclasses

import numpy as np

import jax.numpy as jnp


def assert_remote_delivery_matches(graph, prog, payload, seed):
    """Randomize out/send, fill the halo with a real exchange, then compare
    dense vs kernel deliver(edges='remote') bit-exactly: every pending
    slot, has-flag, delivered flag and paper counter."""
    from repro.core.runtime import deliver, ell_channels, exchange, init_state

    rng = np.random.RandomState(seed)
    es = init_state(graph, prog, None)
    p, vp = graph.n_partitions, graph.vp
    (name, vals), = payload.items()
    send = jnp.logical_and(jnp.asarray(rng.uniform(size=(p, vp)) < 0.6),
                           graph.vertex_mask)
    es = dataclasses.replace(es, out={name: vals}, send=send,
                             export_out={name: vals}, export_send=send)
    es = exchange(graph, es)
    if graph.has_remote_ell:
        assert ell_channels(graph, prog, es.out, es.send, "remote"), \
            "kernel path should engage"
    es_d, del_d = deliver(graph, prog, es, edges="remote", use_ell=False)
    es_k, del_k = deliver(graph, prog, es, edges="remote", use_ell=True)
    (pd,), hd = es_d.pending[name]
    (pk,), hk = es_k.pending[name]
    np.testing.assert_array_equal(np.asarray(hd), np.asarray(hk))
    np.testing.assert_array_equal(np.asarray(pd), np.asarray(pk))
    np.testing.assert_array_equal(np.asarray(del_d), np.asarray(del_k))
    for f in ("net_messages", "net_local_messages", "mem_messages"):
        assert int(getattr(es_d.counters, f)) == \
            int(getattr(es_k.counters, f)), f
