"""Remote-delivery parity: kernel-backed path ≡ dense halo path.

``assert_remote_delivery_matches`` is the shared contract assertion (also
imported by the deterministic kernel suite and the hypothesis sweep); the
tests here drive it directly for every semiring family — including the
max_min / min_mul / max_add apps — and pin the tile-resident group
accounting against the dense per-group reduction it replaced.
"""

from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp


def assert_remote_delivery_matches(graph, prog, payload, seed):
    """Randomize out/send, fill the halo with a real exchange, then compare
    dense vs kernel deliver(edges='remote') bit-exactly: every pending
    slot, has-flag, delivered flag and paper counter."""
    from repro.core.runtime import deliver, ell_channels, exchange, init_state

    rng = np.random.RandomState(seed)
    es = init_state(graph, prog, None)
    p, vp = graph.n_partitions, graph.vp
    (name, vals), = payload.items()
    send = jnp.logical_and(jnp.asarray(rng.uniform(size=(p, vp)) < 0.6),
                           graph.vertex_mask)
    es = dataclasses.replace(es, out={name: vals}, send=send,
                             export_out={name: vals}, export_send=send)
    es = exchange(graph, es)
    if graph.has_remote_ell:
        assert ell_channels(graph, prog, es.out, es.send, "remote"), \
            "kernel path should engage"
    es_d, del_d = deliver(graph, prog, es, edges="remote", use_ell=False)
    es_k, del_k = deliver(graph, prog, es, edges="remote", use_ell=True)
    (pd,), hd = es_d.pending[name]
    (pk,), hk = es_k.pending[name]
    np.testing.assert_array_equal(np.asarray(hd), np.asarray(hk))
    np.testing.assert_array_equal(np.asarray(pd), np.asarray(pk))
    np.testing.assert_array_equal(np.asarray(del_d), np.asarray(del_k))
    for f in ("net_messages", "net_local_messages", "mem_messages"):
        assert int(getattr(es_d.counters, f)) == \
            int(getattr(es_k.counters, f)), f


# ---------------------------------------------------------------------------
# direct cases (tier-1): one skewed fixture, every semiring family
# ---------------------------------------------------------------------------

def _skewed_graph(seed=13, n=130, base_slices=8):
    """Hub-skewed digraph whose remote layout spills into multiple bins."""
    from repro.core import build_partitioned_graph, hash_partition

    rng = np.random.RandomState(seed)
    edges = np.stack([rng.randint(0, n, size=900),
                      rng.randint(0, 4, size=900)], axis=1)
    edges = np.concatenate([edges, rng.randint(0, n, size=(400, 2))])
    edges = np.unique(edges, axis=0)
    edges = edges[edges[:, 0] != edges[:, 1]]
    part = hash_partition(n, 4, seed=2)
    w = rng.uniform(1.0, 4.0, size=len(edges)).astype(np.float32)
    graph = build_partitioned_graph(edges, n, part, weights=w,
                                    ell_base_slices=base_slices)
    assert len(graph.remote_ell) >= 2, "fixture should spill remote bins"
    return graph, n


def test_remote_parity_min_add():
    from repro.core.apps import SSSP
    graph, _ = _skewed_graph()
    rng = np.random.RandomState(3)
    p, vp = graph.n_partitions, graph.vp
    dist = jnp.asarray(np.where(rng.uniform(size=(p, vp)) < 0.8,
                                rng.uniform(0, 50, size=(p, vp)),
                                np.inf).astype(np.float32))
    assert_remote_delivery_matches(graph, SSSP(source=0), {"dist": dist}, 5)


def test_remote_parity_max_min():
    from repro.core.apps import WidestPath
    graph, _ = _skewed_graph()
    rng = np.random.RandomState(4)
    p, vp = graph.n_partitions, graph.vp
    cap = jnp.asarray(np.where(rng.uniform(size=(p, vp)) < 0.8,
                               rng.uniform(0.1, 9, size=(p, vp)),
                               -np.inf).astype(np.float32))
    assert_remote_delivery_matches(graph, WidestPath(source=0), {"cap": cap},
                                   6)


def test_remote_parity_min_mul_and_max_add():
    from repro.core.apps import RandomWalk
    graph, _ = _skewed_graph()
    rng = np.random.RandomState(5)
    p, vp = graph.n_partitions, graph.vp
    odds = jnp.asarray(np.where(rng.uniform(size=(p, vp)) < 0.8,
                                rng.uniform(1, 50, size=(p, vp)),
                                np.inf).astype(np.float32))
    assert_remote_delivery_matches(graph, RandomWalk(source=0, mode="odds"),
                                   {"mass": odds}, 7)
    logp = jnp.asarray(np.where(rng.uniform(size=(p, vp)) < 0.8,
                                -rng.uniform(0, 4, size=(p, vp)),
                                -np.inf).astype(np.float32))
    assert_remote_delivery_matches(graph, RandomWalk(source=0, mode="logprob"),
                                   {"mass": logp}, 8)


def test_tile_group_accounting_equals_dense_reduction():
    """The per-slot ``grp`` ids packed into the remote EllSlices reproduce
    the dense (source-partition, destination) combine-group count for
    arbitrary send sets — the reduction `_ell_deliver` used to pay on the
    dense edge arrays even on the kernel path."""
    from repro.core.runtime import ell_group_accounting, slice_flat

    graph, _ = _skewed_graph()
    p = graph.n_partitions
    rng = np.random.RandomState(11)
    for seed in range(3):
        send_tab = jnp.asarray(
            rng.uniform(size=(p, graph.vp + graph.hp)) < 0.5)
        send_tab = jnp.logical_and(
            send_tab, jnp.concatenate([graph.vertex_mask, graph.halo_mask],
                                      axis=1))
        # dense oracle: segment-max over the block-ragged edge arrays
        # (edge_part resolves each edge's absolute partition, edge_group
        # its block-relative flat combine group)
        bsz = graph.edge_src.shape[0]
        ppb = p // bsz
        epart = graph.edge_part + (jnp.arange(bsz, dtype=jnp.int32)
                                   * ppb)[:, None]
        send_e = send_tab[epart, graph.edge_src]
        valid = jnp.logical_and(
            jnp.logical_and(graph.edge_mask,
                            jnp.logical_not(graph.edge_local)), send_e)
        gseg = (graph.edge_group + (jnp.arange(bsz, dtype=jnp.int32)
                                    * graph.gp)[:, None]).reshape(-1)
        grp_sent = jax.ops.segment_max(
            valid.reshape(-1).astype(jnp.int32), gseg,
            num_segments=bsz * graph.gp).reshape(bsz, graph.gp) > 0
        grp_sent = jnp.logical_and(grp_sent, graph.group_mask)
        want = int(jnp.sum(jnp.logical_and(grp_sent, graph.group_remote)))

        views = [slice_flat(s, graph, p) for s in graph.remote_ell]
        got = int(ell_group_accounting(graph, graph.remote_ell, views,
                                       send_tab.reshape(-1), p))
        assert got == want, (seed, got, want)
