"""Executor hook composition: multiple hooks on one run, method call
ordering, and the consumed-tick contract (every hook's ``before_step``
evaluates each tick even when an earlier one consumes it)."""

import numpy as np
import pytest

from repro.core import bfs_partition, build_partitioned_graph
from repro.core.apps import SSSP
from repro.data.graphs import grid_graph
from repro.exec.driver import ExecHook, run_engine
from repro.exec.policy import make_policy


@pytest.fixture(scope="module")
def road():
    edges, w, n = grid_graph(5, 30, seed=3)
    part = bfs_partition(edges, n, 4, seed=1)
    return build_partitioned_graph(edges, n, part, weights=w)


class Recorder(ExecHook):
    """Logs every method call into a shared list as (hook_name, method)."""

    def __init__(self, name, log):
        self.name = name
        self.log = log

    def on_start(self, ctx):
        self.log.append((self.name, "on_start"))

    def before_step(self, ctx):
        self.log.append((self.name, "before_step"))

    def after_step(self, ctx):
        self.log.append((self.name, "after_step"))

    def on_exit(self, ctx):
        self.log.append((self.name, "on_exit"))


class SkipOnce(Recorder):
    """Consumes exactly one tick (returns False from before_step once)."""

    def __init__(self, name, log, skip_tick):
        super().__init__(name, log)
        self.skip_tick = skip_tick

    def before_step(self, ctx):
        super().before_step(ctx)
        if ctx.tick == self.skip_tick:
            self.log.append((self.name, "CONSUMED"))
            return False


def test_hooks_called_in_order_every_phase(road):
    """Two hooks: list order is call order for every method, each step is
    bracketed before/after, start/exit fire exactly once per hook."""
    log = []
    a, b = Recorder("a", log), Recorder("b", log)
    ctx = run_engine(road, SSSP(source=0), make_policy("hybrid"), None,
                     hooks=(a, b))
    assert ctx.iteration > 1

    assert log[:2] == [("a", "on_start"), ("b", "on_start")]
    assert log[-2:] == [("a", "on_exit"), ("b", "on_exit")]
    per_step = [("a", "before_step"), ("b", "before_step"),
                ("a", "after_step"), ("b", "after_step")]
    assert log[2:-2] == per_step * ctx.iteration


def test_consumed_tick_still_evaluates_every_hook(road):
    """The all-hooks-evaluate contract: when hook a consumes tick 2, hook
    b's before_step still ran that tick (its failure-detection clock must
    advance), no after_step fires, and the run completes correctly."""
    ref = run_engine(road, SSSP(source=0), make_policy("hybrid"), None)

    log = []
    a = SkipOnce("a", log, skip_tick=2)
    b = Recorder("b", log)
    ctx = run_engine(road, SSSP(source=0), make_policy("hybrid"), None,
                     hooks=(a, b))

    # one extra tick: the consumed one did not step
    befores_b = [x for x in log if x == ("b", "before_step")]
    afters_b = [x for x in log if x == ("b", "after_step")]
    assert len(befores_b) == ctx.iteration + 1
    assert len(afters_b) == ctx.iteration
    # b's before_step DID run on the consumed tick: it directly follows
    # a's CONSUMED marker, with no after_step until the next tick's step
    i = log.index(("a", "CONSUMED"))
    assert log[i + 1] == ("b", "before_step")
    assert log[i + 2] == ("a", "before_step")      # next tick begins

    np.testing.assert_array_equal(np.asarray(ctx.es.state["dist"]),
                                  np.asarray(ref.es.state["dist"]))


def test_later_hook_false_does_not_shortcircuit(road):
    """`False in [h.before_step(ctx) for h in hooks]` evaluates the whole
    list: a False from the FIRST hook must not stop the second from being
    called (regression guard on replacing the list with any())."""
    log = []
    a = SkipOnce("a", log, skip_tick=1)
    b = SkipOnce("b", log, skip_tick=1)   # both consume the same tick
    ctx = run_engine(road, SSSP(source=0), make_policy("hybrid"), None,
                     hooks=(a, b))
    assert ctx.iteration > 0
    assert ("a", "CONSUMED") in log and ("b", "CONSUMED") in log


def test_checkpoint_fault_and_trace_hooks_compose(road, tmp_path):
    """The production stack — fault detection + checkpointing + tracing on
    one run — leaves results identical to the bare run and a consistent
    trace."""
    from repro.ft import run_hybrid_ft
    from repro.obs.metrics import MetricsRegistry
    from repro.obs.trace import Tracer

    ref = run_hybrid_ft(road, SSSP(source=0))

    tracer = Tracer()
    reg = MetricsRegistry()
    res = run_hybrid_ft(road, SSSP(source=0), ckpt_dir=str(tmp_path / "c"),
                        tracer=tracer, registry=reg)
    np.testing.assert_array_equal(np.asarray(res.es.state["dist"]),
                                  np.asarray(ref.es.state["dist"]))
    for f in ("iterations", "net_messages", "net_local_messages"):
        assert int(getattr(res.es.counters, f)) == \
            int(getattr(ref.es.counters, f))

    steps = [s for s in tracer.spans if s.cat == "superstep"]
    assert len(steps) == res.iterations
    # the wrapped hooks' work is attributed, and the superstep span that
    # brackets each step is recorded last (TraceHook sits last in the list)
    assert any(s.cat == "hook" and "CheckpointHook.after_step" in s.name
               for s in tracer.spans)
    assert any(s.cat == "hook" and "_FaultHook.before_step" in s.name
               for s in tracer.spans)
    assert reg.value("engine.iterations") == float(res.iterations)
    assert reg.value("checkpoint.bytes_written") > 0
