"""Partition subsystem tests.

  * builder invariants under *arbitrary* vertex->partition labelings
    (including every real partitioner's output): each input edge appears
    exactly once across partitions, ``halo_ptr`` resolves to the correct
    exporter slot, ``is_boundary`` matches a numpy oracle — exercised both
    by a seeded sweep (always) and a hypothesis property test (when
    hypothesis is installed);
  * ``PartitionReport`` oracle checks on path/cycle graphs where the
    optimal cut is known, and numpy-vs-built-graph agreement;
  * partitioner ladder validity + quality ordering (fennel/multilevel beat
    the hash cut, respect the balance cap; bfs stays count-balanced);
  * hybrid-engine fixed points are bit-exact across partitioners for
    SSSP/WCC and oracle-correct throughout — partitioning may move the
    traffic, never the answer;
  * the vectorized ``geometric_graph`` equals the O(n²) brute force.
"""

import importlib.util

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import build_partitioned_graph, run_hybrid
from repro.core.graph import unpack_vertex
from repro.core.apps import SSSP, WCC
from repro.data.graphs import (cycle_graph, geometric_graph, grid_graph,
                               path_graph, rmat_graph, symmetrize)
from repro.partition import (PARTITIONERS, bfs_partition, make_partition,
                             partition_report)

HAVE_HYPOTHESIS = importlib.util.find_spec("hypothesis") is not None


# ---------------------------------------------------------------------------
# builder invariants for arbitrary labelings
# ---------------------------------------------------------------------------

def _random_labeled_digraph(n, m, seed, k, how):
    rng = np.random.RandomState(seed)
    edges = rng.randint(0, n, size=(m, 2))
    edges = edges[edges[:, 0] != edges[:, 1]]
    if len(edges) == 0:
        edges = np.array([[0, 1]])
    edges = np.unique(edges, axis=0)
    if how == "arbitrary":
        part = rng.randint(0, k, size=n).astype(np.int32)
    else:
        part = make_partition(how, edges, n, k, seed=seed % 97)
    w = rng.uniform(0.5, 3.0, size=len(edges)).astype(np.float32)
    return edges, w, n, part


def _check_builder_invariants(edges, w, n, part):
    graph = build_partitioned_graph(edges, n, part, weights=w)
    P, Vp, X = graph.n_partitions, graph.vp, graph.xp

    em = np.asarray(graph.edge_mask)
    sg = np.asarray(graph.edge_src_gid)
    dg = np.asarray(graph.edge_dst_gid)

    # every input edge appears exactly once across partitions
    got = np.stack([sg[em], dg[em]], axis=1)
    got = got[np.lexsort((got[:, 1], got[:, 0]))]
    np.testing.assert_array_equal(got, edges)   # np.unique output is sorted

    # is_boundary == "has an in-edge from another partition" (numpy oracle)
    oracle_b = np.zeros(n, dtype=bool)
    cross = part[edges[:, 0]] != part[edges[:, 1]]
    oracle_b[edges[cross, 1]] = True
    vm = np.asarray(graph.vertex_mask)
    gid = np.asarray(graph.vertex_gid)
    np.testing.assert_array_equal(np.asarray(graph.is_boundary)[vm],
                                  oracle_b[gid[vm]])

    # halo_ptr resolves every remote edge source to the correct exporter slot
    # (partition p's edges live in its block-ragged span, see edge_span)
    esrc = np.asarray(graph.edge_src)
    elocal = np.asarray(graph.edge_local)
    epart = np.asarray(graph.edge_part)
    halo_ptr = np.asarray(graph.halo_ptr)
    halo_mask = np.asarray(graph.halo_mask)
    export_slot = np.asarray(graph.export_slot)
    export_mask = np.asarray(graph.export_mask)
    ppb = P // graph.n_blocks
    for p in range(P):
        b, sl = graph.edge_span(p)
        assert b == p // ppb and sl.stop - sl.start == graph.ep_by_p[p]
        assert (epart[b, sl] == p % ppb).all()
        assert em[b, sl].sum() == em[b, sl][:em[b, sl].sum()].sum()  # prefix
        sel = em[b, sl] & ~elocal[b, sl]
        if not sel.any():
            continue
        hs = esrc[b, sl][sel] - Vp
        assert (hs >= 0).all() and (hs < graph.hp).all()
        assert halo_mask[p, hs].all()
        flat = halo_ptr[p, hs]
        q, x = flat // X, flat % X
        assert export_mask[q, x].all()
        sgp = sg[b, sl][sel]
        exporter_gid = gid[q, export_slot[q, x]]
        np.testing.assert_array_equal(exporter_gid, sgp)
        np.testing.assert_array_equal(q, part[sgp])

    # the numpy quality report and the built halo plan agree
    assert partition_report(edges, n, part, graph=graph) == \
        partition_report(edges, n, part)


@pytest.mark.parametrize("how", ["arbitrary"] + sorted(PARTITIONERS))
@pytest.mark.parametrize("seed", [0, 7, 1234])
def test_builder_invariants_seeded_sweep(how, seed):
    rng = np.random.RandomState(seed + 99)
    n = int(rng.randint(4, 29))
    m = int(rng.randint(n, 3 * n + 1))
    k = int(rng.randint(2, min(6, n) + 1))
    _check_builder_invariants(
        *_random_labeled_digraph(n, m, seed, k, how))


if HAVE_HYPOTHESIS:
    from hypothesis import given, settings, strategies as st

    @st.composite
    def labeled_digraphs(draw, max_n=28, max_e=80):
        """Random digraph + labeling from {arbitrary, each partitioner}."""
        n = draw(st.integers(4, max_n))
        m = draw(st.integers(n, max_e))
        seed = draw(st.integers(0, 2**16))
        k = draw(st.integers(2, min(6, n)))
        how = draw(st.sampled_from(["arbitrary"] + sorted(PARTITIONERS)))
        return _random_labeled_digraph(n, m, seed, k, how)

    @settings(max_examples=25, deadline=None)
    @given(labeled_digraphs())
    def test_builder_invariants_any_labeling(g):
        _check_builder_invariants(*g)


# ---------------------------------------------------------------------------
# PartitionReport oracles
# ---------------------------------------------------------------------------

def test_report_path_graph_contiguous_chunks():
    """Contiguous chunking is the optimal k-cut of a path: k-1 cut edges,
    one boundary vertex (the chunk head) and one halo entry per cut."""
    edges, n = path_graph(64)
    part = (np.arange(n) * 4 // n).astype(np.int32)
    rep = partition_report(edges, n, part)
    assert rep.edge_cut == 3
    assert rep.edge_cut_frac == 3 / 63
    assert rep.boundary_vertices == 3
    assert rep.boundary_frac == 3 / 64
    assert rep.halo_entries == 3
    assert rep.replication == 3 / 64
    assert rep.balance == 1.0
    assert rep.exchange_bytes == 3 * 4
    # chunk 0 keeps 15 in-edges (vertex 0 has none), chunks 1-3 keep 16:
    # a shared-width padded layout would pay 4*16 slots for 63 edges
    assert rep.pad_waste == pytest.approx(4 * 16 / 63)

    # the built graph's export_fanout plan agrees with the numpy route
    g = build_partitioned_graph(edges, n, part)
    assert partition_report(edges, n, part, graph=g) == rep
    # the built ragged graph sees the same skew through its padded spans
    assert g.pad_waste == pytest.approx(
        g.n_partitions * max(g.ep_by_p) / sum(g.ep_by_p))


def test_report_cycle_graph_contiguous_chunks():
    edges, n = cycle_graph(60)
    part = (np.arange(n) * 4 // n).astype(np.int32)
    rep = partition_report(edges, n, part)
    assert rep.edge_cut == 4            # one wrap per chunk boundary
    assert rep.boundary_vertices == 4
    assert rep.halo_entries == 4
    assert rep.balance == 1.0
    assert rep.pad_waste == 1.0         # one in-edge per vertex: no skew
    g = build_partitioned_graph(edges, n, part)
    assert partition_report(edges, n, part, graph=g) == rep
    assert g.pad_waste == 1.0           # equal spans, any pad_multiple


# ---------------------------------------------------------------------------
# partitioner ladder quality + validity
# ---------------------------------------------------------------------------

def test_partitioner_ladder_on_grid():
    edges, w, n = grid_graph(20, 40, seed=0)
    reports = {}
    for name in PARTITIONERS:
        part = make_partition(name, edges, n, 6, seed=0)
        assert part.shape == (n,) and part.dtype == np.int32
        assert part.min() >= 0 and part.max() < 6
        reports[name] = partition_report(edges, n, part, n_partitions=6)
    assert reports["fennel"].edge_cut < reports["hash"].edge_cut
    assert reports["multilevel"].edge_cut < reports["hash"].edge_cut
    assert reports["bfs"].edge_cut < reports["hash"].edge_cut
    assert reports["fennel"].balance <= 1.1 + 1e-9
    assert reports["multilevel"].balance <= 1.1 + 1e-9


def test_multilevel_beats_hash_on_powerlaw():
    edges, n = rmat_graph(1000, avg_degree=6, seed=3)
    hash_rep = partition_report(
        edges, n, make_partition("hash", edges, n, 8, seed=0),
        n_partitions=8)
    ml_rep = partition_report(
        edges, n, make_partition("multilevel", edges, n, 8, seed=0),
        n_partitions=8)
    assert ml_rep.edge_cut_frac < hash_rep.edge_cut_frac / 1.1
    assert ml_rep.balance <= 1.1 + 1e-9


def test_bfs_partition_stays_count_balanced():
    """The smallest-first growth order keeps every partition at or below
    the ceil(n/k) target (the old fixed-order claiming biased early
    partitions; the leftover sweep could then overfill)."""
    for rows, cols, k, seed in ((16, 16, 5, 0), (10, 37, 7, 3)):
        edges, _, n = grid_graph(rows, cols, seed=seed)
        part = bfs_partition(edges, n, k, seed=seed)
        sizes = np.bincount(part, minlength=k)
        assert sizes.max() <= -(-n // k), sizes


# ---------------------------------------------------------------------------
# the engine answer is partitioner-invariant
# ---------------------------------------------------------------------------

def test_sssp_fixed_point_bitexact_across_partitioners():
    edges, w, n = grid_graph(8, 40, seed=2)
    dist = np.full(n, np.inf)
    dist[0] = 0.0
    for _ in range(n):                       # Bellman-Ford oracle
        nd = dist.copy()
        np.minimum.at(nd, edges[:, 1], dist[edges[:, 0]] + w)
        if np.array_equal(nd, dist, equal_nan=True):
            break
        dist = nd
    outs = {}
    for name in PARTITIONERS:
        g = build_partitioned_graph(edges, n, name, weights=w,
                                    n_partitions=5)
        es, _ = run_hybrid(g, SSSP(source=0))
        outs[name] = unpack_vertex(g, es.state["dist"])
        np.testing.assert_allclose(outs[name], dist, rtol=1e-5)
    base = outs.pop("hash")
    for name, got in outs.items():
        np.testing.assert_array_equal(base, got, err_msg=name)


def test_wcc_fixed_point_bitexact_across_partitioners():
    edges, n = rmat_graph(300, avg_degree=4, seed=5)
    e2 = symmetrize(edges)
    outs = {}
    for name in PARTITIONERS:
        g = build_partitioned_graph(e2, n, name, n_partitions=4)
        es, _ = run_hybrid(g, WCC())
        outs[name] = unpack_vertex(g, es.state["label"])
    base = outs.pop("hash")
    for name, got in outs.items():
        np.testing.assert_array_equal(base, got, err_msg=name)


# ---------------------------------------------------------------------------
# vectorized geometric_graph == brute force
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,seed", [(200, 0), (350, 5)])
def test_geometric_graph_matches_bruteforce(n, seed):
    edges, _ = geometric_graph(n, seed=seed)
    rng = np.random.RandomState(seed)
    r = np.sqrt(6.0 / (np.pi * n))
    pts = rng.uniform(size=(n, 2))
    d2 = ((pts[:, None, :] - pts[None, :, :]) ** 2).sum(-1)
    ref = np.argwhere((d2 < r * r) & ~np.eye(n, dtype=bool)).astype(np.int64)
    np.testing.assert_array_equal(edges, ref)
