"""Golden executor-parity suite: the superstep-executor refactor must be
bit-invisible.

``tests/data/golden_executor.json`` holds, for every app × engine ×
delivery path (dense / ELL), a sha256 digest of the final engine state
(state channels + send/active masks) plus the iteration count and every
paper counter, captured from the pre-refactor ``run_bsp`` / ``run_am`` /
``run_hybrid``.  The tests below re-run the same workloads through the
current engines and assert bit-identity — state, iterations, and every
counter — so any drift the unification introduces (a reordered reduction,
a counter bumped in the wrong place, a changed halt rule) fails loudly.

Regenerate (only when a change is *supposed* to move the fixed points):

    PYTHONPATH=src python tests/test_executor_parity.py --regen
"""

import hashlib
import json
import os

import numpy as np
import pytest

GOLDEN_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "data", "golden_executor.json")

ENGINES = ("bsp", "am", "hybrid")
DELIVERY = (("dense", False), ("ell", True))


def _workloads():
    """Deterministic small fixtures: one per app family."""
    from repro.core import bfs_partition, build_partitioned_graph, \
        hash_partition
    from repro.core.apps import (SSSP, WCC, BipartiteMatching,
                                 IncrementalPageRank, RandomWalk, WidestPath)
    from repro.core.apps.pagerank import pagerank_edge_weights
    from repro.core.apps.random_walk import random_walk_edge_weights
    from repro.data.graphs import (bipartite_graph, grid_graph, rmat_graph,
                                   symmetrize)

    out = {}

    edges, w, n = grid_graph(6, 30, seed=3)
    part = bfs_partition(edges, n, 4, seed=1)
    out["sssp"] = (build_partitioned_graph(edges, n, part, weights=w),
                   lambda: SSSP(source=0), None)

    edges, n = rmat_graph(200, avg_degree=5, seed=7)
    part = hash_partition(n, 4, seed=2)
    w = pagerank_edge_weights(edges, n)
    out["pagerank"] = (build_partitioned_graph(edges, n, part, weights=w),
                       lambda: IncrementalPageRank(tolerance=1e-4), None)

    rng = np.random.RandomState(0)
    blocks, off = [], 0
    for size in (30, 25):
        e = rng.randint(0, size, size=(size * 3, 2)) + off
        p = np.stack([np.arange(size - 1), np.arange(1, size)], axis=1) + off
        blocks.append(np.concatenate([e, p], axis=0))
        off += size
    edges = symmetrize(np.concatenate(blocks, axis=0))
    edges = edges[edges[:, 0] != edges[:, 1]]
    part = hash_partition(off, 4, seed=3)
    out["wcc"] = (build_partitioned_graph(edges, off, part),
                  lambda: WCC(), None)

    edges, n = rmat_graph(150, avg_degree=5, seed=9)
    w = (np.random.RandomState(19).uniform(0.5, 8.0, size=len(edges))
         .astype(np.float32))
    part = hash_partition(n, 4, seed=1)
    out["widest"] = (build_partitioned_graph(edges, n, part, weights=w),
                     lambda: WidestPath(source=0), None)

    edges, n = rmat_graph(150, avg_degree=5, seed=15)
    part = bfs_partition(edges, n, 4, seed=2)
    w = random_walk_edge_weights(edges, n, "odds")
    out["random_walk"] = (build_partitioned_graph(edges, n, part, weights=w),
                          lambda: RandomWalk(source=0, mode="odds"), None)

    edges, n_left, n = bipartite_graph(30, 25, avg_degree=3, seed=11)
    part = hash_partition(n, 4, seed=4)
    g = build_partitioned_graph(edges, n, part)
    vdata = {"is_left": g.vertex_gid < n_left, "degree": g.out_degree}
    out["bipartite"] = (g, lambda: BipartiteMatching(seed=1), vdata)
    return out


def _digest(es) -> str:
    """sha256 over the final state channels + send/active, in a fixed
    order, shape/dtype included (so a silent transpose or cast changes the
    digest)."""
    h = hashlib.sha256()
    for name in sorted(es.state):
        a = np.asarray(es.state[name])
        h.update(f"{name}:{a.dtype}:{a.shape}".encode())
        h.update(a.tobytes())
    for name, a in (("send", es.send), ("active", es.active)):
        a = np.asarray(a)
        h.update(f"{name}:{a.dtype}:{a.shape}".encode())
        h.update(a.tobytes())
    return h.hexdigest()


def _snapshot(graph, prog, vdata, engine: str, use_ell: bool) -> dict:
    from repro.core import run_am, run_bsp, run_hybrid
    runners = {"bsp": run_bsp, "am": run_am, "hybrid": run_hybrid}
    es, iters = runners[engine](graph, prog, vdata=vdata, max_iters=500,
                                use_ell=use_ell)
    c = es.counters
    return {
        "digest": _digest(es),
        "iterations": iters,
        "counters": {
            "iterations": int(c.iterations),
            "pseudo_supersteps": np.asarray(c.pseudo_supersteps).tolist(),
            "net_messages": int(c.net_messages),
            "net_local_messages": int(c.net_local_messages),
            "mem_messages": int(c.mem_messages),
        },
    }


def _load_golden() -> dict:
    with open(GOLDEN_PATH) as f:
        return json.load(f)


@pytest.fixture(scope="module")
def workloads():
    return _workloads()


@pytest.fixture(scope="module")
def golden():
    return _load_golden()


@pytest.mark.parametrize("delivery,use_ell", DELIVERY)
@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("app", ["sssp", "pagerank", "wcc", "widest",
                                 "random_walk", "bipartite"])
def test_golden_parity(workloads, golden, app, engine, delivery, use_ell):
    graph, make_prog, vdata = workloads[app]
    got = _snapshot(graph, make_prog(), vdata, engine, use_ell)
    want = golden[app][engine][delivery]
    assert got["iterations"] == want["iterations"], (got, want)
    assert got["counters"] == want["counters"], (got, want)
    assert got["digest"] == want["digest"], \
        f"{app}/{engine}/{delivery}: final state drifted from the golden " \
        f"snapshot"


def regen() -> None:
    golden = {}
    for app, (graph, make_prog, vdata) in _workloads().items():
        golden[app] = {}
        for engine in ENGINES:
            golden[app][engine] = {}
            for delivery, use_ell in DELIVERY:
                golden[app][engine][delivery] = _snapshot(
                    graph, make_prog(), vdata, engine, use_ell)
                print(f"{app}/{engine}/{delivery}: "
                      f"{golden[app][engine][delivery]['digest'][:12]}")
    with open(GOLDEN_PATH, "w") as f:
        json.dump(golden, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {GOLDEN_PATH}")


if __name__ == "__main__":
    import sys
    if "--regen" in sys.argv:
        regen()
    else:
        print(__doc__)
