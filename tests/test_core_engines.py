"""Engine correctness: all three engines reach the oracle fixed points, and
the hybrid engine reproduces the paper's headline claim (global iterations
collapse to ~O(partitions) on high-diameter graphs)."""

import numpy as np
import pytest

import jax.numpy as jnp
import networkx as nx

from repro.core import (bfs_partition, build_partitioned_graph,
                        hash_partition, run_am, run_bsp, run_hybrid)
from repro.core.apps import (SSSP, WCC, BipartiteMatching,
                             IncrementalPageRank, RandomWalk, WidestPath)
from repro.core.apps.pagerank import pagerank_edge_weights
from repro.core.apps.random_walk import random_walk_edge_weights
from repro.data.graphs import (bipartite_graph, grid_graph, path_graph,
                               rmat_graph, symmetrize)

RUNNERS = {"bsp": run_bsp, "am": run_am, "hybrid": run_hybrid}


def unpack(graph, es, field):
    """Collect per-vertex values back to global id order."""
    gid = np.asarray(graph.vertex_gid).ravel()
    val = np.asarray(es.state[field]).reshape(gid.shape[0], -1).squeeze(-1)
    mask = gid >= 0
    out = np.zeros(graph.n_vertices, dtype=val.dtype)
    out[gid[mask]] = val[mask]
    return out


# ---------------------------------------------------------------------------
# SSSP
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def road():
    # long thin lattice: high diameter relative to partition count, the
    # regime of the paper's road-network experiments
    edges, w, n = grid_graph(6, 90, seed=3)
    part = bfs_partition(edges, n, 6, seed=1)
    graph = build_partitioned_graph(edges, n, part, weights=w)
    g = nx.DiGraph()
    for (u, v), wt in zip(edges, w):
        g.add_edge(int(u), int(v), weight=float(wt))
    dist = nx.single_source_dijkstra_path_length(g, 0)
    oracle = np.full(n, np.inf)
    for k, v in dist.items():
        oracle[k] = v
    return graph, oracle, n


@pytest.mark.parametrize("engine", ["bsp", "am", "hybrid"])
def test_sssp_matches_dijkstra(road, engine):
    graph, oracle, n = road
    es, iters = RUNNERS[engine](graph, SSSP(source=0))
    got = unpack(graph, es, "dist")
    np.testing.assert_allclose(got, oracle, rtol=1e-5)
    assert iters > 0


def test_sssp_hybrid_iteration_collapse(road):
    """Paper Fig.3(a): GraphHP needs ~20 iterations where Hama needs
    thousands; here: hybrid iterations ~ O(partitions), bsp ~ O(diameter)."""
    graph, _, _ = road
    _, it_bsp = run_bsp(graph, SSSP(source=0))
    _, it_am = run_am(graph, SSSP(source=0))
    es_h, it_hyb = run_hybrid(graph, SSSP(source=0))
    assert it_hyb * 3 < it_bsp, (it_hyb, it_bsp)
    assert it_am <= it_bsp
    # and network traffic shrinks too (Table 2 ordering)
    assert int(es_h.counters.net_messages) > 0


def test_sssp_path_graph_exact_iterations():
    """A path split into P chunks: BSP needs ~n supersteps, hybrid ~P+1
    global iterations — the sharpest possible statement of the paper's
    execution-model claim."""
    edges, n = path_graph(64)
    part = (np.arange(n) * 4 // n).astype(np.int32)   # 4 contiguous chunks
    graph = build_partitioned_graph(edges, n, part)
    _, it_bsp = run_bsp(graph, SSSP(source=0))
    es, it_hyb = run_hybrid(graph, SSSP(source=0))
    assert it_bsp >= n - 2
    assert it_hyb <= 4 + 2, it_hyb
    got = unpack(graph, es, "dist")
    np.testing.assert_allclose(got, np.arange(n, dtype=np.float32))


# ---------------------------------------------------------------------------
# PageRank (incremental, Algorithm 5)
# ---------------------------------------------------------------------------

def _pr_oracle(edges, n, iters=300):
    """Fixed point of r = 0.15 + 0.85 * W^T r with W row-normalized."""
    deg = np.bincount(edges[:, 0], minlength=n).astype(np.float64)
    r = np.full(n, 0.15)
    for _ in range(iters):
        contrib = np.zeros(n)
        np.add.at(contrib, edges[:, 1], 0.85 * r[edges[:, 0]] / np.maximum(deg[edges[:, 0]], 1))
        r = 0.15 + contrib
    return r


@pytest.fixture(scope="module")
def web():
    edges, n = rmat_graph(400, avg_degree=6, seed=7)
    part = hash_partition(n, 8, seed=2)
    w = pagerank_edge_weights(edges, n)
    graph = build_partitioned_graph(edges, n, part, weights=w)
    return graph, edges, n


@pytest.mark.parametrize("engine", ["bsp", "am", "hybrid"])
def test_pagerank_converges_to_oracle(web, engine):
    graph, edges, n = web
    tol = 1e-5
    es, iters = RUNNERS[engine](graph, IncrementalPageRank(tolerance=tol))
    got = unpack(graph, es, "rank")
    oracle = _pr_oracle(edges, n)
    # Algorithm 5 drops residuals <= tol at each receipt; accumulated error
    # scales with rank mass — a relative + absolute envelope:
    np.testing.assert_allclose(got, oracle, rtol=2e-3, atol=5e-3)


def test_pagerank_hybrid_fewer_iterations(web):
    graph, _, _ = web
    tol = 1e-5
    _, it_bsp = run_bsp(graph, IncrementalPageRank(tolerance=tol))
    _, it_hyb = run_hybrid(graph, IncrementalPageRank(tolerance=tol))
    assert it_hyb < it_bsp, (it_hyb, it_bsp)


# ---------------------------------------------------------------------------
# WCC
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ["bsp", "am", "hybrid"])
def test_wcc(engine):
    rng = np.random.RandomState(0)
    # three disjoint communities
    blocks = []
    off = 0
    for size in (40, 33, 27):
        e = rng.randint(0, size, size=(size * 3, 2)) + off
        # a spanning path guarantees connectivity
        p = np.stack([np.arange(size - 1), np.arange(1, size)], axis=1) + off
        blocks.append(np.concatenate([e, p], axis=0))
        off += size
    edges = symmetrize(np.concatenate(blocks, axis=0))
    edges = edges[edges[:, 0] != edges[:, 1]]
    n = off
    part = hash_partition(n, 5, seed=3)
    graph = build_partitioned_graph(edges, n, part)
    es, _ = RUNNERS[engine](graph, WCC())
    got = unpack(graph, es, "label")
    expect = np.concatenate([np.zeros(40), np.full(33, 40), np.full(27, 73)])
    np.testing.assert_array_equal(got, expect)


# ---------------------------------------------------------------------------
# Widest (maximum-capacity) paths — the max_min semiring
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def capacitated():
    """Skewed digraph with capacity weights + the numpy max-min oracle."""
    rng = np.random.RandomState(19)
    edges, n = rmat_graph(250, avg_degree=5, seed=9)
    w = rng.uniform(0.5, 8.0, size=len(edges)).astype(np.float32)
    part = hash_partition(n, 6, seed=1)
    graph = build_partitioned_graph(edges, n, part, weights=w)
    cap = np.full(n, -np.inf, dtype=np.float64)
    cap[0] = np.inf
    for _ in range(n):                       # Bellman-Ford on (max, min)
        nc = cap.copy()
        np.maximum.at(nc, edges[:, 1], np.minimum(cap[edges[:, 0]], w))
        if np.array_equal(nc, cap):
            break
        cap = nc
    return graph, cap.astype(np.float32), n


@pytest.mark.parametrize("engine", ["bsp", "am", "hybrid"])
def test_widest_path_matches_oracle(capacitated, engine):
    graph, oracle, n = capacitated
    es, iters = RUNNERS[engine](graph, WidestPath(source=0))
    got = unpack(graph, es, "cap")
    np.testing.assert_array_equal(got, oracle)   # max/min: bit-exact
    assert iters > 0


def test_widest_path_hybrid_fewer_iterations(capacitated):
    graph, _, _ = capacitated
    _, it_bsp = run_bsp(graph, WidestPath(source=0))
    _, it_hyb = run_hybrid(graph, WidestPath(source=0))
    assert it_hyb <= it_bsp, (it_hyb, it_bsp)


# ---------------------------------------------------------------------------
# Most-likely absorbing random walk — min_mul (odds) / max_add (log-prob)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def walkable():
    """Digraph + per-mode uniform-transition weight graphs + the numpy
    oracle for the best-walk probability (Bellman-Ford on (min, *))."""
    edges, n = rmat_graph(250, avg_degree=5, seed=15)
    part = bfs_partition(edges, n, 6, seed=2)
    graphs = {m: build_partitioned_graph(
        edges, n, part, weights=random_walk_edge_weights(edges, n, m))
        for m in ("odds", "logprob")}
    w = random_walk_edge_weights(edges, n)
    odds = np.full(n, np.inf, dtype=np.float64)
    odds[0] = 1.0
    for _ in range(2 * n):
        no = odds.copy()
        np.minimum.at(no, edges[:, 1], odds[edges[:, 0]] * w)
        if np.array_equal(no, odds):
            break
        odds = no
    prob = np.where(np.isfinite(odds), 1.0 / odds, 0.0)
    return graphs, prob, n


@pytest.mark.parametrize("mode", ["odds", "logprob"])
@pytest.mark.parametrize("engine", ["bsp", "am", "hybrid"])
def test_random_walk_matches_oracle(walkable, engine, mode):
    graphs, oracle, n = walkable
    graph = graphs[mode]
    prog = RandomWalk(source=0, mode=mode)
    es, iters = RUNNERS[engine](graph, prog)
    got = np.asarray(prog.probability(
        jnp.asarray(unpack(graph, es, "mass"))))
    # odds are exact products of small-int degrees; log-prob sums logs and
    # re-enters through exp, so allow float tolerance there
    if mode == "odds":
        np.testing.assert_allclose(got, oracle, rtol=1e-6)
    else:
        np.testing.assert_allclose(got, oracle, rtol=1e-4, atol=1e-7)
    assert iters > 0


def test_random_walk_modes_agree(walkable):
    """The two semiring formulations are isomorphic: identical best-walk
    probabilities from the min_mul and max_add closures."""
    graphs, _, _ = walkable
    probs = {}
    for mode in ("odds", "logprob"):
        prog = RandomWalk(source=0, mode=mode)
        es, _ = run_hybrid(graphs[mode], prog)
        probs[mode] = np.asarray(prog.probability(
            jnp.asarray(unpack(graphs[mode], es, "mass"))))
    np.testing.assert_allclose(probs["odds"], probs["logprob"],
                               rtol=1e-4, atol=1e-7)


# ---------------------------------------------------------------------------
# Bipartite matching
# ---------------------------------------------------------------------------

def _check_matching(edges_lr, n_left, n, matched):
    """Valid: symmetric partner claims along real edges.  Maximal: no edge
    with both endpoints free."""
    eset = {(int(u), int(v)) for u, v in edges_lr}
    for l in range(n_left):
        m = int(matched[l])
        if m >= 0:
            assert (l, m) in eset, f"matched along non-edge {l}-{m}"
            assert int(matched[m]) == l, f"asymmetric match {l}-{m}"
    for u, v in eset:
        assert matched[u] >= 0 or matched[v] >= 0, f"augmentable edge {u}-{v}"


@pytest.mark.parametrize("engine", ["bsp", "am", "hybrid"])
def test_bipartite_matching(engine):
    edges, n_left, n = bipartite_graph(60, 50, avg_degree=3, seed=11)
    part = hash_partition(n, 6, seed=4)
    graph = build_partitioned_graph(edges, n, part)
    import jax.numpy as jnp
    vdata = {"is_left": graph.vertex_gid < n_left, "degree": graph.out_degree}
    es, iters = RUNNERS[engine](graph, BipartiteMatching(seed=1), vdata=vdata,
                                max_iters=500)
    matched = unpack(graph, es, "matched")
    edges_lr = edges[edges[:, 0] < n_left]
    _check_matching(edges_lr, n_left, n, matched)
    assert iters < 500


def test_bm_hybrid_fewer_iterations():
    edges, n_left, n = bipartite_graph(120, 100, avg_degree=3, seed=5)
    part = bfs_partition(edges, n, 6, seed=0)
    graph = build_partitioned_graph(edges, n, part)
    vdata = {"is_left": graph.vertex_gid < n_left, "degree": graph.out_degree}
    _, it_bsp = run_bsp(graph, BipartiteMatching(seed=1), vdata=vdata, max_iters=500)
    _, it_hyb = run_hybrid(graph, BipartiteMatching(seed=1), vdata=vdata, max_iters=500)
    assert it_hyb <= it_bsp


# ---------------------------------------------------------------------------
# Metrics sanity (paper §7 definitions)
# ---------------------------------------------------------------------------

def test_message_counters_ordering(road):
    """Hama counts everything as RPC; AM-Hama / GraphHP only the cut; the
    hybrid engine additionally collapses exchanges (Table 2 ordering)."""
    graph, _, _ = road
    es_b, _ = run_bsp(graph, SSSP(source=0))
    es_a, _ = run_am(graph, SSSP(source=0))
    es_h, _ = run_hybrid(graph, SSSP(source=0))
    m_hama = int(es_b.counters.net_messages) + int(es_b.counters.net_local_messages)
    m_am = int(es_a.counters.net_messages)
    m_hyb = int(es_h.counters.net_messages)
    assert m_hama > m_am >= m_hyb > 0, (m_hama, m_am, m_hyb)


def test_wire_dtype_decodes_only_float_payloads():
    """Regression: channels whose *genuine* payload dtype is uint16/uint8
    must ride a ``wire_dtype=bf16`` exchange untouched.  The decode used to
    key on the carrier dtype (``l.dtype in (uint16, uint8)``), which also
    bitcast real integer payloads to bf16 and corrupted them on the way
    back; it now decides from the saved dtypes tree (decode iff the
    original leaf was floating)."""
    import jax
    import jax.numpy as jnp
    from repro.core.runtime import Counters, EngineState, exchange

    edges, n = path_graph(16)
    part = np.repeat(np.arange(2), 8).astype(np.int32)
    g = build_partitioned_graph(edges, n, part)
    p, vp, h = g.n_partitions, g.vp, g.hp
    rng = np.random.RandomState(0)
    out = {"flag16": jnp.asarray(rng.randint(0, 2**16, (p, vp)), jnp.uint16),
           "flag8": jnp.asarray(rng.randint(0, 2**8, (p, vp)), jnp.uint8),
           "val": jnp.asarray(rng.randn(p, vp), jnp.float32)}
    ones = jnp.ones((p, vp), bool)
    es = EngineState(
        state=out, out=out, send=ones, active=ones,
        export_out=out, export_send=ones, pending={},
        halo_out=jax.tree.map(lambda l: jnp.zeros((p, h), l.dtype), out),
        halo_send=jnp.zeros((p, h), bool),
        counters=Counters.zeros(p))

    ref = exchange(g, es)                               # exact wire
    got = exchange(g, es, wire_dtype=jnp.bfloat16)      # quantized wire
    hm = np.asarray(g.halo_mask)
    for name in ("flag16", "flag8"):                    # ints: bit-exact
        np.testing.assert_array_equal(np.asarray(got.halo_out[name])[hm],
                                      np.asarray(ref.halo_out[name])[hm])
    expect = np.asarray(ref.halo_out["val"].astype(jnp.bfloat16)
                        .astype(jnp.float32))           # floats: quantized
    np.testing.assert_array_equal(np.asarray(got.halo_out["val"])[hm],
                                  expect[hm])


def test_hybrid_wire_bf16_quantized_exchange(road):
    """§Perf optimization: bf16-quantized exchange payloads keep SSSP
    convergent and within quantization tolerance of the exact run."""
    import dataclasses
    import jax.numpy as jnp
    from functools import partial
    from repro.core.engine_hybrid import hybrid_iteration, init_hybrid
    from repro.core.runtime import quiescent
    import jax

    graph, oracle, n = road
    prog = SSSP(source=0)
    step = jax.jit(partial(hybrid_iteration, graph, prog, vdata=None,
                           wire_dtype=jnp.bfloat16))
    es = init_hybrid(graph, prog, None)
    for _ in range(200):
        if bool(quiescent(prog, es)):
            break
        es = step(es=es)
    got = unpack(graph, es, "dist")
    # bf16 has ~3 decimal digits: allow 1% relative error
    np.testing.assert_allclose(got, oracle, rtol=1e-2)
