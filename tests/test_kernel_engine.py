"""Kernel-backed delivery parity: `use_ell=True` routes local-phase message
combination through the Pallas ELL kernels (and the whole PageRank local
phase through the fused `pr_step` kernel); every app on every engine must
reach the same fixed point as the dense gather/segment path — bit-for-bit
for min/lexmin combiners, to float-reassociation tolerance for 'sum' — with
identical iteration counts and paper counters."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (bfs_partition, build_partitioned_graph,
                        hash_partition, run_am, run_bsp, run_hybrid)
from repro.core.apps import (SSSP, WCC, BipartiteMatching,
                             IncrementalPageRank, RandomWalk, WidestPath)
from repro.core.apps.pagerank import pagerank_edge_weights
from repro.core.apps.random_walk import random_walk_edge_weights
from repro.core.runtime import ell_channels
from repro.data.graphs import bipartite_graph, grid_graph, rmat_graph, symmetrize

from test_delivery_parity import assert_remote_delivery_matches as \
    _assert_remote_delivery_matches

RUNNERS = {"bsp": run_bsp, "am": run_am, "hybrid": run_hybrid}
ENGINES = ["bsp", "am", "hybrid"]


def unpack(graph, es, field):
    gid = np.asarray(graph.vertex_gid).ravel()
    val = np.asarray(es.state[field]).reshape(gid.shape[0], -1).squeeze(-1)
    mask = gid >= 0
    out = np.zeros(graph.n_vertices, dtype=val.dtype)
    out[gid[mask]] = val[mask]
    return out


def assert_counters_equal(a, b):
    for f in ("iterations", "net_messages", "net_local_messages",
              "mem_messages"):
        assert int(getattr(a.counters, f)) == int(getattr(b.counters, f)), f
    np.testing.assert_array_equal(np.asarray(a.counters.pseudo_supersteps),
                                  np.asarray(b.counters.pseudo_supersteps))


def run_pair(engine, graph, make_prog, vdata=None, **kw):
    es_d, it_d = RUNNERS[engine](graph, make_prog(), vdata=vdata,
                                 use_ell=False, **kw)
    es_k, it_k = RUNNERS[engine](graph, make_prog(), vdata=vdata,
                                 use_ell=True, **kw)
    assert it_d == it_k, (it_d, it_k)
    return es_d, es_k


@pytest.fixture(scope="module")
def road():
    edges, w, n = grid_graph(6, 60, seed=3)
    part = bfs_partition(edges, n, 6, seed=1)
    return build_partitioned_graph(edges, n, part, weights=w), n


@pytest.fixture(scope="module")
def web():
    edges, n = rmat_graph(300, avg_degree=6, seed=7)
    part = hash_partition(n, 6, seed=2)
    w = pagerank_edge_weights(edges, n)
    return build_partitioned_graph(edges, n, part, weights=w), n


def test_graph_carries_ell_layout(road):
    graph, _ = road
    assert graph.has_ell and graph.has_remote_ell and graph.kl > 0
    base = graph.local_ell[0]
    assert base.dense and base.lo == 0 and base.stride == graph.vp
    ppb = graph.n_partitions // graph.n_blocks
    assert base.idx.shape == (graph.n_blocks, ppb * graph.vp, base.kb)
    assert base.flat_idx.shape == (graph.n_partitions * graph.vp, base.kb)
    # ELL slots reproduce exactly the local/remote splits of the dense arrays
    n_local = int(jnp.sum(jnp.logical_and(graph.edge_mask, graph.edge_local)))
    n_remote = int(jnp.sum(jnp.logical_and(graph.edge_mask,
                                           jnp.logical_not(graph.edge_local))))
    assert sum(int(jnp.sum(s.msk)) for s in graph.local_ell) == n_local
    assert sum(int(jnp.sum(s.msk)) for s in graph.remote_ell) == n_remote
    # remote sources are halo-encoded past the local slot space
    rbase = graph.remote_ell[0]
    assert rbase.stride == graph.vp + graph.hp
    assert bool(jnp.all(jnp.where(rbase.msk, rbase.idx >= graph.vp, True)))


def test_skewed_graph_keeps_fast_path_with_bins():
    """Power-law in-degree no longer bails out to dense: hub rows spill into
    extra ELL bins and every engine still reaches the dense fixed point with
    identical counters."""
    edges, n = rmat_graph(600, avg_degree=10, seed=11)
    rng = np.random.RandomState(5)
    hubs = np.stack([rng.randint(0, n, size=2000),
                     rng.randint(0, 5, size=2000)], axis=1)
    edges = np.unique(np.concatenate([edges, hubs]), axis=0)
    edges = edges[edges[:, 0] != edges[:, 1]]
    part = hash_partition(n, 4, seed=0)
    w = pagerank_edge_weights(edges, n)
    graph = build_partitioned_graph(edges, n, part, weights=w,
                                    ell_base_slices=16)
    assert len(graph.local_ell) >= 2, "skew should produce spill bins"
    assert not graph.local_ell[1].dense
    for engine in ENGINES:
        es_d, es_k = run_pair(engine, graph,
                              lambda: IncrementalPageRank(tolerance=1e-4))
        np.testing.assert_allclose(unpack(graph, es_d, "rank"),
                                   unpack(graph, es_k, "rank"),
                                   rtol=1e-5, atol=1e-6)
        assert_counters_equal(es_d, es_k)


def test_semiring_channels_are_eligible(road):
    graph, _ = road
    prog = SSSP(source=0)
    out = {"dist": jnp.zeros((graph.n_partitions, graph.vp))}
    send = jnp.zeros((graph.n_partitions, graph.vp), bool)
    assert [c.name for c in ell_channels(graph, prog, out, send)] == ["dist"]
    # bipartite matching declares no semirings -> everything falls back
    assert ell_channels(graph, BipartiteMatching(), {}, send) == []


@pytest.mark.parametrize("engine", ENGINES)
def test_sssp_parity(road, engine):
    graph, _ = road
    es_d, es_k = run_pair(engine, graph, lambda: SSSP(source=0))
    np.testing.assert_array_equal(unpack(graph, es_d, "dist"),
                                  unpack(graph, es_k, "dist"))
    assert_counters_equal(es_d, es_k)


@pytest.mark.parametrize("engine", ENGINES)
def test_wcc_parity(engine):
    rng = np.random.RandomState(0)
    edges = symmetrize(rng.randint(0, 90, size=(400, 2)))
    edges = edges[edges[:, 0] != edges[:, 1]]
    part = hash_partition(90, 5, seed=3)
    graph = build_partitioned_graph(edges, 90, part)
    es_d, es_k = run_pair(engine, graph, WCC)
    np.testing.assert_array_equal(unpack(graph, es_d, "label"),
                                  unpack(graph, es_k, "label"))
    assert_counters_equal(es_d, es_k)


@pytest.mark.parametrize("engine", ENGINES)
def test_pagerank_parity(web, engine):
    """'sum' channels reassociate float adds (ELL reduces along slices,
    segment-sum along edges) so ranks match to tolerance; the integer
    counters and iteration counts must still agree exactly."""
    graph, _ = web
    es_d, es_k = run_pair(engine, graph,
                          lambda: IncrementalPageRank(tolerance=1e-4))
    np.testing.assert_allclose(unpack(graph, es_d, "rank"),
                               unpack(graph, es_k, "rank"),
                               rtol=1e-5, atol=1e-6)
    assert_counters_equal(es_d, es_k)


@pytest.mark.parametrize("engine", ENGINES)
def test_bipartite_matching_fallback_parity(engine):
    """No BM channel is semiring-expressible (lexmin handshake, targeted
    grants) — use_ell must transparently keep the dense path bit-for-bit."""
    edges, n_left, n = bipartite_graph(50, 40, avg_degree=3, seed=11)
    part = hash_partition(n, 5, seed=4)
    graph = build_partitioned_graph(edges, n, part)
    vdata = {"is_left": graph.vertex_gid < n_left, "degree": graph.out_degree}
    es_d, es_k = run_pair(engine, graph, lambda: BipartiteMatching(seed=1),
                          vdata=vdata, max_iters=500)
    np.testing.assert_array_equal(unpack(graph, es_d, "matched"),
                                  unpack(graph, es_k, "matched"))
    assert_counters_equal(es_d, es_k)


@pytest.mark.parametrize("engine", ENGINES)
def test_widest_path_parity(road, engine):
    """max_min delivery (and the generalized fused local phase on hybrid)
    matches the dense path bit-for-bit — max/min never reassociates."""
    graph, _ = road
    es_d, es_k = run_pair(engine, graph, lambda: WidestPath(source=0))
    np.testing.assert_array_equal(unpack(graph, es_d, "cap"),
                                  unpack(graph, es_k, "cap"))
    assert_counters_equal(es_d, es_k)


@pytest.mark.parametrize("mode", ["odds", "logprob"])
@pytest.mark.parametrize("engine", ENGINES)
def test_random_walk_parity(engine, mode):
    """min_mul (odds) and max_add (log-prob) deliveries match the dense
    path bit-for-bit: ⊗ is evaluated identically on both paths and ⊕ is a
    selection."""
    edges, n = rmat_graph(220, avg_degree=5, seed=3)
    w = random_walk_edge_weights(edges, n, mode)
    part = hash_partition(n, 5, seed=1)
    graph = build_partitioned_graph(edges, n, part, weights=w)
    es_d, es_k = run_pair(engine, graph,
                          lambda: RandomWalk(source=0, mode=mode))
    np.testing.assert_array_equal(unpack(graph, es_d, "mass"),
                                  unpack(graph, es_k, "mass"))
    assert_counters_equal(es_d, es_k)


def test_new_apps_fuse_through_min_step(road):
    """The generalized fused gate engages for every monotone-semiring app
    and stays off when the channel combiner doesn't match the semiring ⊕."""
    from repro.core.engine_hybrid import _fused_local_kernel
    from repro.core.vertex_program import Channel
    graph, _ = road
    for prog in (WidestPath(source=0), RandomWalk(source=0, mode="odds"),
                 RandomWalk(source=0, mode="logprob")):
        assert _fused_local_kernel(graph, prog, use_ell=True,
                                   max_local_steps=10) == "min_step"
        assert _fused_local_kernel(graph, prog, use_ell=False,
                                   max_local_steps=10) is None
    # mismatched combiner/⊕ (min channel over a max semiring) must not fuse
    bad = WidestPath(source=0)
    bad.channels = (Channel("cap", "min", ((jnp.float32, -jnp.inf),),
                            semiring="max_min"),)
    assert _fused_local_kernel(graph, bad, use_ell=True,
                               max_local_steps=10) is None


def test_widest_path_fused_cutoff_parity(road):
    """max_local_steps cutoff rollback holds for the generalized (max, min)
    fusion exactly as for SSSP's (min, +)."""
    graph, _ = road
    for steps in (1, 3):
        es_d, it_d = run_hybrid(graph, WidestPath(source=0),
                                max_local_steps=steps, use_ell=False)
        es_k, it_k = run_hybrid(graph, WidestPath(source=0),
                                max_local_steps=steps, use_ell=True)
        assert it_d == it_k, (steps, it_d, it_k)
        np.testing.assert_array_equal(unpack(graph, es_d, "cap"),
                                      unpack(graph, es_k, "cap"))
        assert_counters_equal(es_d, es_k)


def test_hybrid_fused_pr_uses_kernel_and_matches(web):
    """The fused path is actually engaged for PageRank on the hybrid engine
    (fused_kernel declared + ELL present) and collect_metrics=False leaves
    the message counters untouched while converging to the same ranks."""
    from repro.core.engine_hybrid import _fused_local_kernel
    graph, _ = web
    prog = IncrementalPageRank(tolerance=1e-4)
    assert _fused_local_kernel(graph, prog, use_ell=True,
                               max_local_steps=10) == "pr_step"
    assert _fused_local_kernel(graph, prog, use_ell=False,
                               max_local_steps=10) is None

    es_ref, it_ref = run_hybrid(graph, IncrementalPageRank(tolerance=1e-4))
    es_perf, it_perf = run_hybrid(graph, IncrementalPageRank(tolerance=1e-4),
                                  use_ell=True, collect_metrics=False)
    assert it_ref == it_perf
    np.testing.assert_allclose(unpack(graph, es_ref, "rank"),
                               unpack(graph, es_perf, "rank"),
                               rtol=1e-5, atol=1e-6)
    assert int(es_perf.counters.net_messages) == 0
    assert int(es_perf.counters.mem_messages) == 0
    assert int(es_ref.counters.mem_messages) > 0


def test_remote_ell_matches_dense_bitexact(road):
    graph, _ = road
    rng = np.random.RandomState(21)
    p, vp = graph.n_partitions, graph.vp
    dist = jnp.asarray(np.where(rng.uniform(size=(p, vp)) < 0.8,
                                rng.uniform(0, 50, size=(p, vp)),
                                np.inf).astype(np.float32))
    _assert_remote_delivery_matches(graph, SSSP(source=0), {"dist": dist}, 3)
    labels = jnp.asarray(rng.randint(0, graph.n_vertices,
                                     size=(p, vp)).astype(np.int32))
    _assert_remote_delivery_matches(graph, WCC(), {"label": labels}, 4)


def test_remote_ell_skewed_bins_engage_and_match():
    """Deterministic hub graph: the remote layout must actually spill into
    extra bins (the case the old ``ell_max_slices`` bailout regressed to
    dense) and still match the dense path bit-exactly."""
    rng = np.random.RandomState(13)
    n = 120
    edges = np.stack([rng.randint(0, n, size=900),
                      rng.randint(0, 4, size=900)], axis=1)
    edges = np.concatenate([edges, rng.randint(0, n, size=(300, 2))])
    edges = np.unique(edges, axis=0)
    edges = edges[edges[:, 0] != edges[:, 1]]
    part = hash_partition(n, 4, seed=2)
    graph = build_partitioned_graph(edges, n, part, ell_base_slices=8)
    assert len(graph.remote_ell) >= 2
    assert not graph.remote_ell[1].dense
    p, vp = graph.n_partitions, graph.vp
    dist = jnp.asarray(rng.uniform(0, 50, size=(p, vp)).astype(np.float32))
    _assert_remote_delivery_matches(graph, SSSP(source=0), {"dist": dist}, 17)


def test_no_ell_layout_falls_back(road):
    """A graph built without the ELL layout keeps use_ell runs on the dense
    path (kl == 0 -> no eligible channels), same results."""
    edges, w, n = grid_graph(4, 30, seed=5)
    part = bfs_partition(edges, n, 4, seed=1)
    g = build_partitioned_graph(edges, n, part, weights=w, build_ell=False)
    assert not g.has_ell
    es_d, it_d = run_hybrid(g, SSSP(source=0))
    es_k, it_k = run_hybrid(g, SSSP(source=0), use_ell=True)
    assert it_d == it_k
    np.testing.assert_array_equal(unpack(g, es_d, "dist"),
                                  unpack(g, es_k, "dist"))


def test_device_loop_matches_host_loop(road):
    graph, _ = road
    es_h, it_h = run_hybrid(graph, SSSP(source=0), device_loop=False)
    es_d, it_d = run_hybrid(graph, SSSP(source=0), device_loop=True)
    assert it_h == it_d
    np.testing.assert_array_equal(np.asarray(es_h.state["dist"]),
                                  np.asarray(es_d.state["dist"]))
    assert_counters_equal(es_h, es_d)


def test_fused_pr_cutoff_parity(web):
    """A max_local_steps cutoff exits the local phase with the final
    delivery still pending; the fused kernel has already applied it, so the
    engine must roll the apply back — otherwise the next iteration's apply
    double-counts the deltas and ranks diverge from the dense path."""
    graph, _ = web
    for steps in (1, 3):
        es_d, it_d = run_hybrid(graph, IncrementalPageRank(tolerance=1e-4),
                                max_local_steps=steps)
        es_k, it_k = run_hybrid(graph, IncrementalPageRank(tolerance=1e-4),
                                max_local_steps=steps, use_ell=True)
        assert it_d == it_k, (steps, it_d, it_k)
        np.testing.assert_allclose(unpack(graph, es_d, "rank"),
                                   unpack(graph, es_k, "rank"),
                                   rtol=1e-5, atol=1e-6)
        assert_counters_equal(es_d, es_k)


def test_int_semiring_f32_exact_judged_per_bin(road):
    """Integer payloads (WCC labels) ride the kernel as float32, judged per
    ELL degree bin against the largest source gid feeding the bin: at the
    2**24 boundary the bin is still exact (2**24 is representable), one past
    it the channel must fall back to dense — in both delivery directions."""
    import dataclasses
    graph, _ = road
    out = {"label": jnp.zeros((graph.n_partitions, graph.vp), jnp.int32)}
    send = jnp.zeros((graph.n_partitions, graph.vp), bool)
    prog = WCC()
    for edges in ("local", "remote"):
        assert [c.name for c in
                ell_channels(graph, prog, out, send, edges)] == ["label"]

    def rebound(g, side, bound):
        slices = tuple(dataclasses.replace(s, payload_bound=bound)
                       for s in getattr(g, side))
        return dataclasses.replace(g, **{side: slices})

    for side, edges in (("local_ell", "local"), ("remote_ell", "remote")):
        at_edge = rebound(graph, side, 1 << 24)
        past = rebound(graph, side, (1 << 24) + 1)
        assert [c.name for c in
                ell_channels(at_edge, prog, out, send, edges)] == ["label"]
        assert ell_channels(past, prog, out, send, edges) == []
        # float payloads (SSSP distances) are never bound-limited
        assert [c.name for c in
                ell_channels(past, SSSP(source=0),
                             {"dist": out["label"].astype(jnp.float32)},
                             send, edges)] == ["dist"]
    # a poisoned *local* bin must not leak into remote eligibility
    poisoned_local = rebound(graph, "local_ell", (1 << 24) + 1)
    assert [c.name for c in
            ell_channels(poisoned_local, prog, out, send, "remote")] \
        == ["label"]


def test_fused_min_gate_falls_back_past_f32_exact(road):
    """The fused min_step loop keeps the whole int state in float32, so its
    gate needs every vertex id representable — stricter than the per-bin
    message judgment."""
    import dataclasses
    from repro.core.engine_hybrid import _fused_local_kernel
    graph, _ = road
    assert _fused_local_kernel(graph, WCC(), use_ell=True,
                               max_local_steps=10) == "min_step"
    assert _fused_local_kernel(graph, SSSP(source=0), use_ell=True,
                               max_local_steps=10) == "min_step"
    big = dataclasses.replace(graph, n_vertices=(1 << 24) + 2)
    assert _fused_local_kernel(big, WCC(), use_ell=True,
                               max_local_steps=10) is None
    # float states (SSSP) stay fused at any graph size
    assert _fused_local_kernel(big, SSSP(source=0), use_ell=True,
                               max_local_steps=10) == "min_step"


def test_hybrid_fused_min_uses_kernel_and_matches(road):
    """The fused min_step path engages for SSSP on the hybrid engine and
    collect_metrics=False leaves the message counters untouched while
    reaching the identical fixed point."""
    graph, _ = road
    es_ref, it_ref = run_hybrid(graph, SSSP(source=0), use_ell=False)
    es_perf, it_perf = run_hybrid(graph, SSSP(source=0),
                                  use_ell=True, collect_metrics=False)
    assert it_ref == it_perf
    np.testing.assert_array_equal(unpack(graph, es_ref, "dist"),
                                  unpack(graph, es_perf, "dist"))
    assert int(es_perf.counters.net_messages) == 0
    assert int(es_perf.counters.mem_messages) == 0
    assert int(es_ref.counters.mem_messages) > 0


def test_fused_min_cutoff_parity(road):
    """A max_local_steps cutoff exits the fused min local phase with the
    final delivery still pending; the kernel has already applied it, so the
    engine must roll the apply back — distances and counters must match the
    dense path bit-for-bit at every cutoff."""
    graph, _ = road
    for steps in (1, 2, 4):
        es_d, it_d = run_hybrid(graph, SSSP(source=0),
                                max_local_steps=steps, use_ell=False)
        es_k, it_k = run_hybrid(graph, SSSP(source=0),
                                max_local_steps=steps, use_ell=True)
        assert it_d == it_k, (steps, it_d, it_k)
        np.testing.assert_array_equal(unpack(graph, es_d, "dist"),
                                      unpack(graph, es_k, "dist"))
        assert_counters_equal(es_d, es_k)
