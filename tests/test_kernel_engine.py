"""Kernel-backed delivery parity: `use_ell=True` routes local-phase message
combination through the Pallas ELL kernels (and the whole PageRank local
phase through the fused `pr_step` kernel); every app on every engine must
reach the same fixed point as the dense gather/segment path — bit-for-bit
for min/lexmin combiners, to float-reassociation tolerance for 'sum' — with
identical iteration counts and paper counters."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (bfs_partition, build_partitioned_graph,
                        hash_partition, run_am, run_bsp, run_hybrid)
from repro.core.apps import SSSP, WCC, BipartiteMatching, IncrementalPageRank
from repro.core.apps.pagerank import pagerank_edge_weights
from repro.core.runtime import ell_channels
from repro.data.graphs import bipartite_graph, grid_graph, rmat_graph, symmetrize

RUNNERS = {"bsp": run_bsp, "am": run_am, "hybrid": run_hybrid}
ENGINES = ["bsp", "am", "hybrid"]


def unpack(graph, es, field):
    gid = np.asarray(graph.vertex_gid).ravel()
    val = np.asarray(es.state[field]).reshape(gid.shape[0], -1).squeeze(-1)
    mask = gid >= 0
    out = np.zeros(graph.n_vertices, dtype=val.dtype)
    out[gid[mask]] = val[mask]
    return out


def assert_counters_equal(a, b):
    for f in ("iterations", "net_messages", "net_local_messages",
              "mem_messages"):
        assert int(getattr(a.counters, f)) == int(getattr(b.counters, f)), f
    np.testing.assert_array_equal(np.asarray(a.counters.pseudo_supersteps),
                                  np.asarray(b.counters.pseudo_supersteps))


def run_pair(engine, graph, make_prog, vdata=None, **kw):
    es_d, it_d = RUNNERS[engine](graph, make_prog(), vdata=vdata,
                                 use_ell=False, **kw)
    es_k, it_k = RUNNERS[engine](graph, make_prog(), vdata=vdata,
                                 use_ell=True, **kw)
    assert it_d == it_k, (it_d, it_k)
    return es_d, es_k


@pytest.fixture(scope="module")
def road():
    edges, w, n = grid_graph(6, 60, seed=3)
    part = bfs_partition(edges, n, 6, seed=1)
    return build_partitioned_graph(edges, n, part, weights=w), n


@pytest.fixture(scope="module")
def web():
    edges, n = rmat_graph(300, avg_degree=6, seed=7)
    part = hash_partition(n, 6, seed=2)
    w = pagerank_edge_weights(edges, n)
    return build_partitioned_graph(edges, n, part, weights=w), n


def test_graph_carries_ell_layout(road):
    graph, _ = road
    assert graph.has_ell and graph.kl > 0
    assert graph.ell_idx.shape == (graph.n_partitions, graph.vp, graph.kl)
    # ELL slots reproduce exactly the local in-edges of the dense arrays
    n_local = int(jnp.sum(jnp.logical_and(graph.edge_mask, graph.edge_local)))
    assert int(jnp.sum(graph.ell_msk)) == n_local


def test_semiring_channels_are_eligible(road):
    graph, _ = road
    prog = SSSP(source=0)
    out = {"dist": jnp.zeros((graph.n_partitions, graph.vp))}
    send = jnp.zeros((graph.n_partitions, graph.vp), bool)
    assert [c.name for c in ell_channels(graph, prog, out, send)] == ["dist"]
    # bipartite matching declares no semirings -> everything falls back
    assert ell_channels(graph, BipartiteMatching(), {}, send) == []


@pytest.mark.parametrize("engine", ENGINES)
def test_sssp_parity(road, engine):
    graph, _ = road
    es_d, es_k = run_pair(engine, graph, lambda: SSSP(source=0))
    np.testing.assert_array_equal(unpack(graph, es_d, "dist"),
                                  unpack(graph, es_k, "dist"))
    assert_counters_equal(es_d, es_k)


@pytest.mark.parametrize("engine", ENGINES)
def test_wcc_parity(engine):
    rng = np.random.RandomState(0)
    edges = symmetrize(rng.randint(0, 90, size=(400, 2)))
    edges = edges[edges[:, 0] != edges[:, 1]]
    part = hash_partition(90, 5, seed=3)
    graph = build_partitioned_graph(edges, 90, part)
    es_d, es_k = run_pair(engine, graph, WCC)
    np.testing.assert_array_equal(unpack(graph, es_d, "label"),
                                  unpack(graph, es_k, "label"))
    assert_counters_equal(es_d, es_k)


@pytest.mark.parametrize("engine", ENGINES)
def test_pagerank_parity(web, engine):
    """'sum' channels reassociate float adds (ELL reduces along slices,
    segment-sum along edges) so ranks match to tolerance; the integer
    counters and iteration counts must still agree exactly."""
    graph, _ = web
    es_d, es_k = run_pair(engine, graph,
                          lambda: IncrementalPageRank(tolerance=1e-4))
    np.testing.assert_allclose(unpack(graph, es_d, "rank"),
                               unpack(graph, es_k, "rank"),
                               rtol=1e-5, atol=1e-6)
    assert_counters_equal(es_d, es_k)


@pytest.mark.parametrize("engine", ENGINES)
def test_bipartite_matching_fallback_parity(engine):
    """No BM channel is semiring-expressible (lexmin handshake, targeted
    grants) — use_ell must transparently keep the dense path bit-for-bit."""
    edges, n_left, n = bipartite_graph(50, 40, avg_degree=3, seed=11)
    part = hash_partition(n, 5, seed=4)
    graph = build_partitioned_graph(edges, n, part)
    vdata = {"is_left": graph.vertex_gid < n_left, "degree": graph.out_degree}
    es_d, es_k = run_pair(engine, graph, lambda: BipartiteMatching(seed=1),
                          vdata=vdata, max_iters=500)
    np.testing.assert_array_equal(unpack(graph, es_d, "matched"),
                                  unpack(graph, es_k, "matched"))
    assert_counters_equal(es_d, es_k)


def test_hybrid_fused_pr_uses_kernel_and_matches(web):
    """The fused path is actually engaged for PageRank on the hybrid engine
    (fused_kernel declared + ELL present) and collect_metrics=False leaves
    the message counters untouched while converging to the same ranks."""
    from repro.core.engine_hybrid import _use_fused_pr
    graph, _ = web
    prog = IncrementalPageRank(tolerance=1e-4)
    assert _use_fused_pr(graph, prog, use_ell=True, max_local_steps=10)
    assert not _use_fused_pr(graph, prog, use_ell=False, max_local_steps=10)

    es_ref, it_ref = run_hybrid(graph, IncrementalPageRank(tolerance=1e-4))
    es_perf, it_perf = run_hybrid(graph, IncrementalPageRank(tolerance=1e-4),
                                  use_ell=True, collect_metrics=False)
    assert it_ref == it_perf
    np.testing.assert_allclose(unpack(graph, es_ref, "rank"),
                               unpack(graph, es_perf, "rank"),
                               rtol=1e-5, atol=1e-6)
    assert int(es_perf.counters.net_messages) == 0
    assert int(es_perf.counters.mem_messages) == 0
    assert int(es_ref.counters.mem_messages) > 0


def test_no_ell_layout_falls_back(road):
    """A graph built without the ELL layout keeps use_ell runs on the dense
    path (kl == 0 -> no eligible channels), same results."""
    edges, w, n = grid_graph(4, 30, seed=5)
    part = bfs_partition(edges, n, 4, seed=1)
    g = build_partitioned_graph(edges, n, part, weights=w, build_ell=False)
    assert not g.has_ell
    es_d, it_d = run_hybrid(g, SSSP(source=0))
    es_k, it_k = run_hybrid(g, SSSP(source=0), use_ell=True)
    assert it_d == it_k
    np.testing.assert_array_equal(unpack(g, es_d, "dist"),
                                  unpack(g, es_k, "dist"))


def test_device_loop_matches_host_loop(road):
    graph, _ = road
    es_h, it_h = run_hybrid(graph, SSSP(source=0), device_loop=False)
    es_d, it_d = run_hybrid(graph, SSSP(source=0), device_loop=True)
    assert it_h == it_d
    np.testing.assert_array_equal(np.asarray(es_h.state["dist"]),
                                  np.asarray(es_d.state["dist"]))
    assert_counters_equal(es_h, es_d)


def test_fused_pr_cutoff_parity(web):
    """A max_local_steps cutoff exits the local phase with the final
    delivery still pending; the fused kernel has already applied it, so the
    engine must roll the apply back — otherwise the next iteration's apply
    double-counts the deltas and ranks diverge from the dense path."""
    graph, _ = web
    for steps in (1, 3):
        es_d, it_d = run_hybrid(graph, IncrementalPageRank(tolerance=1e-4),
                                max_local_steps=steps)
        es_k, it_k = run_hybrid(graph, IncrementalPageRank(tolerance=1e-4),
                                max_local_steps=steps, use_ell=True)
        assert it_d == it_k, (steps, it_d, it_k)
        np.testing.assert_allclose(unpack(graph, es_d, "rank"),
                                   unpack(graph, es_k, "rank"),
                                   rtol=1e-5, atol=1e-6)
        assert_counters_equal(es_d, es_k)


def test_int_semiring_falls_back_past_f32_exact(road):
    """Integer payloads (WCC labels) ride the kernel as float32; a graph
    with >= 2**24 vertices would round labels, so eligibility must drop."""
    import dataclasses
    graph, _ = road
    out = {"label": jnp.zeros((graph.n_partitions, graph.vp), jnp.int32)}
    send = jnp.zeros((graph.n_partitions, graph.vp), bool)
    assert [c.name for c in ell_channels(graph, WCC(), out, send)] == ["label"]
    big = dataclasses.replace(graph, n_vertices=1 << 24)
    assert ell_channels(big, WCC(), out, send) == []
