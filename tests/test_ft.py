"""Fault tolerance end-to-end: iteration-boundary checkpointing with exact
resume, deterministic fault injection driving the heartbeat -> reassign ->
restore recovery loop, elastic k -> k' resize of graphs and checkpoints,
and straggler flagging from the engine's own pseudo-superstep counters.

Everything runs on the host engine path with an injected logical clock —
no sleeps, no wall-clock in control flow; the distributed (fake 8-device)
twin lives in test_distributed.py.
"""

import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import (AsyncCheckpointer, CheckpointError,
                              latest_checkpoint, load_checkpoint,
                              load_checkpoint_arrays, save_checkpoint)
from repro.core import bfs_partition, build_partitioned_graph, run_hybrid
from repro.core.apps import SSSP, IncrementalPageRank
from repro.core.engine_hybrid import hybrid_iteration
from repro.core.runtime import quiescent
from repro.data.graphs import grid_graph, rmat_graph
from repro.core import hash_partition
from repro.core.apps.pagerank import pagerank_edge_weights
from repro.ft import (FaultInjector, FaultPlan, HeartbeatMonitor,
                      WorkerState, elastic_restore, flag_slow_shards,
                      partition_owners, replan_partitions,
                      reshard_vertex_tree, resize_labels, run_hybrid_ft)
from repro.io.digest import graph_digest
from repro.io.format import load_graph, save_graph
from repro.io.pipeline import build_from_sharded
from repro.io.resize import resize_ghp, resize_checkpoint


@pytest.fixture(scope="module")
def road():
    edges, w, n = grid_graph(6, 60, seed=3)
    part = bfs_partition(edges, n, 6, seed=1)
    return build_partitioned_graph(edges, n, part, weights=w), edges, w, n, \
        part


@pytest.fixture(scope="module")
def web():
    edges, n = rmat_graph(300, avg_degree=6, seed=7)
    part = hash_partition(n, 6, seed=2)
    w = pagerank_edge_weights(edges, n)
    return build_partitioned_graph(edges, n, part, weights=w)


def unpack(graph, es, field):
    gid = np.asarray(graph.vertex_gid).ravel()
    val = np.asarray(es.state[field]).reshape(gid.shape[0], -1).squeeze(-1)
    mask = gid >= 0
    out = np.zeros(graph.n_vertices, dtype=val.dtype)
    out[gid[mask]] = val[mask]
    return out


def assert_counters_equal(a, b):
    for f in ("iterations", "net_messages", "net_local_messages",
              "mem_messages"):
        assert int(getattr(a.counters, f)) == int(getattr(b.counters, f)), f
    np.testing.assert_array_equal(np.asarray(a.counters.pseudo_supersteps),
                                  np.asarray(b.counters.pseudo_supersteps))


def run_to_fixed_point(graph, prog, es):
    step = jax.jit(lambda e: hybrid_iteration(graph, prog, e, None))
    while not bool(quiescent(prog, es)):
        es = step(es)
    return es


# ---------------------------------------------------------------------------
# exact resume
# ---------------------------------------------------------------------------

def test_ft_driver_matches_run_hybrid(road):
    graph = road[0]
    res = run_hybrid_ft(graph, SSSP(source=0))
    es_ref, it_ref = run_hybrid(graph, SSSP(source=0), device_loop=False)
    assert res.iterations == it_ref
    np.testing.assert_array_equal(np.asarray(res.es.state["dist"]),
                                  np.asarray(es_ref.state["dist"]))
    assert_counters_equal(res.es, es_ref)
    assert res.recoveries == [] and res.resumed_from is None


@pytest.mark.parametrize("make_prog,field,kill_after", [
    (lambda: SSSP(source=0), "dist", 2),
    (lambda: IncrementalPageRank(tolerance=1e-4), "rank", 3),
])
def test_kill_and_resume_bit_identical(tmp_path, road, web, make_prog,
                                       field, kill_after):
    """Interrupt after iteration k, restart: final state AND every paper
    counter bit-identical to the uninterrupted run — for a monotone
    min-plus program and for sum-combiner PageRank."""
    graph = road[0] if field == "dist" else web
    ref = run_hybrid_ft(graph, make_prog())
    d = str(tmp_path / "ck")
    r1 = run_hybrid_ft(graph, make_prog(), ckpt_dir=d, max_iters=kill_after)
    assert r1.iterations == kill_after < ref.iterations
    r2 = run_hybrid_ft(graph, make_prog(), ckpt_dir=d)
    assert r2.resumed_from is not None and \
        r2.resumed_from.endswith(f"step_{kill_after:08d}")
    np.testing.assert_array_equal(np.asarray(r2.es.state[field]),
                                  np.asarray(ref.es.state[field]))
    assert_counters_equal(r2.es, ref.es)


def test_resume_refuses_other_graph_or_program(tmp_path, road, web):
    d = str(tmp_path / "ck")
    run_hybrid_ft(road[0], SSSP(source=0), ckpt_dir=d, max_iters=2)
    with pytest.raises(CheckpointError, match="program"):
        run_hybrid_ft(road[0], IncrementalPageRank(tolerance=1e-4),
                      ckpt_dir=d)
    with pytest.raises(CheckpointError, match="graph_digest"):
        run_hybrid_ft(web, SSSP(source=0), ckpt_dir=d)


def test_checkpoint_every_spaces_snapshots(tmp_path, road):
    d = str(tmp_path / "ck")
    run_hybrid_ft(road[0], SSSP(source=0), ckpt_dir=d, max_iters=5,
                  checkpoint_every=2, keep=10)
    steps = sorted(os.listdir(d))
    assert steps == ["step_00000002", "step_00000004"]


# ---------------------------------------------------------------------------
# fault injection -> recovery loop
# ---------------------------------------------------------------------------

def test_injected_kill_triggers_recovery(tmp_path, road):
    graph = road[0]
    ref = run_hybrid_ft(graph, SSSP(source=0))
    inj = FaultInjector(FaultPlan.kill_at(3, worker=1), n_workers=4)
    res = run_hybrid_ft(graph, SSSP(source=0), ckpt_dir=str(tmp_path / "c"),
                        n_workers=4, injector=inj)
    assert len(res.recoveries) == 1
    ev = res.recoveries[0]
    assert ev.failed_workers == (1,)
    assert ev.bytes_read > 0 and ev.restore_seconds > 0
    assert ev.iterations_lost == 0            # checkpoint_every=1
    assert ev.moved                           # partitions were reassigned
    assert res.epoch == 1                     # one reassignment event
    np.testing.assert_array_equal(np.asarray(res.es.state["dist"]),
                                  np.asarray(ref.es.state["dist"]))
    assert_counters_equal(res.es, ref.es)


def test_injected_kill_is_deterministic(tmp_path, road):
    graph = road[0]
    runs = []
    for i in range(2):
        inj = FaultInjector(FaultPlan.kill_at(4, worker=0), n_workers=3)
        runs.append(run_hybrid_ft(graph, SSSP(source=0),
                                  ckpt_dir=str(tmp_path / f"c{i}"),
                                  n_workers=3, injector=inj))
    a, b = runs
    assert [e.tick for e in a.recoveries] == [e.tick for e in b.recoveries]
    assert [e.restored_iteration for e in a.recoveries] == \
        [e.restored_iteration for e in b.recoveries]
    assert a.iterations == b.iterations
    assert_counters_equal(a.es, b.es)


def test_recovery_iterations_lost_with_sparse_checkpoints(tmp_path, road):
    """checkpoint_every=3 + a kill detected past iteration 4 rolls back to
    the iteration-3 snapshot: the recovery event owns the lost work."""
    graph = road[0]
    inj = FaultInjector(FaultPlan.kill_at(2, worker=0), n_workers=2)
    res = run_hybrid_ft(graph, SSSP(source=0), ckpt_dir=str(tmp_path / "c"),
                        checkpoint_every=3, n_workers=2, injector=inj)
    ev = res.recoveries[0]
    assert ev.restored_iteration % 3 == 0
    assert ev.iterations_lost == ev.tick - 1 - ev.restored_iteration
    ref = run_hybrid_ft(graph, SSSP(source=0))
    np.testing.assert_array_equal(np.asarray(res.es.state["dist"]),
                                  np.asarray(ref.es.state["dist"]))


def test_delay_recovers_without_failover(road):
    """A worker silent for one tick turns SUSPECT then heals on its next
    beat — no reassignment, no restore."""
    graph = road[0]
    inj = FaultInjector(FaultPlan(delay={2: (1, 1)}), n_workers=3)
    res = run_hybrid_ft(graph, SSSP(source=0), n_workers=3, injector=inj)
    assert res.recoveries == [] and res.epoch == 0


def test_injector_requires_monotonic_ticks():
    inj = FaultInjector(FaultPlan(), n_workers=2)
    assert list(inj.beating(1)) == [0, 1]
    with pytest.raises(ValueError):
        inj.beating(1)


# ---------------------------------------------------------------------------
# heartbeat state machine (injected clock)
# ---------------------------------------------------------------------------

def test_heartbeat_suspect_heals_on_beat():
    t = [0.0]
    mon = HeartbeatMonitor(2, suspect_after=1.0, fail_after=3.0,
                           clock=lambda: t[0])
    t[0] = 2.0
    mon.beat(0)
    assert mon.sweep() == []
    assert mon.workers[1].state is WorkerState.SUSPECT
    mon.beat(1)
    assert mon.workers[1].state is WorkerState.HEALTHY
    t[0] = 2.5
    assert mon.sweep() == []
    assert mon.workers[1].state is WorkerState.HEALTHY


def test_heartbeat_epoch_bumps_once_per_event():
    t = [0.0]
    mon = HeartbeatMonitor(4, suspect_after=1.0, fail_after=2.0,
                           clock=lambda: t[0])
    for p in range(8):
        mon.assign(p % 4, p)
    t[0] = 3.0
    mon.beat(3)                       # workers 0,1,2 all fail together
    assert sorted(mon.sweep()) == [0, 1, 2]
    moved = mon.reassign_failed()
    assert mon.epoch == 1             # ONE event, not one per worker
    assert sorted(i for items in moved.values() for i in items) == \
        [0, 1, 2, 4, 5, 6]
    assert mon.reassign_failed() == {}
    assert mon.epoch == 1             # nothing moved -> no bump


def test_heartbeat_no_healthy_workers_raises():
    t = [0.0]
    mon = HeartbeatMonitor(2, suspect_after=1.0, fail_after=2.0,
                           clock=lambda: t[0])
    mon.assign(0, "a")
    t[0] = 5.0
    assert sorted(mon.sweep()) == [0, 1]
    with pytest.raises(RuntimeError, match="no healthy workers"):
        mon.reassign_failed()


# ---------------------------------------------------------------------------
# checkpoint layer (raw codec — runs without zstandard)
# ---------------------------------------------------------------------------

def test_checkpoint_raw_codec_roundtrip(tmp_path):
    state = {"a": jnp.arange(12.0).reshape(3, 4), "b": jnp.ones((5,), bool)}
    save_checkpoint(str(tmp_path / "c"), state, step=4, codec="raw")
    restored, step = load_checkpoint(str(tmp_path / "c"), state)
    assert step == 4
    assert all(jax.tree.leaves(jax.tree.map(
        lambda x, y: bool(jnp.all(x == y)), state, restored)))
    arrs, manifest = load_checkpoint_arrays(str(tmp_path / "c"))
    assert manifest["codec"] == "raw"
    assert set(arrs) == {"a", "b"}


def test_load_checkpoint_validates_tree(tmp_path):
    state = {"w": jnp.ones((4, 4)), "b": jnp.zeros((4,))}
    save_checkpoint(str(tmp_path / "c"), state, step=0, codec="raw")
    with pytest.raises(CheckpointError, match="leaves"):
        load_checkpoint(str(tmp_path / "c"), {"w": jnp.ones((4, 4))})
    with pytest.raises(CheckpointError, match="manifest"):
        load_checkpoint(str(tmp_path / "c"),
                        {"w": jnp.ones((4, 4)), "x": jnp.zeros((4,))})
    with pytest.raises(CheckpointError, match="on disk"):
        load_checkpoint(str(tmp_path / "c"),
                        {"w": jnp.ones((4, 4)), "b": jnp.zeros((5,))})
    with pytest.raises(CheckpointError, match="on disk"):
        load_checkpoint(str(tmp_path / "c"),
                        {"w": jnp.ones((4, 4)),
                         "b": jnp.zeros((4,), jnp.int32)})


def test_latest_checkpoint_skips_torn_directory(tmp_path):
    base = tmp_path / "ck"
    for s in (1, 2):
        save_checkpoint(str(base / f"step_{s:08d}"), {"x": jnp.ones(3)},
                        step=s, codec="raw")
    (base / "step_00000003").mkdir()          # torn: no manifest.json
    (base / "step_00000003" / "leaf_00000.npy").write_bytes(b"junk")
    got = latest_checkpoint(str(base))
    assert got is not None and got.endswith("step_00000002")


def test_async_checkpointer_surfaces_worker_error(tmp_path):
    target = tmp_path / "ck"
    target.write_text("not a directory")      # worker's makedirs will fail
    ck = AsyncCheckpointer(str(target), codec="raw")
    ck.save(1, {"x": jnp.ones(3)})
    with pytest.raises(OSError):
        ck.wait()
    ck.close()


def test_async_checkpointer_gc_and_flush(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path / "ck"), keep=2, codec="raw")
    for s in range(1, 6):
        ck.save(s, {"x": jnp.full((4,), float(s))})
    ck.wait()                                 # every queued write durable
    assert ck.bytes_written > 0
    dirs = sorted(os.listdir(tmp_path / "ck"))
    assert dirs == ["step_00000004", "step_00000005"]
    arrs, _ = load_checkpoint_arrays(str(tmp_path / "ck" / dirs[-1]))
    np.testing.assert_array_equal(arrs["x"], np.full((4,), 5.0))
    ck.close()


# ---------------------------------------------------------------------------
# elastic replan / relabel / reshard
# ---------------------------------------------------------------------------

def test_replan_grow_shrink_noop():
    grow = replan_partitions(256, 6, 8)
    shrink = replan_partitions(256, 8, 6)
    noop = replan_partitions(256, 8, 8)
    for plan, w in ((grow, 8), (shrink, 6)):
        assert plan.owner.max() == w - 1
        counts = np.bincount(plan.owner)
        assert counts.max() - counts.min() <= 1
    assert noop.moved == 0
    # moved counts actual ownership changes, symmetric across directions
    assert grow.moved == int(np.sum(partition_owners(256, 6)
                                    != partition_owners(256, 8)))
    assert shrink.moved == grow.moved > 0


def test_resize_labels_grow_splits_shrink_merges():
    part = np.repeat(np.arange(4), 10).astype(np.int32)
    up = resize_labels(part, 8)
    assert sorted(np.unique(up)) == list(range(8))
    # grow refines: each new partition maps into exactly one old one
    assert len(np.unique(np.stack([part, up], 1), axis=0)) == 8
    down = resize_labels(part, 2)
    np.testing.assert_array_equal(down, part * 2 // 4)
    np.testing.assert_array_equal(resize_labels(part, 4), part)
    with pytest.raises(ValueError):
        resize_labels(part, 0)


def test_reshard_vertex_tree_roundtrip():
    rng = np.random.RandomState(0)
    n = 100
    old = np.repeat(np.arange(4), 25).astype(np.int32)
    new = resize_labels(old, 7)
    from repro.core.graph import _vertex_slots
    _, _, slot_o, Vp_o = _vertex_slots(old, n, 8)
    val = np.full((4, Vp_o), -1.0, np.float64)
    val[old, slot_o] = rng.rand(n)            # per-vertex payload
    leaves = {"v": val, "scalar": np.float64(3.0),
              "other": np.ones((4, 3))}       # wrong trailing dim: untouched
    out = reshard_vertex_tree(leaves, old, new, pad_multiple=8)
    _, _, slot_n, Vp_n = _vertex_slots(new, n, 8)
    np.testing.assert_array_equal(out["v"][new, slot_n], val[old, slot_o])
    assert out["scalar"] == 3.0
    np.testing.assert_array_equal(out["other"], leaves["other"])
    # round-trip back to the old layout restores values exactly
    back = reshard_vertex_tree({"v": out["v"]}, new, old, pad_multiple=8)
    np.testing.assert_array_equal(back["v"][old, slot_o], val[old, slot_o])


# ---------------------------------------------------------------------------
# .ghp resize + elastic resume
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kp", [12, 3])
def test_resize_ghp_builds_bit_identical(tmp_path, road, kp):
    _, edges, w, n, part = road
    src = str(tmp_path / "g.ghp")
    save_graph(src, edges, n, part, weights=w, positions=True)
    sg = resize_ghp(src, str(tmp_path / "g2.ghp"), kp)
    newpart = resize_labels(part, kp)
    np.testing.assert_array_equal(sg.part, newpart)
    assert graph_digest(build_from_sharded(sg)) == \
        graph_digest(build_partitioned_graph(edges, n, newpart, weights=w))


@pytest.mark.parametrize("kp", [9, 4])
def test_elastic_resume_reaches_same_fixed_point(tmp_path, road, kp):
    """Checkpoint at k=6, resize to k', resume: the min-plus fixed point is
    bit-identical to the uninterrupted k=6 run — grow AND shrink."""
    graph, edges, w, n, part = road
    ref = run_hybrid_ft(graph, SSSP(source=0))
    d = str(tmp_path / "ck")
    run_hybrid_ft(graph, SSSP(source=0), ckpt_dir=d, max_iters=3)
    newpart = resize_labels(part, kp)
    g2 = build_partitioned_graph(edges, n, newpart, weights=w)
    es, it = elastic_restore(os.path.join(d, "step_00000003"), g2,
                             SSSP(source=0), None, part, newpart)
    assert it == 3
    es = run_to_fixed_point(g2, SSSP(source=0), es)
    np.testing.assert_array_equal(unpack(g2, es, "dist"),
                                  unpack(graph, ref.es, "dist"))


def test_elastic_resume_rejects_sum_channels(tmp_path, road):
    graph, edges, w, n, part = road
    d = str(tmp_path / "ck")
    run_hybrid_ft(graph, SSSP(source=0), ckpt_dir=d, max_iters=2)
    newpart = resize_labels(part, 4)
    g2 = build_partitioned_graph(edges, n, newpart, weights=w)
    with pytest.raises(CheckpointError, match="monotone"):
        elastic_restore(os.path.join(d, "step_00000002"), g2,
                        IncrementalPageRank(tolerance=1e-4), None, part,
                        newpart)


def test_resize_cli_reshards_and_rekeys_checkpoint(tmp_path, road):
    """The full ``python -m repro.io.resize`` flow: resize the .ghp,
    re-shard the newest checkpoint, re-key it to the rebuilt graph's
    digest, resume elastically to the identical fixed point."""
    from repro.io.resize import main as resize_main
    graph, edges, w, n, part = road
    src, dst = str(tmp_path / "g.ghp"), str(tmp_path / "g12.ghp")
    save_graph(src, edges, n, part, weights=w, positions=True)
    ckd, ck2 = str(tmp_path / "ck"), str(tmp_path / "ck12")
    run_hybrid_ft(graph, SSSP(source=0), ckpt_dir=ckd, max_iters=3)
    assert resize_main([src, dst, "-k", "12", "--checkpoint", ckd,
                        "--checkpoint-out", ck2]) == 0
    g12 = build_from_sharded(load_graph(dst))
    es, it = elastic_restore(os.path.join(ck2, "step_00000003"), g12,
                             SSSP(source=0), None, part,
                             load_graph(dst).part,
                             expect_digest=graph_digest(g12))
    assert it == 3
    es = run_to_fixed_point(g12, SSSP(source=0), es)
    ref = run_hybrid_ft(graph, SSSP(source=0))
    np.testing.assert_array_equal(unpack(g12, es, "dist"),
                                  unpack(graph, ref.es, "dist"))
    # a second reshard of an already-elastic checkpoint is refused
    with pytest.raises(CheckpointError, match="already elastic"):
        resize_checkpoint(ck2, str(tmp_path / "ck3"), part,
                          load_graph(dst).part, "x")


# ---------------------------------------------------------------------------
# stragglers
# ---------------------------------------------------------------------------

def test_flag_slow_shards():
    counts = np.array([4, 5, 4, 16, 5, 4])
    flags = flag_slow_shards(counts, factor=1.5)
    assert [f.partition for f in flags] == [3]
    assert flags[0].cause == "straggler" and flags[0].ratio > 3
    skew = flag_slow_shards(counts, balance=2.0, factor=1.5)
    assert skew[0].cause == "skew"
    assert flag_slow_shards(np.array([3, 3, 3])) == []
    assert flag_slow_shards(np.zeros(0)) == []


def test_driver_surfaces_straggler_flags(road):
    graph = road[0]
    res = run_hybrid_ft(graph, SSSP(source=0), straggler_factor=0.01)
    # an absurdly low factor flags every above-median shard — the wiring
    # from Counters.pseudo_supersteps to the run result is what's pinned
    assert res.straggler_flags
    assert all(f.pseudo_supersteps > 0 for f in res.straggler_flags)
