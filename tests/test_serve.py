"""Graph-query serving layer: micro-batching, lane padding, compile-cache
behavior, monotonic request ids, straggler re-dispatch, streaming,
K-lane kill-and-resume through the executor's checkpoint hook."""

import os

import numpy as np
import pytest

from repro.checkpoint.ckpt import CheckpointError
from repro.core import run_hybrid
from repro.core.apps import SSSP
from repro.core.graph import build_partitioned_graph, unpack_vertex
from repro.data.graphs import rmat_graph
from repro.ft.straggler import StragglerMitigator
from repro.serve import ServeEngine
from repro.serve.engine import STATS_FILENAME


@pytest.fixture(scope="module")
def graph():
    edges, n = rmat_graph(128, avg_degree=5, seed=3)
    w = (np.abs(np.sin(np.arange(len(edges)))) * 0.9 + 0.05).astype(
        np.float32)
    return build_partitioned_graph(edges, n, "hash", weights=w,
                                   n_partitions=4), n


@pytest.fixture(scope="module")
def engine(graph):
    # single lane width: every batch pads to 4 lanes, so the whole module
    # shares ONE compiled (sssp, 4) executable
    return ServeEngine(graph[0], lane_widths=(4,))


def test_request_ids_monotonic_across_rounds(engine, graph):
    """Regression: ids came from len(queue), so they collided after the
    queue drained and refilled.  Two submit/run rounds must hand out
    strictly increasing ids and both rounds must answer correctly."""
    g, n = graph
    r1 = [engine.submit("sssp", s) for s in (0, 17, 99)]
    done1 = engine.run()
    r2 = [engine.submit("sssp", s) for s in (5, 0)]
    done2 = engine.run()
    ids = [q.request_id for q in r1 + r2]
    assert ids == sorted(ids) and len(set(ids)) == len(ids)
    assert all(q.done for q in done1 + done2)
    # both rounds produce the single-source fixed points
    es, _ = run_hybrid(g, SSSP(source=0))
    ref0 = np.asarray(unpack_vertex(g, es.state["dist"]))
    np.testing.assert_array_equal(r1[0].result, ref0)
    np.testing.assert_array_equal(r2[1].result, ref0)


def test_one_compile_per_program_width(engine, graph):
    """Batches of 1..4 queries all pad to the fixed lane width, so every
    dispatch so far reused one (program, K) compile."""
    q = engine.submit("sssp", 42)
    engine.run()
    assert q.done
    assert sum(engine.trace_counts.values()) == 1, engine.trace_counts
    assert list(engine.trace_counts) == [(("sssp", ()), 4)]


def test_padded_solo_query_matches_batched(engine):
    """A solo query (padded 1 -> 4 lanes) returns the same answer as the
    same source served inside a full batch."""
    a = engine.submit("sssp", 17)
    engine.run()
    batch = [engine.submit("sssp", s) for s in (3, 17, 60, 2)]
    engine.run()
    np.testing.assert_array_equal(a.result, batch[1].result)


def test_mixed_programs_split_batches(engine):
    """sssp and reach queries never share a lane dispatch; reach is the
    boolean view of the sssp fixed point."""
    d = engine.submit("sssp", 0)
    r = engine.submit("reach", 0)
    engine.run()
    assert r.result.dtype == bool
    np.testing.assert_array_equal(r.result, np.isfinite(d.result))


def test_stream_yields_lanes_as_they_converge(engine, graph):
    """Host-stepped mode: queries complete at their own lane's convergence
    iteration, not the batch's; results match full-run dispatch."""
    g, n = graph
    qs = [engine.submit("sssp", s) for s in (0, n - 1, 17)]
    got = list(engine.stream())
    assert {q.request_id for q in got} == {q.request_id for q in qs}
    iters = [q.iterations for q in got]
    assert iters == sorted(iters)            # yielded in convergence order
    for q in got:
        es, _ = run_hybrid(g, SSSP(source=q.source))
        np.testing.assert_array_equal(
            q.result, np.asarray(unpack_vertex(g, es.state["dist"])))


def test_unknown_program_rejected(engine):
    with pytest.raises(KeyError):
        engine.submit("pagerankk", 0)


def test_straggler_redispatch_and_duplicate_suppression(graph):
    """Deadline re-dispatch state machine with a fake clock: attempt 0
    straggles past the deadline, attempt 1's result wins, and a late
    completion of the same work id is suppressed."""
    g, _ = graph
    t = [0.0]
    sentinel = object()
    attempts = []

    def dispatch(eng, key, k, sources, attempt):
        attempts.append(attempt)
        if attempt == 0:
            t[0] = 10.0                      # blow through the deadline
            return None
        return sentinel

    mit = StragglerMitigator(clock=lambda: t[0], min_deadline=1.0)
    eng = ServeEngine(g, straggler=mit, dispatch_fn=dispatch)
    out = eng._dispatch_mitigated(("sssp", ()), 4, None)
    assert out is sentinel and attempts == [0, 1]
    assert mit.redispatches == 1
    assert mit.complete(0) is False          # first result already won
    assert mit.duplicates_suppressed == 1


def test_straggler_no_result_before_deadline_raises(graph):
    g, _ = graph
    mit = StragglerMitigator(clock=lambda: 0.0, min_deadline=100.0)
    eng = ServeEngine(g, straggler=mit, dispatch_fn=lambda *a: None)
    with pytest.raises(RuntimeError, match="deadline"):
        eng._dispatch_mitigated(("sssp", ()), 4, None)


# ---------------------------------------------------------------------------
# K-lane kill-and-resume (executor checkpoint hook)
# ---------------------------------------------------------------------------

class _Killed(RuntimeError):
    pass


def test_klane_kill_and_resume_bit_identical(graph, tmp_path):
    """Kill a checkpointed K-lane batch mid-flight, resume it from the
    (program, K, sources-digest) checkpoint family in a fresh engine:
    per-lane results are bit-identical to the uninterrupted run, the
    already-converged lane is recorded as dropped from the restored
    frontier, and the resume re-enters past iteration 0."""
    g, n = graph
    srcs = (0, 17, 99, n - 1)       # lane n-1 converges at iteration 1,
    kill_at = 4                     # lanes 0/17 at 5, lane 99 at 7

    ref_eng = ServeEngine(g, lane_widths=(4,))
    refs = [ref_eng.submit("sssp", s) for s in srcs]
    ref_eng.run()

    ckdir = str(tmp_path / "serve_ck")

    def killer(eng, prog, K, iteration):
        if iteration == kill_at:
            raise _Killed(f"injected kill at iteration {iteration}")

    eng = ServeEngine(g, lane_widths=(4,), ckpt_dir=ckdir,
                      on_iteration=killer)
    qs = [eng.submit("sssp", s) for s in srcs]
    with pytest.raises(_Killed):
        eng.run()
    assert not any(q.done for q in qs)
    fams = [f for f in os.listdir(ckdir) if f != STATS_FILENAME]
    assert len(fams) == 1 and fams[0].startswith("sssp_K4_")
    # the kill raised before iteration 4's save: latest durable is 3
    assert any(d.endswith("step_00000003")
               for d in os.listdir(os.path.join(ckdir, fams[0])))

    eng2 = ServeEngine(g, lane_widths=(4,), ckpt_dir=ckdir)
    qs2 = [eng2.submit("sssp", s) for s in srcs]
    done = eng2.run()
    assert all(q.done for q in done)

    [ev] = eng2.resume_events
    assert ev.program == "sssp" and ev.lanes == 4
    assert ev.iteration == kill_at - 1       # resumed past iteration 0
    assert ev.path.endswith("step_00000003")
    # lane n-1 had converged before the checkpoint -> dropped; others not
    assert ev.lanes_done == (False, False, False, True)

    for q_ref, q2 in zip(refs, qs2):
        np.testing.assert_array_equal(q_ref.result, q2.result)
    # batch completed -> its checkpoint family is deleted (only the serving
    # statistics registry persists beside where the family lived)
    assert [f for f in os.listdir(ckdir) if f != STATS_FILENAME] == []


def test_serving_stats_histograms_persisted(graph, tmp_path):
    """The engine records per-program inter-arrival and batch-size
    histograms and persists the registry beside its checkpoint/cache state;
    the file reads back through ``repro.obs.metrics``."""
    from repro.obs import clock as obs_clock
    from repro.obs.metrics import load_registry

    g, _ = graph
    sdir = str(tmp_path / "stats")
    with obs_clock.fake() as fc:
        eng = ServeEngine(g, lane_widths=(4,), stats_dir=sdir)
        for i, s in enumerate((0, 17, 99)):
            fc.advance(0.25 * (i + 1))
            eng.submit("sssp", s)
        eng.run()
    assert eng.stats_path == os.path.join(sdir, STATS_FILENAME)

    reg = load_registry(eng.stats_path)
    h = reg.histogram("serve.arrival_seconds.sssp")
    assert h.count == 2                      # 3 submits -> 2 gaps
    assert abs(h.sum - 1.25) < 1e-9 and abs(h.max - 0.75) < 1e-9
    b = reg.histogram("serve.batch_size.sssp")
    assert b.count == 1 and b.max == 3.0     # one dispatched batch of 3
    assert reg.value("serve.compiles.sssp.K4") == 1.0


def test_klane_resume_requires_monotone(graph, tmp_path):
    """Non-monotone (sum-combiner) programs are refused by the shared
    executor gate before any checkpointed dispatch starts."""
    g, _ = graph
    eng = ServeEngine(g, lane_widths=(4,),
                      ckpt_dir=str(tmp_path / "ppr_ck"))
    eng.submit("ppr", 0)
    with pytest.raises(CheckpointError, match="min/max-combiner"):
        eng.run()
