"""K-lane multi-query programs: lane j of one K-lane run must be
bit-identical to the corresponding single-source run (the contract the
serving layer's micro-batching rests on).

Kept hypothesis-free so the whole file runs in minimal environments; the
property-test sweep over kernel shapes lives in test_kernels.py.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import run_hybrid
from repro.core.apps import (MultiSourceMonotone, PersonalizedPageRank, SSSP,
                             WidestPath, reachable)
from repro.core.apps.pagerank import pagerank_edge_weights
from repro.core.graph import build_partitioned_graph, unpack_vertex
from repro.data.graphs import rmat_graph


@pytest.fixture(scope="module")
def graph():
    edges, n = rmat_graph(128, avg_degree=5, seed=3)
    w = (np.abs(np.sin(np.arange(len(edges)))) * 0.9 + 0.05).astype(
        np.float32)
    return build_partitioned_graph(edges, n, "hash", weights=w,
                                   n_partitions=4), n


def test_multisource_sssp_lanes_bitexact_fused(graph):
    """Staggered sources (hub, tail vertex, mid) through the fused kernel
    path, sources passed per-run via vdata (the serving contract): every
    lane equals the single-source SSSP fixed point bit-for-bit, even
    though the lanes converge at different iterations."""
    g, n = graph
    srcs = [0, n - 1, 17]
    prog = MultiSourceMonotone(lanes=len(srcs), semiring="min_add")
    es, _ = run_hybrid(g, prog, vdata={"sources": jnp.asarray(srcs,
                                                              jnp.int32)})
    lanes = np.asarray(unpack_vertex(g, es.state["val"]))
    assert lanes.shape == (n, len(srcs))
    for j, s in enumerate(srcs):
        es1, _ = run_hybrid(g, SSSP(source=s))
        np.testing.assert_array_equal(
            lanes[:, j], np.asarray(unpack_vertex(g, es1.state["dist"])))
    # reachability is a view of the same fixed point
    assert reachable(lanes).dtype == bool
    assert bool(reachable(lanes)[srcs[0], 0])


def test_multisource_max_min_lanes_bitexact_dense(graph):
    """max_min (widest path) lanes on the generic dense path (use_ell=False)
    match single-source WidestPath runs — the lane axis is engine-wide,
    not a kernel-only feature."""
    g, n = graph
    srcs = [0, 42]
    prog = MultiSourceMonotone(srcs, semiring="max_min")
    es, _ = run_hybrid(g, prog, use_ell=False)
    lanes = np.asarray(unpack_vertex(g, es.state["val"]))
    for j, s in enumerate(srcs):
        es1, _ = run_hybrid(g, WidestPath(source=s), use_ell=False)
        np.testing.assert_array_equal(
            lanes[:, j], np.asarray(unpack_vertex(g, es1.state["cap"])))


def test_ppr_lanes_bitexact_fused():
    """Personalized PageRank lanes through the fused pr_step kernel are
    bit-identical to single-seed runs (the kernel folds the slice axis
    sequentially, so a lane column reduces in the same order as the
    single-frontier dispatch)."""
    edges, n = rmat_graph(128, avg_degree=5, seed=3)
    w = pagerank_edge_weights(edges, n)
    g = build_partitioned_graph(edges, n, "hash", weights=w, n_partitions=4)
    seeds = [7, 90]
    es, _ = run_hybrid(g, PersonalizedPageRank(seeds))
    lanes = np.asarray(unpack_vertex(g, es.state["rank"]))
    for j, s in enumerate(seeds):
        es1, _ = run_hybrid(g, PersonalizedPageRank([s]))
        np.testing.assert_array_equal(
            lanes[:, j], np.asarray(unpack_vertex(g, es1.state["rank"]))[:, 0])
    # all teleport mass sits at the lane's own seed
    assert lanes[seeds[0], 0] > 0 and lanes[seeds[1], 1] > 0


def test_constructor_validation():
    with pytest.raises(ValueError):
        MultiSourceMonotone([0], semiring="add_mul")   # not monotone
    with pytest.raises(ValueError):
        MultiSourceMonotone()                          # no sources, no lanes
    with pytest.raises(ValueError):
        PersonalizedPageRank()
    assert MultiSourceMonotone(lanes=4).lanes == 4
    assert PersonalizedPageRank(lanes=2).channels[0].lanes == 2
