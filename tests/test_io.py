"""Graph I/O subsystem tests.

  * ``save_graph``/``load_graph`` round-trip: arbitrary random edge lists
    (both int dtypes, weighted and not) reproduce the original edge array
    exactly through the sharded ``.ghp`` format — via a seeded sweep
    always, and a hypothesis property test when hypothesis is installed;
  * chunk-size invariance: ``build_partitioned_graph_from_path`` is
    bit-identical to the in-memory ``build_partitioned_graph`` for every
    partitioner name and wildly different chunk sizes (the acceptance bar
    of the out-of-core pipeline), including ELL layouts and spill bins;
  * truncated / corrupt / inconsistent ``meta.json`` and shard files
    raise :class:`GraphFormatError` instead of building a wrong graph;
  * the chunked gzip text reader parses SNAP-style files (comments,
    optional weight column) identically across chunk boundaries;
  * the external-CSR fennel path labels exactly like the in-memory one,
    and the blocked scorer is deterministic per seed;
  * the checked-in ``tests/data/web_toy.tsv.gz`` fixture converts
    end-to-end (the same flow CI drives through the convert CLI).
"""

import gzip
import importlib.util
import json
import os
import shutil

import numpy as np
import pytest

from repro.core import build_partitioned_graph, run_hybrid
from repro.core.apps import SSSP
from repro.core.graph import unpack_vertex
from repro.data.graphs import grid_graph, materialize, rmat_graph
from repro.io import (ArrayEdgeSource, GraphFormatError, TextEdgeSource,
                      build_partitioned_graph_from_path, graph_digest,
                      load_graph, open_edge_source, save_graph)
from repro.io.pipeline import degree_pass, external_undirected_csr
from repro.io.stage import stage_arrays, stage_edges
from repro.partition import (PARTITIONERS, fennel_partition,
                             fennel_partition_csr, make_partition)

HAVE_HYPOTHESIS = importlib.util.find_spec("hypothesis") is not None
FIXTURE = os.path.join(os.path.dirname(__file__), "data", "web_toy.tsv.gz")


def _random_graph(seed, weighted=True, dtype=np.int64):
    rng = np.random.RandomState(seed)
    n = int(rng.randint(5, 40))
    m = int(rng.randint(n, 4 * n))
    edges = rng.randint(0, n, size=(m, 2))
    edges = edges[edges[:, 0] != edges[:, 1]]
    if len(edges) == 0:
        edges = np.array([[0, 1]])
    edges = np.unique(edges, axis=0).astype(dtype)
    w = (rng.uniform(0.1, 5.0, len(edges)).astype(np.float32)
         if weighted else None)
    return edges, n, w


# ---------------------------------------------------------------------------
# save/load round trip
# ---------------------------------------------------------------------------

def _check_roundtrip(tmp_path, edges, n, w, k, seed):
    part = make_partition("hash", edges, n, k, seed=seed)
    path = os.path.join(tmp_path, "g.ghp")
    shutil.rmtree(path, ignore_errors=True)
    sg = save_graph(path, edges, n, part, weights=w)
    lg = load_graph(path)
    assert lg.n_vertices == n and lg.n_edges == len(edges)
    assert np.array_equal(lg.part, part)
    got_e, got_w = lg.edges()
    assert got_e.dtype == edges.dtype
    np.testing.assert_array_equal(got_e, edges)
    if w is None:
        assert got_w is None
    else:
        np.testing.assert_array_equal(got_w, w)
    # each shard holds exactly its partition's in-edges, in original order
    for p in range(lg.n_partitions):
        se, _, pos = lg.shard(p)
        sel = part[edges[:, 1]] == p
        np.testing.assert_array_equal(np.asarray(se), edges[sel])
        np.testing.assert_array_equal(np.asarray(pos), np.nonzero(sel)[0])


@pytest.mark.parametrize("dtype", [np.int64, np.int32])
@pytest.mark.parametrize("seed", [0, 7, 1234])
def test_roundtrip_seeded_sweep(tmp_path, dtype, seed):
    edges, n, w = _random_graph(seed, weighted=seed % 2 == 0, dtype=dtype)
    _check_roundtrip(str(tmp_path), edges, n, w, k=3 + seed % 3,
                     seed=seed % 17)


if HAVE_HYPOTHESIS:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2**16), dtype=st.sampled_from([np.int64,
                                                              np.int32]),
           weighted=st.booleans(), k=st.integers(1, 6))
    def test_roundtrip_any_graph(tmp_path_factory, seed, dtype, weighted, k):
        tmp = tmp_path_factory.mktemp("ghp")
        edges, n, w = _random_graph(seed, weighted=weighted, dtype=dtype)
        _check_roundtrip(str(tmp), edges, n, w, k=k, seed=seed % 97)


# ---------------------------------------------------------------------------
# chunk-size invariance: out-of-core build == in-memory build, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pname", sorted(PARTITIONERS))
@pytest.mark.parametrize("pad_multiple", [8, 4])
@pytest.mark.parametrize("edge_blocks", [1, 4])
def test_from_path_bitexact_per_partitioner(tmp_path, pname, pad_multiple,
                                            edge_blocks):
    """The builder parity sweep: every partitioner × chunk size ×
    ``pad_multiple`` × ``edge_blocks`` — the out-of-core build matches the
    in-memory one bit for bit under both the fully-ragged (B=1) and the
    legacy padded (B=P) edge layouts, at every padding granularity."""
    edges, n = rmat_graph(260, avg_degree=5, seed=2)
    w = np.random.RandomState(1).uniform(0.5, 2.0,
                                         len(edges)).astype(np.float32)
    staged = str(tmp_path / "staged")
    stage_arrays(staged, edges, weights=w, n_vertices=n)
    ref = graph_digest(build_partitioned_graph(
        edges, n, pname, weights=w, n_partitions=4, partition_seed=0,
        pad_multiple=pad_multiple, edge_blocks=edge_blocks))
    for chunk in (11, 97, 1 << 20):
        g = build_partitioned_graph_from_path(staged, pname, 4,
                                              chunk_edges=chunk,
                                              partition_seed=0,
                                              pad_multiple=pad_multiple,
                                              edge_blocks=edge_blocks)
        assert graph_digest(g) == ref, f"{pname} chunk={chunk}"


def test_from_path_bitexact_with_spill_bins(tmp_path):
    """Hub-heavy graph with a tiny base bin: the sliced-ELL spill bins of
    the out-of-core build must match the in-memory ones exactly too."""
    rng = np.random.RandomState(4)
    hub = np.concatenate([
        np.stack([rng.randint(0, 150, 700), np.full(700, 3)], axis=1),
        rng.randint(0, 150, (350, 2))])
    hub = np.unique(hub[hub[:, 0] != hub[:, 1]].astype(np.int64), axis=0)
    staged = str(tmp_path / "staged")
    stage_arrays(staged, hub, n_vertices=150)
    ref = build_partitioned_graph(hub, 150, "fennel", n_partitions=3,
                                  ell_base_slices=8)
    assert len(ref.local_ell) > 1            # binning actually engaged
    g = build_partitioned_graph_from_path(staged, "fennel", 3,
                                          chunk_edges=64, ell_base_slices=8)
    assert graph_digest(g) == graph_digest(ref)


# ---------------------------------------------------------------------------
# ragged (B=1) == padded (B=P) after masking, ELL/spill bins included
# ---------------------------------------------------------------------------

def _goff(g, p):
    """Partition p's block-relative flat group offset (0 under B=P)."""
    ppb = g.n_partitions // g.n_blocks
    return sum(g.gp_by_p[(p // ppb) * ppb:p])


def _bin_rows_by_p(g, s):
    """One ELL bin's valid rows split per partition: (local row, idx, val,
    msk, group-unoffset) in span order — the layout-independent content."""
    rows, idx = np.asarray(s.rows), np.asarray(s.idx)
    val, msk, grp = np.asarray(s.val), np.asarray(s.msk), np.asarray(s.grp)
    B = rows.shape[0]
    ppb = g.n_partitions // B
    out = []
    for p in range(g.n_partitions):
        b, pr = p // ppb, p % ppb
        sel = (rows[b] >= pr * g.vp) & (rows[b] < (pr + 1) * g.vp)
        gv = np.where(msk[b][sel], grp[b][sel] - _goff(g, p), 0)
        out.append((rows[b][sel] - pr * g.vp, idx[b][sel], val[b][sel],
                    msk[b][sel], gv))
    return out


def _assert_ragged_equals_padded(gr, gp):
    """Bit-equality of the B=1 (ragged) and B=P (padded) builds once the
    layout is unwound: identical per-partition spans in every edge/group
    family and every ELL bin, identical flat host views."""
    P, Vp = gr.n_partitions, gr.vp
    assert gr.n_blocks == 1 and gp.n_blocks == P
    assert gr.ep_by_p == gp.ep_by_p and gr.gp_by_p == gp.gp_by_p
    for f in ("vertex_gid", "vertex_mask", "is_boundary", "out_degree",
              "export_slot", "export_mask", "export_fanout", "halo_ptr",
              "halo_mask"):
        np.testing.assert_array_equal(np.asarray(getattr(gr, f)),
                                      np.asarray(getattr(gp, f)),
                                      err_msg=f)
    for p in range(P):
        (br, sr), (bp, sp) = gr.edge_span(p), gp.edge_span(p)
        for f in ("edge_src", "edge_dst", "edge_w", "edge_mask",
                  "edge_local", "edge_src_gid", "edge_dst_gid"):
            np.testing.assert_array_equal(
                np.asarray(getattr(gr, f))[br, sr],
                np.asarray(getattr(gp, f))[bp, sp], err_msg=f"{f} p={p}")
        m = np.asarray(gr.edge_mask)[br, sr]
        np.testing.assert_array_equal(
            np.where(m, np.asarray(gr.edge_group)[br, sr] - _goff(gr, p), 0),
            np.where(m, np.asarray(gp.edge_group)[bp, sp] - _goff(gp, p), 0),
            err_msg=f"edge_group p={p}")
        (br, sr), (bp, sp) = gr.group_span(p), gp.group_span(p)
        for f in ("group_remote", "group_mask"):
            np.testing.assert_array_equal(
                np.asarray(getattr(gr, f))[br, sr],
                np.asarray(getattr(gp, f))[bp, sp], err_msg=f"{f} p={p}")
    for side in ("local_ell", "remote_ell"):
        bins_r, bins_p = getattr(gr, side), getattr(gp, side)
        assert len(bins_r) == len(bins_p), side
        for sr_, sp_ in zip(bins_r, bins_p):
            assert (sr_.kb, sr_.lo, sr_.dense, sr_.stride,
                    sr_.payload_bound) == \
                (sp_.kb, sp_.lo, sp_.dense, sp_.stride, sp_.payload_bound)
            for pr, pp in zip(_bin_rows_by_p(gr, sr_),
                              _bin_rows_by_p(gp, sp_)):
                for a, b in zip(pr, pp):
                    np.testing.assert_array_equal(a, b, err_msg=side)
            # the absolute flat host views agree entry for entry
            fr, fp = np.asarray(sr_.flat_rows), np.asarray(sp_.flat_rows)
            vr, vp_ = fr < P * Vp, fp < P * Vp
            np.testing.assert_array_equal(fr[vr], fp[vp_], err_msg=side)
            np.testing.assert_array_equal(
                np.asarray(sr_.flat_idx)[vr],
                np.asarray(sp_.flat_idx)[vp_], err_msg=side)


def _skewed_random_graph(seed, n=170):
    """Hub-skewed digraph: enough in-degree spread that ell_base_slices=8
    spills extra bins, so the parity check covers them."""
    rng = np.random.RandomState(seed)
    hubs = np.stack([rng.randint(0, n, 600), rng.randint(0, 4, 600)],
                    axis=1)
    edges = np.concatenate([hubs, rng.randint(0, n, (300, 2))])
    edges = np.unique(edges[edges[:, 0] != edges[:, 1]].astype(np.int64),
                      axis=0)
    w = rng.uniform(0.5, 3.0, len(edges)).astype(np.float32)
    return edges, n, w


def _check_ragged_padded_parity(tmp, pname, seed, chunks=(23, 1 << 20)):
    edges, n, w = _skewed_random_graph(seed)
    gr = build_partitioned_graph(edges, n, pname, weights=w,
                                 n_partitions=4, partition_seed=seed,
                                 ell_base_slices=8)
    gp = build_partitioned_graph(edges, n, pname, weights=w,
                                 n_partitions=4, partition_seed=seed,
                                 ell_base_slices=8, edge_blocks=4)
    assert len(gr.local_ell) > 1 or len(gr.remote_ell) > 1
    _assert_ragged_equals_padded(gr, gp)
    # both layouts, out-of-core, every chunk size: bit-identical digests
    staged = os.path.join(tmp, "staged")
    shutil.rmtree(staged, ignore_errors=True)
    stage_arrays(staged, edges, weights=w, n_vertices=n)
    for blocks, ref in ((1, gr), (4, gp)):
        for chunk in chunks:
            g = build_partitioned_graph_from_path(
                staged, pname, 4, chunk_edges=chunk, partition_seed=seed,
                ell_base_slices=8, edge_blocks=blocks)
            assert graph_digest(g) == graph_digest(ref), (blocks, chunk)


@pytest.mark.parametrize("pname", sorted(PARTITIONERS))
def test_ragged_equals_padded_seeded_sweep(tmp_path, pname):
    _check_ragged_padded_parity(str(tmp_path), pname, seed=3)


if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**16),
           pname=st.sampled_from(sorted(PARTITIONERS)))
    def test_ragged_equals_padded_any_graph(tmp_path_factory, seed, pname):
        tmp = tmp_path_factory.mktemp("ragged")
        _check_ragged_padded_parity(str(tmp), pname, seed=seed,
                                    chunks=(37,))


def test_from_path_runs_the_engine(tmp_path):
    """The out-of-core graph is not just byte-equal — it runs."""
    edges, w, n = grid_graph(6, 18, seed=0)
    staged = str(tmp_path / "staged")
    stage_arrays(staged, edges, weights=w, n_vertices=n)
    g = build_partitioned_graph_from_path(staged, "bfs", 3)
    g_ref = build_partitioned_graph(edges, n, "bfs", weights=w,
                                    n_partitions=3)
    es, it = run_hybrid(g, SSSP(source=0))
    es_ref, it_ref = run_hybrid(g_ref, SSSP(source=0))
    assert it == it_ref
    np.testing.assert_array_equal(unpack_vertex(g, es.state["dist"]),
                                  unpack_vertex(g_ref,
                                                es_ref.state["dist"]))


def test_from_path_ghp_input_and_ghp_out(tmp_path):
    edges, n = rmat_graph(150, avg_degree=4, seed=6)
    part = make_partition("multilevel", edges, n, 3, seed=0)
    ghp = str(tmp_path / "g.ghp")
    save_graph(ghp, edges, n, part)
    ref = graph_digest(build_partitioned_graph(edges, n, part))
    assert graph_digest(build_partitioned_graph_from_path(ghp)) == ref
    with pytest.raises(ValueError):
        build_partitioned_graph_from_path(ghp, "hash", 3)
    # ghp_out keeps the sharded intermediate, and it rebuilds identically
    staged = str(tmp_path / "staged")
    stage_arrays(staged, edges, n_vertices=n)
    kept = str(tmp_path / "kept.ghp")
    g = build_partitioned_graph_from_path(staged, part, ghp_out=kept)
    assert graph_digest(g) == ref
    assert graph_digest(build_partitioned_graph_from_path(kept)) == ref


# ---------------------------------------------------------------------------
# corrupt / truncated metadata error paths
# ---------------------------------------------------------------------------

@pytest.fixture
def ghp_dir(tmp_path):
    edges, n = rmat_graph(80, avg_degree=4, seed=9)
    path = str(tmp_path / "g.ghp")
    save_graph(path, edges, n, make_partition("hash", edges, n, 3))
    return path


def _rewrite_meta(path, fn):
    mp = os.path.join(path, "meta.json")
    with open(mp) as f:
        meta = json.load(f)
    out = fn(meta)
    with open(mp, "w") as f:
        f.write(out if isinstance(out, str) else json.dumps(out))


def test_missing_meta(ghp_dir):
    os.remove(os.path.join(ghp_dir, "meta.json"))
    with pytest.raises(GraphFormatError, match="missing"):
        load_graph(ghp_dir)


def test_truncated_meta(ghp_dir):
    raw = open(os.path.join(ghp_dir, "meta.json")).read()
    _rewrite_meta(ghp_dir, lambda m: raw[: len(raw) // 2])
    with pytest.raises(GraphFormatError, match="corrupt or truncated"):
        load_graph(ghp_dir)


def test_wrong_format_tag(ghp_dir):
    _rewrite_meta(ghp_dir, lambda m: {**m, "format": "parquet"})
    with pytest.raises(GraphFormatError, match="format tag"):
        load_graph(ghp_dir)


def test_unsupported_version(ghp_dir):
    _rewrite_meta(ghp_dir, lambda m: {**m, "version": 99})
    with pytest.raises(GraphFormatError, match="version"):
        load_graph(ghp_dir)


def test_old_version_error_names_both_versions(ghp_dir):
    """A v1 directory (pre block-ragged builds) must refuse to load with
    an error naming the file's version and the supported one — not fail
    deep in the builder."""
    from repro.io.format import GHP_VERSION
    assert GHP_VERSION == 2
    _rewrite_meta(ghp_dir, lambda m: {**m, "version": 1})
    with pytest.raises(GraphFormatError,
                       match=r"unsupported version 1 \(have 2\)"):
        load_graph(ghp_dir)


def test_missing_meta_keys(ghp_dir):
    _rewrite_meta(ghp_dir, lambda m: {k: v for k, v in m.items()
                                      if k != "n_edges"})
    with pytest.raises(GraphFormatError, match="missing keys"):
        load_graph(ghp_dir)


def test_shard_range_sum_mismatch(ghp_dir):
    def bump(m):
        m["shards"][0]["n_edges"] += 1
        return m
    _rewrite_meta(ghp_dir, bump)
    with pytest.raises(GraphFormatError, match="shard ranges sum"):
        load_graph(ghp_dir)


def test_missing_shard_file(ghp_dir):
    os.remove(os.path.join(ghp_dir, "shards", "part00001.edges.npy"))
    with pytest.raises(GraphFormatError, match="shard file missing"):
        load_graph(ghp_dir).shard(1)


def test_shard_shape_mismatch(ghp_dir):
    p = os.path.join(ghp_dir, "shards", "part00000.edges.npy")
    arr = np.load(p)
    np.save(p, arr[:-1])
    with pytest.raises(GraphFormatError, match="meta says"):
        load_graph(ghp_dir).shard(0)


def test_part_length_mismatch(ghp_dir):
    part = np.load(os.path.join(ghp_dir, "part.npy"))
    np.save(os.path.join(ghp_dir, "part.npy"), part[:-2])
    with pytest.raises(GraphFormatError, match="meta says"):
        load_graph(ghp_dir)


def test_out_of_range_ids_rejected(tmp_path):
    """Ids the target dtype cannot hold — and negative ids, which would
    wrap part[]/slot_of[] lookups into a wrong graph — fail loudly."""
    big = np.array([[0, 2**31 + 5], [1, 0]], dtype=np.int64)
    with pytest.raises(GraphFormatError, match="does not fit"):
        stage_arrays(str(tmp_path / "s"), big, dtype=np.int32)
    neg = np.array([[0, 1], [1, -3]], dtype=np.int64)
    with pytest.raises(GraphFormatError, match="negative vertex id"):
        save_graph(str(tmp_path / "g.ghp"), neg, 5, np.zeros(5, np.int32))


# ---------------------------------------------------------------------------
# chunked text reader
# ---------------------------------------------------------------------------

def test_text_reader_chunks_and_comments(tmp_path):
    edges, n = rmat_graph(60, avg_degree=3, seed=5)
    p = str(tmp_path / "plain.tsv")
    with open(p, "w") as f:
        f.write("# header\n\n")
        for i, (a, b) in enumerate(edges):
            f.write(f"{a}\t{b}\n")
            if i % 17 == 0:
                f.write("# interleaved comment\n")
    for chunk in (3, 29, 10000):
        src = TextEdgeSource(p, chunk_edges=chunk)
        got = np.concatenate([c for c, w in src.chunks()])
        assert src.weighted is False
        np.testing.assert_array_equal(got, edges)


def test_text_reader_weights_and_gzip(tmp_path):
    p = str(tmp_path / "w.tsv.gz")
    with gzip.open(p, "wt") as f:
        f.write("0 1 0.5\n1 2 1.25\n2 0 3.0\n")
    src = open_edge_source(p, 2)
    chunks = list(src.chunks())
    assert src.weighted is True
    e = np.concatenate([c for c, _ in chunks])
    w = np.concatenate([x for _, x in chunks])
    np.testing.assert_array_equal(e, [[0, 1], [1, 2], [2, 0]])
    np.testing.assert_allclose(w, [0.5, 1.25, 3.0])


def test_text_reader_bad_columns(tmp_path):
    p = str(tmp_path / "bad.tsv")
    with open(p, "w") as f:
        f.write("0 1 2 3\n")
    with pytest.raises(ValueError, match="2 or 3 columns"):
        list(TextEdgeSource(p).chunks())


def test_fixture_parses_and_converts(tmp_path):
    """The checked-in gz fixture (what CI feeds the convert CLI)."""
    src = open_edge_source(FIXTURE, 64)
    nv, ne, out_deg, in_deg = degree_pass(src)
    assert ne == 270 and nv == 94 and src.weighted
    assert int(out_deg.sum()) == ne == int(in_deg.sum())
    g = build_partitioned_graph_from_path(FIXTURE, "fennel", 4,
                                          chunk_edges=37)
    e = np.concatenate([c for c, _ in src.chunks()])
    w = np.concatenate([x for _, x in src.chunks()])
    ref = build_partitioned_graph(e, nv, "fennel", weights=w,
                                  n_partitions=4)
    assert graph_digest(g) == graph_digest(ref)


# ---------------------------------------------------------------------------
# external CSR + blocked fennel
# ---------------------------------------------------------------------------

def test_external_csr_fennel_matches_inmemory(tmp_path):
    edges, n = rmat_graph(500, avg_degree=6, seed=3)
    src = ArrayEdgeSource(edges, n_vertices=n, chunk_edges=83)
    _, _, out_deg, in_deg = degree_pass(src)
    starts, adj = external_undirected_csr(src, n, out_deg + in_deg,
                                          str(tmp_path))
    for seed in (0, 5):
        a = fennel_partition(edges, n, 4, seed=seed)
        b = fennel_partition_csr(starts, adj, n, 4, n_edges=len(edges),
                                 seed=seed)
        np.testing.assert_array_equal(a, b)


def test_fennel_blocked_deterministic_and_block_invariant():
    edges, n = rmat_graph(400, avg_degree=5, seed=8)
    from repro.partition.seed import undirected_csr
    starts, adj = undirected_csr(edges, n)
    base = fennel_partition_csr(starts, adj, n, 5, n_edges=len(edges),
                                seed=2)
    for block in (1, 37, 100000):
        got = fennel_partition_csr(starts, adj, n, 5, n_edges=len(edges),
                                   seed=2, block=block)
        np.testing.assert_array_equal(got, base)


# ---------------------------------------------------------------------------
# staging / materialize
# ---------------------------------------------------------------------------

def test_materialize_then_build(tmp_path):
    staged = materialize(str(tmp_path / "m"), "rmat", n=300, avg_degree=4,
                         seed=1)
    edges, n = rmat_graph(300, avg_degree=4, seed=1)
    assert staged.n_edges == len(edges) and staged.n_vertices == n
    got = np.concatenate([c for c, _ in staged.chunks()])
    np.testing.assert_array_equal(got, edges)
    g = build_partitioned_graph_from_path(str(tmp_path / "m"), "hash", 3)
    ref = build_partitioned_graph(edges, n, "hash", n_partitions=3)
    assert graph_digest(g) == graph_digest(ref)


def test_stage_edges_from_text(tmp_path):
    staged = stage_edges(open_edge_source(FIXTURE, 50),
                         str(tmp_path / "st"))
    src = open_edge_source(FIXTURE, 1 << 20)
    e = np.concatenate([c for c, _ in src.chunks()])
    w = np.concatenate([x for _, x in src.chunks()])
    got_e = np.concatenate([c for c, _ in staged.chunks()])
    got_w = np.concatenate([x for _, x in staged.chunks()])
    np.testing.assert_array_equal(got_e, e)
    np.testing.assert_array_equal(got_w, w)
