"""Substrate tests: optimizer, trainer (loss goes down), hybrid sync,
compression, checkpoint round-trip + elastic restore, data pipeline
determinism, serving engine, fault-tolerance state machines."""

import importlib.util
import os

import numpy as np
import pytest

needs_zstd = pytest.mark.skipif(
    importlib.util.find_spec("zstandard") is None,
    reason="checkpointing needs the optional zstandard package")

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig, LayerSpec
from repro.core.hybrid_sync import (global_sync, inner_steps, outer_init,
                                    stack_pods)
from repro.checkpoint import AsyncCheckpointer, load_checkpoint, save_checkpoint
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticTokens
from repro.ft.elastic import replan_partitions
from repro.ft.heartbeat import HeartbeatMonitor, WorkerState
from repro.ft.straggler import StragglerMitigator, quorum_ready
from repro.models.registry import get_model
from repro.optim.adamw import adamw_init, adamw_update
from repro.optim.compression import ef_init, ef_int8_compress, ef_int8_decompress
from repro.train.trainer import make_train_step


def small_setup():
    # tiny dense GQA transformer (ad-hoc; the LM preset zoo was pruned)
    cfg = ArchConfig(
        name="dense-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab=256,
        pattern=(LayerSpec(mixer="attn", attn="full"),), tie_embeddings=True)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg, jnp.float32)
    return cfg, api, params


def test_adamw_reduces_quadratic():
    params = {"w": jnp.array([3.0, -2.0, 1.0])}
    opt = adamw_init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, opt = adamw_update(params, grads, opt, 0.05, weight_decay=0.0)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.1


def test_train_step_loss_decreases():
    cfg, api, params = small_setup()
    step_fn = jax.jit(make_train_step(cfg, api, peak_lr=3e-3, warmup=5,
                                      total_steps=300))
    opt = adamw_init(params)
    data = SyntheticTokens(DataConfig(vocab=cfg.vocab, seq_len=32,
                                      global_batch=8))
    losses = []
    for step in range(80):
        batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
        params, opt, m = step_fn(params, opt, batch, jnp.asarray(step))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.9, losses[::10]
    assert np.isfinite(losses).all()


def test_microbatched_grads_match_full():
    cfg, api, params = small_setup()
    data = SyntheticTokens(DataConfig(vocab=cfg.vocab, seq_len=16,
                                      global_batch=8))
    batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
    opt = adamw_init(params)
    s1 = jax.jit(make_train_step(cfg, api, microbatches=1))
    s4 = jax.jit(make_train_step(cfg, api, microbatches=4))
    p1, _, m1 = s1(params, opt, batch, jnp.asarray(0))
    p4, _, m4 = s4(params, opt, batch, jnp.asarray(0))
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-5)
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), p1, p4)
    assert max(jax.tree.leaves(d)) < 5e-5


# ---------------------------------------------------------------------------
# hybrid sync (GraphHP -> training)
# ---------------------------------------------------------------------------

def test_hybrid_sync_inner_steps_independent_and_sync_converges():
    cfg, api, params = small_setup()
    n_pods = 2
    step_fn = make_train_step(cfg, api, peak_lr=1e-3, warmup=2,
                              total_steps=100)
    params_pods = stack_pods(params, n_pods)
    opt_pods = stack_pods(adamw_init(params), n_pods)
    outer = outer_init(params, n_pods)
    data = [SyntheticTokens(DataConfig(vocab=cfg.vocab, seq_len=16,
                                       global_batch=4, seed=s))
            for s in range(n_pods)]
    inner = jax.jit(lambda p, o, b, s: inner_steps(step_fn, p, o, b, s))
    for step in range(3):  # local phase: H inner steps, zero cross-pod sync
        batch_pods = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[{k: jnp.asarray(v) for k, v in d.batch(step).items()}
              for d in data])
        params_pods, opt_pods, m = inner(params_pods, opt_pods, batch_pods,
                                         jnp.asarray(step))
    # pods diverged (different data, no sync)
    div = jax.tree.leaves(jax.tree.map(
        lambda p: float(jnp.max(jnp.abs(p[0] - p[1]))), params_pods))
    assert max(div) > 0

    # global phase: one exchange; replicas re-converge to the anchor
    params_pods, outer = jax.jit(global_sync)(params_pods, outer)
    div2 = jax.tree.leaves(jax.tree.map(
        lambda p: float(jnp.max(jnp.abs(p[0] - p[1]))), params_pods))
    assert max(div2) == 0.0


def test_ef_int8_compression_roundtrip_error_feedback():
    tree = {"a": jnp.asarray(np.random.RandomState(0).randn(64, 64) * 0.01,
                             jnp.float32)}
    ef = ef_init(tree)
    q, s, ef2 = ef_int8_compress(tree, ef)
    deq = ef_int8_decompress(q, s)
    err = float(jnp.max(jnp.abs(deq["a"] - tree["a"])))
    scale = float(s["a"])
    assert err <= scale * 0.51 + 1e-9      # within half a quantization step
    # residual carries exactly the rounding error
    np.testing.assert_allclose(np.asarray(ef2.residual["a"]),
                               np.asarray(tree["a"] - deq["a"]), atol=1e-7)
    # second round: residual is fed back, so applied sum stays unbiased
    q2, s2, ef3 = ef_int8_compress(jax.tree.map(jnp.zeros_like, tree), ef2)
    deq2 = ef_int8_decompress(q2, s2)
    total_applied = deq["a"] + deq2["a"]
    total_err = float(jnp.max(jnp.abs(total_applied - tree["a"])))
    assert total_err <= float(s2["a"]) * 0.51 + 1e-9


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

@needs_zstd
def test_checkpoint_roundtrip(tmp_path):
    cfg, api, params = small_setup()
    opt = adamw_init(params)
    state = {"params": params, "opt": opt}
    save_checkpoint(str(tmp_path / "c1"), state, step=7, extra_meta={"a": 1})
    restored, step = load_checkpoint(str(tmp_path / "c1"), state)
    assert step == 7
    same = jax.tree.map(lambda a, b: bool(jnp.all(a == b)), state, restored)
    assert all(jax.tree.leaves(same))


@needs_zstd
def test_checkpoint_detects_corruption(tmp_path):
    state = {"w": jnp.ones((8, 8))}
    save_checkpoint(str(tmp_path / "c2"), state, step=0)
    blob = tmp_path / "c2" / "leaf_00000.npy.zst"
    data = bytearray(blob.read_bytes())
    data[len(data) // 2] ^= 0xFF
    blob.write_bytes(bytes(data))
    with pytest.raises(IOError):
        load_checkpoint(str(tmp_path / "c2"), state)


@needs_zstd
def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path / "ck"), keep=2)
    state = {"w": jnp.arange(16.0)}
    for s in (1, 2, 3):
        ck.save(s, state)
    ck.close()
    from repro.checkpoint.ckpt import latest_checkpoint
    latest = latest_checkpoint(str(tmp_path / "ck"))
    assert latest is not None and latest.endswith("step_00000003")
    dirs = sorted(os.listdir(tmp_path / "ck"))
    assert len(dirs) <= 2      # gc kept last 2


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_determinism_and_sharding():
    cfg = DataConfig(vocab=1000, seq_len=16, global_batch=8, seed=3)
    full = SyntheticTokens(cfg, n_shards=1, shard=0).batch(5)
    sh0 = SyntheticTokens(cfg, n_shards=2, shard=0).batch(5)
    sh1 = SyntheticTokens(cfg, n_shards=2, shard=1).batch(5)
    again = SyntheticTokens(cfg, n_shards=2, shard=1).batch(5)
    np.testing.assert_array_equal(sh1["tokens"], again["tokens"])
    assert sh0["tokens"].shape == (4, 16)
    assert not np.array_equal(sh0["tokens"], sh1["tokens"])
    assert full["tokens"].shape == (8, 16)


def test_prefetcher():
    cfg = DataConfig(vocab=100, seq_len=8, global_batch=2)
    pf = Prefetcher(SyntheticTokens(cfg), depth=2)
    b1 = pf.next()
    b2 = pf.next()
    assert b1["tokens"].shape == (2, 8)
    assert not np.array_equal(b1["tokens"], b2["tokens"])
    pf.close()


# ---------------------------------------------------------------------------
# serving moved: the graph-query ServeEngine is covered in test_serve.py
# ---------------------------------------------------------------------------

# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

def test_heartbeat_failover():
    t = [0.0]
    mon = HeartbeatMonitor(3, suspect_after=1.0, fail_after=2.0,
                           clock=lambda: t[0])
    for p in range(6):
        mon.assign(p % 3, f"partition_{p}")
    t[0] = 1.5
    mon.beat(0)
    mon.beat(1)                      # worker 2 silent
    assert mon.sweep() == []
    assert mon.workers[2].state is WorkerState.SUSPECT
    t[0] = 3.0
    mon.beat(0)
    mon.beat(1)
    assert mon.sweep() == [2]
    moved = mon.reassign_failed()
    got = [i for items in moved.values() for i in items]
    assert sorted(got) == ["partition_2", "partition_5"]
    assert mon.workers[2].assignments == []


def test_elastic_replan():
    plan = replan_partitions(256, old_workers=8, new_workers=6)
    assert plan.owner.max() == 5
    counts = np.bincount(plan.owner)
    assert counts.max() - counts.min() <= 1   # balanced
    plan2 = replan_partitions(256, 8, 8)
    assert plan2.moved == 0


def test_straggler_redispatch_and_duplicates():
    t = [0.0]
    sm = StragglerMitigator(deadline_factor=2.0, min_deadline=1.0,
                            clock=lambda: t[0])
    sm.issue(1, replica=0)
    t[0] = 0.5
    assert sm.complete(1) is True
    sm.issue(2, replica=0)
    t[0] = 4.0                        # way past deadline
    over = sm.overdue()
    assert [w.work_id for w in over] == [2]
    assert sm.redispatches == 1
    assert sm.complete(2) is True
    assert sm.complete(2) is False    # duplicate from the re-dispatch
    assert sm.duplicates_suppressed == 1
    assert quorum_ready(3, 4) and not quorum_ready(2, 4)


@needs_zstd
def test_elastic_checkpoint_restore_other_mesh(tmp_path):
    """Save on a 1-device layout, restore with explicit shardings (the
    single CPU device here, but through the resharding code path)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_host_mesh
    state = {"w": jnp.arange(32.0).reshape(4, 8)}
    save_checkpoint(str(tmp_path / "c3"), state, step=1)
    mesh = make_host_mesh()
    sh = {"w": NamedSharding(mesh, P("data", None))}
    restored, _ = load_checkpoint(str(tmp_path / "c3"), state, shardings=sh)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(state["w"]))
