"""Observability subsystem: the injectable clock, the metrics registry,
span tracing through the executor, the phased profiler's bit-parity with
the fused engines, Chrome trace-event export, and the zero-cost guarantee
for the disabled path."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

import jax

from repro.core import (bfs_partition, build_partitioned_graph,
                        hash_partition, run_bsp, run_hybrid)
from repro.core.apps import SSSP, IncrementalPageRank
from repro.core.apps.pagerank import pagerank_edge_weights
from repro.data.graphs import grid_graph, rmat_graph
from repro.exec.policy import make_policy
from repro.exec.driver import run_engine
from repro.ft import FaultInjector, FaultPlan, run_hybrid_ft
from repro.obs import clock as obs_clock
from repro.obs.export import chrome_trace, profile_blob, write_chrome_trace
from repro.obs.metrics import (MetricsRegistry, load_registry,
                               record_engine_counters, save_registry)
from repro.obs.trace import (RunTraceHook, TraceHook, Tracer, exchange_bytes,
                             phased_run, trace_hooks, wrap_hooks)


@pytest.fixture(scope="module")
def road():
    edges, w, n = grid_graph(6, 40, seed=3)
    part = bfs_partition(edges, n, 4, seed=1)
    return build_partitioned_graph(edges, n, part, weights=w)


@pytest.fixture(scope="module")
def web():
    edges, n = rmat_graph(200, avg_degree=5, seed=7)
    part = hash_partition(n, 4, seed=2)
    w = pagerank_edge_weights(edges, n)
    return build_partitioned_graph(edges, n, part, weights=w)


def assert_counters_equal(a, b):
    for f in ("iterations", "net_messages", "net_local_messages",
              "mem_messages"):
        assert int(getattr(a.counters, f)) == int(getattr(b.counters, f)), f
    np.testing.assert_array_equal(np.asarray(a.counters.pseudo_supersteps),
                                  np.asarray(b.counters.pseudo_supersteps))


# ---------------------------------------------------------------------------
# clock
# ---------------------------------------------------------------------------

def test_fake_clock_drives_heartbeat_without_explicit_param():
    """Satellite: ft/ reads the one installable clock — no monkeypatching,
    no clock= threading."""
    from repro.ft import HeartbeatMonitor

    with obs_clock.fake() as fc:
        mon = HeartbeatMonitor(3, suspect_after=5.0, fail_after=15.0)
        fc.advance(6.0)
        mon.beat(0)
        assert mon.sweep() == []          # suspect only, nobody failed
        fc.advance(10.0)
        assert sorted(mon.sweep()) == [1, 2]
    assert obs_clock._monotonic is not fc    # backend restored on exit


def test_fake_clock_drives_straggler_deadline():
    from repro.ft import StragglerMitigator

    with obs_clock.fake() as fc:
        mit = StragglerMitigator(min_deadline=1.0)
        mit.issue(7, replica=0)
        fc.advance(10.0)
        assert [w.work_id for w in mit.overdue()] == [7]
        assert mit.redispatches == 1


def test_fake_clock_drives_checkpoint_save_billing(road, tmp_path):
    from repro.checkpoint import AsyncCheckpointer
    from repro.exec.iteration import init_hybrid

    es = init_hybrid(road, SSSP(source=0), None)
    with obs_clock.fake() as fc:
        ck = AsyncCheckpointer(str(tmp_path / "c"), keep=2)
        real = obs_clock._perf_counter       # the fake backend
        assert real is fc
        ck.save(1, es)
        ck.wait()
        ck.close()
        # the fake clock never advanced, so the billed snapshot time is 0
        assert ck.save_seconds == 0.0


def test_clock_install_returns_previous():
    prev = obs_clock.install(lambda: 42.0)
    try:
        assert obs_clock.monotonic() == 42.0
    finally:
        obs_clock.install(*prev)


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_registry_round_trip(tmp_path):
    reg = MetricsRegistry()
    reg.inc("a.count", 3, unit="msgs")
    reg.set_gauge("a.vec", [1, 2, 3])
    reg.set_gauge("a.scalar", 2.5, unit="s")
    for v in (0.001, 0.5, 10.0, 2000.0):
        reg.observe("a.hist", v, unit="s")
    path = str(tmp_path / "m.json")
    save_registry(reg, path)
    back = load_registry(path)
    assert back.names() == reg.names()
    assert back.value("a.count") == 3.0
    assert back.value("a.vec") == [1.0, 2.0, 3.0]
    h = back.histogram("a.hist")
    assert h.count == 4 and h.min == 0.001 and h.max == 2000.0
    assert abs(h.mean - (0.001 + 0.5 + 10.0 + 2000.0) / 4) < 1e-9
    assert sum(h.counts) == 4


def test_registry_kind_collision_and_negative_inc():
    reg = MetricsRegistry()
    reg.inc("x")
    with pytest.raises(ValueError, match="counter"):
        reg.set_gauge("x", 1.0)
    with pytest.raises(ValueError, match="negative"):
        reg.inc("x", -1)


def test_record_engine_counters(road):
    es, _ = run_hybrid(road, SSSP(source=0), device_loop=False)
    reg = MetricsRegistry()
    record_engine_counters(reg, es.counters)
    assert reg.value("engine.iterations") == float(es.counters.iterations)
    vec = reg.value("engine.pseudo_supersteps")
    assert len(vec) == road.n_partitions
    np.testing.assert_array_equal(
        np.asarray(vec), np.asarray(es.counters.pseudo_supersteps, float))


# ---------------------------------------------------------------------------
# tracing through the executor
# ---------------------------------------------------------------------------

def test_trace_hook_counters_bit_identical(road):
    """The stepwise TraceHook observes; it must not perturb: final state
    and every paper counter match the untraced run bit-for-bit."""
    prog = SSSP(source=0)
    policy = make_policy("hybrid")
    ref = run_engine(road, prog, policy, None)

    tracer = Tracer()
    ctx = run_engine(road, prog, policy, None, hooks=trace_hooks(tracer))
    np.testing.assert_array_equal(np.asarray(ctx.es.state["dist"]),
                                  np.asarray(ref.es.state["dist"]))
    assert_counters_equal(ctx.es, ref.es)

    steps = [s for s in tracer.spans if s.cat == "superstep"]
    assert len(steps) == ctx.iteration
    assert all(s.dur >= 0 and s.args["exchange_bytes"] >= 0 for s in steps)
    assert sum(s.args["barriers"] for s in steps) == ctx.iteration


def test_device_loop_degrades_to_run_span(road):
    """device_loop rejects stepwise hooks; trace_hooks hands it the
    run-level hook instead and the run still traces."""
    prog = SSSP(source=0)
    policy = make_policy("hybrid")
    tracer = Tracer()
    hooks = trace_hooks(tracer, device_loop=True)
    assert isinstance(hooks[0], RunTraceHook)
    ctx = run_engine(road, prog, policy, None, hooks=hooks, device_loop=True)
    [span] = [s for s in tracer.spans if s.name == "run"]
    assert span.args["iterations"] == ctx.iteration

    with pytest.raises(ValueError, match="device_loop"):
        run_engine(road, prog, policy, None,
                   hooks=(TraceHook(Tracer()),), device_loop=True)


def test_disabled_tracer_contributes_nothing(road):
    assert trace_hooks(None) == ()
    assert trace_hooks(Tracer(enabled=False)) == ()
    t = Tracer(enabled=False)
    with t.span("x"):
        t.instant("y")
    assert t.spans == []
    # wrap_hooks is identity when tracing is off
    h = TraceHook(Tracer())
    assert wrap_hooks(None, (h,)) == (h,)


def test_hot_path_never_imports_tracing():
    """Zero-cost disabled path: importing the engines and the executor must
    not pull in the tracing/metrics modules."""
    code = (
        "import sys\n"
        "import repro.core.runtime, repro.core.distributed\n"
        "import repro.exec.driver, repro.exec.iteration\n"
        "import repro.ft.driver, repro.serve.engine\n"
        "bad = [m for m in sys.modules if m.startswith('repro.obs.')\n"
        "       and m != 'repro.obs.clock' and m != 'repro.obs.metrics']\n"
        "assert 'repro.obs.trace' not in sys.modules, 'trace imported'\n"
        "assert 'repro.obs.export' not in sys.modules, 'export imported'\n"
        "assert not [m for m in bad if m != 'repro.obs.metrics'], bad\n"
    )
    env = dict(os.environ,
               PYTHONPATH="src" + os.pathsep + os.environ.get("PYTHONPATH",
                                                              ""))
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))),
                       capture_output=True, text=True)
    assert r.returncode == 0, r.stderr


def test_exchange_bytes_zero_when_nothing_to_send(road):
    """After quiescence no vertex is exporting: the accounted wire bytes
    for a further exchange are exactly zero."""
    es, _ = run_hybrid(road, SSSP(source=0), device_loop=False)
    assert exchange_bytes(road, es) == 0


# ---------------------------------------------------------------------------
# phased profiler
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ["bsp", "hybrid"])
def test_phased_run_bit_identical(road, engine):
    """The phase decomposition is the step body: final state, iteration
    count, and every counter are bit-identical to the fused engines."""
    runner = {"bsp": run_bsp, "hybrid": run_hybrid}[engine]
    kwargs = {"device_loop": False} if engine == "hybrid" else {}
    es_ref, it_ref = runner(road, SSSP(source=0), **kwargs)

    res = phased_run(road, SSSP(source=0), engine, None)
    assert res.iterations == it_ref
    np.testing.assert_array_equal(np.asarray(res.es.state["dist"]),
                                  np.asarray(es_ref.state["dist"]))
    assert_counters_equal(res.es, es_ref)
    assert len(res.records) == it_ref
    assert all(0.0 <= r.local_compute_fraction <= 1.0 for r in res.records)


def test_phased_hybrid_fewer_barriers_than_bsp(web):
    """The paper's claim on one shared graph: hybrid converges in fewer
    global barriers (and fewer exchanged bytes) than BSP."""
    prog = IncrementalPageRank(tolerance=1e-4)
    b = phased_run(web, prog, "bsp", None)
    h = phased_run(web, prog, "hybrid", None)
    assert h.total_barriers < b.total_barriers
    assert h.total_exchange_bytes < b.total_exchange_bytes


# ---------------------------------------------------------------------------
# Chrome trace export
# ---------------------------------------------------------------------------

def _schema_check(doc):
    evs = [e for e in doc["traceEvents"] if e["ph"] != "M"]
    assert evs, "no events"
    for e in evs:
        assert e["ph"] in ("X", "i")
        for field in ("name", "cat", "ts", "pid", "tid"):
            assert field in e, f"missing {field}"
        assert isinstance(e["ts"], (int, float))
        if e["ph"] == "X":
            assert e["dur"] >= 0
    # timestamps monotone within every (pid, tid) track
    by_track = {}
    for e in evs:
        by_track.setdefault((e["pid"], e["tid"]), []).append(e["ts"])
    for ts in by_track.values():
        assert ts == sorted(ts)
    return evs


def test_chrome_trace_schema(road, tmp_path):
    tracer = Tracer()
    tracer.name_track(0, "hybrid")
    run_engine(road, SSSP(source=0), make_policy("hybrid"), None,
               hooks=trace_hooks(tracer))
    path = str(tmp_path / "trace.json")
    write_chrome_trace(tracer, path)
    with open(path) as f:
        doc = json.load(f)
    evs = _schema_check(doc)
    assert any(e["cat"] == "superstep" for e in evs)
    names = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    assert names and names[0]["args"]["name"] == "hybrid"


def test_ft_recovery_span_in_trace(road, tmp_path):
    """A kill-and-recover FT run leaves the recovery annotated in the
    trace: a cat='ft' span with the rollback accounting, bracketed by
    superstep spans, all schema-valid."""
    tracer = Tracer()
    inj = FaultInjector(FaultPlan.kill_at(3, worker=1), n_workers=4)
    res = run_hybrid_ft(road, SSSP(source=0), ckpt_dir=str(tmp_path / "c"),
                        n_workers=4, injector=inj, tracer=tracer)
    assert len(res.recoveries) == 1

    [rec] = [s for s in tracer.spans if s.cat == "ft"]
    assert rec.name == "recovery"
    assert rec.args["failed_workers"] == [1]
    assert rec.args["iterations_lost"] >= 0
    assert rec.args["bytes_read"] > 0
    # the hooks' own work is visible too (checkpoint saves, fault sweeps)
    assert any(s.cat == "hook" and "CheckpointHook" in s.name
               for s in tracer.spans)
    assert any(s.cat == "superstep" for s in tracer.spans)
    _schema_check(chrome_trace(tracer))


def test_ft_registry_populated_and_flags_from_registry(road):
    """run_hybrid_ft fills the registry and derives straggler flags from
    its gauges; an absurdly low factor flags every partition."""
    reg = MetricsRegistry()
    res = run_hybrid_ft(road, SSSP(source=0), registry=reg,
                        straggler_factor=0.01)
    assert res.registry is reg
    assert reg.value("engine.iterations") == float(res.iterations)
    assert reg.value("ft.recoveries") == 0.0
    assert len(res.straggler_flags) > 0
    flagged = {f.partition for f in res.straggler_flags}
    counts = np.asarray(reg.value("engine.pseudo_supersteps"))
    med = max(float(np.median(counts)), 1.0)
    assert flagged == set(np.flatnonzero(counts > 0.01 * med).tolist())


def test_profile_blob_shape(road):
    tracer = Tracer()
    res = phased_run(road, SSSP(source=0), "hybrid", None, tracer=tracer)
    reg = MetricsRegistry()
    record_engine_counters(reg, res.es.counters)
    blob = profile_blob(tracer=tracer, registry=reg, runs=[res],
                        meta={"fixture": "road"})
    assert blob["schema"] == "repro.obs.profile/1"
    eng = blob["engines"]["hybrid"]
    assert eng["iterations"] == res.iterations
    assert len(eng["supersteps"]) == res.iterations
    assert eng["total_barriers"] == res.total_barriers
    json.dumps(blob)          # fully JSON-serializable
    _schema_check(blob["trace"])
