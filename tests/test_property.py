"""Property-based tests (hypothesis) on system invariants.

  * engine equivalence: on arbitrary random digraphs/partitionings, all three
    engines reach the same SSSP/WCC fixed point as the numpy oracle;
  * the hybrid engine's network messages never exceed standard BSP's
    (the paper's central inequality);
  * monoid combiner laws: segment combination == sequential fold for every
    combiner kind;
  * quiescence is terminal: stepping a converged engine changes nothing;
  * checkpoint save/load round-trips arbitrary pytrees bit-exactly;
  * int8 error-feedback quantization error is bounded by scale/2.
"""

import importlib.util

import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core import (build_partitioned_graph, hash_partition, run_am,
                        run_bsp, run_hybrid)
from repro.core.apps import SSSP, WCC
from repro.core.vertex_program import Channel, combine_segments
from repro.data.graphs import symmetrize


# ---------------------------------------------------------------------------
# random graph strategy
# ---------------------------------------------------------------------------

@st.composite
def digraphs(draw, max_n=28, max_e=80):
    n = draw(st.integers(4, max_n))
    m = draw(st.integers(n, max_e))
    seed = draw(st.integers(0, 2**16))
    rng = np.random.RandomState(seed)
    edges = rng.randint(0, n, size=(m, 2))
    edges = edges[edges[:, 0] != edges[:, 1]]
    if len(edges) == 0:
        edges = np.array([[0, 1]])
    edges = np.unique(edges, axis=0)
    p = draw(st.integers(2, min(6, n)))
    part = hash_partition(n, p, seed=seed)
    w = rng.uniform(0.5, 3.0, size=len(edges)).astype(np.float32)
    return edges, w, n, part


def _sssp_oracle(edges, w, n, src=0):
    dist = np.full(n, np.inf)
    dist[src] = 0.0
    for _ in range(n):          # Bellman-Ford
        nd = dist.copy()
        np.minimum.at(nd, edges[:, 1], dist[edges[:, 0]] + w)
        if np.array_equal(nd, dist, equal_nan=True):
            break
        dist = nd
    return dist


def _unpack(graph, es, field):
    gid = np.asarray(graph.vertex_gid).ravel()
    val = np.asarray(es.state[field]).ravel()
    out = np.zeros(graph.n_vertices, dtype=val.dtype)
    out[gid[gid >= 0]] = val[gid >= 0]
    return out


@settings(max_examples=12, deadline=None)
@given(digraphs())
def test_engines_agree_with_sssp_oracle(g):
    edges, w, n, part = g
    graph = build_partitioned_graph(edges, n, part, weights=w)
    oracle = _sssp_oracle(edges, w, n)
    msgs, iters = {}, {}
    for name, runner in (("bsp", run_bsp), ("am", run_am),
                         ("hyb", run_hybrid)):
        es, it = runner(graph, SSSP(source=0), max_iters=2000)
        got = _unpack(graph, es, "dist")
        np.testing.assert_allclose(got, oracle, rtol=1e-5)
        msgs[name] = int(es.counters.net_messages)
        iters[name] = it
    # What is guaranteed: the hybrid engine never needs MORE global
    # iterations (its global phase subsumes a superstep's boundary work and
    # the local phase converges interiors fully).
    assert iters["hyb"] <= iters["bsp"]
    # Message reduction is the paper's EMPIRICAL claim on locality-
    # partitioned real graphs (reproduced in benchmarks/); on adversarial
    # tiny random digraphs speculative local propagation may export a few
    # extra improvements — hypothesis found 32 vs 30 — so only a sanity
    # envelope is asserted here.
    assert msgs["hyb"] <= int(msgs["bsp"] * 1.5) + 8


@settings(max_examples=8, deadline=None)
@given(digraphs())
def test_wcc_equals_union_find(g):
    edges, _, n, part = g
    e2 = symmetrize(edges)
    graph = build_partitioned_graph(e2, n, part)
    es, _ = run_hybrid(graph, WCC(), max_iters=2000)
    got = _unpack(graph, es, "label")
    # oracle: label = min vertex id in the component (union-find)
    parent = np.arange(n)

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for u, v in e2:
        ru, rv = find(u), find(v)
        if ru != rv:
            parent[max(ru, rv)] = min(ru, rv)
    oracle = np.array([find(i) for i in range(n)])
    np.testing.assert_array_equal(got, oracle)


@settings(max_examples=10, deadline=None)
@given(digraphs())
def test_quiescence_is_terminal(g):
    from repro.core.engine_hybrid import hybrid_iteration
    edges, w, n, part = g
    graph = build_partitioned_graph(edges, n, part, weights=w)
    es, _ = run_hybrid(graph, SSSP(source=0), max_iters=2000)
    es2 = hybrid_iteration(graph, SSSP(source=0), es, None)
    np.testing.assert_array_equal(np.asarray(es.state["dist"]),
                                  np.asarray(es2.state["dist"]))
    assert not bool(jnp.any(es2.send))


# ---------------------------------------------------------------------------
# remote-ELL delivery: kernel layout ≡ dense halo path
# ---------------------------------------------------------------------------

@st.composite
def powerlaw_digraphs(draw, max_n=60):
    """Random digraphs with power-law in-degree (destinations concentrate on
    low vertex ids), the skew regime the sliced-ELL bins exist for."""
    n = draw(st.integers(12, max_n))
    m = draw(st.integers(n, 6 * n))
    seed = draw(st.integers(0, 2**16))
    gamma = draw(st.sampled_from([2.0, 3.0, 5.0]))
    rng = np.random.RandomState(seed)
    src = rng.randint(0, n, size=m)
    dst = np.minimum((n * rng.uniform(size=m) ** gamma).astype(np.int64),
                     n - 1)
    edges = np.unique(np.stack([src, dst], axis=1), axis=0)
    edges = edges[edges[:, 0] != edges[:, 1]]
    if len(edges) == 0:
        edges = np.array([[0, 1]])
    p = draw(st.integers(2, min(6, n)))
    part = hash_partition(n, p, seed=seed)
    w = rng.uniform(0.5, 3.0, size=len(edges)).astype(np.float32)
    return edges, w, n, part, seed


from test_delivery_parity import assert_remote_delivery_matches as \
    _assert_remote_delivery_matches  # noqa: E402  (shared with kernel suite)


@settings(max_examples=15, deadline=None)
@given(powerlaw_digraphs())
def test_remote_ell_matches_dense_bitexact(g):
    """The remote-ELL packer + halo plan reproduce dense
    deliver(edges='remote') bit-exactly: min-combined float payloads (SSSP)
    and int payloads (WCC labels) agree in every pending slot, has-flag and
    paper counter.  ``ell_base_slices=8`` forces the skewed examples into
    multiple degree bins — the case that previously fell back to dense."""
    edges, w, n, part, seed = g
    graph = build_partitioned_graph(edges, n, part, weights=w,
                                    ell_base_slices=8)
    rng = np.random.RandomState(seed + 1)
    p, vp = graph.n_partitions, graph.vp
    dist = jnp.asarray(np.where(rng.uniform(size=(p, vp)) < 0.8,
                                rng.uniform(0, 50, size=(p, vp)),
                                np.inf).astype(np.float32))
    _assert_remote_delivery_matches(graph, SSSP(source=0), {"dist": dist},
                                    seed + 2)
    labels = jnp.asarray(rng.randint(0, n, size=(p, vp)).astype(np.int32))
    _assert_remote_delivery_matches(graph, WCC(), {"label": labels}, seed + 3)


# ---------------------------------------------------------------------------
# combiner monoid laws
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(
    kind=st.sampled_from(["sum", "min", "max", "lexmin"]),
    n_edges=st.integers(1, 60),
    n_dst=st.integers(1, 12),
    seed=st.integers(0, 2**16),
)
def test_segment_combine_equals_fold(kind, n_edges, n_dst, seed):
    rng = np.random.RandomState(seed)
    if kind == "lexmin":
        comps = ((jnp.int32, np.iinfo(np.int32).max),
                 (jnp.int32, np.iinfo(np.int32).max))
        payloads = tuple(jnp.asarray(rng.randint(0, 8, n_edges), jnp.int32)
                         for _ in comps)
    else:
        ident = {"sum": 0.0, "min": np.inf, "max": -np.inf}[kind]
        comps = ((jnp.float32, ident),)
        payloads = (jnp.asarray(rng.randn(n_edges), jnp.float32),)
    ch = Channel("t", kind, comps)
    valid = jnp.asarray(rng.uniform(size=n_edges) < 0.7)
    dst = jnp.asarray(rng.randint(0, n_dst, n_edges), jnp.int32)

    got, has = combine_segments(ch, payloads, valid, dst, n_dst)

    for d in range(n_dst):
        sel = (np.asarray(dst) == d) & np.asarray(valid)
        items = [tuple(np.asarray(p)[i] for p in payloads)
                 for i in np.nonzero(sel)[0]]
        assert bool(has[d]) == (len(items) > 0)
        if not items:
            continue
        if kind == "sum":
            np.testing.assert_allclose(float(got[0][d]),
                                       sum(x[0] for x in items), rtol=1e-5)
        elif kind == "min":
            assert float(got[0][d]) == min(x[0] for x in items)
        elif kind == "max":
            assert float(got[0][d]) == max(x[0] for x in items)
        else:
            best = min(items)
            assert tuple(int(g[d]) for g in got) == tuple(int(v) for v in best)


# ---------------------------------------------------------------------------
# checkpoint / compression
# ---------------------------------------------------------------------------

@st.composite
def pytrees(draw):
    n_leaves = draw(st.integers(1, 5))
    seed = draw(st.integers(0, 2**16))
    rng = np.random.RandomState(seed)
    tree = {}
    for i in range(n_leaves):
        shape = tuple(rng.randint(1, 6, size=rng.randint(1, 3)))
        dtype = rng.choice([np.float32, np.int32])
        arr = (rng.randn(*shape) * 10).astype(dtype)
        tree[f"leaf{i}"] = jnp.asarray(arr)
    return tree


@pytest.mark.skipif(importlib.util.find_spec("zstandard") is None,
                    reason="checkpointing needs the optional zstandard package")
@settings(max_examples=15, deadline=None)
@given(pytrees())
def test_checkpoint_roundtrip_property(tree):
    import tempfile
    from repro.checkpoint import load_checkpoint, save_checkpoint
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, tree, step=1)
        restored, step = load_checkpoint(d, tree)
        assert step == 1
        for k in tree:
            np.testing.assert_array_equal(np.asarray(tree[k]),
                                          np.asarray(restored[k]))
            assert tree[k].dtype == restored[k].dtype


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**16), scale=st.floats(1e-6, 1e3))
def test_int8_quantization_error_bound(seed, scale):
    from repro.optim.compression import ef_init, ef_int8_compress, \
        ef_int8_decompress
    rng = np.random.RandomState(seed)
    tree = {"x": jnp.asarray(rng.randn(17, 5).astype(np.float32) * scale)}
    ef = ef_init(tree)
    q, s, ef2 = ef_int8_compress(tree, ef)
    deq = ef_int8_decompress(q, s)
    err = np.max(np.abs(np.asarray(deq["x"]) - np.asarray(tree["x"])))
    assert err <= float(s["x"]) * 0.5 + 1e-6 * scale
    # residual == exactly the error we just made
    np.testing.assert_allclose(np.asarray(ef2.residual["x"]),
                               np.asarray(tree["x"] - deq["x"]),
                               atol=1e-5 * max(scale, 1.0))
