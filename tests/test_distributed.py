"""Distributed-mode tests.  Each runs in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 so shard_map/GSPMD paths
execute on a real (fake-)multi-device mesh:

  * the distributed GraphHP engine produces the SAME fixed point and
    iteration count as the host engine (the shard_map lowering is faithful);
  * a smoke-sized LM train/prefill/decode cell lowers, compiles AND RUNS
    under the 2×4 mesh with the production sharding rules;
  * the hybrid-sync inner step + global sync run under a (2,2,2) pod mesh.
"""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(body: str, devices: int = 8, timeout: int = 900):
    src = "import os\n" \
          f"os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count={devices}'\n" \
          + textwrap.dedent(body)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + ":" + REPO
    out = subprocess.run([sys.executable, "-c", src], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_distributed_hybrid_engine_matches_host():
    run_sub("""
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.launch.mesh import set_mesh
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.core import build_partitioned_graph, bfs_partition, run_hybrid
    from repro.core.apps import SSSP
    from repro.core.distributed import make_dist_hybrid_step, _es_specs, shard0_specs
    from repro.core.engine_hybrid import init_hybrid
    from repro.core.runtime import quiescent
    from repro.data.graphs import grid_graph

    edges, w, n = grid_graph(6, 40, seed=3)
    part = bfs_partition(edges, n, 8, seed=1)
    graph = build_partitioned_graph(edges, n, part, weights=w,
                                    edge_blocks=8)   # one block per device
    prog = SSSP(source=0)

    # host reference
    es_ref, iters_ref = run_hybrid(graph, prog)
    ref = np.asarray(es_ref.state['dist'])

    # distributed: one partition per device
    mesh = jax.make_mesh((2, 4), ('data', 'model'))
    axes = ('data', 'model')
    step = make_dist_hybrid_step(prog, mesh, axes=axes)
    es = init_hybrid(graph, prog, None)
    gs = jax.tree.map(lambda s: NamedSharding(mesh, s), shard0_specs(graph, axes))
    ess = jax.tree.map(lambda s: NamedSharding(mesh, s), _es_specs(es, axes))
    graph_d = jax.device_put(graph, gs)
    es_d = jax.device_put(es, ess)
    with set_mesh(mesh):
        jitted = jax.jit(step, in_shardings=(gs, ess))
        iters = 0
        while not bool(quiescent(prog, es_d)) and iters < 500:
            es_d = jitted(graph_d, es_d)
            iters += 1
    got = np.asarray(jax.device_get(es_d.state['dist']))
    np.testing.assert_allclose(got, ref, rtol=1e-5)
    assert iters == iters_ref, (iters, iters_ref)
    # paper metric parity: the message counters agree with the host run
    assert int(es_d.counters.net_messages) == int(es_ref.counters.net_messages)
    print('DIST OK', iters, int(es_d.counters.net_messages))
    """)


def test_distributed_hybrid_kernel_path_matches_host():
    """The now-default use_ell=True under shard_map: the ELL kernels
    (including the fused min_step local phase and remote-ELL delivery over
    spill bins) run on block-local partition slices, exercising
    `slice_flat`'s re-offset branch (p != graph.n_partitions), with
    collect_metrics=True riding the tiles' per-slot group ids (no dense
    per-group fallback).  Fixed point, iteration count and every paper
    counter must match the host dense run bit-exactly."""
    run_sub("""
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.launch.mesh import set_mesh
    from jax.sharding import NamedSharding
    from repro.core import build_partitioned_graph, hash_partition, run_hybrid
    from repro.core.apps import SSSP
    from repro.core.distributed import make_dist_hybrid_step, _es_specs, shard0_specs
    from repro.core.engine_hybrid import init_hybrid
    from repro.core.runtime import quiescent

    # hub-skewed digraph so the sliced-ELL layout spills into extra bins
    rng = np.random.RandomState(13)
    n = 160
    edges = np.stack([rng.randint(0, n, size=1200),
                      rng.randint(0, 4, size=1200)], axis=1)
    edges = np.concatenate([edges, rng.randint(0, n, size=(600, 2))])
    edges = np.unique(edges, axis=0)
    edges = edges[edges[:, 0] != edges[:, 1]]
    part = hash_partition(n, 8, seed=2)
    w = rng.uniform(0.5, 3.0, size=len(edges)).astype(np.float32)
    graph = build_partitioned_graph(edges, n, part, weights=w,
                                    ell_base_slices=8, edge_blocks=8)
    assert len(graph.remote_ell) >= 2, 'skew should spill remote bins'
    prog = SSSP(source=0)

    es_ref, iters_ref = run_hybrid(graph, prog, use_ell=False)
    ref = np.asarray(es_ref.state['dist'])

    mesh = jax.make_mesh((2, 4), ('data', 'model'))
    axes = ('data', 'model')
    # kernel path + collect_metrics=True are the defaults now — no kwargs
    step = make_dist_hybrid_step(prog, mesh, axes=axes)
    es = init_hybrid(graph, prog, None)
    gs = jax.tree.map(lambda s: NamedSharding(mesh, s), shard0_specs(graph, axes))
    ess = jax.tree.map(lambda s: NamedSharding(mesh, s), _es_specs(es, axes))
    graph_d = jax.device_put(graph, gs)
    es_d = jax.device_put(es, ess)
    with set_mesh(mesh):
        jitted = jax.jit(step, in_shardings=(gs, ess))
        iters = 0
        while not bool(quiescent(prog, es_d)) and iters < 500:
            es_d = jitted(graph_d, es_d)
            iters += 1
    got = np.asarray(jax.device_get(es_d.state['dist']))
    np.testing.assert_array_equal(got, ref)      # min semiring: bit-exact
    assert iters == iters_ref, (iters, iters_ref)
    for f in ('net_messages', 'net_local_messages', 'mem_messages'):
        assert int(getattr(es_d.counters, f)) == \\
            int(getattr(es_ref.counters, f)), f
    print('DIST ELL OK', iters, int(es_d.counters.net_messages))
    """)


def test_distributed_new_semiring_apps_match_host():
    """WidestPath (max_min) and RandomWalk (min_mul / max_add) through the
    default-kernel distributed step: fixed point and paper counters
    bit-exact against the host dense run for every app."""
    run_sub("""
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.launch.mesh import set_mesh
    from jax.sharding import NamedSharding
    from repro.core import build_partitioned_graph, hash_partition, run_hybrid
    from repro.core.apps import RandomWalk, WidestPath
    from repro.core.apps.random_walk import random_walk_edge_weights
    from repro.core.distributed import make_dist_hybrid_step, _es_specs, shard0_specs
    from repro.core.engine_hybrid import init_hybrid
    from repro.core.runtime import quiescent
    from repro.data.graphs import rmat_graph

    edges, n = rmat_graph(240, avg_degree=5, seed=9)
    part = hash_partition(n, 8, seed=1)
    rng = np.random.RandomState(7)
    w_cap = rng.uniform(0.5, 8.0, size=len(edges)).astype(np.float32)
    g_cap = build_partitioned_graph(edges, n, part, weights=w_cap,
                                    edge_blocks=8)
    g_rw = {m: build_partitioned_graph(
        edges, n, part, edge_blocks=8,
        weights=random_walk_edge_weights(edges, n, m))
        for m in ('odds', 'logprob')}

    mesh = jax.make_mesh((2, 4), ('data', 'model'))
    axes = ('data', 'model')
    cases = [('widest', g_cap, WidestPath(source=0), 'cap'),
             ('rw_odds', g_rw['odds'], RandomWalk(source=0, mode='odds'),
              'mass'),
             ('rw_logp', g_rw['logprob'],
              RandomWalk(source=0, mode='logprob'), 'mass')]
    for name, graph, prog, field in cases:
        es_ref, iters_ref = run_hybrid(graph, prog, use_ell=False)
        step = make_dist_hybrid_step(prog, mesh, axes=axes)
        es = init_hybrid(graph, prog, None)
        gs = jax.tree.map(lambda s: NamedSharding(mesh, s),
                          shard0_specs(graph, axes))
        ess = jax.tree.map(lambda s: NamedSharding(mesh, s),
                           _es_specs(es, axes))
        graph_d = jax.device_put(graph, gs)
        es_d = jax.device_put(es, ess)
        with set_mesh(mesh):
            jitted = jax.jit(step, in_shardings=(gs, ess))
            iters = 0
            while not bool(quiescent(prog, es_d)) and iters < 500:
                es_d = jitted(graph_d, es_d)
                iters += 1
        got = np.asarray(jax.device_get(es_d.state[field]))
        np.testing.assert_array_equal(got, np.asarray(es_ref.state[field]))
        assert iters == iters_ref, (name, iters, iters_ref)
        for f in ('net_messages', 'net_local_messages', 'mem_messages'):
            assert int(getattr(es_d.counters, f)) == \\
                int(getattr(es_ref.counters, f)), (name, f)
        print('DIST', name, 'OK', iters)
    """)


def test_distributed_lane_frontiers_match_host():
    """K-lane multi-source program through the block-sharded distributed
    step: the (P, Vp, L) state shards on dim 0 like everything else, and
    the fixed point, iteration count and message counters are bit-exact
    against the host K-lane run (which itself equals K single runs —
    tests/test_multi.py)."""
    run_sub("""
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.launch.mesh import set_mesh
    from jax.sharding import NamedSharding
    from repro.core import build_partitioned_graph, bfs_partition, run_hybrid
    from repro.core.apps import MultiSourceMonotone
    from repro.core.distributed import make_dist_hybrid_step, _es_specs, shard0_specs
    from repro.core.engine_hybrid import init_hybrid
    from repro.core.runtime import quiescent
    from repro.data.graphs import grid_graph

    edges, w, n = grid_graph(6, 40, seed=3)
    part = bfs_partition(edges, n, 8, seed=1)
    graph = build_partitioned_graph(edges, n, part, weights=w, edge_blocks=8)
    prog = MultiSourceMonotone([0, 7, n - 1, 120], semiring='min_add')

    es_ref, iters_ref = run_hybrid(graph, prog)
    ref = np.asarray(es_ref.state['val'])

    mesh = jax.make_mesh((2, 4), ('data', 'model'))
    axes = ('data', 'model')
    step = make_dist_hybrid_step(prog, mesh, axes=axes)
    es = init_hybrid(graph, prog, None)
    gs = jax.tree.map(lambda s: NamedSharding(mesh, s),
                      shard0_specs(graph, axes))
    ess = jax.tree.map(lambda s: NamedSharding(mesh, s), _es_specs(es, axes))
    graph_d = jax.device_put(graph, gs)
    es_d = jax.device_put(es, ess)
    with set_mesh(mesh):
        jitted = jax.jit(step, in_shardings=(gs, ess))
        iters = 0
        while not bool(quiescent(prog, es_d)) and iters < 500:
            es_d = jitted(graph_d, es_d)
            iters += 1
    got = np.asarray(jax.device_get(es_d.state['val']))
    assert got.shape == ref.shape and got.ndim == 3
    np.testing.assert_array_equal(got, ref)
    assert iters == iters_ref, (iters, iters_ref)
    assert int(es_d.counters.net_messages) == int(es_ref.counters.net_messages)
    print('DIST LANES OK', iters, got.shape)
    """)


def _dist_ft_body(app: str) -> str:
    """Kill-and-resume on the shard_map path: run the FT driver with the
    distributed step + NamedShardings, interrupt after 3 iterations,
    restart from the checkpoint — final state and every paper counter must
    be bit-identical to the uninterrupted distributed run."""
    return """
    import tempfile
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.launch.mesh import set_mesh
    from jax.sharding import NamedSharding
    from repro.core import bfs_partition, build_partitioned_graph, \\
        hash_partition
    from repro.core.apps import SSSP, IncrementalPageRank
    from repro.core.apps.pagerank import pagerank_edge_weights
    from repro.core.distributed import make_dist_hybrid_step, _es_specs, \\
        shard0_specs
    from repro.core.engine_hybrid import init_hybrid
    from repro.data.graphs import grid_graph, rmat_graph
    from repro.ft import run_hybrid_ft

    if %(sssp)s:
        edges, w, n = grid_graph(6, 40, seed=3)
        part = bfs_partition(edges, n, 8, seed=1)
        prog, field = SSSP(source=0), 'dist'
    else:
        edges, n = rmat_graph(240, avg_degree=6, seed=7)
        part = hash_partition(n, 8, seed=2)
        w = pagerank_edge_weights(edges, n)
        prog, field = IncrementalPageRank(tolerance=1e-4), 'rank'
    graph = build_partitioned_graph(edges, n, part, weights=w,
                                    edge_blocks=8)   # one block per device
    mesh = jax.make_mesh((2, 4), ('data', 'model'))
    axes = ('data', 'model')
    step = make_dist_hybrid_step(prog, mesh, axes=axes)
    es0 = init_hybrid(graph, prog, None)
    gs = jax.tree.map(lambda s: NamedSharding(mesh, s),
                      shard0_specs(graph, axes))
    ess = jax.tree.map(lambda s: NamedSharding(mesh, s),
                       _es_specs(es0, axes))
    graph_d = jax.device_put(graph, gs)
    with set_mesh(mesh), tempfile.TemporaryDirectory() as d:
        ref = run_hybrid_ft(graph_d, prog, step_fn=step, es_shardings=ess)
        r1 = run_hybrid_ft(graph_d, prog, step_fn=step, es_shardings=ess,
                           ckpt_dir=d, max_iters=3)
        assert r1.iterations == 3 < ref.iterations
        r2 = run_hybrid_ft(graph_d, prog, step_fn=step, es_shardings=ess,
                           ckpt_dir=d)
        assert r2.resumed_from is not None and \\
            r2.resumed_from.endswith('step_00000003')
    np.testing.assert_array_equal(
        np.asarray(jax.device_get(r2.es.state[field])),
        np.asarray(jax.device_get(ref.es.state[field])))
    for f in ('iterations', 'net_messages', 'net_local_messages',
              'mem_messages'):
        assert int(getattr(r2.es.counters, f)) == \\
            int(getattr(ref.es.counters, f)), f
    np.testing.assert_array_equal(
        np.asarray(r2.es.counters.pseudo_supersteps),
        np.asarray(ref.es.counters.pseudo_supersteps))
    print('DIST FT %(app)s OK', ref.iterations)
    """ % {"sssp": repr(app == "sssp"), "app": app}


def test_distributed_ft_kill_resume_sssp():
    run_sub(_dist_ft_body("sssp"))


def test_distributed_ft_kill_resume_pagerank():
    run_sub(_dist_ft_body("pagerank"))


def test_lm_cell_runs_on_mesh():
    run_sub("""
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.launch.mesh import set_mesh
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import ArchConfig, LayerSpec
    from repro.models.registry import get_model, param_shapes
    from repro.sharding.rules import param_specs, batch_spec
    from repro.sharding.util import sanitize_specs, named
    from repro.train.trainer import make_train_step
    from repro.optim.adamw import adamw_init

    # tiny MoE stack (ad-hoc; the LM preset zoo was pruned)
    cfg = ArchConfig(
        name='moe-smoke', family='moe', n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=32, vocab=256,
        pattern=(LayerSpec(mixer='attn', attn='full', moe=True),),
        n_experts=8, top_k=2, d_expert=32, tie_embeddings=True)
    api = get_model(cfg)
    mesh = jax.make_mesh((2, 4), ('data', 'model'))
    params = api.init(jax.random.PRNGKey(0), cfg, jnp.float32)
    pspecs = sanitize_specs(param_specs(params), params, mesh)
    rng = np.random.RandomState(0)
    batch = {'tokens': jnp.asarray(rng.randint(0, cfg.vocab, (8, 32), dtype=np.int32)),
             'labels': jnp.asarray(rng.randint(0, cfg.vocab, (8, 32), dtype=np.int32))}
    bspecs = sanitize_specs(batch_spec(batch), batch, mesh)
    opt = adamw_init(params)
    from repro.optim.adamw import AdamWState
    ospecs = AdamWState(mu=pspecs, nu=pspecs, step=P())
    step_fn = make_train_step(cfg, api, peak_lr=1e-3)
    with set_mesh(mesh):
        params = jax.device_put(params, named(pspecs, mesh))
        opt = jax.device_put(opt, named(ospecs, mesh))
        batch = jax.device_put(batch, named(bspecs, mesh))
        jitted = jax.jit(step_fn)
        losses = []
        for s in range(3):
            params, opt, m = jitted(params, opt, batch, jnp.asarray(s))
            losses.append(float(m['loss']))
    assert all(np.isfinite(losses)), losses
    assert losses[-1] < losses[0], losses   # same batch => must improve
    print('LM MESH OK', losses)
    """)


def test_decode_cell_seq_sharded_cache():
    run_sub("""
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.launch.mesh import set_mesh
    from repro.configs import ArchConfig, LayerSpec
    from repro.models.registry import get_model
    from repro.sharding.rules import cache_specs
    from repro.sharding.util import sanitize_specs, named

    # tiny dense GQA transformer (ad-hoc; the LM preset zoo was pruned)
    cfg = ArchConfig(
        name='dense-smoke', family='dense', n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab=256,
        pattern=(LayerSpec(mixer='attn', attn='full'),), tie_embeddings=True)
    api = get_model(cfg)
    mesh = jax.make_mesh((2, 4), ('data', 'model'))
    params = api.init(jax.random.PRNGKey(0), cfg, jnp.float32)
    rng = np.random.RandomState(1)
    tokens = jnp.asarray(rng.randint(0, cfg.vocab, (2, 16), dtype=np.int32))

    # unsharded reference
    cache = api.init_cache(cfg, 2, 32, jnp.float32)
    logits_ref, cache_ref = api.prefill(params, {'tokens': tokens}, cache, cfg)
    step_ref, _ = api.decode_step(params, tokens[:, :1], cache_ref, 16, cfg)

    # sequence-sharded cache on the mesh
    cache = api.init_cache(cfg, 2, 32, jnp.float32)
    cspecs = sanitize_specs(cache_specs(cache), cache, mesh)
    with set_mesh(mesh):
        cache = jax.device_put(cache, named(cspecs, mesh))
        logits, cache = jax.jit(lambda p, b, c: api.prefill(p, b, c, cfg))(
            params, {'tokens': tokens}, cache)
        step, _ = jax.jit(lambda p, t, c: api.decode_step(p, t, c, 16, cfg))(
            params, tokens[:, :1], cache)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(logits_ref),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(step), np.asarray(step_ref),
                               rtol=2e-3, atol=2e-3)
    print('DECODE MESH OK')
    """)


def test_hybrid_sync_on_pod_mesh():
    run_sub("""
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.launch.mesh import set_mesh
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import ArchConfig, LayerSpec
    from repro.core.hybrid_sync import (global_sync, inner_steps, outer_init,
                                        stack_pods)
    from repro.models.registry import get_model
    from repro.optim.adamw import adamw_init
    from repro.sharding.rules import param_specs, prepend_axis
    from repro.sharding.util import sanitize_specs, named
    from repro.train.trainer import make_train_step

    # tiny dense GQA transformer (ad-hoc; the LM preset zoo was pruned)
    cfg = ArchConfig(
        name='dense-smoke', family='dense', n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab=256,
        pattern=(LayerSpec(mixer='attn', attn='full'),), tie_embeddings=True)
    api = get_model(cfg)
    mesh = jax.make_mesh((2, 2, 2), ('pod', 'data', 'model'))
    params = api.init(jax.random.PRNGKey(0), cfg, jnp.float32)
    step_fn = make_train_step(cfg, api, peak_lr=1e-3)

    n_pods = 2
    pp = stack_pods(params, n_pods)
    oo = stack_pods(adamw_init(params), n_pods)
    pspecs = prepend_axis(sanitize_specs(param_specs(params), params, mesh), 'pod')
    pspecs = sanitize_specs(pspecs, pp, mesh)
    rng = np.random.RandomState(0)
    batch = {'tokens': jnp.asarray(rng.randint(0, cfg.vocab, (2, 4, 32), dtype=np.int32)),
             'labels': jnp.asarray(rng.randint(0, cfg.vocab, (2, 4, 32), dtype=np.int32))}
    outer = outer_init(params, n_pods)
    with set_mesh(mesh):
        pp = jax.device_put(pp, named(pspecs, mesh))
        inner = jax.jit(lambda p, o, b, s: inner_steps(step_fn, p, o, b, s))
        for s in range(2):
            pp, oo, m = inner(pp, oo, batch, jnp.asarray(s))
        pp, outer = jax.jit(global_sync)(pp, outer)
    div = max(jax.tree.leaves(jax.tree.map(
        lambda p: float(jnp.max(jnp.abs(p[0] - p[1]))), pp)))
    assert div == 0.0, div
    print('HYBRID SYNC MESH OK')
    """)
