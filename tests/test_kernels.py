"""Pallas kernel validation: shape/dtype sweeps + hypothesis property tests
against the pure-jnp oracles (interpret mode executes kernel bodies on CPU)."""

import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.kernels.ell_spmv import ell_spmv, ell_spmv_ref, to_ell
from repro.kernels.min_step import fused_min_step, fused_min_step_ref
from repro.kernels.pr_step import fused_pr_step, fused_pr_step_ref


def _random_ell(rng, r, k, n, density=0.5, dtype=np.float32):
    idx = rng.randint(0, n, size=(r, k)).astype(np.int32)
    val = rng.uniform(0.1, 2.0, size=(r, k)).astype(dtype)
    msk = rng.uniform(size=(r, k)) < density
    x = rng.uniform(0.0, 3.0, size=(n,)).astype(dtype)
    return jnp.asarray(idx), jnp.asarray(val), jnp.asarray(msk), jnp.asarray(x)


SHAPES = [(8, 16, 32), (64, 128, 100), (256, 130, 511), (300, 257, 1024),
          (1024, 128, 64)]
SEMIRINGS = ["add_mul", "min_add", "max_add", "min_mul", "max_min"]
MONOTONE = ["min_add", "min_mul", "max_add", "max_min"]


@pytest.mark.parametrize("semiring", SEMIRINGS)
@pytest.mark.parametrize("shape", SHAPES)
def test_ell_spmv_matches_ref(shape, semiring):
    r, k, n = shape
    rng = np.random.RandomState(hash((r, k, n)) % 2**31)
    idx, val, msk, x = _random_ell(rng, r, k, n)
    got = ell_spmv(idx, val, msk, x, semiring=semiring)
    want = ell_spmv_ref(idx, val, msk, x, semiring=semiring)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_ell_spmv_dtypes(dtype):
    rng = np.random.RandomState(0)
    idx, val, msk, x = _random_ell(rng, 64, 32, 50, dtype=np.float32)
    x = x.astype(dtype)
    val = val.astype(dtype)
    got = ell_spmv(idx, val, msk, x, semiring="add_mul")
    want = ell_spmv_ref(idx, val, msk, x, semiring="add_mul")
    assert got.dtype == want.dtype
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    r=st.integers(1, 90),
    k=st.integers(1, 140),
    n=st.integers(1, 200),
    semiring=st.sampled_from(SEMIRINGS),
    seed=st.integers(0, 2**16),
)
def test_ell_spmv_property(r, k, n, semiring, seed):
    rng = np.random.RandomState(seed)
    idx, val, msk, x = _random_ell(rng, r, k, n)
    got = ell_spmv(idx, val, msk, x, semiring=semiring)
    want = ell_spmv_ref(idx, val, msk, x, semiring=semiring)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_ell_spmv_all_masked_rows_yield_identity():
    rng = np.random.RandomState(1)
    idx, val, msk, x = _random_ell(rng, 16, 8, 10)
    msk = jnp.zeros_like(msk)
    y = ell_spmv(idx, val, msk, x, semiring="min_add")
    assert bool(jnp.all(jnp.isinf(y)))
    y = ell_spmv(idx, val, msk, x, semiring="add_mul")
    np.testing.assert_array_equal(np.asarray(y), 0.0)


def test_to_ell_roundtrip_spmv_equals_dense():
    """COO -> ELL -> spmv == dense matvec (the PageRank contraction)."""
    rng = np.random.RandomState(3)
    n = 37
    edges = np.unique(rng.randint(0, n, size=(200, 2)), axis=0)
    w = rng.uniform(0.1, 1.0, size=len(edges)).astype(np.float32)
    idx, val, msk = to_ell(np.asarray(edges), n, weights=w)
    x = rng.uniform(size=(n,)).astype(np.float32)
    a = np.zeros((n, n), np.float32)
    a[edges[:, 1], edges[:, 0]] = w       # A[dst, src]
    want = a @ x
    got = np.asarray(ell_spmv(idx, val, msk, jnp.asarray(x)))[:n]
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# fused PageRank pseudo-superstep
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(16, 8, 16), (128, 128, 128),
                                   (260, 140, 300)])
def test_fused_pr_step_matches_ref(shape):
    r, k, n = shape
    rng = np.random.RandomState(5)
    idx = jnp.asarray(rng.randint(0, n, size=(r, k)).astype(np.int32))
    val = jnp.asarray(rng.uniform(0, 1, size=(r, k)).astype(np.float32))
    msk = jnp.asarray(rng.uniform(size=(r, k)) < 0.4)
    delta = jnp.asarray(rng.uniform(0, 0.1, size=(n,)).astype(np.float32))
    send = jnp.asarray(rng.uniform(size=(n,)) < 0.5)
    rank = jnp.asarray(rng.uniform(0, 2, size=(r,)).astype(np.float32))
    got = fused_pr_step(idx, val, msk, delta, send, rank, tol=1e-3)
    want = fused_pr_step_ref(idx, val, msk, delta, send, rank, tol=1e-3)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-5, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(r=st.integers(1, 64), k=st.integers(1, 96), n=st.integers(1, 128),
       seed=st.integers(0, 2**16))
def test_fused_pr_step_property(r, k, n, seed):
    rng = np.random.RandomState(seed)
    idx = jnp.asarray(rng.randint(0, n, size=(r, k)).astype(np.int32))
    val = jnp.asarray(rng.uniform(0, 1, size=(r, k)).astype(np.float32))
    msk = jnp.asarray(rng.uniform(size=(r, k)) < 0.5)
    delta = jnp.asarray(rng.uniform(0, 0.1, size=(n,)).astype(np.float32))
    send = jnp.asarray(rng.uniform(size=(n,)) < 0.5)
    rank = jnp.asarray(rng.uniform(0, 2, size=(r,)).astype(np.float32))
    got = fused_pr_step(idx, val, msk, delta, send, rank)
    want = fused_pr_step_ref(idx, val, msk, delta, send, rank)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-5, atol=1e-6)


def test_fused_pr_step_extra_folds_spill_bins():
    """The ``extra`` operand (sliced-ELL spill contributions) lands in the
    returned delta_in, rank and send decisions."""
    rng = np.random.RandomState(7)
    r, k, n = 64, 16, 64
    idx = jnp.asarray(rng.randint(0, n, size=(r, k)).astype(np.int32))
    val = jnp.asarray(rng.uniform(0, 1, size=(r, k)).astype(np.float32))
    msk = jnp.asarray(rng.uniform(size=(r, k)) < 0.4)
    delta = jnp.asarray(rng.uniform(0, 0.1, size=(n,)).astype(np.float32))
    send = jnp.asarray(rng.uniform(size=(n,)) < 0.5)
    rank = jnp.asarray(rng.uniform(0, 2, size=(r,)).astype(np.float32))
    extra = jnp.asarray(rng.uniform(0, 0.01, size=(r,)).astype(np.float32))
    got = fused_pr_step(idx, val, msk, delta, send, rank, extra, tol=1e-3)
    want = fused_pr_step_ref(idx, val, msk, delta, send, rank, extra,
                             tol=1e-3)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# fused min-semiring pseudo-superstep
# ---------------------------------------------------------------------------

def _random_min_problem(rng, r, k, n, density=0.5):
    idx = jnp.asarray(rng.randint(0, n, size=(r, k)).astype(np.int32))
    val = jnp.asarray(rng.uniform(0.1, 2.0, size=(r, k)).astype(np.float32))
    msk = jnp.asarray(rng.uniform(size=(r, k)) < density)
    x = jnp.asarray(np.where(rng.uniform(size=n) < 0.8,
                             rng.uniform(0, 10, size=n),
                             np.inf).astype(np.float32))
    send = jnp.asarray(rng.uniform(size=(n,)) < 0.5)
    return idx, val, msk, x, send


@pytest.mark.parametrize("shape", [(16, 8, 16), (128, 128, 128),
                                   (260, 140, 300)])
def test_fused_min_step_matches_ref(shape):
    r, k, n = shape
    rng = np.random.RandomState(9)
    idx, val, msk, x, send = _random_min_problem(rng, r, k, n)
    xrow = jnp.asarray(rng.uniform(0, 10, size=(r,)).astype(np.float32))
    got = fused_min_step(idx, val, msk, x, send, xrow)
    want = fused_min_step_ref(idx, val, msk, x, send, xrow)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_fused_min_step_extra_and_defaults():
    """xrow defaults to the frontier (the engine case: rows == vertex
    slots) and ``extra`` min-folds spill-bin partials, +inf when absent."""
    rng = np.random.RandomState(3)
    r = n = 48
    idx, val, msk, x, send = _random_min_problem(rng, r, 12, n)
    extra = jnp.asarray(np.where(rng.uniform(size=r) < 0.3,
                                 rng.uniform(0, 1, size=r),
                                 np.inf).astype(np.float32))
    got = fused_min_step(idx, val, msk, x, send, extra=extra)
    want = fused_min_step_ref(idx, val, msk, x, send, x, extra)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    # no senders at all -> d_in is +inf everywhere, state unchanged
    x2, d2, s2 = fused_min_step(idx, val, msk, x, jnp.zeros_like(send))
    assert bool(jnp.all(jnp.isinf(d2)))
    np.testing.assert_array_equal(np.asarray(x2), np.asarray(x))
    assert not bool(jnp.any(s2))


@settings(max_examples=15, deadline=None)
@given(r=st.integers(1, 64), k=st.integers(1, 96), n=st.integers(1, 128),
       seed=st.integers(0, 2**16))
def test_fused_min_step_property(r, k, n, seed):
    rng = np.random.RandomState(seed)
    idx, val, msk, x, send = _random_min_problem(rng, r, k, n)
    xrow = jnp.asarray(rng.uniform(0, 10, size=(r,)).astype(np.float32))
    got = fused_min_step(idx, val, msk, x, send, xrow)
    want = fused_min_step_ref(idx, val, msk, x, send, xrow)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def _random_monotone_problem(rng, r, k, n, semiring, density=0.5):
    """Frontier/state draws matching the semiring's domain: unreached
    vertices sit at the ⊕ identity, edge values in the app's range."""
    idx = jnp.asarray(rng.randint(0, n, size=(r, k)).astype(np.int32))
    lo, hi = (1.0, 3.0) if semiring == "min_mul" else (0.1, 2.0)
    val = jnp.asarray(rng.uniform(lo, hi, size=(r, k)).astype(np.float32))
    msk = jnp.asarray(rng.uniform(size=(r, k)) < density)
    ident = np.inf if semiring.startswith("min") else -np.inf
    sign = -1.0 if semiring == "max_add" else 1.0
    x = jnp.asarray(np.where(rng.uniform(size=n) < 0.8,
                             sign * rng.uniform(0.1, 10, size=n),
                             ident).astype(np.float32))
    send = jnp.asarray(rng.uniform(size=(n,)) < 0.5)
    xrow = jnp.asarray((sign * rng.uniform(0.1, 10, size=r))
                       .astype(np.float32))
    return idx, val, msk, x, send, xrow


@pytest.mark.parametrize("semiring", MONOTONE)
@pytest.mark.parametrize("shape", [(16, 8, 16), (260, 140, 300)])
def test_fused_step_generalized_semirings(shape, semiring):
    """The fused pseudo-superstep kernel is one implementation for the whole
    monotone family: every (⊕, ⊗) pair matches its oracle bit-exactly,
    including the extra (spill) operand and the send'-improvement flags."""
    r, k, n = shape
    rng = np.random.RandomState(17)
    idx, val, msk, x, send, xrow = _random_monotone_problem(
        rng, r, k, n, semiring)
    got = fused_min_step(idx, val, msk, x, send, xrow, semiring=semiring)
    want = fused_min_step_ref(idx, val, msk, x, send, xrow,
                              semiring=semiring)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    # an explicit ⊕-identity extra must be a no-op (the no-spill-bins case)
    ident = np.inf if semiring.startswith("min") else -np.inf
    extra = jnp.full((r,), ident, jnp.float32)
    got2 = fused_min_step(idx, val, msk, x, send, xrow, extra=extra,
                          semiring=semiring)
    for g, w in zip(got2, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


@settings(max_examples=12, deadline=None)
@given(r=st.integers(1, 48), k=st.integers(1, 64), n=st.integers(1, 96),
       semiring=st.sampled_from(MONOTONE), seed=st.integers(0, 2**16))
def test_fused_step_generalized_property(r, k, n, semiring, seed):
    rng = np.random.RandomState(seed)
    idx, val, msk, x, send, xrow = _random_monotone_problem(
        rng, r, k, n, semiring)
    got = fused_min_step(idx, val, msk, x, send, xrow, semiring=semiring)
    want = fused_min_step_ref(idx, val, msk, x, send, xrow,
                              semiring=semiring)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


# ---------------------------------------------------------------------------
# lane frontiers (SpMM): an (N, L) frontier is L queries in one dispatch
# ---------------------------------------------------------------------------

def _assert_kernel_eq(got, want, semiring):
    """Monotone (⊕ = min/max) is order-insensitive, so bit-exact; add_mul
    sums float products, so the kernel's fold and the oracle's jnp.sum may
    round differently."""
    if semiring in MONOTONE:
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    else:
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(r=st.integers(1, 64), k=st.integers(1, 96), n=st.integers(1, 128),
       lanes=st.integers(1, 5), semiring=st.sampled_from(SEMIRINGS),
       seed=st.integers(0, 2**16))
def test_ell_spmm_lanes_property(r, k, n, lanes, semiring, seed):
    """(N, L) frontier: matches the oracle, and every lane column is
    bit-identical to dispatching that lane's (N,) frontier alone (the
    micro-batching parity contract — the kernel folds the slice axis in
    the same order with or without a lane axis)."""
    rng = np.random.RandomState(seed)
    idx, val, msk, _ = _random_ell(rng, r, k, n)
    x = jnp.asarray(rng.uniform(0.0, 3.0, size=(n, lanes)).astype(np.float32))
    got = ell_spmv(idx, val, msk, x, semiring=semiring)
    assert got.shape == (r, lanes)
    _assert_kernel_eq(got, ell_spmv_ref(idx, val, msk, x, semiring=semiring),
                      semiring)
    for j in range(lanes):
        single = ell_spmv(idx, val, msk, x[:, j], semiring=semiring)
        np.testing.assert_array_equal(np.asarray(got[:, j]),
                                      np.asarray(single))


@pytest.mark.parametrize("semiring", MONOTONE)
@pytest.mark.parametrize("lanes", [1, 3])
def test_fused_min_step_lanes(semiring, lanes):
    """Fused monotone pseudo-superstep with lane frontiers: oracle parity
    plus per-lane bit-identity to single-lane dispatch, including per-lane
    ``extra`` spill operands and per-lane send' decisions."""
    r, k, n = 96, 24, 96
    rng = np.random.RandomState(11)
    idx, _, msk, _, _, _ = _random_monotone_problem(rng, r, k, n, semiring)
    lo, hi = (1.0, 3.0) if semiring == "min_mul" else (0.1, 2.0)
    val = jnp.asarray(rng.uniform(lo, hi, size=(r, k)).astype(np.float32))
    ident = np.inf if semiring.startswith("min") else -np.inf
    sign = -1.0 if semiring == "max_add" else 1.0
    x = jnp.asarray(np.where(rng.uniform(size=(n, lanes)) < 0.8,
                             sign * rng.uniform(0.1, 10, size=(n, lanes)),
                             ident).astype(np.float32))
    send = jnp.asarray(rng.uniform(size=(n, lanes)) < 0.5)
    xrow = jnp.asarray((sign * rng.uniform(0.1, 10, size=(r, lanes)))
                       .astype(np.float32))
    extra = jnp.asarray(np.where(rng.uniform(size=(r, lanes)) < 0.3,
                                 sign * rng.uniform(0.1, 1, size=(r, lanes)),
                                 ident).astype(np.float32))
    got = fused_min_step(idx, val, msk, x, send, xrow, extra,
                         semiring=semiring)
    want = fused_min_step_ref(idx, val, msk, x, send, xrow, extra,
                              semiring=semiring)
    for g, w in zip(got, want):
        assert g.shape == (r, lanes)
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    for j in range(lanes):
        singles = fused_min_step(idx, val, msk, x[:, j], send[:, j],
                                 xrow[:, j], extra[:, j], semiring=semiring)
        for g, s in zip(got, singles):
            np.testing.assert_array_equal(np.asarray(g[:, j]), np.asarray(s))


@pytest.mark.parametrize("lanes", [1, 3])
def test_fused_pr_step_lanes(lanes):
    """Fused PageRank pseudo-superstep with lane frontiers: oracle parity
    (allclose — additive folds) AND bit-identical lane columns vs
    single-lane dispatch (exact — the kernel's sequential slice-axis fold
    reduces each lane in single-frontier order)."""
    r, k, n = 96, 24, 96
    rng = np.random.RandomState(13)
    idx = jnp.asarray(rng.randint(0, n, size=(r, k)).astype(np.int32))
    val = jnp.asarray(rng.uniform(0, 1, size=(r, k)).astype(np.float32))
    msk = jnp.asarray(rng.uniform(size=(r, k)) < 0.4)
    delta = jnp.asarray(rng.uniform(0, 0.1, size=(n, lanes))
                        .astype(np.float32))
    send = jnp.asarray(rng.uniform(size=(n, lanes)) < 0.5)
    rank = jnp.asarray(rng.uniform(0, 2, size=(r, lanes)).astype(np.float32))
    extra = jnp.asarray(rng.uniform(0, 0.01, size=(r, lanes))
                        .astype(np.float32))
    got = fused_pr_step(idx, val, msk, delta, send, rank, extra, tol=1e-3)
    want = fused_pr_step_ref(idx, val, msk, delta, send, rank, extra,
                             tol=1e-3)
    for g, w in zip(got, want):
        assert g.shape == (r, lanes)
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-5, atol=1e-6)
    for j in range(lanes):
        singles = fused_pr_step(idx, val, msk, delta[:, j], send[:, j],
                                rank[:, j], extra[:, j], tol=1e-3)
        for g, s in zip(got, singles):
            np.testing.assert_array_equal(np.asarray(g[:, j]), np.asarray(s))
