"""Out-of-core ingestion walkthrough: a graph that lives on disk, end to
end — materialize a synthetic R-MAT into a staged binary directory, convert
it to the sharded ``.ghp`` format with the streaming pipeline (degree pass
-> external-CSR fennel -> destination-partition spill), build the
``PartitionedGraph`` without ever holding the edge list in memory, and
check the result is *bit-identical* to the classic in-memory build before
running PageRank on it.

    PYTHONPATH=src python examples/ingest_pipeline.py [n_vertices]
"""

import os
import sys
import tempfile

import numpy as np

sys.path.insert(0, "src")

from repro.core import build_partitioned_graph, run_hybrid
from repro.core.apps import IncrementalPageRank
from repro.core.apps.pagerank import pagerank_edge_weights
from repro.data.graphs import materialize
from repro.io import (build_partitioned_graph_from_path, graph_digest,
                      load_graph, save_graph, spill_to_ghp)
from repro.io.pipeline import degree_pass, partition_source
from repro.io.readers import open_edge_source


def main():
    n = int(sys.argv[1]) if len(sys.argv) > 1 else 20000
    tmp = tempfile.mkdtemp(prefix="ghp_demo_")
    staged_dir = os.path.join(tmp, "rmat.staged")
    ghp_dir = os.path.join(tmp, "rmat.ghp")

    # 1. put a synthetic graph on disk (benchmarks do this once and every
    #    consumer streams from it)
    src = materialize(staged_dir, "rmat", n=n, avg_degree=8, seed=1)
    print(f"staged: V={src.n_vertices} E={src.n_edges} -> {staged_dir}")

    # 2. the streaming pipeline, stage by stage (one call does all of this:
    #    build_partitioned_graph_from_path(staged_dir, 'fennel', 8))
    nv, ne, out_deg, in_deg = degree_pass(src)
    labels = partition_source(src, "fennel", nv, 8, 0, tmp, ne,
                              out_deg + in_deg)
    sg = spill_to_ghp(src, labels, nv, in_deg, ghp_dir,
                      positions=True, partitioner="fennel")
    sizes = [s["n_edges"] for s in sg.meta["shards"]]
    print(f"spilled {sg.n_partitions} shards (in-edges per shard: {sizes})")

    # 3. out-of-core build from the shards, vs the classic in-memory build
    g_ooc = build_partitioned_graph_from_path(ghp_dir)
    edges, w = src.load_arrays()
    g_mem = build_partitioned_graph(edges, nv, labels)
    same = graph_digest(g_ooc) == graph_digest(g_mem)
    print(f"out-of-core == in-memory, bit for bit: {same} "
          f"({g_ooc.shape_summary})")
    assert same

    # 4. weighted rebuild for PageRank: the .ghp shards carry weights too
    wpr = pagerank_edge_weights(edges, nv)
    save_graph(os.path.join(tmp, "pr.ghp"), edges, nv, labels, weights=wpr)
    g = build_partitioned_graph_from_path(os.path.join(tmp, "pr.ghp"))
    es, iters = run_hybrid(g, IncrementalPageRank(tolerance=1e-4))
    ranks = np.asarray(es.state["rank"])
    print(f"PageRank on the disk-built graph: {iters} global iterations, "
          f"top rank {ranks.max():.2f}")

    # 5. the round trip holds: the .ghp reconstructs the edge list
    e2, _ = load_graph(ghp_dir).edges()
    print(f"round trip intact: {bool(np.array_equal(e2, edges))}")


if __name__ == "__main__":
    main()
