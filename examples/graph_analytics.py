"""Graph-analytics walkthrough: every vertex program (SSSP, incremental
PageRank, WCC, widest paths, most-likely random walks, bipartite matching)
on the hybrid engine, partitioner choice wired through
``build_partitioned_graph`` (pass a ``repro.partition`` name as ``part``),
with the Pallas ELL-SpMV kernel shown as the local-phase hot-loop
equivalent.

    PYTHONPATH=src python examples/graph_analytics.py [partitioner]
"""

import sys

import numpy as np

sys.path.insert(0, "src")

import jax.numpy as jnp

from repro.core import build_partitioned_graph, run_hybrid
from repro.core.apps import (SSSP, WCC, BipartiteMatching,
                             IncrementalPageRank, RandomWalk, WidestPath)
from repro.core.apps.pagerank import pagerank_edge_weights
from repro.core.apps.random_walk import random_walk_edge_weights
from repro.data.graphs import (bipartite_graph, grid_graph, rmat_graph,
                               symmetrize)
from repro.partition import PARTITIONERS, make_partition, partition_report


def main():
    # the partitioner every workload below runs on (default: multilevel,
    # the closest stand-in for the paper's Metis partitions)
    partitioner = sys.argv[1] if len(sys.argv) > 1 else "multilevel"

    # ---- the partitioner ladder on one graph ----------------------------
    edges, w, n = grid_graph(10, 60, seed=0)
    print(f"partition quality on a 10x60 road grid (6 parts):")
    for name in PARTITIONERS:
        rep = partition_report(edges, n, make_partition(name, edges, n, 6),
                               n_partitions=6)
        print(f"  {name:10s} {rep.summary()}")

    # ---- SSSP on a road grid -------------------------------------------
    g = build_partitioned_graph(edges, n, partitioner, weights=w,
                                n_partitions=6)
    es, iters = run_hybrid(g, SSSP(source=0))
    finite = np.isfinite(np.asarray(es.state["dist"])).sum()
    print(f"SSSP [{partitioner}]: {iters} global iterations, "
          f"{finite} reachable slots, "
          f"{int(es.counters.net_messages)} net messages")

    # ---- incremental PageRank on a web-ish graph ------------------------
    edges, n = rmat_graph(1200, avg_degree=6, seed=1)
    wpr = pagerank_edge_weights(edges, n)
    g = build_partitioned_graph(edges, n, partitioner, weights=wpr,
                                n_partitions=6, partition_seed=1)
    es, iters = run_hybrid(g, IncrementalPageRank(tolerance=1e-4))
    ranks = np.asarray(es.state["rank"])
    print(f"PageRank: {iters} global iterations, top rank "
          f"{ranks.max():.2f}, Σrank {ranks.sum():.0f} ≈ N={n}... "
          f"(unnormalized 0.15-base dynamics)")

    # ---- WCC -------------------------------------------------------------
    e2 = symmetrize(edges)
    g = build_partitioned_graph(e2, n, partitioner, n_partitions=6,
                                partition_seed=2)
    es, iters = run_hybrid(g, WCC())
    labels = np.asarray(es.state["label"])
    gid = np.asarray(g.vertex_gid)
    ncomp = len(np.unique(labels[gid >= 0]))
    print(f"WCC: {iters} global iterations, {ncomp} components")

    # ---- widest (bottleneck-capacity) paths -----------------------------
    rng = np.random.RandomState(4)
    caps = rng.uniform(1.0, 10.0, size=len(edges)).astype(np.float32)
    g = build_partitioned_graph(edges, n, partitioner, weights=caps,
                                n_partitions=6, partition_seed=1)
    es, iters = run_hybrid(g, WidestPath(source=0))
    cap = np.asarray(es.state["cap"])
    reach = np.isfinite(cap)              # source sits at +inf, padding at -inf
    print(f"WidestPath: {iters} global iterations, best bottleneck "
          f"{cap[reach].max():.2f} over {int(reach.sum())} "
          f"reachable slots (max_min semiring)")

    # ---- most-likely absorbing random walk ------------------------------
    wrw = random_walk_edge_weights(edges, n, mode="odds")
    g = build_partitioned_graph(edges, n, partitioner, weights=wrw,
                                n_partitions=6, partition_seed=1)
    prog = RandomWalk(source=0, mode="odds")
    es, iters = run_hybrid(g, prog)
    probs = np.asarray(prog.probability(es.state["mass"]))
    print(f"RandomWalk: {iters} global iterations, most-likely-walk mass "
          f"median {np.median(probs[probs > 0]):.2e} (min_mul semiring; "
          f"mode='logprob' runs the same closure over max_add)")

    # ---- bipartite matching ---------------------------------------------
    edges, nl, n = bipartite_graph(300, 260, avg_degree=3, seed=3)
    g = build_partitioned_graph(edges, n, partitioner, n_partitions=6,
                                partition_seed=3)
    vdata = {"is_left": g.vertex_gid < nl, "degree": g.out_degree}
    es, iters = run_hybrid(g, BipartiteMatching(seed=1), vdata=vdata,
                           max_iters=300)
    matched = np.asarray(es.state["matched"])
    n_matched = int(((matched >= 0) & (np.asarray(g.vertex_gid) < nl)
                     & (np.asarray(g.vertex_mask))).sum())
    print(f"BM: {iters} global iterations, {n_matched} lefts matched")

    # ---- the local-phase hot loop as a Pallas kernel ---------------------
    from repro.kernels.ell_spmv import ell_spmv, to_ell
    idx, val, msk = to_ell(edges, n, weights=np.ones(len(edges), np.float32))
    x = jnp.ones((n,), jnp.float32)
    y = ell_spmv(idx, val, msk, x, semiring="add_mul")
    print(f"Pallas ELL-SpMV: y[:4] = {np.asarray(y[:4])} "
          f"(= in-degrees; interpret mode on CPU, Mosaic on TPU)")


if __name__ == "__main__":
    main()
