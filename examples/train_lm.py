"""End-to-end training driver: train a ~100M-param dense LM for a few hundred
steps on the synthetic pipeline, with checkpointing and restart.

    PYTHONPATH=src python examples/train_lm.py --steps 300

(~100M model: 12 x 512 transformer with a 32k vocab; on this CPU container a
step takes O(seconds) — the same step function shards onto the production
mesh with the specs from repro.sharding.rules.)
"""

import argparse
import dataclasses
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.checkpoint import AsyncCheckpointer
from repro.checkpoint.ckpt import latest_checkpoint, load_checkpoint
from repro.configs.base import ArchConfig, LayerSpec
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticTokens
from repro.models.registry import count_params, get_model
from repro.optim.adamw import adamw_init
from repro.train.trainer import make_train_step


def small_lm() -> ArchConfig:
    # ~100M params: 21M embedding (32k x 640, tied) + 14 x 5.7M layers
    return ArchConfig(
        name="demo-100m", family="dense", n_layers=14, d_model=640,
        n_heads=10, n_kv_heads=5, head_dim=64, d_ff=2304, vocab=32_768,
        pattern=(LayerSpec(),), tie_embeddings=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_demo_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    args = ap.parse_args()

    cfg = small_lm()
    api = get_model(cfg)
    print(f"model: {cfg.name}, {count_params(cfg)/1e6:.0f}M params")

    params = api.init(jax.random.PRNGKey(0), cfg, jnp.float32)
    opt = adamw_init(params)
    start_step = 0
    latest = latest_checkpoint(args.ckpt_dir)
    if latest:
        state, start_step = load_checkpoint(latest, {"p": params, "o": opt})
        params, opt = state["p"], state["o"]
        print(f"restored checkpoint at step {start_step}")

    step_fn = jax.jit(make_train_step(cfg, api, peak_lr=3e-4, warmup=50,
                                      total_steps=args.steps))
    data = SyntheticTokens(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                      global_batch=args.batch))
    ckpt = AsyncCheckpointer(args.ckpt_dir, keep=2)

    t0 = time.time()
    for step in range(start_step, args.steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
        params, opt, m = step_fn(params, opt, batch, jnp.asarray(step))
        if step % 20 == 0 or step == args.steps - 1:
            dt = time.time() - t0
            print(f"step {step:4d}  loss {float(m['loss']):.4f}  "
                  f"gnorm {float(m['grad_norm']):.3f}  "
                  f"lr {float(m['lr']):.2e}  ({dt:.1f}s)")
        if step and step % args.ckpt_every == 0:
            ckpt.save(step, {"p": params, "o": opt})
    ckpt.save(args.steps, {"p": params, "o": opt})
    ckpt.close()
    print("done; checkpoints in", args.ckpt_dir)


if __name__ == "__main__":
    main()
