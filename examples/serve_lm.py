"""Batched serving example: submit ragged prompts, run the batch engine
(left-padded lockstep decode with exact positions/masks), print completions.

    PYTHONPATH=src python examples/serve_lm.py
"""

import sys

import numpy as np

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.registry import get_model
from repro.serve.engine import ServeEngine


def main():
    cfg = get_config("phi4-mini-3.8b", smoke=True)   # reduced config on CPU
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg, jnp.float32)

    engine = ServeEngine(cfg, api, params, max_batch=4, max_len=128)
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab, size=(l,)) for l in (5, 12, 3, 9, 7)]
    reqs = [engine.submit(p, max_new=16) for p in prompts]
    done = engine.run(temperature=0.0)
    for r in done:
        print(f"req {r.request_id}: prompt[{len(r.prompt)}] -> {r.result}")
    print(f"served {len(done)} requests in "
          f"{(len(prompts) + 3) // 4} batches")


if __name__ == "__main__":
    main()
