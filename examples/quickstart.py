"""Quickstart: the GraphHP hybrid engine vs standard BSP on one road network.

    PYTHONPATH=src python examples/quickstart.py

Reproduces the paper's headline result in miniature: the hybrid execution
model collapses thousands of global supersteps into a handful of global
iterations, with the same fixed point (here: SSSP distances vs Dijkstra).
"""

import sys

import numpy as np

sys.path.insert(0, "src")

from repro.core import (bfs_partition, build_partitioned_graph, run_am,
                        run_bsp, run_hybrid)
from repro.core.apps import SSSP
from repro.data.graphs import grid_graph


def main():
    # a long thin lattice = high-diameter road network (USA-Road-NE role)
    edges, weights, n = grid_graph(8, 150, seed=0)
    print(f"graph: {n} vertices, {len(edges)} edges")

    part = bfs_partition(edges, n, n_partitions=8, seed=0)
    graph = build_partitioned_graph(edges, n, part, weights=weights)
    print(f"partitioned: {graph.shape_summary}")

    print(f"{'engine':>10} {'global iters':>12} {'net msgs':>10} "
          f"{'in-mem msgs':>12}")
    results = {}
    for name, runner in (("hama", run_bsp), ("am-hama", run_am),
                         ("graphhp", run_hybrid)):
        es, iters = runner(graph, SSSP(source=0))
        m = int(es.counters.net_messages)
        if name == "hama":
            m += int(es.counters.net_local_messages)
        print(f"{name:>10} {iters:>12} {m:>10} "
              f"{int(es.counters.mem_messages):>12}")
        results[name] = (es, iters)

    # all engines agree
    d0 = np.asarray(results["hama"][0].state["dist"])
    for name in ("am-hama", "graphhp"):
        np.testing.assert_allclose(
            np.asarray(results[name][0].state["dist"]), d0, rtol=1e-5)
    speedup = results["hama"][1] / results["graphhp"][1]
    print(f"\nGraphHP used {speedup:.0f}x fewer global iterations "
          f"(paper Fig. 3: hundreds-fold on USA-Road-NE)")


if __name__ == "__main__":
    main()
