"""Graph-query serving example: load a graph once, submit a mix of point
queries (SSSP distances, widest paths, reachability, personalized
PageRank), and let the engine micro-batch them into K-lane dispatches.

    PYTHONPATH=src python examples/serve_graph.py

Shows both drain modes: ``run()`` (one jitted device-side run per batch)
and ``stream()`` (host-stepped; each query comes back as soon as *its*
lane converges, while the rest of the batch keeps iterating).
"""

import sys

import numpy as np

sys.path.insert(0, "src")

from repro.core import build_partitioned_graph
from repro.core.apps.pagerank import pagerank_edge_weights
from repro.data.graphs import rmat_graph
from repro.serve import ServeEngine


def main():
    # 1/out_degree weights: valid shortest-path weights (positive), and
    # exactly what the ppr recurrence needs to stay contractive.
    edges, n = rmat_graph(128, avg_degree=5, seed=7)
    weights = pagerank_edge_weights(edges, n)
    graph = build_partitioned_graph(edges, n, "hash", n_partitions=4,
                                    weights=weights)
    print(f"graph: {n} vertices, {len(edges)} edges")

    # One engine, one compile per (program, lane width): the 4 sssp
    # queries below share a single 4-lane dispatch.
    eng = ServeEngine(graph, lane_widths=(1, 4))
    for s in (0, 17, 101, n - 1):
        eng.submit("sssp", source=s)
    eng.submit("widest", source=0)
    eng.submit("ppr", source=17)

    for q in eng.run():
        res = np.asarray(q.result)
        finite = np.isfinite(res) if res.dtype.kind == "f" else res
        print(f"req {q.request_id:2d} {q.program:>6}(source={q.source:4d}) "
              f"-> {int(np.count_nonzero(finite))}/{n} vertices touched")

    # Streaming: lanes converge at different iterations and are yielded
    # as they do — a short-radius query returns before a long one.
    for s in (0, 17, 101, n - 1):
        eng.submit("sssp", source=s)
    for q in eng.stream():
        print(f"req {q.request_id:2d} sssp(source={q.source:4d}) "
              f"converged at iteration {q.iterations}")


if __name__ == "__main__":
    main()
