"""Reproduction of the paper's experimental tables on synthetic stand-ins
for its datasets (Table 1 -> data/graphs.py):

  Fig. 3 / Table 2  — SSSP on road networks: I / M / T per engine,
                      partition sweep
  Fig. 4            — PageRank convergence vs tolerance threshold
  Fig. 5            — PageRank scalability vs #partitions
  Table 3           — Bipartite matching on citation-ish + geometric graphs
  Table 4 (proxy)   — GraphHP vs the Giraph++-style one-sweep-per-iteration
                      execution (see engine note below)

Each row reports the paper's metrics: I (global iterations), M (network
messages, post-combine), T (wall seconds on this host — engine-relative
only; the cluster numbers in the paper are not reproducible on one CPU).
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core import (bfs_partition, build_partitioned_graph,
                        hash_partition, run_am, run_bsp, run_hybrid)
from repro.core.apps import SSSP, WCC, BipartiteMatching, IncrementalPageRank
from repro.core.apps.pagerank import pagerank_edge_weights
from repro.data.graphs import (bipartite_graph, geometric_graph, grid_graph,
                               rmat_graph)

ENGINES = {"hama": run_bsp, "am-hama": run_am, "graphhp": run_hybrid}


@dataclasses.dataclass
class Row:
    table: str
    engine: str
    config: str
    iterations: int
    net_messages: int
    mem_messages: int
    seconds: float

    def csv(self) -> str:
        us = self.seconds * 1e6
        derived = (f"I={self.iterations};M={self.net_messages};"
                   f"mem={self.mem_messages}")
        return f"{self.table}/{self.config}/{self.engine},{us:.0f},{derived}"


def _run(table, engine_name, config, graph, prog, vdata=None, **kw) -> Row:
    fn = ENGINES[engine_name]
    t0 = time.perf_counter()
    es, iters = fn(graph, prog, vdata=vdata, **kw)
    dt = time.perf_counter() - t0
    net = int(es.counters.net_messages)
    if engine_name == "hama":        # Hama RPCs same-worker messages too
        net += int(es.counters.net_local_messages)
    return Row(table, engine_name, config, iters, net,
               int(es.counters.mem_messages), dt)


# ---------------------------------------------------------------------------

def sssp_road(partition_counts=(4, 8, 12), rows_cols=(12, 220),
              seed=0) -> list[Row]:
    """Fig. 3: high-diameter road network, partition sweep."""
    edges, w, n = grid_graph(*rows_cols, seed=seed)
    out = []
    for p in partition_counts:
        part = bfs_partition(edges, n, p, seed=seed)
        graph = build_partitioned_graph(edges, n, part, weights=w)
        for name in ENGINES:
            out.append(_run("sssp_road", name, f"p{p}", graph, SSSP(source=0)))
    return out


def pagerank_tolerance(tols=(1e-2, 1e-3, 1e-4, 1e-5), n=4000, parts=8,
                       seed=1) -> list[Row]:
    """Fig. 4: convergence vs tolerance on a power-law web graph."""
    edges, n = rmat_graph(n, avg_degree=8, seed=seed)
    w = pagerank_edge_weights(edges, n)
    part = bfs_partition(edges, n, parts, seed=seed)   # ParMetis role (§7.1)
    graph = build_partitioned_graph(edges, n, part, weights=w)
    out = []
    for tol in tols:
        for name in ENGINES:
            out.append(_run("pagerank_tol", name, f"tol{tol:g}", graph,
                            IncrementalPageRank(tolerance=tol)))
    return out


def pagerank_scalability(partition_counts=(4, 8, 16), n=4000, tol=1e-4,
                         seed=2) -> list[Row]:
    """Fig. 5: performance vs #partitions."""
    edges, n = rmat_graph(n, avg_degree=8, seed=seed)
    w = pagerank_edge_weights(edges, n)
    out = []
    for p in partition_counts:
        part = bfs_partition(edges, n, p, seed=seed)   # ParMetis role (§7.1)
        graph = build_partitioned_graph(edges, n, part, weights=w)
        for name in ENGINES:
            out.append(_run("pagerank_scale", name, f"p{p}", graph,
                            IncrementalPageRank(tolerance=tol)))
    return out


def bipartite_matching_table(seed=3) -> list[Row]:
    """Table 3: citation-ish random bipartite + geometric (delaunay role)."""
    out = []
    datasets = {}
    e1, nl1, n1 = bipartite_graph(1200, 1000, avg_degree=4, seed=seed)
    datasets["cit-like"] = (e1, nl1, n1, 8)
    # geometric graph -> bipartify by parity of vertex id
    ge, gn = geometric_graph(2000, seed=seed)
    sel = (ge[:, 0] % 2 == 0) & (ge[:, 1] % 2 == 1)
    e2 = ge[sel]
    e2 = np.concatenate([e2, e2[:, ::-1]], axis=0)
    datasets["geom-like"] = (e2, gn, gn, 8)   # is_left by parity, see below
    for dname, (edges, nl, n, p) in datasets.items():
        part = bfs_partition(edges, n, p, seed=seed)   # ParMetis role (§7.1)
        graph = build_partitioned_graph(edges, n, part)
        import jax.numpy as jnp
        if dname == "cit-like":
            is_left = graph.vertex_gid < nl
        else:
            is_left = graph.vertex_gid % 2 == 0
        vdata = {"is_left": is_left, "degree": graph.out_degree}
        for name in ENGINES:
            out.append(_run("bm", name, dname, graph,
                            BipartiteMatching(seed=seed), vdata=vdata,
                            max_iters=600))
    return out


def giraphpp_proxy(n=4000, tol=1e-4, parts=8, seed=2) -> list[Row]:
    """Table 4 proxy: Giraph++'s graph-centric PageRank sweeps each
    partition's vertices ONCE per global iteration (its bsp() scans active
    vertices and propagates in-partition immediately) — which is exactly the
    AM-Hama engine here — while GraphHP iterates pseudo-supersteps to
    convergence.  Reported next to each other as the paper's Table 4."""
    edges, n = rmat_graph(n, avg_degree=8, seed=seed)
    w = pagerank_edge_weights(edges, n)
    part = bfs_partition(edges, n, parts, seed=seed)
    graph = build_partitioned_graph(edges, n, part, weights=w)
    rows = [
        _run("giraphpp_vs", "am-hama", "giraphpp-proxy", graph,
             IncrementalPageRank(tolerance=tol)),
        _run("giraphpp_vs", "graphhp", "graphhp", graph,
             IncrementalPageRank(tolerance=tol)),
    ]
    return rows
