"""Kernel-regression gates over the committed BENCH_*.json trajectories.

Thresholds live in ``benchmarks/gates.json`` (checked in, reviewed like
code) instead of an inline CI heredoc; each gate names a benchmark table, a
workload (or ``"*"`` for every workload in the table), a metric — a dotted /
indexed path into the workload record, or a list of candidate paths of which
the best present value counts — and an inclusive ``min`` and/or ``max``
bar (booleans count as 0/1, so ``min: 1`` gates a flag).  Bars are
deliberately loose relative to the real margins recorded in the JSONs:
shared CI runners are noisy, and the gate exists to catch the kernel path
regressing toward dense, not to measure it.

    python benchmarks/check_gates.py [--table local_phase|dist_phase]
    python benchmarks/check_gates.py --gates path/to/gates.json

Exits non-zero (listing every violated gate) on failure.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_GATES = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                             "gates.json")


def metric_value(record: dict, spec, prefer: str = "max"):
    """Resolve a metric spec against one workload record.

    A list spec means "best of the present candidates" (e.g. a workload may
    carry a fused variant or not) — best in the direction of the bound, so
    ``prefer='min'`` for ceiling-only gates; a string spec is a dotted path
    with ``[i]`` list indexing.  Returns None when the path is absent.
    """
    if isinstance(spec, list):
        vals = [v for v in (metric_value(record, s) for s in spec)
                if v is not None]
        best = min if prefer == "min" else max
        return best(vals) if vals else None
    cur = record
    for part in spec.replace("]", "").replace("[", ".").split("."):
        if isinstance(cur, list):
            i = int(part)
            cur = cur[i] if 0 <= i < len(cur) else None
        elif isinstance(cur, dict):
            cur = cur.get(part)
        else:
            return None
        if cur is None:
            return None
    return cur


def check_table(name: str, cfg: dict, root: str = REPO_ROOT) -> list[str]:
    """Apply one table's gates; returns human-readable failure strings."""
    path = os.path.join(root, cfg["file"])
    if not os.path.exists(path):
        return [f"{name}: benchmark output {cfg['file']} missing "
                f"(run `python -m benchmarks.run --fast --table {name}`)"]
    with open(path) as f:
        workloads = json.load(f)["workloads"]
    failures = []
    for gate in cfg["gates"]:
        names = (sorted(workloads) if gate["workload"] == "*"
                 else [gate["workload"]])
        for wl in names:
            rec = workloads.get(wl)
            if rec is None:
                failures.append(f"{name}/{wl}: workload missing from "
                                f"{cfg['file']}")
                continue
            prefer = ("min" if ("max" in gate and "min" not in gate)
                      else "max")
            v = metric_value(rec, gate["metric"], prefer=prefer)
            tag = (gate["metric"] if isinstance(gate["metric"], str)
                   else "|".join(gate["metric"]))
            if v is None:
                failures.append(f"{name}/{wl}: metric {tag} absent")
                continue
            lo, hi = gate.get("min"), gate.get("max")
            ok = ((lo is None or v >= lo) and (hi is None or v <= hi))
            bar = " ".join(([f">= {lo}"] if lo is not None else [])
                           + ([f"<= {hi}"] if hi is not None else []))
            print(f"{'PASS' if ok else 'FAIL'} {name}/{wl} {tag}="
                  f"{v:.2f} ({bar}) — {gate['label']}")
            if not ok:
                failures.append(f"{name}/{wl}: {tag}={v:.2f} not {bar} "
                                f"({gate['label']})")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--table", default=None,
                    help="check a single table (default: every table in the "
                         "gates spec; a missing BENCH json fails its table)")
    ap.add_argument("--gates", default=DEFAULT_GATES,
                    help="path to the gates spec (default: checked-in "
                         "benchmarks/gates.json)")
    args = ap.parse_args()

    with open(args.gates) as f:
        spec = json.load(f)
    if args.table is not None:
        if args.table not in spec:
            print(f"unknown table {args.table!r}; have {sorted(spec)}")
            return 2
        spec = {args.table: spec[args.table]}

    failures = []
    for name, cfg in spec.items():
        failures += check_table(name, cfg)
    if failures:
        print("\nregression gates FAILED:")
        for f in failures:
            print(f"  - {f}")
        return 1
    print("\nall regression gates passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
