"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from
results/dryrun/*.json.

    PYTHONPATH=src python -m benchmarks.report [--out results/roofline.md]
"""

from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.roofline import PEAK_FLOPS

ARCH_ORDER = [
    "graphhp-paper",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k",
               "hybrid_iteration", "global_sync"]


def model_flops_per_device(rec) -> float | None:
    """6·N·D (train) / 2·N·D (inference fwd), active params for MoE,
    divided over the mesh."""
    from repro.configs.base import SHAPES, get_config
    from repro.models.registry import count_params
    if rec["arch"] == "graphhp-paper" or rec["shape"] not in SHAPES:
        return None
    try:
        cfg = get_config(rec["arch"])
    except KeyError:        # result row from a since-pruned LM preset
        return None
    shape = SHAPES[rec["shape"]]
    n = count_params(cfg, active_only=True)
    if shape.kind == "train":
        d = shape.global_batch * shape.seq_len
        mult = 6.0
    elif shape.kind == "prefill":
        d = shape.global_batch * shape.seq_len
        mult = 2.0
    else:  # decode: one token per sequence
        d = shape.global_batch
        mult = 2.0
    return mult * n * d / rec.get("devices", 256)


def rows(out_dir: str, mesh: str):
    out = []
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            fn = os.path.join(out_dir, f"{arch}__{shape}__{mesh}.json")
            if not os.path.exists(fn):
                continue
            with open(fn) as f:
                rec = json.load(f)
            out.append(rec)
    return out


def fmt(x, unit=""):
    if x is None:
        return "—"
    if x == 0:
        return "0"
    for div, suf in ((1e15, "P"), (1e12, "T"), (1e9, "G"), (1e6, "M"),
                     (1e3, "k")):
        if abs(x) >= div:
            return f"{x/div:.2f}{suf}{unit}"
    return f"{x:.3g}{unit}"


def render(out_dir: str = "results/dryrun") -> str:
    lines = []
    for mesh, title in (("single", "single-pod (16×16 = 256 chips)"),
                        ("multi", "multi-pod (2×16×16 = 512 chips)")):
        recs = rows(out_dir, mesh)
        if not recs:
            continue
        lines.append(f"\n### Mesh: {title}\n")
        lines.append(
            "| arch | shape | status | mem/dev | t_compute | t_memory | "
            "t_collective | dominant | MODEL/HLO flops | note |")
        lines.append("|---|---|---|---|---|---|---|---|---|---|")
        for rec in recs:
            arch, shape = rec["arch"], rec["shape"]
            if rec["status"] == "skip":
                lines.append(f"| {arch} | {shape} | SKIP | — | — | — | — | — "
                             f"| — | {rec['reason'][:60]}… |")
                continue
            if rec["status"] != "ok":
                lines.append(f"| {arch} | {shape} | **FAIL** | — | — | — | — "
                             f"| — | — | {rec.get('error','')[:60]} |")
                continue
            t = rec["roofline"]
            mem = rec.get("memory", {}).get("bytes_per_device", 0) / 2**30
            mf = model_flops_per_device(rec)
            ratio = f"{mf / t['flops']:.2f}" if mf and t["flops"] else "—"
            note = ""
            if mem > 16:
                note = "exceeds v5e HBM → §Perf target"
            lines.append(
                f"| {arch} | {shape} | ok | {mem:.1f}GiB "
                f"| {t['t_compute_s']*1e3:.1f}ms | {t['t_memory_s']*1e3:.1f}ms "
                f"| {t['t_collective_s']*1e3:.1f}ms | {t['dominant']} "
                f"| {ratio} | {note} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    text = render(args.dir)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
    print(text)


if __name__ == "__main__":
    main()
