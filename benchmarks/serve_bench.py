"""Serving-layer benchmark: K-lane micro-batching vs sequential dispatch.

N SSSP queries are served through :class:`repro.serve.ServeEngine` at each
micro-batch width K in {1, 4, 16, 64}: the engine pads each batch to
exactly K lanes and answers it as ONE K-lane run of the hybrid engine, so
the A/B is K-lane dispatch vs K sequential single-lane dispatches of the
same compiled program (K=1 row).  Per query we record service latency
(every query in a batch completes when its batch completes) and derive
throughput; ``parity_bitexact`` checks that every width returns
bit-identical per-query results.

Sized like ``ft_bench``: the gated workload is an R-MAT graph at 10^6
edges; ``--fast`` swaps in 10^5 (dropping the gated workload, so CI runs
it full).  Also like ``ft_bench`` at this scale, the engine runs with
``use_ell=False``: on CI hosts the Pallas kernels execute in interpret
mode, where compile time at 10^6 edges would swamp the measurement — the
micro-batching margin being measured (shared traversal + per-dispatch
overhead amortized over lanes) is the same on either delivery path.

Writes ``BENCH_serve.json`` (gated via benchmarks/gates.json):

    PYTHONPATH=src python -m benchmarks.serve_bench [--fast]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(REPO_ROOT, "BENCH_serve.json")

N_QUERIES = 16
WIDTHS = (1, 4, 16, 64)
SIZES = {"rmat_1e6": 125_000, "rmat_1e5": 12_500}
AVG_DEGREE = 8


def _graph(n_vertices: int):
    from repro.core.graph import build_partitioned_graph
    from repro.data.graphs import rmat_graph

    edges, n = rmat_graph(n_vertices, avg_degree=AVG_DEGREE, seed=0)
    w = (np.abs(np.sin(np.arange(len(edges)))) * 0.9 + 0.05).astype(
        np.float32)
    return build_partitioned_graph(edges, n, "hash", weights=w,
                                   n_partitions=8), len(edges)


def _serve_at_width(graph, k: int, sources) -> tuple[dict, list]:
    """Serve the query set with every batch padded to exactly k lanes.

    Returns (metrics, per-query results).  One warmup dispatch first, so
    the numbers are the steady-state serving cost (compile time is
    reported separately, not folded into qps).
    """
    import jax
    from repro.serve import ServeEngine

    eng = ServeEngine(graph, lane_widths=(k,), use_ell=False)
    t0 = time.perf_counter()
    eng.submit("sssp", int(sources[0]))
    eng.run()
    compile_s = time.perf_counter() - t0

    lat, results, wall = [], [], 0.0
    for i in range(0, len(sources), k):
        chunk = sources[i:i + k]
        qs = [eng.submit("sssp", int(s)) for s in chunk]
        t0 = time.perf_counter()
        done = eng.run()
        jax.block_until_ready(done[0].result)
        dt = time.perf_counter() - t0
        wall += dt
        lat += [dt] * len(qs)
        results += [q.result for q in done]
    lat = np.asarray(lat)
    return {
        "dispatches": int(np.ceil(len(sources) / k)),
        "wall_s": round(wall, 4),
        "qps": round(len(sources) / wall, 4),
        "p50_ms": round(float(np.percentile(lat, 50)) * 1e3, 1),
        "p99_ms": round(float(np.percentile(lat, 99)) * 1e3, 1),
        "compile_s": round(compile_s, 2),
    }, results


def bench_serve(fast: bool = False, out_path: str = DEFAULT_OUT) -> dict:
    name = "rmat_1e5" if fast else "rmat_1e6"
    graph, n_edges = _graph(SIZES[name])
    rng = np.random.RandomState(7)
    sources = rng.choice(SIZES[name], size=N_QUERIES, replace=False)

    widths, all_results = {}, {}
    for k in WIDTHS:
        widths[str(k)], all_results[k] = _serve_at_width(graph, k, sources)

    # bit-exact parity: every width returns the single-dispatch answers
    base = all_results[1]
    parity = all(np.array_equal(base[i], all_results[k][i])
                 for k in WIDTHS[1:] for i in range(N_QUERIES))

    seq_qps = widths["1"]["qps"]
    rec = {
        "graph": f"V={SIZES[name]} E={n_edges} k={AVG_DEGREE}",
        "n_edges": n_edges,
        "n_queries": N_QUERIES,
        "widths": widths,
        "parity_bitexact": int(parity),
    }
    for k in WIDTHS[1:]:
        rec[f"speedup_k{k}_vs_seq"] = round(widths[str(k)]["qps"] / seq_qps,
                                            3)
    import jax
    out = {
        "meta": {"backend": jax.default_backend(), "use_ell": False,
                 "program": "sssp", "fast": fast},
        "workloads": {name: rec},
    }
    with open(out_path, "w") as f:
        json.dump(out, f, indent=1, sort_keys=True)
    return out


def csv_rows(out: dict) -> list[str]:
    rows = []
    for wl, rec in out["workloads"].items():
        for k, m in rec["widths"].items():
            rows.append(
                f"serve/{wl}/K={k},{1e6 / m['qps']:.0f},"
                f"qps={m['qps']};p50_ms={m['p50_ms']};p99_ms={m['p99_ms']};"
                f"dispatches={m['dispatches']}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="10^5-edge graph (drops the gated 10^6 workload)")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()
    out = bench_serve(fast=args.fast, out_path=args.out)
    print("name,us_per_call,derived")
    for r in csv_rows(out):
        print(r)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
