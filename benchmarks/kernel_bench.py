"""Kernel micro-benchmarks: Pallas (interpret) vs pure-jnp reference vs the
engine's segment-sum path, on local-phase-shaped workloads.

On this CPU container absolute numbers mean little (interpret mode runs the
kernel body in Python); the table exists to (a) exercise the kernels at
benchmark shapes and (b) report the DERIVED arithmetic-intensity numbers the
TPU roofline cares about (bytes/edge, flops/edge).
"""

from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp


def _time(fn, *args, iters=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters


def bench_ell_spmv(rows=4096, k=128, n=4096, seed=0) -> list[str]:
    from repro.kernels.ell_spmv import ell_spmv, ell_spmv_ref
    rng = np.random.RandomState(seed)
    idx = jnp.asarray(rng.randint(0, n, size=(rows, k)).astype(np.int32))
    val = jnp.asarray(rng.uniform(size=(rows, k)).astype(np.float32))
    msk = jnp.asarray(rng.uniform(size=(rows, k)) < 0.5)
    x = jnp.asarray(rng.uniform(size=(n,)).astype(np.float32))

    edges = rows * k
    bytes_per_edge = 4 + 4 + 1 + 4          # idx + val + msk + gathered x
    rows_out = []
    for semiring in ("add_mul", "min_add", "max_add", "min_mul", "max_min"):
        t_ref = _time(jax.jit(lambda *a: ell_spmv_ref(*a, semiring=semiring)),
                      idx, val, msk, x)
        t_pal = _time(lambda *a: ell_spmv(*a, semiring=semiring), idx, val,
                      msk, x)
        derived = (f"edges={edges};bytes/edge={bytes_per_edge};"
                   f"ref_us={t_ref*1e6:.0f};interp_ratio={t_pal/t_ref:.1f}")
        rows_out.append(f"kernel/ell_spmv/{semiring},{t_ref*1e6:.0f},{derived}")
    return rows_out


def _time_staged(stages, args, iters=3):
    """Time a chain of separately-jitted stages, materializing between each —
    models the unfused engine path's per-stage HBM round trips, which a
    single jit would fuse away."""
    def run():
        out = args
        for st in stages:
            out = st(*out)
            jax.block_until_ready(out)
        return out
    run()
    t0 = time.perf_counter()
    for _ in range(iters):
        run()
    return (time.perf_counter() - t0) / iters


def bench_fused_pr_step(rows=4096, k=128, seed=1) -> list[str]:
    from repro.kernels.pr_step import fused_pr_step, fused_pr_step_ref
    rng = np.random.RandomState(seed)
    n = rows
    idx = jnp.asarray(rng.randint(0, n, size=(rows, k)).astype(np.int32))
    val = jnp.asarray(rng.uniform(size=(rows, k)).astype(np.float32))
    msk = jnp.asarray(rng.uniform(size=(rows, k)) < 0.5)
    delta = jnp.asarray(rng.uniform(size=(n,)).astype(np.float32) * 0.1)
    send = jnp.asarray(rng.uniform(size=(n,)) < 0.5)
    rank = jnp.asarray(rng.uniform(size=(rows,)).astype(np.float32))

    t_ref = _time(jax.jit(fused_pr_step_ref), idx, val, msk, delta, send, rank)
    t_pal = _time(fused_pr_step, idx, val, msk, delta, send, rank)
    # unfused engine path: gather -> segment-sum -> add -> compare, each its
    # own dispatch (4 HBM trips)
    t_unf = _time_staged(
        [jax.jit(lambda idx, val, msk, delta, send, rank:
                 (jnp.where(send[idx] & msk, 0.85 * val * delta[idx], 0.0),
                  rank)),
         jax.jit(lambda contrib, rank: (jnp.sum(contrib, axis=1), rank)),
         jax.jit(lambda d_in, rank: (rank + d_in, d_in)),
         jax.jit(lambda rank_n, d_in: (rank_n, d_in, d_in > 1e-4))],
        (idx, val, msk, delta, send, rank))
    derived = (f"hbm_trips_fused=1;hbm_trips_unfused=4;"
               f"unfused_us={t_unf*1e6:.0f};interp_ratio={t_pal/t_ref:.1f}")
    return [f"kernel/fused_pr_step,{t_ref*1e6:.0f},{derived}"]


def bench_fused_min_step(rows=4096, k=128, seed=2) -> list[str]:
    from repro.kernels.min_step import fused_min_step, fused_min_step_ref
    rng = np.random.RandomState(seed)
    n = rows
    idx = jnp.asarray(rng.randint(0, n, size=(rows, k)).astype(np.int32))
    val = jnp.asarray(rng.uniform(0.1, 2.0, size=(rows, k)).astype(np.float32))
    msk = jnp.asarray(rng.uniform(size=(rows, k)) < 0.5)
    x = jnp.asarray(rng.uniform(0, 50, size=(n,)).astype(np.float32))
    send = jnp.asarray(rng.uniform(size=(n,)) < 0.5)

    t_ref = _time(jax.jit(fused_min_step_ref), idx, val, msk, x, send)
    t_pal = _time(fused_min_step, idx, val, msk, x, send)
    # unfused engine path: gather -> segment-min -> min -> compare, each its
    # own dispatch (4 HBM trips)
    t_unf = _time_staged(
        [jax.jit(lambda idx, val, msk, x, send:
                 (jnp.where(send[idx] & msk, x[idx] + val, jnp.inf), x)),
         jax.jit(lambda cand, x: (jnp.min(cand, axis=1), x)),
         jax.jit(lambda d_in, x: (jnp.minimum(x, d_in), d_in, x)),
         jax.jit(lambda x_n, d_in, x: (x_n, d_in, d_in < x))],
        (idx, val, msk, x, send))
    derived = (f"hbm_trips_fused=1;hbm_trips_unfused=4;"
               f"unfused_us={t_unf*1e6:.0f};interp_ratio={t_pal/t_ref:.1f}")
    return [f"kernel/fused_min_step,{t_ref*1e6:.0f},{derived}"]
