"""Roofline term derivation from compiled dry-run artifacts.

compute term    = HLO_FLOPs / peak_FLOPs          (per chip, cost_analysis)
memory term     = HLO_bytes / HBM_bw              (per chip, cost_analysis)
collective term = collective_bytes / ICI_bw       (per chip, parsed from HLO)

collective_bytes is NOT in cost_analysis: we parse the compiled module text
and sum wire bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute, with op-specific ring-cost factors.
"""

from __future__ import annotations

import re

PEAK_FLOPS = 197e12          # bf16 per chip (TPU v5e)
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shape_bytes(text: str) -> int:
    """Sum sizes of every `dtype[dims]` group in a type string."""
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Wire bytes per collective kind from a compiled HLO module.

    Ring costs (n = group size, parsed from replica_groups when present):
      all-reduce      2·(n-1)/n · size
      all-gather      (n-1)/n · result_size
      reduce-scatter  (n-1)/n · operand_size
      all-to-all      (n-1)/n · size
      collective-permute  size
    """
    out: dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"(?:ROOT )?%?[\w.\-]+ = (.+?) (\w[\w\-]*)\(", ls)
        if not m:
            continue
        result_type, op = m.groups()
        kind = None
        for k in _COLLECTIVES:
            if op == k or op.startswith(k + "-start") or op == k + "-done":
                kind = k
                break
        if kind is None or op.endswith("-done"):
            continue
        size = _shape_bytes(result_type)
        if kind == "reduce-scatter":
            # operand = result * n; parse operands inside parens instead
            inner = ls[ls.index("(") + 1:]
            size = _shape_bytes(inner.split("),")[0])
        n = _group_size(ls)
        frac = (n - 1) / n if n > 1 else 0.0
        if kind == "all-reduce":
            size = 2 * size * frac
        elif kind == "collective-permute":
            size = size * (1.0 if n > 1 else 0.0)
        else:
            size = size * frac
        out[kind] += size
    out["total"] = sum(out.values())
    return out


def _group_size(line: str) -> int:
    # replica_groups={{0,1,2,...},{...}} or replica_groups=[8,32]<=[256]
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    m = re.search(r"source_target_pairs=", line)
    if m:
        return 2    # permute: pairwise
    return 1


def roofline_terms(cost: dict, hlo_text: str) -> dict:
    flops = float(cost.get("flops", 0.0) or 0.0)
    bytes_hbm = float(cost.get("bytes accessed", 0.0) or 0.0)
    coll = collective_bytes(hlo_text)
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_hbm / HBM_BW
    t_coll = coll["total"] / ICI_BW
    dominant = max((("compute", t_compute), ("memory", t_memory),
                    ("collective", t_coll)), key=lambda kv: kv[1])[0]
    return {
        "flops": flops, "hbm_bytes": bytes_hbm,
        "collective_bytes": coll["total"],
        "collectives": {k: v for k, v in coll.items() if k != "total"},
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
    }


def model_flops(n_params: int, n_tokens: int, kind: str = "train") -> float:
    """MODEL_FLOPS = 6·N·D for training, 2·N·D for inference forward."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_params * n_tokens
