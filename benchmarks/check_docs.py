"""Docs reference checker: every file and ``path.py:symbol`` pointer in
``docs/*.md`` and ``README.md`` must resolve against the tree.

Docs rot by pointing at code that moved; this makes the pointers part of
CI.  Two kinds of references are extracted:

* ``path.py:symbol`` — the file must exist and its module AST must define
  ``symbol`` at top level (function, class, or assignment — so table
  constants like ``SEMIRINGS`` count).
* bare paths (``src/.../x.py``, ``benchmarks/x.json``, ``tests/x.py``,
  ``docs/x.md``, and ``dir/`` directory pointers) — must exist.

    python benchmarks/check_docs.py

Exits non-zero listing every dangling reference.
"""

from __future__ import annotations

import ast
import os
import re
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

DOC_FILES = ["README.md"] + sorted(
    os.path.join("docs", f)
    for f in (os.listdir(os.path.join(REPO_ROOT, "docs"))
              if os.path.isdir(os.path.join(REPO_ROOT, "docs")) else [])
    if f.endswith(".md"))

TOPDIRS = r"(?:src|benchmarks|tests|examples|docs)"
SYMBOL_REF = re.compile(rf"({TOPDIRS}/[\w/.-]+\.py):([A-Za-z_]\w*)")
FILE_REF = re.compile(rf"(?<![\w/.-])({TOPDIRS}/[\w/.-]+\.(?:py|md|json))")
DIR_REF = re.compile(rf"(?<![\w/.-])({TOPDIRS}/(?:[\w.-]+/)*)(?![\w.-])")


def module_symbols(path: str) -> set[str]:
    """Top-level names a module defines: def/class/assign targets."""
    with open(path) as f:
        tree = ast.parse(f.read(), filename=path)
    names: set[str] = set()
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            names.add(node.name)
        elif isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    names.add(t.id)
        elif isinstance(node, ast.AnnAssign) and isinstance(node.target,
                                                            ast.Name):
            names.add(node.target.id)
    return names


def check_doc(doc: str) -> list[str]:
    with open(os.path.join(REPO_ROOT, doc)) as f:
        text = f.read()
    failures = []
    cache: dict[str, set[str]] = {}
    for path, symbol in SYMBOL_REF.findall(text):
        full = os.path.join(REPO_ROOT, path)
        if not os.path.isfile(full):
            failures.append(f"{doc}: {path}:{symbol} — file missing")
            continue
        if path not in cache:
            cache[path] = module_symbols(full)
        if symbol not in cache[path]:
            failures.append(f"{doc}: {path}:{symbol} — symbol not defined "
                            f"at module top level")
    for path in FILE_REF.findall(text):
        if not os.path.isfile(os.path.join(REPO_ROOT, path)):
            failures.append(f"{doc}: {path} — file missing")
    for path in DIR_REF.findall(text):
        if not os.path.isdir(os.path.join(REPO_ROOT, path)):
            failures.append(f"{doc}: {path} — directory missing")
    return failures


def main() -> int:
    failures: list[str] = []
    n_refs = 0
    for doc in DOC_FILES:
        with open(os.path.join(REPO_ROOT, doc)) as f:
            text = f.read()
        n_refs += (len(SYMBOL_REF.findall(text))
                   + len(FILE_REF.findall(text))
                   + len(DIR_REF.findall(text)))
        failures += check_doc(doc)
    print(f"checked {n_refs} references across {len(DOC_FILES)} docs")
    if failures:
        print("dangling doc references:")
        for f in failures:
            print(f"  - {f}")
        return 1
    print("all doc references resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
