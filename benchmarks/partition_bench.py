"""Partitioner A/B benchmark: the quality ladder on the paper's counters.

GraphHP's headline metric — network messages M — is a direct function of
how many in-edges the partitioner keeps internal, so this table A/Bs the
whole ladder (``hash`` / ``bfs`` / ``fennel`` / ``multilevel``) end-to-end
on the three graph families × apps the paper pairs them with:

  rmat_pagerank   — R-MAT power-law web graph, IncrementalPageRank,
  grid_sssp       — road lattice, SSSP,
  geometric_wcc   — random geometric graph (symmetrized), WCC.

Per (workload × partitioner) it records the static quality report
(edge-cut fraction, boundary fraction, replication H/V, balance, estimated
exchange bytes off the built graph's ``export_fanout``), the partitioner's
own build time, the paper counters from a full ``run_hybrid`` to
quiescence (``net_messages``, iterations), and the wall time of one jitted
distributed step (exchange -> global phase -> local convergence) from a
saturated frontier.  Every fixed point is oracle-checked (Bellman-Ford /
union-find / power iteration); SSSP and WCC are additionally pinned
**bit-exact across partitioners** — the partitioner may only move the
traffic, never the answer.

Emits BENCH_partition.json (committed, trajectory-tracked) and harness CSV
rows; ``benchmarks/gates.json`` gates multilevel-vs-hash ratios and
balance in CI.

    PYTHONPATH=src python -m benchmarks.run --fast --table partition
    PYTHONPATH=src python -m benchmarks.partition_bench [--out PATH]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

import jax

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(REPO_ROOT, "BENCH_partition.json")

N_PARTITIONS = 8


# ---------------------------------------------------------------------------
# numpy oracles
# ---------------------------------------------------------------------------

def _sssp_oracle(edges, w, n, src=0):
    dist = np.full(n, np.inf)
    dist[src] = 0.0
    for _ in range(n):
        nd = dist.copy()
        np.minimum.at(nd, edges[:, 1], dist[edges[:, 0]] + w)
        if np.array_equal(nd, dist, equal_nan=True):
            break
        dist = nd
    return dist


def _wcc_oracle(edges, n):
    parent = np.arange(n)

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for u, v in edges:
        ru, rv = find(int(u)), find(int(v))
        if ru != rv:
            parent[max(ru, rv)] = min(ru, rv)
    return np.array([find(i) for i in range(n)])


def _pagerank_oracle(edges, n, iters=300):
    deg = np.bincount(edges[:, 0], minlength=n).astype(np.float64)
    r = np.full(n, 0.15)
    for _ in range(iters):
        contrib = np.zeros(n)
        np.add.at(contrib, edges[:, 1],
                  0.85 * r[edges[:, 0]] / np.maximum(deg[edges[:, 0]], 1))
        r = 0.15 + contrib
    return r


# ---------------------------------------------------------------------------
# the A/B sweep
# ---------------------------------------------------------------------------

def _dist_step_us(graph, prog, payload_value):
    """One jitted hybrid global iteration from a saturated frontier — the
    partition-dependent cost of exchange + global phase + local phase."""
    from benchmarks.local_phase_bench import _saturate, _time_us
    from repro.core.engine_hybrid import hybrid_iteration, init_hybrid

    es = _saturate(graph, prog, init_hybrid(graph, prog, None), payload_value)
    step = jax.jit(lambda e: hybrid_iteration(graph, prog, e, None))
    return _time_us(step, es, warmup=2, iters=5)


def _workloads(fast: bool):
    from repro.core.apps import SSSP, WCC, IncrementalPageRank
    from repro.core.apps.pagerank import pagerank_edge_weights
    from repro.data.graphs import geometric_graph, grid_graph, rmat_graph, \
        symmetrize

    n_rmat = 3000 if fast else 20000
    rc = (10, 120) if fast else (30, 400)
    n_geo = 4000 if fast else 50000

    # (name, edges, n, weights, make_prog, field, payload, make_oracle,
    #  compare, want_bitexact) — make_oracle runs ONCE per workload (the
    # oracle is partition-invariant), compare judges each fixed point
    edges, n = rmat_graph(n_rmat, avg_degree=8, seed=1)
    wpr = pagerank_edge_weights(edges, n)
    yield ("rmat_pagerank", edges, n, wpr,
           lambda: IncrementalPageRank(tolerance=1e-4), "rank", 0.01,
           lambda e=edges, nn=n: _pagerank_oracle(e, nn),
           lambda got, ora: bool(np.allclose(got, ora, rtol=2e-2,
                                             atol=2e-2)), False)

    edges, w, n = grid_graph(*rc, seed=0)
    yield ("grid_sssp", edges, n, w, lambda: SSSP(source=0), "dist", 1.0,
           lambda e=edges, ww=w, nn=n: _sssp_oracle(e, ww, nn),
           lambda got, ora: bool(np.allclose(got, ora, rtol=1e-5,
                                             equal_nan=True)), True)

    edges, n = geometric_graph(n_geo, seed=2)
    edges = symmetrize(edges)
    yield ("geometric_wcc", edges, n, None, WCC, "label", 1.0,
           lambda e=edges, nn=n: _wcc_oracle(e, nn),
           lambda got, ora: bool(np.array_equal(got, ora)), True)


def bench_partitioners(out_path: str = DEFAULT_OUT, fast: bool = True) -> dict:
    from repro.core import build_partitioned_graph, run_hybrid
    from repro.core.graph import unpack_vertex
    from repro.partition import PARTITIONERS, make_partition, partition_report

    results: dict = {"meta": {"backend": jax.default_backend(),
                              "n_partitions": N_PARTITIONS,
                              "fast": bool(fast),
                              "mode": "interpret" if
                              jax.default_backend() != "tpu" else "mosaic"},
                     "workloads": {}}

    for (name, edges, n, w, make_prog, field, payload, make_oracle,
         compare, want_bitexact) in _workloads(fast):
        rec: dict = {"app": make_prog().__class__.__name__,
                     "graph": f"V={n} E={len(edges)} k={N_PARTITIONS}",
                     "partitioners": {}}
        oracle = make_oracle()
        fixed_points = {}
        for pname in PARTITIONERS:
            t0 = time.perf_counter()
            part = make_partition(pname, edges, n, N_PARTITIONS, seed=0)
            build_s = time.perf_counter() - t0
            graph = build_partitioned_graph(edges, n, part, weights=w)
            rep = partition_report(edges, n, part, graph=graph,
                                   n_partitions=N_PARTITIONS)
            es, iters = run_hybrid(graph, make_prog())
            got = unpack_vertex(graph, es.state[field])
            fixed_points[pname] = got
            rec["partitioners"][pname] = {
                "shape": graph.shape_summary,
                "build_s": round(build_s, 4),
                "edge_cut_frac": round(rep.edge_cut_frac, 4),
                "boundary_frac": round(rep.boundary_frac, 4),
                "replication": round(rep.replication, 4),
                "balance": round(rep.balance, 4),
                "exchange_bytes": rep.exchange_bytes,
                "net_messages": int(es.counters.net_messages),
                "net_local_messages": int(es.counters.net_local_messages),
                "iterations": int(iters),
                "dist_step_us": round(_dist_step_us(graph, make_prog(),
                                                    payload)),
                "oracle_ok": compare(got, oracle),
            }
        ps = rec["partitioners"]
        rec["ratios"] = {
            "net_messages_hash_over_multilevel":
                ps["hash"]["net_messages"]
                / max(ps["multilevel"]["net_messages"], 1),
            "edge_cut_hash_over_multilevel":
                ps["hash"]["edge_cut_frac"]
                / max(ps["multilevel"]["edge_cut_frac"], 1e-9),
        }
        if want_bitexact:
            base = fixed_points["hash"]
            rec["bitexact_across_partitioners"] = bool(all(
                np.array_equal(base, fp, equal_nan=True)
                for fp in fixed_points.values()))
        results["workloads"][name] = rec

    if out_path:
        with open(out_path, "w") as f:
            json.dump(results, f, indent=1)
    return results


def csv_rows(results: dict) -> list[str]:
    rows = []
    for name, r in results["workloads"].items():
        for pname, p in r["partitioners"].items():
            derived = (f"cut={p['edge_cut_frac']:.3f};"
                       f"net={p['net_messages']};iters={p['iterations']};"
                       f"balance={p['balance']:.2f};"
                       f"xbytes={p['exchange_bytes']};"
                       f"build_s={p['build_s']:.3f};"
                       f"oracle_ok={p['oracle_ok']}")
            rows.append(f"partition/{name}/{pname},{p['dist_step_us']:.0f},"
                        f"{derived}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=DEFAULT_OUT,
                    help="where to write BENCH_partition.json")
    ap.add_argument("--full", action="store_true",
                    help="paper-sized graphs (default: CI-sized --fast)")
    args = ap.parse_args()
    results = bench_partitioners(args.out, fast=not args.full)
    print("name,us_per_call,derived")
    for row in csv_rows(results):
        print(row)


if __name__ == "__main__":
    import sys
    sys.path.insert(0, REPO_ROOT)
    sys.path.insert(0, os.path.join(REPO_ROOT, "src"))
    main()
