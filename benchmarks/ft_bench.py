"""Fault-tolerance costs: checkpoint overhead, exact resume, recovery.

Long-running jobs only earn checkpointing if the steady-state tax is small
— the paper's pitch for iteration-boundary snapshots is precisely that the
state worth saving is tiny next to the work a global iteration does.  Two
workloads measure that honestly:

``pagerank_1e6`` / ``pagerank_1e5`` — per-iteration wall time for PageRank
on an R-MAT graph (~10^6 / ~10^5 edges), A/B/C over checkpointing modes
from the *same* warmed state with the *same* jitted step:

  * ``wall_none_s``   — k global iterations, no checkpointing,
  * ``wall_sync_s``   — + a blocking :func:`save_checkpoint` per iteration
                        (the naive in-loop design),
  * ``wall_async_s``  — + an :class:`AsyncCheckpointer` save per iteration
                        (host snapshot in-loop, writes off-thread),
                        including the final ``wait()`` drain.

``ratios.overhead_async`` (gated ``<= 1.10`` at the 10^6-edge size) is the
async mode's per-iteration tax; ``overhead_sync`` is the bar it beats.

``recovery_sssp`` — the recovery loop itself, on the engine's SSSP road
fixture: a full run (``wall_rerun_s``), an interrupted run resumed from its
checkpoint (``exact_resume`` — final state and every paper counter
bit-identical to the uninterrupted run), and a deterministically injected
worker kill whose :class:`RecoveryEvent` yields ``recovery_restore_s``,
``iterations_lost``, and ``reads_latest_only`` (the restore read one
durable checkpoint, never the history — gated).

``klane_resume`` — the serving layer's K-lane kill-and-resume on a K=16
SSSP batch over a ~10^6-edge R-MAT graph: the batch is killed at roughly
half lane convergence, then (a) resumed from its ``(program, K,
sources-digest)`` checkpoint family (converged lanes dropped from the
restored frontier) and (b) recomputed from scratch, both through the same
warmed checkpointing executor.  Gated: resume wall <= 0.5x recompute wall,
per-lane results bit-identical, and at least one converged lane actually
dropped at the resume point.

Emits ``BENCH_ft.json`` (committed, trajectory-tracked); gates live in
``benchmarks/gates.json`` table ``ft``.  ``--fast`` drops the gated
10^6-edge workloads (CI runs the table full-size, it is seconds of work).

    PYTHONPATH=src python -m benchmarks.run --table ft [--fast]
    PYTHONPATH=src python -m benchmarks.ft_bench [--fast] [--out PATH]
"""

from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(REPO_ROOT, "BENCH_ft.json")

N_PARTITIONS = 8
AVG_DEGREE = 8
CKPT_ITERS = 6                  # timed iterations per checkpointing mode
# Cap the local phase: unbounded, PageRank at 10^6 edges runs thousands of
# pseudo-supersteps per global iteration toward its tolerance — minutes of
# CPU that tell us nothing about checkpointing.  The cap keeps one global
# iteration a handful of pseudo-supersteps and is *conservative* for the
# overhead gate: cheaper iterations make the fixed per-checkpoint tax
# relatively larger.  The timed step runs the dense delivery path
# (``use_ell=False``): on CI hosts the Pallas kernels execute in interpret
# mode, ~3 orders slower than compiled XLA at this size — minutes per
# iteration that would measure the interpreter, not checkpointing.  The
# state snapshotted per iteration is identical on either path.
MAX_LOCAL_STEPS = 32
# name -> n_vertices (edges ~ AVG_DEGREE * n).  The 10^6-edge row carries
# the overhead gate; --fast keeps only the small row (gates then need the
# full run, same contract as the ingest table).
WORKLOADS = {
    "pagerank_1e5": 12_500,
    "pagerank_1e6": 125_000,
}


def _pagerank_fixture(n_vertices: int):
    from repro.core import build_partitioned_graph, hash_partition
    from repro.core.apps import IncrementalPageRank
    from repro.core.apps.pagerank import pagerank_edge_weights
    from repro.data.graphs import rmat_graph

    edges, n = rmat_graph(n_vertices, avg_degree=AVG_DEGREE, seed=0)
    part = hash_partition(n, N_PARTITIONS, seed=0)
    w = pagerank_edge_weights(edges, n)
    graph = build_partitioned_graph(edges, n, part, weights=w,
                                    build_ell=False)
    return graph, IncrementalPageRank(tolerance=1e-6), len(edges)


def bench_ckpt_overhead(name: str, n_vertices: int,
                        iters: int = CKPT_ITERS) -> dict:
    """A/B/C the per-iteration cost of checkpointing modes on PageRank."""
    import jax
    from repro.checkpoint import AsyncCheckpointer, save_checkpoint
    from repro.checkpoint.ckpt import checkpoint_bytes, latest_checkpoint
    from repro.core.engine_hybrid import hybrid_iteration, init_hybrid

    graph, prog, n_edges = _pagerank_fixture(n_vertices)
    step = jax.jit(lambda e: hybrid_iteration(
        graph, prog, e, None, max_local_steps=MAX_LOCAL_STEPS,
        use_ell=False))
    es0 = jax.block_until_ready(step(
        init_hybrid(graph, prog, None, use_ell=False)))

    def timed(save=None, drain=None) -> float:
        es = es0
        t0 = time.perf_counter()
        for i in range(iters):
            es = jax.block_until_ready(step(es))
            if save is not None:
                save(i + 1, es)
        if drain is not None:
            drain()                 # in-flight writes become durable
        return time.perf_counter() - t0

    timed()                     # untimed warmup pass (allocator/cache)
    wall_none = timed()
    with tempfile.TemporaryDirectory() as d:
        wall_sync = timed(save=lambda i, es: save_checkpoint(
            os.path.join(d, "sync", f"step_{i:08d}"), es, i))
        ck = AsyncCheckpointer(os.path.join(d, "async"), keep=3)
        wall_async = timed(save=ck.save, drain=ck.wait)
        ck.close()
        ckpt_mb = checkpoint_bytes(
            latest_checkpoint(os.path.join(d, "async"))) / 2**20
    return {
        "n_edges": n_edges,
        "iters": iters,
        "wall_none_s": round(wall_none, 4),
        "wall_sync_s": round(wall_sync, 4),
        "wall_async_s": round(wall_async, 4),
        "per_iter_none_us": round(wall_none / iters * 1e6, 1),
        "ckpt_mb": round(ckpt_mb, 2),
        "ratios": {
            "overhead_sync": round(wall_sync / wall_none, 4),
            "overhead_async": round(wall_async / wall_none, 4),
        },
    }


def bench_recovery() -> dict:
    """Exact resume + injected-failure recovery on the SSSP road fixture."""
    import numpy as np
    from repro.checkpoint import AsyncCheckpointer
    from repro.checkpoint.ckpt import checkpoint_bytes
    from repro.core import bfs_partition, build_partitioned_graph
    from repro.core.apps import SSSP
    from repro.data.graphs import grid_graph
    from repro.ft import FaultInjector, FaultPlan, run_hybrid_ft

    edges, w, n = grid_graph(6, 60, seed=3)
    part = bfs_partition(edges, n, 6, seed=1)
    graph = build_partitioned_graph(edges, n, part, weights=w)

    def identical(a, b) -> bool:
        ok = bool(np.array_equal(np.asarray(a.state["dist"]),
                                 np.asarray(b.state["dist"])))
        for f in ("iterations", "net_messages", "net_local_messages",
                  "mem_messages"):
            ok &= int(getattr(a.counters, f)) == int(getattr(b.counters, f))
        return ok and bool(np.array_equal(
            np.asarray(a.counters.pseudo_supersteps),
            np.asarray(b.counters.pseudo_supersteps)))

    t0 = time.perf_counter()
    ref = run_hybrid_ft(graph, SSSP(source=0))
    wall_rerun = time.perf_counter() - t0

    with tempfile.TemporaryDirectory() as d:
        # interrupt after iteration 2, restart from the checkpoint
        run_hybrid_ft(graph, SSSP(source=0), ckpt_dir=os.path.join(d, "r"),
                      max_iters=2)
        t0 = time.perf_counter()
        res = run_hybrid_ft(graph, SSSP(source=0),
                            ckpt_dir=os.path.join(d, "r"))
        wall_resume = time.perf_counter() - t0
        exact = res.resumed_from is not None and identical(res.es, ref.es)

        # scripted worker kill: heartbeat sweep -> reassign -> restore
        ck = AsyncCheckpointer(os.path.join(d, "f"), keep=3)
        inj = FaultInjector(FaultPlan.kill_at(3, worker=1), n_workers=4)
        rec = run_hybrid_ft(graph, SSSP(source=0), checkpointer=ck,
                            n_workers=4, injector=inj)
        ck.close()
        ev = rec.recoveries[0]
        steps = [os.path.join(d, "f", s) for s in os.listdir(
            os.path.join(d, "f")) if s.startswith("step_")]
        largest = max(checkpoint_bytes(p) for p in steps)
        recovered = identical(rec.es, ref.es)

    return {
        "iterations": ref.iterations,
        "wall_rerun_s": round(wall_rerun, 4),
        "wall_resume_s": round(wall_resume, 4),
        "exact_resume": int(exact),
        "recovery_exact": int(recovered),
        "recovery_restore_s": round(ev.restore_seconds, 4),
        "recovery_bytes_read": ev.bytes_read,
        "iterations_lost": ev.iterations_lost,
        "partitions_moved": sum(len(v) for v in ev.moved.values()),
        # the restore read exactly one durable checkpoint — never a history
        # replay or a from-scratch rebuild
        "reads_latest_only": int(0 < ev.bytes_read <= largest),
        "ratios": {
            "resume_over_rerun": round(wall_resume / wall_rerun, 4),
            "restore_over_rerun": round(ev.restore_seconds / wall_rerun, 4),
        },
    }


def bench_klane_resume(n_vertices: int = 125_000, lanes: int = 16) -> dict:
    """Kill/resume a half-converged K-lane serving batch vs recompute."""
    import numpy as np
    from repro.core import build_partitioned_graph, hash_partition
    from repro.data.graphs import rmat_graph
    from repro.serve import ServeEngine

    edges, n = rmat_graph(n_vertices, avg_degree=AVG_DEGREE, seed=5)
    rng = np.random.RandomState(7)
    w = rng.uniform(0.05, 1.0, len(edges)).astype(np.float32)
    part = hash_partition(n, N_PARTITIONS, seed=0)
    # dense delivery for the same reason as the overhead rows: interpret-mode
    # Pallas would measure the interpreter, not the resume machinery
    graph = build_partitioned_graph(edges, n, part, weights=w,
                                    build_ell=False)
    srcs = [int(s) for s in rng.choice(n, size=lanes, replace=False)]

    # Per-lane convergence iterations (untimed probe).  Kill one iteration
    # short of the last lane's convergence: on this fixture roughly half
    # the lanes (9/16, gated) have converged by the latest durable
    # checkpoint, so the resume both skips most of the redone iterations
    # AND exercises the converged-lane frontier drop.  (On the dense
    # delivery path an iteration costs O(E) regardless of frontier size,
    # so resume/recompute is essentially iterations-rerun/iterations.)
    probe = ServeEngine(graph, lane_widths=(lanes,), use_ell=False)
    for s in srcs:
        probe.submit("sssp", s)
    conv = sorted(q.iterations for q in probe.stream())
    kill_at = conv[-1] - 1               # durable ckpt lands at kill_at - 1

    with tempfile.TemporaryDirectory() as d:
        armed = [True]

        def killer(eng, prog, K, iteration):
            if armed[0] and iteration == kill_at:
                raise KeyboardInterrupt("injected kill")

        eng = ServeEngine(graph, lane_widths=(lanes,), use_ell=False,
                          ckpt_dir=os.path.join(d, "serve"),
                          on_iteration=killer)
        # warm run + kill: pays the (sssp, K) compile, leaves the batch's
        # checkpoint family durable at iteration kill_at - 1
        for s in srcs:
            eng.submit("sssp", s)
        try:
            eng.run()
            raise RuntimeError("injected kill did not fire")
        except KeyboardInterrupt:
            pass
        armed[0] = False

        # (a) resume from the checkpoint family (deleted on completion)
        qs_resume = [eng.submit("sssp", s) for s in srcs]
        t0 = time.perf_counter()
        eng.run()
        wall_resume = time.perf_counter() - t0
        [ev] = eng.resume_events

        # (b) recompute from scratch through the same warmed engine,
        # still checkpointing every iteration (apples-to-apples)
        qs_re = [eng.submit("sssp", s) for s in srcs]
        t0 = time.perf_counter()
        eng.run()
        wall_recompute = time.perf_counter() - t0

    bitexact = all(np.array_equal(a.result, b.result)
                   for a, b in zip(qs_resume, qs_re))
    return {
        "n_edges": len(edges),
        "lanes": lanes,
        "iterations": int(qs_re[0].iterations),
        "conv_iterations": conv,
        "resumed_at_iteration": ev.iteration,
        "lanes_dropped": sum(ev.lanes_done),
        "bitexact": int(bitexact),
        "wall_resume_s": round(wall_resume, 4),
        "wall_recompute_s": round(wall_recompute, 4),
        "ratios": {
            "resume_over_recompute": round(wall_resume / wall_recompute, 4),
        },
    }


def bench_ft(fast: bool = False, out_path: str = DEFAULT_OUT) -> dict:
    results = {"workloads": {}}
    for name, n_vertices in WORKLOADS.items():
        if fast and name == "pagerank_1e6":
            continue            # gated row: CI runs the table full-size
        results["workloads"][name] = bench_ckpt_overhead(name, n_vertices)
    results["workloads"]["recovery_sssp"] = bench_recovery()
    if not fast:                # gated 10^6-edge row, like pagerank_1e6
        results["workloads"]["klane_resume"] = bench_klane_resume()
    with open(out_path, "w") as f:
        json.dump(results, f, indent=1, sort_keys=True)
        f.write("\n")
    return results


def csv_rows(results: dict) -> list[str]:
    rows = []
    for name, rec in results["workloads"].items():
        if "wall_none_s" in rec:
            derived = (f"overhead_async={rec['ratios']['overhead_async']};"
                       f"overhead_sync={rec['ratios']['overhead_sync']};"
                       f"ckpt_mb={rec['ckpt_mb']}")
            rows.append(f"ft/{name},{rec['per_iter_none_us']:.0f},{derived}")
        elif "wall_recompute_s" in rec:
            derived = (f"resume_over_recompute="
                       f"{rec['ratios']['resume_over_recompute']};"
                       f"bitexact={rec['bitexact']};"
                       f"lanes_dropped={rec['lanes_dropped']}")
            rows.append(f"ft/{name},{rec['wall_resume_s'] * 1e6:.0f},"
                        f"{derived}")
        else:
            derived = (f"exact_resume={rec['exact_resume']};"
                       f"reads_latest_only={rec['reads_latest_only']};"
                       f"iterations_lost={rec['iterations_lost']}")
            rows.append(f"ft/{name},{rec['recovery_restore_s'] * 1e6:.0f},"
                        f"{derived}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="drop the gated 10^6-edge workloads "
                         "(pagerank_1e6, klane_resume)")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()
    results = bench_ft(fast=args.fast, out_path=args.out)
    print("name,us_per_call,derived")
    for r in csv_rows(results):
        print(r)


if __name__ == "__main__":
    main()
