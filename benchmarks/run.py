"""Benchmark harness: one function per paper table/figure, plus kernel
micro-benchmarks and the roofline summary (if dry-run JSONs exist).

Prints ``name,us_per_call,derived`` CSV rows (harness contract).

    PYTHONPATH=src python -m benchmarks.run [--fast] [--table NAME]
"""

from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller graphs (CI-sized)")
    ap.add_argument("--table", default=None,
                    help="run a single table: sssp|pagerank|bm|giraphpp|"
                         "kernels|local_phase|dist_phase|partition|ingest|"
                         "ft|serve|obs|roofline")
    args = ap.parse_args()

    if args.table == "dist_phase":
        # must land before the first backend touch: the distributed A/B
        # needs a multi-device mesh, faked on CPU hosts.  Explicit-only
        # (not part of the default sweep) so the env override never leaks
        # into the single-device tables.
        os.environ.setdefault(
            "XLA_FLAGS", "--xla_force_host_platform_device_count=8")

    from benchmarks import kernel_bench, local_phase_bench, paper_tables

    rows: list[str] = []

    def want(name):
        return args.table in (None, name)

    if want("sssp"):
        kw = dict(rows_cols=(8, 110), partition_counts=(4, 8)) if args.fast \
            else dict()
        rows += [r.csv() for r in paper_tables.sssp_road(**kw)]
    if want("pagerank"):
        if args.fast:
            rows += [r.csv() for r in paper_tables.pagerank_tolerance(
                tols=(1e-2, 1e-4), n=1500)]
            rows += [r.csv() for r in paper_tables.pagerank_scalability(
                partition_counts=(4, 8), n=1500)]
        else:
            rows += [r.csv() for r in paper_tables.pagerank_tolerance()]
            rows += [r.csv() for r in paper_tables.pagerank_scalability()]
    if want("bm"):
        rows += [r.csv() for r in paper_tables.bipartite_matching_table()]
    if want("giraphpp"):
        n = 1500 if args.fast else 4000
        rows += [r.csv() for r in paper_tables.giraphpp_proxy(n=n)]
    if want("kernels"):
        rows += kernel_bench.bench_ell_spmv()
        rows += kernel_bench.bench_fused_pr_step()
        rows += kernel_bench.bench_fused_min_step()
    if want("local_phase"):
        rows += local_phase_bench.csv_rows(local_phase_bench.bench_local_phase())
    if args.table == "dist_phase":
        rows += local_phase_bench.dist_csv_rows(
            local_phase_bench.bench_dist_phase(fast=args.fast))
    if args.table == "partition":
        # explicit-only (full run_hybrid sweeps per partitioner; not part
        # of the default table sweep)
        from benchmarks import partition_bench
        rows += partition_bench.csv_rows(
            partition_bench.bench_partitioners(fast=args.fast))
    if args.table == "ingest":
        # explicit-only (spawns a fresh subprocess per measured build;
        # --fast drops the gated 10^7-edge workload, so CI runs it full)
        from benchmarks import ingest_bench
        rows += ingest_bench.csv_rows(
            ingest_bench.bench_ingest(fast=args.fast))
    if args.table == "ft":
        # explicit-only (checkpoint/recovery A/B; --fast drops the gated
        # 10^6-edge overhead workload, so CI runs it full)
        from benchmarks import ft_bench
        rows += ft_bench.csv_rows(ft_bench.bench_ft(fast=args.fast))
    if args.table == "serve":
        # explicit-only (K-lane vs sequential serving A/B; --fast drops
        # the gated 10^6-edge workload, so CI runs it full)
        from benchmarks import serve_bench
        rows += serve_bench.csv_rows(serve_bench.bench_serve(fast=args.fast))
    if args.table == "obs":
        # explicit-only (tracing-overhead A/B + BSP-vs-hybrid report
        # checks; --fast drops the gated 10^6-edge workload, so CI runs
        # it full)
        from benchmarks import obs_bench
        rows += obs_bench.csv_rows(obs_bench.bench_obs(fast=args.fast))
    if want("roofline"):
        rows += roofline_rows()

    print("name,us_per_call,derived")
    for r in rows:
        print(r)


def roofline_rows(out_dir: str = "results/dryrun") -> list[str]:
    """Summarize dry-run JSONs as CSV rows (us = dominant roofline term)."""
    import json
    rows = []
    if not os.path.isdir(out_dir):
        return rows
    for fn in sorted(os.listdir(out_dir)):
        if not fn.endswith(".json"):
            continue
        with open(os.path.join(out_dir, fn)) as f:
            rec = json.load(f)
        if rec.get("status") != "ok" or "roofline" not in rec:
            continue
        t = rec["roofline"]
        dom_t = max(t["t_compute_s"], t["t_memory_s"], t["t_collective_s"])
        derived = (f"dom={t['dominant']};tc={t['t_compute_s']:.3g};"
                   f"tm={t['t_memory_s']:.3g};tx={t['t_collective_s']:.3g};"
                   f"mem_gib={rec.get('memory',{}).get('bytes_per_device',0)/2**30:.2f}")
        rows.append(f"roofline/{rec['arch']}/{rec['shape']}/{rec['mesh']},"
                    f"{dom_t*1e6:.0f},{derived}")
    return rows


if __name__ == "__main__":
    main()
