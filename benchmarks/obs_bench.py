"""Observability overhead: the cost of watching the engine.

The obs subsystem's contract is two-sided: *disabled* it must cost
nothing (the executor with no trace hooks attached runs at raw-step-loop
speed and produces bit-identical counters), *enabled* the stepwise
:class:`~repro.obs.trace.TraceHook` (host-side exchange-bytes accounting
+ counter deltas per superstep) must stay a small tax.  Both claims are
measured on the same warmed jitted hybrid step, A/B/C from the same
state:

``pagerank_1e6`` / ``pagerank_1e5`` — PageRank on an R-MAT graph
(~10^6 / ~10^5 edges), per-superstep wall time over three modes:

  * ``step_raw_s``      — the bare jitted step, blocked,
  * ``step_disabled_s`` — one step through ``run_engine`` with tracing
                          off (zero hooks — the production default),
  * ``step_enabled_s``  — one step through ``run_engine`` with the
                          stepwise TraceHook.

Timing is **paired**: ``SAMPLES`` rounds each measure all three modes
back-to-back from the same warmed state, and the gated overhead ratio
is the *minimum over rounds* of the within-round ratio, clipped at 1::

    overhead_mode = max(1.0, min_i(t_mode[i] / t_raw[i]))

Why the floor estimator: the host work being measured is sub-millisecond
(quiescent check ~0.1 ms, exchange-bytes accounting ~0.25 ms, counter
fetches ~µs — measured directly on this fixture) against a ~1 s
XLA-CPU superstep that jitters by several percent between rounds on a
shared runner.  Mode-vs-mode wall clocks — even min-of-N or median
paired differences — therefore gate on scheduler luck, not on the
subsystem.  A *real* per-step regression (hot-path import doing work,
an added device sync, accidental tracing on the disabled path) is paid
in **every** round including the quietest one, so it survives the min
and fails the gate; symmetric noise does not.  The clip encodes that
engine overhead cannot be negative.  ``overhead_*_median`` (median of
the same per-round ratios, unclipped) is reported alongside for
transparency but is too noisy to gate at the 2% level.

``ratios.overhead_disabled`` (gated ``<= 1.02`` at the 10^6-edge size)
is the disabled path's tax; ``ratios.overhead_enabled`` (gated
``<= 1.10``) the enabled one.  ``counters_identical`` (gated) pins
separate chained ``iters``-superstep runs of the disabled AND traced
paths bit-identical to the raw loop — state and every paper counter.

``report_pagerank`` — the report CLI's cross-engine checks as gate
metrics: BSP and hybrid profiled through
:func:`~repro.obs.trace.phased_run` on one shared graph must reach the
same converged state with the hybrid run using strictly fewer global
barriers.

Fixture choices (``use_ell=False``, ``MAX_LOCAL_STEPS=32``) follow
``benchmarks/ft_bench.py`` for the same reasons: interpret-mode Pallas
would profile the interpreter, and the local-phase cap keeps a global
iteration bounded while staying conservative for the overhead gates
(cheaper iterations make a fixed per-step tax relatively larger).

Emits ``BENCH_obs.json`` (committed, trajectory-tracked); gates live in
``benchmarks/gates.json`` table ``obs``.  ``--fast`` drops the gated
10^6-edge workload (CI runs the table full-size).

    PYTHONPATH=src python -m benchmarks.run --table obs [--fast]
    PYTHONPATH=src python -m benchmarks.obs_bench [--fast] [--out PATH]
"""

from __future__ import annotations

import argparse
import json
import os
import statistics
import time

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(REPO_ROOT, "BENCH_obs.json")

N_PARTITIONS = 8
AVG_DEGREE = 8
OBS_ITERS = 6                   # chained iterations for the identity check
MAX_LOCAL_STEPS = 32            # see module docstring (ft_bench rationale)
SAMPLES = 10                    # paired timing rounds (median differences)
WORKLOADS = {
    "pagerank_1e5": 12_500,
    "pagerank_1e6": 125_000,
}


def _pagerank_fixture(n_vertices: int, tolerance: float = 1e-6):
    from repro.core import build_partitioned_graph, hash_partition
    from repro.core.apps import IncrementalPageRank
    from repro.core.apps.pagerank import pagerank_edge_weights
    from repro.data.graphs import rmat_graph

    edges, n = rmat_graph(n_vertices, avg_degree=AVG_DEGREE, seed=0)
    part = hash_partition(n, N_PARTITIONS, seed=0)
    w = pagerank_edge_weights(edges, n)
    graph = build_partitioned_graph(edges, n, part, weights=w,
                                    build_ell=False)
    return graph, IncrementalPageRank(tolerance=tolerance), len(edges)


def _identical(a, b) -> bool:
    import numpy as np

    ok = bool(np.array_equal(np.asarray(a.state["rank"]),
                             np.asarray(b.state["rank"])))
    for f in ("iterations", "net_messages", "net_local_messages",
              "mem_messages"):
        ok &= int(getattr(a.counters, f)) == int(getattr(b.counters, f))
    return ok and bool(np.array_equal(
        np.asarray(a.counters.pseudo_supersteps),
        np.asarray(b.counters.pseudo_supersteps)))


def bench_tracing_overhead(name: str, n_vertices: int,
                           iters: int = OBS_ITERS) -> dict:
    """A/B/C the per-iteration cost of tracing modes on PageRank."""
    import jax

    from repro.exec.driver import run_engine
    from repro.exec.policy import hybrid_policy
    from repro.obs.trace import Tracer, trace_hooks

    graph, prog, n_edges = _pagerank_fixture(n_vertices)
    policy = hybrid_policy(use_ell=False, collect_metrics=True,
                           max_local_steps=MAX_LOCAL_STEPS)
    jstep = jax.jit(lambda e: policy.step(graph, prog, e, None))
    es0 = jax.block_until_ready(jstep(policy.init(graph, prog, None)))
    max_iters = int(es0.counters.iterations) + iters

    one_iter = int(es0.counters.iterations) + 1
    tracer = Tracer()

    def step_raw():
        jax.block_until_ready(jstep(es0))

    def step_disabled():
        run_engine(graph, prog, policy, None, max_iters=one_iter,
                   hooks=trace_hooks(None), es=es0, jit_step=jstep)

    def step_enabled():
        run_engine(graph, prog, policy, None, max_iters=one_iter,
                   hooks=trace_hooks(tracer), es=es0, jit_step=jstep)

    modes = {"raw": step_raw, "disabled": step_disabled,
             "enabled": step_enabled}
    for fn in modes.values():       # untimed warmup pass per mode
        fn()
    times = {k: [] for k in modes}
    for _ in range(SAMPLES):        # paired rounds: drift hits all modes
        for k, fn in modes.items():
            t0 = time.perf_counter()
            fn()
            times[k].append(time.perf_counter() - t0)

    raw_med = statistics.median(times["raw"])

    def ratios(mode):               # within-round paired ratios
        return [m / r for m, r in zip(times[mode], times["raw"])]

    def overhead(mode):             # floor estimator — see module docstring
        return max(1.0, min(ratios(mode)))

    # counter identity needs real chained runs, untimed: drive each mode
    # `iters` supersteps from es0 and compare final state + counters
    es_raw = es0
    for _ in range(iters):
        es_raw = jax.block_until_ready(jstep(es_raw))
    es_dis = run_engine(graph, prog, policy, None, max_iters=max_iters,
                        hooks=trace_hooks(None), es=es0, jit_step=jstep).es
    chain_tracer = Tracer()
    es_en = run_engine(graph, prog, policy, None, max_iters=max_iters,
                       hooks=trace_hooks(chain_tracer), es=es0,
                       jit_step=jstep).es

    steps = [s for s in chain_tracer.spans if s.cat == "superstep"]
    return {
        "n_edges": n_edges,
        "iters": iters,
        "samples": SAMPLES,
        "step_raw_s": round(raw_med, 5),
        "step_disabled_s": round(statistics.median(times["disabled"]), 5),
        "step_enabled_s": round(statistics.median(times["enabled"]), 5),
        "per_iter_raw_us": round(raw_med * 1e6, 1),
        "counters_identical": int(_identical(es_raw, es_dis)
                                  and _identical(es_raw, es_en)),
        "trace_spans": len(steps),
        "trace_exchange_bytes": int(sum(s.args["exchange_bytes"]
                                        for s in steps)),
        "ratios": {
            "overhead_disabled": round(overhead("disabled"), 4),
            "overhead_enabled": round(overhead("enabled"), 4),
            "overhead_disabled_median": round(
                statistics.median(ratios("disabled")), 4),
            "overhead_enabled_median": round(
                statistics.median(ratios("enabled")), 4),
        },
    }


def bench_report_checks(n_vertices: int = 2_000,
                        tolerance: float = 1e-4) -> dict:
    """The report CLI's BSP-vs-hybrid cross-checks as gateable numbers."""
    import contextlib
    import io

    from repro.obs.report import run_report

    with contextlib.redirect_stdout(io.StringIO()):
        results = run_report(("bsp", "hybrid"), n_vertices=n_vertices,
                             tolerance=tolerance)
    checks = results.pop("checks")
    b, h = results["bsp"], results["hybrid"]
    return {
        "n_vertices": n_vertices,
        "barriers_bsp": b.total_barriers,
        "barriers_hybrid": h.total_barriers,
        "exchange_bytes_bsp": b.total_exchange_bytes,
        "exchange_bytes_hybrid": h.total_exchange_bytes,
        "local_compute_fraction_bsp":
            round(b.mean_local_compute_fraction, 4),
        "local_compute_fraction_hybrid":
            round(h.mean_local_compute_fraction, 4),
        "same_converged_state": int(checks["same_converged_state"]),
        "hybrid_fewer_barriers": int(checks["hybrid_fewer_barriers"]),
        "ratios": {
            "barriers_hybrid_over_bsp": round(
                h.total_barriers / b.total_barriers, 4),
        },
    }


def bench_obs(fast: bool = False, out_path: str = DEFAULT_OUT) -> dict:
    results = {"workloads": {}}
    for name, n_vertices in WORKLOADS.items():
        if fast and name == "pagerank_1e6":
            continue            # gated row: CI runs the table full-size
        results["workloads"][name] = bench_tracing_overhead(name, n_vertices)
    results["workloads"]["report_pagerank"] = bench_report_checks()
    with open(out_path, "w") as f:
        json.dump(results, f, indent=1, sort_keys=True)
        f.write("\n")
    return results


def csv_rows(results: dict) -> list[str]:
    rows = []
    for name, rec in results["workloads"].items():
        if "step_raw_s" in rec:
            derived = (
                f"overhead_disabled={rec['ratios']['overhead_disabled']};"
                f"overhead_enabled={rec['ratios']['overhead_enabled']};"
                f"counters_identical={rec['counters_identical']}")
            rows.append(f"obs/{name},{rec['per_iter_raw_us']:.0f},{derived}")
        else:
            derived = (
                f"barriers_hybrid_over_bsp="
                f"{rec['ratios']['barriers_hybrid_over_bsp']};"
                f"same_converged_state={rec['same_converged_state']};"
                f"local_frac_hybrid={rec['local_compute_fraction_hybrid']}")
            rows.append(f"obs/{name},{rec['barriers_hybrid']},{derived}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="drop the gated 10^6-edge workload (pagerank_1e6)")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()
    results = bench_obs(fast=args.fast, out_path=args.out)
    print("name,us_per_call,derived")
    for r in csv_rows(results):
        print(r)


if __name__ == "__main__":
    main()
