"""Engine-level A/B benchmark of the GraphHP delivery hot loops.

The paper's entire speedup comes from iterating the local phase a lot
(Algorithm 2), so the metric that matters most is the cost of ONE
pseudo-superstep (apply_phase -> deliver(local)); the once-per-iteration
remote delivery (exchange -> deliver(remote) feeding the global phase) is
the second hot path.  Implementations timed per workload:

  dense        the seed path: gather over every padded edge +
               combine_segments, per-channel segment-max message accounting
               inside the loop,
  ell          kernel-backed delivery: semiring channels dispatch to the
               Pallas `ell_spmv` sliced-ELL kernels, counters hoisted out
               (collect_metrics=False),
  fused        the whole pseudo-superstep through the fused `pr_step`
               (PageRank) / `min_step` (SSSP) kernel — deliver + apply in
               one VMEM-resident pass,
  remote_*     deliver(edges='remote') over the halo-fed frontier, dense
               vs. the halo-encoded remote-ELL kernel path.

The pagerank_skew workload adds hub destinations so the sliced-ELL row
binning engages (2+ degree bins) — the regime that used to bail out to
dense past ``ell_max_slices``.

The ``dist_phase`` table (``bench_dist_phase``) A/Bs the same question one
level up: a full `make_dist_hybrid_step` global iteration (exchange ->
global phase -> local convergence loop) under a fake multi-device mesh
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``), dense seed path
vs the now-default kernel path, with ``collect_metrics=True`` riding the
ELL tiles.  Emits BENCH_dist_phase.json.

Emits BENCH_local_phase.json (repo root by default) so the perf trajectory
is tracked per-PR, and returns harness CSV rows.

    PYTHONPATH=src python -m benchmarks.local_phase_bench [--out PATH]
    PYTHONPATH=src python -m benchmarks.run --fast --table dist_phase
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import numpy as np

import jax
import jax.numpy as jnp

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(REPO_ROOT, "BENCH_local_phase.json")
DIST_OUT = os.path.join(REPO_ROOT, "BENCH_dist_phase.json")


def _time_us(fn, *args, warmup=3, iters=20):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def _saturate(graph, prog, es, payload_value):
    """Fill the frontier: every vertex sent last step, has one pending
    message, and the halo table was filled by a real exchange — the
    steady-state shape of a busy iteration."""
    from repro.core.runtime import exchange

    vm = graph.vertex_mask
    pending = {}
    for ch in prog.channels:
        (dt, _), = ch.components
        pending[ch.name] = ((jnp.where(vm, payload_value, 0).astype(dt),), vm)
    es = dataclasses.replace(es, send=vm, pending=pending,
                             export_out=es.out, export_send=vm)
    return exchange(graph, es)


def _saturated_state(graph, prog, vdata, payload_value):
    from repro.core.engine_hybrid import init_hybrid

    return _saturate(graph, prog, init_hybrid(graph, prog, vdata),
                     payload_value)


def _pseudo_superstep(graph, prog, vdata, use_ell, collect_metrics):
    from repro.core.runtime import apply_phase, deliver
    from repro.core.vertex_program import StepInfo

    info = StepInfo(superstep=1, pseudo_step=1, phase="local")

    def step(es):
        es = apply_phase(graph, prog, es, graph.vertex_mask, info, vdata)
        es, _ = deliver(graph, prog, es, edges="local", use_ell=use_ell,
                        collect_metrics=collect_metrics)
        return es

    return jax.jit(step)


def _remote_deliver(graph, prog, use_ell, collect_metrics):
    from repro.core.runtime import deliver

    def step(es):
        es, _ = deliver(graph, prog, es, edges="remote", use_ell=use_ell,
                        collect_metrics=collect_metrics)
        return es

    return jax.jit(step)


def _fused_pr_step_fn(graph, prog):
    """One fused PageRank loop body: the engine's own fused step
    (`engine_hybrid.fused_step_fn`, the same closure
    `_fused_pr_local_phase` iterates) + the collect_metrics=False
    has/running/export bookkeeping."""
    from repro.core.engine_hybrid import fused_step_fn

    kstep, _, _ = fused_step_fn(graph, prog, "pr_step", graph.n_partitions)

    def step(rank, delta, send, eo, esend):
        rank_n, d_in, send_n = kstep(rank, delta, send)
        eo = eo + jnp.where(send_n, d_in, 0.0)
        esend = jnp.logical_or(esend, send_n)
        running = jnp.any(d_in > 0, axis=1)
        return rank_n, d_in, send_n, eo, esend, running

    return jax.jit(step)


def _fused_min_step_fn(graph, prog):
    """One fused min-semiring loop body: the engine's own fused step + the
    collect_metrics=False bookkeeping of `_fused_min_local_phase`."""
    from repro.core.engine_hybrid import fused_step_fn

    kstep, _, _ = fused_step_fn(graph, prog, "min_step", graph.n_partitions)

    def step(x, send, eo, esend):
        x_n, d_n, send_n = kstep(x, send)
        eo = jnp.where(send_n, x_n, eo)
        esend = jnp.logical_or(esend, send_n)
        running = jnp.any(d_n < jnp.inf, axis=1)
        return x_n, send_n, eo, esend, running

    return jax.jit(step)


def _bench_workload(results, name, graph, prog, payload_value, fused=None):
    """Dense/ELL/fused local pseudo-superstep + dense/ELL remote delivery."""
    es = _saturated_state(graph, prog, None, payload_value)
    rec = {"graph": graph.shape_summary, "kl": graph.kl,
           "bins": [len(graph.local_ell), len(graph.remote_ell)]}

    dense = _time_us(_pseudo_superstep(graph, prog, None, False, True), es)
    ell = _time_us(_pseudo_superstep(graph, prog, None, True, False), es)
    rec.update(dense_us=dense, ell_us=ell, speedup_ell=dense / ell)

    if fused == "pr_step":
        fstep = _fused_pr_step_fn(graph, prog)
        rec["fused_us"] = _time_us(
            fstep, es.state["rank"],
            jnp.where(graph.vertex_mask, payload_value, 0.0),
            graph.vertex_mask, jnp.zeros_like(es.state["rank"]),
            jnp.zeros_like(graph.vertex_mask))
    elif fused == "min_step":
        fstep = _fused_min_step_fn(graph, prog)
        ch_name = prog.channels[0].name
        rec["fused_us"] = _time_us(
            fstep, es.state[ch_name].astype(jnp.float32), graph.vertex_mask,
            es.state[ch_name].astype(jnp.float32),
            jnp.zeros_like(graph.vertex_mask))
    if "fused_us" in rec:
        rec["speedup_fused"] = dense / rec["fused_us"]
        rec["speedup_fused_vs_ell"] = ell / rec["fused_us"]

    rdense = _time_us(_remote_deliver(graph, prog, False, True), es)
    rell = _time_us(_remote_deliver(graph, prog, True, False), es)
    rec.update(remote_dense_us=rdense, remote_ell_us=rell,
               speedup_remote=rdense / rell)

    results["workloads"][name] = rec
    return rec


def bench_local_phase(out_path: str = DEFAULT_OUT) -> dict:
    from repro.core import (bfs_partition, build_partitioned_graph,
                            hash_partition)
    from repro.core.apps import SSSP, IncrementalPageRank
    from repro.core.apps.pagerank import pagerank_edge_weights
    from repro.data.graphs import grid_graph, rmat_graph

    results: dict = {"meta": {"backend": jax.default_backend(),
                              "mode": "interpret" if
                              jax.default_backend() != "tpu" else "mosaic"},
                     "workloads": {}}

    # --- PageRank, the --fast web workload -------------------------------
    edges, n = rmat_graph(1500, avg_degree=8, seed=1)
    w = pagerank_edge_weights(edges, n)
    part = bfs_partition(edges, n, 8, seed=1)
    graph = build_partitioned_graph(edges, n, part, weights=w)
    _bench_workload(results, "pagerank_fast", graph,
                    IncrementalPageRank(tolerance=1e-4), 0.01,
                    fused="pr_step")

    # --- PageRank with hub skew: sliced-ELL binning engaged --------------
    rng = np.random.RandomState(2)
    hubs = np.stack([rng.randint(0, n, size=4000),
                     rng.randint(0, 6, size=4000)], axis=1)
    edges_sk = np.unique(np.concatenate([edges, hubs]), axis=0)
    edges_sk = edges_sk[edges_sk[:, 0] != edges_sk[:, 1]]
    w_sk = pagerank_edge_weights(edges_sk, n)
    part_sk = hash_partition(n, 8, seed=2)
    graph_sk = build_partitioned_graph(edges_sk, n, part_sk, weights=w_sk,
                                       ell_base_slices=32)
    assert len(graph_sk.local_ell) > 1, "skew workload should spill bins"
    _bench_workload(results, "pagerank_skew", graph_sk,
                    IncrementalPageRank(tolerance=1e-4), 0.01,
                    fused="pr_step")

    # --- SSSP, the --fast road workload ----------------------------------
    edges, w, n = grid_graph(8, 110, seed=0)
    part = bfs_partition(edges, n, 8, seed=0)
    graph = build_partitioned_graph(edges, n, part, weights=w)
    _bench_workload(results, "sssp_fast", graph, SSSP(source=0), 1.0,
                    fused="min_step")

    if out_path:
        with open(out_path, "w") as f:
            json.dump(results, f, indent=1)
    return results


# ---------------------------------------------------------------------------
# dist_phase: the distributed hybrid step under a fake multi-device mesh
# ---------------------------------------------------------------------------

def _dist_step(graph, prog, mesh, axes, payload_value, use_ell,
               collect_metrics):
    """Jitted sharded (graph, es) -> es distributed step + its operands."""
    import jax
    from jax.sharding import NamedSharding

    from repro.core.distributed import (_es_specs, make_dist_hybrid_step,
                                        shard0_specs)
    from repro.core.engine_hybrid import init_hybrid

    step = make_dist_hybrid_step(prog, mesh, axes=axes, use_ell=use_ell,
                                 collect_metrics=collect_metrics)
    es = init_hybrid(graph, prog, None, use_ell=use_ell,
                     collect_metrics=collect_metrics)
    es = _saturate(graph, prog, es, payload_value)
    gs = jax.tree.map(lambda s: NamedSharding(mesh, s),
                      shard0_specs(graph, axes))
    ess = jax.tree.map(lambda s: NamedSharding(mesh, s), _es_specs(es, axes))
    graph_d = jax.device_put(graph, gs)
    es_d = jax.device_put(es, ess)
    return jax.jit(step, in_shardings=(gs, ess)), graph_d, es_d


def bench_dist_phase(out_path: str = DIST_OUT, fast: bool = True) -> dict:
    """A/B one full distributed global iteration (the `make_dist_hybrid_step`
    jittable), dense seed path vs the default kernel path, on a mesh over
    every available device.  Run under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (benchmarks.run
    sets it for ``--table dist_phase``); the partition axis shards one
    partition per device."""
    import jax

    from repro.core import bfs_partition, build_partitioned_graph
    from repro.core.apps import SSSP, IncrementalPageRank
    from repro.core.apps.pagerank import pagerank_edge_weights
    from repro.data.graphs import grid_graph, rmat_graph

    n_dev = len(jax.devices())
    assert n_dev >= 2 and n_dev % 2 == 0, \
        f"dist_phase needs a multi-device mesh, got {n_dev} devices " \
        "(set XLA_FLAGS=--xla_force_host_platform_device_count=8)"
    axes = ("data", "model")
    mesh = jax.make_mesh((2, n_dev // 2), axes)

    results: dict = {"meta": {"backend": jax.default_backend(),
                              "devices": n_dev,
                              "mesh": [2, n_dev // 2],
                              "mode": "interpret" if
                              jax.default_backend() != "tpu" else "mosaic"},
                     "workloads": {}}

    n_pr = 1500 if fast else 4000
    edges, n = rmat_graph(n_pr, avg_degree=8, seed=1)
    w = pagerank_edge_weights(edges, n)
    part = bfs_partition(edges, n, n_dev, seed=1)
    g_pr = build_partitioned_graph(edges, n, part, weights=w,
                                   edge_blocks=n_dev)  # one block per device

    rc = (8, 110) if fast else (8, 300)
    edges, w, n = grid_graph(*rc, seed=0)
    part = bfs_partition(edges, n, n_dev, seed=0)
    g_ss = build_partitioned_graph(edges, n, part, weights=w,
                                   edge_blocks=n_dev)

    for name, graph, prog, payload in (
            ("pagerank", g_pr, IncrementalPageRank(tolerance=1e-4), 0.01),
            ("sssp", g_ss, SSSP(source=0), 1.0)):
        rec = {"graph": graph.shape_summary,
               "bins": [len(graph.local_ell), len(graph.remote_ell)]}
        variants = {
            # the seed behavior: dense gather/segment everywhere
            "dense": dict(use_ell=False, collect_metrics=True),
            # the new default: kernel path, counters riding the tiles
            "ell": dict(use_ell=True, collect_metrics=True),
            # the perf configuration: kernel path, accounting dropped
            "ell_nometrics": dict(use_ell=True, collect_metrics=False),
        }
        for vname, kw in variants.items():
            step, graph_d, es_d = _dist_step(graph, prog, mesh, axes,
                                             payload, **kw)
            rec[f"{vname}_us"] = _time_us(step, graph_d, es_d,
                                          warmup=2, iters=10)
        rec["speedup_ell"] = rec["dense_us"] / rec["ell_us"]
        rec["speedup_ell_nometrics"] = rec["dense_us"] / rec["ell_nometrics_us"]
        results["workloads"][name] = rec

    if out_path:
        with open(out_path, "w") as f:
            json.dump(results, f, indent=1)
    return results


def dist_csv_rows(results: dict) -> list[str]:
    rows = []
    mesh = "x".join(map(str, results["meta"]["mesh"]))
    for name, r in results["workloads"].items():
        meta = f"mesh={mesh};bins={r['bins']};graph={r['graph']}"
        for variant in ("dense", "ell", "ell_nometrics"):
            sp = {"dense": 1.0, "ell": r["speedup_ell"],
                  "ell_nometrics": r["speedup_ell_nometrics"]}[variant]
            rows.append(f"dist_phase/{name}/{variant},"
                        f"{r[f'{variant}_us']:.0f},speedup={sp:.2f};{meta}")
    return rows


def csv_rows(results: dict) -> list[str]:
    rows = []
    for name, r in results["workloads"].items():
        meta = f"kl={r['kl']};bins={r['bins']};graph={r['graph']}"
        for variant in ("dense", "ell", "fused", "remote_dense",
                        "remote_ell"):
            us = r.get(f"{variant}_us")
            if us is None:
                continue
            sp = {"remote_ell": r.get("speedup_remote", 1.0),
                  "remote_dense": 1.0}.get(
                      variant, r.get(f"speedup_{variant}", 1.0))
            rows.append(f"local_phase/{name}/{variant},{us:.0f},"
                        f"speedup={sp:.2f};{meta}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=DEFAULT_OUT,
                    help="where to write BENCH_local_phase.json")
    args = ap.parse_args()
    results = bench_local_phase(args.out)
    print("name,us_per_call,derived")
    for row in csv_rows(results):
        print(row)


if __name__ == "__main__":
    import sys
    sys.path.insert(0, REPO_ROOT)
    sys.path.insert(0, os.path.join(REPO_ROOT, "src"))
    main()
