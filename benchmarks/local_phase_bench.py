"""Engine-level A/B benchmark of the GraphHP local-phase hot loop.

The paper's entire speedup comes from iterating the local phase a lot
(Algorithm 2), so the metric that matters is the cost of ONE pseudo-superstep
(apply_phase -> deliver(local)).  Three implementations are timed on the
--fast PageRank and SSSP workloads:

  dense   the seed path: gather over every padded edge + combine_segments,
          per-channel segment-max message accounting inside the loop,
  ell     kernel-backed delivery: semiring channels dispatch to the Pallas
          `ell_spmv` ELL kernel, counters hoisted out (collect_metrics=False),
  fused   (PageRank only) the whole pseudo-superstep through the fused
          `pr_step` kernel — deliver + apply in one VMEM-resident pass.

Emits BENCH_local_phase.json (repo root by default) so the perf trajectory
is tracked per-PR, and returns harness CSV rows.

    PYTHONPATH=src python -m benchmarks.local_phase_bench [--out PATH]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

import jax
import jax.numpy as jnp

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(REPO_ROOT, "BENCH_local_phase.json")


def _time_us(fn, *args, warmup=3, iters=20):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def _saturated_state(graph, prog, vdata, payload_value):
    """EngineState with a full frontier: every vertex sent last step and has
    one pending message — the steady-state shape of a busy local phase."""
    import dataclasses
    from repro.core.engine_hybrid import init_hybrid

    es = init_hybrid(graph, prog, vdata)
    vm = graph.vertex_mask
    pending = {}
    for ch in prog.channels:
        (dt, _), = ch.components
        pending[ch.name] = ((jnp.where(vm, payload_value, 0).astype(dt),), vm)
    return dataclasses.replace(es, send=vm, pending=pending)


def _pseudo_superstep(graph, prog, vdata, use_ell, collect_metrics):
    from repro.core.runtime import apply_phase, deliver
    from repro.core.vertex_program import StepInfo

    info = StepInfo(superstep=1, pseudo_step=1, phase="local")

    def step(es):
        es = apply_phase(graph, prog, es, graph.vertex_mask, info, vdata)
        es, _ = deliver(graph, prog, es, edges="local", use_ell=use_ell,
                        collect_metrics=collect_metrics)
        return es

    return jax.jit(step)


def _fused_step(graph, prog):
    """One fused-loop body (mirrors engine_hybrid._fused_pr_local_phase
    with collect_metrics=False): kernel + has/running/export bookkeeping."""
    from repro.core.runtime import flat_ell
    from repro.kernels.common import default_interpret
    from repro.kernels.pr_step import fused_pr_step

    p, vp, kl = graph.n_partitions, graph.vp, graph.kl
    idx, val, msk = flat_ell(graph, p)
    interpret = default_interpret()

    def step(rank, delta, send, eo, esend):
        rank_n, d_in, send_n = fused_pr_step(
            idx, val, msk, delta.reshape(-1), send.reshape(-1),
            rank.reshape(-1), damping=prog.damping, tol=prog.tol,
            interpret=interpret)
        rank_n = rank_n.reshape(p, vp)
        d_in = d_in.reshape(p, vp)
        send_n = send_n.reshape(p, vp)
        eo = eo + jnp.where(send_n, d_in, 0.0)
        esend = jnp.logical_or(esend, send_n)
        running = jnp.any(d_in > 0, axis=1)
        return rank_n, d_in, send_n, eo, esend, running

    return jax.jit(step)


def bench_local_phase(out_path: str = DEFAULT_OUT) -> dict:
    from repro.core import bfs_partition, build_partitioned_graph
    from repro.core.apps import SSSP, IncrementalPageRank
    from repro.core.apps.pagerank import pagerank_edge_weights
    from repro.data.graphs import grid_graph, rmat_graph

    results: dict = {"meta": {"backend": jax.default_backend(),
                              "mode": "interpret" if
                              jax.default_backend() != "tpu" else "mosaic"},
                     "workloads": {}}

    # --- PageRank, the --fast web workload -------------------------------
    edges, n = rmat_graph(1500, avg_degree=8, seed=1)
    w = pagerank_edge_weights(edges, n)
    part = bfs_partition(edges, n, 8, seed=1)
    graph = build_partitioned_graph(edges, n, part, weights=w)
    prog = IncrementalPageRank(tolerance=1e-4)
    es = _saturated_state(graph, prog, None, 0.01)
    dense = _time_us(_pseudo_superstep(graph, prog, None, False, True), es)
    ell = _time_us(_pseudo_superstep(graph, prog, None, True, False), es)
    fstep = _fused_step(graph, prog)
    fused = _time_us(
        fstep, es.state["rank"],
        jnp.where(graph.vertex_mask, 0.01, 0.0), graph.vertex_mask,
        jnp.zeros_like(es.state["rank"]), jnp.zeros_like(graph.vertex_mask))
    results["workloads"]["pagerank_fast"] = {
        "graph": graph.shape_summary, "kl": graph.kl,
        "dense_us": dense, "ell_us": ell, "fused_us": fused,
        "speedup_ell": dense / ell, "speedup_fused": dense / fused,
    }

    # --- SSSP, the --fast road workload ----------------------------------
    edges, w, n = grid_graph(8, 110, seed=0)
    part = bfs_partition(edges, n, 8, seed=0)
    graph = build_partitioned_graph(edges, n, part, weights=w)
    prog = SSSP(source=0)
    es = _saturated_state(graph, prog, None, 1.0)
    dense = _time_us(_pseudo_superstep(graph, prog, None, False, True), es)
    ell = _time_us(_pseudo_superstep(graph, prog, None, True, False), es)
    results["workloads"]["sssp_fast"] = {
        "graph": graph.shape_summary, "kl": graph.kl,
        "dense_us": dense, "ell_us": ell,
        "speedup_ell": dense / ell,
    }

    if out_path:
        with open(out_path, "w") as f:
            json.dump(results, f, indent=1)
    return results


def csv_rows(results: dict) -> list[str]:
    rows = []
    for name, r in results["workloads"].items():
        for variant in ("dense", "ell", "fused"):
            us = r.get(f"{variant}_us")
            if us is None:
                continue
            sp = r.get(f"speedup_{variant}", 1.0)
            rows.append(f"local_phase/{name}/{variant},{us:.0f},"
                        f"speedup={sp:.2f};kl={r['kl']};graph={r['graph']}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=DEFAULT_OUT,
                    help="where to write BENCH_local_phase.json")
    args = ap.parse_args()
    results = bench_local_phase(args.out)
    print("name,us_per_call,derived")
    for row in csv_rows(results):
        print(row)


if __name__ == "__main__":
    import sys
    sys.path.insert(0, REPO_ROOT)
    sys.path.insert(0, os.path.join(REPO_ROOT, "src"))
    main()
