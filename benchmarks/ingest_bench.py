"""Ingestion A/B: in-memory vs out-of-core partition+build, wall + peak RSS.

The survey literature (Ammar & Özsu) puts ingestion + partitioning at a
routinely *dominant* share of end-to-end time on real datasets, and memory
is what caps the in-memory builder's reach — so this table measures both,
honestly: each build runs in a **fresh subprocess** and reports

  * ``wall_s``        — partition (the workload's partitioner, seed 0) +
                        build, excluding imports and backend warmup,
  * ``peak_rss_mb``   — ``ru_maxrss`` *above* a post-import baseline
                        (imports + jax init + staged-dir open), i.e. the
                        memory the build itself added,
  * ``digest``        — :func:`repro.io.graph_digest` of the produced
                        ``PartitionedGraph``.

The in-memory side loads the staged edges into RAM and runs the classic
``make_partition`` + ``build_partitioned_graph``; the out-of-core side
runs ``build_partitioned_graph_from_path`` over the same staged directory.
Digest equality across the two subprocesses is the bit-identity check at
every size — no arrays cross the process boundary.

Workloads are R-MAT at ~10^5 / 10^6 / 10^7 edges (``--fast`` drops the
largest).  ELL layouts are built at the smallest size (cheap, keeps the
kernel-path arrays under the identity check) and skipped above it, where
the padded ELL product would dominate both sides identically and the
interesting number is the ingestion pipeline itself.  Emits
``BENCH_ingest.json`` (committed, trajectory-tracked);
``benchmarks/gates.json`` gates ``peak_rss_ooc_over_inmem <= 0.5`` at the
largest size plus digest equality everywhere, via ``check_gates.py``.

    PYTHONPATH=src python -m benchmarks.run --table ingest [--fast]
    PYTHONPATH=src python -m benchmarks.ingest_bench [--fast] [--out PATH]
"""

from __future__ import annotations

import argparse
import gc
import json
import os
import resource
import subprocess
import sys
import tempfile
import time

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(REPO_ROOT, "BENCH_ingest.json")

N_PARTITIONS = 8
AVG_DEGREE = 8
# name -> (n_vertices, partitioner, build_ell).  The 10^7 row — the RSS
# gate — runs the hash labeling: it balances *in-edges* across shards, so
# peak memory measures the pipeline rather than the padded product (fennel
# clusters R-MAT's hubs into one partition, skewing Ep until the final
# padded arrays — identical on both sides — dominate either peak; that
# layout skew is a ROADMAP item, not an ingestion property).  Fennel takes
# the two smaller rows: external-CSR labeling and kernel-layout (ELL)
# bit-identity stay covered end to end.
WORKLOADS = {
    "rmat_1e5": (12_500, "fennel", True),
    "rmat_1e6": (125_000, "fennel", False),
    "rmat_1e7": (1_250_000, "hash", False),
}


def _maxrss_mb() -> float:
    ru = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    return ru / 1024.0          # linux reports KiB


def run_child(mode: str, staged: str, k: int, partitioner: str,
              build_ell: bool, chunk_edges: int, n: int = 0) -> None:
    """One measured build in this (fresh) process; JSON on stdout.
    (Subprocesses matter twice over: ru_maxrss is a per-process high-water
    mark that Linux carries across exec, so builds must not share a
    process with each other or with a fat parent.)"""
    import jax.numpy as jnp

    from repro.io import graph_digest
    from repro.io.readers import StagedEdgeSource

    if mode == "stage":
        from repro.data.graphs import materialize
        src = materialize(staged, "rmat", n=n, avg_degree=AVG_DEGREE,
                          seed=1)
        print(json.dumps({"n_vertices": src.n_vertices,
                          "n_edges": src.n_edges}))
        return
    src = StagedEdgeSource(staged)
    jnp.zeros(8).block_until_ready()        # backend init lands in baseline
    gc.collect()
    rss0 = _maxrss_mb()
    t0 = time.perf_counter()
    if mode == "inmem":
        from repro.core import build_partitioned_graph
        from repro.partition import make_partition
        edges, w = src.load_arrays()                     # genuinely in RAM
        part = make_partition(partitioner, edges, src.n_vertices, k,
                              seed=0)
        graph = build_partitioned_graph(edges, src.n_vertices, part,
                                        weights=w, build_ell=build_ell)
    elif mode == "ooc":
        from repro.io import build_partitioned_graph_from_path
        graph = build_partitioned_graph_from_path(
            staged, partitioner, k, chunk_edges=chunk_edges,
            partition_seed=0, build_ell=build_ell)
    else:
        raise ValueError(mode)
    wall = time.perf_counter() - t0
    rss1 = _maxrss_mb()
    print(json.dumps({
        "mode": mode, "wall_s": round(wall, 3),
        "peak_rss_mb": round(max(rss1 - rss0, 0.0), 1),
        "baseline_rss_mb": round(rss0, 1),
        "shape": graph.shape_summary,
        "digest": graph_digest(graph),
    }))


def _spawn(mode: str, staged: str, k: int, partitioner: str,
           build_ell: bool, chunk_edges: int, n: int = 0) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(REPO_ROOT, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    cmd = [sys.executable, "-m", "benchmarks.ingest_bench", "--child", mode,
           "--staged", staged, "--k", str(k), "--partitioner", partitioner,
           "--chunk-edges", str(chunk_edges), "--n", str(n)]
    if build_ell:
        cmd.append("--build-ell")
    out = subprocess.run(cmd, cwd=REPO_ROOT, env=env, capture_output=True,
                         text=True)
    if out.returncode != 0:
        raise RuntimeError(f"ingest child {mode} failed:\n{out.stderr}")
    return json.loads(out.stdout.strip().splitlines()[-1])


def bench_ingest(out_path: str = DEFAULT_OUT, fast: bool = False,
                 chunk_edges: int = 1 << 20) -> dict:
    import jax

    results: dict = {"meta": {"backend": jax.default_backend(),
                              "n_partitions": N_PARTITIONS,
                              "avg_degree": AVG_DEGREE,
                              "chunk_edges": chunk_edges,
                              "fast": bool(fast),
                              "rss_metric": "ru_maxrss above post-import "
                                            "baseline, fresh subprocess "
                                            "per build"},
               "workloads": {}}
    names = list(WORKLOADS)[:2] if fast else list(WORKLOADS)
    with tempfile.TemporaryDirectory() as tmp:
        for name in names:
            n, partitioner, build_ell = WORKLOADS[name]
            staged = os.path.join(tmp, name)
            t0 = time.perf_counter()
            staged_meta = _spawn("stage", staged, N_PARTITIONS,
                                 partitioner, False, chunk_edges, n=n)
            stage_s = time.perf_counter() - t0
            rec: dict = {"graph": f"V={staged_meta['n_vertices']} "
                                  f"E={staged_meta['n_edges']} "
                                  f"k={N_PARTITIONS}",
                         "partitioner": partitioner,
                         "build_ell": build_ell,
                         "stage_s": round(stage_s, 3)}
            for mode in ("inmem", "ooc"):
                child = _spawn(mode, staged, N_PARTITIONS, partitioner,
                               build_ell, chunk_edges)
                rec[mode] = {k: v for k, v in child.items() if k != "mode"}
                print(f"{name}/{mode}: wall {child['wall_s']}s, "
                      f"peak rss +{child['peak_rss_mb']}MB "
                      f"(baseline {child['baseline_rss_mb']}MB)")
            rec["bitexact"] = rec["inmem"]["digest"] == rec["ooc"]["digest"]
            rec["ratios"] = {
                "peak_rss_ooc_over_inmem":
                    round(rec["ooc"]["peak_rss_mb"]
                          / max(rec["inmem"]["peak_rss_mb"], 1e-9), 3),
                "wall_ooc_over_inmem":
                    round(rec["ooc"]["wall_s"]
                          / max(rec["inmem"]["wall_s"], 1e-9), 3),
            }
            results["workloads"][name] = rec
    if out_path:
        with open(out_path, "w") as f:
            json.dump(results, f, indent=1)
    return results


def csv_rows(results: dict) -> list[str]:
    rows = []
    for name, r in results["workloads"].items():
        for mode in ("inmem", "ooc"):
            m = r[mode]
            derived = (f"peak_rss_mb={m['peak_rss_mb']};"
                       f"bitexact={r['bitexact']};"
                       f"rss_ratio={r['ratios']['peak_rss_ooc_over_inmem']};"
                       f"{r['graph'].replace(' ', ';')}")
            rows.append(f"ingest/{name}/{mode},{m['wall_s'] * 1e6:.0f},"
                        f"{derived}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--child", default=None,
                    choices=("inmem", "ooc", "stage"),
                    help="internal: run one measured build and print json")
    ap.add_argument("--staged", default=None)
    ap.add_argument("--k", type=int, default=N_PARTITIONS)
    ap.add_argument("--partitioner", default="fennel")
    ap.add_argument("--n", type=int, default=0,
                    help="internal: vertex count for --child stage")
    ap.add_argument("--build-ell", action="store_true")
    ap.add_argument("--chunk-edges", type=int, default=1 << 20)
    ap.add_argument("--fast", action="store_true",
                    help="drop the 10^7-edge workload")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()
    if args.child:
        run_child(args.child, args.staged, args.k, args.partitioner,
                  args.build_ell, args.chunk_edges, n=args.n)
        return
    results = bench_ingest(args.out, fast=args.fast,
                           chunk_edges=args.chunk_edges)
    print("name,us_per_call,derived")
    for row in csv_rows(results):
        print(row)


if __name__ == "__main__":
    sys.path.insert(0, REPO_ROOT)
    sys.path.insert(0, os.path.join(REPO_ROOT, "src"))
    main()
